"""Beyond-paper: IVF coarse partitioning x ICQ two-step (production ANN
deployment shape) — the ops/MAP frontier past the paper's Figure 1."""
from __future__ import annotations

import time

import jax

from benchmarks.common import code_bits, evaluate, header
from repro.configs.base import ICQConfig
from repro.core import fit, mean_average_precision
from repro.core.ivf import build_ivf, ivf_two_step_search
from repro.data import make_table1_dataset


def run(full: bool = False, seed: int = 0):
    rows = []
    n = 10000 if full else 4000
    nq = 500 if full else 150
    xtr, ytr, xte, yte = make_table1_dataset("dataset3")
    xtr, ytr, xte, yte = xtr[:n], ytr[:n], xte[:nq], yte[:nq]
    cfg = ICQConfig(d=16, num_codebooks=8,
                    codebook_size=256 if full else 64, num_fast=2)
    t0 = time.time()
    m = fit(jax.random.PRNGKey(seed), xtr, ytr, cfg, mode="icq",
            epochs=8 if full else 5)
    fit_s = time.time() - t0
    emb_db, emb_q = m.embed(xtr), m.embed(xte)
    ivf = build_ivf(jax.random.PRNGKey(seed + 1), emb_db,
                    n_lists=128 if full else 64)
    for n_probe in (4, 8, 16):
        t0 = time.time()
        r = ivf_two_step_search(emb_q, m.codes, m.C, m.structure, ivf,
                                50, n_probe)
        jax.block_until_ready(r.indices)
        us = (time.time() - t0) / nq * 1e6
        mapv = float(mean_average_precision(r.indices, ytr, yte))
        row = dict(figure="beyond_ivf", dataset=f"dataset3@probe{n_probe}",
                   method="ivf+icq", code_bits=code_bits(cfg),
                   map=round(mapv, 4), avg_ops=round(float(r.avg_ops), 3),
                   pass_rate=round(float(r.pass_rate), 4),
                   fit_s=round(fit_s, 1), search_us=round(us, 1))
        print(",".join(str(v) for v in row.values()), flush=True)
        rows.append(row)
    mapv, ops, pr, us = evaluate(m, xte, yte, ytr)
    print(f"beyond_ivf,dataset3,icq_only,{code_bits(cfg)},{mapv:.4f},"
          f"{ops:.3f},{pr:.4f},{fit_s:.1f},{us:.1f}", flush=True)
    return rows


if __name__ == "__main__":
    header()
    run()
