"""Shared benchmark harness: fit a method, search, report MAP + AvgOps.

Every figure benchmark emits CSV rows:
    figure,dataset,method,code_bits,map,avg_ops,pass_rate,fit_s,search_us
CPU-reduced sizes by default (--full for paper-scale); the *comparisons*
(same code length, same quantizer size, same data) mirror the paper's
protocol exactly.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ICQConfig
from repro.core import (adc_search, fit, mean_average_precision,
                        two_step_search)
from repro.core.baselines import fit_pqn, fit_sq


def code_bits(cfg: ICQConfig) -> int:
    return int(cfg.num_codebooks * np.log2(cfg.codebook_size))


def host_copy(tree):
    """Copy a warm result pytree to host numpy, releasing its device
    buffers before a timing loop starts.

    The engine benches warm each search once and keep the result around
    for the report row (recall, avg_ops).  Holding those jax Arrays
    across the timed calls pins their device allocations, so every
    timed batch re-allocates its top-k carry instead of reusing the
    warm call's freed buffers — and a donating engine (the pipelined
    executor, DESIGN.md §13) can never actually donate into them.  Copy
    the warm result out first, then time against released buffers.
    ``np.array`` both blocks until the value is ready and forces a real
    host copy (``np.asarray`` may alias the device buffer on CPU).
    """
    return jax.tree_util.tree_map(lambda x: np.array(x), tree)


def recall_at_k(retrieved, truth, k=None) -> float:
    """THE benchmark recall: delegates to the oracle-tested
    ``repro.eval.recall_at_k`` (set overlap, -1 padding aware, k > n
    measured against the neighbors that exist) so every figure script
    and engine bench scores identically."""
    from repro import eval as eval_mod

    return eval_mod.recall_at_k(np.asarray(retrieved), np.asarray(truth),
                                k)


def engine_ground_truth(queries, codes, C, k: int = 10, *,
                        query_chunk: int = 32):
    """The engine benches' shared reference ranking: the full f32
    quantized-ADC top-k over the coded database.  This isolates engine
    pruning/precision loss (IVF probing, eq. 2, int8 LUTs, 4-bit slabs)
    from quantization error — random synthetic codes make exact-L2
    recall meaningless for engine comparisons.  For recall against the
    *exact* brute-force neighbors (the pareto sweep), use
    ``repro.eval.ground_truth`` instead."""
    from repro.core.search import adc_search

    return adc_search(queries, codes, C, k, backend="jnp",
                      query_chunk=query_chunk).indices


def evaluate(model, xte, yte, ytr, topk: int = 50, backend: str = "jnp"):
    """(map, avg_ops, pass_rate, search_us_per_query).

    ``backend`` selects the batched search engine ("jnp" | "pallas" |
    "auto" — core.search dispatch); the whole query batch goes through
    one vectorized call.
    """
    emb = model.embed(xte)
    t0 = time.time()
    if model.mode == "icq":
        res = two_step_search(emb, model.codes, model.C, model.structure,
                              topk, backend=backend)
    else:
        res = adc_search(emb, model.codes, model.C, topk, backend=backend)
    jax.block_until_ready(res.indices)
    dt = (time.time() - t0) / len(xte) * 1e6
    mapv = float(mean_average_precision(res.indices, ytr, yte))
    return mapv, float(res.avg_ops), float(res.pass_rate), dt


def fit_method(method: str, key, xtr, ytr, cfg, *, epochs: int,
               num_classes: int = 10, img_hw=None, channels=None):
    """method: icq | sq | pqn | icq_cnn."""
    if method == "icq":
        return fit(key, xtr, ytr, cfg, mode="icq", epochs=epochs,
                   num_classes=num_classes)
    if method == "icq_cnn":
        return fit(key, xtr, ytr, cfg, mode="icq", embed_kind="cnn",
                   epochs=epochs, num_classes=num_classes, img_hw=img_hw,
                   channels=channels)
    if method == "sq":
        return fit_sq(key, xtr, ytr, cfg, epochs=epochs,
                      num_classes=num_classes)
    if method == "pqn":
        return fit_pqn(key, xtr, ytr, cfg, epochs=epochs,
                       num_classes=num_classes, img_hw=img_hw,
                       channels=channels)
    raise ValueError(method)


def bench_row(figure, dataset, method, cfg, key, xtr, ytr, xte, yte, *,
              epochs=4, img_hw=None, channels=None, num_classes=10):
    t0 = time.time()
    model = fit_method(method, key, xtr, ytr, cfg, epochs=epochs,
                       img_hw=img_hw, channels=channels,
                       num_classes=num_classes)
    fit_s = time.time() - t0
    mapv, ops, pr, us = evaluate(model, xte, yte, ytr)
    row = dict(figure=figure, dataset=dataset, method=method,
               code_bits=code_bits(cfg), map=round(mapv, 4),
               avg_ops=round(ops, 3), pass_rate=round(pr, 4),
               fit_s=round(fit_s, 1), search_us=round(us, 1))
    print(",".join(str(v) for v in row.values()), flush=True)
    return row


def header():
    print("figure,dataset,method,code_bits,map,avg_ops,pass_rate,"
          "fit_s,search_us", flush=True)
