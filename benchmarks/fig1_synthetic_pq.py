"""Figure 1: ICQ vs SQ(+PQ-style quantization) on the synthetic datasets
(Table 1) — MAP and Average Ops per code length.

Paper protocol: same code length and quantizer size per comparison;
each point = one trained coding, Average Ops over the test queries.
The SQ+PQ baseline is the shared joint trainer in mode="pq" with the
linear embedding (supervised PQ), matching the paper's description.
"""
from __future__ import annotations

import jax

from benchmarks.common import bench_row, header
from repro.configs.base import ICQConfig
from repro.core.train import fit
from repro.data import make_table1_dataset


def fit_sq_pq(key, xtr, ytr, cfg, *, epochs, **kw):
    return fit(key, xtr, ytr, cfg, mode="pq", epochs=epochs)


def run(full: bool = False, datasets=("dataset1", "dataset2", "dataset3"),
        seed: int = 0):
    rows = []
    n = 10000 if full else 3000
    nq = 1000 if full else 150
    epochs = 10 if full else 4
    for ds in datasets:
        xtr, ytr, xte, yte = make_table1_dataset(ds)
        xtr, ytr, xte, yte = xtr[:n], ytr[:n], xte[:nq], yte[:nq]
        for K in ((4, 8, 16) if full else (4, 8)):
            cfg = ICQConfig(d=16, num_codebooks=K,
                            codebook_size=256 if full else 32,
                            num_fast=max(K // 4, 1))
            key = jax.random.PRNGKey(K + 100_000 * seed)
            rows.append(bench_row("fig1", ds, "icq", cfg, key, xtr, ytr,
                                  xte, yte, epochs=epochs))
            # SQ+PQ baseline: same code length, same quantizer size
            from benchmarks import common
            import time
            t0 = time.time()
            m = fit_sq_pq(key, xtr, ytr, cfg, epochs=epochs)
            mapv, ops, pr, us = common.evaluate(m, xte, yte, ytr)
            row = dict(figure="fig1", dataset=ds, method="sq+pq",
                       code_bits=common.code_bits(cfg), map=round(mapv, 4),
                       avg_ops=round(ops, 3), pass_rate=round(pr, 4),
                       fit_s=round(time.time() - t0, 1),
                       search_us=round(us, 1))
            print(",".join(str(v) for v in row.values()), flush=True)
            rows.append(row)
    return rows


if __name__ == "__main__":
    header()
    run()
