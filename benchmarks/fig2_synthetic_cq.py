"""Figure 2: ICQ vs SQ(+CQ) on the synthetic datasets — verifies the gain
comes from the two-step technique, not the additive quantizer family."""
from __future__ import annotations

import jax

from benchmarks.common import bench_row, header
from repro.configs.base import ICQConfig
from repro.data import make_table1_dataset


def run(full: bool = False, datasets=("dataset1", "dataset2", "dataset3"),
        seed: int = 0):
    rows = []
    n = 10000 if full else 3000
    nq = 1000 if full else 150
    epochs = 10 if full else 4
    for ds in datasets:
        xtr, ytr, xte, yte = make_table1_dataset(ds)
        xtr, ytr, xte, yte = xtr[:n], ytr[:n], xte[:nq], yte[:nq]
        for K in ((4, 8, 16) if full else (8,)):
            cfg = ICQConfig(d=16, num_codebooks=K,
                            codebook_size=256 if full else 32,
                            num_fast=max(K // 4, 1))
            key = jax.random.PRNGKey(100 + K + 100_000 * seed)
            rows.append(bench_row("fig2", ds, "icq", cfg, key, xtr, ytr,
                                  xte, yte, epochs=epochs))
            rows.append(bench_row("fig2", ds, "sq", cfg, key, xtr, ytr,
                                  xte, yte, epochs=epochs))
    return rows


if __name__ == "__main__":
    header()
    run()
