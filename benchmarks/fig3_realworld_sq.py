"""Figure 3: ICQ vs SQ over (pseudo-)MNIST and CIFAR-10 across quantizer
counts K — the K=2 degenerate case (no crude step possible) through
K=16 where the paper's computation-cost gap peaks.

Offline container note: real MNIST/CIFAR are not downloadable here; the
structured stand-ins (repro.data.pseudo_real) match dim / classes /
protocol, and every output row is labeled pseudo_*.
"""
from __future__ import annotations

import jax

from benchmarks.common import bench_row, header
from repro.configs.base import ICQConfig
from repro.data import pseudo_cifar, pseudo_mnist


def run(full: bool = False, seed: int = 0):
    rows = []
    n = 10000 if full else 2000
    nq = 1000 if full else 150
    epochs = 8 if full else 3
    for name, gen in (("pseudo_mnist", pseudo_mnist),
                      ("pseudo_cifar", pseudo_cifar)):
        xtr, ytr, xte, yte = gen(n_train=n, n_test=nq)
        for K in ((2, 4, 8, 16) if full else (2, 8)):
            cfg = ICQConfig(d=16, num_codebooks=K,
                            codebook_size=256 if full else 32,
                            num_fast=max(K // 4, 1))
            key = jax.random.PRNGKey(200 + K + 100_000 * seed)
            rows.append(bench_row("fig3", name, "icq", cfg, key, xtr, ytr,
                                  xte, yte, epochs=epochs))
            rows.append(bench_row("fig3", name, "sq", cfg, key, xtr, ytr,
                                  xte, yte, epochs=epochs))
    return rows


if __name__ == "__main__":
    header()
    run()
