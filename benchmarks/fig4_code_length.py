"""Figure 4: MAP vs *effective code length* (eq. 12) on (pseudo-)CIFAR.

    l_hat = l * flops_ICQ@l / flops_SQ@l

flops_* is the Average-Ops metric; for one-step baselines it equals K.
DQN / DPQ appear in the paper as literature curves — their CIFAR-10
numbers are reproduced below as constants (clearly labeled literature,
not re-runs; they are NOT comparable to the pseudo-CIFAR rows and are
emitted only so the effective-code-length bookkeeping of eq. 12 is
complete).
"""
from __future__ import annotations

import jax

from benchmarks.common import bench_row, code_bits, evaluate, fit_method, header
from repro.configs.base import ICQConfig
from repro.data import pseudo_cifar

# literature reference points (MAP @ code bits, CIFAR-10, from the cited
# papers) — context lines for the figure, not measurements of this code.
LITERATURE = {
    "dqn[lit]": {16: 0.54, 24: 0.56, 32: 0.58, 48: 0.58},
    "dpq[lit]": {16: 0.76, 24: 0.77, 32: 0.77, 48: 0.78},
}


def run(full: bool = False, seed: int = 0):
    rows = []
    n = 10000 if full else 2000
    nq = 1000 if full else 150
    epochs = 8 if full else 3
    xtr, ytr, xte, yte = pseudo_cifar(n_train=n, n_test=nq)
    for K in ((4, 8, 12, 16) if full else (4, 8)):
        cfg = ICQConfig(d=16, num_codebooks=K,
                        codebook_size=256 if full else 32,
                        num_fast=max(K // 4, 1))
        key = jax.random.PRNGKey(300 + K + 100_000 * seed)
        icq_row = bench_row("fig4", "pseudo_cifar", "icq", cfg, key, xtr,
                            ytr, xte, yte, epochs=epochs)
        sq_row = bench_row("fig4", "pseudo_cifar", "sq", cfg, key, xtr,
                           ytr, xte, yte, epochs=epochs)
        eff_bits = icq_row["code_bits"] * (icq_row["avg_ops"]
                                           / sq_row["avg_ops"])
        row = dict(figure="fig4", dataset="pseudo_cifar",
                   method="icq_effective", code_bits=round(eff_bits, 1),
                   map=icq_row["map"], avg_ops=icq_row["avg_ops"],
                   pass_rate=icq_row["pass_rate"], fit_s=0.0, search_us=0.0)
        print(",".join(str(v) for v in row.values()), flush=True)
        rows += [icq_row, sq_row, row]
    for meth, pts in LITERATURE.items():
        for bits, mapv in pts.items():
            print(f"fig4,cifar10,{meth},{bits},{mapv},,,,", flush=True)
    return rows


if __name__ == "__main__":
    header()
    run()
