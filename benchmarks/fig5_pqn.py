"""Figure 5: ICQ vs PQN, both with CNN embeddings, on (pseudo-)MNIST and
CIFAR-10 — same code length per comparison.  (Paper: LeNet for MNIST,
AlexNet for CIFAR; here one LeNet-class CNN sized per dataset — the
comparison is embedding-matched, which is what the figure tests.)"""
from __future__ import annotations

import jax

from benchmarks.common import bench_row, header
from repro.configs.base import ICQConfig
from repro.data import pseudo_cifar, pseudo_mnist


def run(full: bool = False, seed: int = 0):
    rows = []
    n = 8000 if full else 1500
    nq = 800 if full else 120
    epochs = 6 if full else 2
    for name, gen, hw, ch in (("pseudo_mnist", pseudo_mnist, 28, 1),
                              ("pseudo_cifar", pseudo_cifar, 32, 3)):
        xtr, ytr, xte, yte = gen(n_train=n, n_test=nq)
        xtr = xtr.reshape(-1, hw, hw, ch)
        xte = xte.reshape(-1, hw, hw, ch)
        for K in ((4, 8, 16) if full else (8,)):
            cfg = ICQConfig(d=16, num_codebooks=K,
                            codebook_size=256 if full else 32,
                            num_fast=max(K // 4, 1))
            key = jax.random.PRNGKey(400 + K + 100_000 * seed)
            rows.append(bench_row("fig5", name, "icq_cnn", cfg, key, xtr,
                                  ytr, xte, yte, epochs=epochs, img_hw=hw,
                                  channels=ch))
            rows.append(bench_row("fig5", name, "pqn", cfg, key, xtr, ytr,
                                  xte, yte, epochs=epochs, img_hw=hw,
                                  channels=ch))
    return rows


if __name__ == "__main__":
    header()
    run()
