"""Figure 6: unseen-classes protocol (Sablayrolles et al.): train with 3
random classes held out, evaluate retrieval *on the held-out classes
only* — tests whether the coding generalizes beyond supervised labels."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import bench_row, header
from repro.configs.base import ICQConfig
from repro.data import pseudo_cifar, pseudo_mnist


def split_unseen(x, y, holdout, seed=0):
    held = np.isin(y, holdout)
    return (x[~held], y[~held]), (x[held], y[held])


def run(full: bool = False, seed: int = 0):
    rows = []
    n = 8000 if full else 2000
    nq = 1500 if full else 400
    epochs = 8 if full else 3
    rng = np.random.default_rng(7 + seed)
    for name, gen in (("pseudo_mnist", pseudo_mnist),
                      ("pseudo_cifar", pseudo_cifar)):
        xtr, ytr, xte, yte = gen(n_train=n, n_test=nq)
        holdout = rng.choice(10, 3, replace=False)
        (xtr_s, ytr_s), _ = split_unseen(xtr, ytr, holdout)
        _, (xte_u, yte_u) = split_unseen(xte, yte, holdout)
        # database = held-out test vectors; queries = held-out test vectors
        nq_u = min(len(xte_u) // 2, 100)
        xdb, ydb = xte_u[nq_u:], yte_u[nq_u:]
        xq, yq = xte_u[:nq_u], yte_u[:nq_u]
        for K in ((8, 16) if full else (8,)):
            cfg = ICQConfig(d=16, num_codebooks=K,
                            codebook_size=256 if full else 32,
                            num_fast=max(K // 4, 1))
            key = jax.random.PRNGKey(500 + K + 100_000 * seed)
            for method in ("icq", "sq"):
                # fit on seen classes, index + query the unseen ones
                from benchmarks import common
                import time
                t0 = time.time()
                m = common.fit_method(method, key, xtr_s, ytr_s, cfg,
                                      epochs=epochs, num_classes=10)
                # re-encode the unseen database with the fitted coder
                from repro.core import encode as enc
                emb_db = m.embed(xdb)
                codes = (enc.encode_pq(emb_db, m.C) if m.mode == "pq" else
                         enc.icm_encode(emb_db, m.C, cfg.icm_iters))
                import dataclasses as dc
                m2 = dc.replace(m, codes=codes)
                mapv, ops, pr, us = common.evaluate(m2, xq, yq, ydb)
                row = dict(figure="fig6", dataset=name + "_unseen",
                           method=method, code_bits=common.code_bits(cfg),
                           map=round(mapv, 4), avg_ops=round(ops, 3),
                           pass_rate=round(pr, 4),
                           fit_s=round(time.time() - t0, 1),
                           search_us=round(us, 1))
                print(",".join(str(v) for v in row.values()), flush=True)
                rows.append(row)
    return rows


if __name__ == "__main__":
    header()
    run()
