"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by launch.dryrun) and emits the
per-(arch x shape x mesh) three-term table:

    compute  = HLO_FLOPs / (chip peak)          [trip-count-corrected]
    memory   = HLO_bytes / (chip HBM bandwidth)
    collect. = collective_bytes / (chip link bandwidth)

plus the dominant term, MODEL_FLOPS/HLO_FLOPs (useful-compute ratio) and
an MFU-upper-bound estimate  compute / max(all terms)  — what fraction
of peak the cell could reach if perfectly overlapped.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirname: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r):
    mfu_bound = (r["compute_term_s"]
                 / max(r["compute_term_s"], r["memory_term_s"],
                       r["collective_term_s"], 1e-30))
    return (f"| {r['arch']:<20} | {r['shape']:<11} | {r['mesh']:<8} "
            f"| {r.get('variant') or 'base':<9} "
            f"| {r['compute_term_s']:9.3e} | {r['memory_term_s']:9.3e} "
            f"| {r['collective_term_s']:9.3e} | {r['dominant']:<10} "
            f"| {r['useful_flops_ratio']:5.2f} | {mfu_bound:5.2f} |")


HEADER = ("| arch                 | shape       | mesh     | variant   "
          "| compute s | memory s  | collect s | dominant   | useful "
          "| MFU≤  |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None, help="filter: 16x16 / 2x16x16")
    args = ap.parse_args()
    recs = load(args.dir)
    if args.mesh:
        recs = [r for r in recs if r["mesh"] == args.mesh]
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"],
                             r.get("variant", "")))
    print(HEADER)
    for r in recs:
        print(fmt_row(r))
    if recs:
        doms = {}
        for r in recs:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        print(f"\ncells: {len(recs)}  dominant-term histogram: {doms}")


if __name__ == "__main__":
    main()
