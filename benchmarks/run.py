"""Benchmark entry point: one section per paper figure + kernel
microbenchmarks + the roofline table (if dry-run artifacts exist).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig3]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks import (beyond_ivf, fig1_synthetic_pq, fig2_synthetic_cq,
                        fig3_realworld_sq, fig4_code_length, fig5_pqn,
                        fig6_unseen)
from benchmarks.common import header

FIGURES = {
    "fig1": fig1_synthetic_pq.run,
    "fig2": fig2_synthetic_cq.run,
    "fig3": fig3_realworld_sq.run,
    "fig4": fig4_code_length.run,
    "fig5": fig5_pqn.run,
    "fig6": fig6_unseen.run,
    "beyond_ivf": beyond_ivf.run,
}


def kernel_micro():
    """Pallas-kernel microbenchmarks (interpret on CPU; wall time is NOT
    TPU-indicative — correctness + call-overhead tracking only)."""
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)
    rows = []
    for name, fn, args in [
        ("adc_64k_x8", ops.adc,
         (jax.random.randint(key, (65536, 8), 0, 256),
          jax.random.normal(key, (8, 256)))),
        ("kmeans_16k_256", ops.kmeans_assign,
         (jax.random.normal(key, (16384, 64)),
          jax.random.normal(key, (256, 64)))),
        ("flash_4x512", ops.flash_attention,
         (jax.random.normal(key, (4, 512, 8, 64)),
          jax.random.normal(key, (4, 512, 2, 64)),
          jax.random.normal(key, (4, 512, 2, 64)))),
    ]:
        out = fn(*args)                      # compile
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(3):
            jax.block_until_ready(fn(*args))
        us = (time.time() - t0) / 3 * 1e6
        print(f"kernel,{name},interpret,,,,,,{us:.0f}", flush=True)
        rows.append((name, us))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (hours on CPU)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    header()
    t0 = time.time()
    for name, run_fn in FIGURES.items():
        if args.only and name != args.only:
            continue
        run_fn(full=args.full)
    if not args.only:
        kernel_micro()
    print(f"# total {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
