"""Benchmark entry point: one section per paper figure + kernel
microbenchmarks + the batched-search engine benchmark (emits
``BENCH_search.json`` for cross-PR perf tracking) + the roofline table
(if dry-run artifacts exist).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig3]
    PYTHONPATH=src python -m benchmarks.run --only search   # just the JSON
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from benchmarks import (beyond_ivf, fig1_synthetic_pq, fig2_synthetic_cq,
                        fig3_realworld_sq, fig4_code_length, fig5_pqn,
                        fig6_unseen)
from benchmarks.common import header


def search_bench(full: bool = False, *, out_path: str = "BENCH_search.json",
                 n: int = 100_000, nq: int = 64, K: int = 8, m: int = 256,
                 num_fast: int = 2, topk: int = 50, d: int = 16,
                 repeats: int = 3, pallas_n: int = 4096, pallas_nq: int = 8):
    """Batched two-step engine vs the per-query ``lax.map`` baseline on a
    synthetic index (n points, nq-query batches), written to
    ``out_path`` so the perf trajectory is machine-readable across PRs.

    The pallas row runs interpret mode (CPU container) at a reduced size
    — it tracks correctness/call overhead, not TPU latency.
    """
    from repro.core.search import two_step_search
    from repro.data.synthetic import make_synthetic_index
    from repro.kernels.ref import two_step_search_looped

    if full:
        n, nq = max(n, 1_000_000), max(nq, 256)
    key = jax.random.PRNGKey(0)
    codes, C, structure = make_synthetic_index(key, n, d=d, K=K, m=m,
                                               num_fast=num_fast)
    queries = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))

    def timed(fn, *args, **kw):
        res = fn(*args, **kw)                        # compile + warm
        jax.block_until_ready(res.indices)
        t0 = time.time()
        for _ in range(repeats):
            jax.block_until_ready(fn(*args, **kw).indices)
        return res, (time.time() - t0) / repeats

    rows = []
    res_l, dt_l = timed(jax.jit(
        lambda q: two_step_search_looped(q, codes, C, structure, topk)),
        queries)
    rows.append(dict(backend="lax_map", n=n, nq=nq,
                     search_us=round(dt_l / nq * 1e6, 2),
                     avg_ops=round(float(res_l.avg_ops), 4),
                     pass_rate=round(float(res_l.pass_rate), 4)))
    res_b, dt_b = timed(jax.jit(
        lambda q: two_step_search(q, codes, C, structure, topk,
                                  backend="jnp")), queries)
    rows.append(dict(backend="jnp", n=n, nq=nq,
                     search_us=round(dt_b / nq * 1e6, 2),
                     avg_ops=round(float(res_b.avg_ops), 4),
                     pass_rate=round(float(res_b.pass_rate), 4)))
    # pallas interpret: reduced size, correctness/overhead tracking only
    codes_s, queries_s = codes[:pallas_n], queries[:pallas_nq]
    res_p, dt_p = timed(
        lambda q: two_step_search(q, codes_s, C, structure, topk,
                                  backend="pallas", interpret=True),
        queries_s)
    rows.append(dict(backend="pallas_interpret", n=pallas_n, nq=pallas_nq,
                     search_us=round(dt_p / pallas_nq * 1e6, 2),
                     avg_ops=round(float(res_p.avg_ops), 4),
                     pass_rate=round(float(res_p.pass_rate), 4)))

    out = dict(topk=topk, K=K, m=m, num_fast=num_fast, d=d,
               rows=rows,
               speedup_batched_vs_laxmap=round(dt_l / dt_b, 3))
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    for r in rows:
        print(f"search,{r['backend']},n={r['n']},nq={r['nq']},,"
              f"{r['avg_ops']},{r['pass_rate']},,{r['search_us']}",
              flush=True)
    print(f"# batched-vs-laxmap speedup {out['speedup_batched_vs_laxmap']}x"
          f" -> {out_path}", flush=True)
    return out


FIGURES = {
    "fig1": fig1_synthetic_pq.run,
    "fig2": fig2_synthetic_cq.run,
    "fig3": fig3_realworld_sq.run,
    "fig4": fig4_code_length.run,
    "fig5": fig5_pqn.run,
    "fig6": fig6_unseen.run,
    "beyond_ivf": beyond_ivf.run,
    "search": search_bench,
}


def kernel_micro():
    """Pallas-kernel microbenchmarks (interpret on CPU; wall time is NOT
    TPU-indicative — correctness + call-overhead tracking only)."""
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)
    rows = []
    for name, fn, args in [
        ("adc_64k_x8", ops.adc,
         (jax.random.randint(key, (65536, 8), 0, 256),
          jax.random.normal(key, (8, 256)))),
        ("kmeans_16k_256", ops.kmeans_assign,
         (jax.random.normal(key, (16384, 64)),
          jax.random.normal(key, (256, 64)))),
        ("flash_4x512", ops.flash_attention,
         (jax.random.normal(key, (4, 512, 8, 64)),
          jax.random.normal(key, (4, 512, 2, 64)),
          jax.random.normal(key, (4, 512, 2, 64)))),
    ]:
        out = fn(*args)                      # compile
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(3):
            jax.block_until_ready(fn(*args))
        us = (time.time() - t0) / 3 * 1e6
        print(f"kernel,{name},interpret,,,,,,{us:.0f}", flush=True)
        rows.append((name, us))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (hours on CPU)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    header()
    t0 = time.time()
    for name, run_fn in FIGURES.items():
        if args.only and name != args.only:
            continue
        run_fn(full=args.full)
    if not args.only:
        kernel_micro()
    print(f"# total {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
