"""Benchmark entry point: one section per paper figure + kernel
microbenchmarks + the engine benchmarks for cross-PR perf tracking —
batched search (``BENCH_search.json``), batched IVF
(``BENCH_ivf.json``), quantized LUTs (``BENCH_lutq.json``), the 4-bit
fast-scan crude pass (``BENCH_fastscan.json``), the tiled ICM encoding
engine (``BENCH_encode.json``), and the scan-compiled trainer
(``BENCH_train.json``) — plus the roofline table (if dry-run artifacts
exist).  See docs/benchmarks.md for every ``--only`` target.

Engine targets accept ``--config path.json`` (a ``repro.api.ICQConfig``,
docs/api.md) pinning geometry and engine options, so a BENCH run is
reproducible from a checked-in config
(``benchmarks/configs/bench_small.json``).

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig3]
    PYTHONPATH=src python -m benchmarks.run --only search   # just the JSON
    PYTHONPATH=src python -m benchmarks.run --only ivf \
        --config benchmarks/configs/bench_small.json
    PYTHONPATH=src python -m benchmarks.run --only ivf      # BENCH_ivf.json
    PYTHONPATH=src python -m benchmarks.run --only lutq     # BENCH_lutq.json
    PYTHONPATH=src python -m benchmarks.run --only fastscan # BENCH_fastscan.json
    PYTHONPATH=src python -m benchmarks.run --only encode   # BENCH_encode.json
    PYTHONPATH=src python -m benchmarks.run --only train    # BENCH_train.json
    PYTHONPATH=src python -m benchmarks.run --only faults   # BENCH_faults.json
    PYTHONPATH=src python -m benchmarks.run --only pipeline # BENCH_pipeline.json
    PYTHONPATH=src python -m benchmarks.run --only pareto   # BENCH_pareto.json
    PYTHONPATH=src python -m benchmarks.run --only serve    # BENCH_serve.json

Every target accepts ``--seed N`` (default 0), threaded through its
data generation — two same-seed runs report identical recall numbers.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import (beyond_ivf, fig1_synthetic_pq, fig2_synthetic_cq,
                        fig3_realworld_sq, fig4_code_length, fig5_pqn,
                        fig6_unseen, serve_load, sweep)
from benchmarks.common import header, host_copy


def search_bench(full: bool = False, *, out_path: str = "BENCH_search.json",
                 n: int = 100_000, nq: int = 64, K: int = 8, m: int = 256,
                 num_fast: int = 2, topk: int = 50, d: int = 16,
                 repeats: int = 3, pallas_n: int = 4096, pallas_nq: int = 8,
                 seed: int = 0):
    """Batched two-step engine vs the per-query ``lax.map`` baseline on a
    synthetic index (n points, nq-query batches), written to
    ``out_path`` so the perf trajectory is machine-readable across PRs.

    The pallas row runs interpret mode (CPU container) at a reduced size
    — it tracks correctness/call overhead, not TPU latency.  ``seed``
    drives every PRNG key (data + queries): two runs with the same seed
    report identical recall/avg_ops numbers.
    """
    from repro.core.search import two_step_search
    from repro.data.synthetic import make_synthetic_index
    from repro.kernels.ref import two_step_search_looped

    if full:
        n, nq = max(n, 1_000_000), max(nq, 256)
    key = jax.random.PRNGKey(seed)
    codes, C, structure = make_synthetic_index(key, n, d=d, K=K, m=m,
                                               num_fast=num_fast)
    queries = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))

    def timed(fn, *args, **kw):
        # host_copy releases the warm result's device buffers so the
        # timed calls reuse the top-k carry instead of re-allocating it;
        # min-of-repeats: see ivf_bench (cpu-share throttled container)
        res = host_copy(fn(*args, **kw))             # compile + warm
        ts = []
        for _ in range(repeats):
            t0 = time.time()
            jax.block_until_ready(fn(*args, **kw).indices)
            ts.append(time.time() - t0)
        return res, min(ts)

    rows = []
    lax_fn = jax.jit(
        lambda q: two_step_search_looped(q, codes, C, structure, topk))
    jnp_fn = jax.jit(
        lambda q: two_step_search(q, codes, C, structure, topk,
                                  backend="jnp"))
    # the batched-vs-laxmap ratio is the headline: interleave the two
    # engines and take the median of paired ratios (see lutq_bench —
    # common-mode cpu-share interference cancels inside each pair);
    # per-row latencies report min-of-repeats like the other benches
    res_l = host_copy(lax_fn(queries))               # compile + warm,
    res_b = host_copy(jnp_fn(queries))               # buffers released
    ts_l, ts_b = [], []
    for _ in range(3 * repeats):
        t0 = time.time()
        jax.block_until_ready(lax_fn(queries).indices)
        ts_l.append(time.time() - t0)
        t0 = time.time()
        jax.block_until_ready(jnp_fn(queries).indices)
        ts_b.append(time.time() - t0)
    dt_l, dt_b = min(ts_l), min(ts_b)
    pair_ratios = sorted(l / b for l, b in zip(ts_l, ts_b))
    speedup = pair_ratios[len(pair_ratios) // 2]
    rows.append(dict(backend="lax_map", n=n, nq=nq,
                     search_us=round(dt_l / nq * 1e6, 2),
                     avg_ops=round(float(res_l.avg_ops), 4),
                     pass_rate=round(float(res_l.pass_rate), 4)))
    rows.append(dict(backend="jnp", n=n, nq=nq,
                     search_us=round(dt_b / nq * 1e6, 2),
                     avg_ops=round(float(res_b.avg_ops), 4),
                     pass_rate=round(float(res_b.pass_rate), 4)))
    # pallas interpret: reduced size, correctness/overhead tracking only
    codes_s, queries_s = codes[:pallas_n], queries[:pallas_nq]
    res_p, dt_p = timed(
        lambda q: two_step_search(q, codes_s, C, structure, topk,
                                  backend="pallas", interpret=True),
        queries_s)
    rows.append(dict(backend="pallas_interpret", n=pallas_n, nq=pallas_nq,
                     search_us=round(dt_p / pallas_nq * 1e6, 2),
                     avg_ops=round(float(res_p.avg_ops), 4),
                     pass_rate=round(float(res_p.pass_rate), 4)))

    out = dict(topk=topk, K=K, m=m, num_fast=num_fast, d=d,
               rows=rows,
               speedup_batched_vs_laxmap=round(speedup, 3))
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    for r in rows:
        print(f"search,{r['backend']},n={r['n']},nq={r['nq']},,"
              f"{r['avg_ops']},{r['pass_rate']},,{r['search_us']}",
              flush=True)
    print(f"# batched-vs-laxmap speedup {out['speedup_batched_vs_laxmap']}x"
          f" -> {out_path}", flush=True)
    return out


def ivf_bench(full: bool = False, *, out_path: str = "BENCH_ivf.json",
              n: int = 100_000, nq: int = 64, K: int = 8, m: int = 256,
              num_fast: int = 2, topk: int = 50, d: int = 16,
              n_lists: int = 256, probes=(4, 8, 16), repeats: int = 9,
              query_chunk: int = 32, pallas_n_probe: int = 4,
              pallas_nq: int = 8, seed: int = 0):
    """Batched IVF engine vs the per-query ``lax.map`` IVF baseline
    (and the flat two-step engine) on a synthetic index, written to
    ``out_path`` for cross-PR perf tracking.

    Reports us/query and recall@10 (vs exact L2 over the reconstructed
    database) per n_probe and per shard count.  Shard rows require >1
    visible device (CPU: XLA_FLAGS=--xla_force_host_platform_device_
    count=N); with one device only shards=1 is recorded.
    """
    from benchmarks.common import engine_ground_truth, recall_at_k
    from repro.core import codebooks as cb
    from repro.core.search import two_step_search
    from repro.data.synthetic import make_synthetic_index
    from repro.index import (IVFTwoStep, build_ivf, ivf_list_codes,
                             ivf_two_step_search)
    from repro.kernels.ref import ivf_two_step_search_looped

    if full:
        n, nq = max(n, 1_000_000), max(nq, 256)
    key = jax.random.PRNGKey(seed)
    codes, C, structure = make_synthetic_index(key, n, d=d, K=K, m=m,
                                               num_fast=num_fast)
    emb_db = cb.decode(C, codes)                 # reconstructed db points
    queries = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))
    ivf = build_ivf(jax.random.fold_in(key, 3), emb_db, n_lists)
    slab = ivf_list_codes(ivf, codes)
    # recall@10 vs the *full quantized ADC ranking* — see
    # benchmarks.common.engine_ground_truth for why not exact-L2
    gt = engine_ground_truth(queries, codes, C, 10)

    def timed(fn, *args, **kw):
        # min-of-repeats: this container is cpu-share throttled and
        # mean/median of few wall times swing 2-3x between runs; the
        # minimum tracks the interference-free cost.  host_copy releases
        # the warm result's buffers so the timed calls reuse the top-k
        # carry instead of re-allocating it every batch.
        res = host_copy(fn(*args, **kw))         # compile + warm
        ts = []
        for _ in range(repeats):
            t0 = time.time()
            jax.block_until_ready(fn(*args, **kw).indices)
            ts.append(time.time() - t0)
        return res, min(ts)

    def row(engine, n_probe, shards, res, dt, n_run=n, nq_run=nq):
        return dict(engine=engine, n=n_run, nq=nq_run, n_probe=n_probe,
                    shards=shards,
                    search_us=round(dt / nq_run * 1e6, 2),
                    recall10=round(recall_at_k(res.indices[:, :10],
                                               gt[:nq_run], 10), 4),
                    avg_ops=round(float(res.avg_ops), 4),
                    pass_rate=round(float(res.pass_rate), 4))

    rows = []
    # per-query lax.map baseline (the retired formulation) at the
    # headline probe count (8 when swept, else the largest probe)
    headline = 8 if 8 in probes else probes[-1]
    res_l, dt_l = timed(jax.jit(
        lambda q: ivf_two_step_search_looped(q, codes, C, structure, ivf,
                                             topk, headline)), queries)
    rows.append(row("ivf_lax_map", headline, 1, res_l, dt_l))
    # batched jnp engine across the probe sweep
    dt_bh, recall_gap = None, None
    for n_probe in probes:
        res_b, dt_b = timed(jax.jit(
            lambda q, p=n_probe: ivf_two_step_search(
                q, codes, C, structure, ivf, topk, p, backend="jnp",
                list_codes=slab, query_chunk=query_chunk)), queries)
        rows.append(row("ivf_batched_jnp", n_probe, 1, res_b, dt_b))
        if n_probe == headline:
            dt_bh = dt_b
            recall_gap = abs(rows[0]["recall10"] - rows[-1]["recall10"])
    # flat two-step engine for context (the BENCH_search.json hot path)
    res_f, dt_f = timed(jax.jit(
        lambda q: two_step_search(q, codes, C, structure, topk,
                                  backend="jnp")), queries)
    rows.append(row("flat_two_step_jnp", None, 1, res_f, dt_f))
    # pallas interpret: reduced size, correctness/overhead tracking only
    q_s = queries[:pallas_nq]
    res_p, dt_p = timed(
        lambda q: ivf_two_step_search(q, codes, C, structure, ivf, topk,
                                      pallas_n_probe, backend="pallas",
                                      interpret=True), q_s)
    rows.append(row("ivf_pallas_interpret", pallas_n_probe, 1, res_p, dt_p,
                    nq_run=pallas_nq))
    # sharded serving (needs >1 visible device)
    n_dev = len(jax.devices())
    if n_dev > 1:
        mesh = jax.make_mesh((n_dev,), ("data",))
        idx = IVFTwoStep(codes=codes, C=C, structure=structure, ivf=ivf,
                         n_probe=headline, topk=topk,
                         backend="jnp").shard(mesh)
        res_s, dt_s = timed(idx.search, queries)
        rows.append(row("ivf_batched_jnp", headline, n_dev, res_s, dt_s))
    else:
        print("# ivf: 1 device visible — skipping shard rows (set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=N)",
              flush=True)

    out = dict(topk=topk, K=K, m=m, num_fast=num_fast, d=d,
               n_lists=n_lists, imbalance=round(ivf.imbalance, 3),
               rows=rows,
               headline_probe=headline,
               speedup_batched_vs_laxmap_probe8=round(dt_l / dt_bh, 3),
               recall10_gap_probe8=round(recall_gap, 4))
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    for r in rows:
        print(f"ivf,{r['engine']},n={r['n']},nq={r['nq']},"
              f"probe={r['n_probe']},shards={r['shards']},"
              f"recall10={r['recall10']},{r['avg_ops']},{r['pass_rate']},"
              f"{r['search_us']}", flush=True)
    print(f"# ivf batched-vs-laxmap speedup "
          f"{out['speedup_batched_vs_laxmap_probe8']}x (recall gap "
          f"{out['recall10_gap_probe8']}) -> {out_path}", flush=True)
    return out


def lutq_bench(full: bool = False, *, out_path: str = "BENCH_lutq.json",
               n: int = 100_000, nq: int = 64, K: int = 8, m: int = 256,
               num_fast: int = 2, topk: int = 50, d: int = 16,
               repeats: int = 9, pallas_n: int = 4096, pallas_nq: int = 8,
               seed: int = 0):
    """Quantized-LUT (int8) crude pass vs the f32 crude pass on the jnp
    backend, plus end-to-end two-step rows per ``lut_dtype`` and a
    pallas-interpret int8 tracking row, written to ``out_path``
    (DESIGN.md §8).

    The crude-pass rows time exactly the phase-1 work — LUT build
    (+ int8 calibration) and the fast-masked LUT sum over all n points;
    the int8 row's narrow integer accumulation is the memory-traffic
    win being tracked.  recall@10 is measured against the full f32 ADC
    ranking (random synthetic codes make exact-L2 recall meaningless
    for engine comparisons) for the f32 and int8 two-step engines; the
    acceptance gate is a delta <= 0.01.
    """
    from benchmarks.common import engine_ground_truth, recall_at_k
    from repro.core.search import two_step_search
    from repro.data.synthetic import make_synthetic_index
    from repro.index.base import build_lut, lut_sum, quantize_lut

    if full:
        n, nq = max(n, 1_000_000), max(nq, 256)
    key = jax.random.PRNGKey(seed)
    codes, C, structure = make_synthetic_index(key, n, d=d, K=K, m=m,
                                               num_fast=num_fast)
    queries = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))
    fast = structure.fast_mask
    codes_i32 = codes.astype(jnp.int32)
    gt = engine_ground_truth(queries, codes, C, 10)

    def timed(fn, *args):
        out = host_copy(fn(*args))               # compile + warm, release
        ts = []
        for _ in range(repeats):
            t0 = time.time()
            jax.block_until_ready(fn(*args))
            ts.append(time.time() - t0)
        # min-of-repeats: see ivf_bench (cpu-share throttled container)
        return out, min(ts)

    @jax.jit
    def crude_f32(q):
        return lut_sum(build_lut(q, C), codes_i32, fast)

    @jax.jit
    def crude_int8(q):
        return lut_sum(quantize_lut(build_lut(q, C), fast), codes_i32, fast)

    rows = []
    # the crude-pass ratio is the headline: *interleave* the f32/int8
    # measurements so a cpu-share spike hits adjacent samples of both
    # engines equally (back-to-back phases measured ratio swings of 2x+
    # on this throttled container), then take the *median of paired
    # ratios* — common-mode interference cancels inside each pair, so
    # the estimate tracks the engines' true relative cost; per-row
    # latencies still report min-of-repeats like the other benches
    ref = host_copy(crude_f32(queries))          # compile + warm both,
    out = host_copy(crude_int8(queries))         # buffers released
    ts_f, ts_q = [], []
    for _ in range(3 * repeats):
        t0 = time.time()
        jax.block_until_ready(crude_f32(queries))
        ts_f.append(time.time() - t0)
        t0 = time.time()
        jax.block_until_ready(crude_int8(queries))
        ts_q.append(time.time() - t0)
    dt_f, dt_q = min(ts_f), min(ts_q)
    pair_ratios = sorted(f / q for f, q in zip(ts_f, ts_q))
    crude_speedup = pair_ratios[len(pair_ratios) // 2]
    rows.append(dict(stage="crude", lut_dtype="f32", n=n, nq=nq,
                     search_us=round(dt_f / nq * 1e6, 2)))
    max_err = float(jnp.max(jnp.abs(out - ref)))
    rows.append(dict(stage="crude", lut_dtype="int8", n=n, nq=nq,
                     search_us=round(dt_q / nq * 1e6, 2),
                     max_abs_err=round(max_err, 5)))

    recalls = {}
    for lut_dtype in ("f32", "int8"):
        res, dt = timed(jax.jit(
            lambda q, lt=lut_dtype: two_step_search(
                q, codes, C, structure, topk, backend="jnp",
                lut_dtype=lt)), queries)
        recalls[lut_dtype] = recall_at_k(res.indices[:, :10], gt, 10)
        rows.append(dict(stage="two_step", lut_dtype=lut_dtype, n=n, nq=nq,
                         search_us=round(dt / nq * 1e6, 2),
                         recall10=round(recalls[lut_dtype], 4),
                         avg_ops=round(float(res.avg_ops), 4),
                         pass_rate=round(float(res.pass_rate), 4)))
    # pallas interpret: reduced size, correctness/overhead tracking only
    codes_s, q_s = codes[:pallas_n], queries[:pallas_nq]
    res_p, dt_p = timed(lambda q: two_step_search(
        q, codes_s, C, structure, topk, backend="pallas", interpret=True,
        lut_dtype="int8"), q_s)
    rows.append(dict(stage="two_step_pallas_interpret", lut_dtype="int8",
                     n=pallas_n, nq=pallas_nq,
                     search_us=round(dt_p / pallas_nq * 1e6, 2),
                     pass_rate=round(float(res_p.pass_rate), 4)))

    out = dict(topk=topk, K=K, m=m, num_fast=num_fast, d=d, rows=rows,
               speedup_crude_int8_vs_f32=round(crude_speedup, 3),
               recall10_f32=round(recalls["f32"], 4),
               recall10_int8=round(recalls["int8"], 4),
               recall10_delta=round(abs(recalls["f32"] - recalls["int8"]), 4))
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    for r in rows:
        print(f"lutq,{r['stage']},{r['lut_dtype']},n={r['n']},nq={r['nq']},"
              f"recall10={r.get('recall10', '')},{r['search_us']}",
              flush=True)
    print(f"# lutq crude int8-vs-f32 speedup "
          f"{out['speedup_crude_int8_vs_f32']}x (recall@10 delta "
          f"{out['recall10_delta']}) -> {out_path}", flush=True)
    return out


def fastscan_bench(full: bool = False, *,
                   out_path: str = "BENCH_fastscan.json",
                   n: int = 100_000, nq: int = 64, K: int = 8, m: int = 16,
                   num_fast: int = 2, topk: int = 50, d: int = 16,
                   repeats: int = 9, pallas_n: int = 4096,
                   pallas_nq: int = 8, seed: int = 0):
    """4-bit fast-scan crude pass (``code_bits=4``, DESIGN.md §12) vs
    the int8 and f32 crude passes on the jnp backend, written to
    ``out_path``.

    Geometry is pinned to ``m <= 16`` (nibble-addressable codebooks).
    The crude rows time exactly the phase-1 work — LUT build
    (+ calibration where quantized) and the fast-masked LUT sum over
    all n points; the 4-bit row reads half the code bytes and gathers
    per *packed byte* from a paired 256-entry table, which is the
    bandwidth win being tracked (acceptance gate: >= 1.3x vs the int8
    8-bit crude pass).  recall@10 is measured against the full f32 ADC
    ranking for the f32/8-bit and int8/4-bit two-step engines
    (acceptance gate: delta <= 0.01), and code-memory bytes per row are
    reported for both layouts.
    """
    from benchmarks.common import engine_ground_truth, recall_at_k
    from repro.core.encode import pack_nibbles
    from repro.core.search import two_step_search
    from repro.data.synthetic import make_synthetic_index
    from repro.index.base import (build_lut, lut_sum, nibble_lut_sum,
                                  quantize_lut)

    if full:
        n, nq = max(n, 1_000_000), max(nq, 256)
    key = jax.random.PRNGKey(seed)
    codes, C, structure = make_synthetic_index(key, n, d=d, K=K, m=m,
                                               num_fast=num_fast)
    queries = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))
    fast = structure.fast_mask
    codes_i32 = codes.astype(jnp.int32)
    packed = pack_nibbles(codes, K)
    gt = engine_ground_truth(queries, codes, C, 10)

    def timed(fn, *args):
        out = host_copy(fn(*args))               # compile + warm, release
        ts = []
        for _ in range(repeats):
            t0 = time.time()
            jax.block_until_ready(fn(*args))
            ts.append(time.time() - t0)
        # min-of-repeats: see ivf_bench (cpu-share throttled container)
        return out, min(ts)

    @jax.jit
    def crude_f32(q):
        return lut_sum(build_lut(q, C), codes_i32, fast)

    @jax.jit
    def crude_int8(q):
        return lut_sum(quantize_lut(build_lut(q, C), fast), codes_i32, fast)

    @jax.jit
    def crude_nib(q):
        return nibble_lut_sum(quantize_lut(build_lut(q, C), fast), packed,
                              K, cb_mask=fast)

    # interleave all three crude variants and take medians of paired
    # ratios (see lutq_bench: common-mode cpu-share interference cancels
    # inside each round on this throttled container); per-row latencies
    # still report min-of-repeats like the other benches
    ref = host_copy(crude_f32(queries))          # compile + warm all,
    out8 = host_copy(crude_int8(queries))        # buffers released
    out4 = host_copy(crude_nib(queries))
    ts_f, ts_q, ts_n = [], [], []
    for _ in range(3 * repeats):
        t0 = time.time()
        jax.block_until_ready(crude_f32(queries))
        ts_f.append(time.time() - t0)
        t0 = time.time()
        jax.block_until_ready(crude_int8(queries))
        ts_q.append(time.time() - t0)
        t0 = time.time()
        jax.block_until_ready(crude_nib(queries))
        ts_n.append(time.time() - t0)
    dt_f, dt_q, dt_n = min(ts_f), min(ts_q), min(ts_n)

    def median_ratio(num, den):
        r = sorted(a / b for a, b in zip(num, den))
        return r[len(r) // 2]

    speedup_4bit_vs_int8 = median_ratio(ts_q, ts_n)
    speedup_4bit_vs_f32 = median_ratio(ts_f, ts_n)
    # the 4-bit kernel must be *bitwise* the int8 crude pass (same
    # calibration, same dequant expression; DESIGN.md §12)
    bitwise_4bit_vs_int8 = bool(jnp.all(out4 == out8))
    rows = [
        dict(stage="crude", variant="f32", n=n, nq=nq,
             search_us=round(dt_f / nq * 1e6, 2)),
        dict(stage="crude", variant="int8", n=n, nq=nq,
             search_us=round(dt_q / nq * 1e6, 2),
             max_abs_err=round(float(jnp.max(jnp.abs(out8 - ref))), 5)),
        dict(stage="crude", variant="int8_4bit", n=n, nq=nq,
             search_us=round(dt_n / nq * 1e6, 2),
             bitwise_match_int8=bitwise_4bit_vs_int8),
    ]

    recalls = {}
    for label, kw in (("f32_8bit", dict(lut_dtype="f32")),
                      ("int8_4bit", dict(lut_dtype="int8", code_bits=4))):
        cds = packed if kw.get("code_bits") == 4 else codes
        res, dt = timed(jax.jit(
            lambda q, c=cds, k=dict(kw): two_step_search(
                q, c, C, structure, topk, backend="jnp", **k)), queries)
        recalls[label] = recall_at_k(res.indices[:, :10], gt, 10)
        rows.append(dict(stage="two_step", variant=label, n=n, nq=nq,
                         search_us=round(dt / nq * 1e6, 2),
                         recall10=round(recalls[label], 4),
                         avg_ops=round(float(res.avg_ops), 4),
                         pass_rate=round(float(res.pass_rate), 4)))
    # pallas interpret: reduced size, correctness/overhead tracking only
    packed_s, codes_s, q_s = packed[:pallas_n], codes[:pallas_n], \
        queries[:pallas_nq]
    res_j = host_copy(two_step_search(q_s, packed_s, C, structure, topk,
                                      backend="jnp", lut_dtype="int8",
                                      code_bits=4))
    res_p, dt_p = timed(lambda q: two_step_search(
        q, packed_s, C, structure, topk, backend="pallas", interpret=True,
        lut_dtype="int8", code_bits=4), q_s)
    rows.append(dict(stage="two_step_pallas_interpret", variant="int8_4bit",
                     n=pallas_n, nq=pallas_nq,
                     search_us=round(dt_p / pallas_nq * 1e6, 2),
                     pass_rate=round(float(res_p.pass_rate), 4),
                     indices_match_jnp=bool(
                         jnp.all(res_p.indices == res_j.indices))))

    out = dict(topk=topk, K=K, m=m, num_fast=num_fast, d=d, rows=rows,
               bytes_per_row_8bit=K,
               bytes_per_row_4bit=(K + 1) // 2,
               speedup_crude_4bit_vs_int8=round(speedup_4bit_vs_int8, 3),
               speedup_crude_4bit_vs_f32=round(speedup_4bit_vs_f32, 3),
               bitwise_crude_4bit_vs_int8=bitwise_4bit_vs_int8,
               recall10_f32=round(recalls["f32_8bit"], 4),
               recall10_int8_4bit=round(recalls["int8_4bit"], 4),
               recall10_delta=round(abs(recalls["f32_8bit"]
                                        - recalls["int8_4bit"]), 4))
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    for r in rows:
        print(f"fastscan,{r['stage']},{r['variant']},n={r['n']},"
              f"nq={r['nq']},recall10={r.get('recall10', '')},"
              f"{r['search_us']}", flush=True)
    print(f"# fastscan crude 4bit-vs-int8 speedup "
          f"{out['speedup_crude_4bit_vs_int8']}x (vs f32 "
          f"{out['speedup_crude_4bit_vs_f32']}x, bitwise "
          f"{bitwise_4bit_vs_int8}, recall@10 delta "
          f"{out['recall10_delta']}, bytes/row {out['bytes_per_row_8bit']}"
          f"->{out['bytes_per_row_4bit']}) -> {out_path}", flush=True)
    return out


def encode_bench(full: bool = False, *, out_path: str = "BENCH_encode.json",
                 n: int = 100_000, d: int = 16, K: int = 8, m: int = 256,
                 iters: int = 3, chunk: int = 8192, repeats: int = 3,
                 point_chunk: int = 8192, pallas_n: int = 8192,
                 block_n: int = 1024, seed: int = 0):
    """Tiled ICM encoding engine vs the seed per-chunk host loop
    (cross-Gram formulation, ragged last chunk re-jitted), written to
    ``out_path`` for cross-PR perf tracking (DESIGN.md §9).

    The seed loop materializes the (K, K, m, m) cross-Gram and a
    (K, chunk, m) query tensor per chunk and re-traces for the ragged
    final chunk; the engine runs the residual recurrence in padded
    fixed-shape blocks.  Steady-state throughput is reported (both
    warmed), so the seed's extra re-jit is *not* counted against it;
    the parity row asserts both paths assign identical codes.  The
    pallas row runs interpret mode at a reduced size (correctness/call
    overhead tracking, not TPU latency).
    """
    from repro.core import codebooks as cb
    from repro.core.encode import icm_encode
    from repro.kernels.ref import icm_encode_gram

    if full:
        n = max(n, 1_000_000)
    key = jax.random.PRNGKey(seed)
    x = (jax.random.normal(key, (n, d))
         * jnp.linspace(0.3, 2.0, d)[None, :])
    C = cb.init_residual(jax.random.fold_in(key, 1), x[:4096], K, m,
                         iters=10)
    jax.block_until_ready(C)

    seed_fn = jax.jit(lambda e: icm_encode_gram(e, C, iters))

    def seed_loop():
        parts = []
        for s in range(0, n, chunk):
            parts.append(seed_fn(x[s: s + chunk]))   # ragged tail re-jits
        return jnp.concatenate(parts, axis=0)

    def engine_jnp():
        return icm_encode(x, C, iters, backend="jnp",
                          point_chunk=point_chunk)

    def timed(fn):
        out = host_copy(fn())                        # compile + warm, release
        ts = []
        for _ in range(repeats):
            t0 = time.time()
            jax.block_until_ready(fn())
            ts.append(time.time() - t0)
        # min-of-repeats: cpu-share throttled container (see ivf_bench)
        return out, min(ts)

    codes_seed, dt_seed = timed(seed_loop)
    codes_eng, dt_eng = timed(engine_jnp)
    parity = bool(jnp.all(codes_seed == codes_eng))
    rows = [
        dict(engine="seed_chunk_loop", n=n, encode_us_per_pt=round(
            dt_seed / n * 1e6, 3), pts_per_s=round(n / dt_seed)),
        dict(engine="tiled_jnp", n=n, encode_us_per_pt=round(
            dt_eng / n * 1e6, 3), pts_per_s=round(n / dt_eng),
            codes_match_seed=parity),
    ]
    # pallas interpret: reduced size, correctness/overhead tracking only
    x_s = x[:pallas_n]
    codes_p, dt_p = timed(lambda: icm_encode(x_s, C, iters,
                                             backend="pallas",
                                             block_n=block_n,
                                             interpret=True))
    rows.append(dict(engine="tiled_pallas_interpret", n=pallas_n,
                     encode_us_per_pt=round(dt_p / pallas_n * 1e6, 3),
                     pts_per_s=round(pallas_n / dt_p),
                     codes_match_jnp=bool(
                         jnp.all(codes_p == codes_eng[:pallas_n]))))

    out = dict(K=K, m=m, d=d, iters=iters, chunk=chunk,
               point_chunk=point_chunk, rows=rows,
               codes_parity_seed_vs_engine=parity,
               speedup_engine_vs_seed=round(dt_seed / dt_eng, 3))
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    for r in rows:
        print(f"encode,{r['engine']},n={r['n']},,,,,"
              f"{r['pts_per_s']},{r['encode_us_per_pt']}", flush=True)
    print(f"# encode engine-vs-seed speedup "
          f"{out['speedup_engine_vs_seed']}x (codes parity {parity}) "
          f"-> {out_path}", flush=True)
    return out


def train_bench(full: bool = False, *, out_path: str = "BENCH_train.json",
                n: int = 8192, epochs: int = 2, batch_size: int = 256,
                repeats: int = 3, seed: int = 0):
    """Scan-compiled epoch driver vs the seed per-batch host-dispatch
    loop on the joint ICQ trainer, written to ``out_path`` for cross-PR
    perf tracking (DESIGN.md §9).

    Both paths run the identical jitted step function; the delta is
    pure dispatch structure — one ``lax.scan`` + donated state per
    epoch vs one host round-trip (device_put of the indexed batch +
    dispatch + metric fetch) per batch.
    """
    from repro.configs.base import ICQConfig
    from repro.core import variance
    from repro.trainer import (compile_epoch, epoch_batches,
                               init_train_state, make_train_step)
    from repro.data import make_table1_dataset

    if full:
        n, epochs = max(n, 10_000), max(epochs, 8)
    xtr, ytr, _, _ = make_table1_dataset("dataset2")
    xtr, ytr = xtr[:n], ytr[:n]
    cfg = ICQConfig(d=16, num_codebooks=8, codebook_size=64, num_fast=2)
    key = jax.random.PRNGKey(seed)
    state = init_train_state(key, cfg, embed_kind="linear", d_raw=64,
                             mode="icq",
                             sample_batch=(xtr[:4096], ytr[:4096]))
    step = make_train_step(cfg, state["embed_apply"], state["opt"], "icq",
                           None)
    nb = n // batch_size

    step_jit = jax.jit(step)

    def host_loop():
        params, opt_state = state["params"], state["opt_state"]
        rng = jax.random.PRNGKey(seed + 1)
        for ep in range(epochs):
            rng, k = jax.random.split(rng)
            perm = jax.random.permutation(k, n)
            var_state = variance.init_state(cfg.d)
            for b in range(nb):
                idx = perm[b * batch_size:(b + 1) * batch_size]
                params, opt_state, var_state, mets = step_jit(
                    params, opt_state, var_state, (xtr[idx], ytr[idx]))
        jax.block_until_ready(params)
        return params

    epoch_fn = compile_epoch(step, cfg.d, donate=False)

    def scan_loop():
        params, opt_state = state["params"], state["opt_state"]
        rng = jax.random.PRNGKey(seed + 1)
        for ep in range(epochs):
            rng, k = jax.random.split(rng)
            xb, yb = epoch_batches(k, xtr, ytr, batch_size)
            params, opt_state, var_state, mets = epoch_fn(params, opt_state,
                                                          xb, yb)
        jax.block_until_ready(params)
        return params

    # interleave the two drivers and take the median of paired ratios
    # (see lutq_bench: common-mode cpu-share interference cancels inside
    # each pair on this throttled container); per-row latencies report
    # min-of-repeats like the other benches
    host_loop()                                      # compile + warm
    scan_loop()
    ts_host, ts_scan = [], []
    for _ in range(3 * repeats):
        t0 = time.time()
        host_loop()
        ts_host.append(time.time() - t0)
        t0 = time.time()
        scan_loop()
        ts_scan.append(time.time() - t0)
    dt_host, dt_scan = min(ts_host), min(ts_scan)
    pair = sorted(h / s for h, s in zip(ts_host, ts_scan))
    speedup = pair[len(pair) // 2]
    steps_total = epochs * nb
    rows = [
        dict(driver="host_loop", n=n, epochs=epochs, batch=batch_size,
             us_per_step=round(dt_host / steps_total * 1e6, 1)),
        dict(driver="scan_epoch", n=n, epochs=epochs, batch=batch_size,
             us_per_step=round(dt_scan / steps_total * 1e6, 1)),
    ]
    out = dict(n=n, epochs=epochs, batch_size=batch_size,
               steps_per_epoch=nb, rows=rows,
               speedup_scan_vs_host=round(speedup, 3))
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    for r in rows:
        print(f"train,{r['driver']},n={r['n']},epochs={r['epochs']},"
              f"batch={r['batch']},,,,{r['us_per_step']}", flush=True)
    print(f"# train scan-vs-host speedup {out['speedup_scan_vs_host']}x "
          f"-> {out_path}", flush=True)
    return out


def faults_bench(full: bool = False, *, out_path: str = "BENCH_faults.json",
                 n: int = 20_000, nq: int = 32, batches: int = 12,
                 topk: int = 10, seed: int = 0):
    """Chaos target (docs/robustness.md): serve a deterministic fault
    schedule through the resilient engine and report degraded-rate and
    recall-under-faults, written to ``out_path``.

    A seeded ``FaultInjector`` raises inside the Pallas kernel stages
    (forcing the engine's pallas→jnp failover) while the batch stream
    cycles budgets — unbounded, a deadline far below the full path's
    warm time (forcing the ladder down), and crude-only.  Recall is
    measured per batch against a clean full-search engine on the same
    index: the run proves degradation stays *approximate* (recall
    reported), never wrong (no exceptions reach the caller).
    """
    from repro.api import build_ann_engine
    from repro.data.synthetic import make_synthetic_index
    from repro.resilience import FaultInjector, FaultSpec, SearchBudget

    if full:
        n, batches = max(n, 100_000), max(batches, 48)
    key = jax.random.PRNGKey(seed)
    codes, C, structure = make_synthetic_index(key, n, d=16, K=8, m=64,
                                               num_fast=2)
    clean = build_ann_engine(codes, C, structure, topk=topk, backend="jnp")
    inj = FaultInjector(seed=seed,
                        spec=FaultSpec(p_raise=0.3, targets=("kernels.",)))
    chaos = build_ann_engine(codes, C, structure, topk=topk,
                             backend="pallas", fault_injector=inj)
    budgets = (None,
               SearchBudget(deadline_ms=1e-3),     # forces the ladder down
               SearchBudget(allow_refine=False))   # crude floor outright
    recalls, degraded = [], 0
    with inj.installed():
        for i in range(batches):
            q = jax.random.normal(jax.random.fold_in(key, 100 + i),
                                  (nq, structure.xi.shape[0]))
            r = chaos.search(q, budget=budgets[i % len(budgets)])
            ref = clean.search(q)
            hit = np.mean([len(np.intersect1d(a, b)) / topk
                           for a, b in zip(np.asarray(r.indices),
                                           np.asarray(ref.indices))])
            recalls.append(float(hit))
            degraded += int(r.meta.degraded)
    out = {"n": n, "nq": nq, "batches": batches, "topk": topk,
           "seed": seed, "injector_counts": dict(inj.counts),
           "engine_stats": dict(chaos.stats),
           "degraded_rate": degraded / batches,
           "recall_under_faults": float(np.mean(recalls)),
           "recall_worst_batch": float(np.min(recalls))}
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"faults,chaos,n={n},batches={batches},"
          f"degraded_rate={out['degraded_rate']:.2f},"
          f"recall={out['recall_under_faults']:.3f},"
          f"failovers={chaos.stats.get('failovers', 0)},,", flush=True)
    print(f"# faults: degraded_rate {out['degraded_rate']:.2f}, "
          f"recall-under-faults {out['recall_under_faults']:.3f} "
          f"-> {out_path}", flush=True)
    return out


def pipeline_bench(full: bool = False, *,
                   out_path: str = "BENCH_pipeline.json",
                   n: int = 100_000, nq: int = 64, K: int = 8, m: int = 256,
                   topk: int = 50, d: int = 16, tile: int = 16,
                   repeats: int = 9, seed: int = 0):
    """Overlapped crude/refine pipeline (DESIGN.md §13) vs the jitted
    sequential two-step engine, end-to-end us/query at n points, written
    to ``out_path``.

    Both paths run the *same* index state; the sequential side is
    ``jax.jit(index.search)`` — exactly the program ``AnnEngine``
    serves — and the pipelined side is the same index rebuilt with
    ``pipeline="tiles"``, whose executor splits the query batch into
    tiles and dispatches crude(t+1) while refine(t) drains, donating
    the intermediate top-k carry between tiles.  Two operating points
    are measured: *refine-heavy* (``num_fast=2`` of K=8 — the refine
    stage recomputes 6 codebooks per survivor; eq. 2's threshold keeps
    the pass rate low, ~topk/n) and *crude-heavy* (``num_fast=K-2`` —
    the crude pass does nearly all the LUT work and refine touches 2).
    The headline per point is the median of paired ratios over
    interleaved samples (see lutq_bench: common-mode cpu-share
    interference cancels inside each pair); per-row latencies report
    min-of-repeats like the other benches.  Each point also asserts the
    two paths return bitwise-identical ids + distances — the pipeline
    is a pure scheduling change, never an accuracy knob.
    """
    from repro.data.synthetic import make_synthetic_index
    from repro.index import make_index

    if full:
        n, nq = max(n, 1_000_000), max(nq, 256)
    key = jax.random.PRNGKey(seed)
    queries = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))

    rows, speedups = [], {}
    for point, num_fast in (("refine_heavy", 2), ("crude_heavy", K - 2)):
        codes, C, structure = make_synthetic_index(key, n, d=d, K=K, m=m,
                                                   num_fast=num_fast)
        idx = make_index("two-step", codes, C, structure, topk=topk,
                         backend="jnp")
        seq = jax.jit(lambda q, i=idx: i.search(q, topk))
        pipe = make_index("two-step", codes, C, structure, topk=topk,
                          backend="jnp", pipeline="tiles",
                          pipeline_tile=tile)
        res_s = host_copy(seq(queries))          # compile + warm both,
        res_p = host_copy(pipe.search(queries))  # buffers released
        bitwise = (bool(np.array_equal(res_s.indices, res_p.indices))
                   and bool(np.array_equal(res_s.distances,
                                           res_p.distances)))
        assert bitwise, f"pipeline diverged from sequential at {point}"
        ts_s, ts_p = [], []
        for _ in range(repeats):
            t0 = time.time()
            jax.block_until_ready(seq(queries).indices)
            ts_s.append(time.time() - t0)
            t0 = time.time()
            jax.block_until_ready(pipe.search(queries).indices)
            ts_p.append(time.time() - t0)
        pair_ratios = sorted(s / p for s, p in zip(ts_s, ts_p))
        speedups[point] = pair_ratios[len(pair_ratios) // 2]
        for engine, ts, res in (("sequential_jit", ts_s, res_s),
                                ("pipelined_tiles", ts_p, res_p)):
            rows.append(dict(point=point, engine=engine, n=n, nq=nq,
                             num_fast=num_fast,
                             search_us=round(min(ts) / nq * 1e6, 2),
                             avg_ops=round(float(res.avg_ops), 4),
                             pass_rate=round(float(res.pass_rate), 4),
                             bitwise_match=bitwise))

    out = dict(topk=topk, K=K, m=m, d=d, tile=tile, rows=rows,
               speedup_pipelined_refine_heavy=round(
                   speedups["refine_heavy"], 3),
               speedup_pipelined_crude_heavy=round(
                   speedups["crude_heavy"], 3))
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    for r in rows:
        print(f"pipeline,{r['point']},{r['engine']},n={r['n']},"
              f"nq={r['nq']},,{r['avg_ops']},{r['pass_rate']},,"
              f"{r['search_us']}", flush=True)
    print(f"# pipeline speedup refine-heavy "
          f"{out['speedup_pipelined_refine_heavy']}x / crude-heavy "
          f"{out['speedup_pipelined_crude_heavy']}x (tile={tile}, "
          f"bitwise ok) -> {out_path}", flush=True)
    return out


def config_overrides(cfg, target: str):
    """Kwargs for one engine-bench ``--only`` target from an api
    ``ICQConfig`` (repro.api, docs/api.md) — a checked-in config (e.g.
    ``benchmarks/configs/bench_small.json``) pins the geometry/engine
    options so a BENCH run is reproducible bit-for-bit from the repo."""
    t, e, i, s = cfg.train, cfg.encode, cfg.index, cfg.serve
    geom = dict(d=t.d, K=t.num_codebooks, m=t.codebook_size,
                num_fast=t.num_fast)
    table = {
        "search": dict(geom, topk=s.topk),
        "ivf": dict(geom, topk=s.topk, n_lists=i.n_lists,
                    **({"query_chunk": s.query_chunk}
                       if s.query_chunk is not None else {})),
        "lutq": dict(geom, topk=s.topk),
        "fastscan": dict(geom, topk=s.topk),
        "encode": dict(d=t.d, K=t.num_codebooks, m=t.codebook_size,
                       iters=e.icm_iters, chunk=e.chunk,
                       **({"point_chunk": e.point_chunk}
                          if e.point_chunk is not None else {})),
        "train": dict(epochs=t.epochs, batch_size=t.batch_size),
        # pipeline sweeps num_fast itself (its two operating points),
        # so only the remaining geometry comes from the config
        "pipeline": dict(d=t.d, K=t.num_codebooks, m=t.codebook_size,
                         topk=s.topk,
                         **({"tile": s.pipeline_tile}
                            if s.pipeline_tile is not None else {})),
        # serve sweeps the batch window itself; the config pins the
        # geometry and the coalescing tile (ServeConfig.batch_tile)
        "serve": dict(geom, topk=s.topk, tile=s.batch_tile),
    }
    return table.get(target)


CONFIG_TARGETS = ("search", "ivf", "lutq", "fastscan", "encode", "train",
                  "pipeline", "serve")

FIGURES = {
    "fig1": fig1_synthetic_pq.run,
    "fig2": fig2_synthetic_cq.run,
    "fig3": fig3_realworld_sq.run,
    "fig4": fig4_code_length.run,
    "fig5": fig5_pqn.run,
    "fig6": fig6_unseen.run,
    "beyond_ivf": beyond_ivf.run,
    "search": search_bench,
    "ivf": ivf_bench,
    "lutq": lutq_bench,
    "fastscan": fastscan_bench,
    "encode": encode_bench,
    "train": train_bench,
    "faults": faults_bench,
    "pipeline": pipeline_bench,
    "pareto": sweep.run,
    "serve": serve_load.run,
}


def kernel_micro():
    """Pallas-kernel microbenchmarks (interpret on CPU; wall time is NOT
    TPU-indicative — correctness + call-overhead tracking only)."""
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)
    rows = []
    for name, fn, args in [
        ("adc_64k_x8", ops.adc,
         (jax.random.randint(key, (65536, 8), 0, 256),
          jax.random.normal(key, (8, 256)))),
        ("kmeans_16k_256", ops.kmeans_assign,
         (jax.random.normal(key, (16384, 64)),
          jax.random.normal(key, (256, 64)))),
        ("flash_4x512", ops.flash_attention,
         (jax.random.normal(key, (4, 512, 8, 64)),
          jax.random.normal(key, (4, 512, 2, 64)),
          jax.random.normal(key, (4, 512, 2, 64)))),
    ]:
        out = fn(*args)                      # compile
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(3):
            jax.block_until_ready(fn(*args))
        us = (time.time() - t0) / 3 * 1e6
        print(f"kernel,{name},interpret,,,,,,{us:.0f}", flush=True)
        rows.append((name, us))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (hours on CPU)")
    ap.add_argument("--only", default=None,
                    help="run a single section; see docs/benchmarks.md "
                         f"(one of: {', '.join(FIGURES)})")
    ap.add_argument("--config", default=None,
                    help="repro.api ICQConfig JSON pinning the bench "
                         "geometry/engine options (engine targets only: "
                         f"{', '.join(CONFIG_TARGETS)}); e.g. the "
                         "checked-in benchmarks/configs/bench_small.json")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed threaded through every target's data "
                         "generation; same seed => identical "
                         "recall/avg_ops numbers across runs")
    args = ap.parse_args()

    if args.only is not None and args.only not in FIGURES:
        # a typo'd name used to silently run *nothing*; fail loudly
        ap.error(f"unknown --only target {args.only!r}; valid targets: "
                 f"{', '.join(sorted(FIGURES))}")
    overrides = {}
    if args.config is not None:
        if args.only not in CONFIG_TARGETS:
            ap.error(f"--config drives the engine targets "
                     f"({', '.join(CONFIG_TARGETS)}); pass --only "
                     "with one of them")
        from repro.api import ICQConfig
        cfg = ICQConfig.load(args.config)
        overrides = config_overrides(cfg, args.only)
        print(f"# config {args.config} (hash {cfg.config_hash()[:12]}) "
              f"-> {overrides}", flush=True)

    header()
    t0 = time.time()
    for name, run_fn in FIGURES.items():
        if args.only and name != args.only:
            continue
        run_fn(full=args.full, seed=args.seed,
               **(overrides if name == args.only else {}))
    if not args.only:
        kernel_micro()
    print(f"# total {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
