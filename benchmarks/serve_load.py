"""Serving-loop latency under Poisson load (``--only serve`` →
``BENCH_serve.json``; docs/serving.md, docs/benchmarks.md).

Two tenants (a flat two-step index and an IVF index) behind one
``repro.serve.ServingLoop``, driven by a seeded open-loop Poisson
arrival stream across a sweep of coalescing batch windows.  Reported
per (window, tenant): p50/p99 end-to-end latency, request/row
throughput, mean coalescing wait and tile fill.  Three gates ride
along:

  - **bitwise**: every coalesced response is compared to a direct
    ``engine.search`` on the same rows — ids AND distances must match
    exactly (scheduling is never allowed to change math);
  - **determinism**: the no-deadline sweep always serves the full
    ladder level, so result content is seed-deterministic; the JSON
    records one ``ids_sha256`` per window over all delivered ids in
    workload order (tests/test_bench_determinism.py replays it);
  - **degraded-not-broken**: a separate section serves the same tenants
    under an injected ``FaultSpec`` delay with a tight ``deadline_ms``
    budget — responses must degrade (``meta.degraded``), never error.

Latency numbers are wall-clock on a cpu-share throttled container:
like every BENCH target they track trends, not absolute service times,
and are excluded from the determinism contract.
"""
from __future__ import annotations

import hashlib
import json
import time

import jax
import numpy as np


def _ids_sha256(records) -> str:
    h = hashlib.sha256()
    for r in records:
        h.update(np.ascontiguousarray(r["ids"]).tobytes())
    return h.hexdigest()


def run(full: bool = False, *, out_path: str = "BENCH_serve.json",
        n: int = 20_000, d: int = 16, K: int = 8, m: int = 64,
        num_fast: int = 2, topk: int = 10, n_lists: int = 64,
        n_probe: int = 8, tile: int = 8, windows_ms=(0.5, 4.0),
        rate_hz: float = 60.0, duration_s: float = 1.25,
        pool_q: int = 64, closed_requests: int = 48, seed: int = 0):
    """Serve two tenants under seeded Poisson traffic per batch-window
    setting; write latency/throughput/coalescing rows + the bitwise and
    degraded gates to ``out_path``."""
    from repro.api import build_ann_engine
    from repro.core import codebooks as cb
    from repro.data.synthetic import make_synthetic_index
    from repro.resilience import FaultInjector, FaultSpec, SearchBudget
    from repro.serve import (ServingLoop, Tenant, make_workload,
                             run_closed_loop, run_open_loop, summarize)

    if full:
        n, duration_s = max(n, 100_000), max(duration_s, 5.0)
    key = jax.random.PRNGKey(seed)
    codes, C, structure = make_synthetic_index(key, n, d=d, K=K, m=m,
                                               num_fast=num_fast)
    key2 = jax.random.fold_in(key, 1)
    codes2, C2, structure2 = make_synthetic_index(key2, n, d=d, K=K, m=m,
                                                  num_fast=num_fast)
    emb_db2 = cb.decode(C2, codes2)

    def build_tenants(fault_injector=None, budget=None):
        flat = build_ann_engine(codes, C, structure, topk=topk,
                                backend="jnp",
                                fault_injector=fault_injector)
        ivf = build_ann_engine(codes2, C2, structure2, topk=topk,
                               backend="jnp", index="ivf", emb_db=emb_db2,
                               n_lists=n_lists, n_probe=n_probe,
                               key=jax.random.fold_in(key, 2),
                               fault_injector=fault_injector)
        return [Tenant(name="flat", engine=flat, budget=budget),
                Tenant(name="ivf", engine=ivf, budget=budget)]

    tenants = build_tenants()
    rng_pool = np.random.default_rng(seed)
    pools = {t.name: rng_pool.standard_normal((pool_q, d)).astype(np.float32)
             for t in tenants}

    rows, window_hashes, bitwise_ok = [], {}, True
    for w in windows_ms:
        # fresh same-seed workload per window: identical request stream,
        # only the coalescing policy changes
        workload = make_workload(pools, rate_hz, duration_s,
                                 rng=np.random.default_rng(seed + 1))
        with ServingLoop(tenants, window_ms=w, tile=tile) as loop:
            for t in tenants:
                loop.warm(t.name)
            t0 = time.time()
            records = run_open_loop(loop, workload)
            wall_s = time.time() - t0
            stats = dict(loop.stats)
        window_hashes[str(w)] = _ids_sha256(records)
        # bitwise gate: each delivered response vs a direct engine call
        by_name = {t.name: t for t in tenants}
        for spec, rec in zip(workload, records):
            ref = by_name[spec.tenant].engine.search(spec.queries)
            if not (np.array_equal(rec["ids"], np.asarray(ref.indices))
                    and np.array_equal(rec["dists"],
                                       np.asarray(ref.distances))):
                bitwise_ok = False
        for name in sorted(pools):
            srec = [r for r in records if r["tenant"] == name]
            s = summarize(srec, wall_s=wall_s)
            rows.append(dict(window_ms=w, tenant=name, tile=tile, **{
                k: (round(v, 3) if isinstance(v, float) else v)
                for k, v in s.items()}))
        agg = summarize(records, wall_s=wall_s)
        rows.append(dict(window_ms=w, tenant="ALL", tile=tile,
                         batches=stats["batches"],
                         flush_full=stats["flush_full"],
                         flush_window=stats["flush_window"], **{
                             k: (round(v, 3) if isinstance(v, float) else v)
                             for k, v in agg.items()}))

    # closed-loop saturation row at the middle window
    workload = make_workload(pools, rate_hz, duration_s,
                             rng=np.random.default_rng(seed + 1))
    with ServingLoop(tenants, window_ms=windows_ms[0], tile=tile) as loop:
        t0 = time.time()
        crec = run_closed_loop(loop, workload, concurrency=4)
        cwall = time.time() - t0
    closed = {k: (round(v, 3) if isinstance(v, float) else v)
              for k, v in summarize(crec, wall_s=cwall).items()}

    # degraded-not-broken: injected delay + tight deadline must produce
    # meta.degraded responses, never exceptions
    inj = FaultInjector(seed=seed, spec=FaultSpec(
        p_delay=0.8, delay_ms=25.0, targets=("engine.search",)))
    tight = SearchBudget(deadline_ms=2.0)
    faulted = build_tenants(fault_injector=inj, budget=tight)
    fwork = make_workload({t.name: pools[t.name] for t in faulted},
                          rate_hz, min(duration_s, 1.0),
                          rng=np.random.default_rng(seed + 2))
    with inj.installed():
        with ServingLoop(faulted, window_ms=windows_ms[0],
                         tile=tile) as loop:
            t0 = time.time()
            frec = run_open_loop(loop, fwork)
            fwall = time.time() - t0
    fsum = summarize(frec, wall_s=fwall)

    out = dict(seed=seed, n=n, d=d, topk=topk, tile=tile,
               rate_hz=rate_hz, duration_s=duration_s,
               tenants=sorted(pools), windows_ms=list(windows_ms),
               rows=rows,
               closed_loop=dict(window_ms=windows_ms[0],
                                concurrency=4, **closed),
               bitwise_coalesced_vs_direct=bitwise_ok,
               ids_sha256_per_window=window_hashes,
               degraded_under_faults=dict(
                   deadline_ms=tight.deadline_ms,
                   requests=fsum["requests"],
                   degraded_rate=round(fsum["degraded_rate"], 3),
                   p50_ms=round(fsum["p50_ms"], 3),
                   errors=0))
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    for r in rows:
        print(f"serve,window={r['window_ms']},tenant={r['tenant']},"
              f"req={r['requests']},p50={r['p50_ms']},p99={r['p99_ms']},"
              f"qps={r['qps']},fill={r['mean_batch_fill']}", flush=True)
    print(f"# serve bitwise={bitwise_ok} degraded_rate_under_faults="
          f"{out['degraded_under_faults']['degraded_rate']} "
          f"closed_qps={closed['qps']} -> {out_path}", flush=True)
    assert bitwise_ok, "coalesced results diverged from direct search"
    return out
