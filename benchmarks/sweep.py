"""Recall/QPS Pareto sweep (``--only pareto`` -> ``BENCH_pareto.json``).

Grid-sweeps the serving-time knobs — (n_probe, num_fast, refine_cap,
lut_dtype, code_bits) — on a real-shaped workload (``pseudo_sift``:
d=128, clustered, heavy-tailed; queries drawn power-law-skewed like
production traffic), measuring recall@k against the *exact* brute-force
neighbors (``repro.eval.cached_ground_truth`` — unlike the engine
benches, which score against the full ADC ranking) and QPS
(min-of-repeats wall time) per grid point.  The Pareto frontier is
extracted with ``repro.eval.pareto_frontier`` and written alongside the
raw rows; ``repro.api.ICQSession.tune`` is the programmatic face of the
same search (docs/api.md).

    PYTHONPATH=src python -m benchmarks.run --only pareto [--seed N]

JSON schema (docs/benchmarks.md):
    workload, n, nq, d, K, m, k, seed, gt_cache_hit,
    rows:     [{kind, n_probe, num_fast, refine_cap, lut_dtype,
                code_bits, recall, qps, search_us, avg_ops, pass_rate}],
    frontier: [rows on the Pareto frontier, descending qps],
    frontier_monotone: bool (recall non-decreasing as qps decreases)
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def _default_grid(n_lists: int, K: int, k: int):
    """>= 12 serving configurations spanning every swept knob.

    IVF rows sweep (n_probe x num_fast), then refine_cap / int8-LUT /
    4-bit-code variants at the headline probe counts; two flat two-step
    rows anchor the n_probe=None end of the frontier.
    """
    base = dict(kind="ivf", n_probe=None, num_fast=None, refine_cap=None,
                lut_dtype="f32", code_bits=8)
    grid = []
    nf_lo, nf_hi = max(1, K // 4), max(2, K // 2)
    for n_probe in (2, 4, 8, 16):
        for nf in (nf_lo, nf_hi):
            grid.append(dict(base, n_probe=min(n_probe, n_lists),
                             num_fast=nf))
    for cap in (4 * k, 16 * k):
        grid.append(dict(base, n_probe=8, num_fast=nf_lo, refine_cap=cap))
    grid.append(dict(base, n_probe=8, num_fast=nf_lo, lut_dtype="int8"))
    grid.append(dict(base, n_probe=16, num_fast=nf_lo, lut_dtype="int8"))
    grid.append(dict(base, n_probe=8, num_fast=nf_lo, lut_dtype="int8",
                     code_bits=4))
    grid.append(dict(base, kind="two_step", num_fast=nf_lo))
    grid.append(dict(base, kind="two_step", num_fast=nf_hi))
    return grid


def run(full: bool = False, *, out_path: str = "BENCH_pareto.json",
        n: int = 20_000, nq: int = 128, d: int = 128, n_clusters: int = 64,
        K: int = 16, m: int = 16, k: int = 10, n_lists: int = 64,
        icm_iters: int = 3, margin_scale: float = 0.5, repeats: int = 3,
        grid=None, cache_dir: str = ".gt_cache", workload: str = "sift",
        seed: int = 0):
    """The recall/QPS sweep.  Geometry is pinned to m <= 16 so the same
    trained quantizer serves both the byte-coded and the nibble-packed
    (``code_bits=4``) grid points; ``margin_scale`` sets the eq. 2
    sigma from the db's out-of-psi variance mass (smaller = more
    selective crude filter).  Same seed => identical JSON.
    """
    from benchmarks.common import recall_at_k
    from repro import eval as eval_mod
    from repro.core import codebooks as cb
    from repro.core import icq as icq_mod
    from repro.core.encode import icm_encode, pack_codes, pack_nibbles
    from repro.data.pseudo_real import (pseudo_glove, pseudo_sift,
                                        skewed_queries)
    from repro.index import (IVFTwoStep, TwoStep, build_ivf,
                             ivf_list_codes)

    if full:
        n, nq = max(n, 100_000), max(nq, 256)
    gen = pseudo_sift if workload == "sift" else pseudo_glove
    if workload == "glove":
        d = 300
    db, _, cid = gen(n, nq, d=d, n_clusters=n_clusters, seed=seed)
    queries, _ = skewed_queries(db, cid, nq, seed=seed)
    gt_ids, _, gt_hit = eval_mod.cached_ground_truth(db, queries, k,
                                                     cache_dir=cache_dir)

    # train the quantizer once; every grid point is a serving-time
    # reconfiguration of the same codes (exactly what session.tune does)
    key = jax.random.PRNGKey(seed)
    db_j = jnp.asarray(db)
    q_j = jnp.asarray(queries)
    C = cb.init_residual(key, db_j[:8192], K, m, iters=10)
    codes_i = icm_encode(db_j, C, icm_iters, backend="jnp",
                         point_chunk=8192).astype(jnp.int32)
    codes8 = pack_codes(codes_i, m)
    codes4 = pack_nibbles(codes_i, K)
    # psi = the top-variance half of the dims; sigma = eq. 11 over the
    # variance mass outside psi (the identity-embedding analogue of the
    # trained prior)
    lam = jnp.var(db_j, axis=0)
    xi = jnp.zeros((d,), bool).at[jnp.argsort(-lam)[: d // 2]].set(True)
    sigma = icq_mod.margin_sigma(lam, xi, margin_scale)
    structures = {}

    def structure(num_fast):
        if num_fast not in structures:
            mask = icq_mod.fast_set_topk(C, xi, num_fast)
            structures[num_fast] = icq_mod.ICQStructure(
                xi=xi, fast_mask=mask, sigma=sigma)
        return structures[num_fast]

    ivf = build_ivf(jax.random.fold_in(key, 3), db_j, n_lists)
    slabs = {8: ivf_list_codes(ivf, codes8), 4: ivf_list_codes(ivf, codes4)}

    def build_point(g):
        cds = codes4 if g["code_bits"] == 4 else codes8
        kw = dict(codes=cds, C=C, structure=structure(g["num_fast"]),
                  topk=k, backend="jnp", refine_cap=g["refine_cap"],
                  lut_dtype=g["lut_dtype"], code_bits=g["code_bits"])
        if g["kind"] == "ivf":
            return IVFTwoStep(ivf=ivf, n_probe=g["n_probe"],
                              list_codes=slabs[g["code_bits"]], **kw)
        return TwoStep(**kw)

    rows = []
    for g in grid if grid is not None else _default_grid(n_lists, K, k):
        idx = build_point(g)
        call = jax.jit(lambda q, i=idx: i.search(q, k))
        res = call(q_j)
        jax.block_until_ready(res.indices)       # compile + warm
        ts = []
        for _ in range(repeats):
            t0 = time.time()
            jax.block_until_ready(call(q_j).indices)
            ts.append(time.time() - t0)
        # min-of-repeats: cpu-share throttled container (see run.py)
        dt = min(ts)
        row = dict(g, recall=round(recall_at_k(res.indices, gt_ids, k), 4),
                   qps=round(nq / dt, 1),
                   search_us=round(dt / nq * 1e6, 2),
                   avg_ops=round(float(res.avg_ops), 4),
                   pass_rate=round(float(res.pass_rate), 4))
        rows.append(row)
        print(f"pareto,{row['kind']},probe={row['n_probe']},"
              f"nf={row['num_fast']},cap={row['refine_cap']},"
              f"lut={row['lut_dtype']},bits={row['code_bits']},"
              f"recall={row['recall']},qps={row['qps']},"
              f"{row['search_us']}", flush=True)

    front_idx = eval_mod.pareto_frontier(rows)
    frontier = [rows[i] for i in front_idx]
    out = dict(workload=workload, n=n, nq=nq, d=d, K=K, m=m, k=k,
               seed=seed, n_lists=n_lists, margin_scale=margin_scale,
               gt_cache_hit=bool(gt_hit), rows=rows, frontier=frontier,
               frontier_monotone=eval_mod.is_monotone_frontier(frontier))
    with open(out_path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# pareto: {len(rows)} configs, frontier {len(frontier)} "
          f"(monotone {out['frontier_monotone']}), recall "
          f"{frontier[-1]['recall']}@{frontier[-1]['qps']}qps .. "
          f"{frontier[0]['recall']}@{frontier[0]['qps']}qps -> {out_path}",
          flush=True)
    return out
