"""Quickstart: the front-door api end to end — config, fit, index,
search, save, reload (docs/api.md).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.api import (ICQConfig, IndexConfig, ServeConfig, TrainConfig,
                       icq_session, load_ann_engine)
from repro.data import make_table1_dataset
from repro.index import (adc_search, exact_search, mean_average_precision,
                         recall_at)


def main():
    # --- data: Table-1 dataset 3 (8 informative of 64 features) ---
    xtr, ytr, xte, yte = make_table1_dataset("dataset3")
    xtr, ytr, xte, yte = xtr[:3000], ytr[:3000], xte[:200], yte[:200]

    # --- one config for the whole lifecycle (JSON round-trippable) ---
    cfg = ICQConfig(
        train=TrainConfig(d=16, num_codebooks=8, codebook_size=64,
                          num_fast=2, epochs=6),
        index=IndexConfig(kind="two-step"),
        serve=ServeConfig(topk=50))

    # --- fit -> index -> search through one session ---
    session = icq_session(cfg)
    model = session.fit(xtr, ytr, key=jax.random.PRNGKey(0), verbose=True)
    print(f"psi: {int(model.structure.xi.sum())}/16 dims, "
          f"fast codebooks: {int(model.structure.fast_mask.sum())}/8, "
          f"margin sigma: {float(model.structure.sigma):.2f}")

    searcher = session.index()                 # index over the fit data
    r2 = searcher.search(xte)                  # raw queries; model embeds

    # --- compare: crude-first two-step vs full ADC vs exact ---
    emb_q, emb_db = model.embed(xte), model.embed(xtr)
    r1 = adc_search(emb_q, model.codes, model.C, 50)
    gt, _ = exact_search(emb_q, emb_db, 50)
    for name, r in (("two-step", r2), ("adc", r1)):
        print(f"{name:9s} MAP={float(mean_average_precision(r.indices, ytr, yte)):.4f} "
              f"recall@50={float(recall_at(r.indices, gt)):.3f} "
              f"avg_ops={float(r.avg_ops):.2f}/8")
    print(f"speedup at equal codes: {float(r1.avg_ops / r2.avg_ops):.2f}x")

    # --- persist + reload: bitwise-identical serving in a fresh process ---
    path = searcher.save("/tmp/icq_quickstart")
    reloaded = load_ann_engine(path)
    r3 = reloaded(emb_q)
    assert bool((r3.indices == r2.indices).all())
    print(f"artifacts -> {path} (reload serves identical ids)")


if __name__ == "__main__":
    main()
