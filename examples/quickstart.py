"""Quickstart: fit ICQ on a synthetic dataset and run the two-step search.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import ICQConfig
from repro.core import (adc_search, exact_search, fit,
                        mean_average_precision, recall_at, two_step_search)
from repro.data import make_table1_dataset


def main():
    # --- data: Table-1 dataset 3 (8 informative of 64 features) ---
    xtr, ytr, xte, yte = make_table1_dataset("dataset3")
    xtr, ytr, xte, yte = xtr[:3000], ytr[:3000], xte[:200], yte[:200]

    # --- joint training: embedding W + codebooks C + prior Theta ---
    cfg = ICQConfig(d=16, num_codebooks=8, codebook_size=64, num_fast=2)
    model = fit(jax.random.PRNGKey(0), xtr, ytr, cfg, mode="icq",
                epochs=6, verbose=True)
    print(f"psi: {int(model.structure.xi.sum())}/16 dims, "
          f"fast codebooks: {int(model.structure.fast_mask.sum())}/8, "
          f"margin sigma: {float(model.structure.sigma):.2f}")

    # --- search: crude-first two-step vs full ADC vs exact ---
    emb_q, emb_db = model.embed(xte), model.embed(xtr)
    r2 = two_step_search(emb_q, model.codes, model.C, model.structure, 50)
    r1 = adc_search(emb_q, model.codes, model.C, 50)
    gt, _ = exact_search(emb_q, emb_db, 50)

    for name, r in (("two-step", r2), ("adc", r1)):
        print(f"{name:9s} MAP={float(mean_average_precision(r.indices, ytr, yte)):.4f} "
              f"recall@50={float(recall_at(r.indices, gt)):.3f} "
              f"avg_ops={float(r.avg_ops):.2f}/8")
    print(f"speedup at equal codes: {float(r1.avg_ops / r2.avg_ops):.2f}x")


if __name__ == "__main__":
    main()
