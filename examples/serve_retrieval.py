"""Retrieval serving with the Pallas kernels: crude scan (fused two_step
kernel) + survivor refinement (adc kernel), batched over queries — the
TPU execution shape of the paper's search (DESIGN.md §3).

On CPU the kernels run in interpret mode (slow but bit-faithful); on a
TPU backend the same code hits the MXU.

    PYTHONPATH=src python examples/serve_retrieval.py --queries 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ICQConfig
from repro.core import fit, mean_average_precision
from repro.core.search import build_lut
from repro.data import make_table1_dataset
from repro.kernels import ops


def serve_query(q_emb, model, topk=50, refine_cap=256):
    """One query through the kernel path: two_step -> compact -> adc."""
    lut = build_lut(q_emb, model.C)                       # (K, m)
    fast = model.structure.fast_mask
    # bootstrap threshold from the crude top-k (host-side, tiny)
    crude_boot = ops.adc(model.codes,
                         lut * fast[:, None].astype(lut.dtype))
    cand = jax.lax.top_k(-crude_boot, topk)[1]
    full_cand = ops.adc(model.codes[cand], lut)
    far = cand[jnp.argmax(full_cand)]
    thr = crude_boot[far] + model.structure.sigma
    # fused crude + margin test (phase 1)
    crude, passed = ops.two_step(model.codes, lut, fast, thr)
    # compact survivors (static cap), refine with full codes (phase 2)
    masked = jnp.where(passed > 0, crude, jnp.inf)
    surv = jax.lax.top_k(-masked, refine_cap)[1]
    full = ops.adc(model.codes[surv], lut)
    full = jnp.where(jnp.isfinite(-jax.lax.top_k(-masked, refine_cap)[0]),
                     full, jnp.inf)
    order = jax.lax.top_k(-full, topk)[1]
    return surv[order], float(jnp.mean((passed > 0).astype(jnp.float32)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--topk", type=int, default=50)
    args = ap.parse_args()

    xtr, ytr, xte, yte = make_table1_dataset("dataset3")
    xtr, ytr = xtr[:4000], ytr[:4000]
    cfg = ICQConfig(d=16, num_codebooks=8, codebook_size=64, num_fast=2)
    print("fitting index...")
    model = fit(jax.random.PRNGKey(0), xtr, ytr, cfg, mode="icq", epochs=5)

    nq = args.queries
    emb_q = model.embed(xte[:nq])
    t0 = time.time()
    ids, pass_rates = [], []
    for i in range(nq):
        idx, pr = serve_query(emb_q[i], model, topk=args.topk)
        ids.append(np.asarray(idx))
        pass_rates.append(pr)
    dt = time.time() - t0
    ids = np.stack(ids)
    mapv = float(mean_average_precision(jnp.asarray(ids), ytr, yte[:nq]))
    K, kf = cfg.num_codebooks, cfg.num_fast
    ops_avg = kf + np.mean(pass_rates) * (K - kf)
    print(f"{nq} queries in {dt:.2f}s ({dt / nq * 1e3:.1f} ms/q interpret)")
    print(f"MAP={mapv:.4f}  pass_rate={np.mean(pass_rates):.3f}  "
          f"avg_ops={ops_avg:.2f}/{K}")


if __name__ == "__main__":
    main()
