"""Retrieval serving with the batched two-step engine: the whole query
batch goes through one fused dispatch (quant.serve_icq.build_ann_engine
-> core.search two-step, DESIGN.md §3.5) instead of a per-query loop.

backend="jnp" is the vectorized reference; backend="pallas" runs the
(query-tile x point-tile) fused kernels — interpret mode on CPU (slow
but bit-faithful), the MXU path on a TPU backend.

    PYTHONPATH=src python examples/serve_retrieval.py --queries 32
    PYTHONPATH=src python examples/serve_retrieval.py --backend pallas
"""
import argparse
import time

import jax

from repro.configs.base import ICQConfig
from repro.core import fit, mean_average_precision
from repro.data import make_table1_dataset
from repro.quant.serve_icq import build_ann_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--topk", type=int, default=50)
    ap.add_argument("--backend", default="jnp",
                    choices=["auto", "jnp", "pallas"])
    args = ap.parse_args()

    xtr, ytr, xte, yte = make_table1_dataset("dataset3")
    xtr, ytr = xtr[:4000], ytr[:4000]
    cfg = ICQConfig(d=16, num_codebooks=8, codebook_size=64, num_fast=2)
    print("fitting index...")
    model = fit(jax.random.PRNGKey(0), xtr, ytr, cfg, mode="icq", epochs=5)

    engine = build_ann_engine(model.codes, model.C, model.structure,
                              topk=args.topk, backend=args.backend)
    nq = args.queries
    emb_q = model.embed(xte[:nq])
    res = engine(emb_q)                            # compile + warm
    jax.block_until_ready(res.indices)
    t0 = time.time()
    res = engine(emb_q)
    jax.block_until_ready(res.indices)
    dt = time.time() - t0

    mapv = float(mean_average_precision(res.indices, ytr, yte[:nq]))
    K = cfg.num_codebooks
    print(f"{nq} queries in {dt * 1e3:.1f} ms "
          f"({dt / nq * 1e3:.2f} ms/q, backend={args.backend})")
    print(f"MAP={mapv:.4f}  pass_rate={float(res.pass_rate):.3f}  "
          f"avg_ops={float(res.avg_ops):.2f}/{K}")


if __name__ == "__main__":
    main()
