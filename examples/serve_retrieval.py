"""Retrieval serving with the unified index layer: the whole query
batch goes through one fused dispatch (quant.serve_icq.build_ann_engine
-> repro.index, DESIGN.md §7) instead of a per-query loop.

--index picks the implementation: "two-step" (exhaustive ICQ),
"flat" (one-step ADC baseline), or "ivf" (coarse-partitioned ICQ —
probes --probe of --lists inverted lists per query).  --shards N
serves the index sharded over an N-way data mesh (per-shard top-k +
global merge; ids identical to single-device) — on CPU run under
XLA_FLAGS=--xla_force_host_platform_device_count=N.

backend="jnp" is the vectorized reference; backend="pallas" runs the
fused (query-tile x point/candidate-tile) kernels — interpret mode on
CPU (slow but bit-faithful), the MXU path on a TPU backend.

    PYTHONPATH=src python examples/serve_retrieval.py --queries 32
    PYTHONPATH=src python examples/serve_retrieval.py --index ivf --probe 8
    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
        python examples/serve_retrieval.py --index ivf --shards 4
"""
import argparse
import time

import jax

from repro.configs.base import ICQConfig
from repro.core import fit, mean_average_precision
from repro.data import make_table1_dataset
from repro.quant.serve_icq import build_ann_engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--topk", type=int, default=50)
    ap.add_argument("--backend", default="jnp",
                    choices=["auto", "jnp", "pallas"])
    ap.add_argument("--index", default="two-step",
                    choices=["flat", "two-step", "ivf"])
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--lists", type=int, default=64)
    ap.add_argument("--probe", type=int, default=8)
    ap.add_argument("--lut-dtype", default="f32", choices=["f32", "int8"],
                    help="crude-pass LUT precision (DESIGN.md §8)")
    args = ap.parse_args()

    xtr, ytr, xte, yte = make_table1_dataset("dataset3")
    xtr, ytr = xtr[:4000], ytr[:4000]
    cfg = ICQConfig(d=16, num_codebooks=8, codebook_size=64, num_fast=2)
    print("fitting index...")
    model = fit(jax.random.PRNGKey(0), xtr, ytr, cfg, mode="icq", epochs=5)

    mesh = None
    if args.shards > 1:
        if len(jax.devices()) < args.shards:
            raise SystemExit(
                f"--shards {args.shards} needs that many devices; on CPU "
                "set XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{args.shards}")
        mesh = jax.make_mesh((args.shards,), ("data",))
    emb_db = model.embed(xtr) if args.index == "ivf" else None
    engine = build_ann_engine(model.codes, model.C, model.structure,
                              topk=args.topk, backend=args.backend,
                              index=args.index, mesh=mesh, emb_db=emb_db,
                              n_lists=args.lists, n_probe=args.probe,
                              lut_dtype=args.lut_dtype,
                              key=jax.random.PRNGKey(1))
    nq = args.queries
    emb_q = model.embed(xte[:nq])
    res = engine(emb_q)                            # compile + warm
    jax.block_until_ready(res.indices)
    t0 = time.time()
    res = engine(emb_q)
    jax.block_until_ready(res.indices)
    dt = time.time() - t0

    mapv = float(mean_average_precision(res.indices, ytr, yte[:nq]))
    K = cfg.num_codebooks
    print(f"{nq} queries in {dt * 1e3:.1f} ms "
          f"({dt / nq * 1e3:.2f} ms/q, index={args.index}, "
          f"backend={args.backend}, shards={args.shards})")
    print(f"MAP={mapv:.4f}  pass_rate={float(res.pass_rate):.3f}  "
          f"avg_ops={float(res.avg_ops):.2f}/{K}")


if __name__ == "__main__":
    main()
