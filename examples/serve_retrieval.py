"""Retrieval serving with the front-door api: one config tree drives
fit → index → search (``repro.api``, docs/api.md), and the whole query
batch goes through one fused dispatch (the unified index layer,
DESIGN.md §7) instead of a per-query loop.

--index picks the implementation: "two-step" (exhaustive ICQ),
"flat" (one-step ADC baseline), or "ivf" (coarse-partitioned ICQ —
probes --probe of --lists inverted lists per query).  --shards N
serves the index sharded over an N-way data mesh (per-shard top-k +
global merge; ids identical to single-device) — on CPU run under
XLA_FLAGS=--xla_force_host_platform_device_count=N.  --save-artifacts
persists config + model + index for a fresh process
(``launch/serve.py --load-artifacts``).

backend="jnp" is the vectorized reference; backend="pallas" runs the
fused (query-tile x point/candidate-tile) kernels — interpret mode on
CPU (slow but bit-faithful), the MXU path on a TPU backend.

    PYTHONPATH=src python examples/serve_retrieval.py --queries 32
    PYTHONPATH=src python examples/serve_retrieval.py --index ivf --probe 8
    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
        python examples/serve_retrieval.py --index ivf --shards 4
"""
import argparse
import time

import jax

from repro.api import (ICQConfig, IndexConfig, ServeConfig, TrainConfig,
                       icq_session)
from repro.index import mean_average_precision


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--topk", type=int, default=50)
    ap.add_argument("--backend", default="jnp",
                    choices=["auto", "jnp", "pallas"])
    ap.add_argument("--index", default="two-step",
                    choices=["flat", "two-step", "ivf"])
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--lists", type=int, default=64)
    ap.add_argument("--probe", type=int, default=8)
    ap.add_argument("--lut-dtype", default="f32", choices=["f32", "int8"],
                    help="crude-pass LUT precision (DESIGN.md §8)")
    ap.add_argument("--save-artifacts", default=None, metavar="DIR",
                    help="persist config + model + index after serving")
    args = ap.parse_args()

    xtr, ytr, xte, yte = make_data()
    cfg = ICQConfig(
        train=TrainConfig(d=16, num_codebooks=8, codebook_size=64,
                          num_fast=2, epochs=5),
        index=IndexConfig(kind=args.index, n_lists=args.lists,
                          n_probe=args.probe),
        serve=ServeConfig(topk=args.topk, backend=args.backend,
                          lut_dtype=args.lut_dtype))

    mesh = None
    if args.shards > 1:
        if len(jax.devices()) < args.shards:
            raise SystemExit(
                f"--shards {args.shards} needs that many devices; on CPU "
                "set XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{args.shards}")
        mesh = jax.make_mesh((args.shards,), ("data",))

    print("fitting index...")
    session = icq_session(cfg)
    session.fit(xtr, ytr, key=jax.random.PRNGKey(0))
    searcher = session.index(mesh=mesh, key=jax.random.PRNGKey(1))

    nq = args.queries
    res = searcher.search(xte[:nq])                # compile + warm
    jax.block_until_ready(res.indices)
    t0 = time.time()
    res = searcher.search(xte[:nq])
    jax.block_until_ready(res.indices)
    dt = time.time() - t0

    mapv = float(mean_average_precision(res.indices, ytr, yte[:nq]))
    K = cfg.train.num_codebooks
    print(f"{nq} queries in {dt * 1e3:.1f} ms "
          f"({dt / nq * 1e3:.2f} ms/q, index={args.index}, "
          f"backend={args.backend}, shards={args.shards})")
    print(f"MAP={mapv:.4f}  pass_rate={float(res.pass_rate):.3f}  "
          f"avg_ops={float(res.avg_ops):.2f}/{K}")

    if args.save_artifacts:
        path = searcher.save(args.save_artifacts)
        print(f"artifacts -> {path} (serve with launch/serve.py "
              "--load-artifacts)")


def make_data():
    from repro.data import make_table1_dataset
    xtr, ytr, xte, yte = make_table1_dataset("dataset3")
    return xtr[:4000], ytr[:4000], xte, yte


if __name__ == "__main__":
    main()
