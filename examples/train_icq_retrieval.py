"""End-to-end driver: train a retrieval coder (embedding + ICQ quantizer)
with checkpointed, scan-compiled training, build the index, grow it
incrementally, and evaluate — the paper's workload on the framework's
full substrate (DESIGN.md §9).

Each epoch is ONE compiled ``lax.scan`` over pre-permuted
device-resident batches (``trainer.compile_epoch``, donated state) —
the host only touches the loop to checkpoint between epochs.  Export
runs the tiled ICM encoding engine; the last rows are held out of the
initial build and appended afterwards through ``Index.add`` to show the
incremental path produces the same serving surface.

    PYTHONPATH=src python examples/train_icq_retrieval.py --epochs 8
"""
import argparse
import time

import jax

from repro.api import Artifacts, ICQConfig, ServeConfig, TrainConfig
from repro.distributed import CheckpointManager
from repro.index import adc_search, make_index, mean_average_precision
from repro.trainer import (compile_epoch, epoch_batches, finalize,
                           init_train_state, make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="dataset2")
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/icq_retrieval_ckpt")
    ap.add_argument("--hold-out", type=int, default=256,
                    help="rows appended via Index.add after the build")
    ap.add_argument("--save-artifacts", default=None, metavar="DIR",
                    help="persist config + model + index at the end")
    args = ap.parse_args()

    from repro.data import make_table1_dataset
    xtr, ytr, xte, yte = make_table1_dataset(args.dataset)
    # the api config is the source of truth; this example drives the
    # trainer layer underneath it by hand to thread checkpointing
    api_cfg = ICQConfig(train=TrainConfig(
        d=16, num_codebooks=8, codebook_size=64, num_fast=2,
        epochs=args.epochs, batch_size=args.batch_size),
        serve=ServeConfig(topk=50, backend="jnp"))
    cfg = api_cfg.train.hyperparams(icm_iters=api_cfg.encode.icm_iters)

    # explicit epoch loop (vs trainer.fit) to thread checkpointing; the
    # per-epoch work is still one compiled scan with donated state
    key = jax.random.PRNGKey(0)
    k_init, k_shuffle = jax.random.split(key)
    state = init_train_state(
        k_init, cfg, embed_kind="linear", d_raw=64, num_classes=10,
        mode="icq", sample_batch=(xtr[:4096], ytr[:4096]))
    step = make_train_step(cfg, state["embed_apply"], state["opt"], "icq",
                           None)
    epoch_fn = compile_epoch(step, cfg.d)
    params, opt_state = state["params"], state["opt_state"]
    var_state = state["var_state"]
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    start_ep, restored = ckpt.restore_latest(
        {"params": params, "opt": opt_state})
    if start_ep is not None:
        params, opt_state = restored["params"], restored["opt"]
        print(f"resumed from epoch {start_ep}")

    t0 = time.time()
    for ep in range((start_ep + 1) if start_ep is not None else 0,
                    args.epochs):
        xb, yb = epoch_batches(jax.random.fold_in(k_shuffle, ep), xtr, ytr,
                               args.batch_size)
        params, opt_state, var_state, mets = epoch_fn(params, opt_state,
                                                      xb, yb)
        ckpt.save_async(ep, {"params": params, "opt": opt_state})
        print(f"epoch {ep}: total={float(mets['total']):.4f} "
              f"l_e={float(mets['l_e']):.4f} l_c={float(mets['l_c']):.4f} "
              f"psi={int(mets['psi_size'])}")
    ckpt.wait()
    print(f"train {time.time() - t0:.1f}s (one compiled scan per epoch)")

    # hold the tail out of the export, append it through the engine
    n_built = xtr.shape[0] - args.hold_out
    model = finalize(params, state["embed_apply"], var_state, cfg,
                     xtr[:n_built], mode="icq")
    idx = make_index("two-step", model.codes, model.C, model.structure,
                     topk=50, backend="jnp")
    idx = idx.add(model.embed(xtr[n_built:]), icm_iters=cfg.icm_iters)
    assert idx.codes.shape[0] == xtr.shape[0]
    print(f"index: built n={n_built}, +{args.hold_out} via Index.add "
          f"-> n={idx.codes.shape[0]} (no retrain)")

    emb_q = model.embed(xte)
    r2 = idx.search(emb_q)
    r1 = adc_search(emb_q, idx.codes, model.C, 50)
    print(f"two-step MAP={float(mean_average_precision(r2.indices, ytr, yte)):.4f} "
          f"ops={float(r2.avg_ops):.2f} | "
          f"adc MAP={float(mean_average_precision(r1.indices, ytr, yte)):.4f} "
          f"ops={float(r1.avg_ops):.2f}")

    if args.save_artifacts:
        path = Artifacts(config=api_cfg, model=model,
                         index=idx).save(args.save_artifacts)
        print(f"artifacts -> {path} (serve with launch/serve.py "
              "--load-artifacts)")


if __name__ == "__main__":
    main()
