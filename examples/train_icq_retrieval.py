"""End-to-end driver: train a retrieval coder (embedding + ICQ quantizer)
with checkpointed, fault-supervised training, build the index, and
evaluate — the paper's workload on the framework's full substrate.

    PYTHONPATH=src python examples/train_icq_retrieval.py --epochs 8
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import ICQConfig
from repro.core import (adc_search, mean_average_precision, two_step_search)
from repro.core import train as core_train
from repro.core import variance
from repro.data import make_table1_dataset
from repro.data.pipeline import ArrayPipeline
from repro.distributed import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="dataset2")
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/icq_retrieval_ckpt")
    args = ap.parse_args()

    xtr, ytr, xte, yte = make_table1_dataset(args.dataset)
    cfg = ICQConfig(d=16, num_codebooks=8, codebook_size=64, num_fast=2)

    # explicit loop (vs core.fit) to thread checkpointing + the pipeline
    state = core_train.init_train_state(
        jax.random.PRNGKey(0), cfg, embed_kind="linear", d_raw=64,
        num_classes=10, mode="icq",
        sample_batch=(xtr[:4096], ytr[:4096]))
    step = jax.jit(core_train.make_train_step(
        cfg, state["embed_apply"], state["opt"], "icq", None))
    params, opt_state = state["params"], state["opt_state"]
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    start_ep, restored = ckpt.restore_latest(
        {"params": params, "opt": opt_state})
    if start_ep is not None:
        params, opt_state = restored["params"], restored["opt"]
        print(f"resumed from epoch {start_ep}")

    pipe = ArrayPipeline(xtr, ytr, batch_size=args.batch_size)
    t0 = time.time()
    for ep in range((start_ep + 1) if start_ep is not None else 0,
                    args.epochs):
        var_state = variance.init_state(cfg.d)
        for xb, yb in pipe.epoch(ep):
            params, opt_state, var_state, mets = step(
                params, opt_state, var_state, (xb, yb))
        ckpt.save_async(ep, {"params": params, "opt": opt_state})
        print(f"epoch {ep}: total={float(mets['total']):.4f} "
              f"l_e={float(mets['l_e']):.4f} l_c={float(mets['l_c']):.4f} "
              f"psi={int(mets['psi_size'])}")
    ckpt.wait()
    print(f"train {time.time() - t0:.1f}s")

    model = core_train.finalize(params, state["embed_apply"], var_state,
                                cfg, xtr, mode="icq")
    emb_q = model.embed(xte)
    r2 = two_step_search(emb_q, model.codes, model.C, model.structure, 50)
    r1 = adc_search(emb_q, model.codes, model.C, 50)
    print(f"two-step MAP={float(mean_average_precision(r2.indices, ytr, yte)):.4f} "
          f"ops={float(r2.avg_ops):.2f} | "
          f"adc MAP={float(mean_average_precision(r1.indices, ytr, yte)):.4f} "
          f"ops={float(r1.avg_ops):.2f}")


if __name__ == "__main__":
    main()
