"""Train a small LM with the framework's train step (grad accumulation,
checkpointing) for a few hundred steps, then serve it with the ICQ-KV
two-step quantized cache and compare against exact decode — the
ICQ-as-LM-feature integration (DESIGN.md §4).

    PYTHONPATH=src python examples/train_lm_with_icq_kv.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import TokenPipeline
from repro.distributed import CheckpointManager
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import build_train_step
from repro.quant import (ICQKVConfig, build_icq_kv_cache,
                         icq_kv_decode_attention)
from repro.quant.kv_cache import reference_decode_attention


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/lm_icq_kv_ckpt")
    args = ap.parse_args()

    cfg = smoke_config("tinyllama-1.1b")
    mesh = make_host_mesh()
    shape = ShapeSpec("ex", seq_len=args.seq_len,
                      global_batch=args.global_batch, kind="train")
    n_micro = 2
    step_fn, model, opt, init_opt = build_train_step(cfg, n_micro=n_micro,
                                                     mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_opt(params)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                         global_batch=args.global_batch)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    t0 = time.time()
    for i in range(args.steps):
        raw = pipe.batch(i)
        batch = {k: v.reshape(n_micro, -1, args.seq_len)
                 for k, v in raw.items()}
        params, opt_state, mets = jit_step(params, opt_state, batch)
        if i % 50 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(mets['loss']):.4f}")
    ckpt.save(args.steps - 1, {"params": params})
    print(f"trained {args.steps} steps in {time.time() - t0:.1f}s")

    # ---- serve with ICQ-KV: quantized two-step attention at decode ----
    b, S = 2, args.seq_len
    raw = pipe.batch(999)
    prompt = {"tokens": raw["tokens"][:b, :S]}
    logits, caches = jax.jit(
        lambda p, bt: model.prefill(p, bt, S + 8))(params, prompt)

    # pull the raw K/V of layer segment 0 and rebuild as ICQ-KV
    k_all = caches["seg0"]["k"]            # (L, b, S+8, kvh, dh)
    v_all = caches["seg0"]["v"]
    kvcfg = ICQKVConfig(d_fast=max(cfg.head_dim // 4, 4))
    errs, exacts = [], []
    for layer in range(k_all.shape[0]):
        k = k_all[layer][:, :S]
        v = v_all[layer][:, :S]
        q = jax.random.normal(jax.random.PRNGKey(layer),
                              (b, 1, cfg.num_heads, cfg.head_dim)) * 0.5
        cache = build_icq_kv_cache(kvcfg, k, v, max_len=S)
        approx = icq_kv_decode_attention(q, cache, kvcfg, S - 1,
                                         top_c=max(S // 4, 8))
        exact = reference_decode_attention(q, k, v, S - 1)
        errs.append(float(jnp.abs(approx - exact).max()))
        exacts.append(float(jnp.abs(exact).std()))
    raw_bytes = S * cfg.num_kv_heads * cfg.head_dim * 2 * 2
    icq_bytes = (S * cfg.num_kv_heads * kvcfg.d_fast * 2
                 + (S // 4) * cfg.num_kv_heads * cfg.head_dim * 2)
    print(f"ICQ-KV on trained caches: max err {max(errs):.4f} "
          f"(|exact| std ~{np.mean(exacts):.3f}); "
          f"decode HBM bytes {raw_bytes} -> {icq_bytes} "
          f"({raw_bytes / icq_bytes:.1f}x reduction)")


if __name__ == "__main__":
    main()
