"""Dump the largest result buffers of a cell's compiled HLO (debug tool)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
from collections import Counter
from repro.configs import SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import plan_cell, lower_cell
from repro.launch.hlo_cost import parse_module, _shape_elems_bytes

arch, shape_name, mp = sys.argv[1], sys.argv[2], sys.argv[3] == "multi"
mesh = make_production_mesh(multi_pod=mp)
plan = plan_cell(get_config(arch), SHAPES[shape_name], mesh)
compiled = lower_cell(plan).compile()
ma = compiled.memory_analysis()
print(f"temp={ma.temp_size_in_bytes/1e9:.2f}GB args={ma.argument_size_in_bytes/1e9:.2f}GB")
comps, shapes = parse_module(compiled.as_text())
big = Counter()
for cname, comp in comps.items():
    for op in comp.ops:
        _, b = _shape_elems_bytes(op.result_shape)
        if b >= 100e6:
            big[(cname[:36], op.opcode, op.result_shape[:64])] += 1
for (cn, oc, sh), n in big.most_common(20):
    _, b = _shape_elems_bytes(sh)
    print(f"{n:3d}x {b/1e9:6.2f}GB {oc:20s} {sh:64s} {cn}")
