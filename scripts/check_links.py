"""Intra-repo markdown link checker (CI docs job).

Scans README.md, DESIGN.md, ROADMAP.md, and docs/**/*.md for inline
markdown links ``[text](target)`` and fails (exit 1) on any relative
link whose target file does not exist, or whose ``#anchor`` does not
match a heading in the target file (GitHub-style slugification).
External links (http/https/mailto) are not fetched — this container is
offline; the job guards the *intra-repo* doc graph against rot.

    python scripts/check_links.py [repo_root]
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: inline-code markers dropped, lowercased,
    punctuation removed, spaces -> dashes."""
    h = heading.strip().replace("`", "")
    h = "".join(c for c in h.lower() if c.isalnum() or c in " -_")
    return h.replace(" ", "-")


def anchors_of(md_path: pathlib.Path) -> set:
    text = CODE_FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    slugs = set()
    for m in HEADING_RE.finditer(text):
        slugs.add(github_slug(m.group(1)))
    return slugs


def doc_files(root: pathlib.Path):
    for name in ("README.md", "DESIGN.md", "ROADMAP.md"):
        p = root / name
        if p.exists():
            yield p
    docs = root / "docs"
    if docs.is_dir():
        yield from sorted(docs.rglob("*.md"))


def check(root: pathlib.Path) -> list:
    errors = []
    for md in doc_files(root):
        text = CODE_FENCE_RE.sub("", md.read_text(encoding="utf-8"))
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            if path_part:
                dest = (md.parent / path_part).resolve()
                if not dest.exists():
                    errors.append(f"{md.relative_to(root)}: broken link "
                                  f"-> {target} (no such file)")
                    continue
            else:
                dest = md                        # same-file anchor
            if anchor and dest.suffix == ".md":
                if github_slug(anchor) not in anchors_of(dest):
                    errors.append(f"{md.relative_to(root)}: broken anchor "
                                  f"-> {target}")
    return errors


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    errors = check(root)
    n_files = len(list(doc_files(root)))
    if errors:
        for e in errors:
            print(f"BROKEN: {e}", file=sys.stderr)
        print(f"{len(errors)} broken link(s) across {n_files} files",
              file=sys.stderr)
        return 1
    print(f"docs link check OK ({n_files} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
