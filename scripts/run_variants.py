"""§Perf variant runner: lower+compile the hillclimb variants and write
their artifacts next to the baselines (variant suffix in the filename).

    PYTHONPATH=src python scripts/run_variants.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

from repro.launch.dryrun import run_cell

RUNS = [
    # (arch, shape, multi_pod, kwargs)
    # 1. paper-technique cell: ICQ-KV two-step quantized decode
    ("gemma-7b", "decode_32k", False, dict(variant="icq_kv")),
    ("llama3-405b", "decode_32k", False, dict(variant="icq_kv")),
    # 2. collective-bound cell: compressed cross-pod grad combine
    ("deepseek-v2-236b", "train_4k", True, dict(icq_grad=True,
                                                variant="icq_grad")),
    ("internvl2-76b", "train_4k", True, dict(icq_grad=True,
                                             variant="icq_grad")),
    # 3. compute-term: triangular (diagonal-skipping) causal attention
    ("gemma-7b", "prefill_32k", False, dict(attn_impl="triangular",
                                            variant="triangular")),
    ("internvl2-76b", "train_4k", False, dict(attn_impl="triangular",
                                              variant="triangular")),
]


def main():
    failures = []
    for arch, shape, mp, kw in RUNS:
        try:
            run_cell(arch, shape, mp, **kw)
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            failures.append((arch, shape, kw.get("variant")))
    if failures:
        raise SystemExit(f"variant failures: {failures}")
    print("all variants lowered + compiled OK")


if __name__ == "__main__":
    main()
