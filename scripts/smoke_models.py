"""Dev harness: one fwd/train step per arch on reduced configs (CPU)."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import list_archs, smoke_config
from repro.models import build_model


def make_batch(cfg, b=2, s=16, key=None):
    key = key or jax.random.PRNGKey(0)
    batch = {}
    s_text = s - (cfg.num_vision_tokens if cfg.frontend == "vision_stub" else 0)
    tokens = jax.random.randint(key, (b, s_text), 0, cfg.vocab_size)
    batch["tokens"] = tokens
    batch["labels"] = tokens
    if cfg.frontend == "vision_stub":
        batch["patch_emb"] = jax.random.normal(
            key, (b, cfg.num_vision_tokens, cfg.vision_dim), jnp.float32)
    if cfg.encdec:
        batch["audio_emb"] = jax.random.normal(
            key, (b, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    return batch


def main(archs):
    for a in archs:
        cfg = smoke_config(a)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg)
        loss, aux = jax.jit(m.train_forward)(params, batch)
        ok = bool(jnp.isfinite(loss))
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"train {a:22s} loss={float(loss):8.4f} finite={ok} params={n}")
        assert ok, a
        # prefill + decode
        logits, cache = jax.jit(lambda p, bt: m.prefill(p, bt, 32))(params, batch)
        assert bool(jnp.all(jnp.isfinite(logits))), (a, "prefill")
        tok = batch["tokens"][:, -1:]
        logits2, cache2 = jax.jit(m.decode_step)(params, tok, cache)
        assert bool(jnp.all(jnp.isfinite(logits2))), (a, "decode")
        print(f"serve {a:22s} prefill+decode ok logits={logits2.shape}")


if __name__ == "__main__":
    archs = sys.argv[1:] or list_archs()
    main(archs)
