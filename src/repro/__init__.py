"""repro — Interleaved Composite Quantization as a JAX/Pallas ANN system.

The stable entry points live one level down; this root package lazily
re-exports the front-door surface so ``from repro import icq_session``
works without importing the heavy subsystems at startup:

  - ``repro.api``      the front door: config tree, ``icq_session``
                       lifecycle, persistent ``Artifacts``, serving
                       engines (docs/api.md)
  - ``repro.index``    the unified index layer: FlatADC / TwoStep /
                       IVFTwoStep behind one protocol (DESIGN.md §7)
  - ``repro.trainer``  the unified trainer layer: ``fit``, the
                       ``Quantizer`` protocol, the tiled encoder (§9)
  - ``repro.core``     the paper's math (re-exports the two layers
                       above for backward compatibility)

``from repro import *`` pulls exactly ``__all__`` (resolved lazily via
PEP 562 module ``__getattr__``).
"""
from __future__ import annotations

import importlib

# name -> providing module, resolved on first attribute access
_EXPORTS = {
    name: "repro.api" for name in (
        "ICQConfig", "TrainConfig", "EncodeConfig", "IndexConfig",
        "ServeConfig", "ConfigError", "icq_session", "ICQSession",
        "Searcher", "Artifacts", "ArtifactError", "save_artifacts",
        "load_artifacts", "AnnEngine", "build_ann_engine",
        "load_ann_engine")
}
_EXPORTS.update({name: "repro.index" for name in (
    "make_index", "SearchResult", "FlatADC", "TwoStep", "IVFTwoStep",
    "exact_search", "recall_at", "mean_average_precision")})
_EXPORTS.update({name: "repro.trainer" for name in (
    "fit", "make_quantizer", "encode_database", "ICQModel", "Quantizer")})

__all__ = sorted(_EXPORTS) + ["api", "index", "trainer"]


def __getattr__(name: str):
    module = _EXPORTS.get(name)
    if module is None:
        if name in ("api", "index", "trainer"):
            return importlib.import_module(f"repro.{name}")
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    return getattr(importlib.import_module(module), name)


def __dir__():
    return sorted(set(globals()) | set(__all__))
