"""``repro.api`` — the one front door (docs/api.md, DESIGN.md §10).

PRs 1–4 built the internals: a unified index layer (``repro.index``), a
unified trainer layer (``repro.trainer``), fused Pallas engines, and
mesh-sharded serving.  This package is the stable user-facing surface
over all of them:

  - **Config** — one frozen, JSON-round-trippable, schema-versioned
    dataclass tree: ``ICQConfig`` = ``TrainConfig`` + ``EncodeConfig``
    + ``IndexConfig`` + ``ServeConfig``.
  - **Lifecycle** — ``session = icq_session(config)``;
    ``state = session.fit(X, y, key=key)``;
    ``searcher = session.index(db)``; ``searcher.search(q, k)``.
  - **Persistence** — ``Artifacts`` (npz tensors + json manifest with
    format version, config hash, and a dtype/shape inventory):
    ``searcher.save(path)`` then, in a fresh process,
    ``load_ann_engine(path)`` — fit→save→load→search is
    bitwise-identical to the in-process path for all three index types.
  - **Serving** — ``AnnEngine`` (jitted, growable, mesh-shardable) and
    ``build_ann_engine`` (the historical kwarg entry, now a shim over
    the config path).
  - **Resilience** — ``SearchBudget`` / ``ResultMeta`` (deadline-aware
    degraded search), ``ResilienceConfig`` (failover + verification
    knobs), and the deterministic ``FaultInjector`` harness
    (docs/robustness.md).
  - **Traffic** — the async serving engine lives in ``repro.serve``
    (docs/serving.md): ``ServingLoop`` coalesces arriving requests
    into warm fixed-tile batches over one or more tenants' engines,
    bitwise-identical to calling ``Searcher.search`` directly.

Everything here re-exports from the submodules; ``from repro.api
import *`` pulls exactly ``__all__``.
"""
from repro.api.artifacts import (FORMAT_VERSION, ArtifactError, Artifacts,
                                 load_artifacts, save_artifacts)
from repro.api.config import (CHOICES, SCHEMA_VERSION, ConfigError,
                              EncodeConfig, ICQConfig, IndexConfig,
                              ResilienceConfig, ServeConfig, TrainConfig)
from repro.api.serving import (AnnEngine, build_ann_engine, build_index,
                               load_ann_engine)
from repro.api.session import ICQSession, Searcher, icq_session
from repro.resilience import (FaultInjector, FaultSpec, ResultMeta,
                              SearchBudget)

__all__ = [
    # config tree
    "ICQConfig", "TrainConfig", "EncodeConfig", "IndexConfig",
    "ServeConfig", "ConfigError", "SCHEMA_VERSION", "CHOICES",
    # lifecycle
    "icq_session", "ICQSession", "Searcher",
    # persistence
    "Artifacts", "ArtifactError", "save_artifacts", "load_artifacts",
    "FORMAT_VERSION",
    # serving
    "AnnEngine", "build_ann_engine", "build_index", "load_ann_engine",
    # resilience (docs/robustness.md)
    "ResilienceConfig", "SearchBudget", "ResultMeta", "FaultInjector",
    "FaultSpec",
]
