"""Persistent artifacts for ``repro.api`` (docs/api.md): one directory
holding everything a fresh process needs to serve a trained system —
``manifest.json`` (format version, full config + config hash, array
inventory, model/index metadata) plus ``arrays.npz`` (every tensor,
path-keyed like the checkpoint format).

Guarantees:

  - **Bitwise round trip** — ``save`` stores the exact device arrays
    (codes in their packed dtype, f32 codebooks/structure), ``load``
    reconstructs the same frozen index dataclass with the same engine
    options, so fit → save → load → search returns ids *and* distances
    bitwise-identical to the in-process path (tested for FlatADC /
    TwoStep / IVFTwoStep, uint8 + uint16 codes, f32 + int8 LUTs in
    ``tests/test_api.py``).
  - **Self-describing** — the manifest's array inventory (name →
    dtype/shape/sha256) and the recorded npz byte size are checked
    against the files on load, so truncated or tampered artifacts fail
    with a clear ``ArtifactError`` instead of serving garbage;
    ``load(verify_checksums=True)`` recomputes every tensor hash and
    names the corrupted tensor (docs/robustness.md).
  - **Atomic saves** — a save stages into ``<path>.tmp`` and swaps via
    renames, so a crash mid-save never destroys the previous artifact
    directory; ``load`` auto-recovers the ``<path>.old`` left by a
    crash inside the swap itself.
  - **Versioned** — ``format_version`` gates the directory layout and
    the embedded config re-validates against its own
    ``schema_version``; both mismatches raise with instructions.

The model side (embedding params, codebooks, database codes, ICQ
structure, variance estimate) serializes any ``trainer.base.ICQModel``
whose embedder is one of the built-ins (linear / cnn / identity — the
apply function is rebuilt from the recorded kind).  The index side
serializes any of the three index types; IVF's derived in-list codes
slab is *recomputed* on load (deterministic gather) rather than stored,
halving the artifact size.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from typing import Any, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.api.config import ICQConfig

FORMAT_VERSION = 1
_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"
_TMP_SUFFIX = ".tmp"
_OLD_SUFFIX = ".old"

# embedders reconstructible from a recorded kind (core/embed.py)
_EMBED_KINDS = ("linear", "cnn", "identity")


class ArtifactError(RuntimeError):
    """An artifact directory failed to load: wrong format version,
    missing/corrupt/truncated files, or an inventory/checksum mismatch.
    The message says which check failed and on what."""


def tensor_sha256(a: np.ndarray) -> str:
    """Content hash of one tensor's raw bytes (C-contiguous layout) —
    what the manifest inventory records and load-time verification
    recomputes, so same-dtype/same-shape bit rot is caught and named."""
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()


def _embed_apply_for(kind: str):
    from repro.core import embed as embed_mod

    if kind == "linear":
        return embed_mod.linear_apply
    if kind == "cnn":
        return embed_mod.cnn_apply
    if kind == "identity":
        return lambda p, x: x
    raise ArtifactError(
        f"unknown embed kind {kind!r} in manifest; this build rebuilds "
        f"{list(_EMBED_KINDS)}")


def _structure_arrays(structure) -> Dict[str, np.ndarray]:
    return {"structure/xi": np.asarray(structure.xi),
            "structure/fast_mask": np.asarray(structure.fast_mask),
            "structure/sigma": np.asarray(structure.sigma)}


def _structure_from(arrays: Dict[str, np.ndarray]):
    from repro.core.icq import ICQStructure

    return ICQStructure(xi=jnp.asarray(arrays["structure/xi"]),
                        fast_mask=jnp.asarray(arrays["structure/fast_mask"]),
                        sigma=jnp.asarray(arrays["structure/sigma"]))


def _index_opts(config: ICQConfig) -> Dict[str, Any]:
    """Engine options for rebuilding an index from ``config`` — the same
    resolution ``repro.api.serving`` uses to build one, so a loaded
    index serves identically to the in-process original."""
    serve, index = config.serve, config.index
    opts: Dict[str, Any] = dict(topk=serve.topk, backend=serve.backend,
                                query_chunk=serve.query_chunk,
                                lut_dtype=serve.lut_dtype)
    if serve.block_q is not None:
        opts["block_q"] = serve.block_q
    if serve.block_n is not None:
        opts["block_n"] = serve.block_n
    if index.kind != "flat":
        opts["refine_cap"] = index.refine_cap
    # configs written before code_bits existed load with the 8-bit
    # default (from_dict fills missing fields), so old artifacts keep
    # serving byte-packed codes unchanged
    opts["code_bits"] = index.code_bits
    # likewise pre-pipeline configs load with "off" (from_dict default)
    opts["pipeline"] = serve.pipeline
    opts["pipeline_tile"] = serve.pipeline_tile
    return opts


@dataclasses.dataclass
class Artifacts:
    """A saved (or about-to-be-saved) system: config + optional trained
    model + optional built index.  ``save``/``load`` are inverses; see
    the module docstring for the on-disk layout."""
    config: ICQConfig
    model: Optional[Any] = None          # trainer.base.ICQModel
    index: Optional[Any] = None          # repro.index.{FlatADC,TwoStep,IVFTwoStep}
    manifest: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------- save --
    def save(self, path: str) -> str:
        """Write the artifact directory atomically (docs/robustness.md).

        Everything is staged into ``<path>.tmp``; the live directory is
        replaced only by two renames (``path`` → ``<path>.old``,
        ``.tmp`` → ``path``) once the stage is fully written.  A crash
        at *any* point while data is being written leaves the previous
        ``path`` untouched and loadable; a crash inside the rename pair
        leaves it intact at ``<path>.old``, which ``load`` recovers
        automatically.  Stale ``.tmp``/``.old`` leftovers from crashed
        saves are cleared first.  Returns ``path``."""
        arrays: Dict[str, np.ndarray] = {}
        manifest: Dict[str, Any] = {
            "format_version": FORMAT_VERSION,
            "config": self.config.to_dict(),
            "config_hash": self.config.config_hash(),
        }

        if self.model is not None:
            manifest["model"] = self._save_model(arrays)
        if self.index is not None:
            manifest["index"] = self._save_index(arrays)
        if self.model is None and self.index is None:
            raise ArtifactError("nothing to save: artifacts need a model, "
                                "an index, or both")
        arrays = {k: np.asarray(a) for k, a in arrays.items()}
        manifest["arrays"] = {
            k: {"dtype": str(a.dtype), "shape": list(a.shape),
                "sha256": tensor_sha256(a)}
            for k, a in arrays.items()}

        base = path.rstrip("/")
        tmp, old = base + _TMP_SUFFIX, base + _OLD_SUFFIX
        for stale in (tmp, old):
            if os.path.exists(stale):
                shutil.rmtree(stale)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, _ARRAYS), **arrays)
        # the npz byte size joins the manifest so a truncated copy is
        # caught with an expected-vs-found message before np.load
        manifest["arrays_bytes"] = os.path.getsize(
            os.path.join(tmp, _ARRAYS))
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True)

        if os.path.exists(path):
            os.rename(path, old)
        try:
            os.rename(tmp, path)
        except OSError:
            if os.path.exists(old):      # put the previous version back
                os.rename(old, path)
            raise
        if os.path.exists(old):
            shutil.rmtree(old)
        self.manifest = manifest
        return path

    def _save_model(self, arrays: Dict[str, np.ndarray]) -> Dict[str, Any]:
        from repro.distributed.checkpoint import flatten_pytree

        model = self.model
        embed_kind = self.config.train.embed
        if model.embed_params is None:
            embed_kind = "identity"
        else:
            for k, a in flatten_pytree(model.embed_params).items():
                arrays[f"model/embed/{k}"] = a
        arrays["model/C"] = np.asarray(model.C)
        arrays["model/codes"] = np.asarray(model.codes)
        arrays["model/lam"] = np.asarray(model.lam)
        for k, a in _structure_arrays(model.structure).items():
            arrays[f"model/{k}"] = a
        return {"mode": model.mode, "embed": embed_kind,
                "n": int(model.codes.shape[0])}

    def _save_index(self, arrays: Dict[str, np.ndarray]) -> Dict[str, Any]:
        from repro.index import FlatADC, IVFTwoStep, TwoStep

        idx = self.index
        kinds = {FlatADC: "flat", TwoStep: "two-step", IVFTwoStep: "ivf"}
        kind = kinds.get(type(idx))
        if kind is None:
            raise ArtifactError(
                f"cannot serialize index type {type(idx).__name__}; "
                "supported: FlatADC, TwoStep, IVFTwoStep (shard clones "
                "are serving views — save the unsharded source index)")
        code_bits = int(getattr(idx, "code_bits", 8))
        if code_bits != self.config.index.code_bits:
            raise ArtifactError(
                f"index.code_bits={code_bits} on the index being saved "
                f"disagrees with the config's "
                f"index.code_bits={self.config.index.code_bits}; the "
                "embedded config describes the reload, so align them")
        arrays["index/codes"] = np.asarray(idx.codes)
        arrays["index/C"] = np.asarray(idx.C)
        meta: Dict[str, Any] = {"kind": kind, "n": int(idx.codes.shape[0]),
                                "code_bits": code_bits}
        if kind != "flat":
            for k, a in _structure_arrays(idx.structure).items():
                arrays[f"index/{k}"] = a
        if kind == "ivf":
            if int(idx.n_probe) != self.config.index.n_probe:
                raise ArtifactError(
                    f"index.n_probe={int(idx.n_probe)} on the index being "
                    f"saved disagrees with the config's "
                    f"index.n_probe={self.config.index.n_probe}; the "
                    "embedded config describes the reload, so align them")
            arrays["index/ivf/centroids"] = np.asarray(idx.ivf.centroids)
            arrays["index/ivf/lists"] = np.asarray(idx.ivf.lists)
            arrays["index/ivf/list_lens"] = np.asarray(idx.ivf.list_lens)
            meta["imbalance"] = float(idx.ivf.imbalance)
            meta["n_probe"] = int(idx.n_probe)      # informational
            meta["list_codes"] = idx.list_codes is not None
        return meta

    # ------------------------------------------------------------- load --
    @classmethod
    def load(cls, path: str, *, overrides=None,
             verify_checksums: bool = False) -> "Artifacts":
        """Read + verify an artifact directory.  Raises ``ArtifactError``
        on any structural problem (missing/truncated files, version
        mismatch, inventory mismatch) and ``ConfigError`` if the
        embedded config fails its own schema validation.

        Dtype/shape and the npz byte size are always checked;
        ``verify_checksums=True`` additionally recomputes every
        tensor's sha256 against the manifest (catches same-shape bit
        rot; the error names the corrupted tensor).

        ``overrides`` (dotted-path dict, e.g. ``{"serve.backend":
        "jnp"}``) is applied to the embedded config *before* the index
        is rebuilt, so a saved index can be re-served under different
        engine options — except ``index.kind``, which names the stored
        layout and cannot be overridden on load."""
        cls._recover(path)
        manifest = cls._read_manifest(path)
        config = ICQConfig.from_dict(manifest["config"])
        if overrides:
            if "index.kind" in overrides and overrides["index.kind"] \
                    != config.index.kind:
                raise ArtifactError(
                    f"index.kind cannot be overridden on load (artifacts "
                    f"at {path} store a {config.index.kind!r} index); "
                    "rebuild and re-save to change the index kind")
            config = config.with_overrides(overrides)

        arrays = cls._load_arrays(path, manifest,
                                  verify_checksums=verify_checksums)
        model = (cls._load_model(arrays, manifest["model"], config)
                 if "model" in manifest else None)
        index = (cls._load_index(arrays, manifest["index"], config)
                 if "index" in manifest else None)
        return cls(config=config, model=model, index=index,
                   manifest=manifest)

    @staticmethod
    def _recover(path: str) -> None:
        """Finish a save that crashed between its two renames: if
        ``path`` is gone but the previous version sits at
        ``<path>.old``, move it back.  No-op otherwise (an existing
        ``path`` always wins; its stale ``.old`` sibling is just a
        leftover the next save clears)."""
        old = path.rstrip("/") + _OLD_SUFFIX
        if (not os.path.exists(path)
                and os.path.isfile(os.path.join(old, _MANIFEST))):
            os.rename(old, path)

    @staticmethod
    def _read_manifest(path: str) -> Dict[str, Any]:
        manifest_path = os.path.join(path, _MANIFEST)
        if not os.path.isfile(manifest_path):
            raise ArtifactError(
                f"{path!r} is not an artifacts directory (no {_MANIFEST})")
        try:
            with open(manifest_path) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise ArtifactError(
                f"{path}: corrupt {_MANIFEST}: {e}") from None
        version = manifest.get("format_version")
        if version != FORMAT_VERSION:
            raise ArtifactError(
                f"{path}: artifact format_version={version!r} is not "
                f"supported (this build reads {FORMAT_VERSION}); "
                "re-export the artifacts with a matching build")
        if "config" not in manifest:
            raise ArtifactError(f"{path}: manifest has no embedded config")
        return manifest

    @staticmethod
    def _load_arrays(path: str, manifest: Dict, *,
                     verify_checksums: bool = False) -> Dict[str, np.ndarray]:
        npz_path = os.path.join(path, _ARRAYS)
        if not os.path.isfile(npz_path):
            raise ArtifactError(f"{path}: missing {_ARRAYS}")
        expected_bytes = manifest.get("arrays_bytes")
        if expected_bytes is not None:
            found = os.path.getsize(npz_path)
            if found != expected_bytes:
                raise ArtifactError(
                    f"{path}: {_ARRAYS} is truncated or padded — expected "
                    f"{expected_bytes} bytes, found {found}")
        try:
            with np.load(npz_path) as z:
                arrays = {k: z[k] for k in z.files}
        except Exception as e:
            raise ArtifactError(f"{path}: corrupt {_ARRAYS}: {e}") from None
        inventory = manifest.get("arrays", {})
        missing = set(inventory) - set(arrays)
        if missing:
            raise ArtifactError(
                f"{path}: {_ARRAYS} is missing array(s) "
                f"{sorted(missing)} listed in the manifest inventory")
        for name, spec in inventory.items():
            a = arrays[name]
            if (str(a.dtype) != spec["dtype"]
                    or list(a.shape) != list(spec["shape"])):
                raise ArtifactError(
                    f"{path}: array {name!r} is {a.dtype}{list(a.shape)} "
                    f"but the manifest records {spec['dtype']}"
                    f"{spec['shape']} — artifact is corrupt or tampered")
            if verify_checksums and "sha256" in spec:
                got = tensor_sha256(a)
                if got != spec["sha256"]:
                    raise ArtifactError(
                        f"{path}: array {name!r} failed checksum "
                        f"verification (sha256 {got[:12]}… != manifest "
                        f"{spec['sha256'][:12]}…) — tensor is corrupted")
        return arrays

    @staticmethod
    def _load_model(arrays, meta: Dict, config: ICQConfig):
        from repro.trainer.base import ICQModel

        embed_kind = meta["embed"]
        embed_apply = _embed_apply_for(embed_kind)
        prefix = "model/embed/"
        embed_flat = {k[len(prefix):]: a for k, a in arrays.items()
                      if k.startswith(prefix)}
        embed_params = _nest(embed_flat) if embed_flat else None
        structure = _structure_from(
            {k.replace("model/", "", 1): a for k, a in arrays.items()
             if k.startswith("model/structure/")})
        return ICQModel(
            icq_cfg=config.train.hyperparams(
                icm_iters=config.encode.icm_iters),
            embed_params=embed_params,
            embed_apply=embed_apply,
            C=jnp.asarray(arrays["model/C"]),
            codes=jnp.asarray(arrays["model/codes"]),
            structure=structure,
            lam=jnp.asarray(arrays["model/lam"]),
            mode=meta["mode"])

    @staticmethod
    def _load_index(arrays, meta: Dict, config: ICQConfig):
        from repro.index import (FlatADC, IVFIndex, IVFTwoStep, TwoStep,
                                 ivf_list_codes)

        kind = meta["kind"]
        if kind != config.index.kind:
            raise ArtifactError(
                f"manifest index kind {kind!r} disagrees with the embedded "
                f"config's index.kind={config.index.kind!r}")
        # manifests written before code_bits existed store byte-packed
        # codes: the 8-bit default on both sides keeps them loading
        stored_bits = int(meta.get("code_bits", 8))
        if stored_bits != config.index.code_bits:
            raise ArtifactError(
                f"index.code_bits cannot be overridden on load (artifacts "
                f"store the {stored_bits}-bit packed layout); re-encode "
                "and re-save to change the code width")
        codes = jnp.asarray(arrays["index/codes"])
        C = jnp.asarray(arrays["index/C"])
        opts = _index_opts(config)
        if kind == "flat":
            return FlatADC(codes=codes, C=C, **opts)
        structure = _structure_from(
            {k.replace("index/", "", 1): a for k, a in arrays.items()
             if k.startswith("index/structure/")})
        if kind == "two-step":
            return TwoStep(codes=codes, C=C, structure=structure, **opts)
        ivf = IVFIndex(centroids=jnp.asarray(arrays["index/ivf/centroids"]),
                       lists=jnp.asarray(arrays["index/ivf/lists"]),
                       list_lens=jnp.asarray(arrays["index/ivf/list_lens"]),
                       imbalance=float(meta["imbalance"]))
        # n_probe follows the (possibly overridden) config — save checks
        # it matched the index, so the plain reload is unchanged while
        # load-time overrides actually take effect
        return IVFTwoStep(
            codes=codes, C=C, structure=structure, ivf=ivf,
            n_probe=config.index.n_probe,
            list_codes=(ivf_list_codes(ivf, codes)
                        if meta.get("list_codes", True) else None),
            **opts)


def _nest(flat: Dict[str, np.ndarray]) -> Dict:
    """Rebuild a nested dict pytree from ``a/b/c``-keyed arrays (the
    embed params are plain nested dicts, so no template is needed)."""
    out: Dict = {}
    for key, a in flat.items():
        node = out
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(a)
    return out


def save_artifacts(path: str, *, config: ICQConfig, model=None,
                   index=None) -> str:
    """One-call save: ``Artifacts(config, model, index).save(path)``."""
    return Artifacts(config=config, model=model, index=index).save(path)


def load_artifacts(path: str, *, verify_checksums: bool = False) -> Artifacts:
    """One-call load: ``Artifacts.load(path)``."""
    return Artifacts.load(path, verify_checksums=verify_checksums)
