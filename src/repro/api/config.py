"""The frozen config tree behind ``repro.api`` (docs/api.md): one
JSON-round-trippable ``ICQConfig`` covering the whole lifecycle —
training (``TrainConfig``), database encoding (``EncodeConfig``), index
construction (``IndexConfig``), serving (``ServeConfig``), and
behavior under faults and deadlines (``ResilienceConfig``).

Every entry point that used to take its own ad-hoc kwarg set
(``trainer.fit``, ``Index.build``, ``build_ann_engine``, the
``launch/{train,serve}.py`` CLIs, ``benchmarks/run.py``) now reads from
this tree; the old kwargs/flags survive as *overrides* on top of a
config.  The tree is:

  - frozen (hashable, safe to share across sessions and jit closures);
  - schema-versioned (``schema_version``) — configs written by a newer
    schema are rejected with a clear error instead of being silently
    misread;
  - validated on construction *and* on ``from_dict``: unknown keys,
    wrong types, and out-of-choice values all name the offending
    ``section.field`` and the accepted values;
  - content-addressed: ``config_hash()`` is the sha256 of the canonical
    (sorted-key, whitespace-free) JSON, recorded in artifact manifests
    so a loaded index can be traced to the exact config that built it.

``TrainConfig.hyperparams()`` bridges to the paper-level
``repro.configs.base.ICQConfig`` (the loss/prior hyperparameter record
the trainer layer consumes) — the api-level ``ICQConfig`` is the
superset that also knows how to encode, index, and serve.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Optional

SCHEMA_VERSION = 1

# accepted values per "section.field" — the single source the validator,
# the error messages, and docs/api.md all describe
CHOICES = {
    "train.quantizer": ("icq", "sq", "pqn", "pq", "opq", "cq"),
    "train.embed": ("linear", "cnn", "identity"),
    "encode.backend": ("auto", "jnp", "pallas"),
    "index.kind": ("flat", "two-step", "ivf"),
    "index.code_bits": (8, 4),
    "serve.backend": ("auto", "jnp", "pallas"),
    "serve.lut_dtype": ("f32", "int8"),
    "serve.pipeline": ("off", "tiles", "auto"),
}

# the joint trainer modes behind the api quantizer names; the remaining
# names ("pq", "opq", "cq") are the protocol baselines in
# trainer.quantizers driven by the generic init/step/finalize loop
JOINT_MODES = {"icq": "icq", "sq": "cq", "pqn": "pq"}

# float fields with a sign constraint (everything else — alpha2, the
# loss weights' theoretical range — is intentionally unconstrained)
_POSITIVE_FLOATS = {"train.lr", "train.tau",
                    "resilience.backoff_base_ms",
                    "resilience.backoff_max_ms"}
_NONNEG_FLOATS = {"train.pi1", "train.pi2", "train.gamma_p",
                  "train.gamma_icq", "train.gamma_cq",
                  "train.margin_scale", "serve.batch_window_ms"}
# int fields where 0 is meaningful (exceptions to the positive-int rule)
_NONNEG_INTS = {"resilience.max_retries"}


class ConfigError(ValueError):
    """A config failed validation; the message names the offending
    ``section.field`` and what would have been accepted."""


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """What to train: quantizer kind, code geometry, embedding, loss
    and prior hyper-parameters, and the epoch loop's shape."""
    quantizer: str = "icq"       # icq | sq | pqn (joint) | pq | opq | cq
    d: int = 16                  # embedding dim
    num_codebooks: int = 8       # K
    codebook_size: int = 256     # m
    num_fast: int = 2            # |K_fast|
    epochs: int = 5
    batch_size: int = 256
    lr: float = 1e-3
    tau: float = 1.0
    embed: str = "linear"        # linear | cnn | identity
    num_classes: int = 10
    img_hw: Optional[int] = None          # cnn embedder input size
    channels: Optional[int] = None        # cnn embedder input channels
    # prior / loss hyper-parameters (paper eq. 4 and §3.3)
    pi1: float = 0.9
    pi2: float = 0.1
    alpha2: float = -10.0
    gamma_p: float = 0.2
    gamma_icq: float = 2.0
    gamma_cq: float = 0.1
    margin_scale: float = 1.0
    learn_embedding: bool = True

    def hyperparams(self, *, icm_iters: int = 3):
        """The paper-level hyper-parameter record
        (``repro.configs.base.ICQConfig``) the trainer layer consumes.
        ``icm_iters`` comes from the sibling ``EncodeConfig`` (the api
        tree keeps encoding knobs out of the train section)."""
        from repro.configs.base import ICQConfig as CoreICQConfig

        return CoreICQConfig(
            d=self.d, num_codebooks=self.num_codebooks,
            codebook_size=self.codebook_size, num_fast=self.num_fast,
            pi1=self.pi1, pi2=self.pi2, alpha2=self.alpha2,
            gamma_p=self.gamma_p, gamma_icq=self.gamma_icq,
            gamma_cq=self.gamma_cq, margin_scale=self.margin_scale,
            icm_iters=icm_iters, learn_embedding=self.learn_embedding)


@dataclasses.dataclass(frozen=True)
class EncodeConfig:
    """How databases are encoded against the trained codebooks: the
    tiled ICM engine's iteration count, chunking, and backend."""
    icm_iters: int = 3
    chunk: int = 8192            # rows per jitted embed+encode call
    backend: str = "auto"        # auto | jnp | pallas
    point_chunk: Optional[int] = 8192     # Index.add engine chunk


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    """Which index to build over the encoded database and its
    construction-time parameters."""
    kind: str = "two-step"       # flat | two-step | ivf
    n_lists: int = 64            # ivf coarse cells
    n_probe: int = 8             # ivf probed cells per query
    kmeans_iters: int = 20       # ivf coarse k-means iterations
    refine_cap: Optional[int] = None      # static survivor compaction
    code_bits: int = 8           # 8 | 4 (nibble-packed fast-scan, §12)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """How the index answers query batches: result size, backend
    dispatch, crude-pass LUT precision, tiling/chunking knobs
    (``None`` keeps each index class's own tile defaults), and the
    async serving loop's coalescing/tenancy knobs (``repro.serve``,
    docs/serving.md — ignored by the offline batch paths)."""
    topk: int = 50
    backend: str = "auto"        # auto | jnp | pallas
    lut_dtype: str = "f32"       # f32 | int8 (DESIGN.md §8)
    query_chunk: Optional[int] = None
    block_q: Optional[int] = None
    block_n: Optional[int] = None
    pipeline: str = "off"        # off | tiles | auto (DESIGN.md §13)
    pipeline_tile: Optional[int] = None   # queries per pipeline tile
    batch_window_ms: float = 2.0 # serving loop: max coalescing wait
    batch_tile: int = 32         # serving loop: rows per dispatched tile
    max_queue: int = 4096        # serving loop: queued-row backpressure
    tenant: Optional[str] = None # serving loop: this artifact's tenant name


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """How serving behaves under pressure and faults
    (docs/robustness.md): the default search deadline, the degradation
    ladder's knobs, Pallas→jnp failover retries, and artifact checksum
    policy.  Configs written before this section existed load with
    these defaults (``from_dict`` treats a missing section as ``{}``)."""
    deadline_ms: Optional[float] = None   # default per-batch deadline
    degraded_refine_cap: Optional[int] = None  # "capped" rung's cap
    min_n_probe: int = 1                  # "probes" rung's floor (ivf)
    max_retries: int = 2                  # failover retry budget (0 = none)
    backoff_base_ms: float = 10.0         # retry backoff schedule
    backoff_max_ms: float = 1000.0
    pallas_failover: bool = True          # blacklist pallas on fault
    verify_artifacts: bool = False        # full checksum pass on load


_SECTIONS = {"train": TrainConfig, "encode": EncodeConfig,
             "index": IndexConfig, "serve": ServeConfig,
             "resilience": ResilienceConfig}


@dataclasses.dataclass(frozen=True)
class ICQConfig:
    """The one front door's config: ``train`` + ``encode`` + ``index``
    + ``serve`` + ``resilience`` (docs/api.md has the field-by-field
    reference).

    Build programmatically (``ICQConfig(train=TrainConfig(epochs=8))``),
    from JSON (``ICQConfig.load(path)`` / ``from_json``), or from a base
    config plus dotted CLI-style overrides
    (``cfg.with_overrides({"train.epochs": 8})``).  Validation runs on
    every construction path and raises ``ConfigError`` naming the
    offending field."""
    schema_version: int = SCHEMA_VERSION
    train: TrainConfig = dataclasses.field(default_factory=TrainConfig)
    encode: EncodeConfig = dataclasses.field(default_factory=EncodeConfig)
    index: IndexConfig = dataclasses.field(default_factory=IndexConfig)
    serve: ServeConfig = dataclasses.field(default_factory=ServeConfig)
    resilience: ResilienceConfig = dataclasses.field(
        default_factory=ResilienceConfig)

    def __post_init__(self):
        _validate(self)

    # --------------------------------------------------------- to/from --
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, data: Any) -> "ICQConfig":
        if not isinstance(data, dict):
            raise ConfigError(
                f"config root must be a JSON object, got {type(data).__name__}")
        version = data.get("schema_version", None)
        if version is None:
            raise ConfigError(
                "config is missing 'schema_version' — not an api config "
                f"(this build writes schema_version={SCHEMA_VERSION})")
        if not isinstance(version, int) or isinstance(version, bool):
            raise ConfigError(
                f"schema_version must be an int, got {version!r}")
        if version != SCHEMA_VERSION:
            raise ConfigError(
                f"config schema_version={version} is not supported by this "
                f"build (reads exactly {SCHEMA_VERSION}); "
                + ("re-export it with a matching version"
                   if version > SCHEMA_VERSION else
                   "migrate it to the current schema"))
        unknown = set(data) - set(_SECTIONS) - {"schema_version"}
        if unknown:
            raise ConfigError(
                f"unknown config section(s) {sorted(unknown)}; expected "
                f"{sorted(_SECTIONS)} (+ schema_version)")
        sections = {}
        for name, section_cls in _SECTIONS.items():
            sections[name] = _section_from_dict(section_cls,
                                                data.get(name, {}), name)
        return cls(schema_version=version, **sections)

    @classmethod
    def from_json(cls, text: str) -> "ICQConfig":
        try:
            data = json.loads(text)
        except json.JSONDecodeError as e:
            raise ConfigError(f"config is not valid JSON: {e}") from None
        return cls.from_dict(data)

    @classmethod
    def load(cls, path: str) -> "ICQConfig":
        """Read + validate a config JSON file."""
        try:
            with open(path) as f:
                text = f.read()
        except OSError as e:
            raise ConfigError(f"cannot read config {path!r}: {e}") from None
        try:
            return cls.from_json(text)
        except ConfigError as e:
            raise ConfigError(f"{path}: {e}") from None

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    # -------------------------------------------------------- overrides --
    def with_overrides(self, overrides: Dict[str, Any]) -> "ICQConfig":
        """A new config with dotted-path overrides applied — the CLI
        bridge (``--icq-epochs 4`` becomes ``{"train.epochs": 4}``).
        Unknown paths raise ``ConfigError``; values are validated like
        any other construction."""
        if not overrides:
            return self
        data = self.to_dict()
        for path, value in overrides.items():
            section, _, field = path.partition(".")
            if section not in _SECTIONS or not field:
                raise ConfigError(
                    f"override path {path!r} must be 'section.field' with "
                    f"section in {sorted(_SECTIONS)}")
            if field not in {f.name for f in
                             dataclasses.fields(_SECTIONS[section])}:
                raise ConfigError(
                    f"unknown override field {path!r}; {section} has: "
                    f"{sorted(f.name for f in dataclasses.fields(_SECTIONS[section]))}")
            data[section][field] = value
        return ICQConfig.from_dict(data)

    # ------------------------------------------------------------- hash --
    def config_hash(self) -> str:
        """sha256 of the canonical JSON — the identity recorded in
        artifact manifests (``repro.api.artifacts``)."""
        canon = json.dumps(self.to_dict(), sort_keys=True,
                           separators=(",", ":"))
        return hashlib.sha256(canon.encode("utf-8")).hexdigest()


# ----------------------------------------------------------- validation ----

def _type_ok(value, py_type, optional: bool) -> bool:
    if value is None:
        return optional
    if py_type is int:
        return isinstance(value, int) and not isinstance(value, bool)
    if py_type is float:
        return (isinstance(value, (int, float))
                and not isinstance(value, bool))
    if py_type is bool:
        return isinstance(value, bool)
    if py_type is str:
        return isinstance(value, str)
    return True


def _field_spec(f: dataclasses.Field):
    """(py_type, optional) from the field's (string) annotation."""
    ann = f.type if isinstance(f.type, str) else getattr(
        f.type, "__name__", str(f.type))
    optional = ann.startswith("Optional[")
    if optional:
        ann = ann[len("Optional["):-1]
    return {"int": int, "float": float, "bool": bool,
            "str": str}.get(ann, object), optional


def _check_field(section: str, f: dataclasses.Field, value):
    where = f"{section}.{f.name}"
    py_type, optional = _field_spec(f)
    if not _type_ok(value, py_type, optional):
        want = py_type.__name__ + (" or null" if optional else "")
        raise ConfigError(
            f"{where} must be {want}, got {value!r} "
            f"({type(value).__name__})")
    choices = CHOICES.get(where)
    if choices is not None and value not in choices:
        raise ConfigError(
            f"{where}={value!r} is not one of {list(choices)}")
    if value is None or optional:
        return
    if py_type is int and where in _NONNEG_INTS:
        if value < 0:
            raise ConfigError(f"{where} must be >= 0, got {value!r}")
    elif py_type is int and value <= 0:
        raise ConfigError(f"{where} must be a positive int, got {value!r}")
    if where in _POSITIVE_FLOATS and value <= 0:
        raise ConfigError(f"{where} must be > 0, got {value!r}")
    if where in _NONNEG_FLOATS and value < 0:
        raise ConfigError(f"{where} must be >= 0, got {value!r}")


def _section_from_dict(section_cls, data: Any, section: str):
    if not isinstance(data, dict):
        raise ConfigError(f"config section {section!r} must be a JSON "
                          f"object, got {type(data).__name__}")
    fields = {f.name: f for f in dataclasses.fields(section_cls)}
    unknown = set(data) - set(fields)
    if unknown:
        raise ConfigError(
            f"unknown field(s) {sorted(unknown)} in section {section!r}; "
            f"valid fields: {sorted(fields)}")
    kwargs = {}
    for name, f in fields.items():
        if name in data:
            value = data[name]
            py_type, _ = _field_spec(f)
            # JSON has one number type: accept ints for float fields
            if py_type is float and isinstance(value, int) \
                    and not isinstance(value, bool):
                value = float(value)
            kwargs[name] = value
    return section_cls(**kwargs)


def _validate(cfg: "ICQConfig"):
    if cfg.schema_version != SCHEMA_VERSION:
        raise ConfigError(
            f"config schema_version={cfg.schema_version!r} is not "
            f"supported by this build (reads exactly {SCHEMA_VERSION})")
    for section, section_cls in _SECTIONS.items():
        obj = getattr(cfg, section)
        if not isinstance(obj, section_cls):
            raise ConfigError(
                f"config.{section} must be a {section_cls.__name__}, "
                f"got {type(obj).__name__}")
        for f in dataclasses.fields(section_cls):
            _check_field(section, f, getattr(obj, f.name))
    if cfg.train.num_fast >= cfg.train.num_codebooks:
        raise ConfigError(
            f"train.num_fast={cfg.train.num_fast} must be < "
            f"train.num_codebooks={cfg.train.num_codebooks} (the slow "
            "group cannot be empty)")
    if cfg.index.n_probe > cfg.index.n_lists:
        raise ConfigError(
            f"index.n_probe={cfg.index.n_probe} cannot exceed "
            f"index.n_lists={cfg.index.n_lists}")
    if cfg.index.code_bits == 4 and cfg.train.codebook_size > 16:
        raise ConfigError(
            f"index.code_bits=4 requires "
            f"train.codebook_size={cfg.train.codebook_size} <= 16 (4-bit "
            "codes address at most 16 codewords per codebook); set "
            "train.codebook_size <= 16 or keep index.code_bits=8")
    if cfg.train.embed == "cnn" and (cfg.train.img_hw is None
                                     or cfg.train.channels is None):
        raise ConfigError(
            "train.embed='cnn' needs train.img_hw and train.channels")
    res = cfg.resilience
    if res.deadline_ms is not None and res.deadline_ms <= 0:
        raise ConfigError(
            f"resilience.deadline_ms must be > 0 (or null), got "
            f"{res.deadline_ms!r}")
    if res.degraded_refine_cap is not None and res.degraded_refine_cap < 1:
        raise ConfigError(
            f"resilience.degraded_refine_cap must be >= 1 (or null), got "
            f"{res.degraded_refine_cap!r}")
    if res.backoff_max_ms < res.backoff_base_ms:
        raise ConfigError(
            f"resilience.backoff_max_ms={res.backoff_max_ms} cannot be "
            f"smaller than resilience.backoff_base_ms={res.backoff_base_ms}")
