"""Config-driven index construction and the ``AnnEngine`` serving
handle — the execution half of ``repro.api`` (docs/api.md).

``build_index`` turns (codes, C, structure) + the config tree's
``IndexConfig``/``ServeConfig`` sections into one of the unified index
layer's implementations; ``AnnEngine`` wraps any index into a jitted,
optionally mesh-sharded, growable query server.  The historical
``quant.serve_icq.build_ann_engine`` kwarg entry survives as a thin
shim over these (its kwargs are folded into a config), so every serving
caller — ``launch/serve.py``, the examples, the benchmarks — now goes
through the same door, and ``load_ann_engine`` opens that door from a
saved artifact directory.

Resilient serving (docs/robustness.md): ``AnnEngine`` is also the
executor of the degradation ladder and the backend failover —

  - ``search(queries, budget=SearchBudget(...))`` picks a ladder level
    (full → capped → probes → crude) per batch from *measured* warm
    wall times against the budget's deadline, and attaches a
    ``ResultMeta`` (level, stages, wall time, coverage, backend) to
    every ``SearchResult``;
  - a Pallas kernel failure blacklists that backend for the engine and
    transparently retries the batch on the jnp engines (bounded
    retries + exponential backoff, ``repro.resilience.retry``);
  - sharded engines survive dead shards (``mark_shard_dead``): the
    surviving shards' merged top-k is returned and ``meta.coverage``
    reports the reachable fraction instead of the call raising.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.api.artifacts import ArtifactError, Artifacts
from repro.api.config import (ConfigError, IndexConfig, ResilienceConfig,
                              ServeConfig)
from repro.resilience.budget import (DEGRADE_LEVELS, ResultMeta,
                                     SearchBudget, validate_budget)
from repro.resilience.retry import BackoffPolicy, retry_with_backoff

# warm-timing EMA weight: recent batches dominate but one outlier
# doesn't whipsaw the ladder choice
_EMA_ALPHA = 0.3


class AnnEngine:
    """A serving handle over one index: callable for query batches and
    growable via ``add`` (DESIGN.md §9).

    ``engine(queries)`` (or ``engine.search(queries)``) runs the jitted
    batched search — the historical ``build_ann_engine`` contract.
    ``engine.add(new_vectors)`` encodes the new embeddings through the
    tiled ICM engine, appends/routes them into the index *without*
    retraining, and refreshes the jitted search (re-sharding over the
    engine's mesh if one was given); the engine keeps the unsharded
    source index precisely so sharded serving stays growable.  Returns
    ``self`` so calls chain.

    Resilience surface (docs/robustness.md):

    ``resilience``      a ``ResilienceConfig`` — default deadline,
                        degraded-rung knobs, failover retry policy.
    ``fault_injector``  a ``repro.resilience.faults.FaultInjector``;
                        when set the engine serves *eagerly* (no outer
                        jit) so the injector's kernel hooks fire per
                        batch, and checks the ``"engine.search"`` stage
                        itself before each batch.
    ``search(..., budget=)``  per-batch ``SearchBudget``; every result
                        carries ``result.meta`` (a ``ResultMeta``).
    ``mark_shard_dead(s, ...)``  (sharded engines) fail shards over:
                        subsequent searches merge the survivors and
                        report ``meta.coverage`` < 1.0.
    ``stats``           served/degraded counters per ladder level and
                        the failover count — the chaos benchmark's
                        degraded-rate source.
    """

    def __init__(self, index, mesh=None, *,
                 resilience: Optional[ResilienceConfig] = None,
                 fault_injector=None, query_tile: Optional[int] = None):
        self.index = index                   # the unsharded source index
        self.mesh = mesh
        self.resilience = resilience or ResilienceConfig()
        self.fault_injector = fault_injector
        # canonical query-batch tile (rows).  None: each arrival shape
        # compiles its own program (historical behavior).  Set: every
        # search runs as ceil(nq/tile) zero-padded (tile, d) chunks of
        # ONE compiled program — so results are bitwise-independent of
        # how rows were batched (XLA reduction order varies with the
        # compiled batch size, and last-ulp distance drift across
        # shapes is real).  The serving loop pins this to its flush
        # tile, which is what makes coalesced responses bitwise-equal
        # to direct calls on the same engine (docs/serving.md).
        self.query_tile = query_tile
        self._blacklist: set = set()         # backends failed over from
        self._ema: Dict[str, float] = {}     # level -> warm wall-ms EMA
        self._warmed: set = set()            # fn cache keys that compiled
        self.stats: Dict[str, int] = {"degraded": 0, "failovers": 0}
        self._refresh()

    # ---------------------------------------------------------- plumbing --
    def _refresh(self):
        if self.mesh is not None:
            view = self.index.shard(self.mesh)
            # a refresh (engine.add) must not resurrect failed shards
            dead = (getattr(self._view, "dead_shards", frozenset())
                    if hasattr(self, "_view") else frozenset())
            if dead:
                view.mark_shard_dead(*dead)
            self._view = view
        else:
            self._view = self.index
        self._fns: Dict[Tuple, Any] = {}
        self._warmed = set()

    def _backend_eff(self) -> str:
        """The backend the engine currently dispatches to, after
        failover blacklisting (sharded bodies are jnp-only)."""
        from repro.index.base import resolve_backend

        if self.mesh is not None:
            return "jnp"
        be = resolve_backend(getattr(self.index, "backend", "auto"))
        return "jnp" if be in self._blacklist else be

    def _levels(self) -> Tuple[str, ...]:
        """Ladder rungs this engine can serve, least → most degraded."""
        from repro.index import FlatADC, IVFTwoStep, TwoStep

        if self.mesh is not None:
            return ("full",)                 # sharded: full search only
        idx = self.index
        if isinstance(idx, FlatADC):
            return ("full", "crude")         # crude == full (no refine)
        capped = () if self._backend_eff() == "pallas" else ("capped",)
        if isinstance(idx, IVFTwoStep):
            return ("full",) + capped + ("probes", "crude")
        if isinstance(idx, TwoStep):
            return ("full",) + capped + ("crude",)
        # custom Index implementations: full only (plus crude when they
        # provide the protocol's optional search_crude)
        return (("full", "crude") if hasattr(idx, "search_crude")
                else ("full",))

    def _level_index(self, level: str, budget: SearchBudget):
        """The index variant serving one ladder rung — built from the
        frozen source index via ``dataclasses.replace`` (cheap: array
        fields are shared, only engine options change)."""
        idx = self.index
        repl: Dict[str, Any] = {}
        be = self._backend_eff()
        if getattr(idx, "backend", None) is not None and \
                be != getattr(idx, "backend"):
            repl["backend"] = be
        if level == "capped":
            cap = (budget.refine_cap
                   if budget.refine_cap is not None
                   else self.resilience.degraded_refine_cap)
            repl["refine_cap"] = cap if cap is not None else \
                max(4 * self._topk_default(), 64)
        if hasattr(idx, "n_probe"):
            np_eff = int(idx.n_probe)
            if level == "probes":
                np_eff = max(self.resilience.min_n_probe, np_eff // 2)
            if budget.max_n_probe is not None:
                np_eff = min(np_eff, budget.max_n_probe)
            np_eff = max(1, np_eff)
            if np_eff != int(idx.n_probe):
                repl["n_probe"] = np_eff
        return dataclasses.replace(idx, **repl) if repl else idx

    def _topk_default(self) -> int:
        return int(getattr(self.index, "topk", 50))

    def _level_fn(self, level: str, topk: Optional[int],
                  budget: SearchBudget, has_filter: bool = False):
        lidx = (self._view if self.mesh is not None
                else self._level_index(level, budget))
        key = (level, topk, self._backend_eff(),
               getattr(lidx, "refine_cap", None),
               getattr(lidx, "n_probe", None),
               getattr(self._view, "dead_shards", None), has_filter)
        if key in self._fns:
            return key, self._fns[key]
        crude = level == "crude" and hasattr(lidx, "search_crude")
        if has_filter:
            if crude:
                call = (lambda q, f: lidx.search_crude(q, filter=f)) \
                    if topk is None \
                    else (lambda q, f: lidx.search_crude(q, topk, filter=f))
            else:
                call = (lambda q, f: lidx.search(q, filter=f)) \
                    if topk is None \
                    else (lambda q, f: lidx.search(q, topk, filter=f))
        elif crude:
            call = (lambda q: lidx.search_crude(q)) if topk is None \
                else (lambda q: lidx.search_crude(q, topk))
        else:
            call = (lambda q: lidx.search(q)) if topk is None \
                else (lambda q: lidx.search(q, topk))
        # under a fault injector the engine must stay eager: kernel
        # hooks fire at trace time only inside jit, so a jitted fn
        # would check faults once per compile instead of per batch
        # (sharded views run their own inner jit either way); a
        # pipelined index also stays eager — the executor runs a
        # host-level tile loop and owns its own jit/donation boundary,
        # which an outer trace would unroll and defeat
        if (self.fault_injector is None and self.mesh is None
                and getattr(lidx, "pipeline", "off") == "off"):
            call = jax.jit(call)
        self._fns[key] = call
        return key, call

    # ------------------------------------------------------ level choice --
    def _estimate_ms(self, level: str, order: Tuple[str, ...]):
        """Expected warm wall time for a rung: its own EMA, else the
        best measured less-degraded rung as an upper bound (a more
        degraded rung never runs slower), else None (unknown)."""
        if level in self._ema:
            return self._ema[level]
        upper = [self._ema[l] for l in order[:order.index(level)]
                 if l in self._ema]
        return min(upper) if upper else None

    def _pick_level(self, budget: SearchBudget) -> str:
        order = self._levels()
        if budget.force_level is not None:
            if budget.force_level not in order:
                raise ValueError(
                    f"force_level={budget.force_level!r} is not servable "
                    f"by this engine (available: {list(order)})")
            return budget.force_level
        if not budget.allow_refine:
            return "crude" if "crude" in order else order[-1]
        # hard caps promote their rung outright (deterministic, no
        # timing involved): a refine_cap asks for the capped rung, a
        # max_n_probe below the index's n_probe asks for probes
        floor_i = 0
        if budget.refine_cap is not None and "capped" in order:
            floor_i = max(floor_i, order.index("capped"))
        if (budget.max_n_probe is not None and "probes" in order
                and budget.max_n_probe < int(getattr(self.index,
                                                     "n_probe", 1))):
            floor_i = max(floor_i, order.index("probes"))
        order = order[floor_i:]
        deadline = (budget.deadline_ms if budget.deadline_ms is not None
                    else self.resilience.deadline_ms)
        if deadline is None:
            return order[0]
        # measured choice: least-degraded rung whose estimate fits; a
        # rung with no estimate at all (cold engine) is taken
        # optimistically — the measurement it produces steers the next
        # batch; the crude floor is always eligible
        for name in order:
            est = self._estimate_ms(name, self._levels())
            if est is None or est <= deadline:
                return name
        return order[-1]

    # ------------------------------------------------------------ serving --
    def _stages(self, level: str) -> Tuple[str, ...]:
        from repro.index import FlatADC, IVFTwoStep

        idx = self.index
        probe = ("probe",) if (isinstance(idx, IVFTwoStep)
                               or hasattr(idx, "n_probe")) else ()
        if isinstance(idx, FlatADC):
            return probe + ("adc",)
        if level == "crude":
            return probe + ("crude",)
        if level == "capped":
            return probe + ("crude", "refine-capped")
        return probe + ("crude", "refine")

    def _attempt(self, fn, *args):
        if self.fault_injector is not None:
            self.fault_injector.check("engine.search")
        r = fn(*args)
        jax.block_until_ready((r.indices, r.distances))
        return r

    def _run_tiled(self, fn, queries, filter=None):
        """Run one rung's fn over the batch.  Without ``query_tile``
        this is a single call at the arrival shape; with it, the batch
        runs as zero-padded (tile, d) chunks of one compiled program
        and the pad rows are sliced off — per-row results are invariant
        to position and neighbors within a fixed compiled shape, so
        chunking never changes any row's answer (tests/test_serve.py
        holds this bitwise)."""
        tile = self.query_tile
        nq = queries.shape[0]
        if tile is None:
            args = (queries,) if filter is None else (queries, filter)
            return self._attempt(fn, *args)
        tile = int(tile)
        parts = []
        for s in range(0, max(nq, 1), tile):
            chunk = queries[s:s + tile]
            pad = tile - chunk.shape[0]
            if pad:
                chunk = jnp.concatenate(
                    [chunk, jnp.zeros((pad, chunk.shape[1]),
                                      dtype=chunk.dtype)], axis=0)
            args = (chunk,) if filter is None else (chunk, filter)
            parts.append(self._attempt(fn, *args))
        if len(parts) == 1:
            r = parts[0]
            ids, dists = r.indices[:nq], r.distances[:nq]
        else:
            r = parts[-1]
            ids = jnp.concatenate([p.indices for p in parts], axis=0)[:nq]
            dists = jnp.concatenate([p.distances for p in parts],
                                    axis=0)[:nq]
        # avg_ops/pass_rate are padded-batch diagnostics (mean over
        # chunks); the bitwise contract covers ids + distances only
        k = len(parts)
        return r._replace(
            indices=ids, distances=dists,
            avg_ops=sum(p.avg_ops for p in parts) / k,
            pass_rate=sum(p.pass_rate for p in parts) / k)

    def _serve_with_failover(self, level, topk, budget, queries,
                             filter=None):
        """One batch at one rung, with backend failover: a failure on
        the pallas backend blacklists it for the whole engine and the
        batch retries on the jnp engines under the configured backoff;
        jnp/sharded failures retry in place (transient-fault model)."""
        res = self.resilience
        policy = BackoffPolicy(max_retries=res.max_retries,
                               base_ms=res.backoff_base_ms,
                               max_ms=res.backoff_max_ms)
        has_filter = filter is not None
        key, fn = self._level_fn(level, topk, budget, has_filter)
        try:
            return key, self._run_tiled(fn, queries, filter)
        except Exception:
            if res.pallas_failover and self._backend_eff() == "pallas":
                # kernel path failed: fail the backend over, not the
                # query — rebuild this rung on jnp and retry bounded
                self._blacklist.add("pallas")
                self.stats["failovers"] += 1
                self._fns.clear()
                self._warmed.discard(key)
                key, fn = self._level_fn(level, topk, budget, has_filter)
            return key, retry_with_backoff(
                lambda: self._run_tiled(fn, queries, filter),
                policy=policy)

    def __call__(self, queries, budget: Optional[SearchBudget] = None):
        return self.search(queries, budget=budget)

    def search(self, queries, k: Optional[int] = None, *,
               budget: Optional[SearchBudget] = None, filter=None):
        """Serve one query batch; ``k`` overrides the index's built-in
        ``topk`` for this call.  ``budget`` (docs/robustness.md) bounds
        the batch — the engine picks the degradation-ladder rung that
        fits and reports what it did on ``result.meta``.  ``filter``: an
        optional (n,) boolean row predicate — only rows where it is
        True can be returned; absent slots are id -1 / dist +inf
        (jnp engines only)."""
        if filter is not None:
            from repro.index.base import as_filter
            if self._backend_eff() == "pallas":
                raise ValueError(
                    "filtered search requires backend='jnp' (the fused "
                    "kernels cannot mask rows by predicate)")
            filter = as_filter(filter, self.n)
        budget = validate_budget(budget) if budget is not None \
            else SearchBudget()
        level = self._pick_level(budget)
        deadline = (budget.deadline_ms if budget.deadline_ms is not None
                    else self.resilience.deadline_ms)
        t0 = time.perf_counter()
        key, result = self._serve_with_failover(level, k, budget, queries,
                                                filter)
        wall_ms = (time.perf_counter() - t0) * 1000.0
        # warm-only timing: the first call through a compiled fn pays
        # tracing + compilation and would poison the ladder's estimates
        if key in self._warmed:
            prev = self._ema.get(level)
            self._ema[level] = wall_ms if prev is None else \
                (1 - _EMA_ALPHA) * prev + _EMA_ALPHA * wall_ms
        else:
            self._warmed.add(key)
        coverage = float(getattr(self._view, "coverage", 1.0))
        li = DEGRADE_LEVELS.index(level)
        meta = ResultMeta(
            level=li, level_name=level,
            degraded=li > 0 or coverage < 1.0,
            stages=self._stages(level), wall_ms=wall_ms,
            deadline_ms=deadline,
            deadline_exceeded=(deadline is not None and wall_ms > deadline),
            coverage=coverage, backend=self._backend_eff())
        self.stats[level] = self.stats.get(level, 0) + 1
        if meta.degraded:
            self.stats["degraded"] += 1
        return result._replace(meta=meta)

    def warm(self, nq: int, k: Optional[int] = None, *,
             budget: Optional[SearchBudget] = None) -> "AnnEngine":
        """Precompile the (nq, d) program one ``search(queries, k,
        budget=...)`` call would run and mark it warm, so the first real
        batch at that shape pays dispatch instead of trace+compile (and
        its timing feeds the ladder's EMA instead of being discarded as
        a cold call).  The serving loop warms its flush-tile shape this
        way (``repro.serve.ServingLoop.warm``); warming an
        already-compiled shape is a cheap no-op (jit's signature cache
        hits)."""
        budget = validate_budget(budget) if budget is not None \
            else SearchBudget()
        level = self._pick_level(budget)
        key, fn = self._level_fn(level, k, budget)
        d = int(self.index.C.shape[-1])
        zeros = jnp.zeros((int(nq), d), dtype=jnp.float32)
        self._run_tiled(fn, zeros)
        self._warmed.add(key)
        return self

    # ------------------------------------------------------------- shards --
    def mark_shard_dead(self, *shards: int) -> "AnnEngine":
        """Fail shards over (sharded engines only): subsequent searches
        merge the surviving shards' top-k and report the reachable
        fraction on ``meta.coverage`` instead of raising."""
        if self.mesh is None:
            raise ValueError("mark_shard_dead needs a sharded engine "
                             "(AnnEngine(mesh=...))")
        self._view.mark_shard_dead(*shards)
        return self

    @property
    def coverage(self) -> float:
        return float(getattr(self._view, "coverage", 1.0))

    @property
    def n(self) -> int:
        return self.index.codes.shape[0]

    def add(self, new_vectors, **encode_opts) -> "AnnEngine":
        self.index = self.index.add(new_vectors, **encode_opts)
        self._refresh()
        return self


def build_index(codes, C, structure, *, index_cfg: IndexConfig,
                serve_cfg: ServeConfig, emb_db=None, key=None):
    """Build an index from the config tree's sections — THE construction
    path behind ``ICQSession.index``, ``build_ann_engine``, and artifact
    loading (``api.artifacts._index_opts`` mirrors the option
    resolution, which is what makes a loaded index serve identically).

    ``emb_db`` (the embeddings the codes encode) is required for
    ``index_cfg.kind == "ivf"``; ``key`` seeds its coarse k-means.

    ``index_cfg.code_bits == 4`` stores the database nibble-packed
    (DESIGN.md §12): byte-per-code ``codes`` arriving here (the
    ``encode_database`` output) are packed two-per-byte before
    device_put; codes already in the (n, ceil(K/2)) layout are taken
    as-is, so a loaded artifact round-trips bitwise.
    """
    from repro.core.encode import pack_nibbles
    from repro.index import make_index, resolve_code_bits

    code_bits = resolve_code_bits(index_cfg.code_bits)
    if code_bits == 4:
        if C.shape[1] > 16:
            raise ConfigError(
                f"index.code_bits=4 requires codebook_size <= 16 "
                f"codewords (4-bit codes), got m={C.shape[1]}; set "
                "train.codebook_size <= 16 or keep index.code_bits=8")
        if codes.shape[-1] == C.shape[0] and C.shape[0] > 1:
            codes = pack_nibbles(codes, C.shape[0])

    opts: Dict[str, Any] = dict(topk=serve_cfg.topk,
                                backend=serve_cfg.backend,
                                query_chunk=serve_cfg.query_chunk,
                                lut_dtype=serve_cfg.lut_dtype,
                                code_bits=code_bits,
                                pipeline=serve_cfg.pipeline,
                                pipeline_tile=serve_cfg.pipeline_tile)
    # None = keep the index class's own tile defaults (they differ
    # between the flat engines and the IVF slab kernels)
    if serve_cfg.block_q is not None:
        opts["block_q"] = serve_cfg.block_q
    if serve_cfg.block_n is not None:
        opts["block_n"] = serve_cfg.block_n
    if index_cfg.kind != "flat":
        opts["refine_cap"] = index_cfg.refine_cap
    if index_cfg.kind == "ivf":
        if emb_db is None:
            raise ConfigError("index.kind='ivf' needs emb_db= (the "
                              "embeddings the codes encode) to fit the "
                              "coarse quantizer")
        opts.update(emb_db=emb_db, n_lists=index_cfg.n_lists,
                    n_probe=index_cfg.n_probe,
                    kmeans_iters=index_cfg.kmeans_iters, key=key)
    return make_index(index_cfg.kind, jax.device_put(codes),
                      jax.device_put(C), structure, **opts)


def build_ann_engine(codes, C, structure, *, topk: int = 50,
                     backend: str = "auto", block_q=None, block_n=None,
                     query_chunk=None, index: str = "two-step", mesh=None,
                     emb_db=None, n_lists: int = 64, n_probe: int = 8,
                     refine_cap=None, key=None, lut_dtype: str = "f32",
                     code_bits: int = 8, pipeline: str = "off",
                     pipeline_tile=None,
                     resilience: Optional[ResilienceConfig] = None,
                     fault_injector=None):
    """Batched ANN serving entry: returns an ``AnnEngine`` — call it
    with an (nq, d) query batch for a ``repro.index.SearchResult``,
    and grow it in place with ``engine.add(new_vectors)`` (incremental
    encode + append, no retraining).

    This is the historical kwarg surface; the kwargs are folded into
    the api config tree (``IndexConfig`` + ``ServeConfig``) and routed
    through ``build_index`` — new code should build an ``ICQConfig``
    and use ``ICQSession`` / ``build_index`` directly (docs/api.md).

    ``index`` selects the implementation ("flat" | "two-step" | "ivf");
    "ivf" additionally needs ``emb_db`` (the database embeddings the
    codes encode) and takes ``n_lists`` / ``n_probe`` / ``key``.
    ``mesh`` (optional, with a "data" axis) shards the index for
    data-parallel serving.  ``codes`` stay device-resident across calls
    (packed uint8; widened at the kernel boundary).  ``backend`` follows
    the unified dispatch: "pallas" fused kernels on TPU, vectorized jnp
    elsewhere.  ``lut_dtype`` ("f32" | "int8") selects the crude-pass
    LUT precision (DESIGN.md §8; honored by the sharded engines too).
    ``code_bits`` (8 | 4) selects the code storage width — 4 serves the
    fast-scan nibble-packed layout (DESIGN.md §12, needs m <= 16).
    ``pipeline`` ("off" | "tiles" | "auto") enables the overlapped
    crude/refine tile executor (DESIGN.md §13); ``pipeline_tile``
    overrides its queries-per-tile default.  ``resilience`` /
    ``fault_injector`` configure the engine's failure behavior
    (docs/robustness.md).
    """
    # n_lists/n_probe only describe an IVF; for the flat kinds they were
    # historically ignored, so keep them out of the validated config
    index_cfg = (IndexConfig(kind=index, n_lists=n_lists, n_probe=n_probe,
                             refine_cap=refine_cap, code_bits=code_bits)
                 if index == "ivf"
                 else IndexConfig(kind=index, refine_cap=refine_cap,
                                  code_bits=code_bits))
    serve_cfg = ServeConfig(topk=topk, backend=backend, lut_dtype=lut_dtype,
                            query_chunk=query_chunk, block_q=block_q,
                            block_n=block_n, pipeline=pipeline,
                            pipeline_tile=pipeline_tile)
    idx = build_index(codes, C, structure, index_cfg=index_cfg,
                      serve_cfg=serve_cfg, emb_db=emb_db, key=key)
    return AnnEngine(idx, mesh=mesh, resilience=resilience,
                     fault_injector=fault_injector)


def load_ann_engine(path: str, *, mesh=None,
                    overrides: Optional[Dict[str, Any]] = None,
                    verify_checksums: Optional[bool] = None,
                    fault_injector=None) -> AnnEngine:
    """Open a saved artifact directory as a live serving engine.

    The artifacts must contain an index (``Artifacts.save`` with
    ``index=``); ``overrides`` applies dotted config overrides (e.g.
    ``{"serve.backend": "jnp"}``, ``{"index.n_probe": 16}``) before the
    index is rebuilt, so a saved index can be re-served with different
    engine options without re-exporting (``index.kind`` names the
    stored layout and is rejected).  ``mesh`` shards the loaded index
    for data-parallel serving, exactly like ``build_ann_engine(mesh=)``.

    ``verify_checksums`` forces the full per-tensor sha256 pass on load
    (None defers to the embedded config's
    ``resilience.verify_artifacts``); the engine inherits the embedded
    ``ResilienceConfig``.
    """
    if verify_checksums is None:
        # peek: the embedded config decides, unless the caller forces it
        art = Artifacts.load(path, overrides=overrides)
        if art.config.resilience.verify_artifacts:
            art = Artifacts.load(path, overrides=overrides,
                                 verify_checksums=True)
    else:
        art = Artifacts.load(path, overrides=overrides,
                             verify_checksums=verify_checksums)
    if art.index is None:
        raise ArtifactError(
            f"{path}: artifacts hold no index (model-only save); build "
            "one with ICQSession.index and save again")
    return AnnEngine(art.index, mesh=mesh,
                     resilience=art.config.resilience,
                     fault_injector=fault_injector)
