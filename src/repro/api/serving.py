"""Config-driven index construction and the ``AnnEngine`` serving
handle — the execution half of ``repro.api`` (docs/api.md).

``build_index`` turns (codes, C, structure) + the config tree's
``IndexConfig``/``ServeConfig`` sections into one of the unified index
layer's implementations; ``AnnEngine`` wraps any index into a jitted,
optionally mesh-sharded, growable query server.  The historical
``quant.serve_icq.build_ann_engine`` kwarg entry survives as a thin
shim over these (its kwargs are folded into a config), so every serving
caller — ``launch/serve.py``, the examples, the benchmarks — now goes
through the same door, and ``load_ann_engine`` opens that door from a
saved artifact directory.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax

from repro.api.artifacts import ArtifactError, Artifacts
from repro.api.config import ConfigError, IndexConfig, ServeConfig


class AnnEngine:
    """A serving handle over one index: callable for query batches and
    growable via ``add`` (DESIGN.md §9).

    ``engine(queries)`` (or ``engine.search(queries)``) runs the jitted
    batched search — the historical ``build_ann_engine`` contract.
    ``engine.add(new_vectors)`` encodes the new embeddings through the
    tiled ICM engine, appends/routes them into the index *without
    retraining*, and refreshes the jitted search (re-sharding over the
    engine's mesh if one was given); the engine keeps the unsharded
    source index precisely so sharded serving stays growable.  Returns
    ``self`` so calls chain."""

    def __init__(self, index, mesh=None):
        self.index = index                   # the unsharded source index
        self.mesh = mesh
        self._refresh()

    def _refresh(self):
        if self.mesh is not None:
            self._view = self.index.shard(self.mesh)
            self._serve = self._view.search
        else:
            self._view = idx = self.index
            self._serve = jax.jit(lambda queries: idx.search(queries))

    def __call__(self, queries):
        return self._serve(queries)

    def search(self, queries, k: Optional[int] = None):
        """Serve one query batch; ``k`` overrides the index's built-in
        ``topk`` for this call (off the jitted default path)."""
        if k is None:
            return self._serve(queries)
        return self._view.search(queries, topk=k)

    @property
    def n(self) -> int:
        return self.index.codes.shape[0]

    def add(self, new_vectors, **encode_opts) -> "AnnEngine":
        self.index = self.index.add(new_vectors, **encode_opts)
        self._refresh()
        return self


def build_index(codes, C, structure, *, index_cfg: IndexConfig,
                serve_cfg: ServeConfig, emb_db=None, key=None):
    """Build an index from the config tree's sections — THE construction
    path behind ``ICQSession.index``, ``build_ann_engine``, and artifact
    loading (``api.artifacts._index_opts`` mirrors the option
    resolution, which is what makes a loaded index serve identically).

    ``emb_db`` (the embeddings the codes encode) is required for
    ``index_cfg.kind == "ivf"``; ``key`` seeds its coarse k-means.
    """
    from repro.index import make_index

    opts: Dict[str, Any] = dict(topk=serve_cfg.topk,
                                backend=serve_cfg.backend,
                                query_chunk=serve_cfg.query_chunk,
                                lut_dtype=serve_cfg.lut_dtype)
    # None = keep the index class's own tile defaults (they differ
    # between the flat engines and the IVF slab kernels)
    if serve_cfg.block_q is not None:
        opts["block_q"] = serve_cfg.block_q
    if serve_cfg.block_n is not None:
        opts["block_n"] = serve_cfg.block_n
    if index_cfg.kind != "flat":
        opts["refine_cap"] = index_cfg.refine_cap
    if index_cfg.kind == "ivf":
        if emb_db is None:
            raise ConfigError("index.kind='ivf' needs emb_db= (the "
                              "embeddings the codes encode) to fit the "
                              "coarse quantizer")
        opts.update(emb_db=emb_db, n_lists=index_cfg.n_lists,
                    n_probe=index_cfg.n_probe,
                    kmeans_iters=index_cfg.kmeans_iters, key=key)
    return make_index(index_cfg.kind, jax.device_put(codes),
                      jax.device_put(C), structure, **opts)


def build_ann_engine(codes, C, structure, *, topk: int = 50,
                     backend: str = "auto", block_q=None, block_n=None,
                     query_chunk=None, index: str = "two-step", mesh=None,
                     emb_db=None, n_lists: int = 64, n_probe: int = 8,
                     refine_cap=None, key=None, lut_dtype: str = "f32"):
    """Batched ANN serving entry: returns an ``AnnEngine`` — call it
    with an (nq, d) query batch for a ``repro.index.SearchResult``,
    and grow it in place with ``engine.add(new_vectors)`` (incremental
    encode + append, no retraining).

    This is the historical kwarg surface; the kwargs are folded into
    the api config tree (``IndexConfig`` + ``ServeConfig``) and routed
    through ``build_index`` — new code should build an ``ICQConfig``
    and use ``ICQSession`` / ``build_index`` directly (docs/api.md).

    ``index`` selects the implementation ("flat" | "two-step" | "ivf");
    "ivf" additionally needs ``emb_db`` (the database embeddings the
    codes encode) and takes ``n_lists`` / ``n_probe`` / ``key``.
    ``mesh`` (optional, with a "data" axis) shards the index for
    data-parallel serving.  ``codes`` stay device-resident across calls
    (packed uint8; widened at the kernel boundary).  ``backend`` follows
    the unified dispatch: "pallas" fused kernels on TPU, vectorized jnp
    elsewhere.  ``lut_dtype`` ("f32" | "int8") selects the crude-pass
    LUT precision (DESIGN.md §8; honored by the sharded engines too).
    """
    # n_lists/n_probe only describe an IVF; for the flat kinds they were
    # historically ignored, so keep them out of the validated config
    index_cfg = (IndexConfig(kind=index, n_lists=n_lists, n_probe=n_probe,
                             refine_cap=refine_cap)
                 if index == "ivf"
                 else IndexConfig(kind=index, refine_cap=refine_cap))
    serve_cfg = ServeConfig(topk=topk, backend=backend, lut_dtype=lut_dtype,
                            query_chunk=query_chunk, block_q=block_q,
                            block_n=block_n)
    idx = build_index(codes, C, structure, index_cfg=index_cfg,
                      serve_cfg=serve_cfg, emb_db=emb_db, key=key)
    return AnnEngine(idx, mesh=mesh)


def load_ann_engine(path: str, *, mesh=None,
                    overrides: Optional[Dict[str, Any]] = None) -> AnnEngine:
    """Open a saved artifact directory as a live serving engine.

    The artifacts must contain an index (``Artifacts.save`` with
    ``index=``); ``overrides`` applies dotted config overrides (e.g.
    ``{"serve.backend": "jnp"}``, ``{"index.n_probe": 16}``) before the
    index is rebuilt, so a saved index can be re-served with different
    engine options without re-exporting (``index.kind`` names the
    stored layout and is rejected).  ``mesh`` shards the loaded index
    for data-parallel serving, exactly like ``build_ann_engine(mesh=)``.
    """
    art = Artifacts.load(path, overrides=overrides)
    if art.index is None:
        raise ArtifactError(
            f"{path}: artifacts hold no index (model-only save); build "
            "one with ICQSession.index and save again")
    return AnnEngine(art.index, mesh=mesh)
