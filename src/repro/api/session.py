"""The lifecycle facade of ``repro.api`` (docs/api.md): one object that
walks a config through fit → encode → index → search → save.

    from repro.api import ICQConfig, icq_session

    session = icq_session(ICQConfig.load("config.json"))
    state = session.fit(X, y, key=jax.random.PRNGKey(0))   # ICQModel
    searcher = session.index()            # index over the fit data
    result = searcher.search(queries, k=10)
    searcher.save("artifacts/run0")       # fit→save→load→search is
                                          # bitwise-identical (tested)

``fit`` dispatches on ``config.train.quantizer``: the joint trainer
modes ("icq", "sq", "pqn") run the scan-compiled — optionally
data-parallel — epoch driver (``trainer.fit``); the protocol baselines
("pq", "opq", "cq") run the generic ``init``/``step``/``finalize``
loop.  ``index`` builds any of the three index types from the config's
``index``/``serve`` sections over the fit data or a new database, and
``Searcher`` embeds raw-space queries with the trained model before
every search, so callers never touch embeddings, codes, or LUTs.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.api.artifacts import Artifacts
from repro.api.config import (JOINT_MODES, ConfigError, ICQConfig)
from repro.api.serving import AnnEngine, build_index


class Searcher:
    """A trained model + a built (optionally sharded) index behind one
    query method.  ``search`` takes *raw-space* queries (they are
    embedded with the session's model); ``add`` grows the index from
    raw-space vectors without retraining; ``save`` persists model +
    index as one artifact directory (``repro.api.artifacts``)."""

    def __init__(self, model, engine: AnnEngine, config: ICQConfig):
        self.model = model
        self.engine = engine
        self.config = config

    @property
    def index(self):
        """The unsharded source index (a frozen index dataclass)."""
        return self.engine.index

    @property
    def n(self) -> int:
        return self.engine.n

    def search(self, queries, k: Optional[int] = None, *, budget=None):
        """Embed ``queries`` ((nq, ...) raw inputs) and search.  ``k``
        overrides ``config.serve.topk`` for this call; ``budget`` (a
        ``repro.resilience.SearchBudget``) bounds the batch and is
        passed through to the engine (docs/robustness.md).  Returns a
        ``repro.index.SearchResult`` whose ``meta`` reports what the
        engine actually did."""
        emb = self.model.embed(jnp.asarray(queries))
        return self.engine.search(emb, k, budget=budget)

    def add(self, new_x, **encode_opts) -> "Searcher":
        """Encode raw-space ``new_x`` through the model + tiled ICM
        engine and grow the index in place (no retraining).  New rows
        get ids [n, n + n_new).  ``encode_opts`` (``icm_iters``,
        ``encode_backend``, ``point_chunk``) override the config's
        encode section for this call.  Returns ``self``."""
        opts = dict(icm_iters=self.config.encode.icm_iters,
                    encode_backend=self.config.encode.backend,
                    point_chunk=self.config.encode.point_chunk)
        opts.update(encode_opts)
        self.engine.add(self.model.embed(jnp.asarray(new_x)), **opts)
        return self

    def save(self, path: str) -> str:
        """Persist config + model + (unsharded) index to ``path``; a
        fresh process reloads with ``repro.api.load_artifacts`` /
        ``load_ann_engine`` and serves identically."""
        return Artifacts(config=self.config, model=self.model,
                         index=self.engine.index).save(path)


class ICQSession:
    """The front door: holds a validated ``ICQConfig`` and the state the
    lifecycle produces (fitted model, fit-data embeddings)."""

    def __init__(self, config: ICQConfig):
        if not isinstance(config, ICQConfig):
            raise ConfigError(
                f"icq_session needs an api ICQConfig, got "
                f"{type(config).__name__} (build one with "
                "repro.api.ICQConfig or ICQConfig.load(path))")
        self.config = config
        self.model = None                 # trainer.base.ICQModel after fit
        self._fit_emb = None              # embeddings of the fit data

    # -------------------------------------------------------------- fit --
    def fit(self, X, y=None, *, key=None, mesh=None, verbose: bool = False):
        """Train the configured quantizer on ``X`` (+ optional labels
        ``y`` for the supervised embedding loss; zeros when omitted).

        key:   PRNG key threading init + shuffle (default PRNGKey(0)).
        mesh:  optional mesh with a "data" axis — data-parallel epochs
               for the joint trainer modes (``trainer.fit(mesh=)``).

        Returns (and retains) the fitted ``ICQModel``; the fit data's
        embeddings are kept so ``index()`` can build over them without
        re-embedding.
        """
        cfg = self.config
        key = jax.random.PRNGKey(0) if key is None else key
        X = jnp.asarray(X)
        y = (jnp.zeros((X.shape[0],), jnp.int32) if y is None
             else jnp.asarray(y))
        quantizer = cfg.train.quantizer
        hyper = cfg.train.hyperparams(icm_iters=cfg.encode.icm_iters)
        if quantizer in JOINT_MODES:
            from repro.trainer import fit as trainer_fit

            self.model = trainer_fit(
                key, X, y, hyper, mode=JOINT_MODES[quantizer],
                embed_kind=cfg.train.embed,
                num_classes=cfg.train.num_classes,
                img_hw=cfg.train.img_hw, channels=cfg.train.channels,
                epochs=cfg.train.epochs, batch_size=cfg.train.batch_size,
                lr=cfg.train.lr, tau=cfg.train.tau, verbose=verbose,
                mesh=mesh, encode_batch=cfg.encode.chunk,
                encode_backend=cfg.encode.backend)
        else:
            from repro.trainer import make_quantizer

            if mesh is not None:
                raise ConfigError(
                    f"mesh-parallel fit is only wired for the joint "
                    f"trainer modes {sorted(JOINT_MODES)}, not "
                    f"{quantizer!r}")
            q = make_quantizer(quantizer, hyper)
            state = q.init(key, X, y)
            for _ in range(cfg.train.epochs):
                state = q.step(state, (X, y))
            self.model = q.finalize(state, X)
        self._fit_emb = self.model.embed(X)
        return self.model

    # ------------------------------------------------------------ index --
    def index(self, db=None, *, mesh=None, key=None) -> Searcher:
        """Build the configured index and wrap it with the model into a
        ``Searcher``.

        db:    optional (n, ...) raw-space database to index; ``None``
               indexes the fit data (reusing the codes ``fit`` already
               exported — no re-encode).
        mesh:  optional "data"-axis mesh for sharded serving.
        key:   seeds the IVF coarse k-means (default derived from 0).
        """
        if self.model is None:
            raise ConfigError("session.index() before session.fit(); fit "
                              "a model first (or load artifacts with "
                              "repro.api.load_artifacts)")
        cfg = self.config
        if db is None:
            codes, emb_db = self.model.codes, self._fit_emb
        else:
            from repro.trainer import encode_database

            emb_db = self.model.embed(jnp.asarray(db))
            codes = encode_database(
                emb_db, self.model.C,
                mode="pq" if self.model.mode == "pq" else "icm",
                icm_iters=cfg.encode.icm_iters, chunk=cfg.encode.chunk,
                backend=cfg.encode.backend)
        idx = build_index(codes, self.model.C, self.model.structure,
                          index_cfg=cfg.index, serve_cfg=cfg.serve,
                          emb_db=emb_db,
                          key=jax.random.PRNGKey(0) if key is None else key)
        return Searcher(self.model, AnnEngine(idx, mesh=mesh), cfg)

    # ------------------------------------------------------------- save --
    def save(self, path: str) -> str:
        """Persist the fitted model (no index) — ``Searcher.save``
        persists model + index together."""
        if self.model is None:
            raise ConfigError("session.save() before session.fit()")
        return Artifacts(config=self.config, model=self.model).save(path)

    @classmethod
    def from_artifacts(cls, path: str) -> "ICQSession":
        """Rebuild a session (config + fitted model) from saved
        artifacts; ``index()`` then works as after ``fit`` (for a saved
        *index*, prefer ``repro.api.load_ann_engine`` — it skips the
        rebuild and serves the stored index directly)."""
        art = Artifacts.load(path)
        if art.model is None:
            raise ConfigError(
                f"{path}: artifacts hold no model (index-only save); "
                "serve them with repro.api.load_ann_engine instead")
        session = cls(art.config)
        session.model = art.model
        return session


def icq_session(config: ICQConfig) -> ICQSession:
    """Open the front door: validate ``config`` and return an
    ``ICQSession`` (see class docstring for the lifecycle)."""
    return ICQSession(config)
