"""The lifecycle facade of ``repro.api`` (docs/api.md): one object that
walks a config through fit → encode → index → search → save.

    from repro.api import ICQConfig, icq_session

    session = icq_session(ICQConfig.load("config.json"))
    state = session.fit(X, y, key=jax.random.PRNGKey(0))   # ICQModel
    searcher = session.index()            # index over the fit data
    result = searcher.search(queries, k=10)
    searcher.save("artifacts/run0")       # fit→save→load→search is
                                          # bitwise-identical (tested)

``fit`` dispatches on ``config.train.quantizer``: the joint trainer
modes ("icq", "sq", "pqn") run the scan-compiled — optionally
data-parallel — epoch driver (``trainer.fit``); the protocol baselines
("pq", "opq", "cq") run the generic ``init``/``step``/``finalize``
loop.  ``index`` builds any of the three index types from the config's
``index``/``serve`` sections over the fit data or a new database, and
``Searcher`` embeds raw-space queries with the trained model before
every search, so callers never touch embeddings, codes, or LUTs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.artifacts import Artifacts
from repro.api.config import (JOINT_MODES, ConfigError, ICQConfig)
from repro.api.serving import AnnEngine, build_index


class Searcher:
    """A trained model + a built (optionally sharded) index behind one
    query method.  ``search`` takes *raw-space* queries (they are
    embedded with the session's model); ``add`` grows the index from
    raw-space vectors without retraining; ``save`` persists model +
    index as one artifact directory (``repro.api.artifacts``)."""

    def __init__(self, model, engine: AnnEngine, config: ICQConfig):
        self.model = model
        self.engine = engine
        self.config = config

    @property
    def index(self):
        """The unsharded source index (a frozen index dataclass)."""
        return self.engine.index

    @property
    def n(self) -> int:
        return self.engine.n

    def search(self, queries, k: Optional[int] = None, *, budget=None,
               filter=None):
        """Embed ``queries`` ((nq, ...) raw inputs) and search.  ``k``
        overrides ``config.serve.topk`` for this call; ``budget`` (a
        ``repro.resilience.SearchBudget``) bounds the batch and is
        passed through to the engine (docs/robustness.md); ``filter``
        (an (n,) boolean row predicate) restricts results to rows where
        it is True — absent slots come back id -1 / dist +inf.  Returns
        a ``repro.index.SearchResult`` whose ``meta`` reports what the
        engine actually did."""
        emb = self.model.embed(jnp.asarray(queries))
        return self.engine.search(emb, k, budget=budget, filter=filter)

    def add(self, new_x, **encode_opts) -> "Searcher":
        """Encode raw-space ``new_x`` through the model + tiled ICM
        engine and grow the index in place (no retraining).  New rows
        get ids [n, n + n_new).  ``encode_opts`` (``icm_iters``,
        ``encode_backend``, ``point_chunk``) override the config's
        encode section for this call.  Returns ``self``."""
        opts = dict(icm_iters=self.config.encode.icm_iters,
                    encode_backend=self.config.encode.backend,
                    point_chunk=self.config.encode.point_chunk)
        opts.update(encode_opts)
        self.engine.add(self.model.embed(jnp.asarray(new_x)), **opts)
        return self

    def save(self, path: str) -> str:
        """Persist config + model + (unsharded) index to ``path``; a
        fresh process reloads with ``repro.api.load_artifacts`` /
        ``load_ann_engine`` and serves identically."""
        return Artifacts(config=self.config, model=self.model,
                         index=self.engine.index).save(path)


class ICQSession:
    """The front door: holds a validated ``ICQConfig`` and the state the
    lifecycle produces (fitted model, fit-data embeddings)."""

    def __init__(self, config: ICQConfig):
        if not isinstance(config, ICQConfig):
            raise ConfigError(
                f"icq_session needs an api ICQConfig, got "
                f"{type(config).__name__} (build one with "
                "repro.api.ICQConfig or ICQConfig.load(path))")
        self.config = config
        self.model = None                 # trainer.base.ICQModel after fit
        self._fit_emb = None              # embeddings of the fit data

    # -------------------------------------------------------------- fit --
    def fit(self, X, y=None, *, key=None, mesh=None, verbose: bool = False):
        """Train the configured quantizer on ``X`` (+ optional labels
        ``y`` for the supervised embedding loss; zeros when omitted).

        key:   PRNG key threading init + shuffle (default PRNGKey(0)).
        mesh:  optional mesh with a "data" axis — data-parallel epochs
               for the joint trainer modes (``trainer.fit(mesh=)``).

        Returns (and retains) the fitted ``ICQModel``; the fit data's
        embeddings are kept so ``index()`` can build over them without
        re-embedding.
        """
        cfg = self.config
        key = jax.random.PRNGKey(0) if key is None else key
        X = jnp.asarray(X)
        y = (jnp.zeros((X.shape[0],), jnp.int32) if y is None
             else jnp.asarray(y))
        quantizer = cfg.train.quantizer
        hyper = cfg.train.hyperparams(icm_iters=cfg.encode.icm_iters)
        if quantizer in JOINT_MODES:
            from repro.trainer import fit as trainer_fit

            self.model = trainer_fit(
                key, X, y, hyper, mode=JOINT_MODES[quantizer],
                embed_kind=cfg.train.embed,
                num_classes=cfg.train.num_classes,
                img_hw=cfg.train.img_hw, channels=cfg.train.channels,
                epochs=cfg.train.epochs, batch_size=cfg.train.batch_size,
                lr=cfg.train.lr, tau=cfg.train.tau, verbose=verbose,
                mesh=mesh, encode_batch=cfg.encode.chunk,
                encode_backend=cfg.encode.backend)
        else:
            from repro.trainer import make_quantizer

            if mesh is not None:
                raise ConfigError(
                    f"mesh-parallel fit is only wired for the joint "
                    f"trainer modes {sorted(JOINT_MODES)}, not "
                    f"{quantizer!r}")
            q = make_quantizer(quantizer, hyper)
            state = q.init(key, X, y)
            for _ in range(cfg.train.epochs):
                state = q.step(state, (X, y))
            self.model = q.finalize(state, X)
        self._fit_emb = self.model.embed(X)
        return self.model

    # ------------------------------------------------------------ index --
    def index(self, db=None, *, mesh=None, key=None) -> Searcher:
        """Build the configured index and wrap it with the model into a
        ``Searcher``.

        db:    optional (n, ...) raw-space database to index; ``None``
               indexes the fit data (reusing the codes ``fit`` already
               exported — no re-encode).
        mesh:  optional "data"-axis mesh for sharded serving.
        key:   seeds the IVF coarse k-means (default derived from 0).
        """
        if self.model is None:
            raise ConfigError("session.index() before session.fit(); fit "
                              "a model first (or load artifacts with "
                              "repro.api.load_artifacts)")
        cfg = self.config
        if db is None:
            codes, emb_db = self.model.codes, self._fit_emb
        else:
            from repro.trainer import encode_database

            emb_db = self.model.embed(jnp.asarray(db))
            codes = encode_database(
                emb_db, self.model.C,
                mode="pq" if self.model.mode == "pq" else "icm",
                icm_iters=cfg.encode.icm_iters, chunk=cfg.encode.chunk,
                backend=cfg.encode.backend)
        idx = build_index(codes, self.model.C, self.model.structure,
                          index_cfg=cfg.index, serve_cfg=cfg.serve,
                          emb_db=emb_db,
                          key=jax.random.PRNGKey(0) if key is None else key)
        return Searcher(self.model, AnnEngine(idx, mesh=mesh), cfg)

    # ------------------------------------------------------------- tune --
    def _tuning_structure(self, num_fast: int):
        """The trained structure with the fast set re-selected to
        ``num_fast`` codebooks over the *same* trained codebooks and psi
        split (eq. 8's top-k fallback re-ranks by in-psi energy), so
        |K_fast| is sweepable without retraining.  sigma depends only on
        the psi split and is unchanged."""
        st = self.model.structure
        if int(st.fast_mask.sum()) == num_fast:
            return st
        from repro.core import icq as icq_mod

        mask = icq_mod.fast_set_topk(self.model.C, st.xi, num_fast)
        return st._replace(fast_mask=mask)

    def _tune_grid(self) -> List[Dict[str, Any]]:
        """Coarse candidate grid of dotted config overrides for the
        configured index kind — search-time knobs only, so every
        candidate is a cheap ``dataclasses.replace`` on one built
        index."""
        cfg = self.config
        K = cfg.train.num_codebooks
        kind = cfg.index.kind
        if kind == "flat":
            return [{}, {"serve.lut_dtype": "int8"},
                    {"serve.pipeline": "tiles"}]
        nf_opts = sorted({max(1, K // 2), K - 1})
        grid: List[Dict[str, Any]] = []
        if kind == "ivf":
            probes, p = [], 1
            while p < cfg.index.n_lists:
                probes.append(p)
                p *= 4
            probes.append(cfg.index.n_lists)
            for np_ in probes:
                for nf in nf_opts:
                    grid.append({"index.n_probe": np_,
                                 "train.num_fast": nf})
        else:                                            # two-step
            for nf in nf_opts:
                grid.append({"train.num_fast": nf})
                grid.append({"train.num_fast": nf,
                             "index.refine_cap":
                                 max(4 * cfg.serve.topk, 64)})
            grid.append({"train.num_fast": nf_opts[0],
                         "serve.lut_dtype": "int8"})
        # the overlapped crude/refine executor (DESIGN.md §13) is a
        # pure scheduling knob — same results, different wall time — so
        # one candidate at the default operating point is enough for
        # the coarse pass; refinement inherits it if it wins
        grid.append({"serve.pipeline": "tiles"})
        return grid

    def _refine_candidates(self, best_ov: Dict[str, Any]):
        """Local refinement around the coarse winner (faiss-style):
        neighboring n_probe values and num_fast +/- 1."""
        cfg = self.config
        out: List[Dict[str, Any]] = []
        if cfg.index.kind == "ivf":
            np0 = best_ov.get("index.n_probe", cfg.index.n_probe)
            for np_ in sorted({max(1, (3 * np0) // 4),
                               np0 + max(1, np0 // 2)}):
                if 1 <= np_ <= cfg.index.n_lists and np_ != np0:
                    out.append({**best_ov, "index.n_probe": np_})
        if cfg.index.kind != "flat":
            nf0 = best_ov.get("train.num_fast", cfg.train.num_fast)
            for nf in (nf0 - 1, nf0 + 1):
                if 1 <= nf <= cfg.train.num_codebooks - 1 and nf != nf0:
                    out.append({**best_ov, "train.num_fast": nf})
        return out

    def _measure_point(self, ov: Dict[str, Any], base_idx, q_emb,
                       gt_ids, k: int, repeats: int) -> Dict[str, Any]:
        """Recall@k + QPS (min-of-repeats warm timing) for one override
        candidate, served from a ``dataclasses.replace`` of the built
        base index."""
        from repro import eval as eval_mod

        self.config.with_overrides(ov)       # validate the candidate
        repl: Dict[str, Any] = {}
        if "train.num_fast" in ov:
            repl["structure"] = self._tuning_structure(
                ov["train.num_fast"])
        if "index.n_probe" in ov:
            repl["n_probe"] = ov["index.n_probe"]
        if "index.refine_cap" in ov:
            repl["refine_cap"] = ov["index.refine_cap"]
        if "serve.lut_dtype" in ov:
            repl["lut_dtype"] = ov["serve.lut_dtype"]
        if "serve.pipeline" in ov:
            repl["pipeline"] = ov["serve.pipeline"]
        if "serve.pipeline_tile" in ov:
            repl["pipeline_tile"] = ov["serve.pipeline_tile"]
        idx = dataclasses.replace(base_idx, **repl) if repl else base_idx
        # a pipelined index runs a host-level tile loop and owns its
        # own jit/donation boundary — an outer jit would unroll it
        call = (lambda q: idx.search(q, k)) \
            if getattr(idx, "pipeline", "off") != "off" \
            else jax.jit(lambda q: idx.search(q, k))
        r = call(q_emb)                      # compile + warm
        jax.block_until_ready((r.indices, r.distances))
        recall = eval_mod.recall_at_k(np.asarray(r.indices)[:, :k],
                                      gt_ids, k)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            r = call(q_emb)
            jax.block_until_ready((r.indices, r.distances))
            best = min(best, time.perf_counter() - t0)
        qps = q_emb.shape[0] / max(best, 1e-9)
        return {"overrides": dict(ov), "recall": recall, "qps": qps}

    def tune(self, db=None, queries=None, *, target_recall: float = 0.9,
             k: int = 10, grid: Optional[List[Dict[str, Any]]] = None,
             repeats: int = 3, cache_dir: Optional[str] = None,
             key=None, apply: bool = True) -> ICQConfig:
        """Autotune the search-time knobs to ``target_recall`` at max
        QPS and return the tuned ``ICQConfig`` (docs/api.md).

        Measures recall@``k`` against the exact (cached) ground truth
        and warm QPS for a coarse grid of candidates over the knobs the
        configured index kind exposes (n_probe, num_fast, refine_cap,
        lut_dtype), then locally refines around the winner — the
        faiss-style operating-point search.  Selection: the max-QPS
        point with recall >= ``target_recall``; when no candidate
        reaches the target, the max-recall point (the full sweep is
        kept on ``self.last_tune``).

        db:       raw-space database to tune over (None = the fit data,
                  reusing the codes ``fit`` exported).
        queries:  raw-space query sample (required) — recall/QPS are
                  measured on these.
        grid:     explicit override-dict candidates (CI uses a reduced
                  grid); None = the kind's default coarse grid.
        cache_dir:  ground-truth npz cache directory (content-keyed).
        apply:    adopt the tuned config on this session (and re-select
                  the fast set when the winning num_fast differs), so a
                  following ``session.index()`` + ``save`` persist the
                  tuned operating point into Artifacts — a tuned config
                  reloads bitwise like any other.
        """
        if self.model is None:
            raise ConfigError("session.tune() before session.fit(); fit "
                              "a model first (or load artifacts with "
                              "ICQSession.from_artifacts)")
        if queries is None:
            raise ConfigError("session.tune() needs queries= (a raw-space "
                              "query sample to measure recall/QPS on)")
        cfg = self.config
        if db is None:
            codes, emb_db = self.model.codes, self._fit_emb
        else:
            from repro.trainer import encode_database

            emb_db = self.model.embed(jnp.asarray(db))
            codes = encode_database(
                emb_db, self.model.C,
                mode="pq" if self.model.mode == "pq" else "icm",
                icm_iters=cfg.encode.icm_iters, chunk=cfg.encode.chunk,
                backend=cfg.encode.backend)
        from repro import eval as eval_mod

        q_emb = self.model.embed(jnp.asarray(queries))
        gt_ids, _, _ = eval_mod.cached_ground_truth(
            np.asarray(emb_db), np.asarray(q_emb), k,
            cache_dir=cache_dir)
        base_idx = build_index(
            codes, self.model.C, self.model.structure,
            index_cfg=cfg.index, serve_cfg=cfg.serve, emb_db=emb_db,
            key=jax.random.PRNGKey(0) if key is None else key)

        points: List[Dict[str, Any]] = []
        seen = set()

        def measure(ov):
            sig = tuple(sorted(ov.items()))
            if sig in seen:
                return
            seen.add(sig)
            points.append(self._measure_point(ov, base_idx, q_emb,
                                              gt_ids, k, repeats))

        for ov in (grid if grid is not None else self._tune_grid()):
            measure(ov)
        sel, _ = eval_mod.select_operating_point(points, target_recall)
        for ov in self._refine_candidates(points[sel]["overrides"]):
            measure(ov)
        sel, met = eval_mod.select_operating_point(points, target_recall)
        best = points[sel]
        frontier = eval_mod.pareto_frontier(points)
        tuned = cfg.with_overrides(best["overrides"])
        self.last_tune = {
            "points": points,
            "frontier": [points[i] for i in frontier],
            "selected": best, "met_target": met,
            "target_recall": target_recall, "k": k,
        }
        if apply:
            self.config = tuned
            nf = tuned.train.num_fast
            if int(self.model.structure.fast_mask.sum()) != nf:
                self.model.structure = self._tuning_structure(nf)
                self.model.icq_cfg = dataclasses.replace(
                    self.model.icq_cfg, num_fast=nf)
        return tuned

    # ------------------------------------------------------------- save --
    def save(self, path: str) -> str:
        """Persist the fitted model (no index) — ``Searcher.save``
        persists model + index together."""
        if self.model is None:
            raise ConfigError("session.save() before session.fit()")
        return Artifacts(config=self.config, model=self.model).save(path)

    @classmethod
    def from_artifacts(cls, path: str) -> "ICQSession":
        """Rebuild a session (config + fitted model) from saved
        artifacts; ``index()`` then works as after ``fit`` (for a saved
        *index*, prefer ``repro.api.load_ann_engine`` — it skips the
        rebuild and serves the stored index directly)."""
        art = Artifacts.load(path)
        if art.model is None:
            raise ConfigError(
                f"{path}: artifacts hold no model (index-only save); "
                "serve them with repro.api.load_ann_engine instead")
        session = cls(art.config)
        session.model = art.model
        return session


def icq_session(config: ICQConfig) -> ICQSession:
    """Open the front door: validate ``config`` and return an
    ``ICQSession`` (see class docstring for the lifecycle)."""
    return ICQSession(config)
