from repro.configs.base import ArchConfig, ICQConfig, ShapeSpec
from repro.configs.registry import get_config, list_archs, smoke_config
from repro.configs.shapes import SHAPES, shapes_for, skipped_shapes_for

__all__ = [
    "ArchConfig", "ICQConfig", "ShapeSpec",
    "get_config", "list_archs", "smoke_config",
    "SHAPES", "shapes_for", "skipped_shapes_for",
]
