"""Config dataclasses for architectures, shapes, and ICQ hyper-parameters.

Every assigned architecture gets one module in this package exporting
``CONFIG: ArchConfig``.  ``ShapeSpec`` describes one of the four assigned
input shapes.  ``ICQConfig`` carries the paper's quantization
hyper-parameters (codebooks, prior, interleaving penalty).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ICQConfig:
    """Hyper-parameters of Interleaved Composite Quantization (paper §3).

    K codebooks of m codewords over a d-dimensional embedding space; the
    fast group |K_fast| quantizes the learned high-variance subspace psi.
    """
    d: int = 16                  # embedding dim (paper fixes d=16 for synthetic)
    num_codebooks: int = 8       # K
    codebook_size: int = 256     # m  (paper: C_k = 256 -> 8-bit codes)
    num_fast: int = 2            # |K_fast| codebooks for crude comparisons
    # Prior P(Lambda) = pi1*N(0,s1) + pi2*SN(mu2,s2,alpha2)   (paper eq. 4)
    pi1: float = 0.9
    pi2: float = 0.1
    alpha2: float = -10.0        # fixed negative skew (paper §3.3)
    # Loss weights (paper's gamma_1, gamma_2) + CQ inner-product penalty
    gamma_p: float = 0.2         # weight of L^P
    gamma_icq: float = 2.0       # weight of L^ICQ
    gamma_cq: float = 0.1        # weight of the CQ constant-inner-product term
    # Search
    margin_scale: float = 1.0    # scales sigma = sum_{i in psi_bar} lambda_i (eq. 11)
    # Training
    icm_iters: int = 3           # iterated conditional modes rounds for encoding
    learn_embedding: bool = True


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned (input-shape) cell: lowers train_step or serve_step."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"

    @property
    def is_train(self) -> bool:
        return self.kind == "train"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Unified architecture description covering all assigned families."""
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    activation: str = "swiglu"   # swiglu | geglu | gelu
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6

    # ---- MoE ----
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0            # per-expert hidden dim
    first_k_dense: int = 0       # leading dense layers before MoE stack
    dense_d_ff: int = 0          # d_ff used by those dense layers
    router_aux_weight: float = 0.001

    # ---- MLA (DeepSeek) ----
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # ---- SSM (Mamba2 SSD) ----
    ssm: bool = False
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # ---- Hybrid (RecurrentGemma: RG-LRU + local attention) ----
    hybrid: bool = False
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rglru","rglru","local")
    local_window: int = 0
    lru_width: int = 0

    # ---- Encoder-decoder (Whisper) ----
    encdec: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 0     # fixed source length (audio frames)
    learned_pos_emb: bool = False

    # ---- Modality frontend stubs ----
    frontend: str = "none"       # none | audio_stub | vision_stub
    num_vision_tokens: int = 0   # prepended patch-embedding tokens (vlm)
    vision_dim: int = 0

    # ---- Training-time knobs (per-arch defaults, shape-overridable) ----
    remat: bool = True
    remat_block: int = 0               # >0: two-level (sqrt-L) remat blocks
    scan_layers: bool = True
    optimizer_dtype: str = "float32"   # bf16 moments for the largest archs
    grad_accum_dtype: str = "float32"  # microbatch grad accumulator dtype
    microbatch_size: int = 8           # per train-step accumulation slice
    param_dtype: str = "float32"       # bf16 at scale (dry-run overrides)
    compute_dtype: str = "float32"
    attn_chunk: int = 1024             # KV-chunk for online-softmax attention
    moe_dispatch: str = "ragged"       # ragged (1-device) | einsum (GSPMD/EP)
    capacity_factor: float = 1.25      # einsum dispatch capacity
    moe_token_chunk: int = 16384       # dispatch chunk (bounds (E,C,d) bufs)
    ce_chunk: int = 2048               # token-chunked fused head+CE (0 = off)
    seq_shard_acts: bool = False       # Megatron-SP: shard seq dim of the
                                       # residual stream over "model" between
                                       # layers (activation-memory bound)
    vocab_pad: int = 256               # pad embed/head rows to a multiple so
                                       # the vocab dim shards over "model"
                                       # (indivisible vocabs otherwise force
                                       # replicated logits); logits masked/
                                       # sliced back to the true vocab

    # ---- ICQ integration flags ----
    icq_kv: bool = False         # ICQ-quantized KV cache at decode
    icq_grad: bool = False       # ICQ gradient compression across pods

    # ---- long-context policy ----
    supports_long_context: bool = False  # sub-quadratic path for long_500k

    @property
    def padded_vocab(self) -> int:
        p = max(self.vocab_pad, 1)
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def attn_free(self) -> bool:
        return self.ssm

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head), for 6ND."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        emb = V * d
        head = 0 if self.tie_embeddings else V * d
        per_layer = 0
        if self.ssm:
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            # in_proj: z,x,B,C,dt ; out_proj
            conv_dim = d_in + 2 * self.ssm_state
            per_layer = (
                d * (2 * d_in + 2 * self.ssm_state + nheads)
                + conv_dim * self.ssm_conv_width
                + d_in * d + 2 * nheads + d
            )
        else:
            if self.mla:
                qd = self.q_lora_rank or d
                attn = (
                    (d * self.q_lora_rank if self.q_lora_rank else 0)
                    + qd * self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                    + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                    + self.kv_lora_rank * self.num_heads * (self.qk_nope_head_dim + self.v_head_dim)
                    + self.num_heads * self.v_head_dim * d
                )
            else:
                attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            gated = self.activation in ("swiglu", "geglu")
            ff_mult = 3 if gated else 2
            if self.num_experts:
                moe_ff = ff_mult * d * self.moe_d_ff
                ffn = (self.num_experts + self.num_shared_experts) * moe_ff + d * self.num_experts
                dense_ffn = ff_mult * d * (self.dense_d_ff or self.d_ff)
                n_moe = L - self.first_k_dense
                per_layer = attn + (n_moe * ffn + self.first_k_dense * dense_ffn) / L
            else:
                ffn = ff_mult * d * self.d_ff
                per_layer = attn + ffn
            if self.hybrid:
                # average over pattern: rglru blocks replace attention
                lru = self.lru_width or d
                rg = d * lru * 2 + lru * d + 2 * lru * (lru // 16) + 2 * lru  # gates (block-diag) + proj
                n = len(self.block_pattern) or 1
                n_rec = sum(1 for b in self.block_pattern if b == "rglru")
                per_layer = (attn * (n - n_rec) + rg * n_rec) / n + ffn
            per_layer += 2 * d  # norms
        total = emb + head + int(per_layer) * L + d
        if self.encdec:
            total += int(per_layer) * self.encoder_layers  # encoder stack (approx.)
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: routed top-k + shared only)."""
        if not self.num_experts:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        gated = self.activation in ("swiglu", "geglu")
        ff_mult = 3 if gated else 2
        moe_ff = ff_mult * d * self.moe_d_ff
        all_experts = (self.num_experts + self.num_shared_experts) * moe_ff
        active = (self.experts_per_token + self.num_shared_experts) * moe_ff
        n_moe = L - self.first_k_dense
        return self.param_count() - n_moe * (all_experts - active)
