"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,            # MLA: all heads share the compressed latent
    head_dim=128,
    d_ff=1536,                   # routed-expert hidden dim (assignment value)
    vocab_size=102400,
    activation="swiglu",
    num_experts=160,
    num_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1536,
    first_k_dense=1,
    dense_d_ff=12288,
    mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    rope_theta=10000.0,
    optimizer_dtype="bfloat16",
    microbatch_size=2,
    remat_block=10,
    icq_kv=True,                 # composes on the 512-d MLA latent
    icq_grad=True,
)
