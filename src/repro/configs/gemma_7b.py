"""gemma-7b [dense] — GeGLU, head_dim=256, GQA kv=16.  [arXiv:2403.08295; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    activation="geglu",
    tie_embeddings=True,
    rope_theta=10000.0,
    microbatch_size=4,
    remat_block=7,
    icq_kv=True,
    icq_grad=True,
)
