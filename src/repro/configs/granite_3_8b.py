"""granite-3-8b [dense] — GQA kv=8.  [hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    activation="swiglu",
    tie_embeddings=True,
    rope_theta=10000.0,
    microbatch_size=4,
    remat_block=8,
    icq_kv=True,
    icq_grad=True,
)
