"""internvl2-76b [vlm] — InternViT frontend (STUB: input_specs() provides
precomputed patch embeddings) + Llama3-70B-class LM backbone.
[arXiv:2404.16821; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    activation="swiglu",
    rope_theta=500000.0,
    frontend="vision_stub",
    num_vision_tokens=256,       # 256 patch tokens prepended per image
    vision_dim=3200,             # InternViT-6B hidden (projected to d_model)
    optimizer_dtype="bfloat16",
    microbatch_size=2,
    remat_block=10,
    icq_kv=True,
    icq_grad=True,
)
