"""llama3-405b [dense] — GQA kv=8, 128k vocab.  [arXiv:2407.21783; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    activation="swiglu",
    rope_theta=500000.0,
    # bf16 Adam moments: quantized optimizer state so the 405B fits a
    # v5e-256 pod (see DESIGN.md §6 memory budget).
    optimizer_dtype="bfloat16",
    grad_accum_dtype="bfloat16",  # 16 microbatches: ~2-bit loss, -9.5GB/dev
    microbatch_size=1,
    remat_block=14,    # sqrt-L remat: 126 saved carries -> 9+14
    icq_kv=True,
    icq_grad=True,
)
