"""mamba2-1.3b [ssm] — SSD (state-space duality), attn-free.
[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                      # attn-free, no FFN: Mamba2 blocks only
    vocab_size=50280,
    ssm=True,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    microbatch_size=2,
    ssm_chunk=128,
    icq_kv=False,                # no KV cache: inapplicable (DESIGN.md §5)
    icq_grad=True,
    supports_long_context=True,  # O(1) recurrent state -> long_500k runs
)
