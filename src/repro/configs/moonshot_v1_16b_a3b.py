"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64 routed experts top-6
(+2 shared per the Moonlight HF config).  [hf:moonshotai/Moonlight-16B-A3B; hf]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,                   # routed-expert hidden dim (assignment value)
    vocab_size=163840,
    activation="swiglu",
    num_experts=64,
    num_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1408,
    first_k_dense=1,
    dense_d_ff=11264,
    rope_theta=50000.0,
    microbatch_size=4,
    icq_kv=True,
    icq_grad=True,
)
