"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 pattern
(two recurrent blocks per local-attention block), MQA kv=1, window 2048.
[arXiv:2402.19427; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,              # MQA on the local-attention blocks
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    activation="geglu",
    tie_embeddings=True,
    hybrid=True,
    block_pattern=("rglru", "rglru", "local"),
    local_window=2048,
    lru_width=4096,
    rope_theta=10000.0,
    microbatch_size=4,
    icq_kv=False,                # bounded local windows: marginal (DESIGN.md §5)
    icq_grad=True,
    supports_long_context=True,  # bounded window + O(1) LRU state
)
