"""Architecture registry: ``--arch <id>`` lookup for launchers and tests."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig

# arch-id -> module name in this package
_MODULES = {
    "gemma-7b": "gemma_7b",
    "llama3-405b": "llama3_405b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "granite-3-8b": "granite_3_8b",
    "whisper-large-v3": "whisper_large_v3",
    "mamba2-1.3b": "mamba2_1_3b",
    "internvl2-76b": "internvl2_76b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(arch: str) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def smoke_config(arch: str) -> ArchConfig:
    """Reduced config of the same family for CPU smoke tests.

    Small layers/width/experts/vocab; preserves every structural feature
    (GQA ratio, MLA, MoE routing, SSD, hybrid pattern, enc-dec, frontend).
    """
    cfg = get_config(arch)
    repl: Dict = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=64,
        vocab_size=128,
        microbatch_size=2,
        remat=False,
    )
    if cfg.ssm:
        repl.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    else:
        n_heads = max(2, min(cfg.num_heads, 4))
        ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
        n_kv = max(1, n_heads // min(ratio, n_heads))
        repl.update(num_heads=n_heads, num_kv_heads=n_kv, head_dim=16, d_ff=128)
    if cfg.num_experts:
        repl.update(num_experts=8, num_shared_experts=min(cfg.num_shared_experts, 1),
                    experts_per_token=2, moe_d_ff=32, dense_d_ff=128, first_k_dense=min(cfg.first_k_dense, 1))
        repl["num_layers"] = 2
    if cfg.mla:
        repl.update(kv_lora_rank=32, q_lora_rank=48, qk_nope_head_dim=16,
                    qk_rope_head_dim=8, v_head_dim=16)
    if cfg.hybrid:
        repl.update(block_pattern=("rglru", "local"), local_window=32,
                    lru_width=64, num_layers=2)
    if cfg.encdec:
        repl.update(encoder_layers=2, encoder_seq_len=16)
    if cfg.frontend == "vision_stub":
        repl.update(num_vision_tokens=4, vision_dim=48)
    return dataclasses.replace(cfg, **repl)
