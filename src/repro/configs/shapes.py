"""The four assigned input shapes (same set for every LM-family arch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), NOT ``train_step``.  ``long_500k`` requires a
sub-quadratic token-mixing path and only runs for archs with
``supports_long_context=True`` (SSM / hybrid); the skip for pure
full-attention archs is recorded in EXPERIMENTS.md per DESIGN.md §5.
"""
from __future__ import annotations

from repro.configs.base import ShapeSpec

TRAIN_4K = ShapeSpec(name="train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeSpec(name="prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeSpec(name="decode_32k", seq_len=32768, global_batch=128, kind="decode")
LONG_500K = ShapeSpec(name="long_500k", seq_len=524288, global_batch=1, kind="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shapes_for(arch_cfg) -> dict:
    """All shape cells that are runnable for this arch (skips recorded)."""
    out = {}
    for name, spec in SHAPES.items():
        if name == "long_500k" and not arch_cfg.supports_long_context:
            continue
        out[name] = spec
    return out


def skipped_shapes_for(arch_cfg) -> list:
    return [n for n in SHAPES if n not in shapes_for(arch_cfg)]
