"""whisper-large-v3 [audio] — enc-dec transformer backbone; the conv/mel
frontend is a STUB per assignment (input_specs() provides precomputed frame
embeddings).  [arXiv:2212.04356; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,               # decoder layers
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    activation="gelu",
    norm_type="layernorm",
    encdec=True,
    encoder_layers=32,
    encoder_seq_len=1500,        # 30 s audio -> 1500 frames after conv stub
    learned_pos_emb=True,
    frontend="audio_stub",
    microbatch_size=4,
    icq_kv=True,                 # self- and (static) cross-attention caches
    icq_grad=True,
)
