"""ICQ core — the paper's contribution as a composable JAX library.

Layout:
  prior.py      bimodal variance prior P(Lambda) + psi (eqs. 4, 5, 10)
  variance.py   online Welford variance across batches (eq. 9)
  codebooks.py  (K,m,d) codebooks, k-means / residual init, geometry
  encode.py     PQ encode, ICM for additive codes, straight-through
  losses.py     L^E / L^C / L^P / L^ICQ / CQ penalty (eqs. 3, 6)
  icq.py        psi/xi, fast-set selection (eq. 8), margin sigma (eq. 11)
  search.py     thin re-export of the index layer (repro.index, §7)
  train.py      thin re-export of the trainer layer (repro.trainer, §9)
  embed.py      linear / CNN embedding models
  baselines/    PQ, OPQ, CQ, SQ, PQN (adapters over repro.trainer)
"""
from repro.core.train import ICQModel, fit, finalize
from repro.core.icq import ICQStructure, build_structure
from repro.core.search import (SearchResult, adc_search, exact_search,
                               mean_average_precision, recall_at,
                               two_step_search, two_step_search_compact)

__all__ = [
    "ICQModel", "fit", "finalize", "ICQStructure", "build_structure",
    "SearchResult", "adc_search", "exact_search", "two_step_search",
    "two_step_search_compact", "mean_average_precision", "recall_at",
]
