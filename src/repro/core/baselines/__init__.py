"""Baselines the paper compares against (§2, §4).

Unsupervised quantizers: PQ (Jegou et al.), OPQ (Ge et al. — learned
rotation), CQ (Zhang et al. — constant inner-product additive codes).
Supervised pipelines: SQ (Wang et al. — linear embedding + CQ, built on
the shared joint trainer with the ICQ terms disabled) and PQN-style
(Yu et al. — CNN embedding + soft-assign PQ with straight-through).

All return ``core.train.ICQModel`` artifacts so every benchmark calls
one search API.  DQN / DPQ appear in Fig. 4 as literature reference
curves only (numbers from their papers); SQ and PQN are the implemented
comparison systems, exactly as in the paper's own experiments.
"""
from repro.core.baselines.pq import fit_pq
from repro.core.baselines.opq import fit_opq
from repro.core.baselines.cq import fit_cq
from repro.core.baselines.sq import fit_sq
from repro.core.baselines.pqn import fit_pqn

__all__ = ["fit_pq", "fit_opq", "fit_cq", "fit_sq", "fit_pqn"]
