"""Composite Quantization (Zhang, Du, Wang 2014) — thin re-export of
the trainer-layer implementation (``repro.trainer.quantizers``,
DESIGN.md §9).

Additive codebooks with the constant-inner-product constraint, learned
by alternating gradient steps on C (reconstruction + CQ penalty) and ICM
re-encoding.  No embedding model — this is the pure quantizer baseline
used in Fig. 2's SQ+CQ comparison and as ICQ's ablation control.
"""
from __future__ import annotations

from repro.core.train import ICQModel
from repro.trainer.quantizers import CQQuantizer, fit_cq

__all__ = ["ICQModel", "CQQuantizer", "fit_cq"]
