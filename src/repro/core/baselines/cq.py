"""Composite Quantization (Zhang, Du, Wang 2014) — unsupervised.

Additive codebooks with the constant-inner-product constraint, learned
by alternating gradient steps on C (reconstruction + CQ penalty) and ICM
re-encoding.  No embedding model — this is the pure quantizer baseline
used in Fig. 2's SQ+CQ comparison and as ICQ's ablation control.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import codebooks as cb
from repro.core import encode as enc
from repro.core import icq as icq_mod
from repro.core import losses
from repro.core.train import ICQModel
from repro.train.optimizer import AdamW


def fit_cq(key, xs, icq_cfg, *, rounds: int = 10, grad_steps: int = 50,
           lr: float = 5e-3, embed_params=None, embed_apply=None) -> ICQModel:
    apply_fn = embed_apply or (lambda p, x: x)
    emb = apply_fn(embed_params, xs).astype(jnp.float32)
    d = emb.shape[-1]
    C = cb.init_residual(key, emb, icq_cfg.num_codebooks,
                         icq_cfg.codebook_size, iters=10)
    codes = enc.icm_encode(emb, C, icq_cfg.icm_iters)
    opt = AdamW(lr=lambda s: jnp.asarray(lr), weight_decay=0.0, clip_norm=0.0)

    def loss_fn(C, codes):
        rec = cb.decode(C, codes)
        l_rec = jnp.mean(jnp.sum(jnp.square(emb - rec), axis=-1))
        l_cq, _ = losses.cq_penalty(C, codes)
        return l_rec + icq_cfg.gamma_cq * l_cq

    @jax.jit
    def c_steps(C, codes, opt_state):
        def body(carry, _):
            C, opt_state = carry
            g = jax.grad(loss_fn)(C, codes)
            params, opt_state, _ = opt.update({"C": g}, opt_state, {"C": C})
            return (params["C"], opt_state), None
        (C, opt_state), _ = jax.lax.scan(body, (C, opt_state), None,
                                         length=grad_steps)
        return C, opt_state

    encode_jit = jax.jit(lambda e, C, codes: enc.icm_encode(
        e, C, icq_cfg.icm_iters, init_codes=codes))
    opt_state = opt.init({"C": C})
    for _ in range(rounds):
        C, opt_state = c_steps(C, codes, opt_state)
        codes = encode_jit(emb, C, codes)

    structure = icq_mod.ICQStructure(
        xi=jnp.ones((d,), bool),
        fast_mask=jnp.ones((C.shape[0],), bool),
        sigma=jnp.zeros(()))
    return ICQModel(icq_cfg=icq_cfg, embed_params=embed_params,
                    embed_apply=apply_fn, C=C,
                    codes=enc.pack_codes(codes, icq_cfg.codebook_size),
                    structure=structure, lam=jnp.var(emb, axis=0), mode="cq")
