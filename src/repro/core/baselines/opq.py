"""Optimized Product Quantization (Ge et al. 2013) — thin re-export of
the trainer-layer implementation (``repro.trainer.quantizers``,
DESIGN.md §9).

Alternates: (1) PQ in the rotated space R x; (2) rotation update by the
orthogonal Procrustes solution  R = U V^T  from  SVD(X^T Xbar).  The
learned R is folded into the embedding apply so search-side code is
shared with plain PQ.
"""
from __future__ import annotations

from repro.core.train import ICQModel
from repro.trainer.quantizers import OPQQuantizer, fit_opq

__all__ = ["ICQModel", "OPQQuantizer", "fit_opq"]
