"""Optimized Product Quantization (Ge et al. 2013) — non-parametric OPQ.

Alternates: (1) PQ in the rotated space R x; (2) rotation update by the
orthogonal Procrustes solution  R = U V^T  from  SVD(X^T Xbar).  The
learned R is folded into the embedding apply so search-side code is
shared with plain PQ.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import codebooks as cb
from repro.core import encode as enc
from repro.core import icq as icq_mod
from repro.core.train import ICQModel


def fit_opq(key, xs, icq_cfg, *, rounds: int = 8, kmeans_iters: int = 10,
            embed_params=None, embed_apply=None) -> ICQModel:
    base_apply = embed_apply or (lambda p, x: x)
    emb = base_apply(embed_params, xs).astype(jnp.float32)
    d = emb.shape[-1]
    R = jnp.eye(d, dtype=jnp.float32)
    C = None
    for r in range(rounds):
        xr = emb @ R
        C = cb.init_pq(jax.random.fold_in(key, r), xr,
                       icq_cfg.num_codebooks, icq_cfg.codebook_size,
                       kmeans_iters)
        codes = enc.encode_pq(xr, C)
        xbar = cb.decode(C, codes)
        # Procrustes: maximize tr(R^T X^T Xbar)  ->  R = U V^T
        u, s, vt = jnp.linalg.svd(emb.T @ xbar, full_matrices=False)
        R = u @ vt
    xr = emb @ R
    codes = enc.pack_codes(enc.encode_pq(xr, C), icq_cfg.codebook_size)

    ep = {"base": embed_params, "R": R}

    def apply_fn(p, x):
        return base_apply(p["base"], x) @ p["R"]

    structure = icq_mod.ICQStructure(
        xi=jnp.ones((d,), bool),
        fast_mask=jnp.ones((C.shape[0],), bool),
        sigma=jnp.zeros(()))
    return ICQModel(icq_cfg=icq_cfg, embed_params=ep, embed_apply=apply_fn,
                    C=C, codes=codes, structure=structure,
                    lam=jnp.var(xr, axis=0), mode="pq")
