"""Product Quantization (Jegou, Douze, Schmid 2010) — thin re-export of
the trainer-layer implementation (``repro.trainer.quantizers``,
DESIGN.md §9).

Unsupervised: k-means per contiguous subspace; encoding is independent
per codebook; search is one-step ADC over all K tables.
"""
from __future__ import annotations

from repro.core.train import ICQModel
from repro.trainer.quantizers import PQQuantizer, fit_pq

__all__ = ["ICQModel", "PQQuantizer", "fit_pq"]
