"""Product Quantization (Jegou, Douze, Schmid 2010).

Unsupervised: k-means per contiguous subspace; encoding is independent
per codebook; search is one-step ADC over all K tables.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import codebooks as cb
from repro.core import encode as enc
from repro.core import icq as icq_mod
from repro.core.train import ICQModel


def fit_pq(key, xs, icq_cfg, *, kmeans_iters: int = 25,
           embed_params=None, embed_apply=None) -> ICQModel:
    """Fit PQ on raw vectors (or pre-embedded if embed_* given)."""
    apply_fn = embed_apply or (lambda p, x: x)
    emb = apply_fn(embed_params, xs)
    C = cb.init_pq(key, emb, icq_cfg.num_codebooks, icq_cfg.codebook_size,
                   kmeans_iters)
    codes = enc.pack_codes(enc.encode_pq(emb, C), icq_cfg.codebook_size)
    d = emb.shape[-1]
    structure = icq_mod.ICQStructure(
        xi=jnp.ones((d,), bool),
        fast_mask=jnp.ones((C.shape[0],), bool),
        sigma=jnp.zeros(()))
    return ICQModel(icq_cfg=icq_cfg, embed_params=embed_params,
                    embed_apply=apply_fn, C=C, codes=codes,
                    structure=structure, lam=jnp.var(emb, axis=0), mode="pq")
