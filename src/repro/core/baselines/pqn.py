"""Product Quantization Network-style baseline (Yu et al. 2018): CNN
embedding trained end-to-end with soft-assign PQ (straight-through hard
codes) — the shared joint trainer in mode="pq" with the CNN embedder.
Falls back to the linear embedder for flat (non-image) inputs.
"""
from __future__ import annotations

from repro.core.train import ICQModel, fit


def fit_pqn(key, xs, ys, icq_cfg, *, num_classes: int = 10, img_hw=None,
            channels=None, epochs: int = 5, batch_size: int = 256,
            lr: float = 1e-3) -> ICQModel:
    if img_hw is not None:
        return fit(key, xs, ys, icq_cfg, embed_kind="cnn",
                   num_classes=num_classes, img_hw=img_hw, channels=channels,
                   mode="pq", epochs=epochs, batch_size=batch_size, lr=lr)
    return fit(key, xs, ys, icq_cfg, embed_kind="linear",
               num_classes=num_classes, mode="pq", epochs=epochs,
               batch_size=batch_size, lr=lr)
