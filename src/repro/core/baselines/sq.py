"""Supervised Quantization (Wang et al. 2016): learned linear embedding
jointly with CQ codebooks — the shared joint trainer with the ICQ-specific
terms (L^P, L^ICQ) disabled.
"""
from __future__ import annotations

from repro.core.train import ICQModel, fit


def fit_sq(key, xs, ys, icq_cfg, *, num_classes: int = 10, epochs: int = 5,
           batch_size: int = 256, lr: float = 1e-3) -> ICQModel:
    return fit(key, xs, ys, icq_cfg, embed_kind="linear",
               num_classes=num_classes, mode="cq", epochs=epochs,
               batch_size=batch_size, lr=lr)
