"""Codebook containers and initialization for additive/product quantizers.

A quantizer is an array C of shape (K, m, d): K codebooks of m codewords
in R^d.  PQ constrains codebook k to a contiguous d/K slice; ICQ
constrains the *fast* group to the learned subspace psi and the rest to
its complement — with the nonzero coordinates interleaved, not
contiguous (paper §3.1).

Initializers: k-means (Lloyd, matmul-based assignment) for PQ subspaces,
residual k-means for additive codebooks (each codebook fit on the
residual of the previous ones — the standard CQ/AQ warm start).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------- k-means ----

def kmeans_assign(x, cent):
    """Nearest-centroid ids.  x: (n,d), cent: (m,d) -> (n,) int32.

    Matmul formulation (MXU-friendly): argmin_m ||x||^2 - 2 x.c + ||c||^2;
    the ||x||^2 term is constant in m and dropped.
    """
    scores = -2.0 * x @ cent.T + jnp.sum(jnp.square(cent), axis=-1)[None, :]
    return jnp.argmin(scores, axis=-1).astype(jnp.int32)


def kmeans_update(x, ids, m: int):
    """Mean of assigned points per centroid; empty centroids keep position 0
    count guard (caller re-seeds)."""
    d = x.shape[-1]
    sums = jnp.zeros((m, d), jnp.float32).at[ids].add(x.astype(jnp.float32))
    cnts = jnp.zeros((m,), jnp.float32).at[ids].add(1.0)
    return sums / jnp.maximum(cnts, 1.0)[:, None], cnts


def kmeans(key, x, m: int, iters: int = 25):
    """Lloyd's k-means.  Returns (centroids (m,d), ids (n,)).

    Empty clusters are re-seeded to the points currently farthest from
    their centroid (standard fix; keeps m effective codewords).
    """
    n = x.shape[0]
    x = jnp.asarray(x, jnp.float32)
    init_ids = jax.random.choice(key, n, (m,), replace=False)
    cent0 = x[init_ids]

    def body(cent, k):
        ids = kmeans_assign(x, cent)
        new, cnts = kmeans_update(x, ids, m)
        # re-seed empties at far points
        d2 = jnp.sum(jnp.square(x - cent[ids]), axis=-1)
        far = jnp.argsort(-d2)[:m]
        new = jnp.where((cnts > 0)[:, None], new, x[far])
        return new, None

    cent, _ = jax.lax.scan(body, cent0, jnp.arange(iters))
    return cent, kmeans_assign(x, cent)


# --------------------------------------------------------- initializers ----

def init_pq(key, x, num_codebooks: int, m: int, iters: int = 25):
    """PQ init: k-means per contiguous subspace, embedded back into R^d.

    Returns C: (K, m, d) with codebook k nonzero only on its slice.
    """
    n, d = x.shape
    K = num_codebooks
    assert d % K == 0, (d, K)
    sub = d // K
    cbs = []
    for k in range(K):
        xs = x[:, k * sub: (k + 1) * sub]
        cent, _ = kmeans(jax.random.fold_in(key, k), xs, m, iters)
        full = jnp.zeros((m, d), jnp.float32)
        full = full.at[:, k * sub: (k + 1) * sub].set(cent)
        cbs.append(full)
    return jnp.stack(cbs)


def init_residual(key, x, num_codebooks: int, m: int, iters: int = 25,
                  mask=None):
    """Residual k-means init for additive codebooks (CQ/ICQ warm start).

    ``mask``: optional (K, d) 0/1 — support constraint per codebook (ICQ:
    fast codebooks masked to psi, slow to the complement).  Each codebook
    is fit on the (masked) residual of the previous ones.
    """
    n, d = x.shape
    res = x.astype(jnp.float32)
    cbs = []
    for k in range(num_codebooks):
        tgt = res * mask[k][None, :] if mask is not None else res
        cent, ids = kmeans(jax.random.fold_in(key, 101 + k), tgt, m, iters)
        if mask is not None:
            cent = cent * mask[k][None, :]
        cbs.append(cent)
        res = res - cent[ids]
    return jnp.stack(cbs)


# ------------------------------------------------------------ geometry ----

def codeword_sq_norms(C):
    """||c||^2 per codeword.  C: (K,m,d) -> (K,m)."""
    return jnp.sum(jnp.square(C), axis=-1)


def cross_gram(C):
    """Pairwise codeword inner products between codebooks.

    C: (K,m,d) -> G: (K,K,m,m) with G[j,k] = C_j @ C_k^T.  Used by ICM
    encoding (the cross-codebook interaction term) and the CQ penalty.
    """
    return jnp.einsum("jmd,knd->jkmn", C, C)


def decode(C, codes):
    """Decode codes (n,K) against C (K,m,d) -> (n,d)."""
    K = C.shape[0]
    parts = [C[k][codes[:, k]] for k in range(K)]
    return sum(parts)


def quantization_mse(x, C, codes):
    """Mean squared quantization error ||x - decode(codes)||^2 / n."""
    return jnp.mean(jnp.sum(jnp.square(x - decode(C, codes)), axis=-1))
