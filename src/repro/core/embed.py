"""Embedding models W for the quantization pipelines.

- ``linear``: the SQ-style learned linear map R^{d_raw} -> R^d (Wang et
  al. 2016) with an auxiliary classifier head for L^E.
- ``cnn``: a LeNet-style convolutional embedder for image-shaped data
  (the PQN comparison uses CNN embeddings; paper §4.2).  Built on
  ``lax.conv_general_dilated`` — no external NN library.

Both expose  init(key, ...) -> params  and  apply(params, x) -> emb,
plus ``classify(params, emb)`` for the classification loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import nn


# ---------------------------------------------------------------- linear ----

def linear_init(key, d_raw: int, d: int, num_classes: int):
    k1, k2 = jax.random.split(key)
    return {
        "w": nn.dense_init(k1, d_raw, d),
        "b": jnp.zeros((d,), jnp.float32),
        "cls": nn.dense_init(k2, d, num_classes),
    }


def linear_apply(params, x):
    return x @ params["w"] + params["b"]


# ------------------------------------------------------------------- cnn ----

def _conv_init(key, h, w, cin, cout):
    fan_in = h * w * cin
    return (jax.random.normal(key, (h, w, cin, cout), jnp.float32)
            / jnp.sqrt(fan_in))


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_init(key, img_hw: int, channels: int, d: int, num_classes: int,
             width: int = 32):
    """LeNet-style: conv-pool-conv-pool-dense -> d-dim embedding."""
    ks = jax.random.split(key, 5)
    flat = (img_hw // 4) * (img_hw // 4) * (2 * width)
    return {
        "c1": _conv_init(ks[0], 5, 5, channels, width),
        "b1": jnp.zeros((width,), jnp.float32),
        "c2": _conv_init(ks[1], 5, 5, width, 2 * width),
        "b2": jnp.zeros((2 * width,), jnp.float32),
        "fc": nn.dense_init(ks[2], flat, d),
        "fcb": jnp.zeros((d,), jnp.float32),
        "cls": nn.dense_init(ks[3], d, num_classes),
    }


def cnn_apply(params, x):
    """x: (n, H, W, C) float -> (n, d)."""
    h = jax.nn.relu(_conv(x, params["c1"]) + params["b1"])
    h = _pool(h)
    h = jax.nn.relu(_conv(h, params["c2"]) + params["b2"])
    h = _pool(h)
    h = h.reshape(h.shape[0], -1)
    return h @ params["fc"] + params["fcb"]


def classify(params, emb):
    return emb @ params["cls"]


def build_embedder(kind: str, key, *, d_raw=None, d=16, num_classes=10,
                   img_hw=None, channels=None):
    """Factory.  kind: 'linear' | 'cnn' | 'identity'."""
    if kind == "linear":
        params = linear_init(key, d_raw, d, num_classes)
        return params, linear_apply
    if kind == "cnn":
        params = cnn_init(key, img_hw, channels, d, num_classes)
        return params, cnn_apply
    if kind == "identity":
        k2 = jax.random.fold_in(key, 1)
        params = {"cls": nn.dense_init(k2, d, num_classes)}
        return params, lambda p, x: x
    raise ValueError(kind)
