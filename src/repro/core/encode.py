"""Encoding: map embeddings to discrete codes against the codebooks.

PQ encode is independent per codebook (orthogonal supports).  Additive
codes (CQ / ICQ) interact, so we use Iterated Conditional Modes (ICM):
cyclically re-choose codebook k's codeword holding the others fixed.

``icm_encode`` is the tiled encoding engine (DESIGN.md §9): it follows
the same ``jnp | pallas | auto`` backend dispatch as the search engines.
Both backends run the *residual* recurrence — carry the current
reconstruction, and per codebook k score

    argmin_j  ||c_{k,j}||^2 - 2 <x - r_k, c_{k,j}>,
    r_k = recon - c_{k, b_k}   (the others-only partial sum)

one (n, d) x (d, m) matmul per codebook, never materializing the
(K, K, m, m) cross-Gram or the (K, n, m) query tensor of the seed
formulation (kept as the oracle, ``kernels/ref.py::icm_encode_gram``);
``point_chunk`` bounds the jnp working set for database-sized inputs.
The interaction term <r, c_{k,j}> is exactly the summed Gram row, so
the per-step objective is identical and every sweep is non-increasing.

``soft_assign`` is the differentiable (softmax) relaxation used during
joint training, with straight-through hard codes for the forward pass.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import codebooks as cb


def encode_pq(x, C):
    """Independent per-codebook nearest codeword (exact for orthogonal
    supports).  x: (n,d), C: (K,m,d) -> (n,K) int32."""
    # scores[k]: (n, m) = -2 x C_k^T + ||c||^2
    sq = cb.codeword_sq_norms(C)                             # (K,m)
    scores = -2.0 * jnp.einsum("nd,kmd->knm", x, C) + sq[:, None, :]
    return jnp.argmin(scores, axis=-1).T.astype(jnp.int32)   # (n,K)


def _icm_block_jnp(x, C, sq, codes, iters: int):
    """Residual-formulation ICM sweeps over one point block.

    x (n, d) f32, codes (n, K) int32 warm start -> (n, K) int32.  The
    recurrence and operation order mirror the pallas kernel
    (``kernels/icm_encode.py``) exactly, so both backends assign the
    same codes."""
    recon = cb.decode(C, codes)                              # (n, d)

    def sweep(carry, _):
        def step(carry, k):
            codes, recon = carry
            Ck = C[k]                                        # (m, d)
            bk = jax.lax.dynamic_index_in_dim(codes, k, axis=1,
                                              keepdims=False)
            r = recon - jnp.take(Ck, bk, axis=0)
            scores = sq[k][None, :] - 2.0 * (x - r) @ Ck.T   # (n, m)
            new = jnp.argmin(scores, axis=-1).astype(jnp.int32)
            codes = jax.lax.dynamic_update_slice_in_dim(
                codes, new[:, None], k, axis=1)
            return (codes, r + jnp.take(Ck, new, axis=0)), None

        carry, _ = jax.lax.scan(step, carry, jnp.arange(C.shape[0]))
        return carry, None

    (codes, _), _ = jax.lax.scan(sweep, (codes, recon), None, length=iters)
    return codes


def icm_encode(x, C, iters: int = 3, init_codes=None, *,
               backend: str = "auto", point_chunk: Optional[int] = None,
               block_n: int = 1024, interpret=None):
    """ICM encoding for additive codebooks.  x: (n,d) -> codes (n,K)
    int32 (the tiled encoding engine, DESIGN.md §9).

    Warm-started from the independent (PQ-style) assignment unless
    ``init_codes`` given.  Each sweep visits codebooks in order;
    ``iters`` full sweeps (paper uses a small constant, cfg.icm_iters).

    backend:      "jnp" | "pallas" | "auto" (pallas on TPU) — the same
                  dispatch as the search engines; both backends run the
                  identical residual recurrence and assign identical
                  codes (``kernels/ref.py::icm_encode_gram`` is the
                  seed-formulation oracle).
    point_chunk:  optional working-set bound for the jnp engine and the
                  warm start: points are processed in zero-padded
                  blocks of this size via ``lax.map`` (pad rows sliced
                  off; encoding is per-point independent, so chunking
                  never changes a point's codes).
    block_n:      pallas point-tile size.
    interpret:    pallas interpret-mode override (defaults off-TPU).
    """
    from repro.index.base import resolve_backend

    be = resolve_backend(backend)
    n = x.shape[0]
    K = C.shape[0]
    sq = cb.codeword_sq_norms(C)

    def encode_block(args):
        xb, cb0 = args
        codes0 = encode_pq(xb, C) if init_codes is None else cb0
        if be == "pallas":
            from repro.kernels.icm_encode import icm_encode_pallas
            it = (jax.default_backend() != "tpu" if interpret is None
                  else interpret)
            return icm_encode_pallas(xb, codes0, C, iters=iters,
                                     block_n=block_n, interpret=it)
        return _icm_block_jnp(xb, C, sq, codes0, iters)

    codes0_all = (jnp.zeros((n, K), jnp.int32) if init_codes is None
                  else init_codes.astype(jnp.int32))
    if point_chunk is None or n <= point_chunk:
        return encode_block((x, codes0_all))
    pad = (-n) % point_chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))
    cp = jnp.pad(codes0_all, ((0, pad), (0, 0)))
    blocks = (xp.reshape(-1, point_chunk, x.shape[1]),
              cp.reshape(-1, point_chunk, K))
    out = jax.lax.map(encode_block, blocks)
    return out.reshape(-1, K)[:n]


def soft_assign(x, C, tau: float = 1.0):
    """Differentiable assignment: softmax(-dist/tau) per codebook.

    Returns (probs (K,n,m), hard codes (n,K)).  The straight-through
    reconstruction is built in ``st_decode``.
    """
    sq = cb.codeword_sq_norms(C)
    scores = -2.0 * jnp.einsum("nd,kmd->knm", x, C) + sq[:, None, :]
    probs = jax.nn.softmax(-scores / tau, axis=-1)
    hard = jnp.argmin(scores, axis=-1).T.astype(jnp.int32)
    return probs, hard


def st_decode(x, C, tau: float = 1.0):
    """Straight-through decode: forward = hard reconstruction, backward =
    soft (differentiable wrt both x and C).  Returns (xbar, codes)."""
    probs, hard = soft_assign(x, C, tau)
    soft_rec = jnp.einsum("knm,kmd->nd", probs, C)
    hard_rec = cb.decode(C, hard)
    xbar = soft_rec + jax.lax.stop_gradient(hard_rec - soft_rec)
    return xbar, hard


def pack_codes(codes, m: int):
    """Compress int32 codes to the narrowest unsigned dtype that fits m
    (uint8 for m <= 256, uint16 for m <= 65536).  Both packed widths are
    accepted end-to-end by the search engines — codes widen to int32 at
    the LUT-sum / kernel boundary (``tests/test_trainer.py`` keeps the
    uint16 path covered)."""
    if m <= 256:
        return codes.astype(jnp.uint8)
    if m <= 65536:
        return codes.astype(jnp.uint16)
    return codes.astype(jnp.int32)


def unpack_codes(codes):
    return codes.astype(jnp.int32)


def pack_nibbles(codes, K: int):
    """Pack 4-bit codes two-per-byte along the codebook axis (the
    ``code_bits=4`` storage format, DESIGN.md §12).

    codes: (..., K) integer codes with every value < 16 -> (..., ceil(K/2))
    uint8 where byte kp holds codebook 2*kp in its low nibble and
    codebook 2*kp+1 in its high nibble.  Odd K is padded with one
    sentinel column (value 0) in the final byte's high nibble; the
    sentinel never reaches ``lut_sum`` — ``unpack_nibbles`` slices it
    off, and the fast-scan kernels give it an all-zero LUT column.

    The round trip ``unpack_nibbles(pack_nibbles(c, K), K) == c`` is
    exact for any valid codes, mirroring the uint8/uint16
    ``pack_codes``/``unpack_codes`` contract.
    """
    if K != codes.shape[-1]:
        raise ValueError(f"pack_nibbles: codes have {codes.shape[-1]} "
                         f"codebooks, got K={K}")
    c = codes.astype(jnp.int32)
    if K % 2:
        pad = [(0, 0)] * (c.ndim - 1) + [(0, 1)]
        c = jnp.pad(c, pad)                       # sentinel column = 0
    lo = c[..., 0::2]
    hi = c[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles(packed, K: int):
    """Inverse of ``pack_nibbles``: (..., ceil(K/2)) uint8 -> (..., K)
    int32, dropping the sentinel column when K is odd."""
    p = packed.astype(jnp.int32)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    codes = jnp.stack([lo, hi], axis=-1).reshape(*p.shape[:-1],
                                                 2 * p.shape[-1])
    return codes[..., :K]
