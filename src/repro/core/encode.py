"""Encoding: map embeddings to discrete codes against the codebooks.

PQ encode is independent per codebook (orthogonal supports).  Additive
codes (CQ / ICQ) interact, so we use Iterated Conditional Modes (ICM):
cyclically re-choose codebook k's codeword holding the others fixed.
With the cross-Gram blocks G[j,k] = C_j C_k^T precomputed, the per-point
objective for codebook k is

    argmin_j  ||c_{k,j}||^2 - 2 x.c_{k,j} + 2 sum_{k'!=k} <c_{k',b_{k'}}, c_{k,j}>

— a gather of Gram rows plus one (n,d)x(d,m) matmul: MXU-friendly, no
data-dependent branching (DESIGN.md §3).

``soft_assign`` is the differentiable (softmax) relaxation used during
joint training, with straight-through hard codes for the forward pass.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import codebooks as cb


def encode_pq(x, C):
    """Independent per-codebook nearest codeword (exact for orthogonal
    supports).  x: (n,d), C: (K,m,d) -> (n,K) int32."""
    # scores[k]: (n, m) = -2 x C_k^T + ||c||^2
    sq = cb.codeword_sq_norms(C)                             # (K,m)
    scores = -2.0 * jnp.einsum("nd,kmd->knm", x, C) + sq[:, None, :]
    return jnp.argmin(scores, axis=-1).T.astype(jnp.int32)   # (n,K)


def icm_encode(x, C, iters: int = 3, init_codes=None):
    """ICM encoding for additive codebooks.  x: (n,d) -> codes (n,K).

    Warm-started from the independent (PQ-style) assignment unless
    ``init_codes`` given.  Each sweep visits codebooks in order; `iters`
    full sweeps (paper uses a small constant, cfg.icm_iters).
    """
    n, d = x.shape
    K, m, _ = C.shape
    sq = cb.codeword_sq_norms(C)                             # (K,m)
    xc = jnp.einsum("nd,kmd->knm", x, C)                     # (K,n,m)
    G = cb.cross_gram(C)                                     # (K,K,m,m)
    codes = encode_pq(x, C) if init_codes is None else init_codes

    def sweep(codes, _):
        def step(codes, k):
            # interaction: sum over k'!=k of G[k', k][codes[:,k']]
            # gather rows: G[kp,k] is (m,m); codes[:,kp] selects (n,m)
            def one(kp):
                return G[kp, k][codes[:, kp]]                # (n,m)
            inter = jnp.sum(jax.vmap(one)(jnp.arange(K)), axis=0) - one(k)
            scores = sq[k][None, :] - 2.0 * xc[k] + 2.0 * inter
            new_k = jnp.argmin(scores, axis=-1).astype(jnp.int32)
            return codes.at[:, k].set(new_k), None

        codes, _ = jax.lax.scan(step, codes, jnp.arange(K))
        return codes, None

    codes, _ = jax.lax.scan(sweep, codes, jnp.arange(iters))
    return codes


def soft_assign(x, C, tau: float = 1.0):
    """Differentiable assignment: softmax(-dist/tau) per codebook.

    Returns (probs (K,n,m), hard codes (n,K)).  The straight-through
    reconstruction is built in ``st_decode``.
    """
    sq = cb.codeword_sq_norms(C)
    scores = -2.0 * jnp.einsum("nd,kmd->knm", x, C) + sq[:, None, :]
    probs = jax.nn.softmax(-scores / tau, axis=-1)
    hard = jnp.argmin(scores, axis=-1).T.astype(jnp.int32)
    return probs, hard


def st_decode(x, C, tau: float = 1.0):
    """Straight-through decode: forward = hard reconstruction, backward =
    soft (differentiable wrt both x and C).  Returns (xbar, codes)."""
    probs, hard = soft_assign(x, C, tau)
    soft_rec = jnp.einsum("knm,kmd->nd", probs, C)
    hard_rec = cb.decode(C, hard)
    xbar = soft_rec + jax.lax.stop_gradient(hard_rec - soft_rec)
    return xbar, hard


def pack_codes(codes, m: int):
    """Compress int32 codes to the narrowest unsigned dtype that fits m."""
    if m <= 256:
        return codes.astype(jnp.uint8)
    if m <= 65536:
        return codes.astype(jnp.uint16)
    return codes.astype(jnp.int32)


def unpack_codes(codes):
    return codes.astype(jnp.int32)
