"""ICQ structural logic: the psi subspace, codebook clustering, and the
fast-set selection (paper eqs. 5, 7, 8) plus the serving-time hard
projection.

During training the interleaving constraint is *soft* (L^ICQ); before
serving we (a) decide the fast set K_fast by eq. 8 — a codebook is fast
iff every codeword has more energy inside psi than outside — and
(b) optionally hard-project codebooks onto their side of the split so
the crude distance over the fast group is *exactly* the distance in psi
(makes eq. 2's margin interpretation exact).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import prior as prior_mod


class ICQStructure(NamedTuple):
    xi: jnp.ndarray          # (d,) bool — psi membership per dimension
    fast_mask: jnp.ndarray   # (K,) bool — codebook in the fast group
    sigma: jnp.ndarray       # scalar margin (eq. 11): variance mass outside psi


def compute_xi(lam, theta, icq_cfg, *, min_dims: int = 1):
    """xi from the learned prior (eq. 5/7); guarded so |psi| >= min_dims
    and |psi| < d (degenerate splits would disable the two-step search)."""
    xi = prior_mod.psi_mask(lam, theta, pi1=icq_cfg.pi1, pi2=icq_cfg.pi2,
                            alpha2=icq_cfg.alpha2)
    size = jnp.sum(xi)
    fallback = prior_mod.psi_mask_topk(lam, min_dims)
    xi = jnp.where((size < min_dims) | (size >= lam.shape[-1]), fallback, xi)
    return xi


def codebook_energies(C, xi):
    """Per-codebook energy inside/outside psi.  Returns (in_e, out_e): (K, m)."""
    xi = xi.astype(C.dtype)
    in_e = jnp.sum(jnp.square(C) * xi[None, None, :], axis=-1)
    out_e = jnp.sum(jnp.square(C) * (1.0 - xi)[None, None, :], axis=-1)
    return in_e, out_e


def fast_set(C, xi):
    """Eq. 8: codebook k is fast iff every codeword has out-energy < in-energy."""
    in_e, out_e = codebook_energies(C, xi)
    return jnp.all(out_e < in_e, axis=-1)                    # (K,)


def fast_set_topk(C, xi, num_fast: int):
    """Deterministic fallback: the num_fast codebooks with the largest
    in-psi energy fraction.  Guarantees |K_fast| = num_fast even when the
    soft constraint hasn't fully separated the groups."""
    in_e, out_e = codebook_energies(C, xi)
    frac = jnp.sum(in_e, axis=-1) / (jnp.sum(in_e + out_e, axis=-1) + 1e-12)
    order = jnp.argsort(-frac)
    mask = jnp.zeros((C.shape[0],), bool).at[order[:num_fast]].set(True)
    return mask


def project_codebooks(C, xi, fast_mask):
    """Hard interleave: zero fast codebooks outside psi and slow codebooks
    inside psi.  After this, fast/slow groups are exactly orthogonal and
    crude distances decompose (DESIGN.md §3)."""
    xi = xi.astype(C.dtype)
    keep = jnp.where(fast_mask[:, None], xi[None, :], (1.0 - xi)[None, :])
    return C * keep[:, None, :]


def margin_sigma(lam, xi, scale: float = 1.0):
    """Eq. 11: sigma ~ sum of variances outside psi, scaled.

    This bounds (in expectation) the crude-distance error from ignoring
    the slow codebooks, and is the slack used in the eq. 2 comparison.
    """
    return scale * jnp.sum(lam * (1.0 - xi.astype(lam.dtype)))


def build_structure(C, lam, theta, icq_cfg) -> ICQStructure:
    """One-stop: xi from the prior, fast set (eq. 8 with top-k fallback),
    margin sigma (eq. 11)."""
    xi = compute_xi(lam, theta, icq_cfg,
                    min_dims=max(1, icq_cfg.d // icq_cfg.num_codebooks))
    mask = fast_set(C, xi)
    want = icq_cfg.num_fast
    mask = jnp.where(jnp.sum(mask) == want, mask, fast_set_topk(C, xi, want))
    return ICQStructure(xi=xi, fast_mask=mask,
                        sigma=margin_sigma(lam, xi, icq_cfg.margin_scale))
