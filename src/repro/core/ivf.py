"""IVF x ICQ composition — thin re-export of ``repro.index.ivf``
(DESIGN.md §7).

The per-query ``lax.map`` formulation this module used to hold was
retired in favor of the batched candidate-gather engine; it survives as
the oracle/baseline ``kernels/ref.py::ivf_two_step_search_looped``.
``ivf_two_step_search`` keeps its call signature (now with the
``backend`` / ``refine_cap`` engine options of the unified dispatch).
"""
from __future__ import annotations

from repro.index.ivf import (IVFIndex, IVFTwoStep, build_ivf,  # noqa: F401
                             ivf_two_step_search)

__all__ = ["IVFIndex", "IVFTwoStep", "build_ivf", "ivf_two_step_search"]
