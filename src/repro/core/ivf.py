"""IVF (inverted-file) coarse partitioning composed with ICQ — the
beyond-paper extension production ANN systems (FAISS/ScaNN-style) layer
on top of any quantizer.

A coarse k-means splits the database into ``n_lists`` cells; a query
visits only the ``n_probe`` nearest cells and runs the ICQ two-step
search over those candidates.  Ops per query drop by another
~n_lists/n_probe on top of ICQ's crude-test pruning; the paper's
Average-Ops metric generalizes to

    ops = coarse_scan (n_lists dots) / n
          + probed_frac * (|K_fast| + pass_rate * (K - |K_fast|))

Static shapes for TPU: lists are padded to the max list length (pad id
-1, masked) — the memory overhead is the classic IVF imbalance factor,
reported by ``build_ivf``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import codebooks as cb
from repro.core import search as srch


class IVFIndex(NamedTuple):
    centroids: jnp.ndarray       # (n_lists, d)
    lists: jnp.ndarray           # (n_lists, max_len) int32 db ids, -1 pad
    list_lens: jnp.ndarray       # (n_lists,)
    imbalance: float             # max_len / (n / n_lists)


def build_ivf(key, emb_db, n_lists: int, kmeans_iters: int = 20) -> IVFIndex:
    cent, ids = cb.kmeans(key, emb_db, n_lists, iters=kmeans_iters)
    import numpy as np
    ids_np = np.asarray(ids)
    buckets = [np.where(ids_np == l)[0] for l in range(n_lists)]
    max_len = max(max(len(b) for b in buckets), 1)
    lists = np.full((n_lists, max_len), -1, np.int32)
    for l, b in enumerate(buckets):
        lists[l, : len(b)] = b
    lens = np.asarray([len(b) for b in buckets], np.int32)
    n = emb_db.shape[0]
    return IVFIndex(centroids=cent, lists=jnp.asarray(lists),
                    list_lens=jnp.asarray(lens),
                    imbalance=float(max_len / max(n / n_lists, 1)))


def ivf_two_step_search(queries, codes, C, structure, ivf: IVFIndex,
                        topk: int, n_probe: int):
    """IVF + ICQ two-step.  Returns core.search.SearchResult with the
    generalized ops accounting."""
    K = C.shape[0]
    fast = structure.fast_mask
    sigma = structure.sigma
    kf = jnp.sum(fast.astype(jnp.float32))
    n_lists, max_len = ivf.lists.shape
    n = codes.shape[0]

    def one(q):
        # coarse probe: nearest n_probe centroids
        d2c = (jnp.sum(jnp.square(ivf.centroids - q[None]), axis=-1))
        _, probes = jax.lax.top_k(-d2c, n_probe)             # (n_probe,)
        cand_ids = ivf.lists[probes].reshape(-1)             # (n_probe*max_len,)
        valid = cand_ids >= 0
        safe_ids = jnp.where(valid, cand_ids, 0)
        cand_codes = codes[safe_ids]                         # (nc, K)

        lut = srch.build_lut(q, C)
        crude = srch.lut_sum(lut, cand_codes, fast)
        crude = jnp.where(valid, crude, jnp.inf)
        neg_c, boot = jax.lax.top_k(-crude, topk)
        full_boot = srch.lut_sum(lut, cand_codes[boot])
        far = jnp.argmax(jnp.where(jnp.isfinite(-neg_c), full_boot, -jnp.inf))
        t = crude[boot[far]]
        passed = crude < t + sigma                           # eq. 2
        slow = srch.lut_sum(lut, cand_codes, ~fast)
        ranked = jnp.where(passed & valid, crude + slow, jnp.inf)
        neg, idx = jax.lax.top_k(-ranked, topk)
        n_cand = jnp.sum(valid.astype(jnp.float32))
        n_pass = jnp.sum((passed & valid).astype(jnp.float32))
        return safe_ids[idx], -neg, n_cand, n_pass

    ids, dist, n_cand, n_pass = jax.lax.map(one, queries)
    probed_frac = jnp.mean(n_cand) / n
    pass_rate = jnp.mean(n_pass) / jnp.maximum(jnp.mean(n_cand), 1.0)
    coarse = n_lists / n                                     # dots per point
    avg_ops = coarse * K / 2 + probed_frac * (kf + pass_rate * (K - kf))
    # (coarse dots cost ~d mults each ~ K/2 LUT-adds-equivalent at m=2d)
    return srch.SearchResult(ids, dist, avg_ops, pass_rate)
