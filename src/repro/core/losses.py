"""The four loss terms of the ICQ objective (paper §3.1):

    min_{W,C,Theta}  L^E + L^C + gamma1 * L^P + gamma2 * L^ICQ

L^E  — embedding accuracy (classification CE or triplet);
L^C  — quantization error (straight-through additive reconstruction),
       plus the CQ constant-inner-product penalty when requested;
L^P  — prior NLL over the variance vector (see core.prior);
L^ICQ— the interleaving penalty (eq. 6): per codeword, the product of its
       energy inside psi and outside psi must vanish, i.e. every codeword
       commits to one side of the split.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import encode as enc


def classification_loss(logits, labels):
    """Softmax cross-entropy.  logits: (n, classes), labels: (n,)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - ll)


def triplet_loss(anchor, positive, negative, margin: float = 1.0):
    """PQN-style triplet loss on embeddings (n, d)."""
    d_ap = jnp.sum(jnp.square(anchor - positive), axis=-1)
    d_an = jnp.sum(jnp.square(anchor - negative), axis=-1)
    return jnp.mean(jnp.maximum(d_ap - d_an + margin, 0.0))


def quantization_loss(x, C, tau: float = 1.0):
    """L^C: mean ||x - xbar||^2 with straight-through decode — gradients
    reach both the embeddings and the codebooks."""
    xbar, codes = enc.st_decode(x, C, tau)
    return jnp.mean(jnp.sum(jnp.square(x - xbar), axis=-1)), codes


def cq_penalty(C, codes, eps_target=None):
    """Composite-Quantization constraint: the cross-codebook inner-product
    sum should be a *constant* over the dataset (Zhang et al. 2014) so
    that ||q - xbar||^2 ranks identically to the LUT-sum distance.

    Penalizes the batch variance of  s_i = sum_{j != k} <c_j,b_ij, c_k,b_ik>
    around its (learned or running) mean; returns (penalty, batch mean).
    """
    sel = _selected(C, codes)                                # (n,K,d)
    tot = jnp.sum(sel, axis=1)                               # (n,d)
    sq_sum = jnp.sum(jnp.square(sel), axis=(1, 2))           # sum_k ||c_k||^2
    cross = jnp.sum(jnp.square(tot), axis=-1) - sq_sum       # (n,)
    mean = jnp.mean(cross) if eps_target is None else eps_target
    return jnp.mean(jnp.square(cross - mean)), jnp.mean(cross)


def _selected(C, codes):
    """Gather selected codewords: (n, K, d)."""
    K = C.shape[0]
    return jnp.stack([C[k][codes[:, k]] for k in range(K)], axis=1)


def icq_loss(C, xi):
    """L^ICQ (eq. 6): sum over codewords of ||c o xi|| * ||c o (1-xi)||.

    xi: (d,) in [0,1] (hard 0/1 at serving; a soft relaxation is allowed
    during training — the paper treats this as a soft constraint).
    Normalized per codeword by ||c|| so the penalty is scale-free.
    """
    xi = xi.astype(jnp.float32)
    in_e = jnp.sqrt(jnp.sum(jnp.square(C) * xi[None, None, :], axis=-1) + 1e-12)
    out_e = jnp.sqrt(jnp.sum(jnp.square(C) * (1.0 - xi)[None, None, :], axis=-1) + 1e-12)
    norm = jnp.sum(jnp.square(C), axis=-1) + 1e-12
    return jnp.mean(in_e * out_e / norm)
