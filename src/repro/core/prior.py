"""Bimodal variance prior P(Lambda) = pi1*N(0,s1) + pi2*SN(mu2,s2,alpha2).

Paper §3.1 (eq. 4) + robustified loss (§3.3, eq. 10).  The prior is a
product over dimensions; minimizing its negative log-likelihood drives
most per-dimension variances toward the zero-centered major mode and a
few toward the negative-skew minor mode located near max(Lambda) — this
is what concentrates dataset variance into the small subspace psi used
for crude distance comparisons.

Trainable parameters Theta = {sigma1, sigma2, mu2} are stored as raw
(unconstrained) values and mapped through softplus for positivity;
alpha2, pi1, pi2 are fixed per §3.3.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

_LOG_2PI = 1.8378770664093453  # log(2*pi)
_EPS = 1e-12


def _softplus(x):
    return jax.nn.softplus(x)


def _inv_softplus(y: float) -> float:
    # inverse of softplus for y > 0 (numerically fine for y in [1e-4, 1e4])
    import math
    return float(math.log(math.expm1(y))) if y < 30 else float(y)


def init_theta(sigma1: float = 0.1, sigma2: float = 0.5, mu2: float = 1.0) -> Dict:
    """Unconstrained Theta pytree (raw_* go through softplus; mu2 is free)."""
    return {
        "raw_sigma1": jnp.asarray(_inv_softplus(sigma1), jnp.float32),
        "raw_sigma2": jnp.asarray(_inv_softplus(sigma2), jnp.float32),
        "mu2": jnp.asarray(mu2, jnp.float32),
    }


def init_theta_from_data(lam) -> Dict:
    """Data-driven Theta init: the major mode must cover the bulk of the
    current variances and the minor mode must sit at the top of the
    distribution, otherwise the mixture collapses to one mode before the
    embedding has a chance to reshape Lambda (§3.3 degeneracy).

    sigma1 ~ RMS of the lower half, mu2 ~ max(Lambda), sigma2 ~ spread of
    the upper quartile.
    """
    import numpy as np
    lam = np.asarray(lam, np.float64)
    lo = np.sort(lam)[: max(len(lam) // 2, 1)]
    hi = np.sort(lam)[-max(len(lam) // 4, 1):]
    sigma1 = float(max(np.sqrt(np.mean(lo ** 2)), 1e-2))
    mu2 = float(max(lam.max(), sigma1 * 3))
    sigma2 = float(max(hi.std(), 0.25 * mu2, 1e-2))
    return init_theta(sigma1=sigma1, sigma2=sigma2, mu2=mu2)


def theta_values(theta: Dict):
    """(sigma1, sigma2, mu2) with positivity constraints applied."""
    return (_softplus(theta["raw_sigma1"]) + 1e-4,
            _softplus(theta["raw_sigma2"]) + 1e-4,
            theta["mu2"])


def normal_logpdf(x, mu, sigma):
    z = (x - mu) / sigma
    return -0.5 * (z * z + _LOG_2PI) - jnp.log(sigma)


def normal_logcdf(x):
    """log Phi(x) — jax.scipy's log_ndtr is tail-stable *and* has a
    well-defined gradient in the deep left tail (erfc-based forms give
    0/0 = NaN there, which poisons the joint training step)."""
    return jax.scipy.special.log_ndtr(x)


def skewnormal_logpdf(x, mu, sigma, alpha):
    """log SN(x; mu, sigma, alpha) = log2 + logphi(z) - log(sigma) + logPhi(alpha z)."""
    z = (x - mu) / sigma
    return (jnp.log(2.0) + normal_logpdf(z, 0.0, 1.0) - jnp.log(sigma)
            + normal_logcdf(alpha * z))


def mode_log_components(lam, theta, *, pi1: float, pi2: float, alpha2: float):
    """Per-dimension log(pi1*N) and log(pi2*SN).  lam: (d,) nonneg."""
    s1, s2, mu2 = theta_values(theta)
    log_major = jnp.log(pi1) + normal_logpdf(lam, 0.0, s1)
    log_minor = jnp.log(pi2) + skewnormal_logpdf(lam, mu2, s2, alpha2)
    return log_major, log_minor


def nll(lam, theta, *, pi1: float, pi2: float, alpha2: float):
    """Robustified negative log-likelihood L^P (paper eq. 4 + eq. 10).

    eq. 4:  -log prod_i [pi1 N(lam_i) + pi2 SN(lam_i)]
    eq. 10: additionally  -log sum_i pi2 SN(lam_i)  so the minor mode is
            never emptied out (keeps psi non-degenerate).
    Mean-reduced over d so gamma_p is dimension-independent.
    """
    log_major, log_minor = mode_log_components(
        lam, theta, pi1=pi1, pi2=pi2, alpha2=alpha2)
    log_mix = jnp.logaddexp(log_major, log_minor)
    nll_mix = -jnp.mean(log_mix)
    # robustness term: -log P(SN) = -log sum_i pi2 SN(lam_i)
    nll_minor = -jax.nn.logsumexp(log_minor)
    return nll_mix + nll_minor / lam.shape[-1]


def psi_mask(lam, theta, *, pi1: float, pi2: float, alpha2: float):
    """xi in {0,1}^d (paper eq. 5/7): dim i in psi iff the minor mode is
    more likely, i.e. pi2*SN(lam_i) > pi1*N(lam_i)."""
    log_major, log_minor = mode_log_components(
        lam, theta, pi1=pi1, pi2=pi2, alpha2=alpha2)
    return (log_minor > log_major)


def psi_mask_topk(lam, k: int):
    """Fallback xi when the prior is untrained/degenerate: top-k variances.
    Used to guarantee |psi| >= 1 at serving time (robustness guard)."""
    d = lam.shape[-1]
    thresh = jnp.sort(lam)[d - k]
    return lam >= thresh
