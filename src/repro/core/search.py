"""Two-step similarity search — thin re-export of the unified index
layer (``repro.index``, DESIGN.md §7).

The engine implementations moved to ``repro.index``:

  index/base.py   SearchResult, build_lut / lut_sum ADC primitives,
                  backend resolution, query chunking, exact_search,
                  MAP / recall metrics
  index/flat.py   adc_search, two_step_search (jnp | pallas | auto
                  dispatch, optional refine_cap compaction), FlatADC /
                  TwoStep index classes
  index/ivf.py    batched IVF composition (see core/ivf.py shim)

This module keeps the historical import surface
(``from repro.core import search as srch``) stable; new code should
import from ``repro.index`` directly.
"""
from __future__ import annotations

from repro.index.base import (QuantizedLUT, SearchResult,  # noqa: F401
                              build_lut, chunked_over_queries, exact_search,
                              lut_sum, mean_average_precision, quantize_lut,
                              recall_at, resolve_backend, resolve_lut_dtype)
from repro.index.flat import (adc_search, two_step_search,  # noqa: F401
                              two_step_search_compact)

# historical private aliases, kept for callers that reached into them
_resolve_backend = resolve_backend
_chunked_over_queries = chunked_over_queries

__all__ = [
    "QuantizedLUT", "SearchResult", "build_lut", "lut_sum", "quantize_lut",
    "adc_search", "exact_search", "two_step_search",
    "two_step_search_compact", "mean_average_precision", "recall_at",
    "resolve_backend", "resolve_lut_dtype", "chunked_over_queries",
]
