"""Two-step similarity search (paper §3.4) + evaluation metrics.

Asymmetric distance computation (ADC): for query q the per-codebook LUT

    T[k, j] = ||c_{k,j}||^2 - 2 <q, c_{k,j}>

gives  ||q - xbar||^2 = ||q||^2 + sum_k T[k, b_k] + (cross terms).  With
the CQ constant-inner-product constraint the cross terms are a dataset
constant, and after ICQ's hard projection the fast/slow groups are
exactly orthogonal — so ranking by the LUT sum is ranking by distance.

Two-step search (TPU-native dense adaptation, DESIGN.md §3):
  phase 1: crude distance = LUT sum over the |K_fast| fast codebooks for
           all n points; bootstrap a threshold t from the full distance
           of the top-`topk` crude candidates;
  phase 2: points with  crude < t + sigma  (eq. 2) are refined with the
           remaining K - |K_fast| codebooks; everything else is pruned.

This module is the *dispatch layer* over two batched engines
(DESIGN.md §3.5):

  backend="jnp"     fully vectorized reference — batched ``build_lut``,
                    one ``take_along_axis`` gather per LUT sum, batched
                    ``top_k`` over the whole query block (no per-query
                    ``lax.map``).  Optionally chunked over queries
                    (``query_chunk``) to bound the (nq, n) working set.
  backend="pallas"  the fused (query-tile x point-tile) kernels in
                    ``kernels/batched_search.py``: LUT tiles pinned in
                    VMEM, each codes tile streamed from HBM once per
                    query tile, eq. 2 test + slow-codebook refine +
                    top-k merge fused in-kernel.
  backend="auto"    "pallas" on TPU backends, "jnp" elsewhere.

Database codes are stored packed (uint8 for m <= 256, core.encode.
pack_codes) and widened to int32 only at the engine boundary — 4x less
HBM traffic per streamed codes tile.

"Average Ops" — the paper's speed metric (Figs. 1-5) — counts LUT adds
per point:  |K_fast| + pass_rate * (K - |K_fast|), vs always-K for
ADC baselines.  The analytic count is exact for the dense formulation
and measurable identically on CPU and TPU.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import codebooks as cb


# ----------------------------------------------------------------- LUTs ----

def build_lut(q, C):
    """Per-query ADC tables.  q: (d,) or (nq,d); C: (K,m,d) -> (.., K, m)."""
    sq = cb.codeword_sq_norms(C)                             # (K,m)
    if q.ndim == 1:
        return sq - 2.0 * jnp.einsum("d,kmd->km", q, C)
    return sq[None] - 2.0 * jnp.einsum("qd,kmd->qkm", q, C)


def lut_sum(lut, codes, cb_mask=None):
    """Sum selected LUT entries — one vectorized ``take_along_axis``
    gather (vmap/batch friendly; no Python loop over codebooks).

    Shapes:
      lut (K,m),    codes (n,K)     -> (n,)
      lut (nq,K,m), codes (n,K)     -> (nq, n)   shared database codes
      lut (nq,K,m), codes (nq,t,K)  -> (nq, t)   per-query candidate codes

    ``cb_mask``: optional (K,) bool — restrict to a codebook subset
    (the fast group for crude distances).
    """
    codes = codes.astype(jnp.int32)
    if cb_mask is not None:
        lut = lut * cb_mask[:, None].astype(lut.dtype)
    if lut.ndim == 3 and codes.ndim == 2:
        # batched LUTs against the shared database codes: accumulate one
        # (nq, n) gather per codebook (lax.scan over K) instead of
        # materializing the (nq, K, n) gather, which blows the cache at
        # serving sizes (~4x slower measured at nq=64, n=100k)
        def step(acc, lut_and_codes):
            lut_k, codes_k = lut_and_codes               # (nq,m), (n,)
            return acc + jnp.take(lut_k, codes_k, axis=1), None
        acc0 = jnp.zeros((lut.shape[0], codes.shape[0]), lut.dtype)
        acc, _ = jax.lax.scan(step, acc0,
                              (jnp.swapaxes(lut, 0, 1), codes.T))
        return acc
    idx = jnp.swapaxes(codes, -1, -2)                        # (..., K, n)
    parts = jnp.take_along_axis(lut, idx, axis=-1)           # (..., K, n)
    return jnp.sum(parts, axis=-2)


# -------------------------------------------------------------- searches ----

class SearchResult(NamedTuple):
    indices: jnp.ndarray     # (nq, topk) database ids, nearest first
    distances: jnp.ndarray   # (nq, topk) LUT-sum distances (monotone in L2)
    avg_ops: jnp.ndarray     # scalar — average LUT adds per database point
    pass_rate: jnp.ndarray   # scalar — fraction refined (phase-2 survivors)


def _resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown search backend {backend!r}")
    return backend


def exact_search(queries, X, topk: int):
    """Brute-force L2 ground truth.  queries: (nq,d), X: (n,d)."""
    d2 = (jnp.sum(jnp.square(queries), -1)[:, None]
          - 2.0 * queries @ X.T + jnp.sum(jnp.square(X), -1)[None, :])
    neg, idx = jax.lax.top_k(-d2, topk)
    return idx, -neg


def _chunked_over_queries(fn, queries, query_chunk: Optional[int]):
    """Apply the vectorized ``fn`` to query blocks of ``query_chunk`` (a
    working-set bound for huge batches); None = one block."""
    if query_chunk is None or queries.shape[0] <= query_chunk:
        return fn(queries)
    nq = queries.shape[0]
    pad = (-nq) % query_chunk
    qp = jnp.pad(queries, ((0, pad), (0, 0)))
    blocks = qp.reshape(-1, query_chunk, queries.shape[1])
    outs = jax.lax.map(fn, blocks)
    return jax.tree.map(
        lambda a: a.reshape((-1,) + a.shape[2:])[:nq], outs)


def adc_search(queries, codes, C, topk: int, *, backend: str = "auto",
               block_q: int = 64, block_n: int = 512, interpret=None,
               query_chunk: Optional[int] = None):
    """Baseline one-step ADC: full K-codebook LUT sum for every point,
    batched over the whole query block."""
    K, m = C.shape[0], C.shape[1]
    be = _resolve_backend(backend)

    if be == "pallas":
        # codes stay packed into the kernel (widened per-tile in VMEM)
        from repro.kernels import ops

        def one_block(qs):
            luts = build_lut(qs, C)
            _, vals, ids = ops.batched_crude_topk(
                codes, luts.reshape(qs.shape[0], K * m), topk,
                block_q=block_q, block_n=block_n, interpret=interpret,
                want_crude=False)
            return ids, vals
    else:
        codes = codes.astype(jnp.int32)              # widen packed codes

        def one_block(qs):
            luts = build_lut(qs, C)                  # (nq,K,m)
            dist = lut_sum(luts, codes)              # (nq,n)
            neg, ids = jax.lax.top_k(-dist, topk)
            return ids, -neg

    idx, vals = _chunked_over_queries(one_block, queries, query_chunk)
    return SearchResult(idx, vals, jnp.asarray(float(K)), jnp.asarray(1.0))


def _eq2_passed(luts, codes, crude, topk: int, sigma):
    """Eq. 2 margin test, shared by the jnp engines: bootstrap the
    neighbor list from the crude top-k, rank it by full distance; the
    threshold compares *crude vs crude of the furthest list element*
    plus the margin sigma.  Returns the (nq, n) pass mask."""
    neg_c, cand = jax.lax.top_k(-crude, topk)            # (nq,topk)
    cand_codes = jnp.take(codes, cand, axis=0)           # (nq,topk,K)
    full_cand = lut_sum(luts, cand_codes)                # (nq,topk)
    far = jnp.argmax(full_cand, axis=1)                  # (nq,)
    t = -jnp.take_along_axis(neg_c, far[:, None], axis=1)[:, 0]
    return crude < (t + sigma)[:, None]


def _two_step_block_jnp(qs, codes, C, fast, sigma, topk: int):
    """Vectorized two-step over one query block.  Returns
    (idx (nq,topk), dist (nq,topk), passed_frac (nq,))."""
    luts = build_lut(qs, C)                              # (nq,K,m)
    crude = lut_sum(luts, codes, fast)                   # (nq,n)
    passed = _eq2_passed(luts, codes, crude, topk, sigma)
    # refine passers only; pruned points are excluded from the ranking
    slow = lut_sum(luts, codes, ~fast)
    ranked = jnp.where(passed, crude + slow, jnp.inf)
    neg, idx = jax.lax.top_k(-ranked, topk)
    return idx, -neg, jnp.mean(passed.astype(jnp.float32), axis=1)


def _two_step_pallas(queries, codes, C, fast, sigma, topk: int,
                     block_q: int, block_n: int, interpret):
    """Fused-kernel two-step: phase-1 crude + candidate top-k in one
    kernel, tiny candidate refinement in jnp, fused phase-2 kernel."""
    from repro.kernels import ops
    nq = queries.shape[0]
    K, m = C.shape[0], C.shape[1]
    luts = build_lut(queries, C)                         # (nq,K,m)
    fast_f = fast.astype(luts.dtype)[None, :, None]
    lut_fast = (luts * fast_f).reshape(nq, K * m)
    lut_slow = (luts * (1.0 - fast_f)).reshape(nq, K * m)

    crude, cand_vals, cand_idx = ops.batched_crude_topk(
        codes, lut_fast, topk, block_q=block_q, block_n=block_n,
        interpret=interpret)
    # threshold bootstrap on the (nq, topk) candidate set — tiny, jnp
    cand_codes = jnp.take(codes, cand_idx, axis=0)       # (nq,topk,K)
    full_cand = cand_vals + lut_sum(luts, cand_codes, ~fast)
    far = jnp.argmax(full_cand, axis=1)
    t = jnp.take_along_axis(cand_vals, far[:, None], axis=1)[:, 0]
    thr = t + sigma                                      # (nq,)

    dist, idx = ops.batched_refine_topk(
        codes, lut_slow, crude, thr, topk, block_q=block_q,
        block_n=block_n, interpret=interpret)
    passed_frac = jnp.mean((crude < thr[:, None]).astype(jnp.float32), axis=1)
    return idx, dist, passed_frac


def two_step_search(queries, codes, C, structure, topk: int, *,
                    backend: str = "auto", block_q: int = 64,
                    block_n: int = 512, interpret=None,
                    query_chunk: Optional[int] = None):
    """ICQ two-step search (eq. 2 crude test -> eq. 1 refinement),
    batched over the whole query block.

    structure: core.icq.ICQStructure (xi, fast_mask, sigma).
    backend:   "jnp" | "pallas" | "auto" (pallas on TPU) — see module
               docstring; both produce identical rankings.
    """
    K = C.shape[0]
    fast = structure.fast_mask
    sigma = structure.sigma
    kf = jnp.sum(fast.astype(jnp.float32))
    be = _resolve_backend(backend)

    if be == "pallas":
        # codes stay packed into the kernels (widened per-tile in VMEM);
        # query_chunk bounds the dense (chunk, n) crude matrix here too
        fn = functools.partial(_two_step_pallas, codes=codes, C=C,
                               fast=fast, sigma=sigma, topk=topk,
                               block_q=block_q, block_n=block_n,
                               interpret=interpret)
    else:
        fn = functools.partial(_two_step_block_jnp,
                               codes=codes.astype(jnp.int32), C=C,
                               fast=fast, sigma=sigma, topk=topk)
    idx, dist, pf = _chunked_over_queries(fn, queries, query_chunk)
    pass_rate = jnp.mean(pf)
    avg_ops = kf + pass_rate * (K - kf)
    return SearchResult(idx, dist, avg_ops, pass_rate)


def two_step_search_compact(queries, codes, C, structure, topk: int,
                            refine_cap: int, *,
                            query_chunk: Optional[int] = None):
    """Two-step search with an explicit survivor compaction (the TPU
    execution shape): at most ``refine_cap`` survivors per query are
    gathered and refined — a static-shape bound on phase-2 work.

    Semantically identical to ``two_step_search`` whenever the number of
    passers <= refine_cap; with a smaller cap it keeps the refine_cap
    *best crude* survivors (a quality/throughput dial for serving).
    """
    K = C.shape[0]
    fast = structure.fast_mask
    sigma = structure.sigma
    kf = jnp.sum(fast.astype(jnp.float32))
    codes = codes.astype(jnp.int32)

    def one_block(qs):
        luts = build_lut(qs, C)
        crude = lut_sum(luts, codes, fast)
        passed = _eq2_passed(luts, codes, crude, topk, sigma)
        # compact: best-crude survivors first, capped
        masked = jnp.where(passed, crude, jnp.inf)
        neg_s, surv = jax.lax.top_k(-masked, refine_cap)
        valid = jnp.isfinite(-neg_s)
        surv_codes = jnp.take(codes, surv, axis=0)       # (nq,cap,K)
        full_surv = lut_sum(luts, surv_codes)
        ranked = jnp.where(valid, full_surv, jnp.inf)
        neg, pos = jax.lax.top_k(-ranked, topk)
        idx = jnp.take_along_axis(surv, pos, axis=1)
        return idx, -neg, jnp.mean(passed.astype(jnp.float32), axis=1)

    idx, dist, pf = _chunked_over_queries(one_block, queries, query_chunk)
    pass_rate = jnp.mean(pf)
    avg_ops = kf + pass_rate * (K - kf)
    return SearchResult(idx, dist, avg_ops, pass_rate)


# --------------------------------------------------------------- metrics ----

def mean_average_precision(retrieved_ids, db_labels, query_labels):
    """Label-based MAP (the paper's metric): a retrieved point is relevant
    iff it shares the query's class.  retrieved_ids: (nq, R)."""
    rel = (db_labels[retrieved_ids] == query_labels[:, None]).astype(jnp.float32)
    ranks = jnp.arange(1, rel.shape[1] + 1, dtype=jnp.float32)[None, :]
    cum = jnp.cumsum(rel, axis=1)
    prec_at = cum / ranks
    denom = jnp.maximum(jnp.sum(rel, axis=1), 1.0)
    ap = jnp.sum(prec_at * rel, axis=1) / denom
    return jnp.mean(ap)


def recall_at(retrieved_ids, true_ids):
    """Fraction of true nearest neighbors recovered.  Both (nq, R)."""
    hits = (retrieved_ids[:, :, None] == true_ids[:, None, :]).any(axis=1)
    return jnp.mean(hits.astype(jnp.float32))
