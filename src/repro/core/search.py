"""Two-step similarity search (paper §3.4) + evaluation metrics.

Asymmetric distance computation (ADC): for query q the per-codebook LUT

    T[k, j] = ||c_{k,j}||^2 - 2 <q, c_{k,j}>

gives  ||q - xbar||^2 = ||q||^2 + sum_k T[k, b_k] + (cross terms).  With
the CQ constant-inner-product constraint the cross terms are a dataset
constant, and after ICQ's hard projection the fast/slow groups are
exactly orthogonal — so ranking by the LUT sum is ranking by distance.

Two-step search (TPU-native dense adaptation, DESIGN.md §3):
  phase 1: crude distance = LUT sum over the |K_fast| fast codebooks for
           all n points; bootstrap a threshold t from the full distance
           of the top-`topk` crude candidates;
  phase 2: points with  crude < t + sigma  (eq. 2) are refined with the
           remaining K - |K_fast| codebooks; everything else is pruned.

"Average Ops" — the paper's speed metric (Figs. 1-5) — counts LUT adds
per point:  |K_fast| + pass_rate * (K - |K_fast|), vs always-K for
ADC baselines.  The analytic count is exact for the dense formulation
and measurable identically on CPU and TPU.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import codebooks as cb


# ----------------------------------------------------------------- LUTs ----

def build_lut(q, C):
    """Per-query ADC tables.  q: (d,) or (nq,d); C: (K,m,d) -> (.., K, m)."""
    sq = cb.codeword_sq_norms(C)                             # (K,m)
    if q.ndim == 1:
        return sq - 2.0 * jnp.einsum("d,kmd->km", q, C)
    return sq[None] - 2.0 * jnp.einsum("qd,kmd->qkm", q, C)


def lut_sum(lut, codes, cb_mask=None):
    """Sum selected LUT entries.  lut: (K,m), codes: (n,K) -> (n,).

    ``cb_mask``: optional (K,) bool — restrict to a codebook subset
    (the fast group for crude distances).
    """
    K = lut.shape[0]
    parts = jnp.stack([lut[k][codes[:, k]] for k in range(K)], axis=1)  # (n,K)
    if cb_mask is not None:
        parts = parts * cb_mask[None, :].astype(parts.dtype)
    return jnp.sum(parts, axis=1)


# -------------------------------------------------------------- searches ----

class SearchResult(NamedTuple):
    indices: jnp.ndarray     # (nq, topk) database ids, nearest first
    distances: jnp.ndarray   # (nq, topk) LUT-sum distances (monotone in L2)
    avg_ops: jnp.ndarray     # scalar — average LUT adds per database point
    pass_rate: jnp.ndarray   # scalar — fraction refined (phase-2 survivors)


def exact_search(queries, X, topk: int):
    """Brute-force L2 ground truth.  queries: (nq,d), X: (n,d)."""
    d2 = (jnp.sum(jnp.square(queries), -1)[:, None]
          - 2.0 * queries @ X.T + jnp.sum(jnp.square(X), -1)[None, :])
    neg, idx = jax.lax.top_k(-d2, topk)
    return idx, -neg


def adc_search(queries, codes, C, topk: int):
    """Baseline one-step ADC: full K-codebook LUT sum for every point."""
    K = C.shape[0]

    def one(q):
        lut = build_lut(q, C)
        dist = lut_sum(lut, codes)
        neg, idx = jax.lax.top_k(-dist, topk)
        return idx, -neg

    idx, dist = jax.lax.map(one, queries)
    return SearchResult(idx, dist, jnp.asarray(float(K)), jnp.asarray(1.0))


def two_step_search(queries, codes, C, structure, topk: int):
    """ICQ two-step search (eq. 2 crude test -> eq. 1 refinement).

    structure: core.icq.ICQStructure (xi, fast_mask, sigma).
    """
    K = C.shape[0]
    fast = structure.fast_mask
    sigma = structure.sigma
    kf = jnp.sum(fast.astype(jnp.float32))

    def one(q):
        lut = build_lut(q, C)                                # (K,m)
        crude = lut_sum(lut, codes, fast)                    # (n,)
        # bootstrap the neighbor list from the crude top-k, rank it by
        # full distance; eq. 2 then compares *crude vs crude of the
        # furthest list element* plus the margin sigma
        neg_c, cand = jax.lax.top_k(-crude, topk)
        full_cand = lut_sum(lut, codes[cand])                # (topk,)
        far = jnp.argmax(full_cand)                          # k-th best by full
        t = crude[cand[far]]
        passed = crude < t + sigma                           # eq. 2
        # refine passers only; pruned points are excluded from the ranking
        slow_sum = lut_sum(lut, codes, ~fast)
        full = crude + slow_sum
        ranked = jnp.where(passed, full, jnp.inf)
        neg, idx = jax.lax.top_k(-ranked, topk)
        return idx, -neg, jnp.mean(passed.astype(jnp.float32))

    idx, dist, pr = jax.lax.map(one, queries)
    pass_rate = jnp.mean(pr)
    avg_ops = kf + pass_rate * (K - kf)
    return SearchResult(idx, dist, avg_ops, pass_rate)


def two_step_search_compact(queries, codes, C, structure, topk: int,
                            refine_cap: int):
    """Two-step search with an explicit survivor compaction (the TPU
    execution shape): at most ``refine_cap`` survivors per query are
    gathered and refined — a static-shape bound on phase-2 work.

    Semantically identical to ``two_step_search`` whenever the number of
    passers <= refine_cap; with a smaller cap it keeps the refine_cap
    *best crude* survivors (a quality/throughput dial for serving).
    """
    K = C.shape[0]
    fast = structure.fast_mask
    sigma = structure.sigma
    kf = jnp.sum(fast.astype(jnp.float32))

    def one(q):
        lut = build_lut(q, C)
        crude = lut_sum(lut, codes, fast)
        neg_c, cand = jax.lax.top_k(-crude, topk)
        full_cand = lut_sum(lut, codes[cand])
        far = jnp.argmax(full_cand)
        t = crude[cand[far]]
        passed = crude < t + sigma
        # compact: best-crude survivors first, capped
        masked = jnp.where(passed, crude, jnp.inf)
        neg_s, surv = jax.lax.top_k(-masked, refine_cap)
        valid = jnp.isfinite(-neg_s)
        full_surv = lut_sum(lut, codes[surv])
        ranked = jnp.where(valid, full_surv, jnp.inf)
        neg, pos = jax.lax.top_k(-ranked, topk)
        return surv[pos], -neg, jnp.mean(passed.astype(jnp.float32))

    idx, dist, pr = jax.lax.map(one, queries)
    pass_rate = jnp.mean(pr)
    avg_ops = kf + pass_rate * (K - kf)
    return SearchResult(idx, dist, avg_ops, pass_rate)


# --------------------------------------------------------------- metrics ----

def mean_average_precision(retrieved_ids, db_labels, query_labels):
    """Label-based MAP (the paper's metric): a retrieved point is relevant
    iff it shares the query's class.  retrieved_ids: (nq, R)."""
    rel = (db_labels[retrieved_ids] == query_labels[:, None]).astype(jnp.float32)
    ranks = jnp.arange(1, rel.shape[1] + 1, dtype=jnp.float32)[None, :]
    cum = jnp.cumsum(rel, axis=1)
    prec_at = cum / ranks
    denom = jnp.maximum(jnp.sum(rel, axis=1), 1.0)
    ap = jnp.sum(prec_at * rel, axis=1) / denom
    return jnp.mean(ap)


def recall_at(retrieved_ids, true_ids):
    """Fraction of true nearest neighbors recovered.  Both (nq, R)."""
    hits = (retrieved_ids[:, :, None] == true_ids[:, None, :]).any(axis=1)
    return jnp.mean(hits.astype(jnp.float32))
