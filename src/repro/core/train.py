"""Joint embedding + quantizer training — thin re-export of the unified
trainer layer (``repro.trainer``, DESIGN.md §9), kept for the
historical import surface exactly like ``core/search.py`` re-exports
the index layer.

The implementation lives in:

    trainer/joint.py   the jitted train step (loss terms per mode),
                       init, and the engine-backed ``finalize`` export
    trainer/epoch.py   ``fit`` — the scan-compiled (optionally
                       mesh-sharded) epoch driver with proper key
                       threading
    trainer/encode.py  padded-chunk database encoding

New code should import from ``repro.trainer``.
"""
from repro.trainer.base import ICQModel
from repro.trainer.epoch import fit
from repro.trainer.joint import (_pq_support_mask, _soft_xi, finalize,
                                 init_train_state, make_train_step)

__all__ = ["ICQModel", "fit", "finalize", "init_train_state",
           "make_train_step"]
