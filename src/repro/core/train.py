"""Joint embedding + quantizer training (paper §3.1-3.3).

One trainer covers ICQ and the ablation/baseline modes by switching the
active loss terms (paper eq. 3 augmented):

    mode="icq":  L^E + L^C + gamma1 L^P + gamma2 L^ICQ (+ CQ penalty)
    mode="cq":   L^E + L^C + CQ penalty          (SQ = linear embed + cq)
    mode="pq":   L^E + L^C with codebooks hard-projected onto contiguous
                 subspaces after every step (PQ/PQN-style)

Gradient flow notes:
- Lambda is the *online* variance estimate (eq. 9, core.variance); its
  value comes from the running state but its gradient flows through the
  current batch's sample variance (straight-through running stats), so
  L^P shapes the embedding W as intended.
- xi is hard for search but L^ICQ uses the prior's soft responsibilities
  (minor-mode posterior) so the interleaving penalty stays differentiable
  in Theta.
- L^C uses straight-through soft assignments (core.encode.st_decode);
  codebooks get dense gradients, embeddings see the hard reconstruction.

The trainer is a pure-JAX step (jit-compiled) driven by a host loop;
encode-side ICM re-encoding happens at export time (``finalize``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import codebooks as cb
from repro.core import embed as embed_mod
from repro.core import encode as enc
from repro.core import icq as icq_mod
from repro.core import losses
from repro.core import prior as prior_mod
from repro.core import variance
from repro.train.optimizer import AdamW


@dataclasses.dataclass
class ICQModel:
    """Fitted artifact: everything the search side needs."""
    icq_cfg: Any
    embed_params: Any
    embed_apply: Callable
    C: jnp.ndarray               # (K,m,d) — hard-projected for mode="icq"
    codes: jnp.ndarray           # (n,K) database codes (ICM-encoded)
    structure: icq_mod.ICQStructure
    lam: jnp.ndarray             # (d,) final variance estimate
    mode: str = "icq"

    def embed(self, x):
        return self.embed_apply(self.embed_params, x)


def _pq_support_mask(K: int, d: int):
    """(K,d) 0/1 contiguous-subspace masks (PQ)."""
    assert d % K == 0
    sub = d // K
    m = jnp.zeros((K, d))
    for k in range(K):
        m = m.at[k, k * sub:(k + 1) * sub].set(1.0)
    return m


def init_train_state(key, icq_cfg, *, embed_kind: str = "linear",
                     d_raw: Optional[int] = None, num_classes: int = 10,
                     img_hw: Optional[int] = None, channels: Optional[int] = None,
                     mode: str = "icq", lr: float = 1e-3,
                     sample_batch=None) -> Dict:
    """Build params + optimizer + variance state.  ``sample_batch`` (x, y)
    seeds the codebooks from real embeddings (residual k-means)."""
    d, K, m = icq_cfg.d, icq_cfg.num_codebooks, icq_cfg.codebook_size
    k_embed, k_cb, k3 = jax.random.split(key, 3)
    embed_params, embed_apply = embed_mod.build_embedder(
        embed_kind, k_embed, d_raw=d_raw, d=d, num_classes=num_classes,
        img_hw=img_hw, channels=channels)

    theta0 = prior_mod.init_theta()
    if sample_batch is not None:
        emb0 = embed_apply(embed_params, sample_batch[0])
        if mode == "pq":
            C0 = cb.init_pq(k_cb, emb0, K, m)
        else:
            C0 = cb.init_residual(k_cb, emb0, K, m)
        theta0 = prior_mod.init_theta_from_data(jnp.var(emb0, axis=0))
    else:
        C0 = jax.random.normal(k_cb, (K, m, d), jnp.float32) * 0.1

    params = {"embed": embed_params, "C": C0, "theta": theta0}
    opt = AdamW(lr=lambda step: jnp.asarray(lr, jnp.float32),
                weight_decay=0.0, clip_norm=1.0)
    return {
        "params": params,
        "opt_state": opt.init(params),
        "var_state": variance.init_state(d),
        "opt": opt,
        "embed_apply": embed_apply,
        "mode": mode,
        "pq_mask": _pq_support_mask(K, d) if mode == "pq" else None,
    }


def _soft_xi(lam, theta, icq_cfg):
    """Minor-mode posterior responsibility — the differentiable xi."""
    log_major, log_minor = prior_mod.mode_log_components(
        lam, theta, pi1=icq_cfg.pi1, pi2=icq_cfg.pi2, alpha2=icq_cfg.alpha2)
    return jax.nn.sigmoid(log_minor - log_major)


def make_train_step(icq_cfg, embed_apply, opt: AdamW, mode: str,
                    pq_mask=None, tau: float = 1.0):
    """Returns jit-able step(params, opt_state, var_state, batch) ->
    (params, opt_state, var_state, metrics)."""

    def loss_fn(params, var_state, x, y):
        emb = embed_apply(params["embed"], x)
        # --- L^E ---
        logits = embed_mod.classify(params["embed"], emb)
        l_e = losses.classification_loss(logits, y)
        # --- online variance with straight-through running value ---
        new_var = variance.update(var_state, emb)
        _, lam_batch = variance.batch_moments(emb)
        lam = (jax.lax.stop_gradient(variance.lambda_hat(new_var) - lam_batch)
               + lam_batch)
        # --- L^C ---
        l_c, codes = losses.quantization_loss(emb, params["C"], tau)
        total = l_e + l_c
        mets = {"l_e": l_e, "l_c": l_c}
        if mode in ("icq", "cq"):
            l_cq, _ = losses.cq_penalty(params["C"], codes)
            total = total + icq_cfg.gamma_cq * l_cq
            mets["l_cq"] = l_cq
        if mode == "icq":
            l_p = prior_mod.nll(lam, params["theta"], pi1=icq_cfg.pi1,
                                pi2=icq_cfg.pi2, alpha2=icq_cfg.alpha2)
            xi_soft = _soft_xi(jax.lax.stop_gradient(lam), params["theta"],
                               icq_cfg)
            l_icq = losses.icq_loss(params["C"], xi_soft)
            total = total + icq_cfg.gamma_p * l_p + icq_cfg.gamma_icq * l_icq
            mets.update(l_p=l_p, l_icq=l_icq, psi_size=jnp.sum(xi_soft > 0.5))
        mets["total"] = total
        return total, (new_var, mets)

    def step(params, opt_state, var_state, batch):
        x, y = batch
        grads, (new_var, mets) = jax.grad(loss_fn, has_aux=True)(
            params, var_state, x, y)
        if mode == "icq":
            # Theta must track the (moving) variance distribution faster
            # than W reshapes it, or the mixture collapses to one mode
            # (§3.3); 3 scalars, so the boosted rate is cheap and safe.
            grads = dict(grads, theta=jax.tree.map(
                lambda g: g * 10.0, grads["theta"]))
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        if mode == "pq":                      # hard support projection
            params = dict(params, C=params["C"] * pq_mask[:, None, :])
        mets["gnorm"] = gnorm
        return params, opt_state, new_var, mets

    return step


def fit(key, xs, ys, icq_cfg, *, embed_kind="linear", num_classes=10,
        img_hw=None, channels=None, mode="icq", epochs=5, batch_size=256,
        lr=1e-3, tau=1.0, verbose=False) -> ICQModel:
    """Host training loop over (xs, ys) numpy/jnp arrays -> fitted ICQModel."""
    n = xs.shape[0]
    d_raw = xs.shape[-1] if xs.ndim == 2 else None
    nb = max(n // batch_size, 1)
    state = init_train_state(
        key, icq_cfg, embed_kind=embed_kind, d_raw=d_raw,
        num_classes=num_classes, img_hw=img_hw, channels=channels, mode=mode,
        lr=lr, sample_batch=(xs[:min(n, 4096)], ys[:min(n, 4096)]))
    step = jax.jit(make_train_step(icq_cfg, state["embed_apply"], state["opt"],
                                   mode, state["pq_mask"], tau))
    params, opt_state, var_state = (state["params"], state["opt_state"],
                                    state["var_state"])
    rng = jax.random.PRNGKey(0x5EED)
    for ep in range(epochs):
        rng, k = jax.random.split(rng)
        perm = jax.random.permutation(k, n)
        var_state = variance.init_state(icq_cfg.d)   # fresh estimate per epoch
        for b in range(nb):
            idx = perm[b * batch_size:(b + 1) * batch_size]
            params, opt_state, var_state, mets = step(
                params, opt_state, var_state, (xs[idx], ys[idx]))
        if verbose:
            print(f"  epoch {ep}: " + " ".join(
                f"{k}={float(v):.4f}" for k, v in mets.items()))
    return finalize(params, state["embed_apply"], var_state, icq_cfg, xs,
                    mode=mode)


def finalize(params, embed_apply, var_state, icq_cfg, xs, *, mode="icq",
             encode_batch: int = 8192) -> ICQModel:
    """Export: hard-project codebooks (ICQ), ICM-encode the database,
    build the search structure."""
    lam = variance.lambda_hat(var_state)
    C = params["C"]
    if mode == "icq":
        structure = icq_mod.build_structure(C, lam, params["theta"], icq_cfg)
        C = icq_mod.project_codebooks(C, structure.xi, structure.fast_mask)
        # rebuild with projected C (fast set/energies unchanged by projection)
        structure = icq_mod.ICQStructure(
            xi=structure.xi, fast_mask=structure.fast_mask,
            sigma=structure.sigma)
    else:
        xi = prior_mod.psi_mask_topk(lam, max(1, icq_cfg.d // 2))
        structure = icq_mod.ICQStructure(
            xi=xi, fast_mask=jnp.ones((C.shape[0],), bool),
            sigma=jnp.zeros(()))

    encode_fn = jax.jit(lambda e: enc.encode_pq(e, C) if mode == "pq"
                        else enc.icm_encode(e, C, icq_cfg.icm_iters))
    chunks = []
    n = xs.shape[0]
    for s in range(0, n, encode_batch):
        emb = embed_apply(params["embed"], xs[s: s + encode_batch])
        chunks.append(encode_fn(emb))
    # store packed (uint8 for m <= 256): 4x less HBM traffic per codes
    # tile; search engines widen to int32 at the kernel boundary
    codes = enc.pack_codes(jnp.concatenate(chunks, axis=0),
                           icq_cfg.codebook_size)
    return ICQModel(icq_cfg=icq_cfg, embed_params=params["embed"],
                    embed_apply=embed_apply, C=C, codes=codes,
                    structure=structure, lam=lam, mode=mode)
