"""Online per-dimension variance estimation (paper §3.2, eq. 9).

The embeddings X change every step (W is being trained), so Lambda is
estimated across batches with the paper's incremental update:

    M_b = M_{b-1} + (m_b - M_{b-1}) / b
    L_b = L_{b-1} + (l_b - L_{b-1}) / b + (1/b)(1 - 1/b)(m_b - M_{b-1})^2

where (m_b, l_b) are the sample mean/variance of batch b.  This is exact
for equal-sized batches; ``welford_merge`` is the count-weighted exact
(Chan et al.) merge used when batch sizes differ (e.g. a ragged last
batch or cross-host merges in the distributed pipeline).

State is a small pytree — jit/scan-safe, checkpointable, and psum-able
(counts and count-weighted sums are additive across data-parallel hosts).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def init_state(d: int) -> Dict:
    return {
        "mean": jnp.zeros((d,), jnp.float32),
        "var": jnp.zeros((d,), jnp.float32),
        "count": jnp.zeros((), jnp.float32),   # number of batches seen (paper's b)
        "n": jnp.zeros((), jnp.float32),       # number of samples seen (exact merge)
        "m2": jnp.zeros((d,), jnp.float32),    # sum of squared deviations (exact merge)
        # present from step 0 so the state pytree is scan-carry stable
        # (the compiled epoch driver scans update as the carry)
        "_exact_mean": jnp.zeros((d,), jnp.float32),
    }


def batch_moments(x):
    """Sample mean/variance of one batch of embeddings x: (b, d)."""
    x = x.astype(jnp.float32)
    m = jnp.mean(x, axis=0)
    v = jnp.var(x, axis=0)
    return m, v


def global_batch_moments(x, axis_name=None):
    """Batch moments of the *global* batch when ``x`` is the local shard
    of a data-parallel region (shard_map/pmap over ``axis_name``).

    Equal shard sizes (the scan epoch driver guarantees them) make the
    pmean of local means/second moments the exact global moments; with
    ``axis_name=None`` this is exactly ``batch_moments``.  Differentiable
    (pmean is linear), so the straight-through Lambda gradient in the
    joint trainer flows unchanged under data parallelism.
    """
    if axis_name is None:
        return batch_moments(x)
    x = x.astype(jnp.float32)
    m = jax.lax.pmean(jnp.mean(x, axis=0), axis_name)
    ex2 = jax.lax.pmean(jnp.mean(jnp.square(x), axis=0), axis_name)
    return m, ex2 - jnp.square(m)


def update(state: Dict, x) -> Dict:
    """Paper eq. 9 — equal-weight incremental update with batch b's moments.

    Also maintains the exact (n, m2) Welford accumulators so both
    estimators are available; ``lambda_hat`` reads the paper's estimate.
    """
    m_b, l_b = batch_moments(x)
    return update_from_moments(state, m_b, l_b,
                               jnp.asarray(x.shape[0], jnp.float32))


def update_from_moments(state: Dict, m_b, l_b, nb) -> Dict:
    """``update`` with precomputed batch moments (and sample count
    ``nb``) — the form the data-parallel trainer uses with *global*
    moments from ``global_batch_moments`` so every shard applies the
    identical state transition (DESIGN.md §9)."""
    b = state["count"] + 1.0
    inv_b = 1.0 / b
    delta = m_b - state["mean"]
    new_mean = state["mean"] + delta * inv_b
    new_var = (state["var"] + (l_b - state["var"]) * inv_b
               + inv_b * (1.0 - inv_b) * jnp.square(delta))

    # exact count-weighted merge (Chan) in parallel
    nb = jnp.asarray(nb, jnp.float32)
    n = state["n"]
    tot = n + nb
    d_exact = m_b - _exact_mean(state)
    m2 = state["m2"] + l_b * nb + jnp.square(d_exact) * n * nb / jnp.maximum(tot, 1.0)
    exact_mean = _exact_mean(state) + d_exact * nb / jnp.maximum(tot, 1.0)

    return {"mean": new_mean, "var": new_var, "count": b,
            "n": tot, "m2": m2, "_exact_mean": exact_mean}


def _exact_mean(state):
    return state.get("_exact_mean", state["mean"] * 0.0)


def welford_merge(a: Dict, b: Dict) -> Dict:
    """Exact merge of two variance states (cross-host / cross-shard)."""
    na, nb = a["n"], b["n"]
    tot = jnp.maximum(na + nb, 1.0)
    ma, mb = _exact_mean(a), _exact_mean(b)
    delta = mb - ma
    m2 = a["m2"] + b["m2"] + jnp.square(delta) * na * nb / tot
    mean = ma + delta * nb / tot
    count = a["count"] + b["count"]
    var = m2 / tot
    return {"mean": mean, "var": var, "count": count,
            "n": na + nb, "m2": m2, "_exact_mean": mean}


def lambda_hat(state: Dict):
    """Current per-dimension variance estimate Lambda (paper's estimator)."""
    return state["var"]


def lambda_exact(state: Dict):
    """Exact pooled variance from the (n, m2) accumulators."""
    return state["m2"] / jnp.maximum(state["n"], 1.0)
