from repro.data.synthetic import (guyon_dataset, SYNTHETIC_DATASETS,
                                  make_table1_dataset)
from repro.data.pseudo_real import pseudo_mnist, pseudo_cifar
from repro.data.pipeline import TokenPipeline, ArrayPipeline

__all__ = [
    "guyon_dataset", "SYNTHETIC_DATASETS", "make_table1_dataset",
    "pseudo_mnist", "pseudo_cifar", "TokenPipeline", "ArrayPipeline",
]
