"""Data pipelines.

``TokenPipeline`` — deterministic synthetic LM token stream for the
training examples and benchmarks: seeded per (host, step, microbatch) so
every data-parallel host draws a disjoint, reproducible shard without
any cross-host coordination (restart-safe: step index is the only
state, so resume-from-checkpoint replays the exact stream).

``ArrayPipeline`` — host-side minibatcher over in-memory arrays with
per-epoch shuffling and sharded slicing for the retrieval workloads.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    """Synthetic-but-structured token stream (Zipfian unigrams + a linear
    congruential 'topic' drift so the LM has actual signal to fit)."""
    vocab_size: int
    seq_len: int
    global_batch: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0

    def __post_init__(self):
        assert self.global_batch % self.num_hosts == 0
        self.local_batch = self.global_batch // self.num_hosts
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks
        self._probs = (probs / probs.sum()).astype(np.float64)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for ``step`` on this host: {'tokens','labels'} int32."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host_id)
        toks = rng.choice(self.vocab_size, size=(self.local_batch, self.seq_len),
                          p=self._probs).astype(np.int32)
        # topic drift: overwrite a sliding window with a repeated motif
        motif_len = min(32, self.seq_len)
        motif = rng.integers(0, self.vocab_size, motif_len, dtype=np.int32)
        start = int(rng.integers(0, max(self.seq_len - motif_len, 1)))
        toks[:, start: start + motif_len] = motif[None, :]
        return {"tokens": toks, "labels": toks.copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass
class ArrayPipeline:
    """Shuffled minibatches over (x, y) arrays; optional host sharding."""
    x: np.ndarray
    y: np.ndarray
    batch_size: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    drop_remainder: bool = True

    def epoch(self, epoch_idx: int) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        rng = np.random.default_rng(self.seed * 7919 + epoch_idx)
        perm = rng.permutation(len(self.x))
        shard = perm[self.host_id:: self.num_hosts]
        nb = len(shard) // self.batch_size
        end = nb * self.batch_size if self.drop_remainder else len(shard)
        for s in range(0, end, self.batch_size):
            idx = shard[s: s + self.batch_size]
            yield self.x[idx], self.y[idx]

    def num_batches(self) -> int:
        return (len(self.x) // self.num_hosts) // self.batch_size
