"""Structured stand-ins for MNIST / CIFAR-10 (offline container — the
real downloads are unavailable; see DESIGN.md §6 Data note).

``pseudo_mnist``: 10 classes of 28x28 grayscale "digits" built from
per-class stroke templates (random walks) + elastic jitter + noise —
matched dim (784), class count, and split sizes (60k/10k by default,
reducible).

``pseudo_cifar``: 10 classes of 32x32x3 textured patches — per-class
color palette + oriented gratings + noise (3072-d), 50k/10k.

Both have genuine within-class structure and between-class separation so
supervised-retrieval MAP behaves qualitatively like the real datasets.
Every benchmark that uses them labels the substitution.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def _stroke_template(rng, hw: int = 28, n_steps: int = 60):
    canvas = np.zeros((hw, hw), np.float32)
    pos = np.array([hw / 2, hw / 2]) + rng.uniform(-6, 6, 2)
    vel = rng.uniform(-1.5, 1.5, 2)
    for _ in range(n_steps):
        vel = 0.8 * vel + rng.uniform(-1.0, 1.0, 2)
        pos = np.clip(pos + vel, 2, hw - 3)
        r, c = int(pos[0]), int(pos[1])
        canvas[r - 1: r + 2, c - 1: c + 2] += 0.4
    return np.clip(canvas, 0, 1)


def pseudo_mnist(n_train: int = 10000, n_test: int = 2000, seed: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(x_train (n,784), y_train, x_test, y_test), values in [0,1]."""
    rng = np.random.default_rng(seed)
    hw = 28
    templates = [_stroke_template(rng, hw) for _ in range(10)]

    def sample(n):
        y = rng.integers(0, 10, n).astype(np.int32)
        xs = np.empty((n, hw * hw), np.float32)
        for i in range(n):
            t = templates[y[i]]
            # elastic jitter: shift + small affine + noise
            sr, sc = rng.integers(-2, 3, 2)
            img = np.roll(np.roll(t, sr, 0), sc, 1)
            img = img * rng.uniform(0.7, 1.2) + 0.08 * rng.standard_normal((hw, hw))
            xs[i] = np.clip(img, 0, 1).ravel()
        return xs, y

    x_tr, y_tr = sample(n_train)
    x_te, y_te = sample(n_test)
    return x_tr, y_tr, x_te, y_te


def pseudo_cifar(n_train: int = 10000, n_test: int = 2000, seed: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(x_train (n,3072), y_train, x_test, y_test), values in [0,1]."""
    rng = np.random.default_rng(seed + 17)
    hw = 32
    yy, xx = np.meshgrid(np.arange(hw), np.arange(hw), indexing="ij")
    palettes = rng.uniform(0.1, 0.9, size=(10, 3))
    freqs = rng.uniform(0.15, 0.8, size=(10,))
    angles = rng.uniform(0, np.pi, size=(10,))

    def sample(n):
        y = rng.integers(0, 10, n).astype(np.int32)
        xs = np.empty((n, hw * hw * 3), np.float32)
        for i in range(n):
            c = y[i]
            phase = rng.uniform(0, 2 * np.pi)
            ang = angles[c] + rng.uniform(-0.2, 0.2)
            grating = 0.5 + 0.5 * np.sin(
                freqs[c] * (np.cos(ang) * xx + np.sin(ang) * yy) + phase)
            img = grating[:, :, None] * palettes[c][None, None, :]
            img = img + 0.1 * rng.standard_normal((hw, hw, 3))
            xs[i] = np.clip(img, 0, 1).ravel()
        return xs, y

    x_tr, y_tr = sample(n_train)
    x_te, y_te = sample(n_test)
    return x_tr, y_tr, x_te, y_te
