"""Structured stand-ins for real datasets (offline container — the
real downloads are unavailable; see DESIGN.md §6 Data note).

``pseudo_mnist``: 10 classes of 28x28 grayscale "digits" built from
per-class stroke templates (random walks) + elastic jitter + noise —
matched dim (784), class count, and split sizes (60k/10k by default,
reducible).

``pseudo_cifar``: 10 classes of 32x32x3 textured patches — per-class
color palette + oriented gratings + noise (3072-d), 50k/10k.

``pseudo_sift`` / ``pseudo_glove``: ANN-benchmark-shaped vector
workloads for the recall/QPS sweep harness (docs/benchmarks.md
``pareto`` target): a SIFT-like d=128 set (non-negative, clustered,
heavy-tailed cluster scales) and a GloVe-like d=300 set (dense signed,
Zipf-weighted cluster sizes, norm spread).  ``skewed_queries`` draws a
query workload whose cluster popularity follows a power law — the
skewed-traffic scenario real serving sees.

All generators have genuine within-class/cluster structure so recall,
MAP, and IVF probe behavior are qualitatively like the real datasets.
Every benchmark that uses them labels the substitution.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def _stroke_template(rng, hw: int = 28, n_steps: int = 60):
    canvas = np.zeros((hw, hw), np.float32)
    pos = np.array([hw / 2, hw / 2]) + rng.uniform(-6, 6, 2)
    vel = rng.uniform(-1.5, 1.5, 2)
    for _ in range(n_steps):
        vel = 0.8 * vel + rng.uniform(-1.0, 1.0, 2)
        pos = np.clip(pos + vel, 2, hw - 3)
        r, c = int(pos[0]), int(pos[1])
        canvas[r - 1: r + 2, c - 1: c + 2] += 0.4
    return np.clip(canvas, 0, 1)


def pseudo_mnist(n_train: int = 10000, n_test: int = 2000, seed: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(x_train (n,784), y_train, x_test, y_test), values in [0,1]."""
    rng = np.random.default_rng(seed)
    hw = 28
    templates = [_stroke_template(rng, hw) for _ in range(10)]

    def sample(n):
        y = rng.integers(0, 10, n).astype(np.int32)
        xs = np.empty((n, hw * hw), np.float32)
        for i in range(n):
            t = templates[y[i]]
            # elastic jitter: shift + small affine + noise
            sr, sc = rng.integers(-2, 3, 2)
            img = np.roll(np.roll(t, sr, 0), sc, 1)
            img = img * rng.uniform(0.7, 1.2) + 0.08 * rng.standard_normal((hw, hw))
            xs[i] = np.clip(img, 0, 1).ravel()
        return xs, y

    x_tr, y_tr = sample(n_train)
    x_te, y_te = sample(n_test)
    return x_tr, y_tr, x_te, y_te


def _clustered_vectors(rng, n: int, d: int, n_clusters: int,
                       cluster_weights: np.ndarray, scales: np.ndarray,
                       centers: np.ndarray):
    """Draw ``n`` vectors from a Gaussian mixture with per-cluster
    anisotropic covariance — returns (X (n, d) f32, cluster_ids (n,))."""
    cid = rng.choice(n_clusters, size=n, p=cluster_weights)
    X = np.empty((n, d), np.float32)
    axes = rng.standard_normal((n_clusters, d))     # per-cluster stretch
    for c in range(n_clusters):
        idx = cid == c
        k = int(idx.sum())
        if k == 0:
            continue
        z = rng.standard_normal((k, d))
        stretch = 1.0 + 1.5 * np.abs(axes[c]) / np.sqrt(d)
        X[idx] = centers[c] + scales[c] * z * stretch[None, :]
    return X, cid.astype(np.int32)


def pseudo_sift(n: int = 20000, n_queries: int = 256, d: int = 128,
                n_clusters: int = 64, seed: int = 0):
    """SIFT-like workload: (db (n, d), queries (nq, d), db_cluster_ids).

    Matches the gross statistics the d=128 SIFT descriptors have that
    matter to an ANN engine: non-negative heavy-tailed coordinates,
    strong cluster structure (local descriptors repeat across images),
    and cluster scales drawn log-normal so some clusters are tight and
    some diffuse.  Queries are held-out draws from the same mixture.
    """
    rng = np.random.default_rng(seed)
    centers = np.abs(rng.standard_normal((n_clusters, d))) * 1.5
    scales = np.exp(rng.normal(-0.7, 0.5, n_clusters))   # heavy-tailed
    weights = rng.dirichlet(np.full(n_clusters, 0.5))    # uneven sizes
    X, cid = _clustered_vectors(rng, n, d, n_clusters, weights, scales,
                                centers)
    Q, _ = _clustered_vectors(rng, n_queries, d, n_clusters, weights,
                              scales, centers)
    # SIFT is non-negative (gradient histogram magnitudes)
    return np.abs(X), np.abs(Q), cid


def pseudo_glove(n: int = 20000, n_queries: int = 256, d: int = 300,
                 n_clusters: int = 128, seed: int = 0):
    """GloVe-like workload: (db (n, d), queries (nq, d), db_cluster_ids).

    Dense signed embeddings with Zipf-weighted cluster sizes (word
    frequency is Zipfian, and frequent-word neighborhoods are denser)
    and a broad norm spread across clusters.
    """
    rng = np.random.default_rng(seed + 101)
    centers = rng.standard_normal((n_clusters, d)) * 1.2
    scales = np.exp(rng.normal(-0.5, 0.4, n_clusters))
    ranks = np.arange(1, n_clusters + 1, dtype=np.float64)
    weights = (1.0 / ranks) / np.sum(1.0 / ranks)        # Zipf sizes
    X, cid = _clustered_vectors(rng, n, d, n_clusters, weights, scales,
                                centers)
    Q, _ = _clustered_vectors(rng, n_queries, d, n_clusters, weights,
                              scales, centers)
    return X, Q, cid


def skewed_queries(db: np.ndarray, db_cluster_ids: np.ndarray,
                   n_queries: int = 256, *, alpha: float = 1.5,
                   noise: float = 0.15, seed: int = 0):
    """Power-law-skewed query workload over an existing clustered db.

    Cluster popularity ~ rank^-alpha over the clusters present in
    ``db_cluster_ids`` (rank order randomized by ``seed``), so a few
    clusters dominate the traffic — the hot-key pattern production
    query logs show.  Each query is a db point from the sampled cluster
    plus Gaussian noise scaled by ``noise`` times the db's global std.
    Returns (queries (n_queries, d) f32, query_cluster_ids).
    """
    rng = np.random.default_rng(seed + 7)
    clusters = np.unique(db_cluster_ids)
    ranks = rng.permutation(len(clusters)) + 1.0
    pop = ranks ** -float(alpha)
    pop /= pop.sum()
    qcid = rng.choice(clusters, size=n_queries, p=pop)
    sigma = float(np.std(db)) * noise
    out = np.empty((n_queries, db.shape[1]), np.float32)
    for i, c in enumerate(qcid):
        rows = np.nonzero(db_cluster_ids == c)[0]
        base = db[rng.choice(rows)]
        out[i] = base + sigma * rng.standard_normal(db.shape[1])
    return out, qcid.astype(np.int32)


def pseudo_cifar(n_train: int = 10000, n_test: int = 2000, seed: int = 0
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(x_train (n,3072), y_train, x_test, y_test), values in [0,1]."""
    rng = np.random.default_rng(seed + 17)
    hw = 32
    yy, xx = np.meshgrid(np.arange(hw), np.arange(hw), indexing="ij")
    palettes = rng.uniform(0.1, 0.9, size=(10, 3))
    freqs = rng.uniform(0.15, 0.8, size=(10,))
    angles = rng.uniform(0, np.pi, size=(10,))

    def sample(n):
        y = rng.integers(0, 10, n).astype(np.int32)
        xs = np.empty((n, hw * hw * 3), np.float32)
        for i in range(n):
            c = y[i]
            phase = rng.uniform(0, 2 * np.pi)
            ang = angles[c] + rng.uniform(-0.2, 0.2)
            grating = 0.5 + 0.5 * np.sin(
                freqs[c] * (np.cos(ang) * xx + np.sin(ang) * yy) + phase)
            img = grating[:, :, None] * palettes[c][None, None, :]
            img = img + 0.1 * rng.standard_normal((hw, hw, 3))
            xs[i] = np.clip(img, 0, 1).ravel()
        return xs, y

    x_tr, y_tr = sample(n_train)
    x_te, y_te = sample(n_test)
    return x_tr, y_tr, x_te, y_te
