"""Guyon-style synthetic classification datasets (paper Table 1).

Reimplements the NIPS-2003 variable-selection benchmark generator
(Guyon 2003 — the method behind sklearn's ``make_classification``):

  - ``n_informative`` dimensions: class centroids placed at the vertices
    of a hypercube of side 2*class_sep, Gaussian clusters around them;
  - redundant dimensions: random linear combinations of the informative
    ones;
  - the remaining dimensions: pure noise;
  - optional random rotation/shuffle of columns.

Table 1: three datasets, 10000 train / 1000 test, 64 features, with
32 / 16 / 8 informative features.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

SYNTHETIC_DATASETS: Dict[str, Dict] = {
    "dataset1": dict(n_train=10000, n_test=1000, n_features=64,
                     n_informative=32, n_classes=10, seed=1),
    "dataset2": dict(n_train=10000, n_test=1000, n_features=64,
                     n_informative=16, n_classes=10, seed=2),
    "dataset3": dict(n_train=10000, n_test=1000, n_features=64,
                     n_informative=8, n_classes=10, seed=3),
}


def guyon_dataset(n_samples: int, n_features: int, n_informative: int,
                  n_classes: int = 10, n_redundant: int | None = None,
                  class_sep: float = 1.5, seed: int = 0,
                  shuffle_features: bool = True) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (X (n, n_features) float32, y (n,) int32)."""
    rng = np.random.default_rng(seed)
    if n_redundant is None:
        n_redundant = max((n_features - n_informative) // 2, 0)
    n_noise = n_features - n_informative - n_redundant
    assert n_noise >= 0

    # class centroids on hypercube vertices (random subset of corners)
    corners = rng.integers(0, 2, size=(n_classes, n_informative)).astype(np.float64)
    centroids = (2.0 * corners - 1.0) * class_sep
    # per-class random covariance shaping (as in Guyon's generator)
    y = rng.integers(0, n_classes, size=n_samples).astype(np.int32)
    X_inf = rng.standard_normal((n_samples, n_informative))
    for c in range(n_classes):
        idx = y == c
        A = rng.uniform(-1, 1, size=(n_informative, n_informative))
        X_inf[idx] = X_inf[idx] @ A * 0.5 + centroids[c]

    parts = [X_inf]
    if n_redundant:
        B = rng.uniform(-1, 1, size=(n_informative, n_redundant))
        parts.append(X_inf @ B / np.sqrt(n_informative))
    if n_noise:
        parts.append(0.1 * rng.standard_normal((n_samples, n_noise)))
    X = np.concatenate(parts, axis=1)

    if shuffle_features:
        perm = rng.permutation(n_features)
        X = X[:, perm]
    return X.astype(np.float32), y


def make_table1_dataset(name: str):
    """One of the paper's Table-1 datasets -> (x_train, y_train, x_test, y_test)."""
    spec = SYNTHETIC_DATASETS[name]
    n = spec["n_train"] + spec["n_test"]
    X, y = guyon_dataset(n, spec["n_features"], spec["n_informative"],
                         spec["n_classes"], seed=spec["seed"])
    nt = spec["n_train"]
    return X[:nt], y[:nt], X[nt:], y[nt:]


def make_synthetic_index(key, n: int, d: int = 16, K: int = 8, m: int = 256,
                         num_fast: int = 2, sigma: float = 0.5):
    """Random packed ICQ index + structure for serving/benchmark smoke
    paths (launch/serve.py --ann, benchmarks/run.py search).

    Returns (codes (n,K) packed via encode.pack_codes — uint8 for
    m <= 256, C (K,m,d) f32, ICQStructure).  One shared fixture so the
    benchmark and the serving demo cannot diverge.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.encode import pack_codes
    from repro.core.icq import ICQStructure

    C = jax.random.normal(key, (K, m, d)) * (1.0 / np.sqrt(K))
    codes = pack_codes(
        jax.random.randint(jax.random.fold_in(key, 1), (n, K), 0, m), m)
    fast = jnp.zeros((K,), bool).at[:num_fast].set(True)
    structure = ICQStructure(xi=jnp.ones((d,), bool), fast_mask=fast,
                             sigma=jnp.asarray(sigma))
    return codes, C, structure
