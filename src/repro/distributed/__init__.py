"""Distributed runtime: sharding rules, checkpointing, fault tolerance,
elastic re-meshing."""
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        param_shardings, replicated)
from repro.distributed.checkpoint import (CheckpointManager, flatten_pytree,
                                          unflatten_pytree)
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               TrainSupervisor)
from repro.distributed.elastic import (make_elastic_mesh, plan_mesh_shape,
                                       reshard_state)

__all__ = [
    "batch_shardings", "cache_shardings", "param_shardings", "replicated",
    "CheckpointManager", "flatten_pytree", "unflatten_pytree",
    "HeartbeatMonitor", "TrainSupervisor",
    "make_elastic_mesh", "plan_mesh_shape", "reshard_state",
]
