"""Checkpointing: npz-based, atomic, retention-managed, async-capable.

No orbax in this environment, so the manager is built directly:

  - pytrees are flattened to path-keyed arrays and written as one .npz
    per checkpoint step plus a JSON manifest (step, tree structure,
    dtypes, wall time, framework version);
  - writes go to ``step_XXXXXXXX.tmp/`` and are *renamed* into place —
    a crash mid-write never corrupts the latest checkpoint;
  - ``restore_latest`` scans manifests, skips incomplete/corrupt entries
    (fault tolerance: a node dying during save must not poison restart);
  - retention keeps the newest ``keep`` checkpoints plus every
    ``keep_period``-th step (for post-hoc analysis);
  - ``save_async`` ships the host copy to a background thread so the
    train loop only pays for the device->host transfer.

Multi-host note: under jax.distributed each host writes only the shards
it owns (addressable_shards); here (single-process CPU) that set is the
full tree, and the format is identical.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def flatten_pytree(tree) -> Dict[str, np.ndarray]:
    """Flatten a pytree to path-keyed host arrays (``a/b/0/c`` keys) —
    the on-disk layout shared by checkpoints and ``repro.api``
    artifacts (one npz per save, keys = tree paths)."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def unflatten_pytree(template, flat: Dict[str, np.ndarray]):
    """Inverse of ``flatten_pytree`` against a structural ``template``
    (leaf dtypes/shapes are restored from the template's leaves)."""
    return _unflatten(template, flat)


_flatten = flatten_pytree


def _unflatten(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(flat[key].astype(leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, keep_period: int = 0):
        self.dir = directory
        self.keep = keep
        self.keep_period = keep_period
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ paths --
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.dir):
            full = os.path.join(self.dir, name)
            if (name.startswith("step_") and not name.endswith(".tmp")
                    and os.path.exists(os.path.join(full, "manifest.json"))):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps)

    # ------------------------------------------------------------- save --
    def save(self, step: int, state: Any, *, extra: Optional[Dict] = None):
        """Blocking atomic save of a pytree ``state`` at ``step``."""
        host_state = jax.tree.map(np.asarray, state)
        self._write(step, host_state, extra or {})

    def save_async(self, step: int, state: Any, *,
                   extra: Optional[Dict] = None):
        """Device->host copy now; disk write on a background thread."""
        self.wait()                       # one in-flight save at a time
        host_state = jax.tree.map(np.asarray, state)
        self._thread = threading.Thread(
            target=self._write, args=(step, host_state, extra or {}),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_state, extra: Dict):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_state)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "num_arrays": len(flat),
            "bytes": int(sum(a.nbytes for a in flat.values())),
            **extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)             # atomic commit
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        doomed = steps[:-self.keep] if self.keep else []
        for s in doomed:
            if self.keep_period and s % self.keep_period == 0:
                continue
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---------------------------------------------------------- restore --
    def restore(self, step: int, template: Any) -> Any:
        path = self._step_dir(step)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten(template, flat)

    def restore_latest(self, template: Any) -> Tuple[Optional[int], Any]:
        """(step, state) of the newest *valid* checkpoint, or (None, template).

        Walks backwards over manifests so a truncated/corrupt newest
        checkpoint (crash during rename is impossible, but disk-full
        mid-npz is not) falls through to the previous one.
        """
        for step in reversed(self.all_steps()):
            try:
                return step, self.restore(step, template)
            except Exception:
                continue
        return None, template
