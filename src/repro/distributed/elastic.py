"""Elastic re-meshing: shrink/grow the device mesh when hosts leave or
join, preserving the logical sharding rules.

Policy: keep the 'model' axis at the largest size that still divides the
tensor-parallel dims (TP size is architecture-coupled: heads/d_ff must
divide it), absorb all remaining devices into 'data' (FSDP/DP shrink is
always safe), and drop stragglers to a power-of-two fleet so collectives
stay balanced.  Parameters move to the new mesh by device_put with the
re-derived NamedSharding — for a real fleet this is the
restore-from-checkpoint path (distributed.fault_tolerance), for in-
process shrink it is a resharding transfer.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

from repro.distributed import sharding as shrules


def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def plan_mesh_shape(num_devices: int, *, model_divisors: Sequence[int],
                    max_model: int = 16) -> Tuple[int, int]:
    """(data, model) for the surviving fleet.

    ``model_divisors``: dims that the model axis must divide (num_kv_heads,
    d_ff tiling, expert count ...).  Picks the largest power-of-two model
    size <= max_model dividing all of them and the device count.
    """
    usable = _pow2_floor(num_devices)
    model = _pow2_floor(max_model)
    while model > 1:
        if usable % model == 0 and all(d % model == 0 for d in model_divisors
                                       if d > 0):
            break
        model //= 2
    return usable // model, model


def make_elastic_mesh(devices=None, *, model_divisors: Sequence[int] = (),
                      max_model: int = 16) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    data, model = plan_mesh_shape(len(devices), model_divisors=model_divisors,
                                  max_model=max_model)
    import numpy as np
    grid = np.asarray(devices[: data * model]).reshape(data, model)
    return Mesh(grid, ("data", "model"))


def reshard_state(state, old_mesh: Mesh, new_mesh: Mesh, cfg=None):
    """Move a (params/opt) pytree onto ``new_mesh`` under the same logical
    rules.  On a single controller this is a device_put; multi-controller
    recovery goes through the checkpoint instead (same sharding specs)."""
    shardings = shrules.param_shardings(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state),
        new_mesh)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, shardings)
