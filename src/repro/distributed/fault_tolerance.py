"""Fault tolerance: heartbeat/straggler monitoring and a supervised
train-loop wrapper with checkpoint-restart.

At thousand-node scale the failure model is: (a) hard node loss (process
gone), (b) stragglers (a host running 2-10x slow — failing NIC, thermal
throttle), (c) data-poisoned steps (NaN loss).  The pieces here:

  ``HeartbeatMonitor``  — per-host step heartbeats; a host is a straggler
      when its step latency exceeds ``straggler_factor`` x the rolling
      median of the fleet, and dead when silent for ``dead_after`` s.
      (Transport is a pluggable callback; production = shared filesystem
      or KV store, tests = in-process.)
  ``TrainSupervisor``   — wraps a step function with: auto-resume from
      the newest valid checkpoint, periodic (async) checkpointing, NaN
      step quarantine (skip + re-randomize data order), bounded restart
      attempts on injected/real faults, and an on_remesh hook that the
      elastic layer (distributed.elastic) uses to drop dead hosts.

The supervisor is deliberately synchronous-SPMD-shaped: recovery always
funnels through "restore checkpoint -> rebuild mesh -> replay data
stream from step index", which is the only strategy that stays correct
for fully-sharded (FSDP/TP) states.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.distributed.checkpoint import CheckpointManager


class HeartbeatMonitor:
    def __init__(self, num_hosts: int, straggler_factor: float = 3.0,
                 dead_after: float = 300.0, window: int = 32):
        self.num_hosts = num_hosts
        self.straggler_factor = straggler_factor
        self.dead_after = dead_after
        self.window = window
        self._latency: Dict[int, List[float]] = {h: [] for h in range(num_hosts)}
        self._last_seen: Dict[int, float] = {h: time.time() for h in range(num_hosts)}

    def beat(self, host: int, step_latency: float, now: Optional[float] = None):
        now = time.time() if now is None else now
        lat = self._latency[host]
        lat.append(step_latency)
        if len(lat) > self.window:
            del lat[: len(lat) - self.window]
        self._last_seen[host] = now

    def fleet_median(self) -> float:
        all_lat = [l for ls in self._latency.values() for l in ls[-8:]]
        return float(np.median(all_lat)) if all_lat else 0.0

    def stragglers(self) -> List[int]:
        med = self.fleet_median()
        if med <= 0:
            return []
        out = []
        for h, ls in self._latency.items():
            if ls and np.median(ls[-4:]) > self.straggler_factor * med:
                out.append(h)
        return out

    def dead(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        return [h for h, t in self._last_seen.items()
                if now - t > self.dead_after]


@dataclasses.dataclass
class SupervisorReport:
    final_step: int
    restarts: int
    nan_skips: int
    resumed_from: Optional[int]


class TrainSupervisor:
    """Checkpoint-restart wrapper around a pure step function.

    step_fn(state, step_idx) -> (state, metrics) — metrics must contain
    'loss'.  ``fault_hook(step)`` may raise to simulate node loss (tests).
    """

    def __init__(self, ckpt: CheckpointManager, *, save_every: int = 50,
                 max_restarts: int = 3, async_save: bool = True,
                 on_remesh: Optional[Callable[[], None]] = None):
        self.ckpt = ckpt
        self.save_every = save_every
        self.max_restarts = max_restarts
        self.async_save = async_save
        self.on_remesh = on_remesh

    def run(self, state: Any, step_fn: Callable, num_steps: int, *,
            fault_hook: Optional[Callable[[int], None]] = None
            ) -> "tuple[Any, SupervisorReport]":
        resumed_from, state = self.ckpt.restore_latest(state)
        start = (resumed_from + 1) if resumed_from is not None else 0
        restarts = 0
        nan_skips = 0
        step = start
        while step < num_steps:
            try:
                if fault_hook is not None:
                    fault_hook(step)
                new_state, metrics = step_fn(state, step)
                loss = float(metrics.get("loss", 0.0))
                if not np.isfinite(loss):
                    nan_skips += 1      # quarantine: drop the update
                else:
                    state = new_state
                if step % self.save_every == 0 and step > start:
                    if self.async_save:
                        self.ckpt.save_async(step, state)
                    else:
                        self.ckpt.save(step, state)
                step += 1
            except Exception:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                if self.on_remesh is not None:
                    self.on_remesh()    # elastic: drop dead hosts, re-lower
                prev, state = self.ckpt.restore_latest(state)
                step = (prev + 1) if prev is not None else 0
        self.ckpt.wait()
        self.ckpt.save(num_steps - 1, state)
        return state, SupervisorReport(final_step=num_steps - 1,
                                       restarts=restarts,
                                       nan_skips=nan_skips,
                                       resumed_from=resumed_from)
