"""Logical-axis sharding rules: param/optimizer/cache/batch PartitionSpecs.

Strategy (DESIGN.md §6): 2-D (data, model) mesh per pod, plus an outer
"pod" axis for cross-pod data parallelism.  Parameters are *fully
sharded* — TP dims over "model" (Megatron-style: column-parallel in,
row-parallel out; experts over "model" = EP) and the remaining large dim
over "data" (FSDP / ZeRO-3).  Every rule is divisibility-guarded: a dim
that doesn't divide its axis falls back to replication rather than
failing, so one rule-set serves all ten architectures.

KV caches shard batch over "data" and heads over "model" when the head
count divides, otherwise the *sequence* dim over "model" (context-
parallel decode: GSPMD inserts the softmax partial-reduce collectives).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class MeshView:
    """A mesh facade hiding some axes from the sharding rules — used
    inside shard_map regions that are *manual* over those axes (sharding
    constraints there may only reference the auto axes).  ``base`` is
    the physical mesh handed to NamedSharding."""

    def __init__(self, base, hidden=()):
        self.base = base
        self._hidden = set(hidden)

    @property
    def axis_names(self):
        return tuple(a for a in self.base.axis_names
                     if a not in self._hidden)

    @property
    def shape(self):
        return {k: v for k, v in self.base.shape.items()
                if k not in self._hidden}


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def shard_map_compat(fn, mesh, in_specs, out_specs, axis_names=None):
    """``jax.shard_map`` across the jax versions this repo meets: the
    top-level API with ``check_vma`` (newer), with ``check_rep``, or the
    ``jax.experimental.shard_map`` fallback.  Replication checking is
    disabled uniformly — our regions end in all_gather/psum so outputs
    *are* replicated, which older checkers cannot always prove.

    ``axis_names`` (optional): the mesh axes the region is *manual*
    over (partial-manual shard_map; the rest stay GSPMD-auto).  Newer
    jax spells this ``axis_names={...}``, the experimental fallback
    spells it ``auto=<complement>``."""
    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        try:
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False, **kw)
        except TypeError:
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = ({} if axis_names is None
          else {"auto": frozenset(mesh.axis_names) - set(axis_names)})
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False, **kw)


def make_mesh_auto(sizes: Sequence[int], names: Sequence[str]):
    """``jax.make_mesh`` with all-Auto axis types across jax versions:
    newer jax needs ``axis_types=(AxisType.Auto, ...)`` for meshes whose
    regions mix sharding constraints with shard_map; 0.4.x has neither
    ``AxisType`` nor the ``axis_types`` parameter (its meshes are
    implicitly auto)."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(tuple(sizes), tuple(names),
                             axis_types=(AxisType.Auto,) * len(tuple(names)))
    except (ImportError, TypeError, AttributeError):
        return jax.make_mesh(tuple(sizes), tuple(names))


def abstract_mesh(sizes: Sequence[int], names: Sequence[str]):
    """``jax.sharding.AbstractMesh`` across the signature change: newer
    jax takes ``(axis_sizes, axis_names)``, older jax takes one
    ``((name, size), ...)`` shape tuple."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(sizes), tuple(names))
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


def maybe(axis, dim: int, mesh: Mesh):
    """Shard `dim` over `axis` only if it divides evenly."""
    if axis is None:
        return None
    sizes = [axis_size(mesh, a) for a in (axis if isinstance(axis, tuple) else (axis,))]
    total = 1
    for s in sizes:
        total *= s
    return axis if total > 1 and dim % total == 0 else None


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
    return "/".join(parts)


# ------------------------------------------------------------------ params

def fsdp_axes(mesh: Mesh, fsdp_over_pod: bool = True):
    """The FSDP axis set: in-pod 'data', plus 'pod' when present — at
    405B scale the parameters/optimizer must shard over *all* data-
    parallel devices (ZeRO-3 across pods) to fit 16 GB/chip.

    ``fsdp_over_pod=False`` keeps params replicated across pods (pure
    cross-pod DP): required by the compressed gradient-exchange variant,
    where pods only communicate int8 gradient shards."""
    if "pod" in mesh.axis_names and fsdp_over_pod:
        return ("pod", "data")
    return "data"


def param_pspec(path, leaf, mesh: Mesh, fsdp_over_pod: bool = True) -> P:
    """Rule table keyed on the trailing param name; specs cover trailing
    dims and are left-padded with None (stacked layer axes unsharded).
    'data' in the table means the FSDP axis set (pod+data on the
    multi-pod mesh)."""
    name = _path_str(path)
    last = name.rsplit("/", 1)[-1]
    shape = leaf.shape
    nd = len(shape)

    fsdp = fsdp_axes(mesh, fsdp_over_pod)

    def spec(*trailing):
        trailing = ["data" if t == "data" else t for t in trailing]
        trailing = [fsdp if t == "data" else t for t in trailing]
        assert len(trailing) <= nd, (name, shape, trailing)
        full = [None] * (nd - len(trailing)) + trailing
        full = [maybe(a, shape[i], mesh) for i, a in enumerate(full)]
        return P(*full)

    if nd == 0 or last in ("A_log", "dt_bias", "lambda"):
        return P()
    # --- embeddings / heads ---
    if last in ("embed",):
        return spec("model", "data")                 # (V, d)
    if last == "head":
        return spec("data", "model")                 # (d, V)
    if last in ("enc_pos", "dec_pos"):
        return spec(None, "data")
    if last == "vis_proj":
        return spec(None, "model")
    # --- attention ---
    if last in ("wq", "wk", "wv"):
        return spec("data", "model")
    if last == "wo":
        return spec("model", "data")
    # --- MLA ---
    if last in ("w_dq", "w_dkv"):
        return spec("data", None)
    if last in ("w_uq", "w_uk", "w_uv"):
        return spec("data", "model")
    # --- MoE experts (E, d, f) / (E, f, d); router replicated ---
    if last == "router":
        return P(*([None] * nd))
    if last in ("we_gate", "we_up"):
        return spec("model", "data", None)           # E -> model (EP)
    if last == "we_down":
        return spec("model", None, "data")
    if last in ("w_gate", "w_up"):
        return spec("data", "model")
    if last == "w_down":
        return spec("model", "data")
    # --- SSM ---
    if last == "w_in":
        return spec("data", "model")
    if last == "conv_w":
        return spec(None, "model")
    if last in ("conv_b", "D"):
        return spec("model")
    if last == "w_out":
        return spec("model", "data")
    # --- RG-LRU ---
    if last in ("w_x", "w_gate_branch"):
        return spec("data", "model")
    if last == "w" and ("rg" in name or "ig" in name):
        return spec("model", None, None)             # (nb, bw, bw)
    if last == "b" and ("rg" in name or "ig" in name):
        return spec("model", None)
    # --- plain MLP biases ---
    if last == "b_up":
        return spec("model")
    if last == "b_down":
        return spec("data")
    if last == "w_up" or last == "w_gate":
        return spec("data", "model")
    # norms / everything small: replicated
    return P(*([None] * nd))


def param_shardings(params_shape, mesh: Mesh, fsdp_over_pod: bool = True):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, param_pspec(p, l, mesh,
                                                     fsdp_over_pod)),
        params_shape)


# ------------------------------------------------------------------ caches

def cache_pspec(path, leaf, cfg, mesh: Mesh) -> P:
    name = _path_str(path)
    last = name.rsplit("/", 1)[-1]
    shape = leaf.shape
    nd = len(shape)
    msize = axis_size(mesh, "model")

    def pad(*trailing):
        trailing = list(trailing)
        full = [None] * (nd - len(trailing)) + trailing
        full = [maybe(a, shape[i], mesh) for i, a in enumerate(full)]
        return P(*full)

    if last == "pos" or nd == 0:
        return P()
    if last in ("k", "v"):                           # (..., b, S, kvh, dh)
        if cfg.num_kv_heads % max(msize, 1) == 0 and cfg.num_kv_heads >= msize:
            return pad("data", None, "model", None)
        return pad("data", "model", None, None)      # context-parallel S
    if last == "k_pos":                              # (..., b, S)
        if cfg.num_kv_heads % max(msize, 1) == 0 and cfg.num_kv_heads >= msize:
            return pad("data", None)
        return pad("data", "model")
    if last in ("ck", "cv"):                         # (..., b, Senc, kvh, dh)
        return pad("data", None, None, "model")      # dh -> model
    if last in ("latent", "k_rope"):                 # (..., b, S, r)
        return pad("data", "model", None)
    if last == "state":                              # ssm (..., b, h, p, n)
        return pad("data", "model", None, None)
    if last == "h":                                  # rglru (..., b, w)
        return pad("data", "model")
    if last == "conv":                               # (..., b, w-1, c)
        return pad("data", None, "model")
    return P(*([None] * nd))


def cache_shardings(cache_shape, cfg, mesh: Mesh):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, cache_pspec(p, l, cfg, mesh)), cache_shape)


# ------------------------------------------------------------------ batch

def batch_pspec(leaf, mesh: Mesh) -> P:
    shape = leaf.shape
    if len(shape) == 0:
        return P()
    ba = batch_axes(mesh)
    first = maybe(ba if len(ba) > 1 else ba[0], shape[0], mesh)
    return P(first, *([None] * (len(shape) - 1)))


def batch_shardings(batch_shape, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, batch_pspec(l, mesh)), batch_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
