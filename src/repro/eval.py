"""Evaluation core for recall/QPS benchmarking and tuning.

Pure, oracle-tested primitives shared by ``benchmarks/sweep.py``, the
per-figure benchmark scripts (via ``benchmarks/common.py``), and
``ICQSession.tune`` (docs/api.md):

  - ``recall_at_k``            set-overlap recall with -1 padding and
                               k > n handling;
  - ``tie_aware_recall_at_k``  distance-tie tolerant recall — any id
                               whose exact distance ties the k-th true
                               neighbor counts as a hit;
  - ``ground_truth`` /         brute-force (optionally filtered) exact
    ``cached_ground_truth``    neighbors, with an on-disk npz cache
                               keyed by the content of (db, queries, k,
                               filter);
  - ``pareto_frontier`` /      monotone recall-vs-QPS frontier
    ``select_operating_point`` extraction and faiss-style operating
                               point selection.

Everything here is host-side numpy on purpose: these functions score and
select, they never run inside jit.
"""
from __future__ import annotations

import hashlib
import os
from typing import Optional, Sequence

import numpy as np


def recall_at_k(retrieved, truth, k: Optional[int] = None):
    """Mean recall@k: |retrieved[:k] ∩ truth[:k]| / |valid truth[:k]|.

    retrieved: (nq, r) ids; truth: (nq, t) ids.  Entries ``< 0`` are
    padding (absent neighbors — e.g. a filtered search with fewer than
    k eligible rows, or ground truth over a database with n < k) and
    never count as hits nor toward the denominator.  ``k`` defaults to
    the retrieved width; ``k`` larger than either width just uses every
    available column — recall@k with k > n is measured against the n
    true neighbors that exist.  A query with an empty valid truth set
    scores recall 1.0 (vacuously complete).
    """
    r = np.asarray(retrieved)
    t = np.asarray(truth)
    if r.ndim != 2 or t.ndim != 2 or r.shape[0] != t.shape[0]:
        raise ValueError(f"recall_at_k: expected (nq, r) retrieved and "
                         f"(nq, t) truth with matching nq, got "
                         f"{r.shape} and {t.shape}")
    if k is not None:
        if k <= 0:
            raise ValueError(f"recall_at_k: k must be positive, got {k}")
        r, t = r[:, :k], t[:, :k]
    valid_t = t >= 0
    hits = (r[:, :, None] == t[:, None, :]) & valid_t[:, None, :] \
        & (r >= 0)[:, :, None]
    inter = hits.any(axis=1).sum(axis=1)          # truth ids recovered
    n_true = valid_t.sum(axis=1)
    per_q = np.where(n_true > 0, inter / np.maximum(n_true, 1), 1.0)
    return float(per_q.mean())


def tie_aware_recall_at_k(retrieved, queries, db, k: int, *,
                          filter=None, rtol: float = 1e-6):
    """Recall@k that accepts any ordering among distance ties.

    A retrieved id counts as a hit iff its exact L2 distance is within
    ``rtol`` (relative, plus absolute 1e-9) of the k-th smallest exact
    distance — so when several rows tie at the boundary, an engine may
    return any of them without being penalized.  The denominator is
    ``min(k, #eligible rows)``.  ``filter``: optional (n,) bool row
    predicate (filtered oracle).
    """
    q = np.asarray(queries, np.float64)
    x = np.asarray(db, np.float64)
    r = np.asarray(retrieved)[:, :k]
    d2 = (np.sum(q * q, -1)[:, None] - 2.0 * q @ x.T
          + np.sum(x * x, -1)[None, :])           # (nq, n)
    if filter is not None:
        pred = np.asarray(filter, bool)
        d2 = np.where(pred[None, :], d2, np.inf)
    n_valid = np.isfinite(d2).sum(axis=1)
    kth = np.partition(d2, min(k, d2.shape[1]) - 1,
                       axis=1)[:, min(k, d2.shape[1]) - 1]   # (nq,)
    recalls = []
    for i in range(r.shape[0]):
        denom = min(k, int(n_valid[i]))
        if denom == 0:
            recalls.append(1.0)
            continue
        ids = r[i][r[i] >= 0]
        thresh = kth[i] * (1.0 + rtol) + 1e-9
        hits = int(np.sum(d2[i, ids] <= thresh)) if len(ids) else 0
        recalls.append(min(hits, denom) / denom)
    return float(np.mean(recalls))


def ground_truth(db, queries, k: int, *, filter=None,
                 query_chunk: Optional[int] = 128):
    """Exact L2 top-k over ``db`` ((n, d)) for ``queries`` ((nq, d)),
    optionally restricted to rows where ``filter`` is True.

    Returns (ids (nq, k) int64, distances (nq, k) f32), padded with
    id -1 / distance +inf when fewer than k rows exist (n < k, or the
    filter passes fewer than k rows) — the exact shape ``recall_at_k``
    expects as ``truth``.
    """
    import jax.numpy as jnp
    from repro.index.base import exact_search
    db_j = jnp.asarray(db)
    q_j = jnp.asarray(queries)
    n = db_j.shape[0]
    eff_k = min(k, n)
    ids, dist = exact_search(q_j, db_j, eff_k, query_chunk=query_chunk,
                             filter=filter)
    ids = np.asarray(ids, np.int64)
    dist = np.asarray(dist, np.float32)
    # normalize padding: absent slots are (-1, +inf)
    ids = np.where(np.isinf(dist), -1, ids)
    if eff_k < k:
        pad = k - eff_k
        ids = np.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
        dist = np.pad(dist, ((0, 0), (0, pad)),
                      constant_values=np.inf)
    return ids, dist


def _gt_cache_key(db, queries, k: int, filter) -> str:
    h = hashlib.sha256()
    for part in (np.ascontiguousarray(np.asarray(db, np.float32)),
                 np.ascontiguousarray(np.asarray(queries, np.float32))):
        h.update(str(part.shape).encode())
        h.update(part.tobytes())
    h.update(f"k={k}".encode())
    if filter is not None:
        h.update(np.ascontiguousarray(
            np.asarray(filter, bool)).tobytes())
    return h.hexdigest()[:24]


def cached_ground_truth(db, queries, k: int, *, cache_dir: Optional[str],
                        filter=None, query_chunk: Optional[int] = 128):
    """``ground_truth`` with an on-disk npz cache.

    The cache key is the sha256 of the *contents* of (db, queries, k,
    filter), so a stale file can never be returned for different data.
    ``cache_dir=None`` disables caching.  Returns (ids, distances,
    cache_hit: bool).
    """
    if cache_dir is None:
        ids, dist = ground_truth(db, queries, k, filter=filter,
                                 query_chunk=query_chunk)
        return ids, dist, False
    path = os.path.join(cache_dir,
                        f"gt_{_gt_cache_key(db, queries, k, filter)}.npz")
    if os.path.exists(path):
        with np.load(path) as z:
            return z["ids"], z["distances"], True
    ids, dist = ground_truth(db, queries, k, filter=filter,
                             query_chunk=query_chunk)
    os.makedirs(cache_dir, exist_ok=True)
    tmp = path + ".tmp.npz"         # savez appends .npz unless present
    np.savez(tmp, ids=ids, distances=dist)
    os.replace(tmp, path)
    return ids, dist, False


def pareto_frontier(points: Sequence[dict], *, x: str = "qps",
                    y: str = "recall"):
    """Indices of the Pareto-optimal points of ``points`` (maximize
    both ``x`` and ``y``), ordered by descending ``x``.

    The returned frontier is monotone by construction: walking it from
    the fastest point to the slowest, ``y`` strictly increases — i.e.
    recall is non-decreasing as QPS decreases.  Dominated and duplicate
    points are dropped.
    """
    order = sorted(range(len(points)),
                   key=lambda i: (-points[i][x], -points[i][y]))
    keep, best_y = [], -np.inf
    for i in order:
        if points[i][y] > best_y:
            keep.append(i)
            best_y = points[i][y]
    return keep


def is_monotone_frontier(points: Sequence[dict], *, x: str = "qps",
                         y: str = "recall") -> bool:
    """True iff ``points`` sorted by descending ``x`` have
    non-decreasing ``y`` — the shape ``pareto_frontier`` guarantees."""
    srt = sorted(points, key=lambda p: -p[x])
    ys = [p[y] for p in srt]
    return all(b >= a for a, b in zip(ys, ys[1:]))


def select_operating_point(points: Sequence[dict], target: float, *,
                           x: str = "qps", y: str = "recall"):
    """faiss-style selection: the index of the max-``x`` point whose
    ``y`` meets ``target``; falls back to the max-``y`` point (ties
    broken toward higher ``x``) when none reaches the target.  Returns
    (index, met_target: bool); raises on an empty sweep."""
    if not points:
        raise ValueError("select_operating_point: empty sweep")
    eligible = [i for i in range(len(points)) if points[i][y] >= target]
    if eligible:
        return max(eligible, key=lambda i: points[i][x]), True
    return max(range(len(points)),
               key=lambda i: (points[i][y], points[i][x])), False
