"""Unified index layer (DESIGN.md §7): one ``Index`` protocol
(``build``/``search``/``shard``), three implementations, one backend
dispatch.

    from repro.index import make_index
    idx = make_index("ivf", codes, C, structure, emb_db=emb,
                     n_lists=256, n_probe=8)
    idx = idx.shard(mesh)                 # optional: data-parallel serve
    result = idx.search(queries)          # SearchResult

``core.search`` and ``core.ivf`` re-export everything here for
backward compatibility; new code should import from ``repro.index``.
The config-driven facade over this layer — sessions, persistent
artifacts, ``load_ann_engine`` — is ``repro.api`` (docs/api.md), which
re-exports the names most callers need (``make_index``,
``SearchResult``, the three index classes) at the package root.
"""
from repro.index.base import (CODE_BITS, Index, LUT_DTYPES, QuantizedLUT,
                              SearchResult, build_lut, chunked_over_queries,
                              exact_search, fastscan_kernel_operands,
                              lut_sum, mean_average_precision,
                              nibble_lut_sum, pad_luts_even, quantize_lut,
                              recall_at, resolve_backend, resolve_code_bits,
                              resolve_lut_dtype)
from repro.index.flat import (FlatADC, TwoStep, adc_search, two_step_search,
                              two_step_search_compact)
from repro.index.ivf import (IVFIndex, IVFTwoStep, build_ivf, ivf_assign,
                             ivf_extend, ivf_list_codes,
                             ivf_two_step_search)
from repro.index.pipelined import (PIPELINE_MODES, PipelinedSearch,
                                   maybe_pipelined, resolve_pipeline,
                                   resolve_tile)

INDEX_KINDS = {
    "flat": FlatADC,
    "two-step": TwoStep,
    "ivf": IVFTwoStep,
}


def make_index(kind: str, codes, C, structure=None, **opts):
    """Build an index by name: "flat" (one-step ADC), "two-step"
    (exhaustive ICQ), or "ivf" (coarse-partitioned ICQ; needs
    ``emb_db=`` and optionally ``key=``, ``n_lists=``)."""
    try:
        cls = INDEX_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown index kind {kind!r}; "
                         f"expected one of {sorted(INDEX_KINDS)}") from None
    return cls.build(codes, C, structure, **opts)


__all__ = [
    "Index", "SearchResult", "FlatADC", "TwoStep", "IVFTwoStep",
    "IVFIndex", "INDEX_KINDS", "CODE_BITS", "LUT_DTYPES", "QuantizedLUT",
    "make_index",
    "adc_search", "two_step_search", "two_step_search_compact",
    "ivf_two_step_search", "build_ivf", "ivf_assign", "ivf_extend",
    "ivf_list_codes", "build_lut",
    "lut_sum", "nibble_lut_sum", "pad_luts_even",
    "fastscan_kernel_operands", "quantize_lut", "exact_search",
    "chunked_over_queries", "resolve_backend", "resolve_code_bits",
    "resolve_lut_dtype", "mean_average_precision", "recall_at",
    "PIPELINE_MODES", "PipelinedSearch", "maybe_pipelined",
    "resolve_pipeline", "resolve_tile",
]
