"""Index-layer foundations: the ``Index`` protocol, the shared
``SearchResult`` record, ADC LUT primitives, backend resolution, query
chunking, exact ground truth, and retrieval metrics (DESIGN.md §7).

Every concrete index (``flat.FlatADC``, ``flat.TwoStep``,
``ivf.IVFTwoStep``) speaks the same three-verb protocol:

    build(...)            -> Index      classmethod constructor
    search(queries, topk) -> SearchResult
    shard(mesh)           -> Index      mesh-sharded serving clone

so serving entries (``quant/serve_icq.build_ann_engine``,
``launch/serve.py --ann``) select an index kind by name and never touch
engine internals.  All implementations route through the same
``jnp | pallas | auto`` backend dispatch.

The ADC math lives here (moved from ``core/search.py``, which is now a
thin re-export): per-query LUTs ``T[k, j] = ||c_{k,j}||^2 - 2 <q,
c_{k,j}>`` and their masked sums — ranking by the LUT sum is ranking by
L2 distance after ICQ's hard projection (cross terms constant).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp


class SearchResult(NamedTuple):
    indices: jnp.ndarray     # (nq, topk) database ids, nearest first
    distances: jnp.ndarray   # (nq, topk) LUT-sum distances (monotone in L2)
    avg_ops: jnp.ndarray     # scalar — average LUT adds per database point
    pass_rate: jnp.ndarray   # scalar — fraction refined (phase-2 survivors)


@runtime_checkable
class Index(Protocol):
    """The unified index protocol (DESIGN.md §7)."""

    def search(self, queries, topk: Optional[int] = None) -> SearchResult:
        ...

    def shard(self, mesh) -> "Index":
        ...


# ----------------------------------------------------------------- LUTs ----

def build_lut(q, C):
    """Per-query ADC tables.  q: (d,) or (nq,d); C: (K,m,d) -> (.., K, m)."""
    # lazy: repro.core re-exports this module's names, so a module-level
    # import here would cycle when repro.index is imported first
    from repro.core import codebooks as cb
    sq = cb.codeword_sq_norms(C)                             # (K,m)
    if q.ndim == 1:
        return sq - 2.0 * jnp.einsum("d,kmd->km", q, C)
    return sq[None] - 2.0 * jnp.einsum("qd,kmd->qkm", q, C)


def lut_sum(lut, codes, cb_mask=None):
    """Sum selected LUT entries — one vectorized ``take_along_axis``
    gather (vmap/batch friendly; no Python loop over codebooks).

    Shapes:
      lut (K,m),    codes (n,K)     -> (n,)
      lut (nq,K,m), codes (n,K)     -> (nq, n)   shared database codes
      lut (nq,K,m), codes (nq,t,K)  -> (nq, t)   per-query candidate codes

    ``cb_mask``: optional (K,) bool — restrict to a codebook subset
    (the fast group for crude distances).
    """
    codes = codes.astype(jnp.int32)
    if cb_mask is not None:
        lut = lut * cb_mask[:, None].astype(lut.dtype)
    if lut.ndim == 3 and codes.ndim == 2:
        # batched LUTs against the shared database codes: accumulate one
        # (nq, n) gather per codebook (lax.scan over K) instead of
        # materializing the (nq, K, n) gather, which blows the cache at
        # serving sizes (~4x slower measured at nq=64, n=100k)
        def step(acc, lut_and_codes):
            lut_k, codes_k = lut_and_codes               # (nq,m), (n,)
            return acc + jnp.take(lut_k, codes_k, axis=1), None
        acc0 = jnp.zeros((lut.shape[0], codes.shape[0]), lut.dtype)
        acc, _ = jax.lax.scan(step, acc0,
                              (jnp.swapaxes(lut, 0, 1), codes.T))
        return acc
    idx = jnp.swapaxes(codes, -1, -2)                        # (..., K, n)
    parts = jnp.take_along_axis(lut, idx, axis=-1)           # (..., K, n)
    return jnp.sum(parts, axis=-2)


# ------------------------------------------------------------- dispatch ----

def resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown search backend {backend!r}")
    return backend


def chunked_over_queries(fn, queries, query_chunk: Optional[int]):
    """Apply the vectorized ``fn`` to query blocks of ``query_chunk`` (a
    working-set bound for huge batches); None = one block."""
    if query_chunk is None or queries.shape[0] <= query_chunk:
        return fn(queries)
    nq = queries.shape[0]
    pad = (-nq) % query_chunk
    qp = jnp.pad(queries, ((0, pad), (0, 0)))
    blocks = qp.reshape(-1, query_chunk, queries.shape[1])
    outs = jax.lax.map(fn, blocks)
    return jax.tree.map(
        lambda a: a.reshape((-1,) + a.shape[2:])[:nq], outs)


def exact_search(queries, X, topk: int, *,
                 query_chunk: Optional[int] = None):
    """Brute-force L2 ground truth.  queries: (nq,d), X: (n,d).

    ``query_chunk`` bounds the dense (nq, n) distance matrix to
    (query_chunk, n) blocks — ground-truth computation at benchmark
    sizes (nq x n = 64 x 1M) OOMs without it.
    """
    xsq = jnp.sum(jnp.square(X), -1)[None, :]

    def one_block(qs):
        d2 = (jnp.sum(jnp.square(qs), -1)[:, None]
              - 2.0 * qs @ X.T + xsq)
        neg, idx = jax.lax.top_k(-d2, topk)
        return idx, -neg

    return chunked_over_queries(one_block, queries, query_chunk)


# --------------------------------------------------------------- metrics ----

def mean_average_precision(retrieved_ids, db_labels, query_labels):
    """Label-based MAP (the paper's metric): a retrieved point is relevant
    iff it shares the query's class.  retrieved_ids: (nq, R)."""
    rel = (db_labels[retrieved_ids] == query_labels[:, None]).astype(jnp.float32)
    ranks = jnp.arange(1, rel.shape[1] + 1, dtype=jnp.float32)[None, :]
    cum = jnp.cumsum(rel, axis=1)
    prec_at = cum / ranks
    denom = jnp.maximum(jnp.sum(rel, axis=1), 1.0)
    ap = jnp.sum(prec_at * rel, axis=1) / denom
    return jnp.mean(ap)


def recall_at(retrieved_ids, true_ids):
    """Fraction of true nearest neighbors recovered.  Both (nq, R)."""
    hits = (retrieved_ids[:, :, None] == true_ids[:, None, :]).any(axis=1)
    return jnp.mean(hits.astype(jnp.float32))
