"""Index-layer foundations: the ``Index`` protocol, the shared
``SearchResult`` record, ADC LUT primitives, backend resolution, query
chunking, exact ground truth, and retrieval metrics (DESIGN.md §7).

Every concrete index (``flat.FlatADC``, ``flat.TwoStep``,
``ivf.IVFTwoStep``) speaks the same three-verb protocol:

    build(...)            -> Index      classmethod constructor
    search(queries, topk) -> SearchResult
    shard(mesh)           -> Index      mesh-sharded serving clone

so serving entries (``quant/serve_icq.build_ann_engine``,
``launch/serve.py --ann``) select an index kind by name and never touch
engine internals.  All implementations route through the same
``jnp | pallas | auto`` backend dispatch.

The ADC math lives here (moved from ``core/search.py``, which is now a
thin re-export): per-query LUTs ``T[k, j] = ||c_{k,j}||^2 - 2 <q,
c_{k,j}>`` and their masked sums — ranking by the LUT sum is ranking by
L2 distance after ICQ's hard projection (cross terms constant).

Quantized LUTs (DESIGN.md §8): ``quantize_lut`` calibrates a per-query
affine int8 form of the tables (Bolt / Quick-ADC style) and ``lut_sum``
accumulates the int8 entries in a narrow integer dtype before one
rescale back to true-distance units — the crude pass of the two-step
engines runs on these when ``lut_dtype="int8"``; the refine pass always
stays float32.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

LUT_DTYPES = ("f32", "int8")
CODE_BITS = (8, 4)


class SearchResult(NamedTuple):
    indices: jnp.ndarray     # (nq, topk) database ids, nearest first
    distances: jnp.ndarray   # (nq, topk) LUT-sum distances (monotone in L2)
    avg_ops: jnp.ndarray     # scalar — average LUT adds per database point
    pass_rate: jnp.ndarray   # scalar — fraction refined (phase-2 survivors)
    # Host-side resilience metadata (repro.resilience.budget.ResultMeta),
    # attached by the serving engine *outside* jit; None inside traced
    # index code (an empty pytree leaf-wise, so jit treats it as static).
    meta: Optional[object] = None


@runtime_checkable
class Index(Protocol):
    """The unified index protocol (DESIGN.md §7, §9).

    ``add`` is the incremental build surface: new vectors are encoded
    through the tiled engine (``core.encode.icm_encode``, PQ
    warm-started — exact for orthogonal-support codebooks too) and
    appended as rows (flat/two-step) or routed into the owning inverted
    lists (IVF) *without retraining*; a new index is returned (indexes
    are frozen).  Encoding is per-point, so an ``add`` produces search
    results identical to a from-scratch build over the concatenated
    data against the same codebooks (and, for IVF, the same coarse
    centroids)."""

    def search(self, queries, topk: Optional[int] = None) -> SearchResult:
        ...

    def add(self, new_vectors, *, icm_iters: int = 3) -> "Index":
        ...

    def shard(self, mesh) -> "Index":
        ...


# ----------------------------------------------------------------- LUTs ----

class QuantizedLUT(NamedTuple):
    """Per-query affine-int8 ADC tables (DESIGN.md §8).

    An f32 table ``T`` is calibrated per query from its min/max over the
    *summed* codebook subset: ``scale = (hi - lo) / 255``, and each
    entry is stored as ``q = round((T - lo) / scale) - 128`` in int8.
    Dequantization of a single entry is ``scale * q + bias`` with
    ``bias = lo + 128 * scale``; a sum over S selected entries is
    recovered *exactly in the bias term* as

        sum_T ~= scale * sum_q + S * bias

    so quantized crude distances stay in true-distance units and remain
    comparable against eq. 2 thresholds and across shards (the scale is
    query-global: it depends only on the query's LUT, never on which
    rows/lists a shard owns).

    Fields:
      q      int8 tables, same shape as the source LUT ((nq, K, m) or
             (K, m)); codebooks outside the calibration mask are zeroed
             so they contribute nothing to integer sums.
      scale  (nq,) (or scalar) f32 per-query step size, >= 1e-12.
      bias   (nq,) (or scalar) f32 per-*selected-entry* dequant offset.
    """
    q: jnp.ndarray
    scale: jnp.ndarray
    bias: jnp.ndarray


def resolve_lut_dtype(lut_dtype: str) -> str:
    """Validate the ``lut_dtype`` engine option ("f32" | "int8")."""
    if lut_dtype not in LUT_DTYPES:
        raise ValueError(f"unknown lut_dtype {lut_dtype!r}; "
                         f"expected one of {LUT_DTYPES}")
    return lut_dtype


def resolve_code_bits(code_bits) -> int:
    """Validate the ``code_bits`` storage option (8 | 4, DESIGN.md §12)."""
    if code_bits not in CODE_BITS:
        raise ValueError(f"unknown code_bits {code_bits!r}; "
                         f"expected one of {CODE_BITS}")
    return code_bits


def quantize_lut(lut, cb_mask=None) -> QuantizedLUT:
    """Per-query affine int8 calibration of ADC tables (DESIGN.md §8).

    lut:      (nq, K, m) or (K, m) f32 tables from ``build_lut``.
    cb_mask:  optional (K,) bool — calibrate min/max over (and keep
              only) this codebook subset; entries of masked-out
              codebooks are zeroed in the int8 table.  Pass the fast
              mask when the quantized table feeds a crude (fast-group)
              sum: the tighter range roughly halves the step size.

    Returns a ``QuantizedLUT``; the worst-case round-trip error of any
    kept entry is ``scale / 2`` (plus float rounding), so a sum over S
    entries is within ``S * scale / 2`` of the f32 sum.
    """
    red = tuple(range(lut.ndim - 2, lut.ndim))               # (K, m) axes
    if cb_mask is None:
        lo = jnp.min(lut, axis=red)
        hi = jnp.max(lut, axis=red)
    else:
        keep = cb_mask[:, None]                              # (K, 1)
        lo = jnp.min(jnp.where(keep, lut, jnp.inf), axis=red)
        hi = jnp.max(jnp.where(keep, lut, -jnp.inf), axis=red)
    scale = jnp.maximum((hi - lo) / 255.0, 1e-12)
    lo_b = lo[..., None, None]
    q = jnp.clip(jnp.round((lut - lo_b) / scale[..., None, None]) - 128.0,
                 -128.0, 127.0).astype(jnp.int8)
    if cb_mask is not None:
        q = q * cb_mask[:, None].astype(jnp.int8)
    return QuantizedLUT(q=q, scale=scale, bias=lo + 128.0 * scale)


def _bias_count(K: int, cb_mask):
    """Number of codebooks entering a quantized sum — the ``S`` of the
    accumulated-bias correction ``S * bias`` (DESIGN.md §8)."""
    return (jnp.asarray(float(K), jnp.float32) if cb_mask is None
            else jnp.sum(cb_mask.astype(jnp.float32)))


def dequantize_acc(qlut: QuantizedLUT, acc, cb_mask=None):
    """Rescale an integer LUT-sum accumulator to true-distance f32:
    ``scale * acc + count * bias`` — THE definition of the quantized
    dequant, shared by every jnp engine (``lut_sum``'s quantized body
    and the unrolled IVF loop); the fused kernels receive the identical
    (scale, offset) pair via ``quantized_kernel_operands`` and apply
    the same expression in the same order, which is what makes jnp /
    pallas / sharded int8 rankings bitwise-identical.

    acc: integer array whose *leading* dims broadcast against
    ``qlut.scale`` (e.g. (nq, n) acc with (nq,) scale, or (n,) acc
    with scalar scale)."""
    offset = _bias_count(qlut.q.shape[-2], cb_mask) * qlut.bias
    return (qlut.scale[..., None] * acc.astype(jnp.float32)
            + offset[..., None])


def quantized_kernel_operands(luts, cb_mask=None):
    """Calibrate ``luts`` ((nq, K, m) f32) and flatten into the fused
    crude kernels' operand triple: ``(q_flat (nq, K*m) int8, scale
    (nq,) f32, offset (nq,) f32)`` with ``offset = count * bias`` —
    the same accounting as ``dequantize_acc``."""
    qlut = quantize_lut(luts, cb_mask)
    nq, K, m = qlut.q.shape
    return (qlut.q.reshape(nq, K * m), qlut.scale,
            _bias_count(K, cb_mask) * qlut.bias)


def _int_acc_dtype(K: int):
    # |q| <= 128 per entry, so a K-codebook sum fits int16 whenever
    # K * 128 <= int16 max — true for every real config (K <= 255); the
    # narrow accumulator is the point of the quantized crude pass
    # (~half the accumulator traffic of f32/int32 on the CPU backend)
    return jnp.int16 if K * 128 <= jnp.iinfo(jnp.int16).max else jnp.int32


def build_lut(q, C):
    """Per-query ADC tables ``T[k, j] = ||c_{k,j}||^2 - 2 <q, c_{k,j}>``.

    q: (d,) or (nq, d) f32 queries; C: (K, m, d) codebooks ->
    (K, m) or (nq, K, m) f32.  Ranking by sums of these tables is
    ranking by L2 distance (the ``||q||^2`` term is constant per query).
    """
    # lazy: repro.core re-exports this module's names, so a module-level
    # import here would cycle when repro.index is imported first
    from repro.core import codebooks as cb
    sq = cb.codeword_sq_norms(C)                             # (K,m)
    if q.ndim == 1:
        return sq - 2.0 * jnp.einsum("d,kmd->km", q, C)
    return sq[None] - 2.0 * jnp.einsum("qd,kmd->qkm", q, C)


def lut_sum(lut, codes, cb_mask=None):
    """Sum selected LUT entries — one vectorized ``take_along_axis``
    gather (vmap/batch friendly; no Python loop over codebooks).

    Shapes (f32 ``lut`` array or ``QuantizedLUT`` whose ``q`` has the
    same shape):
      lut (K,m),    codes (n,K)     -> (n,)
      lut (nq,K,m), codes (n,K)     -> (nq, n)   shared database codes
      lut (nq,K,m), codes (nq,t,K)  -> (nq, t)   per-query candidate codes

    ``codes`` may arrive in any integer dtype (packed uint8 included);
    they are widened to int32 gather indices here.

    ``cb_mask``: optional (K,) bool — restrict to a codebook subset
    (the fast group for crude distances).

    Passing a ``QuantizedLUT`` (from ``quantize_lut``) accumulates the
    int8 entries in the narrowest exact integer dtype (int16 for
    K <= 255, else int32) and applies one affine rescale at the end:
    ``scale * acc + count * bias`` with ``count`` the number of summed
    codebooks — the result is in true-distance units (DESIGN.md §8).
    The mask the table was *calibrated* with must cover the mask summed
    over here (masked-out codebooks are zeroed in ``q``, so the integer
    sum skips them but ``count`` must only count kept ones).
    """
    if isinstance(lut, QuantizedLUT):
        return _lut_sum_quantized(lut, codes, cb_mask)
    codes = codes.astype(jnp.int32)
    if cb_mask is not None:
        lut = lut * cb_mask[:, None].astype(lut.dtype)
    if lut.ndim == 3 and codes.ndim == 2:
        # batched LUTs against the shared database codes: accumulate one
        # (nq, n) gather per codebook (lax.scan over K) instead of
        # materializing the (nq, K, n) gather, which blows the cache at
        # serving sizes (~4x slower measured at nq=64, n=100k)
        def step(acc, lut_and_codes):
            lut_k, codes_k = lut_and_codes               # (nq,m), (n,)
            return acc + jnp.take(lut_k, codes_k, axis=1), None
        acc0 = jnp.zeros((lut.shape[0], codes.shape[0]), lut.dtype)
        acc, _ = jax.lax.scan(step, acc0,
                              (jnp.swapaxes(lut, 0, 1), codes.T))
        return acc
    idx = jnp.swapaxes(codes, -1, -2)                        # (..., K, n)
    parts = jnp.take_along_axis(lut, idx, axis=-1)           # (..., K, n)
    return jnp.sum(parts, axis=-2)


def _lut_sum_quantized(qlut: QuantizedLUT, codes, cb_mask=None):
    """Integer-accumulating ``lut_sum`` body for ``QuantizedLUT``s.

    Masked-out codebooks are already zeroed in ``qlut.q`` (quantize_lut
    calibration mask), so the integer accumulation simply sums all K
    gathered entries; ``cb_mask`` only determines the bias count.  The
    final rescale ``scale * acc + (count * bias)`` is ordered exactly
    like the fused kernels' dequant so jnp and pallas agree bitwise.
    """
    q = qlut.q
    acc_dt = _int_acc_dtype(q.shape[-2])
    codes = codes.astype(jnp.int32)
    if q.ndim == 3 and codes.ndim == 2:
        def step(acc, q_and_codes):
            q_k, codes_k = q_and_codes                   # (nq,m), (n,)
            return acc + jnp.take(q_k, codes_k, axis=1).astype(acc_dt), None
        acc0 = jnp.zeros((q.shape[0], codes.shape[0]), acc_dt)
        acc, _ = jax.lax.scan(step, acc0,
                              (jnp.swapaxes(q, 0, 1), codes.T))
        return dequantize_acc(qlut, acc, cb_mask)
    idx = jnp.swapaxes(codes, -1, -2)                        # (..., K, n)
    parts = jnp.take_along_axis(q, idx, axis=-1)             # (..., K, n)
    acc = jnp.sum(parts.astype(acc_dt), axis=-2)
    return dequantize_acc(qlut, acc, cb_mask)


def pad_luts_even(luts):
    """Zero-pad the codebook axis of ``luts`` ((..., K, m) f32 or int8)
    to even K — the sentinel codebook of the nibble format (DESIGN.md
    §12).  Its entries are all zero, so a sentinel nibble (always code
    0) contributes nothing to any sum; bias/offset accounting keeps
    counting the *real* codebooks only."""
    K = luts.shape[-2]
    if K % 2 == 0:
        return luts
    pad = [(0, 0)] * (luts.ndim - 2) + [(0, 1), (0, 0)]
    return jnp.pad(luts, pad)


def fastscan_kernel_operands(luts, cb_mask=None):
    """Calibrate ``luts`` ((nq, K, m) f32, m <= 16) into the fast-scan
    kernels' operand triple: ``(q_flat (nq, Keven*m) int8, scale (nq,),
    offset (nq,))`` where Keven = K rounded up to even with an all-zero
    sentinel codebook.  scale/offset are identical to
    ``quantized_kernel_operands`` (the sentinel never enters the bias
    count), so the dequant expression — and therefore the ranking —
    matches the 8-bit int8 path bitwise."""
    qlut = quantize_lut(luts, cb_mask)
    nq, K, m = qlut.q.shape
    q_pad = pad_luts_even(qlut.q)
    return (q_pad.reshape(nq, -1), qlut.scale,
            _bias_count(K, cb_mask) * qlut.bias)


def nibble_lut_sum(lut, packed, K: int, cb_mask=None):
    """``lut_sum`` over nibble-packed codes (``code_bits=4``,
    DESIGN.md §12).

    packed: (n, ceil(K/2)) or (nq, t, ceil(K/2)) uint8 from
    ``pack_nibbles``; K is the real codebook count (the sentinel column
    of odd K never contributes).

    f32 ``lut``: unpack and defer to ``lut_sum`` — values identical to
    the 8-bit path.  ``QuantizedLUT`` with shared database codes: the
    fast path — a per-query *paired-byte* table ``pair[kp, b] =
    q[2kp, b & 15] + q[2kp+1, b >> 4]`` ((nq, ceil(K/2), 256) int16,
    exact: two int8 entries always fit int16) turns the K-gather scan
    into a ceil(K/2)-gather scan directly over the packed bytes.  The
    integer accumulator equals the unpack-then-``lut_sum`` accumulator
    term for term, and the final ``dequantize_acc`` rescale is the same
    expression in the same order, so jnp / pallas / sharded rankings
    stay bitwise-identical across code_bits.
    """
    from repro.core.encode import unpack_nibbles
    if not isinstance(lut, QuantizedLUT):
        return lut_sum(lut, unpack_nibbles(packed, K), cb_mask)
    q = lut.q
    if q.ndim != 3 or packed.ndim != 2:
        # per-query candidate codes (small t) or single-query tables:
        # the widened path is already cheap there
        return _lut_sum_quantized(lut, unpack_nibbles(packed, K), cb_mask)
    nq, Kq, m = q.shape
    if Kq != K:
        raise ValueError(f"nibble_lut_sum: table has {Kq} codebooks, "
                         f"got K={K}")
    if m > 16:
        raise ValueError(f"nibble_lut_sum needs m <= 16 codewords "
                         f"(4-bit codes), got m={m}")
    q_pad = pad_luts_even(q)
    if m < 16:
        # pad the codeword axis to 16 so every nibble value indexes
        # in-range (codes < m, so pad entries are never selected)
        q_pad = jnp.pad(q_pad, ((0, 0), (0, 0), (0, 16 - m)))
    lo_q = q_pad[:, 0::2, :].astype(jnp.int16)           # (nq, Kp, 16)
    hi_q = q_pad[:, 1::2, :].astype(jnp.int16)
    pair = (hi_q[:, :, :, None]
            + lo_q[:, :, None, :]).reshape(nq, -1, 256)  # (nq, Kp, 256)
    acc_dt = _int_acc_dtype(K)
    codes = packed.astype(jnp.int32)

    def step(acc, pair_and_codes):
        pair_kp, codes_kp = pair_and_codes               # (nq,256), (n,)
        return acc + jnp.take(pair_kp, codes_kp,
                              axis=1).astype(acc_dt), None

    acc0 = jnp.zeros((nq, codes.shape[0]), acc_dt)
    acc, _ = jax.lax.scan(step, acc0,
                          (jnp.swapaxes(pair, 0, 1), codes.T))
    return dequantize_acc(lut, acc, cb_mask)


# ------------------------------------------------------------- dispatch ----

def resolve_backend(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown search backend {backend!r}")
    return backend


def chunked_over_queries(fn, queries, query_chunk: Optional[int]):
    """Apply the vectorized ``fn`` to query blocks of ``query_chunk`` (a
    working-set bound for huge batches); None = one block.

    queries: (nq, d).  When nq is not a multiple of ``query_chunk`` the
    batch is zero-padded up to the next multiple, ``fn`` runs on every
    (query_chunk, d) block via ``lax.map``, and every output leaf is
    sliced back to its true first-``nq`` rows — callers never see pad
    queries, but ``fn`` must tolerate all-zero query rows (every engine
    here does: a zero query just produces finite distances that are
    discarded by the slice).
    """
    from repro.kernels.stages import pad_to
    if query_chunk is None or queries.shape[0] <= query_chunk:
        return fn(queries)
    nq = queries.shape[0]
    qp = pad_to(queries, nq + (-nq) % query_chunk)
    blocks = qp.reshape(-1, query_chunk, queries.shape[1])
    outs = jax.lax.map(fn, blocks)
    return jax.tree.map(
        lambda a: a.reshape((-1,) + a.shape[2:])[:nq], outs)


def as_filter(filter, n: int):
    """Validate a per-row metadata predicate: a length-``n`` boolean
    vector (True = row eligible).  Any array-like of shape (n,) is
    accepted and cast to bool; wrong shapes raise by name."""
    f = jnp.asarray(filter)
    if f.ndim != 1 or f.shape[0] != n:
        raise ValueError(f"filter must be a ({n},) boolean predicate "
                         f"(one entry per database row), got shape "
                         f"{tuple(f.shape)}")
    return f.astype(bool)


def mask_filtered_ids(ids, dist):
    """Post-filter result convention: slots whose distance is +inf (no
    eligible row left to fill them) report id ``-1``.  Applied only on
    filtered searches so unfiltered results stay bitwise unchanged."""
    return jnp.where(jnp.isinf(dist), -1, ids)


def exact_search(queries, X, topk: int, *,
                 query_chunk: Optional[int] = None, filter=None):
    """Brute-force L2 ground truth.  queries: (nq,d), X: (n,d).

    ``query_chunk`` bounds the dense (nq, n) distance matrix to
    (query_chunk, n) blocks — ground-truth computation at benchmark
    sizes (nq x n = 64 x 1M) OOMs without it.

    ``filter``: optional (n,) bool per-row predicate — rows where it is
    False are excluded (the filtered-search oracle).  When fewer than
    ``topk`` rows pass, the tail slots report id ``-1`` at distance
    ``+inf``.
    """
    xsq = jnp.sum(jnp.square(X), -1)[None, :]
    pred = None if filter is None else as_filter(filter, X.shape[0])

    def one_block(qs):
        d2 = (jnp.sum(jnp.square(qs), -1)[:, None]
              - 2.0 * qs @ X.T + xsq)
        if pred is not None:
            d2 = jnp.where(pred[None, :], d2, jnp.inf)
        neg, idx = jax.lax.top_k(-d2, topk)
        if pred is not None:
            idx = mask_filtered_ids(idx, -neg)
        return idx, -neg

    return chunked_over_queries(one_block, queries, query_chunk)


# --------------------------------------------------------------- metrics ----

def mean_average_precision(retrieved_ids, db_labels, query_labels):
    """Label-based MAP (the paper's metric): a retrieved point is relevant
    iff it shares the query's class.  retrieved_ids: (nq, R)."""
    rel = (db_labels[retrieved_ids] == query_labels[:, None]).astype(jnp.float32)
    ranks = jnp.arange(1, rel.shape[1] + 1, dtype=jnp.float32)[None, :]
    cum = jnp.cumsum(rel, axis=1)
    prec_at = cum / ranks
    denom = jnp.maximum(jnp.sum(rel, axis=1), 1.0)
    ap = jnp.sum(prec_at * rel, axis=1) / denom
    return jnp.mean(ap)


def recall_at(retrieved_ids, true_ids):
    """Fraction of true nearest neighbors recovered.  Both (nq, R)."""
    hits = (retrieved_ids[:, :, None] == true_ids[:, None, :]).any(axis=1)
    return jnp.mean(hits.astype(jnp.float32))
