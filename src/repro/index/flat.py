"""Flat (exhaustive) indexes: one-step ADC and the ICQ two-step engine.

Both scan every database point; ``TwoStep`` prunes refinement work with
the paper's eq. 2 margin test.  The engine implementations moved here
from ``core/search.py`` (now a thin re-export) as part of the unified
index layer (DESIGN.md §7); behavior and backends are unchanged:

  backend="jnp"     fully vectorized reference — batched ``build_lut``,
                    one ``take_along_axis`` gather per LUT sum, batched
                    ``top_k`` over the whole query block (no per-query
                    ``lax.map``).  Optionally chunked over queries
                    (``query_chunk``) to bound the (nq, n) working set.
  backend="pallas"  the fused (query-tile x point-tile) kernels in
                    ``kernels/batched_search.py``: LUT tiles pinned in
                    VMEM, each codes tile streamed from HBM once per
                    query tile, eq. 2 test + slow-codebook refine +
                    top-k merge fused in-kernel.
  backend="auto"    "pallas" on TPU backends, "jnp" elsewhere.

``two_step_search`` folds the static survivor compaction that used to be
a separate entry (``two_step_search_compact``) into the dispatch as the
``refine_cap`` engine option: at most ``refine_cap`` best-crude
survivors per query are gathered and refined — a static-shape bound on
phase-2 work (jnp engine only; the fused kernels bound phase-2 memory
with the in-kernel top-k merge instead).

Database codes are stored packed (uint8 for m <= 256, core.encode.
pack_codes) and widened to int32 only at the engine boundary — 4x less
HBM traffic per streamed codes tile.

"Average Ops" — the paper's speed metric (Figs. 1-5) — counts LUT adds
per point:  |K_fast| + pass_rate * (K - |K_fast|), vs always-K for
ADC baselines.

``lut_dtype="int8"`` (DESIGN.md §8) runs the crude pass on per-query
affine-quantized tables (``base.quantize_lut``): integer accumulation,
one rescale back to true-distance units.  The refine/slow pass always
stays float32 — eq. 2's exact re-ranking is untouched; quantization
only perturbs which points pass the margin test and the crude component
of reported distances (bounded by |K_fast| * scale / 2 per point).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.index.base import (SearchResult, as_filter, build_lut,
                              chunked_over_queries, lut_sum,
                              mask_filtered_ids, resolve_backend,
                              resolve_code_bits, resolve_lut_dtype)
# The search paths are compositions of the stage objects (DESIGN.md
# §13); the stage module lazily imports index.base inside method
# bodies, so this top-level import is cycle-free.
from repro.kernels.stages import (CrudeStage, RefineStage, ThresholdStage,
                                  widen_codes as _widen_codes)


# -------------------------------------------------------------- engines ----

def _check_fastscan_geometry(code_bits: int, m: int):
    """``code_bits=4`` stores two codes per byte, so every code must be
    a nibble: m <= 16 codewords per codebook (DESIGN.md §12)."""
    code_bits = resolve_code_bits(code_bits)
    if code_bits == 4 and m > 16:
        raise ValueError(f"code_bits=4 requires codebook_size <= 16 "
                         f"codewords (4-bit codes), got m={m}")
    return code_bits


def _check_filter(filter, n: int, backend: str):
    """Resolve the per-row predicate of a filtered search (docs/api.md).

    Filtered search is a jnp-engine capability — the fused kernels
    bound their candidate sets in-kernel and cannot drop rows by
    predicate (mirroring the ``refine_cap`` restriction), so
    ``backend="pallas"`` + ``filter`` raises by name."""
    if filter is None:
        return None
    if backend == "pallas":
        raise ValueError("filtered search requires backend='jnp' (the "
                         "fused kernels cannot mask rows by predicate; "
                         "like refine_cap, filter is a jnp-engine "
                         "option)")
    return as_filter(filter, n)

def _adc_block(qs, env, *, topk: int, backend: str, block_q: int = 64,
               block_n: int = 512, interpret=None, quantized: bool = False,
               code_bits: int = 8, has_filter: bool = False):
    """One-step ADC over one query block: a single ``CrudeStage`` with
    ``fast=None`` (the full table is the crude pass) — there is no
    threshold or refine stage to compose.  env: {"codes", "C"[, "pred"]}.
    Returns (ids (nq, topk), dist (nq, topk))."""
    pred = env["pred"] if has_filter else None
    stage = CrudeStage(backend=backend, topk=topk, block_q=block_q,
                       block_n=block_n, interpret=interpret,
                       quantized=quantized, code_bits=code_bits,
                       want_crude=False)
    luts = build_lut(qs, env["C"])
    if backend == "pallas":
        # codes stay packed into the kernel (widened per-tile in VMEM)
        out = stage(env["codes"], luts, None)
        return out.cand_idx, out.cand_vals
    dist = stage(env["codes"], luts, None, pred=pred).crude   # (nq, n)
    neg, ids = jax.lax.top_k(-dist, topk)
    if pred is not None:
        ids = mask_filtered_ids(ids, -neg)
    return ids, -neg


def adc_search(queries, codes, C, topk: int, *, backend: str = "auto",
               block_q: int = 64, block_n: int = 512, interpret=None,
               query_chunk: Optional[int] = None, lut_dtype: str = "f32",
               code_bits: int = 8, filter=None):
    """Baseline one-step ADC: full K-codebook LUT sum for every point,
    batched over the whole query block.

    queries (nq, d) f32; codes (n, K) packed int — nibble-packed
    (n, ceil(K/2)) uint8 under ``code_bits=4`` (DESIGN.md §12); C
    (K, m, d) f32.  ``lut_dtype="int8"`` quantizes the whole table per
    query (no fast subset here — the one-step ranking itself becomes
    approximate, with per-point error <= K * scale / 2).

    ``filter``: optional (n,) bool per-row predicate (jnp engine only)
    — excluded rows never appear in results; slots with no eligible row
    left report id -1 at distance +inf."""
    K, m = C.shape[0], C.shape[1]
    be = resolve_backend(backend)
    quantized = resolve_lut_dtype(lut_dtype) == "int8"
    code_bits = _check_fastscan_geometry(code_bits, m)
    pred = _check_filter(filter, codes.shape[0], be)
    if be != "pallas" and code_bits != 4:
        codes = codes.astype(jnp.int32)              # widen packed codes
    env = {"codes": codes, "C": C, "pred": pred}
    fn = functools.partial(_adc_block, env=env, topk=topk, backend=be,
                           block_q=block_q, block_n=block_n,
                           interpret=interpret, quantized=quantized,
                           code_bits=code_bits, has_filter=pred is not None)
    idx, vals = chunked_over_queries(fn, queries, query_chunk)
    return SearchResult(idx, vals, jnp.asarray(float(K)), jnp.asarray(1.0))


# The two-step engine as a crude/refine phase pair (DESIGN.md §13).
# Each phase is a pure function of (queries | carry, env) where env is
# the borrowed index state {"codes", "C", "fast", "sigma"[, "pred"]};
# the carry between them is the owned intermediate buffer set
# (luts, crude, cand_vals, cand_idx) that the refine phase is the last
# reader of.  The sequential blocks below compose the two phases
# back-to-back; ``index/pipelined.py`` jits them separately (refine with
# ``donate_argnums`` on the carry) and overlaps crude(t+1) with
# refine(t) across query tiles.

def _flat_crude_phase(qs, env, *, topk: int, backend: str,
                      block_q: int = 64, block_n: int = 512,
                      interpret=None, quantized: bool = False,
                      code_bits: int = 8, has_filter: bool = False):
    """Phase 1: per-query LUTs + the crude pass.  Returns the carry
    (luts, crude, cand_vals, cand_idx) — the fused kernel also emits
    its running crude top-k; the dense jnp path defers the candidate
    top-k to the threshold bootstrap (cand_* = None).

    ``pred`` (filtered search, jnp): excluded rows get crude = +inf
    *before* the eq. 2 bootstrap, so they can neither become
    candidates, set the threshold, nor pass the margin test — recall is
    measured against the filtered oracle, not a post-hoc drop."""
    stage = CrudeStage(backend=backend, topk=topk, block_q=block_q,
                       block_n=block_n, interpret=interpret,
                       quantized=quantized, code_bits=code_bits)
    luts = build_lut(qs, env["C"])                       # (nq,K,m)
    if backend == "pallas":
        out = stage(env["codes"], luts, env["fast"])
        return luts, out.crude, out.cand_vals, out.cand_idx
    pred = env["pred"] if has_filter else None
    out = stage(env["codes"], luts, env["fast"], pred=pred)
    return luts, out.crude, None, None


def _flat_refine_phase(carry, env, *, topk: int, backend: str,
                       block_q: int = 64, block_n: int = 512,
                       interpret=None, quantized: bool = False,
                       code_bits: int = 8,
                       refine_cap: Optional[int] = None,
                       has_filter: bool = False):
    """Phases 2+3: the eq. 2 threshold bootstrap and the refine pass.
    Consumes (donates) the crude-phase carry.  Returns (idx, dist,
    passed_frac (nq,)).

    The bootstrap formulation per path is preserved exactly: the dense
    jnp path ranks candidates from the crude matrix
    (``ThresholdStage.from_dense`` — quantized mode uses the
    crude + exact-slow decomposition the kernels share), the pallas
    path from the kernel's candidate list (``from_candidates``).
    ``refine_cap`` (jnp only) swaps the dense refine for the static
    survivor compaction: the refine_cap best crude survivors are
    gathered and re-ranked by full LUT sum (always exact f32 — under
    ``lut_dtype="int8"`` quantization only affects which points survive
    and their selection order)."""
    luts, crude, cand_vals, cand_idx = carry
    codes, fast, sigma = env["codes"], env["fast"], env["sigma"]
    pred = env["pred"] if has_filter else None
    tstage = ThresholdStage(topk=topk, quantized=quantized,
                            code_bits=code_bits)
    rstage = RefineStage(backend=backend, topk=topk, block_q=block_q,
                         block_n=block_n, interpret=interpret,
                         code_bits=code_bits)
    if backend == "pallas":
        thr = tstage.from_candidates(luts, codes, cand_vals, cand_idx,
                                     fast, sigma)
        idx, dist, passed = rstage(codes, luts, crude, thr, fast)
        return idx, dist, jnp.mean(passed.astype(jnp.float32), axis=1)
    thr = tstage.from_dense(luts, codes, crude, fast, sigma)
    if refine_cap is None:
        idx, dist, passed = rstage(codes, luts, crude, thr, fast,
                                   pred=pred)
        return idx, dist, jnp.mean(passed.astype(jnp.float32), axis=1)
    # compact: best-crude survivors first, capped
    passed = crude < thr[:, None]
    masked = jnp.where(passed, crude, jnp.inf)
    neg_s, surv = jax.lax.top_k(-masked, refine_cap)
    valid = jnp.isfinite(-neg_s)
    surv_codes = jnp.take(codes, surv, axis=0)           # (nq,cap,K)
    if code_bits == 4:
        surv_codes = _widen_codes(surv_codes, env["C"].shape[0],
                                  code_bits)
    full_surv = lut_sum(luts, surv_codes)
    ranked = jnp.where(valid, full_surv, jnp.inf)
    neg, pos = jax.lax.top_k(-ranked, topk)
    idx = jnp.take_along_axis(surv, pos, axis=1)
    if pred is not None:
        idx = mask_filtered_ids(idx, -neg)
    return idx, -neg, jnp.mean(passed.astype(jnp.float32), axis=1)


def _two_step_block_jnp(qs, codes, C, fast, sigma, topk: int,
                        quantized: bool = False, code_bits: int = 8,
                        pred=None):
    """Vectorized two-step over one query block: the sequential
    composition of the crude and refine phases.  Returns
    (idx (nq,topk), dist (nq,topk), passed_frac (nq,))."""
    env = {"codes": codes, "C": C, "fast": fast, "sigma": sigma,
           "pred": pred}
    carry = _flat_crude_phase(qs, env, topk=topk, backend="jnp",
                              quantized=quantized, code_bits=code_bits,
                              has_filter=pred is not None)
    return _flat_refine_phase(carry, env, topk=topk, backend="jnp",
                              quantized=quantized, code_bits=code_bits,
                              has_filter=pred is not None)


def _two_step_block_compact(qs, codes, C, fast, sigma, topk: int,
                            refine_cap: int, quantized: bool = False,
                            code_bits: int = 8, pred=None):
    """Two-step with the static survivor compaction — the same phase
    pair with the capped refine tail (see ``_flat_refine_phase``)."""
    env = {"codes": codes, "C": C, "fast": fast, "sigma": sigma,
           "pred": pred}
    carry = _flat_crude_phase(qs, env, topk=topk, backend="jnp",
                              quantized=quantized, code_bits=code_bits,
                              has_filter=pred is not None)
    return _flat_refine_phase(carry, env, topk=topk, backend="jnp",
                              quantized=quantized, code_bits=code_bits,
                              refine_cap=refine_cap,
                              has_filter=pred is not None)


def _two_step_pallas(queries, codes, C, fast, sigma, topk: int,
                     block_q: int, block_n: int, interpret,
                     quantized: bool = False, code_bits: int = 8):
    """Fused-kernel two-step: phase-1 crude + candidate top-k in one
    kernel, tiny candidate refinement in jnp, fused phase-2 kernel —
    the same phase pair, pallas stages.  ``quantized`` feeds phase 1
    int8 tables (dequantized in-kernel); phase 2 keeps the exact f32
    slow tables either way."""
    env = {"codes": codes, "C": C, "fast": fast, "sigma": sigma,
           "pred": None}
    carry = _flat_crude_phase(queries, env, topk=topk, backend="pallas",
                              block_q=block_q, block_n=block_n,
                              interpret=interpret, quantized=quantized,
                              code_bits=code_bits)
    return _flat_refine_phase(carry, env, topk=topk, backend="pallas",
                              block_q=block_q, block_n=block_n,
                              interpret=interpret, quantized=quantized,
                              code_bits=code_bits)


def two_step_search(queries, codes, C, structure, topk: int, *,
                    backend: str = "auto", block_q: int = 64,
                    block_n: int = 512, interpret=None,
                    query_chunk: Optional[int] = None,
                    refine_cap: Optional[int] = None,
                    lut_dtype: str = "f32", code_bits: int = 8,
                    filter=None):
    """ICQ two-step search (eq. 2 crude test -> eq. 1 refinement),
    batched over the whole query block.

    structure:  core.icq.ICQStructure (xi, fast_mask, sigma).
    backend:    "jnp" | "pallas" | "auto" (pallas on TPU) — see module
                docstring; both produce identical rankings.
    code_bits:  8 (byte codes) | 4 (fast-scan mode, DESIGN.md §12:
                ``codes`` arrive nibble-packed (n, ceil(K/2)) uint8,
                requires codebook_size <= 16; rankings match the 8-bit
                path bitwise for either lut_dtype).
    refine_cap: optional static survivor compaction (jnp engine): at
                most this many best-crude survivors are refined.  Under
                lut_dtype="f32", semantically identical to the dense
                ranking whenever the survivor count <= refine_cap; a
                smaller cap is a quality/throughput dial for serving.
                Under "int8" the capped path re-ranks its survivors by
                *exact* f32 full distance while the dense path ranks by
                quantized-crude + exact-slow, so the two can differ on
                quantization-margin ties even with a sufficient cap
                (the capped ranking is the more exact of the two).
    lut_dtype:  "f32" (exact crude pass) | "int8" (per-query quantized
                crude tables, DESIGN.md §8).  The refine pass is always
                f32; both backends produce identical rankings for
                either dtype.
    filter:     optional (n,) bool per-row metadata predicate (jnp
                engine only, like refine_cap): excluded rows get crude
                +inf *before* the eq. 2 bootstrap — they can't become
                candidates, set the threshold, or appear in results;
                unfilled slots report id -1 at distance +inf.
    """
    K = C.shape[0]
    fast = structure.fast_mask
    sigma = structure.sigma
    kf = jnp.sum(fast.astype(jnp.float32))
    be = resolve_backend(backend)
    quantized = resolve_lut_dtype(lut_dtype) == "int8"
    code_bits = _check_fastscan_geometry(code_bits, C.shape[1])
    pred = _check_filter(filter, codes.shape[0], be)
    # nibble codes stay packed through both backends (the jnp blocks
    # unpack on the fly; the kernels unpack in-VMEM)
    codes_j = codes if code_bits == 4 else codes.astype(jnp.int32)

    if be == "pallas":
        if refine_cap is not None:
            raise ValueError("refine_cap compaction requires backend='jnp'"
                             " (the fused kernels bound phase-2 work with"
                             " the in-kernel top-k merge instead)")
        # codes stay packed into the kernels (widened per-tile in VMEM);
        # query_chunk bounds the dense (chunk, n) crude matrix here too
        fn = functools.partial(_two_step_pallas, codes=codes, C=C,
                               fast=fast, sigma=sigma, topk=topk,
                               block_q=block_q, block_n=block_n,
                               interpret=interpret, quantized=quantized,
                               code_bits=code_bits)
    elif refine_cap is not None:
        fn = functools.partial(_two_step_block_compact,
                               codes=codes_j, C=C,
                               fast=fast, sigma=sigma, topk=topk,
                               refine_cap=min(max(refine_cap, topk),
                                              codes.shape[0]),
                               quantized=quantized, code_bits=code_bits,
                               pred=pred)
    else:
        fn = functools.partial(_two_step_block_jnp,
                               codes=codes_j, C=C,
                               fast=fast, sigma=sigma, topk=topk,
                               quantized=quantized, code_bits=code_bits,
                               pred=pred)
    idx, dist, pf = chunked_over_queries(fn, queries, query_chunk)
    pass_rate = jnp.mean(pf)
    avg_ops = kf + pass_rate * (K - kf)
    return SearchResult(idx, dist, avg_ops, pass_rate)


def two_step_search_compact(queries, codes, C, structure, topk: int,
                            refine_cap: int, *,
                            query_chunk: Optional[int] = None):
    """Back-compat wrapper: the survivor compaction is now the
    ``refine_cap`` option of ``two_step_search``'s dispatch."""
    return two_step_search(queries, codes, C, structure, topk,
                           backend="jnp", query_chunk=query_chunk,
                           refine_cap=refine_cap)


def _flat_crude_only_phase(qs, env, *, topk: int, backend: str,
                           block_q: int = 64, block_n: int = 512,
                           interpret=None, quantized: bool = False,
                           code_bits: int = 8, has_filter: bool = False):
    """The degraded pipeline: a ``CrudeStage`` with the refine stage
    dropped (the resilience ladder's crude rung).  jnp ranks the dense
    crude matrix directly; pallas takes the fused kernel's candidate
    list (``want_crude=False`` — no dense matrix at all).  Returns
    (idx, dist, pf=0) like the full phase pair."""
    stage = CrudeStage(backend=backend, topk=topk, block_q=block_q,
                       block_n=block_n, interpret=interpret,
                       quantized=quantized, code_bits=code_bits,
                       want_crude=False)
    luts = build_lut(qs, env["C"])
    if backend == "pallas":
        out = stage(env["codes"], luts, env["fast"])
        return (out.cand_idx, out.cand_vals,
                jnp.zeros(qs.shape[0], dtype=jnp.float32))
    pred = env["pred"] if has_filter else None
    crude = stage(env["codes"], luts, env["fast"], pred=pred).crude
    neg_c, cand = jax.lax.top_k(-crude, topk)
    if pred is not None:
        cand = mask_filtered_ids(cand, -neg_c)
    return cand, -neg_c, jnp.zeros(qs.shape[0], dtype=jnp.float32)


def _two_step_crude_block_jnp(qs, codes, C, fast, sigma, topk: int,
                              quantized: bool = False, code_bits: int = 8,
                              pred=None):
    """Crude-only ranking over one query block: the exact crude top-k
    the full jnp path bootstraps eq. 2 candidates from, with no
    refinement."""
    env = {"codes": codes, "C": C, "fast": fast, "pred": pred}
    return _flat_crude_only_phase(qs, env, topk=topk, backend="jnp",
                                  quantized=quantized,
                                  code_bits=code_bits,
                                  has_filter=pred is not None)


def _two_step_crude_pallas(qs, codes, C, fast, topk: int, block_q: int,
                           block_n: int, interpret,
                           quantized: bool = False, code_bits: int = 8):
    """Crude-only ranking via the phase-1 kernel: ``batched_crude_topk``
    already emits the crude top-k (its candidate list); skip the dense
    crude matrix and phase 2 entirely."""
    env = {"codes": codes, "C": C, "fast": fast, "pred": None}
    return _flat_crude_only_phase(qs, env, topk=topk, backend="pallas",
                                  block_q=block_q, block_n=block_n,
                                  interpret=interpret,
                                  quantized=quantized,
                                  code_bits=code_bits)


def two_step_crude_search(queries, codes, C, structure, topk: int, *,
                          backend: str = "auto", block_q: int = 64,
                          block_n: int = 512, interpret=None,
                          query_chunk: Optional[int] = None,
                          lut_dtype: str = "f32", code_bits: int = 8,
                          filter=None):
    """The degradation ladder's crude floor (docs/robustness.md): rank
    by the fast-subset crude distance only, skipping eq. 2 and the
    refine pass.  Bitwise-identical to the crude top-k the full path
    computes internally (the eq. 2 bootstrap candidates), on either
    backend.  ``pass_rate`` is 0 (nothing refined); ``avg_ops`` is
    |K_fast| per point.  Under ``code_bits=4`` this rung serves
    directly from the packed nibbles (fast-scan crude pass).
    ``filter`` (jnp only) masks rows pre-top-k like the full path."""
    fast = structure.fast_mask
    kf = jnp.sum(fast.astype(jnp.float32))
    be = resolve_backend(backend)
    quantized = resolve_lut_dtype(lut_dtype) == "int8"
    code_bits = _check_fastscan_geometry(code_bits, C.shape[1])
    pred = _check_filter(filter, codes.shape[0], be)

    if be == "pallas":
        fn = functools.partial(_two_step_crude_pallas, codes=codes, C=C,
                               fast=fast, topk=topk, block_q=block_q,
                               block_n=block_n, interpret=interpret,
                               quantized=quantized, code_bits=code_bits)
    else:
        codes_j = codes if code_bits == 4 else codes.astype(jnp.int32)
        fn = functools.partial(_two_step_crude_block_jnp,
                               codes=codes_j, C=C,
                               fast=fast, sigma=structure.sigma, topk=topk,
                               quantized=quantized, code_bits=code_bits,
                               pred=pred)
    idx, dist, pf = chunked_over_queries(fn, queries, query_chunk)
    return SearchResult(idx, dist, kf, jnp.mean(pf))


def two_step_phase_env(codes, C, structure, *, backend: str,
                       code_bits: int, pred=None):
    """The borrowed-operand environment the flat phase functions close
    over nothing and read everything from: stored codes (packed into
    the kernels, widened once for the jnp byte path — the same
    ``codes_j`` rule as ``two_step_search``), codebooks, the ICQ
    structure's fast mask and margin, and the optional filter
    predicate."""
    codes_j = (codes if (backend == "pallas" or code_bits == 4)
               else codes.astype(jnp.int32))
    return {"codes": codes_j, "C": C, "fast": structure.fast_mask,
            "sigma": structure.sigma, "pred": pred}


def two_step_phase_fns(*, topk: int, backend: str, block_q: int = 64,
                       block_n: int = 512, interpret=None,
                       quantized: bool = False, code_bits: int = 8,
                       refine_cap: Optional[int] = None,
                       crude_only: bool = False,
                       has_filter: bool = False):
    """The flat two-step engine as a ``(crude_fn, refine_fn)`` phase
    pair over ``(qs | carry, env)`` — the contract
    ``index/pipelined.py`` jits and overlaps.  ``crude_only`` drops the
    refine stage (the degraded rung): refine_fn is None and crude_fn
    returns final (idx, dist, pf) tiles directly."""
    common = dict(topk=topk, backend=backend, block_q=block_q,
                  block_n=block_n, interpret=interpret,
                  quantized=quantized, code_bits=code_bits,
                  has_filter=has_filter)
    if crude_only:
        return functools.partial(_flat_crude_only_phase, **common), None
    crude = functools.partial(_flat_crude_phase, **common)
    refine = functools.partial(_flat_refine_phase, refine_cap=refine_cap,
                               **common)
    return crude, refine


def adc_phase_fns(*, topk: int, backend: str, block_q: int = 64,
                  block_n: int = 512, interpret=None,
                  quantized: bool = False, code_bits: int = 8,
                  has_filter: bool = False):
    """One-step ADC as a phase pair: the whole search is its crude
    stage, so the refine slot is always None (the pipelined executor
    still overlaps tile dispatch)."""
    def crude_fn(qs, env):
        ids, vals = _adc_block(qs, env, topk=topk, backend=backend,
                               block_q=block_q, block_n=block_n,
                               interpret=interpret, quantized=quantized,
                               code_bits=code_bits,
                               has_filter=has_filter)
        return ids, vals, jnp.zeros(qs.shape[0], dtype=jnp.float32)
    return crude_fn, None


# -------------------------------------------------------------- indexes ----

def _encode_new_rows(new_vectors, C, codes_dtype, *, icm_iters: int,
                     encode_backend: str, point_chunk: Optional[int],
                     code_bits: int = 8):
    """Shared ``Index.add`` encode step (DESIGN.md §9): run the tiled
    ICM engine over the new embeddings (PQ warm start; for
    orthogonal-support PQ codebooks the interaction terms vanish, so
    ICM reproduces the independent assignment exactly) and pack to the
    stored codes format (``codes_dtype`` for byte codes; nibble rows
    under ``code_bits=4`` — the dtype is uint8 either way, but the
    packed row width differs)."""
    from repro.core import encode as enc

    new = enc.icm_encode(jnp.asarray(new_vectors), C, icm_iters,
                         backend=encode_backend, point_chunk=point_chunk)
    if code_bits == 4:
        return enc.pack_nibbles(new, C.shape[0])
    return new.astype(codes_dtype)

@dataclasses.dataclass(frozen=True)
class FlatADC:
    """One-step exhaustive ADC index (baseline; no pruning).

    ``lut_dtype="int8"`` quantizes the full per-query table (the whole
    one-step ranking becomes approximate, DESIGN.md §8)."""
    codes: jnp.ndarray                  # (n, K) packed
    C: jnp.ndarray                      # (K, m, d)
    topk: int = 50
    backend: str = "auto"
    block_q: int = 64
    block_n: int = 512
    interpret: Optional[bool] = None
    query_chunk: Optional[int] = None
    lut_dtype: str = "f32"
    code_bits: int = 8
    pipeline: str = "off"               # off | tiles | auto (DESIGN.md §13)
    pipeline_tile: Optional[int] = None

    @classmethod
    def build(cls, codes, C, structure=None, **opts) -> "FlatADC":
        return cls(codes=codes, C=C, **opts)

    def search(self, queries, topk: Optional[int] = None, *,
               filter=None) -> SearchResult:
        k = topk if topk is not None else self.topk
        if self.pipeline != "off":
            from repro.index.pipelined import maybe_pipelined
            res = maybe_pipelined(self, queries, k, filter=filter)
            if res is not None:
                return res
        return adc_search(queries, self.codes, self.C, k,
                          backend=self.backend, block_q=self.block_q,
                          block_n=self.block_n, interpret=self.interpret,
                          query_chunk=self.query_chunk,
                          lut_dtype=self.lut_dtype,
                          code_bits=self.code_bits, filter=filter)

    def search_crude(self, queries, topk: Optional[int] = None, *,
                     filter=None) -> SearchResult:
        """One-step ADC has no cheap/refine split — the crude floor of
        the degradation ladder is the full search itself."""
        return self.search(queries, topk, filter=filter)

    def add(self, new_vectors, *, icm_iters: int = 3,
            encode_backend: str = "auto",
            point_chunk: Optional[int] = 8192) -> "FlatADC":
        """Encode ``new_vectors`` ((n_new, d) embeddings) through the
        tiled engine and append their rows — incremental build, no
        retraining (DESIGN.md §9).  Returns a new index; new rows get
        ids [n, n + n_new)."""
        new = _encode_new_rows(new_vectors, self.C, self.codes.dtype,
                               icm_iters=icm_iters,
                               encode_backend=encode_backend,
                               point_chunk=point_chunk,
                               code_bits=self.code_bits)
        return dataclasses.replace(
            self, codes=jnp.concatenate([self.codes, new], axis=0))

    def shard(self, mesh):
        from repro.index.sharded import ShardedFlatADC
        return ShardedFlatADC(self, mesh)


@dataclasses.dataclass(frozen=True)
class TwoStep:
    """Exhaustive ICQ two-step index (eq. 2 pruning, optional
    ``refine_cap`` compaction, optional int8 crude tables)."""
    codes: jnp.ndarray                  # (n, K) packed
    C: jnp.ndarray                      # (K, m, d)
    structure: object                   # core.icq.ICQStructure
    topk: int = 50
    backend: str = "auto"
    block_q: int = 64
    block_n: int = 512
    interpret: Optional[bool] = None
    query_chunk: Optional[int] = None
    refine_cap: Optional[int] = None
    lut_dtype: str = "f32"
    code_bits: int = 8
    pipeline: str = "off"               # off | tiles | auto (DESIGN.md §13)
    pipeline_tile: Optional[int] = None

    @classmethod
    def build(cls, codes, C, structure, **opts) -> "TwoStep":
        return cls(codes=codes, C=C, structure=structure, **opts)

    def search(self, queries, topk: Optional[int] = None, *,
               filter=None) -> SearchResult:
        k = topk if topk is not None else self.topk
        if self.pipeline != "off":
            from repro.index.pipelined import maybe_pipelined
            res = maybe_pipelined(self, queries, k, filter=filter)
            if res is not None:
                return res
        return two_step_search(queries, self.codes, self.C, self.structure,
                               k,
                               backend=self.backend, block_q=self.block_q,
                               block_n=self.block_n, interpret=self.interpret,
                               query_chunk=self.query_chunk,
                               refine_cap=self.refine_cap,
                               lut_dtype=self.lut_dtype,
                               code_bits=self.code_bits, filter=filter)

    def search_crude(self, queries, topk: Optional[int] = None, *,
                     filter=None) -> SearchResult:
        """Crude-only floor (docs/robustness.md): the fast-subset crude
        ranking, bitwise-identical to the full path's internal eq. 2
        bootstrap candidates on the same backend.  Under an active
        pipeline this is the degraded pipeline — the refine stage is
        dropped and crude tiles stream straight out."""
        k = topk if topk is not None else self.topk
        if self.pipeline != "off":
            from repro.index.pipelined import maybe_pipelined
            res = maybe_pipelined(self, queries, k, filter=filter,
                                  crude_only=True)
            if res is not None:
                return res
        return two_step_crude_search(
            queries, self.codes, self.C, self.structure, k,
            backend=self.backend, block_q=self.block_q,
            block_n=self.block_n, interpret=self.interpret,
            query_chunk=self.query_chunk, lut_dtype=self.lut_dtype,
            code_bits=self.code_bits, filter=filter)

    def add(self, new_vectors, *, icm_iters: int = 3,
            encode_backend: str = "auto",
            point_chunk: Optional[int] = 8192) -> "TwoStep":
        """Encode ``new_vectors`` ((n_new, d) embeddings) through the
        tiled engine and append their rows — incremental build, no
        retraining (DESIGN.md §9).  Returns a new index; new rows get
        ids [n, n + n_new)."""
        new = _encode_new_rows(new_vectors, self.C, self.codes.dtype,
                               icm_iters=icm_iters,
                               encode_backend=encode_backend,
                               point_chunk=point_chunk,
                               code_bits=self.code_bits)
        return dataclasses.replace(
            self, codes=jnp.concatenate([self.codes, new], axis=0))

    def shard(self, mesh):
        from repro.index.sharded import ShardedTwoStep
        return ShardedTwoStep(self, mesh)
