"""IVF (inverted-file) coarse partitioning composed with ICQ — the
paper's path to sub-linear query cost, batched for serving traffic
(DESIGN.md §7).

A coarse k-means splits the database into ``n_lists`` cells; a query
visits only the ``n_probe`` nearest cells and runs the ICQ two-step
search over those candidates.  Ops per query drop by another
~n_lists/n_probe on top of ICQ's crude-test pruning; the paper's
Average-Ops metric generalizes to

    ops = coarse_scan (n_lists dots) / n
          + probed_frac * (|K_fast| + pass_rate * (K - |K_fast|))

The batched engine (vs the retired per-query ``lax.map`` formulation,
kept as ``kernels/ref.py::ivf_two_step_search_looped``):

  1. coarse-probe the whole query block at once: one (nq, n_lists)
     distance matmul + batched ``top_k`` -> probes (nq, n_probe);
  2. gather the padded candidate slab: ``lists[probes]`` flattens to
     (nq, nc = n_probe * max_len) global ids (-1 pad) and one codes
     gather yields (nq, nc, K) — *still packed* uint8; codes widen only
     at the LUT-sum / kernel boundary;
  3. run the batched crude -> eq. 2 -> refine pipeline over the slab:
     backend="jnp" mirrors ``flat.two_step_search`` (with the optional
     static ``refine_cap`` compaction), backend="pallas" reuses the
     (query-tile x candidate-tile) fused kernels over the gathered slab
     (``kernels/batched_search.py`` ivf_* variants).

Static shapes for TPU: lists are padded to the max list length (pad id
-1, masked) — the memory overhead is the classic IVF imbalance factor,
reported by ``build_ivf``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.index.base import (SearchResult, _int_acc_dtype, build_lut,
                              chunked_over_queries, dequantize_acc,
                              lut_sum, mask_filtered_ids, quantize_lut,
                              resolve_backend, resolve_lut_dtype)
# The slab search paths are compositions of the stage objects
# (DESIGN.md §13); stages lazily imports index modules inside method
# bodies, so this top-level import is cycle-free.
from repro.kernels.stages import (CrudeStage, RefineStage, ThresholdStage,
                                  widen_codes as _widen_slab)


class IVFIndex(NamedTuple):
    centroids: jnp.ndarray       # (n_lists, d)
    lists: jnp.ndarray           # (n_lists, max_len) int32 db ids, -1 pad
    list_lens: jnp.ndarray       # (n_lists,)
    imbalance: float             # max_len / (n / n_lists)


def _pack_buckets(buckets, n_lists: int, n: int) -> IVFIndex:
    """Lay per-list id buckets out as the padded (n_lists, max_len)
    slab — shared by ``build_ivf`` / ``ivf_assign`` / ``ivf_extend``.
    Bucket entries must already be global database ids in ascending
    order (assignment iterates ids in order, so they are)."""
    # max over bucket lengths is 0 when every bucket is empty (k-means
    # collapse / n_lists > n leaves stragglers); keep max_len >= 1 so
    # the padded layout stays well-formed with all-(-1) rows
    max_len = max(max((len(b) for b in buckets), default=0), 1)
    lists = np.full((n_lists, max_len), -1, np.int32)
    for l, b in enumerate(buckets):
        lists[l, : len(b)] = b
    lens = np.asarray([len(b) for b in buckets], np.int32)
    return IVFIndex(centroids=None, lists=jnp.asarray(lists),
                    list_lens=jnp.asarray(lens),
                    imbalance=float(max_len / max(n / n_lists, 1)))


def build_ivf(key, emb_db, n_lists: int, kmeans_iters: int = 20) -> IVFIndex:
    """Coarse k-means partition of ``emb_db`` into padded inverted lists.

    List entries are int32 *global database ids* (pad -1): gathering
    ``codes[lists[probes]]`` keeps the candidate codes in their stored
    packed dtype (uint8 for m <= 256) all the way to the LUT-sum /
    kernel boundary — the gather never widens.
    """
    from repro.core import codebooks as cb

    n = int(emb_db.shape[0])
    if n_lists < 1:
        raise ValueError(f"n_lists must be >= 1, got {n_lists}")
    if n == 0:
        raise ValueError("cannot build an IVF over an empty database")
    # k-means cannot seed more centroids than points: fit the real
    # count and pad the remaining rows with a far-away sentinel (huge
    # but finite, so probe distances stay ordered, never NaN) over
    # permanently empty lists
    k_eff = min(n_lists, n)
    cent, ids = cb.kmeans(key, emb_db, k_eff, iters=kmeans_iters)
    if k_eff < n_lists:
        pad = jnp.full((n_lists - k_eff, cent.shape[1]), 1e15,
                       cent.dtype)
        cent = jnp.concatenate([cent, pad], axis=0)
    ids_np = np.asarray(ids)
    buckets = [np.where(ids_np == l)[0] for l in range(n_lists)]
    return _pack_buckets(buckets, n_lists, n)._replace(centroids=cent)


def ivf_assign(centroids, emb_db) -> IVFIndex:
    """Inverted lists from *fixed* coarse centroids: assign every
    ``emb_db`` row to its nearest centroid.  The from-scratch
    counterpart of ``ivf_extend`` — ``build_ivf(key, e1, L)`` then
    ``ivf_extend``-ing e2 yields exactly
    ``ivf_assign(ivf.centroids, concat(e1, e2))`` (DESIGN.md §9)."""
    from repro.core import codebooks as cb

    n = int(emb_db.shape[0])
    n_lists = centroids.shape[0]
    ids_np = np.asarray(cb.kmeans_assign(jnp.asarray(emb_db, jnp.float32),
                                         centroids))
    buckets = [np.where(ids_np == l)[0] for l in range(n_lists)]
    return _pack_buckets(buckets, n_lists, n)._replace(centroids=centroids)


def ivf_extend(ivf: IVFIndex, new_emb, start_id: int) -> IVFIndex:
    """Route new points into the existing inverted lists — the IVF leg
    of ``Index.add`` (DESIGN.md §9).  Centroids stay fixed (no
    retraining); each new embedding is assigned to its nearest centroid
    and its global id (``start_id + row``) appended to that list, with
    the padded slab re-laid-out (max_len grows as needed).  Appending
    preserves ascending id order per list, so the result is identical
    to ``ivf_assign`` over the concatenated embeddings."""
    from repro.core import codebooks as cb

    n_lists = ivf.lists.shape[0]
    new_ids = np.asarray(cb.kmeans_assign(
        jnp.asarray(new_emb, jnp.float32), ivf.centroids))
    lists_np = np.asarray(ivf.lists)
    lens_np = np.asarray(ivf.list_lens)
    buckets = [lists_np[l, : lens_np[l]] for l in range(n_lists)]
    for l in range(n_lists):
        extra = start_id + np.where(new_ids == l)[0].astype(np.int32)
        if extra.size:
            buckets[l] = np.concatenate([buckets[l], extra])
    n = start_id + int(new_emb.shape[0])
    return _pack_buckets(buckets, n_lists, n)._replace(
        centroids=ivf.centroids)


# -------------------------------------------------------------- engines ----

def coarse_probe(qs, centroids, n_probe: int):
    """Nearest-``n_probe`` centroid ids for a query block: one (nq,
    n_lists) distance matmul + batched top_k.  Returns (nq, n_probe)."""
    d2c = (jnp.sum(jnp.square(centroids), -1)[None, :]
           - 2.0 * qs @ centroids.T)                     # + ||q||^2 const
    _, probes = jax.lax.top_k(-d2c, n_probe)
    return probes


def ivf_list_codes(ivf: "IVFIndex", codes):
    """Move the packed codes *inside* the inverted lists: one padded
    (n_lists, max_len, K) slab in the stored dtype (pad rows repeat
    codes[0]; validity rides on the id slab).  Serving then gathers
    contiguous list rows per probe instead of scattered database rows —
    measurably faster and the layout the sharded engine serves from."""
    return jnp.take(codes, jnp.maximum(ivf.lists, 0), axis=0)


def gather_candidates(probes, lists, codes, topk: int, list_codes=None):
    """Flatten the probed lists into the per-query candidate slab.

    Returns (cand_ids (nq, nc), valid (nq, nc), cand_codes (nq, nc, K)
    in the *stored* packed dtype).  ``list_codes`` (from
    ``ivf_list_codes``) switches the codes gather to contiguous list
    rows; values are identical either way.  The slab is right-padded
    with invalid columns up to ``topk`` so downstream top_k calls always
    have enough columns.
    """
    nq = probes.shape[0]
    cand_ids = lists[probes].reshape(nq, -1)             # (nq, nc)
    if list_codes is not None:
        cand_codes = list_codes[probes].reshape(
            nq, cand_ids.shape[1], -1)                   # contiguous rows
    if cand_ids.shape[1] < topk:                         # tiny-slab guard
        pad = topk - cand_ids.shape[1]
        cand_ids = jnp.pad(cand_ids, ((0, 0), (0, pad)),
                           constant_values=-1)
    valid = cand_ids >= 0
    safe = jnp.where(valid, cand_ids, 0)
    if list_codes is None:
        cand_codes = jnp.take(codes, safe, axis=0)       # packed dtype kept
    elif cand_codes.shape[1] < cand_ids.shape[1]:
        cand_codes = jnp.pad(
            cand_codes,
            ((0, 0), (0, cand_ids.shape[1] - cand_codes.shape[1]), (0, 0)))
    return cand_ids, valid, cand_codes


def _slab_codes(cand_codes, k: int, code_bits: int):
    """Codebook k's codes from the candidate slab, widened to int32.
    Under ``code_bits=4`` the slab stays nibble-packed — the byte column
    is gathered once and the right nibble shifted out (DESIGN.md §12)."""
    if code_bits == 4:
        byte = cand_codes[:, :, k // 2].astype(jnp.int32)
        return (byte >> (4 * (k % 2))) & 0xF
    return cand_codes[:, :, k].astype(jnp.int32)


def _ivf_bootstrap_threshold(luts, crude, cand_codes, topk: int, sigma,
                             fast=None, code_bits: int = 8):
    """Eq. 2 threshold over the candidate slab — kept as the historical
    entry point; the arithmetic lives in
    ``kernels.stages.ThresholdStage.from_dense_slab``.  With ``fast``
    given (the quantized-crude path) the candidates' full distances are
    quantized-crude + exact-slow — the decomposition the fused kernels
    use — so jnp and pallas bootstrap identical thresholds under
    ``lut_dtype="int8"``."""
    stage = ThresholdStage(topk=topk, quantized=fast is not None,
                           code_bits=code_bits)
    return stage.from_dense_slab(luts, cand_codes, crude, fast, sigma)


def _ivf_crude_scores(luts, cand_codes, valid, fast, *,
                      quantized: bool, need_slow: bool,
                      code_bits: int = 8):
    """Crude (and optionally slow) LUT sums over the candidate slab —
    the shared scoring core of the full jnp engine and the crude-only
    floor (so the two are bitwise-identical by construction).

    One unrolled pass over the K (static, small) codebooks feeds both
    accumulators via per-codebook (nq, nc) gathers — never
    materializing the (nq, K, nc) parts tensor (which blows the cache
    at serving slab sizes) or a transposed codes copy; masking the
    gathered value == masking the LUT before the gather.  Returns
    (crude (nq, nc) with invalid +inf, slow (nq, nc))."""
    fvals = fast.astype(luts.dtype)                          # (K,)
    K = luts.shape[1]
    nq, nc = valid.shape
    slow = jnp.zeros((nq, nc), luts.dtype)
    if quantized:
        # int8 crude accumulation (DESIGN.md §8): masked codebooks are
        # zeroed in the table, the narrow integer sum skips them, one
        # affine rescale recovers true-distance units (ordered exactly
        # like the fused kernel's dequant)
        qlut = quantize_lut(luts, fast)
        acc = jnp.zeros((nq, nc), _int_acc_dtype(K))
        for k in range(K):
            ck = _slab_codes(cand_codes, k, code_bits)
            acc = acc + jnp.take_along_axis(qlut.q[:, k, :], ck,
                                            axis=1).astype(acc.dtype)
            if need_slow:
                v = jnp.take_along_axis(luts[:, k, :], ck, axis=1)
                slow = slow + (1.0 - fvals[k]) * v
        crude = dequantize_acc(qlut, acc, fast)
    else:
        crude = jnp.zeros((nq, nc), luts.dtype)
        for k in range(K):
            v = jnp.take_along_axis(
                luts[:, k, :], _slab_codes(cand_codes, k, code_bits), axis=1)
            crude = crude + fvals[k] * v
            if need_slow:
                slow = slow + (1.0 - fvals[k]) * v
    return jnp.where(valid, crude, jnp.inf), slow


def _ivf_crude_phase(qs, env, *, topk: int, n_probe: int, backend: str,
                     block_q: int = 4, block_n: int = 128, interpret=None,
                     quantized: bool = False, code_bits: int = 8,
                     refine_cap: Optional[int] = None,
                     has_filter: bool = False):
    """Crude half of the IVF two-step over one query tile: probe +
    gather + ``CrudeStage.slab``.  Returns the inter-phase carry
    ``(luts, crude, cand_vals, cand_pos, slow, cand_codes, safe,
    valid)`` — unused slots are None per backend (jnp defers the crude
    top-k to the bootstrap; pallas defers the slow sums to the fused
    refine kernel).  The refine phase is the carry's last reader, so
    the pipelined executor donates it (DESIGN.md §13)."""
    luts = build_lut(qs, env["C"])                       # (nq, K, m)
    probes = coarse_probe(qs, env["centroids"], n_probe)
    cand_ids, valid, cand_codes = gather_candidates(
        probes, env["lists"], env["codes"], topk, env["list_codes"])
    safe = jnp.where(valid, cand_ids, 0)
    stage = CrudeStage(backend=backend, topk=topk, block_q=block_q,
                       block_n=block_n, interpret=interpret,
                       quantized=quantized, code_bits=code_bits)
    if backend == "pallas":
        out = stage.slab(cand_codes, cand_ids, valid, luts, env["fast"])
        return (luts, out.crude, out.cand_vals, out.cand_idx, None,
                cand_codes, safe, valid)
    pred = env["pred"] if has_filter else None
    if pred is not None:
        # filtered rows score +inf crude: they can't pass eq. 2, can't
        # set the bootstrap threshold, and rank last
        valid = valid & pred[safe]
    out = stage.slab(cand_codes, cand_ids, valid, luts, env["fast"],
                     need_slow=refine_cap is None)
    return (luts, out.crude, None, None, out.slow, cand_codes, safe,
            valid)


def _ivf_refine_phase(carry, env, *, topk: int, backend: str,
                      block_q: int = 4, block_n: int = 128, interpret=None,
                      quantized: bool = False, code_bits: int = 8,
                      refine_cap: Optional[int] = None,
                      has_filter: bool = False):
    """Threshold bootstrap + refine over the crude carry.  Returns (ids
    (nq,topk), dist (nq,topk), n_cand (nq,), n_pass (nq,)).  The
    optional jnp ``refine_cap`` compaction re-ranks only the ``cap``
    best survivors by one full-table sum (the exact historical
    arithmetic, inline — it is a carry consumer, not a stage)."""
    luts, crude, cand_vals, cand_pos, slow, cand_codes, safe, valid = carry
    fast, sigma = env["fast"], env["sigma"]
    tstage = ThresholdStage(topk=topk, quantized=quantized,
                            code_bits=code_bits)
    rstage = RefineStage(backend=backend, topk=topk, block_q=block_q,
                         block_n=block_n, interpret=interpret,
                         code_bits=code_bits)
    n_cand = jnp.sum(valid.astype(jnp.float32), axis=1)
    if backend == "pallas":
        thr = tstage.from_slab_candidates(luts, cand_codes, cand_vals,
                                          cand_pos, fast, sigma)
        ids, dist, passed = rstage.slab(cand_codes, luts, crude, thr,
                                        fast, safe)
        n_pass = jnp.sum(passed.astype(jnp.float32), axis=1)
        return ids, dist, n_cand, n_pass
    pred = env["pred"] if has_filter else None
    thr = tstage.from_dense_slab(luts, cand_codes, crude,
                                 fast if quantized else None, sigma)
    passed = crude < thr[:, None]                        # invalid->inf->F
    if refine_cap is None:
        ids, dist, _ = rstage.slab(cand_codes, luts, crude, thr, fast,
                                   safe, slow=slow, pred=pred)
    else:
        # clamp into [topk, nc]: the slab is padded to >= topk columns
        cap = min(max(refine_cap, topk), crude.shape[1])
        masked = jnp.where(passed, crude, jnp.inf)
        neg_s, surv = jax.lax.top_k(-masked, cap)        # slab positions
        alive = jnp.isfinite(-neg_s)
        surv_codes = jnp.take_along_axis(cand_codes, surv[:, :, None],
                                         axis=1)         # (nq, cap, K)
        full_surv = lut_sum(luts, _widen_slab(surv_codes, luts.shape[1],
                                              code_bits))
        ranked = jnp.where(alive, full_surv, jnp.inf)
        neg, cpos = jax.lax.top_k(-ranked, topk)
        pos = jnp.take_along_axis(surv, cpos, axis=1)
        ids = jnp.take_along_axis(safe, pos, axis=1)
        dist = -neg
        if pred is not None:
            ids = mask_filtered_ids(ids, dist)
    n_pass = jnp.sum(passed.astype(jnp.float32), axis=1)
    return ids, dist, n_cand, n_pass


def _ivf_block_jnp(qs, codes, C, fast, sigma, topk: int, centroids, lists,
                   n_probe: int, refine_cap: Optional[int],
                   list_codes=None, quantized: bool = False,
                   code_bits: int = 8, pred=None):
    """Batched IVF two-step over one query block — the sequential
    composition of the crude and refine phases.  Returns (ids
    (nq,topk), dist (nq,topk), n_cand (nq,), n_pass (nq,))."""
    env = {"codes": codes, "C": C, "fast": fast, "sigma": sigma,
           "centroids": centroids, "lists": lists,
           "list_codes": list_codes, "pred": pred}
    crude_fn, refine_fn = ivf_phase_fns(
        topk=topk, n_probe=n_probe, backend="jnp", quantized=quantized,
        code_bits=code_bits, refine_cap=refine_cap,
        has_filter=pred is not None)
    return refine_fn(crude_fn(qs, env), env)


def _ivf_block_pallas(qs, codes, C, fast, sigma, topk: int, centroids,
                      lists, n_probe: int, block_q: int, block_n: int,
                      interpret, list_codes=None, quantized: bool = False,
                      code_bits: int = 8):
    """Fused-kernel batched IVF: the (query-tile x candidate-tile)
    kernels from ``kernels/batched_search.py`` sweep the gathered slab
    (phase-1 crude + running top-k, then fused eq. 2 + refine + top-k
    merge); the tiny threshold bootstrap stays in jnp.  ``quantized``
    feeds phase 1 int8 tables (dequantized in-kernel); phase 2 keeps
    the exact f32 slow tables either way."""
    env = {"codes": codes, "C": C, "fast": fast, "sigma": sigma,
           "centroids": centroids, "lists": lists,
           "list_codes": list_codes, "pred": None}
    crude_fn, refine_fn = ivf_phase_fns(
        topk=topk, n_probe=n_probe, backend="pallas", block_q=block_q,
        block_n=block_n, interpret=interpret, quantized=quantized,
        code_bits=code_bits)
    return refine_fn(crude_fn(qs, env), env)


def ivf_ops_result(ids, dist, n_cand, n_pass, *, n: int, n_lists: int,
                   K, kf) -> SearchResult:
    """Fold per-query candidate/pass counts into the generalized
    Average-Ops accounting shared by every IVF engine."""
    probed_frac = jnp.mean(n_cand) / n
    pass_rate = jnp.mean(n_pass) / jnp.maximum(jnp.mean(n_cand), 1.0)
    coarse = n_lists / n                                 # dots per point
    avg_ops = coarse * K / 2 + probed_frac * (kf + pass_rate * (K - kf))
    # (coarse dots cost ~d mults each ~ K/2 LUT-adds-equivalent at m=2d)
    return SearchResult(ids, dist, avg_ops, pass_rate)


def ivf_two_step_search(queries, codes, C, structure, ivf: IVFIndex,
                        topk: int, n_probe: int, *, backend: str = "auto",
                        block_q: int = 4, block_n: int = 128,
                        interpret=None, query_chunk: Optional[int] = None,
                        refine_cap: Optional[int] = None, list_codes=None,
                        lut_dtype: str = "f32", code_bits: int = 8,
                        filter=None):
    """Batched IVF + ICQ two-step.  Returns SearchResult with the
    generalized ops accounting (see module docstring).

    ``list_codes`` (optional, from ``ivf_list_codes``) serves from the
    in-list codes slab — same results, faster gather.  ``lut_dtype``
    ("f32" | "int8") selects the crude-pass table precision (DESIGN.md
    §8); the refine pass is always f32.  ``code_bits=4`` serves from
    nibble-packed codes/list_codes (DESIGN.md §12) — the fast-scan slab
    variant — with identical rankings to the 8-bit layout.  ``filter``:
    optional (n,) boolean row predicate (jnp engine only); excluded
    rows never appear in results — absent slots are id -1 / dist
    +inf."""
    from repro.index.flat import _check_fastscan_geometry, _check_filter

    K = C.shape[0]
    code_bits = _check_fastscan_geometry(code_bits, C.shape[1])
    fast = structure.fast_mask
    sigma = structure.sigma
    kf = jnp.sum(fast.astype(jnp.float32))
    n_lists = ivf.lists.shape[0]
    n = codes.shape[0]
    if not 1 <= n_probe <= n_lists:
        raise ValueError(f"n_probe={n_probe} outside [1, {n_lists}]")
    be = resolve_backend(backend)
    quantized = resolve_lut_dtype(lut_dtype) == "int8"
    pred = _check_filter(filter, n, be)

    if be == "pallas":
        if refine_cap is not None:
            raise ValueError("refine_cap compaction requires backend='jnp'"
                             " (the fused kernels bound phase-2 work with"
                             " the in-kernel top-k merge instead)")
        fn = functools.partial(_ivf_block_pallas, codes=codes, C=C,
                               fast=fast, sigma=sigma, topk=topk,
                               centroids=ivf.centroids, lists=ivf.lists,
                               n_probe=n_probe, block_q=block_q,
                               block_n=block_n, interpret=interpret,
                               list_codes=list_codes, quantized=quantized,
                               code_bits=code_bits)
    else:
        fn = functools.partial(_ivf_block_jnp, codes=codes, C=C, fast=fast,
                               sigma=sigma, topk=topk,
                               centroids=ivf.centroids, lists=ivf.lists,
                               n_probe=n_probe, refine_cap=refine_cap,
                               list_codes=list_codes, quantized=quantized,
                               code_bits=code_bits, pred=pred)
    ids, dist, n_cand, n_pass = chunked_over_queries(fn, queries,
                                                     query_chunk)
    return ivf_ops_result(ids, dist, n_cand, n_pass, n=n, n_lists=n_lists,
                          K=K, kf=kf)


def _ivf_crude_only_phase(qs, env, *, topk: int, n_probe: int,
                          backend: str, block_q: int = 4,
                          block_n: int = 128, interpret=None,
                          quantized: bool = False, code_bits: int = 8,
                          has_filter: bool = False):
    """Single-phase crude-only IVF ranking (the degradation ladder's
    floor): probe + gather + ``CrudeStage.slab`` + top-k, skipping
    eq. 2 and refinement — structurally the full path with its refine
    phase dropped, so the ranking is exactly the crude top-k the full
    path bootstraps its eq. 2 candidates from (same backend)."""
    luts = build_lut(qs, env["C"])
    probes = coarse_probe(qs, env["centroids"], n_probe)
    cand_ids, valid, cand_codes = gather_candidates(
        probes, env["lists"], env["codes"], topk, env["list_codes"])
    safe = jnp.where(valid, cand_ids, 0)
    stage = CrudeStage(backend=backend, topk=topk, block_q=block_q,
                       block_n=block_n, interpret=interpret,
                       quantized=quantized, code_bits=code_bits)
    if backend == "pallas":
        out = stage.slab(cand_codes, cand_ids, valid, luts, env["fast"])
        pos_safe = jnp.where(jnp.isfinite(out.cand_vals), out.cand_idx, 0)
        ids = jnp.take_along_axis(safe, pos_safe, axis=1)
        n_cand = jnp.sum(valid.astype(jnp.float32), axis=1)
        return ids, out.cand_vals, n_cand, jnp.zeros_like(n_cand)
    pred = env["pred"] if has_filter else None
    if pred is not None:
        valid = valid & pred[safe]
    out = stage.slab(cand_codes, cand_ids, valid, luts, env["fast"],
                     need_slow=False)
    neg_c, pos = jax.lax.top_k(-out.crude, topk)
    ids = jnp.take_along_axis(safe, pos, axis=1)
    if pred is not None:
        ids = mask_filtered_ids(ids, -neg_c)
    n_cand = jnp.sum(valid.astype(jnp.float32), axis=1)
    return ids, -neg_c, n_cand, jnp.zeros_like(n_cand)


def _ivf_crude_block_jnp(qs, codes, C, fast, topk: int, centroids, lists,
                         n_probe: int, list_codes=None,
                         quantized: bool = False, code_bits: int = 8,
                         pred=None):
    """Crude-only IVF ranking over one query block (jnp)."""
    env = {"codes": codes, "C": C, "fast": fast, "sigma": None,
           "centroids": centroids, "lists": lists,
           "list_codes": list_codes, "pred": pred}
    crude_fn, _ = ivf_phase_fns(
        topk=topk, n_probe=n_probe, backend="jnp", quantized=quantized,
        code_bits=code_bits, crude_only=True,
        has_filter=pred is not None)
    return crude_fn(qs, env)


def _ivf_crude_block_pallas(qs, codes, C, fast, topk: int, centroids,
                            lists, n_probe: int, block_q: int, block_n: int,
                            interpret, list_codes=None,
                            quantized: bool = False, code_bits: int = 8):
    """Crude-only IVF via the phase-1 kernel: ``ivf_crude_topk``'s
    running top-k over the slab *is* the crude ranking; phase 2 is
    skipped.  ``code_bits=4`` streams the nibble-packed slab through the
    fast-scan variant."""
    env = {"codes": codes, "C": C, "fast": fast, "sigma": None,
           "centroids": centroids, "lists": lists,
           "list_codes": list_codes, "pred": None}
    crude_fn, _ = ivf_phase_fns(
        topk=topk, n_probe=n_probe, backend="pallas", block_q=block_q,
        block_n=block_n, interpret=interpret, quantized=quantized,
        code_bits=code_bits, crude_only=True)
    return crude_fn(qs, env)


# ------------------------------------------------------ phase factories ----

def ivf_phase_env(codes, C, structure, ivf: IVFIndex, *, list_codes=None,
                  pred=None):
    """The borrowed-operand environment shared by every IVF phase — the
    arrays a ``PipelinedSearch`` executor may alias across query tiles
    (the phases only read them)."""
    return {"codes": codes, "C": C, "fast": structure.fast_mask,
            "sigma": structure.sigma, "centroids": ivf.centroids,
            "lists": ivf.lists, "list_codes": list_codes, "pred": pred}


def ivf_phase_fns(*, topk: int, n_probe: int, backend: str,
                  block_q: int = 4, block_n: int = 128, interpret=None,
                  quantized: bool = False, code_bits: int = 8,
                  refine_cap: Optional[int] = None,
                  crude_only: bool = False, has_filter: bool = False):
    """The IVF search split at the crude/refine boundary: returns
    ``(crude_fn, refine_fn)`` taking ``(qs|carry, env)`` — the phase
    pair both the sequential blocks above and the pipelined executor
    compose.  ``crude_only`` returns the single-phase floor as
    ``(crude_fn, None)``."""
    common = dict(topk=topk, backend=backend, block_q=block_q,
                  block_n=block_n, interpret=interpret,
                  quantized=quantized, code_bits=code_bits,
                  has_filter=has_filter)
    if crude_only:
        return (functools.partial(_ivf_crude_only_phase, n_probe=n_probe,
                                  **common), None)
    return (functools.partial(_ivf_crude_phase, n_probe=n_probe,
                              refine_cap=refine_cap, **common),
            functools.partial(_ivf_refine_phase, refine_cap=refine_cap,
                              **common))


def ivf_crude_search(queries, codes, C, structure, ivf: IVFIndex,
                     topk: int, n_probe: int, *, backend: str = "auto",
                     block_q: int = 4, block_n: int = 128, interpret=None,
                     query_chunk: Optional[int] = None, list_codes=None,
                     lut_dtype: str = "f32", code_bits: int = 8,
                     filter=None):
    """The IVF rung of the degradation ladder's crude floor
    (docs/robustness.md): probe + crude-only ranking over the candidate
    slab.  Bitwise-identical ids/values to the crude top-k the full
    path computes internally on the same backend.  ``avg_ops`` drops
    the pass-rate term (nothing refined).  ``code_bits=4`` serves the
    floor straight from the nibble-packed slab."""
    from repro.index.flat import _check_fastscan_geometry, _check_filter

    K = C.shape[0]
    code_bits = _check_fastscan_geometry(code_bits, C.shape[1])
    fast = structure.fast_mask
    kf = jnp.sum(fast.astype(jnp.float32))
    n_lists = ivf.lists.shape[0]
    n = codes.shape[0]
    if not 1 <= n_probe <= n_lists:
        raise ValueError(f"n_probe={n_probe} outside [1, {n_lists}]")
    be = resolve_backend(backend)
    quantized = resolve_lut_dtype(lut_dtype) == "int8"
    pred = _check_filter(filter, n, be)

    if be == "pallas":
        fn = functools.partial(_ivf_crude_block_pallas, codes=codes, C=C,
                               fast=fast, topk=topk,
                               centroids=ivf.centroids, lists=ivf.lists,
                               n_probe=n_probe, block_q=block_q,
                               block_n=block_n, interpret=interpret,
                               list_codes=list_codes, quantized=quantized,
                               code_bits=code_bits)
    else:
        fn = functools.partial(_ivf_crude_block_jnp, codes=codes, C=C,
                               fast=fast, topk=topk,
                               centroids=ivf.centroids, lists=ivf.lists,
                               n_probe=n_probe, list_codes=list_codes,
                               quantized=quantized, code_bits=code_bits,
                               pred=pred)
    ids, dist, n_cand, n_pass = chunked_over_queries(fn, queries,
                                                     query_chunk)
    return ivf_ops_result(ids, dist, n_cand, n_pass, n=n, n_lists=n_lists,
                          K=K, kf=kf)


# --------------------------------------------------------------- index ----

@dataclasses.dataclass(frozen=True)
class IVFTwoStep:
    """IVF-pruned ICQ two-step index: coarse partition probe + batched
    candidate-slab two-step."""
    codes: jnp.ndarray                  # (n, K) packed ((n, ceil(K/2))
                                        # nibble-packed at code_bits=4)
    C: jnp.ndarray                      # (K, m, d)
    structure: object                   # core.icq.ICQStructure
    ivf: IVFIndex
    n_probe: int = 8
    topk: int = 50
    backend: str = "auto"
    block_q: int = 4
    block_n: int = 128
    interpret: Optional[bool] = None
    query_chunk: Optional[int] = None
    refine_cap: Optional[int] = None
    lut_dtype: str = "f32"
    code_bits: int = 8
    list_codes: Optional[jnp.ndarray] = None     # (n_lists, max_len, K)
    pipeline: str = "off"                        # "off" | "tiles" | "auto"
    pipeline_tile: Optional[int] = None

    @classmethod
    def build(cls, codes, C, structure, *, emb_db, key=None,
              n_lists: int = 64, kmeans_iters: int = 20,
              **opts) -> "IVFTwoStep":
        """Fit the coarse quantizer over ``emb_db`` and assemble the
        index (codes slab moved inside the lists).  ``emb_db`` must be
        the embeddings the codes encode."""
        key = jax.random.PRNGKey(0) if key is None else key
        ivf = build_ivf(key, emb_db, n_lists, kmeans_iters=kmeans_iters)
        return cls(codes=codes, C=C, structure=structure, ivf=ivf,
                   list_codes=ivf_list_codes(ivf, codes), **opts)

    def search(self, queries, topk: Optional[int] = None, *,
               filter=None) -> SearchResult:
        k = topk if topk is not None else self.topk
        if self.pipeline != "off":
            from repro.index.pipelined import maybe_pipelined
            res = maybe_pipelined(self, queries, k, filter=filter)
            if res is not None:
                return res
        return ivf_two_step_search(
            queries, self.codes, self.C, self.structure, self.ivf,
            k, self.n_probe,
            backend=self.backend, block_q=self.block_q,
            block_n=self.block_n, interpret=self.interpret,
            query_chunk=self.query_chunk, refine_cap=self.refine_cap,
            list_codes=self.list_codes, lut_dtype=self.lut_dtype,
            code_bits=self.code_bits, filter=filter)

    def search_crude(self, queries, topk: Optional[int] = None,
                     n_probe: Optional[int] = None, *,
                     filter=None) -> SearchResult:
        """Crude-only floor (docs/robustness.md): probe + crude ranking
        with no refinement, bitwise-identical to the full path's
        internal crude top-k on the same backend.  ``n_probe`` lets the
        ladder's "probes" rung reuse this entry with a reduced probe
        count."""
        k = topk if topk is not None else self.topk
        if self.pipeline != "off":
            from repro.index.pipelined import maybe_pipelined
            res = maybe_pipelined(self, queries, k, filter=filter,
                                  crude_only=True, n_probe=n_probe)
            if res is not None:
                return res
        return ivf_crude_search(
            queries, self.codes, self.C, self.structure, self.ivf, k,
            n_probe if n_probe is not None else self.n_probe,
            backend=self.backend, block_q=self.block_q,
            block_n=self.block_n, interpret=self.interpret,
            query_chunk=self.query_chunk, list_codes=self.list_codes,
            lut_dtype=self.lut_dtype, code_bits=self.code_bits,
            filter=filter)

    def add(self, new_vectors, *, icm_iters: int = 3,
            encode_backend: str = "auto",
            point_chunk: Optional[int] = 8192) -> "IVFTwoStep":
        """Encode ``new_vectors`` ((n_new, d) embeddings) through the
        tiled engine and route them into the owning inverted lists —
        incremental build, coarse centroids fixed, no retraining
        (DESIGN.md §9).  New rows get ids [n, n + n_new); the in-list
        codes slab is rebuilt when the index serves from one.  Search
        results are identical to a from-scratch index over the
        concatenated embeddings with the same centroids
        (``ivf_assign``)."""
        from repro.index.flat import _encode_new_rows

        new = _encode_new_rows(new_vectors, self.C, self.codes.dtype,
                               icm_iters=icm_iters,
                               encode_backend=encode_backend,
                               point_chunk=point_chunk,
                               code_bits=self.code_bits)
        codes = jnp.concatenate([self.codes, new], axis=0)
        ivf = ivf_extend(self.ivf, new_vectors,
                         start_id=self.codes.shape[0])
        lc = (ivf_list_codes(ivf, codes) if self.list_codes is not None
              else None)
        return dataclasses.replace(self, codes=codes, ivf=ivf,
                                   list_codes=lc)

    def shard(self, mesh):
        from repro.index.sharded import ShardedIVFTwoStep
        return ShardedIVFTwoStep(self, mesh)
