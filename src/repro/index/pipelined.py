"""PipelinedSearch: the overlapped crude/refine executor (DESIGN.md §13).

The two-step engines are split at the crude/refine boundary into phase
pairs (``flat.two_step_phase_fns`` / ``ivf.ivf_phase_fns``) over
``(qs | carry, env)``.  This module schedules those phases over query
tiles so the crude pass of tile t+1 overlaps the threshold + refine of
tile t:

    crude(0) | refine(0)   refine(1)   refine(2) ...
             | crude(1)    crude(2)    crude(3)

Both phases are jitted once per static configuration; the refine jit
donates the inter-stage carry (``donate_argnums=(0,)``) — the refine
phase is the carry's last reader (the stage contract in
``kernels/stages.py``), so XLA recycles the dense (tile, n) crude
buffer across tiles instead of allocating a fresh one per tile.  The
borrowed index state (codes, codebooks, masks, inverted lists) is
closed over by both jits as trace constants and aliased across every
tile unchanged.

The schedule relies only on dispatch-ahead: ``crude_jit(t+1)`` is
dispatched *before* ``refine_jit(t)``'s result is consumed, so the two
computations overlap wherever the runtime executes asynchronously (TPU
always; CPU via the async dispatch queue).  Per-tile working sets are
also much smaller than whole-batch ones — the (tile, n) crude slab of a
refine-heavy point fits in cache where the (nq, n) one does not.

Results are bitwise-identical to the *jitted* sequential engines (what
``AnnEngine`` actually serves): every per-query row of every phase
output depends only on that query's row (eq. 2 thresholds bootstrap
from the query's own crude top-k), so tiling the query axis is
structurally the same computation as ``base.chunked_over_queries``, and
the aggregate accounting (pass-rate means, IVF candidate counts)
reduces the identical vectors.  To make that identity *bitwise*, the
phase jits mirror the engine's program structure exactly: index state
(codes, codebooks, masks) is closed over as jit constants — exactly as
``jax.jit(index.search)`` captures it — and only the per-call operands
(the query tile, the filter predicate) are traced arguments.  Passing
the index state as operands instead measurably changes XLA's lowering
of the LUT build (constants fold differently than parameters) and
drifts distances by ~1 ulp on some shapes.  The *eager* sequential
path can likewise differ from any jitted program by reassociation
ulps (eager dispatches one fused kernel per primitive); rankings
agree, and tests/test_stages.py pins the jit-vs-jit comparison
bitwise while holding the eager comparison to ids + 1-ulp distances.

``maybe_pipelined`` is the single routing entry the index dataclasses
call when their ``pipeline`` field is "tiles" or "auto": "tiles" always
engages (even a single tile — serving's engine wrappers rely on the
executor owning the jit boundary), "auto" declines batches of one tile
or less (returning None, falling back to the sequential path).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp

# Donation is an aliasing hint: on TPU the refine phase recycles its
# donated (tile, n) carry for same-shaped outputs/temporaries; CPU XLA
# declines (the refine outputs are (tile, topk)) and warns once per
# trace — expected and not actionable, so silence exactly that message.
warnings.filterwarnings("ignore",
                        message="Some donated buffers were not usable")

from repro.index.base import SearchResult
from repro.kernels.stages import pad_to

PIPELINE_MODES = ("off", "tiles", "auto")
_DEFAULT_TILE_JNP = 16


def resolve_pipeline(value: str) -> str:
    if value not in PIPELINE_MODES:
        raise ValueError(f"unknown pipeline mode {value!r}; expected one "
                         f"of {PIPELINE_MODES}")
    return value


def resolve_tile(pipeline_tile: Optional[int], backend: str,
                 block_q: int) -> int:
    """The query-tile size: explicit ``pipeline_tile`` wins; otherwise
    one kernel query-block per tile on pallas (the kernel grid already
    tiles queries at block_q) and a small cache-friendly default on
    jnp."""
    if pipeline_tile is not None:
        tile = int(pipeline_tile)
        if tile < 1:
            raise ValueError(f"pipeline_tile must be a positive int, "
                             f"got {pipeline_tile!r}")
        return tile
    return block_q if backend == "pallas" else _DEFAULT_TILE_JNP


def _phase_fns(kind: str, crude_only: bool, topk: int, backend: str,
               block_q: int, block_n: int, interpret, quantized: bool,
               code_bits: int, refine_cap: Optional[int],
               has_filter: bool, n_probe: Optional[int]):
    """The raw (crude, refine) phase pair for one static engine
    configuration (refine is None for the single-phase engines)."""
    common = dict(topk=topk, backend=backend, block_q=block_q,
                  block_n=block_n, interpret=interpret,
                  quantized=quantized, code_bits=code_bits,
                  has_filter=has_filter)
    if kind == "ivf":
        from repro.index.ivf import ivf_phase_fns
        return ivf_phase_fns(n_probe=n_probe, refine_cap=refine_cap,
                             crude_only=crude_only, **common)
    if kind == "adc":
        from repro.index.flat import adc_phase_fns
        return adc_phase_fns(**common)
    from repro.index.flat import two_step_phase_fns
    return two_step_phase_fns(refine_cap=refine_cap,
                              crude_only=crude_only, **common)


def _bind_jits(crude_fn, refine_fn, env: dict, has_filter: bool):
    """Close the phase fns over the borrowed index state and jit them.

    The env arrays become jit *constants* — the same capture structure
    as ``jax.jit(index.search)``, which is what keeps the pipelined
    programs bitwise-equal to the jitted sequential engines (module
    docstring).  Only the query tile / carry and (when filtering) the
    predicate are traced operands; the refine jit donates the carry it
    is the last reader of."""
    if has_filter:
        crude_jit = jax.jit(
            lambda qs, pred: crude_fn(qs, dict(env, pred=pred)))
        refine_jit = (None if refine_fn is None else jax.jit(
            lambda carry, pred: refine_fn(carry, dict(env, pred=pred)),
            donate_argnums=(0,)))
    else:
        crude_jit = jax.jit(lambda qs: crude_fn(qs, env))
        refine_jit = (None if refine_fn is None else jax.jit(
            lambda carry: refine_fn(carry, env), donate_argnums=(0,)))
    return crude_jit, refine_jit


@dataclasses.dataclass(frozen=True)
class PipelinedSearch:
    """A bound pipelined-search plan: the jitted phase pair (index
    state closed over), the tile size and the finalizer that folds
    concatenated per-query outputs into a SearchResult.  ``pred`` (the
    optional filter predicate) is the one per-call operand besides the
    query tiles — pass it iff the plan was bound with a filter."""
    crude_jit: Callable
    refine_jit: Optional[Callable]
    tile: int
    finalize: Callable

    def __call__(self, queries, pred=None) -> SearchResult:
        args = () if pred is None else (pred,)
        nq = queries.shape[0]
        n_tiles = -(-nq // self.tile)
        qp = pad_to(queries, n_tiles * self.tile)
        tiles = [qp[t * self.tile:(t + 1) * self.tile]
                 for t in range(n_tiles)]
        outs = []
        if self.refine_jit is None:
            # single-phase pipelines (ADC / the degraded crude rung):
            # nothing to overlap against, but tile dispatch still
            # streams ahead of result consumption
            for tq in tiles:
                outs.append(self.crude_jit(tq, *args))
        else:
            carry = self.crude_jit(tiles[0], *args)
            for t in range(n_tiles):
                # dispatch crude(t+1) before touching refine(t): the
                # async runtime overlaps the two, and refine donates
                # the carry it is the last reader of
                nxt = (self.crude_jit(tiles[t + 1], *args)
                       if t + 1 < n_tiles else None)
                outs.append(self.refine_jit(carry, *args))
                carry = nxt
        cat = tuple(jnp.concatenate(parts, axis=0)[:nq]
                    for parts in zip(*outs))
        return self.finalize(*cat)


def _plan(index, topk: int, *, crude_only: bool, has_filter: bool,
          n_probe: Optional[int]) -> PipelinedSearch:
    """Bind an index's configuration to a PipelinedSearch plan."""
    from repro.index import flat, ivf
    from repro.index.base import resolve_backend, resolve_lut_dtype

    be = resolve_backend(index.backend)
    quantized = resolve_lut_dtype(index.lut_dtype) == "int8"
    code_bits = flat._check_fastscan_geometry(index.code_bits,
                                              index.C.shape[1])
    K = index.C.shape[0]
    tile = resolve_tile(index.pipeline_tile, be, index.block_q)
    refine_cap = getattr(index, "refine_cap", None)
    if be == "pallas" and refine_cap is not None:
        raise ValueError("refine_cap compaction requires backend='jnp'"
                         " (the fused kernels bound phase-2 work with"
                         " the in-kernel top-k merge instead)")

    if isinstance(index, ivf.IVFTwoStep):
        np_ = n_probe if n_probe is not None else index.n_probe
        n_lists = index.ivf.lists.shape[0]
        n = index.codes.shape[0]
        if not 1 <= np_ <= n_lists:
            raise ValueError(f"n_probe={np_} outside [1, {n_lists}]")
        kf = jnp.sum(index.structure.fast_mask.astype(jnp.float32))
        env = ivf.ivf_phase_env(index.codes, index.C, index.structure,
                                index.ivf, list_codes=index.list_codes)
        cf, rf = _phase_fns("ivf", crude_only, topk, be, index.block_q,
                            index.block_n, index.interpret, quantized,
                            code_bits, refine_cap, has_filter, np_)
        cj, rj = _bind_jits(cf, rf, env, has_filter)
        finalize = functools.partial(ivf.ivf_ops_result, n=n,
                                     n_lists=n_lists, K=K, kf=kf)
        return PipelinedSearch(cj, rj, tile, finalize)

    if isinstance(index, flat.FlatADC):
        codes = (index.codes if (be == "pallas" or code_bits == 4)
                 else index.codes.astype(jnp.int32))
        env = {"codes": codes, "C": index.C, "pred": None}
        cf, rf = _phase_fns("adc", True, topk, be, index.block_q,
                            index.block_n, index.interpret, quantized,
                            code_bits, None, has_filter, None)
        cj, rj = _bind_jits(cf, rf, env, has_filter)

        def finalize(idx, vals, _pf):
            return SearchResult(idx, vals, jnp.asarray(float(K)),
                                jnp.asarray(1.0))
        return PipelinedSearch(cj, rj, tile, finalize)

    # flat TwoStep
    kf = jnp.sum(index.structure.fast_mask.astype(jnp.float32))
    if refine_cap is not None:
        refine_cap = min(max(refine_cap, topk), index.codes.shape[0])
    env = flat.two_step_phase_env(index.codes, index.C, index.structure,
                                  backend=be, code_bits=code_bits)
    cf, rf = _phase_fns("two_step", crude_only, topk, be, index.block_q,
                        index.block_n, index.interpret, quantized,
                        code_bits, refine_cap, has_filter, None)
    cj, rj = _bind_jits(cf, rf, env, has_filter)

    if crude_only:
        def finalize(idx, dist, pf):
            return SearchResult(idx, dist, kf, jnp.mean(pf))
    else:
        def finalize(idx, dist, pf):
            pass_rate = jnp.mean(pf)
            avg_ops = kf + pass_rate * (K - kf)
            return SearchResult(idx, dist, avg_ops, pass_rate)
    return PipelinedSearch(cj, rj, tile, finalize)


def plan_for(index, topk: int, *, crude_only: bool = False,
             has_filter: bool = False,
             n_probe: Optional[int] = None) -> PipelinedSearch:
    """The per-index plan cache.  Plans close over the index's device
    arrays (``_bind_jits``), so they are cached *on the instance* —
    ``dataclasses.replace`` / ``Index.add`` return fresh objects and
    therefore fresh plans, which keeps a cached closure from ever
    serving stale state.  Repeated searches on one index reuse the
    traced phase pair (jit's signature cache handles tile shapes)."""
    key = (topk, crude_only, has_filter, n_probe)
    cache = index.__dict__.get("_pipeline_plans")
    if cache is None:
        cache = {}
        object.__setattr__(index, "_pipeline_plans", cache)
    plan = cache.get(key)
    if plan is None:
        plan = _plan(index, topk, crude_only=crude_only,
                     has_filter=has_filter, n_probe=n_probe)
        cache[key] = plan
    return plan


def maybe_pipelined(index, queries, topk: int, *, filter=None,
                    crude_only: bool = False,
                    n_probe: Optional[int] = None
                    ) -> Optional[SearchResult]:
    """Route a search through the pipelined executor if the index's
    ``pipeline`` mode engages; returns None to fall back to the
    sequential path ("auto" with a batch of one tile or less)."""
    from repro.index import flat
    from repro.index.base import resolve_backend

    mode = resolve_pipeline(index.pipeline)
    if mode == "off":
        return None
    be = resolve_backend(index.backend)
    tile = resolve_tile(index.pipeline_tile, be, index.block_q)
    if mode == "auto" and queries.shape[0] <= tile:
        return None
    pred = flat._check_filter(filter, index.codes.shape[0], be)
    plan = plan_for(index, topk, crude_only=crude_only,
                    has_filter=pred is not None, n_probe=n_probe)
    return plan(queries, pred)
