"""Mesh-sharded ANN serving (DESIGN.md §7): packed codes / IVF lists
sharded over the ``data`` mesh axis via ``shard_map``, per-shard local
top-k, and a global merge that returns *bitwise-identical ids* to the
single-device engines (distances agree to float-reassociation ulps:
the SPMD-partitioned program may reassociate the LUT einsum).

Merge discipline: every local top-k carries (distance, global key)
pairs; shards ``all_gather`` their candidate lists and a two-key
ascending ``lax.sort`` on (distance, key) reproduces ``jax.lax.top_k``'s
lowest-index-wins tie-breaking globally.  Because each shard computes
its columns with the same per-column arithmetic as the single-device
engine (LUT sums reduce over K only), the merged ranking — including
the +inf tail and the eq. 2 threshold bootstrap, which is merged
*before* thresholding so every shard prunes against the global
threshold — reproduces the single-device ranking exactly.

The shard_map bodies are jnp-only: the ``backend`` / ``interpret`` /
tile options of the source index apply to its single-device engines and
are intentionally not consulted here (fused-kernel sharded serving is a
TPU bring-up item; the dispatch makes it a local change).  Likewise
``pipeline`` (DESIGN.md §13): the sharded clones always serve
``pipeline="off"`` — the shard_map body is one fused SPMD program per
batch, so there is no host-level crude/refine boundary to overlap;
sharding a pipelined index yields a working non-pipelined clone.

``lut_dtype`` *is* honored: with "int8" each shard runs its crude pass
on the quantized tables (DESIGN.md §8).  Calibration is query-global by
construction — ``quantize_lut`` derives scale/bias from the per-query
LUT alone, which is computed from the *replicated* codebooks inside the
shard_map body, so every shard quantizes with the identical affine and
dequantized crude distances merge comparably across shards (a per-shard
min/max would break the global top-k ordering).  The refine/full pass
stays f32 on every shard, and the eq. 2 bootstrap mirrors the
single-device quantized decomposition (quantized-crude + exact-slow),
so sharded ids remain bitwise-identical to the single-device
``lut_dtype="int8"`` engines.

Layouts:
  ShardedFlatADC / ShardedTwoStep   codes rows sharded: shard s owns
      global rows [s*ns, (s+1)*ns); local top-k keys are global row ids.
  ShardedIVFTwoStep                 inverted lists sharded: shard s owns
      list rows [s*Ls, (s+1)*Ls) plus the per-list packed codes slab
      gathered at shard time (codes live *inside* the inverted lists,
      the classic IVF serving layout).  Probes are computed from the
      replicated centroids; a probe slot is processed by exactly the
      shard owning that list, every other shard masks it to
      (+inf, id_max) so no slab position is ever contributed twice.
      Keys are slab positions (probe-slot major) — the single-device
      candidate order.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.encode import unpack_nibbles
from repro.distributed.sharding import shard_map_compat
from repro.index import ivf as ivf_mod
from repro.index.base import (SearchResult, as_filter, build_lut,
                              lut_sum, mask_filtered_ids, quantize_lut,
                              resolve_code_bits, resolve_lut_dtype)

_I32_MAX = jnp.iinfo(jnp.int32).max


def _put(mesh, x, spec):
    return jax.device_put(x, NamedSharding(mesh, spec))


def _pad_rows(x, rows, fill=0):
    if x.shape[0] == rows:
        return x
    pad = [(0, rows - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad, constant_values=fill)


def _sharded_add(self, new_vectors, **kw):
    """Sharded serving clones are immutable: they copy only the padded,
    sharded arrays (never retaining the source index), so there is
    nothing to append to.  Grow the *source* index with ``Index.add``
    and re-shard — ``quant.serve_icq.build_ann_engine`` keeps the
    source index and does exactly that in its ``add``."""
    raise NotImplementedError(
        "sharded indexes are serving clones: call add() on the source "
        "index and re-shard(mesh) (or use build_ann_engine(...).add, "
        "which keeps the source index for you)")


def _shard_row_filter(self, filter):
    """Validate an (n,) row predicate and lay it out P("data") alongside
    the row-sharded codes (pad rows fill False — a pad row is never a
    real candidate anyway)."""
    f = as_filter(filter, self.n)
    D = _data_size(self.mesh)
    return _put(self.mesh, _pad_rows(f, D * self.ns, fill=False),
                P("data"))


def _gather_sorted(cols, axis_name: str, num_keys: int = 2):
    """all_gather each (nq, k) operand along the shard axis and two-key
    sort ascending — the global merge primitive.  Returns the sorted
    (nq, D*k) operands."""
    gathered = tuple(jax.lax.all_gather(c, axis_name, axis=1, tiled=True)
                     for c in cols)
    return jax.lax.sort(gathered, dimension=1, num_keys=num_keys)


def _data_size(mesh) -> int:
    return mesh.shape["data"]


def _sanitize(dist):
    """Defensive NaN/Inf scrub for per-shard distances: a poisoned
    shard's garbage must sort dead-last, never win a top-k or wedge the
    two-key merge sort (NaN ordering is unspecified).  Bitwise no-op on
    finite data, so the healthy path keeps single-device parity."""
    return jnp.nan_to_num(dist, nan=jnp.inf, posinf=jnp.inf,
                          neginf=jnp.inf)


class _DeadShardMixin:
    """Shard-failover surface shared by the sharded serving clones
    (docs/robustness.md).

    ``mark_shard_dead(s, ...)`` excludes shards from serving: every
    compiled body masks a dead shard's contributions to +inf before the
    global merge, so ``search`` returns the surviving shards' merged
    top-k instead of raising — results are exactly the single-device
    ranking restricted to the surviving shards' rows (flat/two-step row
    sharding; for list-sharded IVF, restricted to the surviving shards'
    inverted lists).  ``coverage`` reports the reachable fraction of
    the database; the serving engine surfaces it on ``ResultMeta`` and
    flags the result degraded.

    The dead set is *static* per compiled function — it joins the jit
    cache key — so failover costs one recompile, not a per-batch
    branch.  Marking is in-place (serving clones hold device buffers;
    callers keep their reference) and monotone; a replacement shard
    means re-sharding the source index."""

    dead_shards: frozenset = frozenset()
    # sharded clones never pipeline (module docstring): the engine
    # wrappers probe this field to decide who owns the jit boundary
    pipeline: str = "off"

    def mark_shard_dead(self, *shards: int):
        D = _data_size(self.mesh)
        for s in shards:
            if not 0 <= s < D:
                raise ValueError(f"shard {s} outside [0, {D})")
        dead = self.dead_shards | set(shards)
        if len(dead) >= D:
            raise ValueError(
                f"cannot mark all {D} shards dead — no data would remain "
                "(re-shard the source index instead)")
        self.dead_shards = frozenset(dead)
        return self

    def _dead_key(self):
        return tuple(sorted(self.dead_shards))

    def _alive_arr(self):
        """(D,) bool, True where the shard still serves."""
        D = _data_size(self.mesh)
        alive = np.ones(D, bool)
        alive[list(self.dead_shards)] = False
        return jnp.asarray(alive)

    @property
    def coverage(self) -> float:
        """Reachable fraction of the database's real rows (1.0 = no
        dead shards)."""
        if not self.dead_shards:
            return 1.0
        return self._alive_rows() / max(self.n, 1)


# ------------------------------------------------------------- flat ADC ----

class ShardedFlatADC(_DeadShardMixin):
    """Row-sharded one-step ADC: local full LUT sums + local top-k,
    merged by (distance, global row id).

    Construction (`FlatADC.shard(mesh)`): codes rows are zero-padded up
    to a multiple of the shard count and laid out P("data") — shard s
    owns global rows [s*ns, (s+1)*ns); pad rows are masked to +inf
    before the local top-k so they never merge.  ``lut_dtype`` follows
    the source index (int8 = quantized full-table sums, query-global
    calibration — see module docstring)."""

    def __init__(self, base, mesh):
        self.mesh = mesh
        self.C = _put(mesh, base.C, P())
        n = base.codes.shape[0]
        D = _data_size(mesh)
        self.n = n
        self.ns = -(-n // D)
        self.topk = base.topk
        self.lut_dtype = resolve_lut_dtype(getattr(base, "lut_dtype", "f32"))
        self.code_bits = resolve_code_bits(getattr(base, "code_bits", 8))
        self.codes = _put(mesh, _pad_rows(base.codes, D * self.ns),
                          P("data"))
        self.dead_shards = frozenset()
        self._fns = {}

    def _alive_rows(self) -> int:
        # shard s owns real rows [s*ns, min((s+1)*ns, n))
        return sum(max(0, min((s + 1) * self.ns, self.n) - s * self.ns)
                   for s in range(_data_size(self.mesh))
                   if s not in self.dead_shards)

    def _fn(self, topk: int, has_filter: bool = False):
        key = (topk, self._dead_key(), has_filter)
        if key in self._fns:
            return self._fns[key]
        C, n, ns = self.C, self.n, self.ns
        K = C.shape[0]
        k_loc = min(topk, ns)
        quantized = self.lut_dtype == "int8"
        code_bits = self.code_bits
        alive = self._alive_arr()

        def body(qs, codes_shard, *rest):
            si = jax.lax.axis_index("data")
            off = si * ns
            if code_bits == 4:      # nibble slab: unpack once per shard
                codes_shard = unpack_nibbles(codes_shard, K)
            luts = build_lut(qs, C)
            lut = quantize_lut(luts) if quantized else luts
            dist = lut_sum(lut, codes_shard)               # (nq, ns)
            gids = off + jnp.arange(ns, dtype=jnp.int32)
            keep = (gids[None, :] < n) & alive[si]
            if has_filter:
                keep = keep & rest[0][None, :]
            dist = jnp.where(keep, _sanitize(dist), jnp.inf)
            neg, li = jax.lax.top_k(-dist, k_loc)
            mv, mg = _gather_sorted((-neg, jnp.take(gids, li)), "data")
            return mg[:, :topk], mv[:, :topk]

        specs = (P(), P("data")) + ((P("data"),) if has_filter else ())
        fn = jax.jit(shard_map_compat(
            body, self.mesh, in_specs=specs,
            out_specs=(P(), P())))
        self._fns[key] = fn
        return fn

    def search(self, queries, topk: Optional[int] = None, *,
               filter=None) -> SearchResult:
        """queries (nq, d) f32 -> SearchResult; ids bitwise-identical
        to the single-device engine, distances to reassociation ulps.
        ``filter``: optional (n,) boolean row predicate."""
        topk = self.topk if topk is None else topk
        if filter is not None:
            pred = _shard_row_filter(self, filter)
            idx, dist = self._fn(topk, True)(queries, self.codes, pred)
            idx = mask_filtered_ids(idx, dist)
        else:
            idx, dist = self._fn(topk)(queries, self.codes)
        K = self.C.shape[0]
        return SearchResult(idx, dist, jnp.asarray(float(K)),
                            jnp.asarray(1.0))

    add = _sharded_add

    def shard(self, mesh):
        raise ValueError("index is already sharded")


# ------------------------------------------------------------- two-step ----

class ShardedTwoStep(_DeadShardMixin):
    """Row-sharded ICQ two-step.  The eq. 2 threshold is bootstrapped
    from the *merged* global crude top-k (each shard refines its local
    crude candidates, shards exchange (crude, gid, full) triples), so
    every shard prunes against the exact single-device threshold.

    Construction (`TwoStep.shard(mesh)`): codes rows zero-padded to a
    multiple of the shard count, laid out P("data"); pad rows mask to
    +inf before every local top-k.  ``lut_dtype="int8"`` quantizes the
    crude pass per shard with the query-global affine (module
    docstring); the slow/full tables stay f32."""

    def __init__(self, base, mesh):
        self.mesh = mesh
        self.C = _put(mesh, base.C, P())
        self.structure = base.structure
        n = base.codes.shape[0]
        D = _data_size(mesh)
        self.n = n
        self.ns = -(-n // D)
        self.topk = base.topk
        self.lut_dtype = resolve_lut_dtype(getattr(base, "lut_dtype", "f32"))
        self.code_bits = resolve_code_bits(getattr(base, "code_bits", 8))
        self.codes = _put(mesh, _pad_rows(base.codes, D * self.ns),
                          P("data"))
        self.dead_shards = frozenset()
        self._fns = {}

    def _alive_rows(self) -> int:
        return sum(max(0, min((s + 1) * self.ns, self.n) - s * self.ns)
                   for s in range(_data_size(self.mesh))
                   if s not in self.dead_shards)

    def _fn(self, topk: int, has_filter: bool = False):
        key = (topk, self._dead_key(), has_filter)
        if key in self._fns:
            return self._fns[key]
        C, n, ns = self.C, self.n, self.ns
        K = C.shape[0]
        fast = self.structure.fast_mask
        sigma = self.structure.sigma
        k_loc = min(topk, ns)
        quantized = self.lut_dtype == "int8"
        code_bits = self.code_bits
        alive = self._alive_arr()

        def body(qs, codes_shard, *rest):
            si = jax.lax.axis_index("data")
            off = si * ns
            if code_bits == 4:      # nibble slab: unpack once per shard
                codes_shard = unpack_nibbles(codes_shard, K)
            luts = build_lut(qs, C)
            crude_lut = quantize_lut(luts, fast) if quantized else luts
            crude = lut_sum(crude_lut, codes_shard, fast)  # (nq, ns)
            gids = off + jnp.arange(ns, dtype=jnp.int32)
            keep = (gids[None, :] < n) & alive[si]
            if has_filter:
                # filtered rows: crude +inf, so they can't bootstrap the
                # eq. 2 threshold, can't pass it, and rank dead last —
                # same exclusion semantics as the single-device engine
                keep = keep & rest[0][None, :]
            crude = jnp.where(keep, _sanitize(crude), jnp.inf)

            # phase 1: local crude top-k + local full distances, merged
            # globally before the threshold bootstrap (quantized mode
            # mirrors the single-device decomposition: quantized crude
            # + exact slow)
            neg_c, li = jax.lax.top_k(-crude, k_loc)
            cand_codes = jnp.take(codes_shard, li, axis=0)
            if quantized:
                full_cand = -neg_c + lut_sum(luts, cand_codes, ~fast)
            else:
                full_cand = lut_sum(luts, cand_codes)      # (nq, k_loc)
            sv, _, sf = _gather_sorted(
                (-neg_c, jnp.take(gids, li), full_cand), "data")
            sv, sf = sv[:, :topk], sf[:, :topk]
            # +inf crude slots (dead shards / tiny dbs) carry garbage
            # full distances — exclude them from the far-element argmax
            # (no-op when the merged top-k is fully populated)
            far = jnp.argmax(jnp.where(jnp.isfinite(sv), sf, -jnp.inf),
                             axis=1)
            t = jnp.take_along_axis(sv, far[:, None], axis=1)[:, 0]
            thr = t + sigma

            # phase 2: prune against the global threshold, local refine
            # top-k, merge by (full distance, global id)
            passed = crude < thr[:, None]
            slow = lut_sum(luts, codes_shard, ~fast)
            ranked = jnp.where(passed, crude + slow, jnp.inf)
            neg, li2 = jax.lax.top_k(-ranked, k_loc)
            mv, mg = _gather_sorted((-neg, jnp.take(gids, li2)), "data")
            pf = jax.lax.psum(
                jnp.sum(passed.astype(jnp.float32), axis=1), "data") / n
            return mg[:, :topk], mv[:, :topk], pf

        specs = (P(), P("data")) + ((P("data"),) if has_filter else ())
        fn = jax.jit(shard_map_compat(
            body, self.mesh, in_specs=specs,
            out_specs=(P(), P(), P())))
        self._fns[key] = fn
        return fn

    def search(self, queries, topk: Optional[int] = None, *,
               filter=None) -> SearchResult:
        """queries (nq, d) f32 -> SearchResult; ids and pass accounting
        bitwise-identical to the single-device engine.  ``filter``:
        optional (n,) boolean row predicate."""
        topk = self.topk if topk is None else topk
        if filter is not None:
            pred = _shard_row_filter(self, filter)
            idx, dist, pf = self._fn(topk, True)(queries, self.codes,
                                                 pred)
            idx = mask_filtered_ids(idx, dist)
        else:
            idx, dist, pf = self._fn(topk)(queries, self.codes)
        K = self.C.shape[0]
        kf = jnp.sum(self.structure.fast_mask.astype(jnp.float32))
        pass_rate = jnp.mean(pf)
        return SearchResult(idx, dist, kf + pass_rate * (K - kf), pass_rate)

    add = _sharded_add

    def shard(self, mesh):
        raise ValueError("index is already sharded")


# ------------------------------------------------------------------ IVF ----

class ShardedIVFTwoStep(_DeadShardMixin):
    """List-sharded batched IVF: shard s owns list rows
    [s*Ls, (s+1)*Ls) and their packed codes slab.  Candidate keys are
    slab positions (probe-slot major), identical to the single-device
    candidate order, so the merged ranking is bitwise-equal.

    Construction (`IVFTwoStep.shard(mesh)`): list rows and the in-list
    codes slab are padded to a multiple of the shard count (pad lists
    all-invalid, id -1) and laid out P("data"); centroids/codebooks are
    replicated.  ``lut_dtype="int8"`` runs each shard's slab crude pass
    on the query-global quantized tables (module docstring); the
    refine/full pass stays f32."""

    def __init__(self, base, mesh):
        # copy fields rather than retaining base: the sharded clone must
        # not pin the replicated codes/slab arrays for its lifetime
        self.mesh = mesh
        self.C = _put(mesh, base.C, P())
        self.structure = base.structure
        self.centroids = _put(mesh, base.ivf.centroids, P())
        n_lists, max_len = base.ivf.lists.shape
        D = _data_size(mesh)
        self.n = base.codes.shape[0]
        self.n_lists = n_lists
        self.max_len = max_len
        self.Ls = -(-n_lists // D)
        self.n_probe = base.n_probe
        self.topk = base.topk
        self.refine_cap = base.refine_cap
        self.lut_dtype = resolve_lut_dtype(getattr(base, "lut_dtype", "f32"))
        self.code_bits = resolve_code_bits(getattr(base, "code_bits", 8))
        lists_p = _pad_rows(base.ivf.lists, D * self.Ls, fill=-1)
        # codes live inside the inverted lists (ivf_list_codes slab) so
        # serving never touches the flat codes array; pad rows are
        # all-invalid (validity rides on the id slab)
        slab = (base.list_codes if base.list_codes is not None
                else ivf_mod.ivf_list_codes(base.ivf, base.codes))
        slab = _pad_rows(slab, D * self.Ls)
        self.lists = _put(mesh, lists_p, P("data"))
        self.list_codes = _put(mesh, slab, P("data"))
        # host-side per-list sizes (padded rows own 0 points) so
        # ``coverage`` under dead shards is computable without a gather
        lens = np.zeros(D * self.Ls, np.int64)
        lens[:n_lists] = np.asarray(base.ivf.list_lens)
        self._list_lens = lens
        self.dead_shards = frozenset()
        self._fns = {}

    def _alive_rows(self) -> int:
        # shard s owns list rows [s*Ls, (s+1)*Ls); its reachable points
        # are the sizes of those inverted lists
        Ls = self.Ls
        return int(sum(self._list_lens[s * Ls:(s + 1) * Ls].sum()
                       for s in range(_data_size(self.mesh))
                       if s not in self.dead_shards))

    def _fn(self, topk: int, has_filter: bool = False):
        key = (topk, self._dead_key(), has_filter)
        if key in self._fns:
            return self._fns[key]
        C, centroids = self.C, self.centroids
        fast = self.structure.fast_mask
        sigma = self.structure.sigma
        n_probe, Ls, max_len = self.n_probe, self.Ls, self.max_len
        refine_cap = self.refine_cap
        # a shard owns at most min(n_probe, Ls) of a query's probes:
        # compact the owned probe slots into that static bound so the
        # per-shard slab sweep is ~1/D of the single-device work (the
        # point of partition-parallel serving), instead of scoring the
        # full n_probe slab with non-owned columns masked
        P_loc = min(n_probe, Ls)
        nc0 = n_probe * max_len                  # single-device slab width
        nc = max(nc0, topk)
        nc_loc0 = P_loc * max_len
        nc_loc = max(nc_loc0, topk)
        k_loc = min(topk, nc_loc)
        cap = (None if refine_cap is None
               else min(max(refine_cap, topk), nc))
        cap_loc = None if cap is None else min(cap, nc_loc)
        quantized = self.lut_dtype == "int8"
        code_bits = self.code_bits
        alive = self._alive_arr()

        def body(qs, lists_sh, slab_sh, *rest):
            si = jax.lax.axis_index("data")
            L0 = si * Ls
            nq = qs.shape[0]
            luts = build_lut(qs, C)
            probes = ivf_mod.coarse_probe(qs, centroids, n_probe)
            local = (probes >= L0) & (probes < L0 + Ls)    # (nq, n_probe)
            # owned probe slots first, in slot order (rank = slot index
            # for owned, n_probe for the rest; top_k of the negation)
            slot = jnp.arange(n_probe, dtype=jnp.int32)[None, :]
            _, sel = jax.lax.top_k(-jnp.where(local, slot, n_probe), P_loc)
            sel_local = jnp.take_along_axis(local, sel, axis=1)
            rows = jnp.where(
                sel_local, jnp.take_along_axis(probes, sel, axis=1) - L0, 0)
            ids = jnp.where(sel_local[:, :, None], lists_sh[rows], -1)
            ids = ids.reshape(nq, nc_loc0)
            codes = slab_sh[rows].reshape(nq, nc_loc0, -1)  # packed dtype
            if code_bits == 4:  # nibble slab: unpack the gathered rows
                codes = unpack_nibbles(codes, C.shape[0])
            owned = jnp.repeat(sel_local, max_len, axis=1)  # (nq, nc_loc0)
            # global slab positions (probe-slot major — the
            # single-device candidate order) of the compacted columns
            pos = (sel[:, :, None] * max_len
                   + jnp.arange(max_len, dtype=jnp.int32)[None, None, :]
                   ).reshape(nq, nc_loc0)
            if nc_loc > nc_loc0:                 # tiny-slab pad columns
                extra = nc_loc - nc_loc0         # (global pos nc0..nc-1,
                ids = jnp.pad(ids, ((0, 0), (0, extra)),  # shard 0 owns)
                              constant_values=-1)
                codes = jnp.pad(codes, ((0, 0), (0, extra), (0, 0)))
                owned = jnp.concatenate(
                    [owned, jnp.broadcast_to(si == 0, (nq, extra))], axis=1)
                pos = jnp.concatenate(
                    [pos, jnp.broadcast_to(
                        nc0 + jnp.arange(extra, dtype=jnp.int32)[None],
                        (nq, extra))], axis=1)
            valid = owned & (ids >= 0) & alive[si]
            safe = jnp.where(valid, ids, 0)
            if has_filter:
                # replicated (n,) predicate — same exclusion as the
                # single-device engine's valid &= pred[safe]
                valid = valid & rest[0][safe]

            crude_lut = quantize_lut(luts, fast) if quantized else luts
            crude = lut_sum(crude_lut, codes, fast)        # (nq, nc_loc)
            crude = jnp.where(valid, _sanitize(crude), jnp.inf)
            # a slab position is contributed by its owning shard only;
            # everywhere else it sorts dead last
            pos_key = jnp.where(owned, pos, _I32_MAX)
            cols = jnp.broadcast_to(
                jnp.arange(nc_loc, dtype=jnp.int32)[None], crude.shape)

            # phase 1: local (crude, pos) top-k via two-key sort; full
            # distances only for the k_loc bootstrap candidates; global
            # merge, then the eq. 2 threshold on the merged candidates
            c_s, p_s, col_s = jax.lax.sort((crude, pos_key, cols),
                                           dimension=1, num_keys=2)
            c_s, p_s, col_s = c_s[:, :k_loc], p_s[:, :k_loc], col_s[:, :k_loc]
            cand_codes = jnp.take_along_axis(codes, col_s[:, :, None],
                                             axis=1)
            if quantized:       # quantized crude + exact slow (§8)
                full_cand = c_s + lut_sum(luts, cand_codes, ~fast)
            else:
                full_cand = lut_sum(luts, cand_codes)      # (nq, k_loc)
            sv, sp, sf = _gather_sorted((c_s, p_s, full_cand), "data")
            sv, sf = sv[:, :topk], sf[:, :topk]
            far = jnp.argmax(jnp.where(jnp.isfinite(sv), sf, -jnp.inf),
                             axis=1)
            t = jnp.take_along_axis(sv, far[:, None], axis=1)[:, 0]
            thr = t + sigma
            passed = crude < thr[:, None]

            if cap is None:
                slow = lut_sum(luts, codes, ~fast)
                ranked = jnp.where(passed, crude + slow, jnp.inf)
                r_s, k_s, i_s = jax.lax.sort((ranked, pos_key, safe),
                                             dimension=1, num_keys=2)
                mv, _, mi = _gather_sorted(
                    (r_s[:, :k_loc], k_s[:, :k_loc], i_s[:, :k_loc]),
                    "data")
                dist, idx = mv[:, :topk], mi[:, :topk]
            else:
                # static compaction: merge the (crude, pos)-best cap
                # survivors globally (full distances computed for the
                # local cap_loc survivors only), then rank the compacted
                # set by full distance (compaction-slot tie-break = the
                # single-device top_k order)
                masked = jnp.where(passed, crude, jnp.inf)
                c2, p2, col2 = jax.lax.sort((masked, pos_key, cols),
                                            dimension=1, num_keys=2)
                c2, p2, col2 = (c2[:, :cap_loc], p2[:, :cap_loc],
                                col2[:, :cap_loc])
                surv_codes = jnp.take_along_axis(codes, col2[:, :, None],
                                                 axis=1)
                f2 = lut_sum(luts, surv_codes)             # (nq, cap_loc)
                i2 = jnp.take_along_axis(safe, col2, axis=1)
                gv, _, gf, gi = _gather_sorted((c2, p2, f2, i2), "data")
                gv, gf, gi = gv[:, :cap], gf[:, :cap], gi[:, :cap]
                ranked = jnp.where(jnp.isfinite(gv), gf, jnp.inf)
                neg, cpos = jax.lax.top_k(-ranked, topk)
                dist = -neg
                idx = jnp.take_along_axis(gi, cpos, axis=1)

            n_cand = jax.lax.psum(
                jnp.sum(valid.astype(jnp.float32), axis=1), "data")
            n_pass = jax.lax.psum(
                jnp.sum(passed.astype(jnp.float32), axis=1), "data")
            return idx, dist, n_cand, n_pass

        specs = ((P(), P("data"), P("data"))
                 + ((P(),) if has_filter else ()))
        fn = jax.jit(shard_map_compat(
            body, self.mesh, in_specs=specs,
            out_specs=(P(), P(), P(), P())))
        self._fns[key] = fn
        return fn

    def search(self, queries, topk: Optional[int] = None, *,
               filter=None) -> SearchResult:
        """queries (nq, d) f32 -> SearchResult with the generalized IVF
        ops accounting; ids and counts bitwise-identical to the
        single-device engine.  ``filter``: optional (n,) boolean row
        predicate (replicated — list-sharded ids are global)."""
        topk = self.topk if topk is None else topk
        if filter is not None:
            pred = _put(self.mesh, as_filter(filter, self.n), P())
            ids, dist, n_cand, n_pass = self._fn(topk, True)(
                queries, self.lists, self.list_codes, pred)
            ids = mask_filtered_ids(ids, dist)
        else:
            ids, dist, n_cand, n_pass = self._fn(topk)(
                queries, self.lists, self.list_codes)
        K = self.C.shape[0]
        kf = jnp.sum(self.structure.fast_mask.astype(jnp.float32))
        return ivf_mod.ivf_ops_result(ids, dist, n_cand, n_pass, n=self.n,
                                      n_lists=self.n_lists, K=K, kf=kf)

    add = _sharded_add

    def shard(self, mesh):
        raise ValueError("index is already sharded")
