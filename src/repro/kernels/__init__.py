"""Pallas TPU kernels for the compute hot-spots, each validated against a
pure-jnp oracle (ref.py) via interpret=True on CPU:

  adc.py              ADC LUT sum (one-hot matmul formulation, MXU)
  two_step.py         fused crude ADC + eq. 2 margin test (ICQ phase 1)
  batched_search.py   batched fused two-step engine: (query-tile x
                      point-tile) grid, LUT tiles pinned in VMEM, codes
                      streamed once per query tile, in-kernel top-k merge
  kmeans.py           nearest-centroid assignment (codebook training/encode)
  flash_attention.py  blockwise online-softmax causal attention

ops.py — jit'd public wrappers (auto interpret off-TPU); ref.py — oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
