"""Pallas TPU kernel: ADC LUT sum (asymmetric distance computation).

Given per-query LUTs T (K, m) and database codes (n, K), computes
dist_i = sum_k T[k, codes[i, k]] for a tile of points at a time.

TPU adaptation (DESIGN.md §3): the per-element table *gather* of the GPU
formulation maps poorly onto the VPU lanes; instead each tile does a
one-hot(codes) x LUT **matmul** on the MXU — onehot (blk_n, K*m) times
flattened LUT (K*m,) — which is dense, layout-friendly, and at m=256,
K<=16 still arithmetically cheap (2*K*m = 8K flops/point at 197 TFLOP/s
beats an HBM-bound gather).  The LUT (K*m*4B <= 16 KiB) is pinned in
VMEM across the whole grid; code tiles stream HBM->VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def flat_onehot(codes, K: int, m: int, dtype):
    """(blk_n, K) int codes -> (blk_n, K*m) one-hot over the flattened
    LUT, with exactly K ones per row.

    Built from a *single* iota compare against the flattened codes: column
    j of the output matches iff codes[i, j // m] == j % m.  Peak
    intermediate is O(blk_n * K * m) — the size of the result — instead of
    the O(blk_n * K * K*m) boolean the K-way broadcast-then-sum
    formulation materializes.
    """
    blk_n = codes.shape[0]
    flat = codes + (jnp.arange(K, dtype=jnp.int32) * m)[None, :]   # (blk,K)
    flat_rep = jnp.broadcast_to(flat[:, :, None],
                                (blk_n, K, m)).reshape(blk_n, K * m)
    iota = jax.lax.broadcasted_iota(jnp.int32, (blk_n, K * m), 1)
    return (flat_rep == iota).astype(dtype)


def _adc_kernel(codes_ref, lut_ref, out_ref, *, K: int, m: int):
    codes = codes_ref[...]                      # (blk_n, K) int32
    lut = lut_ref[...]                          # (K, m) f32
    onehot = flat_onehot(codes, K, m, lut.dtype)     # (blk_n, K*m)
    out_ref[...] = onehot @ lut.reshape(K * m)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def adc_pallas(codes, lut, *, block_n: int = 512, interpret: bool = True):
    """codes: (n, K) int; lut: (K, m) float32 -> dists (n,) float32."""
    n, K = codes.shape
    m = lut.shape[1]
    if n % block_n != 0:
        block_n = _largest_divisor(n, block_n)
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_adc_kernel, K=K, m=m),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, K), lambda i: (i, 0)),
            pl.BlockSpec((K, m), lambda i: (0, 0)),   # LUT pinned in VMEM
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        interpret=interpret,
    )(codes.astype(jnp.int32), lut.astype(jnp.float32))


def _largest_divisor(n: int, cap: int) -> int:
    for b in range(min(cap, n), 0, -1):
        if n % b == 0:
            return b
    return 1
