"""Pallas TPU kernels: batched fused two-step search (DESIGN.md §3.5)
and the IVF candidate-slab variants (DESIGN.md §7).

The serving-shaped hot path: a (query-tile x point-tile) grid where a
tile of per-query flattened LUTs (blk_q, K*m) is pinned in VMEM for the
whole inner sweep over point tiles, and each codes tile (blk_n, K)
streamed HBM->VMEM is reused by *all* blk_q queries in the tile — vs the
per-query formulation that re-streams the entire codes array once per
query.  Distances come from a one-hot(codes) x LUT^T matmul on the MXU:
(blk_n, K*m) @ (K*m, blk_q) -> a (blk_q, blk_n) distance tile per grid
step.

Two kernels:

  crude_topk   phase 1 — crude (fast-masked) LUT sums for every point,
               plus an in-kernel running top-k of the crude distances
               (the eq. 2 threshold bootstrap candidates), merged across
               point tiles in VMEM.
  refine_topk  phase 2 — fused eq. 2 threshold test (crude < t + sigma),
               slow-codebook LUT sum for survivors, and an in-kernel
               top-k merge of the full distances.  Pruned points never
               enter the ranking.

The running top-k merge sorts the concatenated (running, tile) pair with
a two-key ``lax.sort`` on (distance, global index), which reproduces
``jax.lax.top_k``'s lowest-index-wins tie-breaking *globally* — returned
indices are bit-identical to a monolithic top-k over the full distance
row, including the all-ties +inf tail when fewer than ``topk`` points
survive the margin test.

Both kernels accept arbitrary (non-divisible) n and nq: inputs are
zero-padded up to the tile grid and pad columns are masked to +inf
before the merge (the dense crude matrix is simply sliced).

Codes enter in their *stored* packed dtype (uint8 for m <= 256) and are
widened to int32 per-tile inside the kernel — the HBM->VMEM stream
carries 1 byte/entry, which is the 4x traffic saving the packing is for.

Quantized-LUT mode (DESIGN.md §8): the crude kernels also accept
*int8* LUT tiles (``lut_flat`` dtype int8, plus per-query ``lut_scale``
/ ``lut_offset`` f32 columns).  The one-hot dot then runs int8 x int8
with ``preferred_element_type=int32`` — the MXU's native quantized
form — and the (blk_q, blk_n) int32 tile is rescaled in-VMEM to
true-distance f32 (``scale * acc + offset``) before the masking/top-k
merge, which is therefore unchanged.  An int8 tile is 4x smaller than
f32, doubling-and-more the LUT capacity that can stay VMEM-pinned per
query tile.  The refine kernels are f32-only on purpose: eq. 2's exact
re-ranking (the slow/full pass) must not be quantized.

Fast-scan mode (``code_bits=4``, DESIGN.md §12): with 16-codeword
codebooks two codes pack into one byte, so the codes stream halves
again — every kernel accepts ``code_bits=4`` with nibble-packed codes
((n, ceil(K/2)) uint8) and unpacks them in-VMEM via shift/mask before
the one-hot dot.  The LUT operand covers the *even-padded* K (odd K
gets an all-zero sentinel codebook — ``index.base.pad_luts_even`` /
``fastscan_kernel_operands``), so sentinel nibbles contribute exactly
zero and the dequant affine (offset counts real codebooks only) is
unchanged from the 8-bit int8 path; the 16-entry int8 LUT columns
accumulate through the same ``preferred_element_type=int32`` dot with
one rescale at tile end.  ``fastscan_crude_topk_pallas`` /
``ivf_fastscan_crude_topk_pallas`` are the named crude entry points.

IVF variants (``ivf_crude_topk_pallas`` / ``ivf_refine_topk_pallas``):
same two-phase structure, but the codes operand is the *gathered
candidate slab* (nq, nc, K) — per-query candidates, so the distance
tile is a batched matvec ``(blk_q, blk_n, K*m) x (blk_q, K*m)`` instead
of the shared-codes matmul.  Candidate validity rides in as the global
id slab (pad id -1): invalid and grid-pad columns are masked to +inf
*in the dense crude output* so phase 2 needs no separate mask.  Top-k
indices are slab positions (probe-slot major), mapped back to global db
ids by the caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.adc import flat_onehot
# The tile helpers shared by every fused kernel live in the stage
# module (DESIGN.md §13) — one definition serves batched_search,
# icm_encode, ops, and the stage objects.
from repro.kernels.stages import (check_quantized_args as
                                  _check_quantized_args,
                                  init_topk as _init_topk,
                                  merge_topk as _merge_topk,
                                  pad_to as _pad_to,
                                  resolve_kernel_code_bits as
                                  _resolve_kernel_code_bits,
                                  unpack_nibble_tile as
                                  _unpack_nibble_tile)


def _crude_topk_kernel(codes_ref, lut_ref, *refs,
                       K: int, m: int, topk: int, n: int, blk_n: int,
                       want_crude: bool, quantized: bool,
                       nibble: bool = False):
    ni = pl.program_id(1)
    codes = codes_ref[...].astype(jnp.int32)     # widen packed codes per-tile
    if nibble:
        codes = _unpack_nibble_tile(codes)       # (blk_n, K) fast-scan mode
    lut = lut_ref[...]                  # (blk_q, K*m) f32 | int8, fast-masked
    blk_q = lut.shape[0]
    if quantized:
        scale_ref, offset_ref, *refs = refs
        onehot = flat_onehot(codes, K, m, jnp.int8)   # (blk_n, K*m)
        acc = jax.lax.dot_general(                    # int8 x int8 -> int32
            lut, onehot, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32)
        # rescale to true-distance f32: masked codebooks are zero in the
        # int8 tile, so only the offset (= |K_fast| * bias) corrects them
        crude = scale_ref[...] * acc.astype(jnp.float32) + offset_ref[...]
    else:
        onehot = flat_onehot(codes, K, m, lut.dtype)  # (blk_n, K*m)
        crude = jax.lax.dot_general(                  # (blk_q, blk_n) on MXU
            lut, onehot, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    if want_crude:
        crude_ref, vals_ref, idx_ref = refs
        crude_ref[...] = crude
    else:
        vals_ref, idx_ref = refs

    gidx = ni * blk_n + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_n), 1)
    masked = jnp.where(gidx < n, crude, jnp.inf)      # hide pad columns

    @pl.when(ni == 0)
    def _():
        _init_topk(vals_ref, idx_ref)

    _merge_topk(vals_ref, idx_ref, masked, gidx, topk)


def _refine_topk_kernel(codes_ref, lut_ref, crude_ref, thr_ref,
                        vals_ref, idx_ref,
                        *, K: int, m: int, topk: int, n: int, blk_n: int,
                        nibble: bool = False):
    ni = pl.program_id(1)
    codes = codes_ref[...].astype(jnp.int32)     # widen packed codes per-tile
    if nibble:
        codes = _unpack_nibble_tile(codes)
    lut = lut_ref[...]                           # (blk_q, K*m) f32, slow-masked
    crude = crude_ref[...]                       # (blk_q, blk_n) f32
    thr = thr_ref[...]                           # (blk_q, 1) f32 = t + sigma
    blk_q = lut.shape[0]
    onehot = flat_onehot(codes, K, m, lut.dtype)
    slow = jax.lax.dot_general(
        lut, onehot, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    full = crude + slow                               # eq. 1 refinement

    gidx = ni * blk_n + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_n), 1)
    passed = (crude < thr) & (gidx < n)               # eq. 2 margin test
    ranked = jnp.where(passed, full, jnp.inf)

    @pl.when(ni == 0)
    def _():
        _init_topk(vals_ref, idx_ref)

    _merge_topk(vals_ref, idx_ref, ranked, gidx, topk)


@functools.partial(jax.jit,
                   static_argnames=("topk", "block_q", "block_n", "interpret",
                                    "want_crude", "code_bits"))
def crude_topk_pallas(codes, lut_flat, lut_scale=None, lut_offset=None, *,
                      topk: int, block_q: int = 64, block_n: int = 512,
                      interpret: bool = True, want_crude: bool = True,
                      code_bits: int = 8):
    """Phase 1.  codes (n, K) int (packed dtypes welcome — widened
    per-tile in-kernel), lut_flat (nq, K*m) fast-masked flattened
    tables, f32 *or* int8 (quantized-LUT mode, DESIGN.md §8: int8
    requires ``lut_scale`` (nq,) and ``lut_offset`` (nq,) f32 — the
    per-query dequant affine, offset already multiplied by the summed
    codebook count) -> (crude (nq, n) f32, cand_vals (nq, topk) f32,
    cand_idx (nq, topk) i32).  Crude values are always returned in
    true-distance f32 units, whatever the LUT dtype.

    ``code_bits=4`` is fast-scan mode (DESIGN.md §12): codes arrive
    nibble-packed (n, ceil(K/2)) uint8 and are unpacked in-VMEM via
    shift/mask; ``lut_flat`` must cover the even-padded K (an all-zero
    sentinel codebook for odd K — ``index.base.pad_luts_even`` /
    ``fastscan_kernel_operands``), so the dot and dequant are otherwise
    identical to the 8-bit path and rankings match it bitwise.

    ``want_crude=False`` skips writing the dense (nq, n) crude matrix
    to HBM (one-step ADC only needs the top-k) and returns crude=None.

    Padding: n and nq are padded up to the (block_q, block_n) grid
    (``_pad_to``); pad point columns are masked to +inf before the
    in-kernel merge and all outputs are sliced back to (nq, ...)."""
    quantized = _check_quantized_args(lut_flat, lut_scale, lut_offset)
    n, Kc = codes.shape
    nq, Km = lut_flat.shape
    K, m = _resolve_kernel_code_bits(code_bits, Kc, Km)
    n_pad = pl.cdiv(n, block_n) * block_n
    nq_pad = pl.cdiv(nq, block_q) * block_q
    grid = (nq_pad // block_q, n_pad // block_n)
    topk_shapes = (jax.ShapeDtypeStruct((nq_pad, topk), jnp.float32),
                   jax.ShapeDtypeStruct((nq_pad, topk), jnp.int32))
    topk_specs = (pl.BlockSpec((block_q, topk), lambda qi, ni: (qi, 0)),
                  pl.BlockSpec((block_q, topk), lambda qi, ni: (qi, 0)))
    crude_shape = (jax.ShapeDtypeStruct((nq_pad, n_pad), jnp.float32),)
    crude_spec = (pl.BlockSpec((block_q, block_n), lambda qi, ni: (qi, ni)),)
    in_specs = [
        pl.BlockSpec((block_n, Kc), lambda qi, ni: (ni, 0)),
        pl.BlockSpec((block_q, Km), lambda qi, ni: (qi, 0)),  # pinned
    ]
    operands = [_pad_to(codes, n_pad),
                _pad_to(lut_flat if quantized
                        else lut_flat.astype(jnp.float32), nq_pad)]
    if quantized:
        col = pl.BlockSpec((block_q, 1), lambda qi, ni: (qi, 0))
        in_specs += [col, col]
        operands += [
            _pad_to(jnp.asarray(lut_scale, jnp.float32)[:, None], nq_pad),
            _pad_to(jnp.asarray(lut_offset, jnp.float32)[:, None], nq_pad)]
    outs = pl.pallas_call(
        functools.partial(_crude_topk_kernel, K=K, m=m, topk=topk, n=n,
                          blk_n=block_n, want_crude=want_crude,
                          quantized=quantized, nibble=code_bits == 4),
        out_shape=(crude_shape if want_crude else ()) + topk_shapes,
        grid=grid,
        in_specs=in_specs,
        out_specs=(crude_spec if want_crude else ()) + topk_specs,
        interpret=interpret,
    )(*operands)
    if want_crude:
        crude, vals, idx = outs
        return crude[:nq, :n], vals[:nq], idx[:nq]
    vals, idx = outs
    return None, vals[:nq], idx[:nq]


# ------------------------------------------------------- IVF slab kernels ----

def _slab_distances(codes, lut, K: int, m: int):
    """Per-query candidate-slab distances: codes (blk_q, blk_n, K) int32,
    lut (blk_q, K*m) f32 | int8 -> (blk_q, blk_n) f32 | int32 via a
    batched onehot-matvec (one MXU-shaped dot per query row; int8 LUTs
    dot int8 x int8 into an int32 tile — the caller rescales).

    VMEM sizing: the one-hot intermediate is blk_q * blk_n * K*m at the
    LUT's width — unlike the shared-codes kernels there is one one-hot
    *per query row*.  Tile sizes must keep blk_q * blk_n * K * m * 4B
    well under VMEM (the 4 x 128 defaults give 4 MB at K=8, m=256, f32;
    int8 one-hots are 4x smaller); raising blk_q is the expensive
    axis."""
    blk_q, blk_n, _ = codes.shape
    quantized = lut.dtype == jnp.int8
    onehot = flat_onehot(codes.reshape(blk_q * blk_n, K), K, m,
                         lut.dtype).reshape(blk_q, blk_n, K * m)
    return jax.lax.dot_general(
        onehot, lut, dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32 if quantized else jnp.float32)


def _ivf_crude_kernel(codes_ref, ids_ref, lut_ref, *refs,
                      K: int, m: int, topk: int, nc: int, blk_n: int,
                      quantized: bool, nibble: bool = False):
    ni = pl.program_id(1)
    codes = codes_ref[...].astype(jnp.int32)     # (blk_q, blk_n, K)
    if nibble:
        codes = _unpack_nibble_tile(codes)
    ids = ids_ref[...]                           # (blk_q, blk_n) global ids
    lut = lut_ref[...]                  # (blk_q, K*m) fast-masked f32 | int8
    if quantized:
        scale_ref, offset_ref, crude_ref, vals_ref, idx_ref = refs
        acc = _slab_distances(codes, lut, K, m)          # int32
        crude = (scale_ref[...] * acc.astype(jnp.float32)
                 + offset_ref[...])
    else:
        crude_ref, vals_ref, idx_ref = refs
        crude = _slab_distances(codes, lut, K, m)

    blk_q = lut.shape[0]
    gidx = ni * blk_n + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_n), 1)
    # invalid (-1 pad) and grid-pad columns become +inf in the *dense*
    # output, so the refine phase inherits the mask through crude
    masked = jnp.where((ids >= 0) & (gidx < nc), crude, jnp.inf)
    crude_ref[...] = masked

    @pl.when(ni == 0)
    def _():
        _init_topk(vals_ref, idx_ref)

    _merge_topk(vals_ref, idx_ref, masked, gidx, topk)


def _ivf_refine_kernel(codes_ref, lut_ref, crude_ref, thr_ref, vals_ref,
                       idx_ref, *, K: int, m: int, topk: int, nc: int,
                       blk_n: int, nibble: bool = False):
    ni = pl.program_id(1)
    codes = codes_ref[...].astype(jnp.int32)
    if nibble:
        codes = _unpack_nibble_tile(codes)
    lut = lut_ref[...]                           # (blk_q, K*m) slow-masked
    crude = crude_ref[...]                       # (blk_q, blk_n) inf-masked
    thr = thr_ref[...]                           # (blk_q, 1)
    slow = _slab_distances(codes, lut, K, m)
    full = crude + slow                          # eq. 1 refinement

    blk_q = lut.shape[0]
    gidx = ni * blk_n + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_n), 1)
    passed = crude < thr                         # invalid columns are +inf
    ranked = jnp.where(passed & (gidx < nc), full, jnp.inf)

    @pl.when(ni == 0)
    def _():
        _init_topk(vals_ref, idx_ref)

    _merge_topk(vals_ref, idx_ref, ranked, gidx, topk)


@functools.partial(jax.jit,
                   static_argnames=("topk", "block_q", "block_n", "interpret",
                                    "code_bits"))
def ivf_crude_topk_pallas(cand_codes, cand_ids, lut_flat, lut_scale=None,
                          lut_offset=None, *, topk: int, block_q: int = 4,
                          block_n: int = 128, interpret: bool = True,
                          code_bits: int = 8):
    """IVF phase 1 over the gathered candidate slab.

    cand_codes (nq, nc, K) int (packed dtypes welcome — widened
    per-tile in-kernel), cand_ids (nq, nc) int32 global db ids (-1
    pad), lut_flat (nq, K*m) fast-masked tables, f32 *or* int8
    (quantized-LUT mode: int8 requires ``lut_scale`` / ``lut_offset``
    (nq,) f32, see ``crude_topk_pallas``) -> (crude (nq, nc) f32 with
    invalid columns +inf, cand_vals (nq, topk) f32, cand_pos (nq, topk)
    i32 slab positions).  Crude values are always true-distance f32.

    ``code_bits=4`` is the fast-scan slab variant: cand_codes arrive
    nibble-packed (nq, nc, ceil(K/2)) uint8, unpacked in-VMEM via
    shift/mask against an even-K-padded ``lut_flat`` (see
    ``crude_topk_pallas``).

    Padding: nq and nc are padded up to the (block_q, block_n) grid
    (``_pad_to`` on the query axis; the slab pad columns carry id -1 so
    they mask to +inf like in-slab invalid candidates); outputs are
    sliced back to (nq, nc)/(nq, topk)."""
    quantized = _check_quantized_args(lut_flat, lut_scale, lut_offset)
    nq, nc, Kc = cand_codes.shape
    Km = lut_flat.shape[1]
    K, m = _resolve_kernel_code_bits(code_bits, Kc, Km)
    nc_pad = pl.cdiv(nc, block_n) * block_n
    nq_pad = pl.cdiv(nq, block_q) * block_q
    grid = (nq_pad // block_q, nc_pad // block_n)
    codes_p = jnp.pad(cand_codes, ((0, nq_pad - nq), (0, nc_pad - nc),
                                   (0, 0)))
    ids_p = jnp.pad(cand_ids, ((0, nq_pad - nq), (0, nc_pad - nc)),
                    constant_values=-1)
    in_specs = [
        pl.BlockSpec((block_q, block_n, Kc), lambda qi, ni: (qi, ni, 0)),
        pl.BlockSpec((block_q, block_n), lambda qi, ni: (qi, ni)),
        pl.BlockSpec((block_q, Km), lambda qi, ni: (qi, 0)),   # pinned
    ]
    operands = [codes_p, ids_p,
                _pad_to(lut_flat if quantized
                        else lut_flat.astype(jnp.float32), nq_pad)]
    if quantized:
        col = pl.BlockSpec((block_q, 1), lambda qi, ni: (qi, 0))
        in_specs += [col, col]
        operands += [
            _pad_to(jnp.asarray(lut_scale, jnp.float32)[:, None], nq_pad),
            _pad_to(jnp.asarray(lut_offset, jnp.float32)[:, None], nq_pad)]
    crude, vals, idx = pl.pallas_call(
        functools.partial(_ivf_crude_kernel, K=K, m=m, topk=topk, nc=nc,
                          blk_n=block_n, quantized=quantized,
                          nibble=code_bits == 4),
        out_shape=(jax.ShapeDtypeStruct((nq_pad, nc_pad), jnp.float32),
                   jax.ShapeDtypeStruct((nq_pad, topk), jnp.float32),
                   jax.ShapeDtypeStruct((nq_pad, topk), jnp.int32)),
        grid=grid,
        in_specs=in_specs,
        out_specs=(
            pl.BlockSpec((block_q, block_n), lambda qi, ni: (qi, ni)),
            pl.BlockSpec((block_q, topk), lambda qi, ni: (qi, 0)),
            pl.BlockSpec((block_q, topk), lambda qi, ni: (qi, 0)),
        ),
        interpret=interpret,
    )(*operands)
    return crude[:nq, :nc], vals[:nq], idx[:nq]


@functools.partial(jax.jit,
                   static_argnames=("topk", "block_q", "block_n", "interpret",
                                    "code_bits"))
def ivf_refine_topk_pallas(cand_codes, lut_flat, crude, thresholds, *,
                           topk: int, block_q: int = 4, block_n: int = 128,
                           interpret: bool = True, code_bits: int = 8):
    """IVF phase 2 over the candidate slab.  cand_codes (nq, nc, K) int
    (packed dtypes welcome; nibble-packed (nq, nc, ceil(K/2)) under
    ``code_bits=4`` with an even-K-padded lut_flat), lut_flat (nq, K*m)
    f32 (slow-masked — always f32: the refine pass is eq. 2's exact
    re-ranking and is never quantized), crude (nq, nc) f32 from phase 1
    (invalid columns +inf; a quantized phase 1 already emits dequantized
    f32), thresholds (nq,) f32 = t + sigma -> (dist (nq, topk) f32, pos
    (nq, topk) i32 slab positions).

    Padding: nq/nc padded up to the grid; the crude matrix is embedded
    in a +inf canvas so pad columns can never pass the margin test, and
    outputs are sliced back to (nq, topk)."""
    nq, nc, Kc = cand_codes.shape
    Km = lut_flat.shape[1]
    K, m = _resolve_kernel_code_bits(code_bits, Kc, Km)
    nc_pad = pl.cdiv(nc, block_n) * block_n
    nq_pad = pl.cdiv(nq, block_q) * block_q
    grid = (nq_pad // block_q, nc_pad // block_n)
    codes_p = jnp.pad(cand_codes, ((0, nq_pad - nq), (0, nc_pad - nc),
                                   (0, 0)))
    crude_p = jnp.full((nq_pad, nc_pad), jnp.inf, jnp.float32)
    crude_p = jax.lax.dynamic_update_slice(
        crude_p, crude.astype(jnp.float32), (0, 0))
    thr = _pad_to(jnp.asarray(thresholds, jnp.float32)[:, None], nq_pad)
    vals, idx = pl.pallas_call(
        functools.partial(_ivf_refine_kernel, K=K, m=m, topk=topk, nc=nc,
                          blk_n=block_n, nibble=code_bits == 4),
        out_shape=(jax.ShapeDtypeStruct((nq_pad, topk), jnp.float32),
                   jax.ShapeDtypeStruct((nq_pad, topk), jnp.int32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, block_n, Kc), lambda qi, ni: (qi, ni, 0)),
            pl.BlockSpec((block_q, Km), lambda qi, ni: (qi, 0)),   # pinned
            pl.BlockSpec((block_q, block_n), lambda qi, ni: (qi, ni)),
            pl.BlockSpec((block_q, 1), lambda qi, ni: (qi, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_q, topk), lambda qi, ni: (qi, 0)),
            pl.BlockSpec((block_q, topk), lambda qi, ni: (qi, 0)),
        ),
        interpret=interpret,
    )(codes_p, _pad_to(lut_flat.astype(jnp.float32), nq_pad), crude_p, thr)
    return vals[:nq], idx[:nq]


@functools.partial(jax.jit,
                   static_argnames=("topk", "block_q", "block_n", "interpret",
                                    "code_bits"))
def refine_topk_pallas(codes, lut_flat, crude, thresholds, *, topk: int,
                       block_q: int = 64, block_n: int = 512,
                       interpret: bool = True, code_bits: int = 8):
    """Phase 2.  codes (n, K) int (packed dtypes welcome — widened
    per-tile in-kernel; nibble-packed (n, ceil(K/2)) under
    ``code_bits=4`` with an even-K-padded lut_flat), lut_flat (nq, K*m)
    f32 (slow-masked — always f32: the refine pass is eq. 2's exact
    re-ranking and is never quantized), crude (nq, n) f32 from phase 1
    (a quantized phase 1 already emits dequantized f32), thresholds
    (nq,) f32 = t + sigma -> (dist (nq, topk) f32, idx (nq, topk) i32);
    pruned points rank +inf.

    Padding: n/nq padded up to the grid (``_pad_to``); the crude matrix
    is embedded in a +inf canvas so pad columns can never pass the
    margin test, and outputs are sliced back to (nq, topk)."""
    n, Kc = codes.shape
    nq, Km = lut_flat.shape
    K, m = _resolve_kernel_code_bits(code_bits, Kc, Km)
    n_pad = pl.cdiv(n, block_n) * block_n
    nq_pad = pl.cdiv(nq, block_q) * block_q
    grid = (nq_pad // block_q, n_pad // block_n)
    # pad crude with +inf so pad columns can never pass the margin test
    crude_p = jnp.full((nq_pad, n_pad), jnp.inf, jnp.float32)
    crude_p = jax.lax.dynamic_update_slice(
        crude_p, crude.astype(jnp.float32), (0, 0))
    thr = _pad_to(jnp.asarray(thresholds, jnp.float32)[:, None], nq_pad)
    vals, idx = pl.pallas_call(
        functools.partial(_refine_topk_kernel, K=K, m=m, topk=topk, n=n,
                          blk_n=block_n, nibble=code_bits == 4),
        out_shape=(jax.ShapeDtypeStruct((nq_pad, topk), jnp.float32),
                   jax.ShapeDtypeStruct((nq_pad, topk), jnp.int32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, Kc), lambda qi, ni: (ni, 0)),
            pl.BlockSpec((block_q, Km), lambda qi, ni: (qi, 0)),  # pinned
            pl.BlockSpec((block_q, block_n), lambda qi, ni: (qi, ni)),
            pl.BlockSpec((block_q, 1), lambda qi, ni: (qi, 0)),
        ],
        out_specs=(
            pl.BlockSpec((block_q, topk), lambda qi, ni: (qi, 0)),
            pl.BlockSpec((block_q, topk), lambda qi, ni: (qi, 0)),
        ),
        interpret=interpret,
    )(_pad_to(codes, n_pad),
      _pad_to(lut_flat.astype(jnp.float32), nq_pad), crude_p, thr)
    return vals[:nq], idx[:nq]


def fastscan_crude_topk_pallas(packed_codes, lut_flat, lut_scale=None,
                               lut_offset=None, **opts):
    """The 4-bit fast-scan crude kernel (DESIGN.md §12):
    ``crude_topk_pallas`` over nibble-packed codes ((n, ceil(K/2))
    uint8, in-VMEM shift/mask unpack).  ``lut_flat`` must be the
    even-K-padded operand from ``index.base.fastscan_kernel_operands``
    (int8) or ``pad_luts_even`` (f32)."""
    return crude_topk_pallas(packed_codes, lut_flat, lut_scale,
                             lut_offset, code_bits=4, **opts)


def ivf_fastscan_crude_topk_pallas(packed_cand_codes, cand_ids, lut_flat,
                                   lut_scale=None, lut_offset=None, **opts):
    """The 4-bit fast-scan IVF slab crude kernel:
    ``ivf_crude_topk_pallas`` over a nibble-packed candidate slab
    ((nq, nc, ceil(K/2)) uint8); see ``fastscan_crude_topk_pallas``."""
    return ivf_crude_topk_pallas(packed_cand_codes, cand_ids, lut_flat,
                                 lut_scale, lut_offset, code_bits=4, **opts)
