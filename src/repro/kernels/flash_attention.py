"""Pallas TPU kernel: blockwise online-softmax (flash) causal attention.

Grid (batch*heads, num_q_blocks, num_k_blocks) with the K dimension
innermost; the output block plus the running (m, l) statistics are
*revisited* across the K steps (TPU grids execute sequentially, so
output aliasing doubles as the accumulator — no scratch juggling).
Fully-masked blocks above the diagonal are skipped with ``pl.when``.

Block shapes default to (128, head_dim) — MXU-aligned (128 lanes) with a
VMEM working set of q/k/v/o blocks ~4 * 128 * dh * 4B (<= 256 KiB at
dh=128), far under the ~16 MiB VMEM budget, leaving room for the
compiler's double buffering of the streamed K/V tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                  *, scale: float, causal: bool, blk_q: int, blk_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip blocks entirely above the diagonal
    run = (not causal) or (ki * blk_k <= qi * blk_q + blk_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0]                              # (blk_q, dh)
        k = k_ref[0]                              # (blk_k, dh)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * blk_q + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 0)
            k_pos = ki * blk_k + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[0]                         # (blk_q,)
        l_prev = l_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        m_ref[0] = m_new
        l_ref[0] = l_prev * corr + jnp.sum(p, axis=-1)
        o_ref[0] = (o_ref[0] * corr[:, None]
                    + jax.lax.dot_general(
                        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))


def _finalize(o, l):
    return o / jnp.maximum(l, 1e-30)[..., None]


@functools.partial(jax.jit,
                   static_argnames=("causal", "blk_q", "blk_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, blk_q: int = 128,
                           blk_k: int = 128, interpret: bool = True):
    """q: (bh, sq, dh), k/v: (bh, sk, dh) -> (bh, sq, dh).

    GQA/MHA head folding happens in ops.py; this kernel sees flat bh.
    """
    bh, sq, dh = q.shape
    sk = k.shape[1]
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, sk)
    assert sq % blk_q == 0 and sk % blk_k == 0, (sq, blk_q, sk, blk_k)
    grid = (bh, sq // blk_q, sk // blk_k)
    scale = dh ** -0.5

    o, m, l = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          blk_q=blk_q, blk_k=blk_k),
        out_shape=(jax.ShapeDtypeStruct((bh, sq, dh), jnp.float32),
                   jax.ShapeDtypeStruct((bh, sq), jnp.float32),
                   jax.ShapeDtypeStruct((bh, sq), jnp.float32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, blk_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_q), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, blk_q), lambda b, i, j: (b, i)),
        ),
        interpret=interpret,
    )(q, k, v)
    return _finalize(o, l).astype(v.dtype)
