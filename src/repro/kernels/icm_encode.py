"""Pallas TPU kernel: point-tiled ICM encoding for additive codebooks
(DESIGN.md §9).

The encoding hot path is the producer-side twin of the batched search
kernels: every database (or newly added) vector must be assigned K
additive codewords by Iterated Conditional Modes.  The seed formulation
materialized the full (K, K, m, m) cross-Gram plus a (K, n, m) query
tensor and swept codebooks with a vmap-of-gathers inner loop — memory
traffic far beyond what the arithmetic needs (kept as the oracle,
``kernels/ref.py::icm_encode_gram``).

This kernel uses the *residual* formulation instead: carrying the
current reconstruction ``recon = sum_k c_{k, b_k}`` per point makes the
codebook-k sweep step

    r      = recon - c_{k, b_k}                   # others-only partial sum
    scores = ||c_{k,j}||^2 - 2 <x - r, c_{k,j}>   # (blk_n, m)
    b_k    = argmin_j scores;  recon = r + c_{k, b_k}

— mathematically identical to the Gram-gather objective (the
interaction term <r, c_{k,j}> *is* the summed Gram row), but one
(blk_n, d) x (d, m) MXU matmul per codebook instead of K gathered
(blk_n, m) Gram rows, with no (K, K, m, m) or (K, n, m) materialization
at all.  Codeword gathers are one-hot matmuls (bit-exact vs a gather:
one 1.0 and zeros), the same trick as ``kernels/adc.py``.

Tiling: grid = (n / blk_n,) over point tiles; the codebooks C (K, m, d)
and their squared norms (K, m) are VMEM-pinned for the whole sweep
(K*m*d*4B — 128 KB at the seed config, orders below the Gram's 16 MB),
and each point tile runs all ``iters`` sweeps in-register before the
codes tile is written back once.  Warm start (PQ-style independent
assignment unless the caller passes codes) is computed outside and
streamed in with the x tile.

The batched-jnp fallback (``core/encode.py::icm_encode`` backend
dispatch) runs the identical residual recurrence in the identical
order, so jnp and pallas produce the same codes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.stages import pad_to


def _onehot(idx, m: int, dtype):
    """(blk_n,) int32 -> (blk_n, m) one-hot; matmul-gather helper."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, m), 1)
    return (idx[:, None] == iota).astype(dtype)


def _icm_kernel(x_ref, codes0_ref, c_ref, sq_ref, out_ref, *,
                K: int, m: int, iters: int):
    x = x_ref[...]                               # (blk_n, d) f32
    codes = codes0_ref[...].astype(jnp.int32)    # (blk_n, K) warm start
    C = c_ref[...]                               # (K, m, d) VMEM-pinned
    sq = sq_ref[...]                             # (K, m)

    recon = jnp.zeros_like(x)
    for k in range(K):                           # static K: unrolled
        recon = recon + _onehot(codes[:, k], m, x.dtype) @ C[k]

    def sweep(_, carry):
        codes, recon = carry
        for k in range(K):
            r = recon - _onehot(codes[:, k], m, x.dtype) @ C[k]
            scores = sq[k][None, :] - 2.0 * (x - r) @ C[k].T
            new = jnp.argmin(scores, axis=-1).astype(jnp.int32)
            codes = codes.at[:, k].set(new)
            recon = r + _onehot(new, m, x.dtype) @ C[k]
        return codes, recon

    codes, _ = jax.lax.fori_loop(0, iters, sweep, (codes, recon))
    out_ref[...] = codes


@functools.partial(jax.jit,
                   static_argnames=("iters", "block_n", "interpret"))
def icm_encode_pallas(x, init_codes, C, *, iters: int = 3,
                      block_n: int = 1024, interpret: bool = True):
    """Point-tiled ICM encode.  x (n, d) f32, init_codes (n, K) int
    (the warm start — PQ assignment or previous codes), C (K, m, d) f32
    -> codes (n, K) int32.

    Padding: n is zero-padded up to the (block_n,) grid; pad rows carry
    x = 0 / codes = 0 through the sweeps and are sliced off before
    returning (a zero point just argmins real scores — never NaN)."""
    from repro.core import codebooks as cb

    n, d = x.shape
    K, m, _ = C.shape
    n_pad = pl.cdiv(n, block_n) * block_n
    xp = pad_to(x.astype(jnp.float32), n_pad)
    cp = pad_to(init_codes.astype(jnp.int32), n_pad)
    sq = cb.codeword_sq_norms(C).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_icm_kernel, K=K, m=m, iters=iters),
        out_shape=jax.ShapeDtypeStruct((n_pad, K), jnp.int32),
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, K), lambda i: (i, 0)),
            pl.BlockSpec((K, m, d), lambda i: (0, 0, 0)),   # pinned
            pl.BlockSpec((K, m), lambda i: (0, 0)),          # pinned
        ],
        out_specs=pl.BlockSpec((block_n, K), lambda i: (i, 0)),
        interpret=interpret,
    )(xp, cp, C.astype(jnp.float32), sq)
    return out[:n]
