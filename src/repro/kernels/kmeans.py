"""Pallas TPU kernel: k-means assignment (nearest centroid).

scores = -2 X C^T + ||c||^2 on the MXU, argmin over centroids on the
VPU.  The centroid block (m, d) and its squared norms are pinned in VMEM
across the grid (m <= 256, d <= 1024 -> <= 1 MiB); point tiles stream.
This is the hot loop of codebook training (Lloyd iterations over the
full dataset) and of PQ/ICM encoding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.adc import _largest_divisor


def _kmeans_kernel(x_ref, cent_ref, csq_ref, ids_ref, dist_ref):
    x = x_ref[...]                               # (blk_n, d)
    cent = cent_ref[...]                         # (m, d)
    csq = csq_ref[...]                           # (1, m)
    scores = csq - 2.0 * jax.lax.dot_general(
        x, cent, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # (blk_n, m)
    ids_ref[...] = jnp.argmin(scores, axis=-1).astype(jnp.int32)
    xsq = jnp.sum(jnp.square(x.astype(jnp.float32)), axis=-1)
    dist_ref[...] = jnp.min(scores, axis=-1) + xsq


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_pallas(x, cent, *, block_n: int = 1024,
                         interpret: bool = True):
    """x (n,d), cent (m,d) -> (ids (n,) int32, sq-dist (n,) f32)."""
    n, d = x.shape
    m = cent.shape[0]
    if n % block_n != 0:
        block_n = _largest_divisor(n, block_n)
    csq = jnp.sum(jnp.square(cent.astype(jnp.float32)), axis=-1).reshape(1, m)
    grid = (n // block_n,)
    return pl.pallas_call(
        _kmeans_kernel,
        out_shape=(jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.float32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=(pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((block_n,), lambda i: (i,))),
        interpret=interpret,
    )(x, cent, csq)
