"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; TPU
v5e is the compile target) and False on real TPU backends.  The GQA
head-folding for flash attention lives here so the kernel stays MHA.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.adc import adc_pallas
from repro.kernels.batched_search import (crude_topk_pallas,
                                          fastscan_crude_topk_pallas,
                                          ivf_crude_topk_pallas,
                                          ivf_fastscan_crude_topk_pallas,
                                          ivf_refine_topk_pallas,
                                          refine_topk_pallas)
from repro.kernels.icm_encode import icm_encode_pallas
from repro.kernels.two_step import two_step_pallas
from repro.kernels.kmeans import kmeans_assign_pallas
from repro.kernels.flash_attention import flash_attention_pallas
# Shared tile helpers (DESIGN.md §13) re-exported at the ops surface so
# kernel callers get one canonical definition of the padding/merge
# contract instead of re-implementing it per wrapper.
from repro.kernels.stages import (check_quantized_args, init_topk,  # noqa: F401
                                  merge_topk, pad_to,
                                  resolve_kernel_code_bits,
                                  unpack_nibble_tile)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ----------------------------------------------------------- fault hook ----
# The resilience layer's injection point (repro.resilience.faults): every
# public op calls the hook with its stage name before dispatching to the
# kernel, so a seeded FaultInjector can deterministically fail "Pallas"
# stages and drive the engine's jnp failover.  None (the default) is
# free; note that under an outer jit the hook fires at trace time only —
# the serving engine runs eager whenever an injector is attached.
_FAULT_HOOK = None


def set_fault_hook(hook):
    """Install ``hook(stage: str)`` (or None to clear).  Returns the
    previous hook so callers can restore it."""
    global _FAULT_HOOK
    prev = _FAULT_HOOK
    _FAULT_HOOK = hook
    return prev


def _check_faults(stage: str) -> None:
    if _FAULT_HOOK is not None:
        _FAULT_HOOK("kernels." + stage)


def adc(codes, lut, *, block_n: int = 512, interpret=None):
    """ADC LUT sum: codes (n,K) int32, lut (K,m) -> dists (n,) f32."""
    _check_faults("adc")
    it = _default_interpret() if interpret is None else interpret
    return adc_pallas(codes, lut, block_n=block_n, interpret=it)


def two_step(codes, lut, fast_mask, threshold, *, block_n: int = 512,
             interpret=None):
    """Fused crude ADC + eq. 2 margin test -> (crude, passed)."""
    _check_faults("two_step")
    it = _default_interpret() if interpret is None else interpret
    return two_step_pallas(codes, lut, fast_mask, threshold,
                           block_n=block_n, interpret=it)


def batched_crude_topk(codes, lut_flat, topk: int, *, block_q: int = 64,
                       block_n: int = 512, interpret=None,
                       want_crude: bool = True, lut_scale=None,
                       lut_offset=None, code_bits: int = 8):
    """Batched phase 1: crude LUT sums for every (query, point) pair plus
    the in-kernel running top-k of crude distances.

    codes (n, K) int (packed ok), lut_flat (nq, K*m) fast-masked
    flattened tables — f32, or int8 with ``lut_scale``/``lut_offset``
    (nq,) f32 (quantized-LUT mode; crude output is dequantized f32) ->
    (crude (nq, n) | None, cand_vals (nq, topk), cand_idx (nq, topk));
    ``want_crude=False`` skips the dense matrix.  ``code_bits=4`` is
    fast-scan mode: nibble-packed codes (n, ceil(K/2)) uint8 against an
    even-K-padded lut_flat (DESIGN.md §12).
    """
    _check_faults("batched_crude_topk")
    it = _default_interpret() if interpret is None else interpret
    return crude_topk_pallas(codes, lut_flat, lut_scale, lut_offset,
                             topk=topk, block_q=block_q,
                             block_n=block_n, interpret=it,
                             want_crude=want_crude, code_bits=code_bits)


def batched_refine_topk(codes, lut_flat, crude, thresholds, topk: int, *,
                        block_q: int = 64, block_n: int = 512,
                        interpret=None, code_bits: int = 8):
    """Batched phase 2: fused eq. 2 test + slow-codebook sum + top-k merge.

    codes (n, K) int, lut_flat (nq, K*m) f32 (slow-masked), crude (nq, n),
    thresholds (nq,) -> (dist (nq, topk), idx (nq, topk)).
    """
    _check_faults("batched_refine_topk")
    it = _default_interpret() if interpret is None else interpret
    return refine_topk_pallas(codes, lut_flat, crude, thresholds, topk=topk,
                              block_q=block_q, block_n=block_n, interpret=it,
                              code_bits=code_bits)


def ivf_crude_topk(cand_codes, cand_ids, lut_flat, topk: int, *,
                   block_q: int = 4, block_n: int = 128, interpret=None,
                   lut_scale=None, lut_offset=None, code_bits: int = 8):
    """IVF phase 1 over the gathered candidate slab: crude LUT sums +
    in-kernel running top-k of crude distances (slab positions).

    cand_codes (nq, nc, K) int (packed ok), cand_ids (nq, nc) int32
    global ids (-1 pad), lut_flat (nq, K*m) fast-masked tables — f32,
    or int8 with ``lut_scale``/``lut_offset`` (nq,) f32 (quantized-LUT
    mode; crude output is dequantized f32) -> (crude (nq, nc) with
    invalid +inf, vals (nq, topk), pos (nq, topk)).
    """
    _check_faults("ivf_crude_topk")
    it = _default_interpret() if interpret is None else interpret
    return ivf_crude_topk_pallas(cand_codes, cand_ids, lut_flat, lut_scale,
                                 lut_offset, topk=topk,
                                 block_q=block_q, block_n=block_n,
                                 interpret=it, code_bits=code_bits)


def ivf_refine_topk(cand_codes, lut_flat, crude, thresholds, topk: int, *,
                    block_q: int = 4, block_n: int = 128, interpret=None,
                    code_bits: int = 8):
    """IVF phase 2: fused eq. 2 test + slow-codebook sum + top-k merge
    over the candidate slab -> (dist (nq, topk), pos (nq, topk))."""
    _check_faults("ivf_refine_topk")
    it = _default_interpret() if interpret is None else interpret
    return ivf_refine_topk_pallas(cand_codes, lut_flat, crude, thresholds,
                                  topk=topk, block_q=block_q,
                                  block_n=block_n, interpret=it,
                                  code_bits=code_bits)


def icm_encode(x, init_codes, C, *, iters: int = 3, block_n: int = 1024,
               interpret=None):
    """Point-tiled ICM encode (DESIGN.md §9): x (n, d), init_codes
    (n, K) warm start, C (K, m, d) -> codes (n, K) int32."""
    _check_faults("icm_encode")
    it = _default_interpret() if interpret is None else interpret
    return icm_encode_pallas(x, init_codes, C, iters=iters,
                             block_n=block_n, interpret=it)


def kmeans_assign(x, cent, *, block_n: int = 1024, interpret=None):
    """Nearest-centroid assignment -> (ids, sq-dists)."""
    _check_faults("kmeans_assign")
    it = _default_interpret() if interpret is None else interpret
    return kmeans_assign_pallas(x, cent, block_n=block_n, interpret=it)


def flash_attention(q, k, v, *, causal: bool = True, blk_q: int = 128,
                    blk_k: int = 128, interpret=None):
    """Causal flash attention with GQA support.

    q: (b, sq, H, dh); k/v: (b, sk, KVH, dh) -> (b, sq, H, dh).
    Query heads are grouped with their KV head and folded into the
    kernel's flat batch*heads axis.
    """
    _check_faults("flash_attention")
    it = _default_interpret() if interpret is None else interpret
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    # (b, s, kvh, g, dh) -> (b*kvh*g, s, dh); kv repeated across g
    qf = q.reshape(b, sq, kvh, g, dh).transpose(0, 2, 3, 1, 4)
    qf = qf.reshape(b * kvh * g, sq, dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(b * kvh, sk, dh), g, axis=0)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(b * kvh, sk, dh), g, axis=0)
    of = flash_attention_pallas(qf, kf, vf, causal=causal, blk_q=blk_q,
                                blk_k=blk_k, interpret=it)
    o = of.reshape(b, kvh, g, sq, dh).transpose(0, 3, 1, 2, 4)
    return o.reshape(b, sq, h, dh)


def fastscan_crude_topk(packed_codes, lut_flat, topk: int, *,
                        block_q: int = 64, block_n: int = 512,
                        interpret=None, want_crude: bool = True,
                        lut_scale=None, lut_offset=None):
    """The 4-bit fast-scan crude pass (DESIGN.md §12): phase 1 over
    nibble-packed codes (n, ceil(K/2)) uint8, unpacked in-VMEM via
    shift/mask; lut_flat must cover the even-padded K
    (``index.base.fastscan_kernel_operands`` / ``pad_luts_even``).
    Same outputs as ``batched_crude_topk``."""
    _check_faults("fastscan_crude_topk")
    it = _default_interpret() if interpret is None else interpret
    return fastscan_crude_topk_pallas(packed_codes, lut_flat, lut_scale,
                                      lut_offset, topk=topk,
                                      block_q=block_q, block_n=block_n,
                                      interpret=it, want_crude=want_crude)


def ivf_fastscan_crude_topk(packed_cand_codes, cand_ids, lut_flat,
                            topk: int, *, block_q: int = 4,
                            block_n: int = 128, interpret=None,
                            lut_scale=None, lut_offset=None):
    """The 4-bit fast-scan IVF slab crude pass: ``ivf_crude_topk`` over
    a nibble-packed candidate slab (nq, nc, ceil(K/2)) uint8 (see
    ``fastscan_crude_topk``)."""
    _check_faults("ivf_fastscan_crude_topk")
    it = _default_interpret() if interpret is None else interpret
    return ivf_fastscan_crude_topk_pallas(packed_cand_codes, cand_ids,
                                          lut_flat, lut_scale, lut_offset,
                                          topk=topk, block_q=block_q,
                                          block_n=block_n, interpret=it)


def pack_nibbles(codes, K: int):
    """Nibble-pack 4-bit codes two-per-byte along the codebook axis
    (the ``code_bits=4`` storage format) — re-export of
    ``core.encode.pack_nibbles`` at the kernel-ops surface."""
    from repro.core.encode import pack_nibbles as _pack
    return _pack(codes, K)


def unpack_nibbles(packed, K: int):
    """Inverse of ``pack_nibbles`` (exact round trip; drops the odd-K
    sentinel column) — re-export of ``core.encode.unpack_nibbles``."""
    from repro.core.encode import unpack_nibbles as _unpack
    return _unpack(packed, K)
