"""Pure-jnp oracles for every Pallas kernel (tests assert allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adc_ref(codes, lut):
    """codes (n,K) int32, lut (K,m) f32 -> (n,) f32."""
    K = lut.shape[0]
    parts = jnp.stack([lut[k][codes[:, k]] for k in range(K)], axis=1)
    return jnp.sum(parts, axis=1).astype(jnp.float32)


def two_step_ref(codes, lut, fast_mask, threshold):
    """-> (crude (n,) f32, passed (n,) int32)."""
    masked = lut * fast_mask[:, None].astype(lut.dtype)
    crude = adc_ref(codes, masked)
    return crude, (crude < threshold).astype(jnp.int32)


def batched_crude_ref(codes, luts, fast_mask=None):
    """codes (n,K) int, luts (nq,K,m) f32 -> crude (nq,n) f32 by per-query
    gather-sum (the pre-batching formulation)."""
    if fast_mask is not None:
        luts = luts * fast_mask[None, :, None].astype(luts.dtype)
    return jnp.stack([adc_ref(codes, luts[i]) for i in range(luts.shape[0])])


def two_step_search_looped(queries, codes, C, structure, topk: int):
    """The pre-batching per-query ``lax.map`` two-step search — kept as
    the numerical oracle for the vectorized engine and as the latency
    baseline in ``benchmarks/run.py search``.  Returns
    core.search.SearchResult."""
    from repro.core import search as srch

    K = C.shape[0]
    codes = codes.astype(jnp.int32)
    fast = structure.fast_mask
    sigma = structure.sigma
    kf = jnp.sum(fast.astype(jnp.float32))

    def one(q):
        lut = srch.build_lut(q, C)                           # (K,m)
        crude = srch.lut_sum(lut, codes, fast)               # (n,)
        neg_c, cand = jax.lax.top_k(-crude, topk)
        full_cand = srch.lut_sum(lut, codes[cand])           # (topk,)
        far = jnp.argmax(full_cand)
        t = crude[cand[far]]
        passed = crude < t + sigma                           # eq. 2
        slow_sum = srch.lut_sum(lut, codes, ~fast)
        ranked = jnp.where(passed, crude + slow_sum, jnp.inf)
        neg, idx = jax.lax.top_k(-ranked, topk)
        return idx, -neg, jnp.mean(passed.astype(jnp.float32))

    idx, dist, pr = jax.lax.map(one, queries)
    pass_rate = jnp.mean(pr)
    avg_ops = kf + pass_rate * (K - kf)
    return srch.SearchResult(idx, dist, avg_ops, pass_rate)


def ivf_two_step_search_looped(queries, codes, C, structure, ivf,
                               topk: int, n_probe: int):
    """The pre-batching per-query ``lax.map`` IVF + two-step (moved here
    from ``core/ivf.py``) — the numerical oracle for the batched IVF
    engine and the latency baseline in ``benchmarks/run.py ivf``.
    Returns the same SearchResult / generalized ops accounting."""
    from repro.core import search as srch
    from repro.index.ivf import ivf_ops_result

    K = C.shape[0]
    fast = structure.fast_mask
    sigma = structure.sigma
    kf = jnp.sum(fast.astype(jnp.float32))
    n_lists = ivf.lists.shape[0]
    n = codes.shape[0]

    def one(q):
        # coarse probe: nearest n_probe centroids
        d2c = jnp.sum(jnp.square(ivf.centroids - q[None]), axis=-1)
        _, probes = jax.lax.top_k(-d2c, n_probe)             # (n_probe,)
        cand_ids = ivf.lists[probes].reshape(-1)             # (n_probe*len,)
        valid = cand_ids >= 0
        safe_ids = jnp.where(valid, cand_ids, 0)
        cand_codes = codes[safe_ids]                         # (nc, K)

        lut = srch.build_lut(q, C)
        crude = srch.lut_sum(lut, cand_codes, fast)
        crude = jnp.where(valid, crude, jnp.inf)
        neg_c, boot = jax.lax.top_k(-crude, topk)
        full_boot = srch.lut_sum(lut, cand_codes[boot])
        far = jnp.argmax(jnp.where(jnp.isfinite(-neg_c), full_boot,
                                   -jnp.inf))
        t = crude[boot[far]]
        passed = crude < t + sigma                           # eq. 2
        slow = srch.lut_sum(lut, cand_codes, ~fast)
        ranked = jnp.where(passed & valid, crude + slow, jnp.inf)
        neg, idx = jax.lax.top_k(-ranked, topk)
        n_cand = jnp.sum(valid.astype(jnp.float32))
        n_pass = jnp.sum((passed & valid).astype(jnp.float32))
        return safe_ids[idx], -neg, n_cand, n_pass

    ids, dist, n_cand, n_pass = jax.lax.map(one, queries)
    return ivf_ops_result(ids, dist, n_cand, n_pass, n=n, n_lists=n_lists,
                          K=K, kf=kf)


def icm_encode_gram(x, C, iters: int = 3, init_codes=None):
    """The seed cross-Gram ICM formulation — kept as the numerical
    oracle for the tiled encoding engine (``core.encode.icm_encode``
    jnp/pallas backends) and as the baseline in ``benchmarks/run.py
    encode``.

    Materializes the full (K, K, m, m) cross-Gram plus the (K, n, m)
    query-codeword inner products and sweeps codebooks with a
    vmap-of-gathers interaction sum; x (n, d), C (K, m, d) ->
    codes (n, K) int32.  Warm-started from the independent (PQ-style)
    assignment unless ``init_codes`` given — the same warm start the
    tiled engine uses.
    """
    from repro.core import codebooks as cb
    from repro.core.encode import encode_pq

    K, m, _ = C.shape
    sq = cb.codeword_sq_norms(C)                             # (K,m)
    xc = jnp.einsum("nd,kmd->knm", x, C)                     # (K,n,m)
    G = cb.cross_gram(C)                                     # (K,K,m,m)
    codes = encode_pq(x, C) if init_codes is None else init_codes

    def sweep(codes, _):
        def step(codes, k):
            # interaction: sum over k'!=k of G[k', k][codes[:,k']]
            # gather rows: G[kp,k] is (m,m); codes[:,kp] selects (n,m)
            def one(kp):
                return G[kp, k][codes[:, kp]]                # (n,m)
            inter = jnp.sum(jax.vmap(one)(jnp.arange(K)), axis=0) - one(k)
            scores = sq[k][None, :] - 2.0 * xc[k] + 2.0 * inter
            new_k = jnp.argmin(scores, axis=-1).astype(jnp.int32)
            return codes.at[:, k].set(new_k), None

        codes, _ = jax.lax.scan(step, codes, jnp.arange(K))
        return codes, None

    codes, _ = jax.lax.scan(sweep, codes, jnp.arange(iters))
    return codes


def kmeans_assign_ref(x, cent):
    """x (n,d), cent (m,d) -> (ids (n,) int32, sq-dist (n,) f32)."""
    x32 = x.astype(jnp.float32)
    c32 = cent.astype(jnp.float32)
    scores = (-2.0 * x32 @ c32.T + jnp.sum(jnp.square(c32), -1)[None, :])
    ids = jnp.argmin(scores, axis=-1).astype(jnp.int32)
    dist = jnp.min(scores, axis=-1) + jnp.sum(jnp.square(x32), -1)
    return ids, dist


def flash_attention_ref(q, k, v, *, causal=True):
    """q (bh,sq,dh), k/v (bh,sk,dh) -> (bh,sq,dh).  Plain softmax."""
    sq, sk = q.shape[1], k.shape[1]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(v.dtype)
