"""Pure-jnp oracles for every Pallas kernel (tests assert allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adc_ref(codes, lut):
    """codes (n,K) int32, lut (K,m) f32 -> (n,) f32."""
    K = lut.shape[0]
    parts = jnp.stack([lut[k][codes[:, k]] for k in range(K)], axis=1)
    return jnp.sum(parts, axis=1).astype(jnp.float32)


def two_step_ref(codes, lut, fast_mask, threshold):
    """-> (crude (n,) f32, passed (n,) int32)."""
    masked = lut * fast_mask[:, None].astype(lut.dtype)
    crude = adc_ref(codes, masked)
    return crude, (crude < threshold).astype(jnp.int32)


def kmeans_assign_ref(x, cent):
    """x (n,d), cent (m,d) -> (ids (n,) int32, sq-dist (n,) f32)."""
    x32 = x.astype(jnp.float32)
    c32 = cent.astype(jnp.float32)
    scores = (-2.0 * x32 @ c32.T + jnp.sum(jnp.square(c32), -1)[None, :])
    ids = jnp.argmin(scores, axis=-1).astype(jnp.int32)
    dist = jnp.min(scores, axis=-1) + jnp.sum(jnp.square(x32), -1)
    return ids, dist


def flash_attention_ref(q, k, v, *, causal=True):
    """q (bh,sq,dh), k/v (bh,sk,dh) -> (bh,sq,dh).  Plain softmax."""
    sq, sk = q.shape[1], k.shape[1]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(v.dtype)
