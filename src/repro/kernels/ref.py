"""Pure-jnp oracles for every Pallas kernel (tests assert allclose)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adc_ref(codes, lut):
    """codes (n,K) int32, lut (K,m) f32 -> (n,) f32."""
    K = lut.shape[0]
    parts = jnp.stack([lut[k][codes[:, k]] for k in range(K)], axis=1)
    return jnp.sum(parts, axis=1).astype(jnp.float32)


def two_step_ref(codes, lut, fast_mask, threshold):
    """-> (crude (n,) f32, passed (n,) int32)."""
    masked = lut * fast_mask[:, None].astype(lut.dtype)
    crude = adc_ref(codes, masked)
    return crude, (crude < threshold).astype(jnp.int32)


def batched_crude_ref(codes, luts, fast_mask=None):
    """codes (n,K) int, luts (nq,K,m) f32 -> crude (nq,n) f32 by per-query
    gather-sum (the pre-batching formulation)."""
    if fast_mask is not None:
        luts = luts * fast_mask[None, :, None].astype(luts.dtype)
    return jnp.stack([adc_ref(codes, luts[i]) for i in range(luts.shape[0])])


def two_step_search_looped(queries, codes, C, structure, topk: int):
    """The pre-batching per-query ``lax.map`` two-step search — kept as
    the numerical oracle for the vectorized engine and as the latency
    baseline in ``benchmarks/run.py search``.  Returns
    core.search.SearchResult."""
    from repro.core import search as srch

    K = C.shape[0]
    codes = codes.astype(jnp.int32)
    fast = structure.fast_mask
    sigma = structure.sigma
    kf = jnp.sum(fast.astype(jnp.float32))

    def one(q):
        lut = srch.build_lut(q, C)                           # (K,m)
        crude = srch.lut_sum(lut, codes, fast)               # (n,)
        neg_c, cand = jax.lax.top_k(-crude, topk)
        full_cand = srch.lut_sum(lut, codes[cand])           # (topk,)
        far = jnp.argmax(full_cand)
        t = crude[cand[far]]
        passed = crude < t + sigma                           # eq. 2
        slow_sum = srch.lut_sum(lut, codes, ~fast)
        ranked = jnp.where(passed, crude + slow_sum, jnp.inf)
        neg, idx = jax.lax.top_k(-ranked, topk)
        return idx, -neg, jnp.mean(passed.astype(jnp.float32))

    idx, dist, pr = jax.lax.map(one, queries)
    pass_rate = jnp.mean(pr)
    avg_ops = kf + pass_rate * (K - kf)
    return srch.SearchResult(idx, dist, avg_ops, pass_rate)


def kmeans_assign_ref(x, cent):
    """x (n,d), cent (m,d) -> (ids (n,) int32, sq-dist (n,) f32)."""
    x32 = x.astype(jnp.float32)
    c32 = cent.astype(jnp.float32)
    scores = (-2.0 * x32 @ c32.T + jnp.sum(jnp.square(c32), -1)[None, :])
    ids = jnp.argmin(scores, axis=-1).astype(jnp.int32)
    dist = jnp.min(scores, axis=-1) + jnp.sum(jnp.square(x32), -1)
    return ids, dist


def flash_attention_ref(q, k, v, *, causal=True):
    """q (bh,sq,dh), k/v (bh,sk,dh) -> (bh,sq,dh).  Plain softmax."""
    sq, sk = q.shape[1], k.shape[1]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(v.dtype)
