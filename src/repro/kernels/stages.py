"""Composable search-stage primitives with explicit buffer ownership
(DESIGN.md §13).

The two-step engines (flat and IVF, jnp and Pallas) are compositions of
three stages:

    CrudeStage      fast-subset LUT sums (+ the crude top-k on the fused
                    kernels) — the cheap pass of paper eq. 2.
    ThresholdStage  the eq. 2 threshold bootstrap: rank the crude top-k
                    candidates by full distance, take the furthest
                    element's crude value + sigma.
    RefineStage     slow-codebook sums for margin-test survivors and the
                    final full-distance top-k (eq. 1: full = crude +
                    slow).

Every monolithic search path in ``index/flat.py`` / ``index/ivf.py`` is
expressed as a composition of these objects, and the ``PipelinedSearch``
executor (``index/pipelined.py``) runs the same stages split at the
crude/refine boundary so the crude pass of query-tile t+1 overlaps the
refine of tile t.  The stages wrap the *existing* jnp bodies and fused
Pallas kernels unchanged — composition happens at the operand level, so
composed results are bitwise-identical to the historical monolithic
paths (tested in ``tests/test_stages.py``).

Buffer ownership (the contract the pipelined executor relies on):

  stage           borrows                          owns (produces)    donates
  CrudeStage      codes / candidate slab, LUT      crude, cand_vals,  —
                  tiles (flattened kernel           cand_idx (, slow)
                  operands), cand_ids, filter
  ThresholdStage  luts, codes/slab, crude or       thr                —
                  (cand_vals, cand_idx)
  RefineStage     codes/slab, slow LUT tiles,      dist, idx          crude
                  thr, safe ids                                       carry

"Borrows" are operands the stage reads but never invalidates — the
executor may alias them across tiles (database codes, codebooks, the
candidate slab).  "Owns" are buffers the stage allocates and hands to
its consumer.  "Donates" marks the inter-stage carry a consumer may
reuse in place: ``RefineStage`` is the last reader of the dense crude
matrix, so the pipelined executor jits the refine phase with
``donate_argnums`` on the carry and XLA recycles the (tile, n) buffer
for the next tile instead of allocating a fresh one.

This module is also the canonical home of the tile helpers that were
historically copy-pasted per kernel file: ``pad_to``, ``merge_topk`` /
``init_topk``, ``unpack_nibble_tile``, ``check_quantized_args``,
``resolve_kernel_code_bits``, ``widen_codes``.  ``batched_search.py``,
``icm_encode.py``, ``ops.py`` and ``index/base.py`` import them from
here.

Layering note: stage methods lazily import ``repro.kernels.ops`` and
``repro.index.base`` *inside* their bodies — ``batched_search.py``
imports this module's helpers at its top, so a module-level import of
``ops`` here would cycle.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

I32_MAX = jnp.iinfo(jnp.int32).max


# ------------------------------------------------------- shared helpers ----

def pad_to(x, rows: int):
    """The shared padding contract of every tiled kernel wrapper:
    zero-pad the *leading* axis of ``x`` up to ``rows`` (a whole number
    of grid tiles).  Pad rows are real kernel inputs — each kernel
    masks the pad columns/rows it produces to +inf (or carries validity
    ids) so padding never reaches a returned value; callers always
    slice outputs back to true sizes before returning."""
    return x if x.shape[0] == rows else jnp.pad(
        x, [(0, rows - x.shape[0])] + [(0, 0)] * (x.ndim - 1))


def merge_topk(vals_ref, idx_ref, tile_vals, tile_idx, topk: int):
    """Merge a (blk_q, blk_n) tile into the running (blk_q, topk) lists.

    Two-key ascending sort on (distance, global index) == global
    ``top_k(-dist)`` ordering with its lowest-index tie-break.
    """
    merged_v = jnp.concatenate([vals_ref[...], tile_vals], axis=1)
    merged_i = jnp.concatenate([idx_ref[...], tile_idx], axis=1)
    sv, si = jax.lax.sort((merged_v, merged_i), dimension=1, num_keys=2)
    vals_ref[...] = sv[:, :topk]
    idx_ref[...] = si[:, :topk]


def init_topk(vals_ref, idx_ref):
    """Seed the running top-k carry: +inf distances, id_max indices —
    the all-ties tail every real candidate sorts ahead of."""
    vals_ref[...] = jnp.full(vals_ref.shape, jnp.inf, jnp.float32)
    idx_ref[...] = jnp.full(idx_ref.shape, I32_MAX, jnp.int32)


def unpack_nibble_tile(packed):
    """In-VMEM shift/mask unpack of a nibble-packed codes tile
    (DESIGN.md §12): (..., Kp) int32 bytes -> (..., 2*Kp) int32 codes,
    byte kp -> (low nibble, high nibble) = codebooks (2kp, 2kp+1).  The
    sentinel column of odd K stays in place — its LUT column is all
    zero (``index.base.pad_luts_even``), so it adds nothing to any
    dot."""
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)


def resolve_kernel_code_bits(code_bits: int, Kc: int, Km: int):
    """Shared wrapper-side geometry: the stored code columns ``Kc``
    widen to ``K = 2 * Kc`` codebook columns under the nibble format
    (``code_bits=4``); the flattened LUT width ``Km`` must then be an
    even-K multiple (sentinel codebook included)."""
    if code_bits not in (8, 4):
        raise ValueError(f"unknown code_bits {code_bits!r}; "
                         f"expected one of (8, 4)")
    K = 2 * Kc if code_bits == 4 else Kc
    if Km % K:
        raise ValueError(
            f"lut_flat width {Km} is not a multiple of K={K}"
            + (" (pad odd-K tables with index.base.pad_luts_even)"
               if code_bits == 4 else ""))
    return K, Km // K


def check_quantized_args(lut_flat, lut_scale, lut_offset) -> bool:
    """int8 LUTs need the per-query affine columns; f32 forbids them."""
    if lut_flat.dtype == jnp.int8:
        if lut_scale is None or lut_offset is None:
            raise ValueError("int8 lut_flat requires lut_scale and "
                             "lut_offset (see index.base.quantize_lut)")
        return True
    if lut_scale is not None or lut_offset is not None:
        raise ValueError("lut_scale/lut_offset are only valid with an "
                         "int8 lut_flat")
    return False


def widen_codes(codes, K: int, code_bits: int):
    """Stored codes (any trailing-axis-packed gather) -> int32 codebook
    indices: plain widening for byte codes, shift/mask nibble unpack
    (sentinel column dropped) for ``code_bits=4``.  Works on (n, Kc)
    rows and (nq, t, Kc) gathered slabs alike."""
    if code_bits == 4:
        from repro.core.encode import unpack_nibbles
        return unpack_nibbles(codes, K)
    return codes.astype(jnp.int32)


# ---------------------------------------------------- kernel LUT operands ----

def crude_lut_operands(luts, fast=None, *, quantized: bool,
                       code_bits: int = 8):
    """The crude pass's flattened kernel operand triple ``(lut_flat,
    lut_scale, lut_offset)`` from per-query tables ``luts`` ((nq, K, m)
    f32) and the optional fast mask — the branch every Pallas search
    path used to inline.  f32 mode masks the tables and returns
    ``(flat, None, None)``; int8 mode calibrates the per-query affine
    (``quantized_kernel_operands`` / even-K ``fastscan_kernel_operands``
    under the nibble format)."""
    from repro.index.base import (fastscan_kernel_operands, pad_luts_even,
                                  quantized_kernel_operands)
    nibble = code_bits == 4
    if quantized:
        return (fastscan_kernel_operands(luts, fast) if nibble
                else quantized_kernel_operands(luts, fast))
    if fast is None:
        lut = luts
    else:
        fast_f = fast.astype(luts.dtype)[None, :, None]
        lut = luts * fast_f
    lut = pad_luts_even(lut) if nibble else lut
    return lut.reshape(luts.shape[0], -1), None, None


def slow_lut_operand(luts, fast, *, code_bits: int = 8):
    """The refine pass's flattened slow-masked f32 tables (the refine
    pass is never quantized — eq. 2's exact re-ranking)."""
    from repro.index.base import pad_luts_even
    fast_f = fast.astype(luts.dtype)[None, :, None]
    lut_slow = luts * (1.0 - fast_f)
    lut_slow = (pad_luts_even(lut_slow) if code_bits == 4
                else lut_slow).reshape(luts.shape[0], -1)
    return lut_slow


# -------------------------------------------------------- stage protocol ----

class BufferSpec(NamedTuple):
    """A stage's operand contract: ``borrows`` are read-only inputs the
    executor may alias across tiles, ``owns`` are buffers the stage
    allocates for its consumer, ``donates`` names the inter-stage carry
    this stage is the last reader of (safe for ``jax.jit``
    ``donate_argnums`` reuse)."""
    borrows: Tuple[str, ...]
    owns: Tuple[str, ...]
    donates: Tuple[str, ...] = ()


class CrudeOut(NamedTuple):
    """CrudeStage products.  ``crude`` is the dense (nq, n|nc) matrix
    (None when ``want_crude=False``); ``cand_vals``/``cand_idx`` are the
    fused kernels' running crude top-k (None on the dense jnp paths,
    which defer the top-k to the threshold bootstrap); ``slow`` is the
    jnp IVF engine's fused slow accumulator (its unrolled slab sweep
    feeds both sums in one pass — the stage owns both buffers)."""
    crude: Optional[jnp.ndarray]
    cand_vals: Optional[jnp.ndarray] = None
    cand_idx: Optional[jnp.ndarray] = None
    slow: Optional[jnp.ndarray] = None


@dataclasses.dataclass(frozen=True)
class CrudeStage:
    """Phase 1 of eq. 2: fast-subset crude distances.

    Static config only — traced operands go through ``__call__``
    (flat: shared database codes) / ``slab`` (IVF: gathered candidate
    slab).  ``backend="pallas"`` wraps the fused crude kernels
    (``ops.batched_crude_topk`` / ``ops.ivf_crude_topk``), which also
    emit the running crude top-k; ``backend="jnp"`` produces the dense
    crude matrix via the vectorized LUT sums."""
    backend: str = "jnp"                # "jnp" | "pallas"
    topk: int = 50
    block_q: int = 64
    block_n: int = 512
    interpret: Optional[bool] = None
    quantized: bool = False
    code_bits: int = 8
    want_crude: bool = True

    buffers = BufferSpec(
        borrows=("codes | cand_codes", "luts", "cand_ids", "filter pred"),
        owns=("crude", "cand_vals", "cand_idx", "slow (ivf jnp)"))

    def __call__(self, codes, luts, fast=None, *, pred=None) -> CrudeOut:
        """Flat crude pass.  codes (n, K) packed (nibble rows under
        ``code_bits=4``), luts (nq, K, m) f32, fast optional (K,) bool
        (None = full-table one-step ADC), pred optional (n,) bool
        filter (jnp only — excluded rows score +inf)."""
        nibble = self.code_bits == 4
        if self.backend == "pallas":
            from repro.kernels import ops
            lut_flat, scale, offset = crude_lut_operands(
                luts, fast, quantized=self.quantized,
                code_bits=self.code_bits)
            crude, vals, idx = ops.batched_crude_topk(
                codes, lut_flat, self.topk, block_q=self.block_q,
                block_n=self.block_n, interpret=self.interpret,
                want_crude=self.want_crude, lut_scale=scale,
                lut_offset=offset, code_bits=self.code_bits)
            return CrudeOut(crude, vals, idx)
        from repro.index.base import (lut_sum, nibble_lut_sum,
                                      quantize_lut)
        K = luts.shape[1]
        ct = quantize_lut(luts, fast) if self.quantized else luts
        crude = (nibble_lut_sum(ct, codes, K, fast) if nibble
                 else lut_sum(ct, codes, fast))
        if pred is not None:
            crude = jnp.where(pred[None, :], crude, jnp.inf)
        return CrudeOut(crude)

    def slab(self, cand_codes, cand_ids, valid, luts, fast, *,
             need_slow: bool = False) -> CrudeOut:
        """IVF crude pass over the gathered candidate slab.  cand_codes
        (nq, nc, Kc) packed, cand_ids (nq, nc) global ids (-1 pad),
        valid (nq, nc) bool (ids >= 0, possibly anded with a filter
        predicate — the jnp engine's exclusion channel).

        jnp: one unrolled sweep over the K codebooks feeds the crude
        (and, with ``need_slow``, the slow) accumulator — the stage
        owns both buffers; splitting the sweep would double the slab
        gathers.  pallas: the fused slab kernel, which inherits
        validity through the +inf-masked dense crude output."""
        if self.backend == "pallas":
            from repro.kernels import ops
            lut_flat, scale, offset = crude_lut_operands(
                luts, fast, quantized=self.quantized,
                code_bits=self.code_bits)
            crude, vals, pos = ops.ivf_crude_topk(
                cand_codes, cand_ids, lut_flat, self.topk,
                block_q=self.block_q, block_n=self.block_n,
                interpret=self.interpret, lut_scale=scale,
                lut_offset=offset, code_bits=self.code_bits)
            return CrudeOut(crude, vals, pos)
        from repro.index.ivf import _ivf_crude_scores
        crude, slow = _ivf_crude_scores(luts, cand_codes, valid, fast,
                                        quantized=self.quantized,
                                        need_slow=need_slow,
                                        code_bits=self.code_bits)
        return CrudeOut(crude, slow=slow)


@dataclasses.dataclass(frozen=True)
class ThresholdStage:
    """The eq. 2 threshold bootstrap: the neighbor list is the crude
    top-k; its furthest element (by full distance) sets ``thr = t +
    sigma``.  Tiny — (nq, topk) work — and always jnp, even between the
    fused kernels.

    ``quantized`` selects the decomposed full-distance form
    (quantized-crude + exact-slow) that keeps jnp and Pallas thresholds
    bitwise-identical under ``lut_dtype="int8"``; the dense f32 jnp
    path ranks candidates by one full-table sum instead (the historical
    formulation — preserved exactly)."""
    topk: int = 50
    quantized: bool = False
    code_bits: int = 8

    buffers = BufferSpec(
        borrows=("luts", "codes | cand_codes",
                 "crude | (cand_vals, cand_idx)"),
        owns=("thr",))

    def from_dense(self, luts, codes, crude, fast, sigma):
        """Bootstrap from the dense crude matrix (jnp flat path):
        exactly the historical ``_eq2_passed`` arithmetic, returning
        the (nq,) threshold instead of the pass mask (``passed = crude
        < thr[:, None]`` — the same expression, evaluated by the
        refine stage)."""
        from repro.index.base import lut_sum
        neg_c, cand = jax.lax.top_k(-crude, self.topk)       # (nq,topk)
        cand_codes = jnp.take(codes, cand, axis=0)           # (nq,topk,K)
        if self.code_bits == 4:
            cand_codes = widen_codes(cand_codes, luts.shape[1],
                                     self.code_bits)
        if not self.quantized:
            full_cand = lut_sum(luts, cand_codes)            # (nq,topk)
        else:
            full_cand = -neg_c + lut_sum(luts, cand_codes, ~fast)
        far = jnp.argmax(full_cand, axis=1)                  # (nq,)
        t = -jnp.take_along_axis(neg_c, far[:, None], axis=1)[:, 0]
        return t + sigma

    def from_candidates(self, luts, codes, cand_vals, cand_idx, fast,
                        sigma):
        """Bootstrap from the fused crude kernel's running top-k (flat
        pallas path): candidate full distances are crude + exact-slow
        on either LUT dtype (the kernel already dequantized
        ``cand_vals`` to true-distance f32)."""
        from repro.index.base import lut_sum
        cand_codes = jnp.take(codes, cand_idx, axis=0)       # (nq,topk,K)
        if self.code_bits == 4:
            cand_codes = widen_codes(cand_codes, luts.shape[1],
                                     self.code_bits)
        full_cand = cand_vals + lut_sum(luts, cand_codes, ~fast)
        far = jnp.argmax(full_cand, axis=1)
        t = jnp.take_along_axis(cand_vals, far[:, None], axis=1)[:, 0]
        return t + sigma

    def from_dense_slab(self, luts, cand_codes, crude, fast, sigma):
        """IVF bootstrap from the dense slab crude (jnp path): the slab
        may hold fewer than topk valid candidates — invalid entries
        rank +inf and are excluded from the far-element argmax."""
        from repro.index.base import lut_sum
        neg_c, cand = jax.lax.top_k(-crude, self.topk)       # (nq, topk)
        cand_top = jnp.take_along_axis(
            cand_codes, cand[:, :, None], axis=1)            # (nq,topk,K)
        cand_top = widen_codes(cand_top, luts.shape[1], self.code_bits)
        if not self.quantized:
            full_cand = lut_sum(luts, cand_top)
        else:
            full_cand = -neg_c + lut_sum(luts, cand_top, ~fast)
        far = jnp.argmax(
            jnp.where(jnp.isfinite(-neg_c), full_cand, -jnp.inf), axis=1)
        t = -jnp.take_along_axis(neg_c, far[:, None], axis=1)[:, 0]
        return t + sigma

    def from_slab_candidates(self, luts, cand_codes, cand_vals, cand_pos,
                             fast, sigma):
        """IVF bootstrap from the fused slab kernel's running top-k
        (pallas path); +inf slots (slabs thinner than topk) are
        excluded from the far-element argmax."""
        from repro.index.base import lut_sum
        ok = jnp.isfinite(cand_vals)
        pos_safe = jnp.where(ok, cand_pos, 0)
        cand_top = jnp.take_along_axis(cand_codes, pos_safe[:, :, None],
                                       axis=1)
        cand_top = widen_codes(cand_top, luts.shape[1], self.code_bits)
        full_cand = cand_vals + lut_sum(luts, cand_top, ~fast)
        far = jnp.argmax(jnp.where(ok, full_cand, -jnp.inf), axis=1)
        t = jnp.take_along_axis(cand_vals, far[:, None], axis=1)[:, 0]
        return t + sigma


@dataclasses.dataclass(frozen=True)
class RefineStage:
    """Phase 2 of eq. 2: slow-codebook sums for margin-test survivors
    and the final full-distance top-k (eq. 1: full = crude + slow).
    The last reader of the dense crude matrix — the pipelined executor
    donates the crude carry into this stage."""
    backend: str = "jnp"
    topk: int = 50
    block_q: int = 64
    block_n: int = 512
    interpret: Optional[bool] = None
    code_bits: int = 8

    buffers = BufferSpec(
        borrows=("codes | cand_codes", "luts (slow tiles)", "thr",
                 "safe ids", "filter pred"),
        owns=("dist", "idx"),
        donates=("crude",))

    def __call__(self, codes, luts, crude, thr, fast, *, pred=None):
        """Flat refine.  Returns (idx, dist, passed) — ``passed`` is
        the (nq, n) margin-test mask (the pass-rate accounting input);
        the pallas path reports it as the equivalent mask recomputed
        from the crude carry (identical: the kernel evaluates the same
        expression in-kernel)."""
        from repro.index.base import (lut_sum, mask_filtered_ids,
                                      nibble_lut_sum)
        if self.backend == "pallas":
            from repro.kernels import ops
            lut_slow = slow_lut_operand(luts, fast,
                                        code_bits=self.code_bits)
            dist, idx = ops.batched_refine_topk(
                codes, lut_slow, crude, thr, self.topk,
                block_q=self.block_q, block_n=self.block_n,
                interpret=self.interpret, code_bits=self.code_bits)
            return idx, dist, crude < thr[:, None]
        K = luts.shape[1]
        slow = (nibble_lut_sum(luts, codes, K, ~fast)
                if self.code_bits == 4 else lut_sum(luts, codes, ~fast))
        passed = crude < thr[:, None]
        ranked = jnp.where(passed, crude + slow, jnp.inf)
        neg, idx = jax.lax.top_k(-ranked, self.topk)
        if pred is not None:
            idx = mask_filtered_ids(idx, -neg)
        return idx, -neg, passed

    def slab(self, cand_codes, luts, crude, thr, fast, safe, *,
             slow=None, pred=None):
        """IVF refine over the candidate slab.  ``safe`` maps slab
        positions back to global db ids; the jnp path consumes the
        ``slow`` accumulator the crude stage fused into its sweep."""
        from repro.index.base import mask_filtered_ids
        if self.backend == "pallas":
            from repro.kernels import ops
            lut_slow = slow_lut_operand(luts, fast,
                                        code_bits=self.code_bits)
            dist, pos = ops.ivf_refine_topk(
                cand_codes, lut_slow, crude, thr, self.topk,
                block_q=self.block_q, block_n=self.block_n,
                interpret=self.interpret, code_bits=self.code_bits)
            # merged positions are always real slab columns (the slab
            # is padded to >= topk); clip only guards take_along_axis
            ids = jnp.take_along_axis(
                safe, jnp.minimum(pos, safe.shape[1] - 1), axis=1)
            return ids, dist, crude < thr[:, None]
        passed = crude < thr[:, None]            # invalid -> inf -> False
        ranked = jnp.where(passed, crude + slow, jnp.inf)
        neg, pos = jax.lax.top_k(-ranked, self.topk)
        ids = jnp.take_along_axis(safe, pos, axis=1)
        if pred is not None:
            ids = mask_filtered_ids(ids, -neg)
        return ids, -neg, passed


def two_step_stages(*, backend: str, topk: int, block_q: int, block_n: int,
                    interpret=None, quantized: bool = False,
                    code_bits: int = 8, want_crude: bool = True):
    """The standard crude→threshold→refine triple for one engine
    configuration — the composition every two-step search path (flat
    and IVF, monolithic and pipelined) is built from."""
    crude = CrudeStage(backend=backend, topk=topk, block_q=block_q,
                       block_n=block_n, interpret=interpret,
                       quantized=quantized, code_bits=code_bits,
                       want_crude=want_crude)
    thr = ThresholdStage(topk=topk, quantized=quantized,
                         code_bits=code_bits)
    refine = RefineStage(backend=backend, topk=topk, block_q=block_q,
                         block_n=block_n, interpret=interpret,
                         code_bits=code_bits)
    return crude, thr, refine
