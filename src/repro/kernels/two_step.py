"""Pallas TPU kernel: fused ICQ phase-1 — crude ADC over the fast
codebooks + the eq. 2 margin test, in one pass over the code tiles.

Outputs both the crude distances and the pass mask so phase 2 (survivor
compaction + full refine) reads a bitmap instead of recomputing.  The
fast subset is selected with a (K,) 0/1 mask folded into the LUT (zeroed
rows for slow codebooks) — branch-free, so the same kernel body serves
any |K_fast| without recompilation.

Threshold (t + sigma) arrives as a (1, 1) scalar tile broadcast to every
grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.adc import _largest_divisor, flat_onehot


def _two_step_kernel(codes_ref, lut_ref, thr_ref, crude_ref, pass_ref,
                     *, K: int, m: int):
    codes = codes_ref[...]                      # (blk_n, K)
    lut = lut_ref[...]                          # (K, m) — pre-masked to fast
    thr = thr_ref[0, 0]
    onehot = flat_onehot(codes, K, m, lut.dtype)     # (blk_n, K*m)
    crude = onehot @ lut.reshape(K * m)
    crude_ref[...] = crude
    pass_ref[...] = (crude < thr).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def two_step_pallas(codes, lut, fast_mask, threshold, *, block_n: int = 512,
                    interpret: bool = True):
    """codes (n,K) int32, lut (K,m) f32, fast_mask (K,) bool,
    threshold scalar -> (crude (n,) f32, passed (n,) int32)."""
    n, K = codes.shape
    m = lut.shape[1]
    if n % block_n != 0:
        block_n = _largest_divisor(n, block_n)
    grid = (n // block_n,)
    masked_lut = lut * fast_mask[:, None].astype(lut.dtype)
    thr = jnp.asarray(threshold, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_two_step_kernel, K=K, m=m),
        out_shape=(jax.ShapeDtypeStruct((n,), jnp.float32),
                   jax.ShapeDtypeStruct((n,), jnp.int32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, K), lambda i: (i, 0)),
            pl.BlockSpec((K, m), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=(pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((block_n,), lambda i: (i,))),
        interpret=interpret,
    )(codes.astype(jnp.int32), masked_lut.astype(jnp.float32), thr)
