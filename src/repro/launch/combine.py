"""Cross-pod gradient-combine programs (§Perf variant 'icq_grad').

Deployment model: each pod runs its own GSPMD train-step program (the
single-pod cells, already green); between steps the pods exchange
gradients over the cross-pod links.  That exchange is lowered here as a
standalone *fully-manual* shard_map program over the multi-pod mesh —
fully manual because XLA's SPMD partitioner CHECK-fails on
partial-manual (manual pod + auto data/model) at 512 devices (see
EXPERIMENTS.md §Perf), and the combine is elementwise so nothing needs
auto partitioning.

Two variants over the same flattened gradient vector (params are
pod-replicated / in-pod FSDP-sharded, so each device owns N/256
elements):

  fp32:  psum over 'pod'                      (baseline wire: 4 B/elem)
  int8:  EF-quantize -> all_gather int8 over 'pod' -> dequant mean
         (wire: ~1 B/elem + 1/256 scales)

The dry-run artifacts record the collective bytes of each — the
compression ratio on the scarce cross-pod links.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import shard_map_compat
from repro.launch.steps import CellPlan
from repro.quant.grad_compress import ef_quantize
from repro.quant.int8 import dequantize_int8


def _combine_int8(g, r):
    q, s, r_new = ef_quantize(g, r)
    qs = jax.lax.all_gather(q, "pod")                 # int8 on the wire
    ss = jax.lax.all_gather(s, "pod")
    mean = jnp.mean(dequantize_int8(qs, ss), axis=0)
    return mean.astype(g.dtype), r_new


def _combine_fp32(g, r):
    return jax.lax.pmean(g, "pod"), r


def plan_combine_cell(cfg, mesh, *, compressed: bool) -> CellPlan:
    """One (n_params,) fp32 gradient vector, sharded over every device
    within a pod and replicated across pods."""
    n = cfg.param_count()
    block = 256                                       # one int8 scale / block
    n_dev_per_pod = mesh.shape["data"] * mesh.shape["model"]
    rows = ((n // block + n_dev_per_pod - 1)
            // n_dev_per_pod) * n_dev_per_pod
    g = jax.ShapeDtypeStruct((rows, block), jnp.float32)
    r = jax.ShapeDtypeStruct((rows, block), jnp.float32)
    spec = P(("data", "model"), None)                 # pod-replicated
    shard = NamedSharding(mesh, spec)
    # outputs ARE pod-replicated (gather+mean / pmean) — the compat shim
    # disables the replication checker uniformly
    fn = shard_map_compat(
        _combine_int8 if compressed else _combine_fp32,
        mesh, (spec, spec), (spec, spec))

    class _Shape:                                     # minimal ShapeSpec-like
        name = "grad_combine"
        kind = "train"
        seq_len = 0
        global_batch = 0

    return CellPlan(cfg=cfg, shape=_Shape(), mesh=mesh, kind="train",
                    n_micro=1, fn=fn, args=(g, r),
                    in_shardings=(shard, shard),
                    out_shardings=(shard, shard), donate=(1,))


def lower_combine(cfg, mesh, *, compressed: bool):
    plan = plan_combine_cell(cfg, mesh, compressed=compressed)
    jitted = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                     out_shardings=plan.out_shardings,
                     donate_argnums=plan.donate)
    with mesh:
        return jitted.lower(*plan.args), plan
