import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on
the production meshes and extract the roofline terms.

For each cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...,
                           donate_argnums=...).lower(*ShapeDtypeStructs)
        compiled = lowered.compile()
        memory_analysis / cost_analysis / HLO collective scan

Success of compile() for the 16x16 (single-pod) and 2x16x16 (multi-pod)
meshes is deliverable (e); the JSON artifacts written to
``experiments/dryrun/`` feed the roofline table (EXPERIMENTS.md §Roofline)
and the perf loop (§Perf).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import SHAPES, get_config, list_archs, shapes_for
from repro.launch.hlo_cost import analyze_hlo, xla_cost_analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_cell, plan_cell

# ----------------------------------------------------- hardware constants --
PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e)
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (DCI noted in DESIGN.md)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one 'f32[16,1024]'-style result (tuples: sum members)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum result-operand sizes of every collective op in the HLO.

    Returns (total_bytes, by_op dict).  The result shape of a collective
    equals (or bounds) its wire payload per device: all-reduce result ==
    contribution, all-gather result == gathered payload received,
    reduce-scatter result == the reduced shard, all-to-all == exchanged.
    """
    by_op = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        by_op[op] = by_op.get(op, 0) + b
    return sum(by_op.values()), by_op


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the cell (6ND train / 2ND per decode token,
    N = active *matmul* params for MoE) + attention score/value flops.

    The input-embedding table is a gather (0 flops), so it is excluded;
    for tied embeddings the table still does the head matmul and counts
    once (param_count already holds it once in that case).
    """
    n_active = cfg.active_param_count()
    if not cfg.tie_embeddings:
        n_active -= cfg.vocab_size * cfg.d_model   # gather-only input embed
    B, S = shape.global_batch, shape.seq_len

    # attention layer count + per-token context length: hybrids attend on
    # a fraction of layers with a bounded window (recurrentgemma: 1/3 of
    # layers, 2048-window), so full-S^2 accounting badly over-counts.
    n_att = 0 if cfg.attn_free else cfg.num_layers
    ctx_full = S
    if cfg.hybrid and cfg.block_pattern:
        frac = cfg.block_pattern.count("local") / len(cfg.block_pattern)
        n_att = cfg.num_layers * frac
        ctx_full = min(S, cfg.local_window or S)

    def att_flops(tokens_per_row, causal_half):
        ctx = ctx_full if not causal_half else ctx_full / 2 \
            if ctx_full == S else ctx_full  # windowed causal ~= window
        return n_att * B * 2 * 2 * tokens_per_row * ctx * cfg.q_dim

    if shape.kind == "train":
        flops = 6.0 * n_active * B * S
        if n_att and cfg.num_heads:
            flops += 3.0 * att_flops(S, causal_half=True)   # fwd + 2x bwd
        return flops
    if shape.kind == "prefill":
        flops = 2.0 * n_active * B * S
        if n_att and cfg.num_heads:
            flops += att_flops(S, causal_half=True)
        return flops
    # decode: one token against an S-long (or window-bounded) cache
    flops = 2.0 * n_active * B
    if n_att and cfg.num_heads:
        flops += att_flops(1, causal_half=False)
    return flops


def exec_flops(cfg, shape) -> float:
    """FLOPs the compiled step actually executes (analytic): MODEL_FLOPS
    plus the remat recompute (one extra forward per layer for train).

    XLA's HloCostAnalysis counts every while-loop *body once* (scan trip
    counts are not folded in), so ``cost_analysis()['flops']`` badly
    undercounts scanned-layers programs; the roofline compute term uses
    this analytic count instead (validated against an unrolled-HLO audit
    in tests/test_dryrun_audit.py).
    """
    mf = model_flops(cfg, shape)
    if shape.kind == "train" and cfg.remat:
        return mf * 8.0 / 6.0       # fwd + recomputed fwd + 2x bwd
    return mf


def analyze(compiled, lowered, cfg, shape, mesh) -> dict:
    n_dev = mesh.devices.size
    cost = xla_cost_analysis(compiled)
    hlo = compiled.as_text()
    # trip-count-aware HLO accounting (launch.hlo_cost) — XLA's builtin
    # counts while bodies once, useless for scanned-layers programs
    acc = analyze_hlo(hlo)
    flops = acc["flops"]
    bytes_accessed = acc["bytes"]
    coll_bytes = acc["collective_bytes"]
    by_op = {k: int(v) for k, v in acc["collectives_by_op"].items()}

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception:
        pass

    mf = model_flops(cfg, shape)
    ef = exec_flops(cfg, shape)                 # analytic cross-check
    compute_term = flops / PEAK_FLOPS
    memory_term = bytes_accessed / HBM_BW
    collective_term = coll_bytes / LINK_BW
    dominant = max(
        (("compute", compute_term), ("memory", memory_term),
         ("collective", collective_term)), key=lambda kv: kv[1])[0]
    return {
        "arch": cfg.name, "shape": shape.name, "kind": shape.kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "devices": int(n_dev),
        "hlo_flops_per_dev": flops,             # trip-count corrected
        "hlo_bytes_per_dev": bytes_accessed,
        "collective_bytes_per_dev": float(coll_bytes),
        "collectives_by_op": by_op,
        "xla_static_flops": float(cost.get("flops", 0.0)),
        "compute_term_s": compute_term,
        "memory_term_s": memory_term,
        "collective_term_s": collective_term,
        "dominant": dominant,
        "model_flops_global": mf,
        "model_flops_per_dev": mf / n_dev,
        "exec_flops_analytic_per_dev": ef / n_dev,
        "useful_flops_ratio": (mf / n_dev) / flops if flops else 0.0,
        "memory": mem,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             icq_grad: bool = False, attn_impl: str = "chunked",
             out_dir: str = "experiments/dryrun", verbose: bool = True,
             variant: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if variant == "icq_kv":
        from repro.launch.steps import plan_icq_kv_cell
        plan = plan_icq_kv_cell(cfg, shape, mesh)
    else:
        plan = plan_cell(cfg, shape, mesh, icq_grad=icq_grad,
                         attn_impl=attn_impl)
    lowered = lower_cell(plan)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    rec = analyze(compiled, lowered, plan.cfg, shape, mesh)
    rec.update(n_micro=plan.n_micro, lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1), icq_grad=icq_grad,
               attn_impl=attn_impl, variant=variant)
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "multi" if multi_pod else "single"
    suffix = f"_{variant}" if variant else ""
    path = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_tag}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        print(f"[ok] {arch:22s} {shape_name:12s} {mesh_tag:6s} "
              f"flops/dev={rec['hlo_flops_per_dev']:.3e} "
              f"bytes/dev={rec['hlo_bytes_per_dev']:.3e} "
              f"coll/dev={rec['collective_bytes_per_dev']:.3e} "
              f"dom={rec['dominant']} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--icq-grad", action="store_true",
                    help="compressed cross-pod grad combine (multi mesh)")
    ap.add_argument("--attn-impl", default="chunked")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        cfg = get_config(arch)
        cells = ([args.shape] if args.shape
                 else list(shapes_for(cfg).keys()))
        for shape_name in cells:
            for mp in meshes:
                try:
                    run_cell(arch, shape_name, mp, icq_grad=args.icq_grad,
                             attn_impl=args.attn_impl, out_dir=args.out,
                             variant=args.variant)
                except Exception as e:
                    failures.append((arch, shape_name, mp, repr(e)))
                    print(f"[FAIL] {arch} {shape_name} "
                          f"{'multi' if mp else 'single'}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: "
                         + "; ".join(f"{a}/{s}/{m}" for a, s, m, _ in failures))
    print("all requested cells lowered + compiled OK")


if __name__ == "__main__":
    main()
