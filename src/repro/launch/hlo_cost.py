"""Trip-count-aware cost analysis of optimized HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts every while-loop body
exactly once, so any scan-over-layers / scan-over-microbatches program
(i.e. every cell in this framework) is undercounted by ~L x.  This
module re-derives the roofline inputs from ``compiled.as_text()``:

  1. split the module into computations and build a module-wide
     op-name -> result-shape table (operands are bare %name refs);
  2. find each ``while`` op's body/condition and extract the trip count
     from the condition's ``compare(iter, constant(N)), direction=LT``;
  3. propagate execution multipliers through the call graph
     (while bodies x trip count, fusions/calls x 1 per caller execution);
  4. FLOPs: 2*M*N*K for every ``dot`` (wherever it appears, incl. inside
     fusion computations), x multiplier;
  5. bytes: operand + result buffer sizes of *top-level* ops in
     executable computations (entry + while bodies + conditional
     branches), x multiplier — fusion-internal ops are VMEM-resident and
     excluded, approximating HBM traffic like HloCostAnalysis does;
  6. collective bytes: result sizes of all-reduce / all-gather /
     reduce-scatter / all-to-all / collective-permute, x multiplier,
     split by op kind.

Validated in tests/test_hlo_cost.py against XLA's own counts on
loop-free programs and against scanned-vs-unrolled equivalence.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple


def xla_cost_analysis(compiled) -> Dict:
    """XLA's builtin ``compiled.cost_analysis()`` across jax versions:
    newer jax returns one flat dict, 0.4.x returns a one-element list
    of dicts (one per partition).  Always returns a dict ({} when XLA
    reports nothing)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "while", "conditional", "call", "after-all",
               "add-dependency"}


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    """(elements, bytes) summed over every dtype[dims] group."""
    elems = 0
    bts = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bts += n * _DTYPE_BYTES[dt]
    return elems, bts


@dataclasses.dataclass
class Op:
    name: str
    rest: str        # everything right of '='
    opcode: str
    result_shape: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op]
    root: Optional[Op]


_RESULT_OPCODE = re.compile(
    r"^(\((?:[^()]|\([^)]*\))*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([a-z0-9\-]+)")


def parse_module(hlo: str):
    """-> (computations dict, name->result_shape table)."""
    comps: Dict[str, Computation] = {}
    shapes: Dict[str, str] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(name=m.group(2), is_entry=bool(m.group(1)),
                                  ops=[], root=None)
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        rm = _RESULT_OPCODE.match(rest)
        if not rm:
            continue
        op = Op(name=name, rest=rest, opcode=rm.group(2),
                result_shape=rm.group(1))
        shapes[name] = op.result_shape
        cur.ops.append(op)
        if line.lstrip().startswith("ROOT"):
            cur.root = op
    return comps, shapes


def _operand_names(op: Op) -> List[str]:
    """Names referenced inside the op's argument list (first paren group
    after the opcode), excluding computation references."""
    idx = op.rest.find(op.opcode)
    tail = op.rest[idx + len(op.opcode):]
    if not tail.startswith("("):
        return []
    depth = 0
    end = 0
    for i, ch in enumerate(tail):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERAND_RE.findall(tail[: end + 1])


def _trip_count(cond: Computation) -> int:
    consts: Dict[str, int] = {}
    for op in cond.ops:
        m = re.search(r"constant\((-?\d+)\)", op.rest)
        if m and ("s32[]" in op.rest or "s64[]" in op.rest
                  or "u32[]" in op.rest):
            consts[op.name] = int(m.group(1))
    root = cond.root or (cond.ops[-1] if cond.ops else None)
    if root is None or "compare" not in root.rest:
        return 1
    if "direction=LT" not in root.rest and "direction=GT" not in root.rest:
        return 1
    for name, val in consts.items():
        if re.search(r"%" + re.escape(name) + r"\b", root.rest):
            return max(val, 1)
    return max(consts.values(), default=1)


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    res_elems, _ = _shape_elems_bytes(op.result_shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    operands = _operand_names(op)
    if not m or not operands:
        return 2.0 * res_elems
    lhs_shape = shapes.get(operands[0], "")
    mm = _SHAPE_RE.search(lhs_shape)
    if not mm:
        return 2.0 * res_elems
    lhs_dims = [int(d) for d in mm.group(2).split(",") if d]
    k = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(lhs_dims):
            k *= lhs_dims[idx]
    # batch dims are part of res_elems already; contracted dims give K
    return 2.0 * res_elems * k


def analyze_hlo(hlo: str) -> Dict[str, float]:
    comps, shapes = parse_module(hlo)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                "collectives_by_op": {}}

    # ---- execution multipliers over the call graph (topological-ish:
    # process callers before callees by repeated relaxation) ----
    mult: Dict[str, float] = {entry.name: 1.0}
    executable = {entry.name}
    order = [entry.name]
    visited = {entry.name}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 1.0)
        for op in comp.ops:
            body = re.search(r"body=%?([\w\.\-]+)", op.rest)
            cond = re.search(r"condition=%?([\w\.\-]+)", op.rest)
            if body and cond and cond.group(1) in comps:
                # XLA annotates scan-derived loops with the exact count
                tc = re.search(r'known_trip_count[^\d]*(\d+)', op.rest)
                trips = (int(tc.group(1)) if tc
                         else _trip_count(comps[cond.group(1)]))
                for tgt in (body.group(1), cond.group(1)):
                    mult[tgt] = mult.get(tgt, 0.0) + m * trips
                    if tgt not in visited:
                        visited.add(tgt)
                        order.append(tgt)
                executable.add(body.group(1))
                continue
            call = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", op.rest)
            if call:
                tgt = call.group(1)
                mult[tgt] = mult.get(tgt, 0.0) + m
                if tgt not in visited:
                    visited.add(tgt)
                    order.append(tgt)
            br = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
            if br:
                for tgt in re.findall(r"%?([\w\.\-]+)", br.group(1)):
                    mult[tgt] = mult.get(tgt, 0.0) + m
                    executable.add(tgt)
                    if tgt not in visited:
                        visited.add(tgt)
                        order.append(tgt)

    flops = 0.0
    bts = 0.0
    coll: Dict[str, float] = {}
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for op in comp.ops:
            code = op.opcode
            if code in ("dot", "convolution"):
                flops += m * _dot_flops(op, shapes)
            if cname in executable and code not in _SKIP_BYTES:
                _, rb = _shape_elems_bytes(op.result_shape)
                if code in ("slice", "dynamic-slice", "gather"):
                    ob = rb                     # reads only the window
                elif code == "dynamic-update-slice":
                    # in-place: writes + reads the update window only
                    upd = _operand_names(op)
                    _, ub = _shape_elems_bytes(
                        shapes.get(upd[1], "") if len(upd) > 1 else "")
                    bts += m * 2 * ub
                    continue
                else:
                    ob = 0
                    for oname in _operand_names(op):
                        _, b1 = _shape_elems_bytes(shapes.get(oname, ""))
                        ob += b1
                bts += m * (rb + ob)
                base = next((c for c in COLLECTIVES if code.startswith(c)),
                            None)
                if base is not None and not code.endswith("-done"):
                    coll[base] = coll.get(base, 0.0) + m * rb
    return {"flops": flops, "bytes": bts,
            "collective_bytes": sum(coll.values()),
            "collectives_by_op": coll}
