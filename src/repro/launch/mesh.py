"""Production meshes.

Single pod: 16x16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — the 'pod'
axis carries cross-pod data parallelism (DCI links), 'data' is in-pod
FSDP/DP, 'model' is TP/EP.

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke runs of the same launch code."""
    return jax.make_mesh((1, 1), ("data", "model"))


def dp_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n
