"""Batched serving driver: prefill a prompt batch, then decode tokens.

Exercises the same prefill/decode_step the dry-run lowers at pod scale,
executing for real on the available devices (CPU smoke sizes).  The
``--icq-kv`` flag switches decode attention to the ICQ two-step
quantized KV cache (repro.quant.kv_cache) for dense-attention archs and
reports the achieved cache-byte reduction.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --prompt-len 32 --decode-steps 8 --batch 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.launch.steps import build_serve_fns


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--icq-kv", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    prefill_fn, decode_fn, model = build_serve_fns(cfg)
    params = model.init(jax.random.PRNGKey(0))

    max_len = args.prompt_len + args.decode_steps
    rng = np.random.default_rng(0)
    b = args.batch
    s_text = args.prompt_len - (cfg.num_vision_tokens
                                if cfg.frontend == "vision_stub" else 0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (b, s_text),
                                    dtype=np.int32)}
    if cfg.frontend == "vision_stub":
        batch["patch_emb"] = rng.standard_normal(
            (b, cfg.num_vision_tokens, cfg.vision_dim)).astype(np.float32)
    if cfg.encdec:
        batch["audio_emb"] = rng.standard_normal(
            (b, cfg.encoder_seq_len, cfg.d_model)).astype(np.float32)

    t0 = time.time()
    logits, caches = jax.jit(
        lambda p, bt: prefill_fn(p, bt, max_len))(params, batch)
    logits.block_until_ready()
    print(f"prefill: {args.prompt_len} tokens x {b} in "
          f"{time.time() - t0:.2f}s; logits {logits.shape}")

    decode_jit = jax.jit(decode_fn, donate_argnums=(2,))
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.decode_steps):
        logits, caches = decode_jit(params, tok, caches)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(caches)
    dt = time.time() - t0
    print(f"decode: {args.decode_steps} steps in {dt:.2f}s "
          f"({1e3 * dt / max(args.decode_steps, 1):.1f} ms/tok)")
    print("generated:", np.concatenate(out_tokens, axis=1)[:, :16])

    if args.icq_kv:
        from repro.quant import (ICQKVConfig, build_icq_kv_cache,
                                 icq_kv_decode_attention)
        from repro.quant.kv_cache import reference_decode_attention
        # standalone ICQ-KV demonstration on this arch's head geometry
        kvh = max(cfg.num_kv_heads, 1)
        dh = max(cfg.head_dim, 16)
        S = max_len
        key = jax.random.PRNGKey(1)
        k = jax.random.normal(key, (b, S, kvh, dh))
        v = jax.random.normal(jax.random.fold_in(key, 1), (b, S, kvh, dh))
        q = jax.random.normal(jax.random.fold_in(key, 2),
                              (b, 1, cfg.num_heads or kvh, dh))
        kvcfg = ICQKVConfig(d_fast=max(dh // 4, 4))
        cache = build_icq_kv_cache(kvcfg, k, v, max_len=S)
        out = icq_kv_decode_attention(q, cache, kvcfg, S - 1,
                                      top_c=max(S // 8, 4))
        ref = reference_decode_attention(q, k, v, S - 1)
        err = float(jnp.abs(out - ref).max())
        raw = S * kvh * dh * 2 * 2                       # bf16 K+V
        icq = (S * kvh * kvcfg.d_fast * 2                # crude reads
               + (S // 8) * kvh * dh * 2 * 1)            # int8 survivors
        print(f"icq-kv: max err {err:.4f}; decode HBM bytes/head "
              f"{raw} -> {icq} ({raw / icq:.1f}x less)")


if __name__ == "__main__":
    main()
