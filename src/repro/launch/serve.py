"""Batched serving driver: prefill a prompt batch, then decode tokens.

Exercises the same prefill/decode_step the dry-run lowers at pod scale,
executing for real on the available devices (CPU smoke sizes).  The
``--icq-kv`` flag switches decode attention to the ICQ two-step
quantized KV cache (repro.quant.kv_cache) for dense-attention archs and
reports the achieved cache-byte reduction.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --prompt-len 32 --decode-steps 8 --batch 2

``--ann`` serves the unified ANN index layer instead (no LM): a
synthetic packed-uint8 index is built and query batches stream through
the front-door api (``repro.api.build_ann_engine``, docs/api.md),
reporting per-query latency, pass rate, and Average Ops.  The run is
driven by an api config tree — ``--config path.json`` loads one, and
the engine flags (``--ann-index``, ``--ann-backend``, ``--ann-lists``,
``--ann-probe``, ``--lut-dtype``, ``--code-bits``, ``--ann-m``) are
dotted overrides on top of it (``--code-bits 4`` serves the
nibble-packed fast-scan layout, DESIGN.md §12 — pair it with
``--ann-m 16`` or a config whose ``train.codebook_size`` <= 16).
``--save-artifacts DIR`` persists the built index
(``repro.api.Artifacts``); ``--load-artifacts DIR`` serves a saved
directory in a fresh process instead of building one.  ``--ann-shards
N`` serves the index sharded over an N-way ``data`` mesh (run under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` on CPU):

    PYTHONPATH=src python -m repro.launch.serve --ann --ann-n 100000 \
        --ann-queries 64 --ann-backend jnp
    PYTHONPATH=src python -m repro.launch.serve --ann \
        --save-artifacts /tmp/ann && \
        PYTHONPATH=src python -m repro.launch.serve \
        --load-artifacts /tmp/ann
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m repro.launch.serve --ann \
        --ann-index ivf --ann-shards 4 --ann-n 20000

``--serve-loop`` serves one or more saved artifact directories through
the async coalescing loop (``repro.serve.ServingLoop``, docs/serving.md)
under a short seeded Poisson workload instead of fixed query batches:
each repeatable ``--tenant NAME=DIR`` loads one Artifacts dir as a
tenant (a bare ``--load-artifacts DIR`` joins the loop as tenant
``default``), duplicate names or paths fail up front with a one-line
error, and ``--batch-window-ms`` / ``--batch-tile`` override every
tenant's coalescing knobs.  Per-tenant p50/p99 latency, QPS, and tile
fill are reported:

    PYTHONPATH=src python -m repro.launch.serve --serve-loop \
        --tenant prod=/tmp/ann_a --tenant canary=/tmp/ann_b \
        --batch-window-ms 2 --batch-tile 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.launch.steps import build_serve_fns


def _serve_mesh(shards: int):
    if shards <= 1:
        return None
    if len(jax.devices()) < shards:
        raise SystemExit(
            f"--ann-shards {shards} needs {shards} devices but only "
            f"{len(jax.devices())} are visible; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={shards}")
    from repro.distributed.sharding import make_mesh_auto
    return make_mesh_auto((shards,), ("data",))


def _serve_batches(engine, nq: int, d: int, batches: int, label: str):
    """Warm + time ``batches`` random query batches through ``engine``."""
    qkey = jax.random.fold_in(jax.random.PRNGKey(0), 2)
    queries = jax.random.normal(qkey, (nq, d))
    res = engine(queries)                      # compile + warm
    jax.block_until_ready(res.indices)
    t0 = time.time()
    for i in range(batches):
        q = jax.random.normal(jax.random.fold_in(qkey, i), (nq, d))
        res = engine(q)
        jax.block_until_ready(res.indices)
    dt = (time.time() - t0) / batches
    print(f"{label}: {dt * 1e6 / nq:.1f} us/query "
          f"(batch {dt * 1e3:.1f} ms), pass_rate={float(res.pass_rate):.3f}, "
          f"avg_ops={float(res.avg_ops):.2f}")
    return queries, res


def serve_ann(cfg, n: int, nq: int, *, batches: int = 3, shards: int = 1,
              n_add: int = 0, save_dir=None):
    """Synthetic ANN serving loop through the front-door api: the config
    tree's ``train`` section fixes the synthetic index geometry, the
    ``index``/``serve`` sections drive construction and the engine
    (``repro.api.build_ann_engine``).

    ``n_add`` > 0 additionally exercises the incremental build surface:
    after the timed batches, ``n_add`` fresh vectors are encoded and
    appended via ``AnnEngine.add`` (ICM engine, no retraining; sharded
    engines re-shard the grown source index) and one more query batch
    is served from the grown index.  ``save_dir`` persists the built
    index (index-only artifacts) for ``--load-artifacts``."""
    from repro.api import Artifacts, build_ann_engine
    from repro.data.synthetic import make_synthetic_index

    d, K, m = cfg.train.d, cfg.train.num_codebooks, cfg.train.codebook_size
    key = jax.random.PRNGKey(0)
    codes, C, structure = make_synthetic_index(key, n, d=d, K=K, m=m,
                                               num_fast=cfg.train.num_fast)
    mesh = _serve_mesh(shards)
    emb_db = None
    if cfg.index.kind == "ivf":
        from repro.core import codebooks as cb
        emb_db = cb.decode(C, codes)          # reconstructed db embeddings
    engine = build_ann_engine(codes, C, structure, topk=cfg.serve.topk,
                              backend=cfg.serve.backend,
                              index=cfg.index.kind, mesh=mesh,
                              emb_db=emb_db, n_lists=cfg.index.n_lists,
                              n_probe=cfg.index.n_probe,
                              query_chunk=cfg.serve.query_chunk,
                              lut_dtype=cfg.serve.lut_dtype,
                              code_bits=cfg.index.code_bits,
                              pipeline=cfg.serve.pipeline,
                              pipeline_tile=cfg.serve.pipeline_tile,
                              key=jax.random.fold_in(key, 1))
    queries, _ = _serve_batches(
        engine, nq, d, batches,
        f"ann: index={cfg.index.kind} n={n} nq={nq} topk={cfg.serve.topk} "
        f"backend={cfg.serve.backend} lut={cfg.serve.lut_dtype} "
        f"bits={cfg.index.code_bits} pipeline={cfg.serve.pipeline} "
        f"shards={shards}")

    if n_add > 0:
        from repro.core import codebooks as cb
        new_codes = jax.random.randint(jax.random.fold_in(key, 3),
                                       (n_add, K), 0, m)
        new_vecs = cb.decode(C, new_codes) + 0.01 * jax.random.normal(
            jax.random.fold_in(key, 4), (n_add, d))
        t0 = time.time()
        engine.add(new_vecs)
        dt_add = time.time() - t0
        res2 = engine(queries)
        jax.block_until_ready(res2.indices)
        print(f"ann-add: +{n_add} vectors in {dt_add * 1e3:.1f} ms "
              f"(encode+append, no retrain) -> n={engine.n}; "
              f"post-add pass_rate={float(res2.pass_rate):.3f}")

    if save_dir:
        path = Artifacts(config=cfg, index=engine.index).save(save_dir)
        print(f"ann: artifacts (config hash {cfg.config_hash()[:12]}) "
              f"-> {path}; reload with --load-artifacts")


def serve_loaded(path: str, nq: int, *, batches: int = 3, shards: int = 1,
                 overrides=None, verify: bool = False):
    """Serve a saved artifact directory end-to-end: load + verify the
    manifest, rebuild the index (``repro.api.load_ann_engine``), and
    stream random query batches through it — the fresh-process half of
    the fit→save→load→search contract (CI runs this against artifacts
    written by ``launch/train.py --save-artifacts`` and by
    ``--ann --save-artifacts``).

    ``verify`` forces the full per-tensor sha256 pass
    (``--verify-artifacts``, docs/robustness.md).  Malformed artifacts
    — missing directory, missing/truncated files, checksum mismatches —
    exit with a one-line actionable error instead of a traceback."""
    from repro.api import ArtifactError, load_ann_engine

    try:
        engine = load_ann_engine(path, mesh=_serve_mesh(shards),
                                 overrides=overrides or None,
                                 verify_checksums=verify or None)
    except (ArtifactError, FileNotFoundError, OSError) as e:
        # the artifact layer's messages already name the file and the
        # expected-vs-found sizes/hashes — surface them, not the stack
        raise SystemExit(f"--load-artifacts {path}: {e}") from e
    d = engine.index.C.shape[-1]
    print(f"loaded artifacts {path}: index n={engine.n} d={d} "
          f"(kind from manifest)")
    _serve_batches(engine, nq, d, batches,
                   f"ann-loaded: n={engine.n} nq={nq} shards={shards}")


def serve_traffic(specs, *, rate_hz: float, duration_s: float,
                  window_ms=None, tile=None, shards: int = 1,
                  overrides=None, seed: int = 0, pool_q: int = 64):
    """Serve tenant artifact dirs through the coalescing loop under a
    seeded Poisson workload (``--serve-loop``; docs/serving.md).

    Spec conflicts — duplicate tenant names, two specs resolving to the
    same Artifacts directory — and artifact errors exit with a one-line
    actionable message instead of a traceback (or a silent double
    load)."""
    from repro.api import ArtifactError
    from repro.serve import (ServeError, ServingLoop, load_tenants,
                             make_workload, run_open_loop, summarize)

    try:
        tenants = load_tenants(specs, mesh=_serve_mesh(shards),
                               overrides=overrides or None)
    except (ServeError, ArtifactError, FileNotFoundError, OSError) as e:
        raise SystemExit(f"--serve-loop: {e}") from e
    rng = np.random.default_rng(seed)
    pools = {name: rng.standard_normal((pool_q, t.d)).astype(np.float32)
             for name, t in sorted(tenants.items())}
    workload = make_workload(pools, rate_hz, duration_s, rng=rng)
    with ServingLoop(tenants, window_ms=window_ms, tile=tile) as loop:
        for name in tenants:
            loop.warm(name)
        t0 = time.time()
        records = run_open_loop(loop, workload)
        wall_s = time.time() - t0
        stats = dict(loop.stats)
    for name in sorted(tenants):
        s = summarize([r for r in records if r["tenant"] == name],
                      wall_s=wall_s)
        if not s["requests"]:
            print(f"serve-loop[{name}]: no arrivals this run")
            continue
        print(f"serve-loop[{name}]: {s['requests']} req, "
              f"p50 {s['p50_ms']:.2f} ms, p99 {s['p99_ms']:.2f} ms, "
              f"{s['qps']:.1f} qps, fill {s['mean_batch_fill']:.2f}, "
              f"queue {s['mean_queue_ms']:.2f} ms")
    agg = summarize(records, wall_s=wall_s)
    print(f"serve-loop: {agg['requests']} req total, "
          f"{stats['batches']} flushes "
          f"(full {stats['flush_full']} / window {stats['flush_window']}), "
          f"p50 {agg['p50_ms']:.2f} ms, p99 {agg['p99_ms']:.2f} ms, "
          f"{agg['qps']:.1f} qps, degraded {agg['degraded_rate']:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--icq-kv", action="store_true")
    ap.add_argument("--ann", action="store_true",
                    help="serve the batched ANN index layer (no LM)")
    ap.add_argument("--config", default=None,
                    help="repro.api ICQConfig JSON driving the --ann run "
                         "(docs/api.md); the --ann-*/--lut-dtype flags "
                         "below override individual fields")
    ap.add_argument("--save-artifacts", default=None, metavar="DIR",
                    help="persist the --ann index (index-only artifacts); "
                         "reload with --load-artifacts DIR")
    ap.add_argument("--load-artifacts", default=None, metavar="DIR",
                    help="serve a saved artifact directory instead of "
                         "building one (repro.api.load_ann_engine); "
                         "engine flags act as overrides")
    ap.add_argument("--verify-artifacts", action="store_true",
                    help="with --load-artifacts: verify every tensor's "
                         "sha256 against the manifest before serving "
                         "(docs/robustness.md)")
    ap.add_argument("--ann-n", type=int, default=100_000)
    ap.add_argument("--ann-queries", type=int, default=64)
    ap.add_argument("--ann-backend", default=None,
                    choices=["auto", "jnp", "pallas"],
                    help="override serve.backend (config default: auto)")
    ap.add_argument("--ann-index", default=None,
                    choices=["flat", "two-step", "ivf"],
                    help="override index.kind (config default: two-step)")
    ap.add_argument("--ann-shards", type=int, default=1,
                    help="shard the index over an N-way data mesh")
    ap.add_argument("--ann-lists", type=int, default=None,
                    help="override index.n_lists (config default: 64)")
    ap.add_argument("--ann-probe", type=int, default=None,
                    help="override index.n_probe (config default: 8)")
    ap.add_argument("--lut-dtype", default=None, choices=["f32", "int8"],
                    help="override serve.lut_dtype (int8 = quantized "
                         "tables, DESIGN.md §8)")
    ap.add_argument("--code-bits", type=int, default=None, choices=[8, 4],
                    help="override index.code_bits (4 = nibble-packed "
                         "fast-scan codes, DESIGN.md §12; needs "
                         "codebook_size <= 16, e.g. --ann-m 16)")
    ap.add_argument("--pipeline", default=None,
                    choices=["off", "tiles", "auto"],
                    help="override serve.pipeline (tiles = overlap the "
                         "crude pass of one query tile with the refine "
                         "of the previous, DESIGN.md §13)")
    ap.add_argument("--pipeline-tile", type=int, default=None,
                    help="override serve.pipeline_tile (queries per "
                         "pipeline tile; default block_q on pallas, "
                         "16 on jnp)")
    ap.add_argument("--ann-m", type=int, default=None,
                    help="override train.codebook_size (the synthetic "
                         "index's codewords per codebook)")
    ap.add_argument("--ann-add", type=int, default=0,
                    help="after serving, grow the index by N vectors via "
                         "AnnEngine.add (incremental encode, DESIGN.md §9)")
    ap.add_argument("--serve-loop", action="store_true",
                    help="serve artifact tenants through the async "
                         "coalescing loop under a seeded Poisson workload "
                         "(repro.serve.ServingLoop, docs/serving.md)")
    ap.add_argument("--tenant", action="append", default=[],
                    metavar="NAME=DIR",
                    help="load an Artifacts dir as a named tenant of the "
                         "--serve-loop (repeatable); duplicate names or "
                         "paths are rejected up front")
    ap.add_argument("--batch-window-ms", type=float, default=None,
                    help="--serve-loop: override every tenant's "
                         "serve.batch_window_ms (max coalescing wait)")
    ap.add_argument("--batch-tile", type=int, default=None,
                    help="--serve-loop: override every tenant's "
                         "serve.batch_tile (rows per dispatched tile)")
    ap.add_argument("--serve-rate", type=float, default=50.0,
                    help="--serve-loop: Poisson arrival rate (req/s)")
    ap.add_argument("--serve-duration", type=float, default=1.0,
                    help="--serve-loop: workload duration (s)")
    ap.add_argument("--serve-seed", type=int, default=0,
                    help="--serve-loop: seed for arrivals + query rows")
    args = ap.parse_args()

    overrides = {k: v for k, v in {
        "serve.backend": args.ann_backend,
        "index.kind": args.ann_index,
        "index.n_lists": args.ann_lists,
        "index.n_probe": args.ann_probe,
        "serve.lut_dtype": args.lut_dtype,
        "index.code_bits": args.code_bits,
        "serve.pipeline": args.pipeline,
        "serve.pipeline_tile": args.pipeline_tile,
        "train.codebook_size": args.ann_m,
    }.items() if v is not None}

    if args.serve_loop:
        specs = list(args.tenant)
        if args.load_artifacts:
            # a bare --load-artifacts joins the loop as tenant
            # "default"; parse_tenant_specs then catches a --tenant
            # pointing at the same directory (or reusing the name)
            # with a one-line error instead of double-loading it
            specs = [f"default={args.load_artifacts}"] + specs
        if not specs:
            ap.error("--serve-loop needs at least one --tenant NAME=DIR "
                     "(or --load-artifacts DIR)")
        serve_traffic(specs, rate_hz=args.serve_rate,
                      duration_s=args.serve_duration,
                      window_ms=args.batch_window_ms,
                      tile=args.batch_tile, shards=args.ann_shards,
                      overrides=overrides, seed=args.serve_seed)
        return
    for flag, val in (("--tenant", args.tenant or None),
                      ("--batch-window-ms", args.batch_window_ms),
                      ("--batch-tile", args.batch_tile)):
        if val is not None:
            ap.error(f"{flag} requires --serve-loop")
    if args.load_artifacts:
        # flags that only make sense when *building* an index would be
        # silently ignored here — reject them instead
        for flag, val in (("--config", args.config),
                          ("--save-artifacts", args.save_artifacts),
                          ("--ann-add", args.ann_add or None),
                          ("--ann-index", args.ann_index)):
            if val is not None:
                ap.error(f"{flag} cannot be combined with "
                         "--load-artifacts (the artifacts embed their "
                         "own config and index layout); remaining "
                         "engine flags act as overrides")
        serve_loaded(args.load_artifacts, args.ann_queries,
                     shards=args.ann_shards, overrides=overrides,
                     verify=args.verify_artifacts)
        return
    if args.verify_artifacts:
        ap.error("--verify-artifacts only applies to --load-artifacts")
    if args.ann:
        from repro.api import ICQConfig

        cfg = (ICQConfig.load(args.config) if args.config
               else ICQConfig())
        serve_ann(cfg.with_overrides(overrides), args.ann_n,
                  args.ann_queries, shards=args.ann_shards,
                  n_add=args.ann_add, save_dir=args.save_artifacts)
        return
    if args.arch is None:
        ap.error("--arch is required unless --ann or --load-artifacts "
                 "is given")

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    prefill_fn, decode_fn, model = build_serve_fns(cfg)
    params = model.init(jax.random.PRNGKey(0))

    max_len = args.prompt_len + args.decode_steps
    rng = np.random.default_rng(0)
    b = args.batch
    s_text = args.prompt_len - (cfg.num_vision_tokens
                                if cfg.frontend == "vision_stub" else 0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (b, s_text),
                                    dtype=np.int32)}
    if cfg.frontend == "vision_stub":
        batch["patch_emb"] = rng.standard_normal(
            (b, cfg.num_vision_tokens, cfg.vision_dim)).astype(np.float32)
    if cfg.encdec:
        batch["audio_emb"] = rng.standard_normal(
            (b, cfg.encoder_seq_len, cfg.d_model)).astype(np.float32)

    t0 = time.time()
    logits, caches = jax.jit(
        lambda p, bt: prefill_fn(p, bt, max_len))(params, batch)
    logits.block_until_ready()
    print(f"prefill: {args.prompt_len} tokens x {b} in "
          f"{time.time() - t0:.2f}s; logits {logits.shape}")

    decode_jit = jax.jit(decode_fn, donate_argnums=(2,))
    tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.decode_steps):
        logits, caches = decode_jit(params, tok, caches)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(caches)
    dt = time.time() - t0
    print(f"decode: {args.decode_steps} steps in {dt:.2f}s "
          f"({1e3 * dt / max(args.decode_steps, 1):.1f} ms/tok)")
    print("generated:", np.concatenate(out_tokens, axis=1)[:, :16])

    if args.icq_kv:
        from repro.quant import (ICQKVConfig, build_icq_kv_cache,
                                 icq_kv_decode_attention)
        from repro.quant.kv_cache import reference_decode_attention
        # standalone ICQ-KV demonstration on this arch's head geometry
        kvh = max(cfg.num_kv_heads, 1)
        dh = max(cfg.head_dim, 16)
        S = max_len
        key = jax.random.PRNGKey(1)
        k = jax.random.normal(key, (b, S, kvh, dh))
        v = jax.random.normal(jax.random.fold_in(key, 1), (b, S, kvh, dh))
        q = jax.random.normal(jax.random.fold_in(key, 2),
                              (b, 1, cfg.num_heads or kvh, dh))
        kvcfg = ICQKVConfig(d_fast=max(dh // 4, 4))
        cache = build_icq_kv_cache(kvcfg, k, v, max_len=S)
        out = icq_kv_decode_attention(q, cache, kvcfg, S - 1,
                                      top_c=max(S // 8, 4))
        ref = reference_decode_attention(q, k, v, S - 1)
        err = float(jnp.abs(out - ref).max())
        raw = S * kvh * dh * 2 * 2                       # bf16 K+V
        icq = (S * kvh * kvcfg.d_fast * 2                # crude reads
               + (S // 8) * kvh * dh * 2 * 1)            # int8 survivors
        print(f"icq-kv: max err {err:.4f}; decode HBM bytes/head "
              f"{raw} -> {icq} ({raw / icq:.1f}x less)")


if __name__ == "__main__":
    main()
