"""Step builders + input specs for every (arch x shape) cell.

``build_train_step``  — gradient accumulation over microbatches
    (lax.scan), remat inside the model's layer scan, optimizer update,
    optional ICQ-grad compressed cross-pod combine.
``build_serve_fns``   — prefill (full forward + cache build) and
    decode_step (one token against a seq_len cache).
``input_specs``       — ShapeDtypeStruct stand-ins for every model input
    of a cell: weak-type-correct, shardable, no device allocation.

Microbatching: the pipeline delivers batches already shaped
(n_micro, micro_batch, seq); the microbatch dim is scanned, the batch
dim is sharded over (pod, data).  n_micro is derived from the arch's
``microbatch_size`` (per-DP-shard rows) so every cell fits HBM:
    n_micro = global_batch / (dp_size * microbatch_size).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shrules
from repro.models import build_model
from repro.quant.grad_compress import (compress_state_init,
                                       compressed_cross_pod_mean)
from repro.quant.kv_cache import ICQKVConfig
from repro.train.optimizer import make_optimizer


# ----------------------------------------------------------- geometry ----

def num_microbatches(cfg, shape, dp: int) -> int:
    per_shard = max(shape.global_batch // max(dp, 1), 1)
    n_micro = max(per_shard // max(cfg.microbatch_size, 1), 1)
    while shape.global_batch % n_micro:
        n_micro -= 1
    return max(n_micro, 1)


def batch_struct(cfg, shape, n_micro: int, *, train: bool) -> Dict[str, Any]:
    """ShapeDtypeStructs of one input batch (microbatch-major for train)."""
    B = shape.global_batch
    S = shape.seq_len
    s_text = S - (cfg.num_vision_tokens if cfg.frontend == "vision_stub" else 0)

    def shp(*dims):
        return (n_micro,) + dims if train else dims

    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct(shp(B // n_micro if train else B, s_text),
                                       jnp.int32),
    }
    if train:
        specs["labels"] = specs["tokens"]
    if cfg.frontend == "vision_stub":
        specs["patch_emb"] = jax.ShapeDtypeStruct(
            shp(B // n_micro if train else B, cfg.num_vision_tokens,
                cfg.vision_dim), jnp.bfloat16)
    if cfg.encdec:
        specs["audio_emb"] = jax.ShapeDtypeStruct(
            shp(B // n_micro if train else B, cfg.encoder_seq_len, cfg.d_model),
            jnp.bfloat16)
    return specs


def batch_shardings(specs, mesh, *, train: bool):
    """Batch dim -> (pod, data); the train microbatch axis (leading) is
    scanned, not sharded; everything else replicated."""
    ba = shrules.batch_axes(mesh)
    axis = ba if len(ba) > 1 else ba[0]
    batch_dim = 1 if train else 0

    def one(leaf):
        nd = len(leaf.shape)
        if nd <= batch_dim:
            return NamedSharding(mesh, P())
        spec = [None] * nd
        spec[batch_dim] = shrules.maybe(axis, leaf.shape[batch_dim], mesh)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, specs)


# -------------------------------------------------------------- train ----

def tree_zeros(tree, dtype):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype), tree)


def build_train_step(cfg, *, n_micro: int, multi_pod: bool = False,
                     icq_grad: bool = False, attn_impl: str = "chunked",
                     total_steps: int = 10000, mesh=None):
    """Returns (train_step, model, opt).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics).
    batch tensors are (n_micro, micro_B, ...); grads accumulate in fp32.
    When ``icq_grad`` and ``multi_pod``: the cross-pod grad combine is
    int8-compressed with error feedback (opt_state carries the residual).
    """
    model_mesh = mesh
    if icq_grad and multi_pod and mesh is not None:
        # inside the pod-manual shard_map region only (data, model) are
        # GSPMD-auto; activation constraints must not name 'pod'
        model_mesh = shrules.MeshView(mesh, hidden=("pod",))
    model = build_model(cfg, attn_impl=attn_impl, mesh=model_mesh)
    opt = make_optimizer(cfg, total_steps=total_steps)

    def loss_fn(params, mb):
        loss, aux = model.train_forward(params, mb)
        return loss, aux

    acc_dtype = jnp.dtype(cfg.grad_accum_dtype)

    def grads_of(params, batch):
        def micro(acc, mb):
            gacc, lacc = acc
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            gacc = jax.tree.map(
                lambda a, b: a + b.astype(acc_dtype), gacc, g)
            return (gacc, lacc + loss), None
        (gacc, lsum), _ = jax.lax.scan(
            micro, (tree_zeros(params, acc_dtype),
                    jnp.zeros((), jnp.float32)), batch)
        scale = 1.0 / n_micro
        return jax.tree.map(lambda g: (g * scale).astype(acc_dtype), gacc), \
            lsum * scale

    if icq_grad and multi_pod:
        def train_step(params, opt_state, batch):
            grads, loss = grads_of(params, batch)
            grads, res = compressed_cross_pod_mean(
                grads, opt_state["ef_residual"])
            loss = jax.lax.pmean(loss, "pod")
            new_params, new_opt, gnorm = _opt_update(opt, grads, opt_state,
                                                     params)
            new_opt = dict(new_opt, ef_residual=res)
            return new_params, new_opt, {"loss": loss, "gnorm": gnorm}
    else:
        def train_step(params, opt_state, batch):
            grads, loss = grads_of(params, batch)
            new_params, new_opt, gnorm = _opt_update(opt, grads, opt_state,
                                                     params)
            return new_params, new_opt, {"loss": loss, "gnorm": gnorm}

    def init_opt_state(params):
        st = opt.init(params)
        if icq_grad and multi_pod:
            st = dict(st, ef_residual=compress_state_init(params))
        return st

    return train_step, model, opt, init_opt_state


def _opt_update(opt, grads, opt_state, params):
    inner = {k: v for k, v in opt_state.items() if k != "ef_residual"}
    new_params, new_inner, gnorm = opt.update(grads, inner, params)
    return new_params, new_inner, gnorm


# ---------------------------------------------------------------- serve ----

def build_serve_fns(cfg, *, attn_impl: str = "chunked", mesh=None):
    """(prefill_fn, decode_fn, model).  prefill(params, batch, max_len);
    decode(params, tokens, caches)."""
    model = build_model(cfg, attn_impl=attn_impl, mesh=mesh)

    def prefill_fn(params, batch, max_len: int):
        return model.prefill(params, batch, max_len)

    def decode_fn(params, tokens, caches):
        return model.decode_step(params, tokens, caches)

    return prefill_fn, decode_fn, model


# ------------------------------------------------------------- lowering ----

@dataclasses.dataclass
class CellPlan:
    """Everything needed to lower one (arch x shape x mesh) cell."""
    cfg: Any
    shape: Any
    mesh: Any
    kind: str                    # train | prefill | decode
    n_micro: int
    fn: Any                      # the jittable step
    args: Tuple                  # ShapeDtypeStruct pytrees
    in_shardings: Tuple
    out_shardings: Any
    donate: Tuple[int, ...]


def scale_config(cfg):
    """Production dtype policy for pod-scale lowering: bf16 params +
    bf16 compute (fp32 accumulation inside matmuls via
    preferred_element_type; norms/softmax already compute in fp32)."""
    return dataclasses.replace(cfg, param_dtype="bfloat16",
                               compute_dtype="bfloat16")


def plan_cell(cfg, shape, mesh, *, icq_grad: bool = False,
              attn_impl: str = "chunked") -> CellPlan:
    multi_pod = "pod" in mesh.axis_names
    dp = shrules.axis_size(mesh, "data") * shrules.axis_size(mesh, "pod")
    cfg = scale_config(cfg)

    if shape.kind == "train":
        n_micro = num_microbatches(cfg, shape, dp)
        train_step, model, opt, init_opt = build_train_step(
            cfg, n_micro=n_micro, multi_pod=multi_pod, icq_grad=icq_grad,
            attn_impl=attn_impl, mesh=mesh)
        params_sh = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        opt_sh = jax.eval_shape(init_opt, params_sh)
        bspec = batch_struct(cfg, shape, n_micro, train=True)
        # compressed cross-pod exchange implies pure DP across pods
        # (pods only share int8 gradient payloads, so params must be
        # pod-replicated); otherwise FSDP spans the pod axis too.
        p_shard = shrules.param_shardings(
            params_sh, mesh, fsdp_over_pod=not (icq_grad and multi_pod))
        o_shard = opt_shardings(opt_sh, params_sh, p_shard, mesh)
        b_shard = batch_shardings(bspec, mesh, train=True)
        fn = train_step
        metric_shard = {"loss": shrules.replicated(mesh),
                        "gnorm": shrules.replicated(mesh)}
        if icq_grad and multi_pod:
            fn = wrap_pod_manual(train_step, mesh,
                                 (p_shard, o_shard, b_shard),
                                 (p_shard, o_shard, metric_shard))
        return CellPlan(
            cfg=cfg, shape=shape, mesh=mesh, kind="train", n_micro=n_micro,
            fn=fn, args=(params_sh, opt_sh, bspec),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate=(0, 1))

    prefill_fn, decode_fn, model = build_serve_fns(cfg, attn_impl=attn_impl,
                                                   mesh=mesh)
    params_sh = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = shrules.param_shardings(params_sh, mesh)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "prefill":
        bspec = batch_struct(cfg, shape, 1, train=False)
        b_shard = batch_shardings(bspec, mesh, train=False)
        fn = functools.partial(prefill_fn, max_len=S)
        return CellPlan(
            cfg=cfg, shape=shape, mesh=mesh, kind="prefill", n_micro=1,
            fn=fn, args=(params_sh, bspec),
            in_shardings=(p_shard, b_shard),
            out_shardings=None, donate=())

    # decode: one token against a seq_len cache
    cache_sh = jax.eval_shape(
        functools.partial(model.init_cache, B, S, jnp.bfloat16))
    c_shard = shrules.cache_shardings(cache_sh, cfg, mesh)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    t_shard = batch_shardings(tok, mesh, train=False)
    return CellPlan(
        cfg=cfg, shape=shape, mesh=mesh, kind="decode", n_micro=1,
        fn=decode_fn, args=(params_sh, tok, cache_sh),
        in_shardings=(p_shard, t_shard, c_shard),
        out_shardings=(None, c_shard), donate=(2,))


def opt_shardings(opt_sh, params_sh, p_shard, mesh):
    """Optimizer moments mirror the param shardings; scalars replicated;
    ef_residual mirrors params."""
    def like_params(sub):
        return jax.tree.map(
            lambda _, s: s, sub,
            jax.tree.map(lambda s: s, p_shard))

    out = {}
    for k, v in opt_sh.items():
        if k in ("m", "v", "ef_residual", "f"):
            out[k] = jax.tree.map(lambda leaf, sh: sh, v, p_shard) \
                if _same_struct(v, p_shard) else _fallback(v, mesh)
        else:
            out[k] = jax.tree.map(lambda _: shrules.replicated(mesh), v)
    return out


def _same_struct(a, b) -> bool:
    return (jax.tree_util.tree_structure(a)
            == jax.tree_util.tree_structure(b))


def _fallback(tree, mesh):
    return jax.tree.map(lambda _: shrules.replicated(mesh), tree)


def pod_manual_spec(sharding):
    """Project a NamedSharding's PartitionSpec onto the 'pod' axis only —
    the in/out specs for a shard_map that is *manual over pod* and GSPMD-
    auto over (data, model)."""
    spec = sharding.spec
    out = []
    for entry in spec:
        if entry == "pod":
            out.append("pod")
        elif isinstance(entry, tuple) and "pod" in entry:
            out.append("pod")
        else:
            out.append(None)
    return P(*out)


def wrap_pod_manual(fn, mesh, in_shardings, out_shardings):
    """shard_map(fn) manual over the 'pod' axis so explicit cross-pod
    collectives (jax.lax.all_gather(axis_name='pod') in the compressed
    grad combine) are legal; data/model stay GSPMD-auto."""
    in_specs = jax.tree.map(pod_manual_spec, in_shardings,
                            is_leaf=lambda x: hasattr(x, "spec"))
    out_specs = jax.tree.map(
        pod_manual_spec, out_shardings,
        is_leaf=lambda x: hasattr(x, "spec"))
    return shrules.shard_map_compat(fn, mesh, in_specs, out_specs,
                                    axis_names={"pod"})


def plan_icq_kv_cell(cfg, shape, mesh, *, top_c_frac: float = 1 / 16,
                     d_fast_frac: float = 1 / 4) -> CellPlan:
    """Decode cell with the ICQ two-step quantized KV cache (the paper's
    technique as the serving hot path) — §Perf variant 'icq_kv'."""
    from repro.quant.serve_icq import (build_icq_decode,
                                       icq_kv_cache_shardings,
                                       supports_icq_kv)
    cfg = scale_config(cfg)
    assert supports_icq_kv(cfg), cfg.name
    kv_cfg = ICQKVConfig(d_fast=max(int(cfg.head_dim * d_fast_frac), 16))
    model = build_model(cfg, mesh=mesh)
    decode_fn, init_cache = build_icq_decode(cfg, kv_cfg, mesh=mesh)
    params_sh = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = shrules.param_shardings(params_sh, mesh)
    B, S = shape.global_batch, shape.seq_len
    cache_sh = jax.eval_shape(functools.partial(init_cache, B, S))
    c_shard = icq_kv_cache_shardings(cache_sh, cfg, mesh)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    t_shard = batch_shardings(tok, mesh, train=False)
    top_c = max(int(S * top_c_frac), 128)
    fn = functools.partial(decode_fn, top_c=top_c)
    return CellPlan(
        cfg=cfg, shape=shape, mesh=mesh, kind="decode", n_micro=1,
        fn=fn, args=(params_sh, tok, cache_sh),
        in_shardings=(p_shard, t_shard, c_shard),
        out_shardings=(None, c_shard), donate=(2,))


def lower_cell(plan: CellPlan):
    """jit(...).lower(...) under the cell's mesh.  Returns the Lowered."""
    jitted = jax.jit(plan.fn,
                     in_shardings=plan.in_shardings,
                     out_shardings=plan.out_shardings,
                     donate_argnums=plan.donate)
    with plan.mesh:
        return jitted.lower(*plan.args)
