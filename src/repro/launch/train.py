"""End-to-end training driver.

Runs real steps on the available devices (CPU smoke -> TPU pod with the
same code path): data pipeline -> sharded train_step -> checkpointing /
fault-tolerant supervision.  The production meshes are exercised without
hardware by ``dryrun.py``; this driver actually executes.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 20 --seq-len 128 --global-batch 8 --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import TokenPipeline
from repro.distributed import CheckpointManager, TrainSupervisor
from repro.distributed import sharding as shrules
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (batch_shardings, batch_struct,
                                build_train_step, num_microbatches)


def make_host_batch(pipe, cfg, shape, n_micro, step):
    raw = pipe.batch(step)
    B = shape.global_batch

    def shape_mb(x):
        return x.reshape((n_micro, B // n_micro) + x.shape[1:])

    batch = {k: shape_mb(v) for k, v in raw.items()}
    if cfg.frontend == "vision_stub":
        v = cfg.num_vision_tokens
        batch["tokens"] = batch["tokens"][..., : shape.seq_len - v]
        batch["labels"] = batch["labels"][..., : shape.seq_len - v]
        batch["patch_emb"] = np.random.default_rng(step).standard_normal(
            (n_micro, B // n_micro, v, cfg.vision_dim)).astype(np.float32)
    if cfg.encdec:
        batch["audio_emb"] = np.random.default_rng(step).standard_normal(
            (n_micro, B // n_micro, cfg.encoder_seq_len, cfg.d_model)
        ).astype(np.float32)
    return batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config of the arch family (CPU-sized)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeSpec(name="cli", seq_len=args.seq_len,
                      global_batch=args.global_batch, kind="train")
    mesh = make_host_mesh()
    dp = shrules.axis_size(mesh, "data")
    n_micro = num_microbatches(cfg, shape, dp)

    train_step, model, opt, init_opt = build_train_step(
        cfg, n_micro=n_micro, mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_opt(params)

    bspec = batch_struct(cfg, shape, n_micro, train=True)
    b_shard = batch_shardings(bspec, mesh, train=True)
    step_jit = jax.jit(train_step, donate_argnums=(0, 1))

    pipe = TokenPipeline(vocab_size=cfg.vocab_size,
                         seq_len=shape.seq_len,
                         global_batch=shape.global_batch)
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    sup = TrainSupervisor(ckpt, save_every=args.save_every)

    state = {"params": params, "opt": opt_state}

    def one_step(state, idx):
        batch = make_host_batch(pipe, cfg, shape, n_micro, idx)
        batch = jax.tree.map(
            lambda x, s: jax.device_put(x, s), batch, b_shard)
        t0 = time.time()
        p, o, metrics = step_jit(state["params"], state["opt"], batch)
        loss = float(metrics["loss"])
        print(f"step {idx:5d} loss={loss:8.4f} "
              f"gnorm={float(metrics['gnorm']):7.3f} "
              f"dt={time.time() - t0:5.2f}s")
        return {"params": p, "opt": o}, {"loss": loss}

    state, report = sup.run(state, one_step, args.steps)
    print(f"done: final_step={report.final_step} restarts={report.restarts} "
          f"resumed_from={report.resumed_from}")


if __name__ == "__main__":
    main()
