"""End-to-end training driver.

Runs real steps on the available devices (CPU smoke -> TPU pod with the
same code path): data pipeline -> sharded train_step -> checkpointing /
fault-tolerant supervision.  The production meshes are exercised without
hardware by ``dryrun.py``; this driver actually executes.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 20 --seq-len 128 --global-batch 8 --smoke

``--icq`` runs the *retrieval* pipeline instead (no LM): the trainer
layer's scan-compiled ``fit`` (DESIGN.md §9) on a synthetic Table-1
dataset — optionally data-parallel over ``--icq-shards`` devices —
then builds a serving index, grows it with ``Index.add``, and
round-trips a query batch:

    PYTHONPATH=src python -m repro.launch.train --icq --icq-epochs 4
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m repro.launch.train --icq --icq-shards 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import TokenPipeline
from repro.distributed import CheckpointManager, TrainSupervisor
from repro.distributed import sharding as shrules
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (batch_shardings, batch_struct,
                                build_train_step, num_microbatches)


def make_host_batch(pipe, cfg, shape, n_micro, step):
    raw = pipe.batch(step)
    B = shape.global_batch

    def shape_mb(x):
        return x.reshape((n_micro, B // n_micro) + x.shape[1:])

    batch = {k: shape_mb(v) for k, v in raw.items()}
    if cfg.frontend == "vision_stub":
        v = cfg.num_vision_tokens
        batch["tokens"] = batch["tokens"][..., : shape.seq_len - v]
        batch["labels"] = batch["labels"][..., : shape.seq_len - v]
        batch["patch_emb"] = np.random.default_rng(step).standard_normal(
            (n_micro, B // n_micro, v, cfg.vision_dim)).astype(np.float32)
    if cfg.encdec:
        batch["audio_emb"] = np.random.default_rng(step).standard_normal(
            (n_micro, B // n_micro, cfg.encoder_seq_len, cfg.d_model)
        ).astype(np.float32)
    return batch


def icq_config_from_args(args):
    """Resolve the run's ``repro.api.ICQConfig``: ``--config path.json``
    (validated, schema-versioned) or the CLI default, with the legacy
    flags applied as dotted overrides — a flag left at its ``None``
    default defers to the config."""
    from repro.api import ICQConfig, TrainConfig, ServeConfig

    if args.config is not None:
        cfg = ICQConfig.load(args.config)
    else:                       # the historical CLI defaults
        cfg = ICQConfig(
            train=TrainConfig(codebook_size=64, epochs=3, batch_size=256),
            serve=ServeConfig(topk=20, backend="jnp"))
    overrides = {}
    if args.icq_epochs is not None:
        overrides["train.epochs"] = args.icq_epochs
    if args.icq_batch is not None:
        overrides["train.batch_size"] = args.icq_batch
    if args.icq_index is not None:
        overrides["index.kind"] = args.icq_index
    return cfg.with_overrides(overrides)


def run_icq(args):
    """Train -> index -> add -> query -> (save): the retrieval pipeline
    through the front-door api (``repro.api.icq_session``, docs/api.md)
    — scan epochs, optional data-parallel mesh, tiled encoding engine,
    incremental index build, persistent artifacts."""
    import jax.numpy as jnp

    from repro.api import icq_session
    from repro.data import make_table1_dataset
    from repro.index import recall_at

    cfg = icq_config_from_args(args)
    xtr, ytr, xte, yte = make_table1_dataset(args.icq_dataset)
    xtr, ytr = xtr[: args.icq_n], ytr[: args.icq_n]
    n_held = max(args.icq_add, 1)
    x_held, xtr = xtr[-n_held:], xtr[:-n_held]       # rows added post-build
    ytr = ytr[:-n_held]

    mesh = None
    if args.icq_shards > 1:
        if len(jax.devices()) < args.icq_shards:
            raise SystemExit(
                f"--icq-shards {args.icq_shards} needs that many devices; "
                "on CPU set XLA_FLAGS=--xla_force_host_platform_device_"
                f"count={args.icq_shards}")
        mesh = shrules.make_mesh_auto((args.icq_shards,), ("data",))

    session = icq_session(cfg)
    t0 = time.time()
    model = session.fit(xtr, ytr, key=jax.random.PRNGKey(args.seed),
                        mesh=mesh, verbose=True)
    print(f"icq: fit n={xtr.shape[0]} epochs={cfg.train.epochs} "
          f"shards={args.icq_shards} in {time.time() - t0:.1f}s; "
          f"psi={int(model.structure.xi.sum())}/{cfg.train.d} "
          f"fast={int(model.structure.fast_mask.sum())}"
          f"/{cfg.train.num_codebooks}")

    searcher = session.index(mesh=mesh,
                             key=jax.random.PRNGKey(args.seed + 1))
    n0 = searcher.n
    searcher.add(x_held)                             # incremental build
    res = searcher.search(xte[:64])
    jax.block_until_ready(res.indices)
    # the held-out rows must be findable: query with themselves
    self_res = searcher.search(x_held[: min(n_held, 16)])
    self_ids = jnp.arange(n0, n0 + min(n_held, 16))[:, None]
    hit = float(recall_at(self_res.indices, self_ids))
    print(f"icq: index={cfg.index.kind} grown {n0} -> {searcher.n}; "
          f"query batch ok (pass_rate={float(res.pass_rate):.3f}); "
          f"added-row self-recall@{cfg.serve.topk}={hit:.3f}")

    if args.save_artifacts:
        path = searcher.save(args.save_artifacts)
        print(f"icq: artifacts (config hash "
              f"{cfg.config_hash()[:12]}) -> {path}; reload with "
              "launch/serve.py --load-artifacts or "
              "repro.api.load_ann_engine")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config of the arch family (CPU-sized)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--icq", action="store_true",
                    help="run the retrieval trainer pipeline (no LM): "
                         "scan-compiled fit -> index -> add -> query")
    ap.add_argument("--config", default=None,
                    help="repro.api ICQConfig JSON driving the --icq run "
                         "(docs/api.md); the --icq-* flags below override "
                         "individual fields")
    ap.add_argument("--save-artifacts", default=None, metavar="DIR",
                    help="after the --icq run, persist config + model + "
                         "index (repro.api.Artifacts); reload with "
                         "launch/serve.py --load-artifacts DIR")
    ap.add_argument("--icq-dataset", default="dataset2")
    ap.add_argument("--icq-n", type=int, default=4000)
    ap.add_argument("--icq-epochs", type=int, default=None,
                    help="override train.epochs (config default: 3)")
    ap.add_argument("--icq-batch", type=int, default=None,
                    help="override train.batch_size (config default: 256)")
    ap.add_argument("--icq-shards", type=int, default=1,
                    help="data-parallel training/serving mesh size")
    ap.add_argument("--icq-index", default=None,
                    choices=["flat", "two-step", "ivf"],
                    help="override index.kind (config default: two-step)")
    ap.add_argument("--icq-add", type=int, default=64,
                    help="held-out rows appended via Index.add post-build")
    args = ap.parse_args()

    if args.icq:
        run_icq(args)
        return
    if args.arch is None:
        ap.error("--arch is required unless --icq is given")

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = ShapeSpec(name="cli", seq_len=args.seq_len,
                      global_batch=args.global_batch, kind="train")
    mesh = make_host_mesh()
    dp = shrules.axis_size(mesh, "data")
    n_micro = num_microbatches(cfg, shape, dp)

    train_step, model, opt, init_opt = build_train_step(
        cfg, n_micro=n_micro, mesh=mesh)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_opt(params)

    bspec = batch_struct(cfg, shape, n_micro, train=True)
    b_shard = batch_shardings(bspec, mesh, train=True)
    step_jit = jax.jit(train_step, donate_argnums=(0, 1))

    pipe = TokenPipeline(vocab_size=cfg.vocab_size,
                         seq_len=shape.seq_len,
                         global_batch=shape.global_batch)
    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    sup = TrainSupervisor(ckpt, save_every=args.save_every)

    state = {"params": params, "opt": opt_state}

    def one_step(state, idx):
        batch = make_host_batch(pipe, cfg, shape, n_micro, idx)
        batch = jax.tree.map(
            lambda x, s: jax.device_put(x, s), batch, b_shard)
        t0 = time.time()
        p, o, metrics = step_jit(state["params"], state["opt"], batch)
        loss = float(metrics["loss"])
        print(f"step {idx:5d} loss={loss:8.4f} "
              f"gnorm={float(metrics['gnorm']):7.3f} "
              f"dt={time.time() - t0:5.2f}s")
        return {"params": p, "opt": o}, {"loss": loss}

    state, report = sup.run(state, one_step, args.steps)
    print(f"done: final_step={report.final_step} restarts={report.restarts} "
          f"resumed_from={report.resumed_from}")


if __name__ == "__main__":
    main()
