from repro.models.transformer import build_model, ModelFns

__all__ = ["build_model", "ModelFns"]
