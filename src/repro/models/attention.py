"""Attention layers: GQA with chunked online-softmax, sliding-window local
attention, cross attention, and single-token decode with a KV cache.

The chunked implementation is the pure-JAX (GSPMD-shardable) path used by
train/prefill at every scale; the Pallas flash kernel in
``repro.kernels.flash_attention`` is the TPU hot-path drop-in, selected via
``attn_impl="pallas"`` (validated against the same oracle in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import nn

NEG_INF = -1e30


def attn_init(key, cfg, dtype="float32"):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "wq": nn.dense_init(ks[0], d, cfg.num_heads * cfg.head_dim, dtype),
        "wk": nn.dense_init(ks[1], d, cfg.num_kv_heads * cfg.head_dim, dtype),
        "wv": nn.dense_init(ks[2], d, cfg.num_kv_heads * cfg.head_dim, dtype),
        "wo": nn.dense_init(ks[3], cfg.num_heads * cfg.head_dim, d, dtype),
    }


def _split_heads(x, n_heads, head_dim):
    return x.reshape(*x.shape[:-1], n_heads, head_dim)


def qkv_project(p, x, cfg, positions, rope: bool = True):
    """Project + rope.  Returns q:(b,s,H,dh), k,v:(b,s,KVH,dh)."""
    q = _split_heads(x @ p["wq"], cfg.num_heads, cfg.head_dim)
    k = _split_heads(x @ p["wk"], cfg.num_kv_heads, cfg.head_dim)
    v = _split_heads(x @ p["wv"], cfg.num_kv_heads, cfg.head_dim)
    if rope:
        q = nn.apply_rope(q, positions, cfg.rope_theta)
        k = nn.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k, scale):
    """q:(b,sq,H,dh) k:(b,sk,KVH,dh) -> scores (b,KVH,G,sq,sk) fp32."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, dh)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                      preferred_element_type=jnp.float32) * scale


def _gqa_out(probs, v):
    """probs:(b,KVH,G,sq,sk) v:(b,sk,KVH,dh) -> (b,sq,H,dh)."""
    b, kvh, g, sq, sk = probs.shape
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, kvh * g, v.shape[-1])


def chunked_attention(q, k, v, *, causal: bool, chunk: int,
                      q_offset: int = 0, window: int = 0,
                      kv_valid: int = 0):
    """Online-softmax attention, O(chunk^2) live memory.

    Double scan: outer over query chunks, inner over KV chunks, carrying
    (running max, normalizer, accumulator).  ``window>0`` adds a sliding
    band mask (local attention); ``kv_valid>0`` masks keys at positions
    >= kv_valid (padded cross-attention).  All shapes static -> scan
    compiles O(1) in sequence length.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    dv = v.shape[-1]
    g = h // kvh
    scale = dh ** -0.5
    qc = min(chunk, sq)
    kc = min(chunk, sk)
    nq, nk = sq // qc, sk // kc
    assert sq % qc == 0 and sk % kc == 0, (sq, qc, sk, kc)

    q = q.reshape(b, nq, qc, h, dh)
    k = k.reshape(b, nk, kc, kvh, dh)
    v = v.reshape(b, nk, kc, kvh, dv)

    def q_step(_, qi):
        qblk = q[:, qi]                                    # (b,qc,h,dh)
        q_pos = q_offset + qi * qc + jnp.arange(qc)

        # checkpoint per KV chunk: the scan backward otherwise stacks the
        # (qc, kc) prob tiles over BOTH scan levels — a full S x S fp32
        # attention matrix per layer (flash-attention-style recompute).
        @jax.checkpoint
        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk = k[:, ki], v[:, ki]
            k_pos = ki * kc + jnp.arange(kc)
            s = _gqa_scores(qblk, kblk, scale)             # (b,kvh,g,qc,kc)
            mask = jnp.ones((qc, kc), dtype=bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            if kv_valid:
                mask &= (k_pos < kv_valid)[None, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, qc, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]       # (b,kvh,g,qc,dv)
        out = jnp.moveaxis(out, 3, 1).reshape(b, qc, h, dv)
        return None, out.astype(v.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))   # (nq,b,qc,h,dv)
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, dv)


def triangular_chunked_attention(q, k, v, *, chunk: int, window: int = 0):
    """Causal attention that SKIPS fully-masked (upper-triangle) chunk
    pairs — the beyond-baseline FLOP-exact path (see EXPERIMENTS.md §Perf).

    Enumerates the (qi, ki<=qi) pair list statically (optionally band-
    limited for local attention) and scans over it, scatter-accumulating
    per-query-chunk online-softmax state.  HLO FLOPs ≈ the true causal
    half, vs 2x for the masked full scan.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    kvh = k.shape[2]
    dv = v.shape[-1]
    g = h // kvh
    scale = dh ** -0.5
    qc = kc = min(chunk, sq, sk)
    nq, nk = sq // qc, sk // kc
    assert sq % qc == 0 and sk % kc == 0
    offset = nk - nq  # prefix keys (q block i sees key blocks <= i+offset)

    pairs = []
    for qi in range(nq):
        for ki in range(qi + offset + 1):
            if window and (qi + offset - ki) * kc >= window + kc:
                continue  # entire pair outside the sliding band
            pairs.append((qi, ki))
    pairs = jnp.asarray(pairs, jnp.int32)                  # (P,2)

    q = q.reshape(b, nq, qc, h, dh)
    k = k.reshape(b, nk, kc, kvh, dh)
    v = v.reshape(b, nk, kc, kvh, dv)

    def step(carry, pair):
        m, l, acc = carry                                  # (b,kvh,g,nq,qc[,dh])
        qi, ki = pair[0], pair[1]
        qblk = jax.lax.dynamic_index_in_dim(q, qi, 1, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(k, ki, 1, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(v, ki, 1, keepdims=False)
        q_pos = (qi + 0) * qc + jnp.arange(qc) + (offset * kc)
        k_pos = ki * kc + jnp.arange(kc)
        s = _gqa_scores(qblk, kblk, scale)                 # (b,kvh,g,qc,kc)
        mask = q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = jax.lax.dynamic_index_in_dim(m, qi, 3, keepdims=False)
        l_prev = jax.lax.dynamic_index_in_dim(l, qi, 3, keepdims=False)
        a_prev = jax.lax.dynamic_index_in_dim(acc, qi, 3, keepdims=False)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        a_new = a_prev * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk).astype(jnp.float32)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 3)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 3)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 3)
        return (m, l, acc), None

    m0 = jnp.full((b, kvh, g, nq, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, nq, qc), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, nq, qc, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), pairs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]           # (b,kvh,g,nq,qc,dv)
    out = jnp.moveaxis(out, (3, 4), (1, 2)).reshape(b, sq, kvh * g, dv)
    return out.astype(v.dtype)


def full_attention(q, k, v, *, causal: bool, q_offset: int = 0, window: int = 0,
                   mask=None):
    """Reference einsum attention (small seq / oracles / whisper encoder)."""
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    scale = dh ** -0.5
    s = _gqa_scores(q, k, scale)
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    m = jnp.ones((sq, sk), dtype=bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    if mask is not None:
        m &= mask
    s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v)


def decode_attention(q, k_cache, v_cache, length_mask):
    """Single-token decode.  q:(b,1,H,dh), caches:(b,S,KVH,dh),
    length_mask:(b,S) bool (True = valid slot)."""
    b, _, h, dh = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    scale = dh ** -0.5
    qg = q.reshape(b, kvh, g, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(length_mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, dh)


def attention_apply(p, x, cfg, positions, *, causal=True, window=0,
                    impl="chunked", rope=True):
    """Full-sequence attention (train / prefill)."""
    q, k, v = qkv_project(p, x, cfg, positions, rope=rope)
    s = x.shape[1]
    if impl == "full" or s <= cfg.attn_chunk:
        out = full_attention(q, k, v, causal=causal, window=window)
    elif impl == "triangular" and causal:
        out = triangular_chunked_attention(q, k, v, chunk=cfg.attn_chunk,
                                           window=window)
    elif not causal and s % cfg.attn_chunk:
        # ragged non-causal (whisper's 1500-frame encoder): pad + mask
        sp = _pad_len(s, cfg.attn_chunk)
        pad = ((0, 0), (0, sp - s), (0, 0), (0, 0))
        out = chunked_attention(jnp.pad(q, pad), jnp.pad(k, pad),
                                jnp.pad(v, pad), causal=False,
                                chunk=cfg.attn_chunk, kv_valid=s)[:, :s]
    else:
        out = chunked_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk,
                                window=window)
    return out.reshape(*x.shape[:-1], cfg.num_heads * cfg.head_dim) @ p["wo"]


# ------------------------------------------------------ cross attention ----

def cross_attn_init(key, cfg, dtype="float32"):
    return attn_init(key, cfg, dtype)


def cross_attention_apply(p, x, enc_out, cfg):
    """Decoder cross-attention over encoder states (no rope, no mask).
    Chunked when either side exceeds attn_chunk: the (sq, s_enc) prob
    tensor at train time otherwise dominates decoder activation memory
    (4096 x 1500 x heads per row on whisper)."""
    b, s, _ = x.shape
    q = _split_heads(x @ p["wq"], cfg.num_heads, cfg.head_dim)
    k = _split_heads(enc_out @ p["wk"], cfg.num_kv_heads, cfg.head_dim)
    v = _split_heads(enc_out @ p["wv"], cfg.num_kv_heads, cfg.head_dim)
    sk = k.shape[1]
    if max(s, sk) <= cfg.attn_chunk:
        out = full_attention(q, k, v, causal=False)
    else:
        qc = _pad_len(s, cfg.attn_chunk)
        kc = _pad_len(sk, cfg.attn_chunk)
        qp = jnp.pad(q, ((0, 0), (0, qc - s), (0, 0), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, kc - sk), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, kc - sk), (0, 0), (0, 0)))
        out = chunked_attention(qp, kp, vp, causal=False,
                                chunk=cfg.attn_chunk, kv_valid=sk)
        out = out[:, :s]
    return out.reshape(b, s, cfg.num_heads * cfg.head_dim) @ p["wo"]


def _pad_len(n: int, c: int) -> int:
    return ((n + c - 1) // c) * c


def cross_attention_decode(p, x, k_cache, v_cache, cfg):
    """Decode-time cross-attention against the precomputed static cache."""
    b = x.shape[0]
    q = _split_heads(x @ p["wq"], cfg.num_heads, cfg.head_dim)
    valid = jnp.ones(k_cache.shape[:2], dtype=bool)
    out = decode_attention(q, k_cache, v_cache, valid)
    return out.reshape(b, 1, cfg.num_heads * cfg.head_dim) @ p["wo"]
