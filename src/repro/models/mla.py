"""Multi-head Latent Attention (DeepSeek-V2).

Train/prefill: queries through a low-rank bottleneck (q_lora), keys/values
decompressed per-head from a shared kv_lora latent + a head-shared rope key.

Decode: the *absorbed* formulation — W_uk folds into the query and W_uv
into the output projection, so the KV cache is just the (kv_lora +
rope_dim)-wide latent per token.  This is the memory-optimal serving path
and the surface ICQ-KV quantizes (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.models.attention import NEG_INF, chunked_attention, full_attention


def mla_init(key, cfg, dtype="float32"):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    h = cfg.num_heads
    qk_nope, qk_rope, v_dim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    p = {
        "w_dkv": nn.dense_init(ks[0], d, cfg.kv_lora_rank + qk_rope, dtype),
        "kv_norm": nn.rmsnorm_init(cfg.kv_lora_rank, dtype),
        "w_uk": nn.dense_init(ks[1], cfg.kv_lora_rank, h * qk_nope, dtype),
        "w_uv": nn.dense_init(ks[2], cfg.kv_lora_rank, h * v_dim, dtype),
        "wo": nn.dense_init(ks[3], h * v_dim, d, dtype),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = nn.dense_init(ks[4], d, cfg.q_lora_rank, dtype)
        p["q_norm"] = nn.rmsnorm_init(cfg.q_lora_rank, dtype)
        p["w_uq"] = nn.dense_init(ks[5], cfg.q_lora_rank, h * (qk_nope + qk_rope), dtype)
    else:
        p["w_q"] = nn.dense_init(ks[5], d, h * (qk_nope + qk_rope), dtype)
    return p


def _queries(p, x, cfg, positions):
    h = cfg.num_heads
    qk_nope, qk_rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        cq = nn.rmsnorm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
        q = cq @ p["w_uq"]
    else:
        q = x @ p["w_q"]
    q = q.reshape(*x.shape[:-1], h, qk_nope + qk_rope)
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = nn.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent(p, x, cfg, positions):
    ckv = x @ p["w_dkv"]                                    # (b,s,lora+rope)
    latent = nn.rmsnorm(ckv[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = ckv[..., cfg.kv_lora_rank:][..., None, :]      # (b,s,1,rope)
    k_rope = nn.apply_rope(k_rope, positions, cfg.rope_theta)[..., 0, :]
    return latent, k_rope


def mla_attention_apply(p, x, cfg, positions):
    """Full-sequence causal MLA (train / prefill).

    Short sequences take the dense path.  Long sequences use *lazy
    decompression*: materializing the per-head K (b, s, h, d) from the
    latent costs s*h*(dn+dr) bytes (3.2 GB/device at deepseek's 32k
    prefill); the chunked path instead decompresses one KV block at a
    time inside the online-softmax scan, so only (b, chunk, h, d) ever
    exists — the latent itself is the resident sequence state.
    """
    b, s, _ = x.shape
    h = cfg.num_heads
    qk_nope, qk_rope, v_dim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _queries(p, x, cfg, positions)
    latent, k_rope = _latent(p, x, cfg, positions)
    if s <= cfg.attn_chunk:
        k_nope = (latent @ p["w_uk"]).reshape(b, s, h, qk_nope)
        v = (latent @ p["w_uv"]).reshape(b, s, h, v_dim)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, s, h, qk_rope))], axis=-1)
        out = full_attention(q, k, v, causal=True)
    else:
        out = mla_chunked_attention(p, q_nope, q_rope, latent, k_rope, cfg)
    return out.reshape(b, s, h * v_dim) @ p["wo"]


def mla_chunked_attention(p, q_nope, q_rope, latent, k_rope_seq, cfg):
    """Online-softmax causal MLA with per-block latent decompression."""
    b, s, h, dn = q_nope.shape
    dr = q_rope.shape[-1]
    dv = cfg.v_head_dim
    scale = (dn + dr) ** -0.5
    c = min(cfg.attn_chunk, s)
    while s % c:
        c -= 1
    n = s // c
    qn = q_nope.reshape(b, n, c, h, dn)
    qr = q_rope.reshape(b, n, c, h, dr)
    lat = latent.reshape(b, n, c, -1)
    krs = k_rope_seq.reshape(b, n, c, dr)

    def q_step(_, qi):
        qn_blk, qr_blk = qn[:, qi], qr[:, qi]              # (b,c,h,·)
        q_pos = qi * c + jnp.arange(c)

        @jax.checkpoint
        def kv_step(carry, ki):
            m, l, acc = carry
            lat_blk = lat[:, ki]                           # (b,c,lora)
            k_nope = (lat_blk @ p["w_uk"]).reshape(b, c, h, dn)
            v_blk = (lat_blk @ p["w_uv"]).reshape(b, c, h, dv)
            kr_blk = krs[:, ki]                            # (b,c,dr)
            k_pos = ki * c + jnp.arange(c)
            sc = (jnp.einsum("bqhd,bkhd->bhqk", qn_blk, k_nope,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bqhr,bkr->bhqk", qr_blk.astype(jnp.float32),
                               kr_blk.astype(jnp.float32))) * scale
            mask = q_pos[:, None] >= k_pos[None, :]
            sc = jnp.where(mask, sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            pr = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(pr, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", pr.astype(v_blk.dtype), v_blk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, c), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, c), jnp.float32)
        a0 = jnp.zeros((b, h, c, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(n))
        out = acc / jnp.maximum(l, 1e-30)[..., None]        # (b,h,c,dv)
        return None, jnp.moveaxis(out, 1, 2).astype(latent.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(n))     # (n,b,c,h,dv)
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, dv)


def mla_prefill_latent(p, x, cfg, positions):
    """Latent + rope-key streams to seed the decode cache."""
    return _latent(p, x, cfg, positions)


def mla_decode_attention(p, x, latent_cache, k_rope_cache, cfg, positions,
                         length_mask):
    """Absorbed decode: scores in latent space, cache = latent + rope key.

    latent_cache: (b,S,kv_lora); k_rope_cache: (b,S,rope); x: (b,1,d).
    """
    b = x.shape[0]
    h = cfg.num_heads
    qk_nope, qk_rope, v_dim = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank
    q_nope, q_rope = _queries(p, x, cfg, positions)         # (b,1,h,·)
    # absorb W_uk: q_lat[b,h,lora] = q_nope · W_uk(head slice)
    w_uk = p["w_uk"].reshape(lora, h, qk_nope)
    q_lat = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0], w_uk)
    scores = (
        jnp.einsum("bhl,bsl->bhs", q_lat, latent_cache,
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32),
                     k_rope_cache.astype(jnp.float32))
    ) * (qk_nope + qk_rope) ** -0.5
    scores = jnp.where(length_mask[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhs,bsl->bhl", probs.astype(latent_cache.dtype),
                         latent_cache)
    # absorb W_uv
    w_uv = p["w_uv"].reshape(lora, h, v_dim)
    out = jnp.einsum("bhl,lhv->bhv", out_lat, w_uv).reshape(b, 1, h * v_dim)
    return out @ p["wo"]
