"""Mixture-of-Experts layer with top-k routing and capacity-bounded
sorted gather/scatter dispatch.

Dispatch design (DESIGN.md §6): the classic one-hot einsum dispatch costs
2·T·(T·k·cf)·D FLOPs — quadratic in tokens and larger than the expert
GEMMs themselves for DeepSeek-scale expert counts.  We instead compute
(expert, slot) -> token indices with a sort + exclusive-cumsum, gather
tokens to an (E, C, D) buffer, run batched expert GEMMs (shardable over
the expert axis = EP), and scatter-add the combine.  FLOPs are then the
true active-expert FLOPs; the gathers are bytes, not FLOPs.  Under GSPMD
the gather/scatter lower to the EP all-to-all/all-gather pattern.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import nn


def moe_init(key, cfg, dtype="float32"):
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    p = {
        "router": nn.dense_init(ks[0], d, e, "float32"),   # router kept fp32
        "we_gate": jax.vmap(lambda k: nn.dense_init(k, d, f, dtype))(
            jax.random.split(ks[1], e)),
        "we_up": jax.vmap(lambda k: nn.dense_init(k, d, f, dtype))(
            jax.random.split(ks[2], e)),
        "we_down": jax.vmap(lambda k: nn.dense_init(k, f, d, dtype))(
            jax.random.split(ks[3], e)),
    }
    if cfg.num_shared_experts:
        p["shared"] = nn.mlp_init(ks[4], d, cfg.num_shared_experts * f,
                                  "swiglu", dtype)
    return p


def router_topk(logits, k: int):
    """Softmax-then-topk (DeepSeek-style), gates renormalized over top-k."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, ids = jax.lax.top_k(probs, k)                   # (T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, ids, probs


def load_balance_loss(probs, ids, num_experts: int):
    """Switch-transformer aux loss: E * sum_e f_e * P_e."""
    T = probs.shape[0]
    counts = jnp.zeros((num_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(T * ids.shape[-1], 1)
    P = probs.mean(axis=0)
    return num_experts * jnp.sum(f * P)


def _dispatch_indices(ids, num_experts: int, capacity: int):
    """token->slot assignment.  Returns (token_idx (E*C,), valid (E*C,),
    slot_of_flat (T*k,), kept (T*k,)) — all int32/bool, static shapes."""
    Tk = ids.size
    fid = ids.reshape(-1)                                  # (T*k,)
    order = jnp.argsort(fid)                               # stable enough: ties by index
    fid_sorted = fid[order]
    # rank within expert group
    group_sizes = jnp.zeros((num_experts,), jnp.int32).at[fid].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(group_sizes)[:-1]])
    rank = jnp.arange(Tk, dtype=jnp.int32) - starts[fid_sorted]
    kept_sorted = rank < capacity
    slot_sorted = jnp.where(kept_sorted, fid_sorted * capacity + rank, Tk + capacity * num_experts)
    # scatter source token (flat tk index) into slots
    token_of_slot = jnp.full((num_experts * capacity + Tk + 1,), -1, jnp.int32)
    token_of_slot = token_of_slot.at[jnp.where(kept_sorted, slot_sorted, num_experts * capacity + Tk)].set(order)
    token_of_slot = token_of_slot[: num_experts * capacity]
    valid = token_of_slot >= 0
    return token_of_slot, valid


def moe_apply(p, x, cfg):
    """x: (..., d) -> (out (..., d), aux_loss scalar).

    Long sequences are processed in token chunks (lax.scan): capacity
    scales with the *chunk*, so the (E, C, d) dispatch buffers stay
    O(chunk) instead of O(tokens) — at deepseek's 32k prefill the
    unchunked buffers were 5 GB/device x several copies (EXPERIMENTS.md
    §Perf).  Per-chunk capacity also localizes overflow drops.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    xt_all = x.reshape(-1, d)
    T_all = xt_all.shape[0]
    chunk = getattr(cfg, "moe_token_chunk", 16384) or T_all
    if T_all > chunk:
        c = chunk
        while T_all % c:
            c -= 1
        nc = T_all // c

        def body(carry, xc):
            out, aux = _moe_apply_flat(p, xc, cfg)
            return None, (out, aux)

        _, (outs, auxes) = jax.lax.scan(
            body, None, xt_all.reshape(nc, c, d))
        return outs.reshape(orig_shape), jnp.mean(auxes)
    out, aux = _moe_apply_flat(p, xt_all, cfg)
    return out.reshape(orig_shape), aux


def _moe_apply_flat(p, xt, cfg):
    """One dispatch round over xt: (T, d) -> ((T, d), aux)."""
    d = xt.shape[-1]
    T = xt.shape[0]
    k = cfg.experts_per_token
    E = cfg.num_experts
    C = max(int(T * k * cfg.capacity_factor / E), 4)

    logits = xt.astype(jnp.float32) @ p["router"]
    gates, ids, probs = router_topk(logits, k)
    aux = load_balance_loss(probs, ids, E) * cfg.router_aux_weight

    tok_of_slot, valid = _dispatch_indices(ids, E, C)      # (E*C,)
    src_token = jnp.where(valid, tok_of_slot // k, 0)
    gate_of_slot = jnp.where(
        valid, gates.reshape(-1)[jnp.clip(tok_of_slot, 0)], 0.0)

    xe = xt[src_token].reshape(E, C, d)                    # gather -> (E,C,d)
    xe = xe * valid.reshape(E, C, 1).astype(xe.dtype)
    h = nn.gated_act(cfg.activation if cfg.activation != "gelu" else "swiglu",
                     jnp.einsum("ecd,edf->ecf", xe, p["we_gate"]),
                     jnp.einsum("ecd,edf->ecf", xe, p["we_up"]))
    ye = jnp.einsum("ecf,efd->ecd", h, p["we_down"])        # (E,C,d)
    ye = (ye.reshape(E * C, d) * gate_of_slot[:, None].astype(ye.dtype))
    out = jnp.zeros((T, d), ye.dtype).at[src_token].add(
        jnp.where(valid[:, None], ye, 0))

    if cfg.num_shared_experts:
        out = out + nn.mlp_apply(p["shared"], xt, "swiglu")
    return out, aux


def moe_apply_dense_reference(p, x, cfg):
    """Oracle: every expert on every token, weighted by (top-k) gates.
    Exact when capacity is unbounded; used by tests only."""
    orig_shape = x.shape
    xt = x.reshape(-1, orig_shape[-1])
    logits = xt.astype(jnp.float32) @ p["router"]
    gates, ids, _ = router_topk(logits, cfg.experts_per_token)
    full_gates = jnp.zeros((xt.shape[0], cfg.num_experts), jnp.float32)
    full_gates = jax.vmap(lambda g, i, r: r.at[i].set(g))(gates, ids, full_gates)
    h = nn.gated_act("swiglu",
                     jnp.einsum("td,edf->tef", xt, p["we_gate"]),
                     jnp.einsum("td,edf->tef", xt, p["we_up"]))
    ye = jnp.einsum("tef,efd->ted", h, p["we_down"])
    out = jnp.einsum("ted,te->td", ye, full_gates.astype(ye.dtype))
    if cfg.num_shared_experts:
        out = out + nn.mlp_apply(p["shared"], xt, "swiglu")
    return out.reshape(orig_shape)
