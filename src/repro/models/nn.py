"""Minimal pure-pytree module primitives (no flax in this environment).

Params are nested dicts of jnp arrays.  ``*_init`` builds params,
matching ``apply``-style functions consume them.  All functions are
jit/scan/vmap-safe and dtype-explicit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(name):
    return jnp.dtype(name)


def dense_init(key, in_dim: int, out_dim: int, dtype="float32", scale: float | None = None):
    """Lecun-normal dense kernel (no bias); shape (in, out)."""
    s = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * s).astype(dtype)


def bias_init(out_dim: int, dtype="float32"):
    return jnp.zeros((out_dim,), dtype=dtype)


def embedding_init(key, vocab: int, d: int, dtype="float32"):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)


def rmsnorm_init(d: int, dtype="float32"):
    return jnp.ones((d,), dtype=dtype)


def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype="float32"):
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(x, p, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------- RoPE ----

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta))           # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs        # (..., s, hd/2)
    angles = angles[..., None, :]                                    # (..., s, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------- activations ----

def gated_act(kind: str, gate, up):
    if kind == "swiglu":
        return jax.nn.silu(gate) * up
    if kind == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    raise ValueError(kind)


# ------------------------------------------------------------- MLP ----

def mlp_init(key, d_model: int, d_ff: int, activation: str, dtype="float32"):
    ks = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
            "w_up": dense_init(ks[1], d_model, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d_model, dtype),
        }
    return {  # plain gelu MLP (whisper)
        "w_up": dense_init(ks[0], d_model, d_ff, dtype),
        "b_up": bias_init(d_ff, dtype),
        "w_down": dense_init(ks[1], d_ff, d_model, dtype),
        "b_down": bias_init(d_model, dtype),
    }


def mlp_apply(p, x, activation: str):
    if activation in ("swiglu", "geglu"):
        h = gated_act(activation, x @ p["w_gate"], x @ p["w_up"])
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"] + p["b_up"], approximate=True)
    return h @ p["w_down"] + p["b_down"]


def cross_entropy(logits, labels, z_loss: float = 1e-4):
    """Mean token CE with optional z-loss; logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return jnp.mean(loss)


def chunked_cross_entropy_head(x, w_head, labels, mask=None, *,
                               chunk: int = 2048, z_loss: float = 1e-4,
                               vocab_real: int = 0):
    """Fused head-projection + CE, scanned over *sequence* chunks.

    The full-vocab logits buffer ((tokens, V) fp32) dominates training
    temp memory at 32k-256k vocabs; chunking bounds it to (b, chunk, V)
    and ``jax.checkpoint`` re-materializes each chunk's logits in the
    backward instead of keeping them alive.  Chunking along the sequence
    dim (not flattened tokens) keeps the batch dim — and its (pod, data)
    sharding — intact, so GSPMD never reshards the activations.

    x: (b, s, d); labels: (b, s); mask: (b, s) float/bool or None.
    Returns mean CE over masked tokens.
    """
    b, s, d = x.shape
    c = min(chunk, s)
    while s % c:
        c -= 1
    nc = s // c
    xc = jnp.moveaxis(x.reshape(b, nc, c, d), 1, 0)          # (nc,b,c,d)
    lc = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)        # (nc,b,c)
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    mc = jnp.moveaxis(mask.astype(jnp.float32).reshape(b, nc, c), 1, 0)

    @jax.checkpoint
    def one(xb, lb, mb):
        logits = (xb @ w_head).astype(jnp.float32)           # (b,c,V)
        if vocab_real and vocab_real < logits.shape[-1]:
            pad_mask = jnp.arange(logits.shape[-1]) < vocab_real
            logits = jnp.where(pad_mask, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        loss = lse - ll
        if z_loss:
            loss = loss + z_loss * jnp.square(lse)
        return jnp.sum(loss * mb)

    def body(acc, inp):
        xb, lb, mb = inp
        return acc + one(xb, lb, mb), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc, mc))
    return total / jnp.maximum(jnp.sum(mask), 1.0)
