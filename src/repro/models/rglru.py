"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The linear recurrence h_t = a_t * h_{t-1} + b_t is evaluated with
``jax.lax.associative_scan`` (parallel prefix) for train/prefill — TPU-
friendly log-depth — and as an O(1) update at decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import nn

_C = 8.0  # Griffin's fixed recurrence-sharpness constant


def _block_diag_init(key, width: int, num_blocks: int, dtype):
    bw = width // num_blocks
    k1, k2 = jax.random.split(key)
    return {
        "w": (jax.random.normal(k1, (num_blocks, bw, bw), jnp.float32)
              / jnp.sqrt(bw)).astype(dtype),
        "b": jnp.zeros((num_blocks, bw), dtype),
    }


def _block_diag_apply(p, x):
    nb, bw, _ = p["w"].shape
    xb = x.reshape(*x.shape[:-1], nb, bw)
    return (jnp.einsum("...ni,nio->...no", xb, p["w"]) + p["b"]).reshape(x.shape)


def rglru_init(key, cfg, dtype="float32"):
    ks = jax.random.split(key, 6)
    d, w = cfg.d_model, cfg.lru_width
    nb = cfg.num_heads
    # Lambda init so that a = sigmoid(L)^c lands in [0.9, 0.999]
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1 / _C) / (1 - u ** (1 / _C)))
    return {
        "w_x": nn.dense_init(ks[1], d, w, dtype),          # recurrent branch
        "w_gate_branch": nn.dense_init(ks[2], d, w, dtype),  # gelu branch
        "conv_w": (jax.random.normal(ks[3], (4, w), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "rg": _block_diag_init(ks[4], w, nb, dtype),       # recurrence gate
        "ig": _block_diag_init(ks[5], w, nb, dtype),       # input gate
        "lambda": lam,
        "w_out": nn.dense_init(jax.random.fold_in(key, 7), w, d, dtype),
    }


def _causal_conv(x, w, b):
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    return sum(pad[:, i: i + x.shape[1], :] * w[i] for i in range(width)) + b


def _rglru_core(p, x, h0=None):
    """x: (b,l,w) post-conv recurrent-branch input -> (y, h_last)."""
    r = jax.nn.sigmoid(_block_diag_apply(p["rg"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag_apply(p["ig"], x).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(p["lambda"])          # (b,l,w) <= 0
    a = jnp.exp(log_a)
    gated_x = i * x.astype(jnp.float32)
    b_t = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x
    if h0 is not None:
        # fold h0 in as a virtual first step: handled by caller at decode
        pass

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b_t), axis=1)
    if h0 is not None:
        hh = hh + aa * h0[:, None, :]
    return hh.astype(x.dtype), hh[:, -1].astype(x.dtype)


def rglru_block_apply(p, x, cfg, *, h0=None, conv_state=None,
                      return_state: bool = False):
    """Full Griffin recurrent block (train / prefill)."""
    rec = x @ p["w_x"]
    rec = _causal_conv(rec, p["conv_w"], p["conv_b"])
    y, h_last = _rglru_core(p, rec, h0=h0)
    gate = jax.nn.gelu(x @ p["w_gate_branch"], approximate=True)
    out = (y * gate) @ p["w_out"]
    if return_state:
        return out, h_last
    return out


def rglru_init_cache(cfg, batch: int, dtype):
    return {
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        "conv": jnp.zeros((batch, 3, cfg.lru_width), dtype),
    }


def rglru_decode_step(p, x, cache, cfg):
    """x: (b,1,d) -> (out (b,1,d), new cache)."""
    b = x.shape[0]
    rec_new = x[:, 0] @ p["w_x"]                            # (b,w)
    win = jnp.concatenate([cache["conv"], rec_new[:, None]], axis=1)
    rec = jnp.einsum("bwc,wc->bc", win, p["conv_w"]) + p["conv_b"]
    r = jax.nn.sigmoid(_block_diag_apply(p["rg"], rec).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag_apply(p["ig"], rec).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(p["lambda"])
    a = jnp.exp(log_a)
    b_t = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * rec.astype(jnp.float32))
    h = a * cache["h"] + b_t
    gate = jax.nn.gelu(x[:, 0] @ p["w_gate_branch"], approximate=True)
    out = ((h.astype(x.dtype) * gate) @ p["w_out"])[:, None]
    return out, {"h": h, "conv": jnp.concatenate([cache["conv"][:, 1:], rec_new[:, None]], axis=1)}
