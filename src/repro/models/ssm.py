"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Train/prefill uses the chunked SSD algorithm: intra-chunk quadratic
(attention-like with a decay mask) + inter-chunk linear recurrence over
chunk states via ``lax.scan`` — O(L·Q) compute, O(1) HLO in depth/length.
Decode is the O(1) recurrent update on a cached (heads, head_dim, state)
tensor; there is no KV cache, so ICQ-KV is inapplicable (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import nn


def ssm_dims(cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    conv_dim = d_in + 2 * cfg.ssm_state
    return d_in, nheads, conv_dim


def ssm_init(key, cfg, dtype="float32"):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    d_in, nheads, conv_dim = ssm_dims(cfg)
    n = cfg.ssm_state
    return {
        # fused in-proj: [z (d_in), x (d_in), B (n), C (n), dt (nheads)]
        "w_in": nn.dense_init(ks[0], d, 2 * d_in + 2 * n + nheads, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_dim),
                                     dtype=jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "D": jnp.ones((nheads,), dtype),
        "norm": nn.rmsnorm_init(d_in, dtype),
        "w_out": nn.dense_init(ks[2], d_in, d, dtype),
    }


def _causal_conv(x, w, b):
    """Per-channel causal conv1d.  x:(b,l,c), w:(width,c)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + x.shape[1], :] * w[i] for i in range(width))
    return out + b


def _segsum(dA):
    """Stable 'segment sum' for the intra-chunk decay mask.
    dA: (..., cl) -> (..., cl, cl) lower-tri cumulative sums."""
    cl = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]              # sum_{k+1..q}
    mask = jnp.tril(jnp.ones((cl, cl), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, D, chunk: int, h0=None):
    """SSD scan.  x:(b,l,h,p) dt:(b,l,h) A:(h,) B,C:(b,l,n) D:(h,).
    Returns (y:(b,l,h,p), final state:(b,h,p,n))."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, l)
    while l % Q:            # ragged lengths: largest divisor <= chunk
        Q -= 1
    nc = l // Q
    xr = x.reshape(b, nc, Q, h, p)
    dtr = dt.reshape(b, nc, Q, h)
    Br = B.reshape(b, nc, Q, n)
    Cr = C.reshape(b, nc, Q, n)
    dA = dtr * A                                            # (b,nc,Q,h) <= 0
    dAh = jnp.moveaxis(dA, -1, -2)                          # (b,nc,h,Q)
    xdt = xr * dtr[..., None]                               # dt-weighted input

    # ---- intra-chunk (quadratic within chunk, like masked attention) ----
    Lmask = jnp.exp(_segsum(dAh))                           # (b,nc,h,Q,Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", Cr, Br)          # (b,nc,Q,Q)
    y_intra = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", scores, Lmask,
                         jnp.moveaxis(xdt, 3, 3))
    # note: xdt is (b,nc,Q,h,p); einsum treats axes (b,c,k,h,p)

    # ---- chunk states:  S_c = sum_k exp(cum_last - cum_k) B_k x_k^T ----
    cum = jnp.cumsum(dAh, axis=-1)                          # (b,nc,h,Q)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)             # (b,nc,h,Q)
    S = jnp.einsum("bchq,bcqn,bcqhp->bchpn", decay_to_end, Br, xdt)

    # ---- inter-chunk recurrence over chunk states ----
    chunk_decay = jnp.exp(cum[..., -1])                     # (b,nc,h)

    def scan_fn(carry, inp):
        S_c, dec = inp                                      # (b,h,p,n),(b,h)
        prev = carry
        new = prev * dec[..., None, None] + S_c
        return new, prev                                    # emit state *entering* chunk

    init = h0 if h0 is not None else jnp.zeros((b, h, p, n), x.dtype)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(S, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)           # (b,nc,h,p,n)

    # ---- inter-chunk contribution ----
    state_decay = jnp.exp(cum)                              # decay from chunk start
    y_inter = jnp.einsum("bcqn,bchq,bchpn->bcqhp", Cr, state_decay, prev_states)

    y = (y_intra + y_inter).reshape(b, l, h, p) + x * D[None, None, :, None]
    return y, final


def ssm_block_apply(p, x, cfg, *, h0=None, return_state=False):
    """Full Mamba-2 block: in-proj, conv, SSD, gated norm, out-proj."""
    b, l, _ = x.shape
    d_in, nheads, conv_dim = ssm_dims(cfg)
    n = cfg.ssm_state
    zxbcdt = x @ p["w_in"]
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in: d_in + d_in + 2 * n]
    dt_raw = zxbcdt[..., -nheads:]
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :d_in].reshape(b, l, nheads, cfg.ssm_head_dim)
    B = xbc[..., d_in: d_in + n]
    C = xbc[..., d_in + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, hT = ssd_chunked(xs, dt.astype(xs.dtype), A.astype(xs.dtype), B, C,
                        p["D"], cfg.ssm_chunk, h0=h0)
    y = y.reshape(b, l, d_in)
    y = nn.rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["w_out"]
    if return_state:
        return out, hT
    return out


def ssm_init_cache(cfg, batch: int, dtype):
    d_in, nheads, conv_dim = ssm_dims(cfg)
    return {
        "state": jnp.zeros((batch, nheads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }


def ssm_decode_step(p, x, cache, cfg):
    """One-token recurrent update.  x: (b,1,d)."""
    b = x.shape[0]
    d_in, nheads, conv_dim = ssm_dims(cfg)
    n = cfg.ssm_state
    zxbcdt = x[:, 0] @ p["w_in"]
    z = zxbcdt[..., :d_in]
    xbc_new = zxbcdt[..., d_in: d_in + d_in + 2 * n]
    dt_raw = zxbcdt[..., -nheads:]
    # conv over cached window + current
    win = jnp.concatenate([cache["conv"], xbc_new[:, None, :]], axis=1)
    w = p["conv_w"]
    xbc = jax.nn.silu(jnp.einsum("bwc,wc->bc", win, w) + p["conv_b"])
    xs = xbc[..., :d_in].reshape(b, nheads, cfg.ssm_head_dim)
    B = xbc[..., d_in: d_in + n]
    C = xbc[..., d_in + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A).astype(xs.dtype)                   # (b,h)
    upd = jnp.einsum("bhp,bn->bhpn", xs * dt[..., None].astype(xs.dtype), B)
    state = cache["state"] * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, C) + xs * p["D"][None, :, None]
    y = y.reshape(b, d_in)
    y = nn.rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["w_out"])[:, None, :]
    new_cache = {"state": state,
                 "conv": jnp.concatenate([cache["conv"][:, 1:], xbc_new[:, None]], axis=1)}
    return out, new_cache
