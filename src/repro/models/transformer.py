"""Unified model assembly for all assigned architectures.

One scaffold covers: dense decoder LMs (gemma/llama/tinyllama/granite),
MoE (+first-k-dense) stacks (moonshot), MLA+MoE (deepseek-v2), SSM
(mamba2), hybrid RG-LRU/local-attention groups (recurrentgemma), the
Whisper encoder-decoder, and the InternVL vision-stub VLM.

Layers are *stacked* (params carry a leading layer axis) and applied with
``lax.scan`` so HLO size is O(1) in depth — required to compile
llama3-405b's 126 layers on the CPU dry-run host.  ``cfg.remat`` wraps the
scan body in ``jax.checkpoint`` for training.

Three entry points per model (built by ``build_model``):
  train_forward(params, batch)          -> (loss, aux)
  prefill(params, batch)                -> (last-token logits, cache)
  decode_step(params, tokens, cache)    -> (logits, new cache)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import nn
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod


# =================================================================
# per-layer init / apply, switched on `kind`
# =================================================================

def _norm_init(cfg, dtype):
    if cfg.norm_type == "layernorm":
        return nn.layernorm_init(cfg.d_model, dtype)
    return nn.rmsnorm_init(cfg.d_model, dtype)


def _norm_apply(cfg, p, x):
    if cfg.norm_type == "layernorm":
        return nn.layernorm(x, p, cfg.norm_eps)
    return nn.rmsnorm(x, p, cfg.norm_eps)


def layer_init(key, cfg, dtype, kind: str):
    """kind: dense | moe | mla_moe | mla_dense | ssm | rglru | local | enc | dec"""
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {}
    if kind == "ssm":
        p["norm1"] = _norm_init(cfg, dtype)
        p["mixer"] = ssm_mod.ssm_init(ks[0], cfg, dtype)
        return p
    p["norm1"] = _norm_init(cfg, dtype)
    p["norm2"] = _norm_init(cfg, dtype)
    if kind in ("dense", "moe", "local", "enc", "dec"):
        p["attn"] = attn.attn_init(ks[0], cfg, dtype)
    elif kind in ("mla_moe", "mla_dense"):
        p["attn"] = mla_mod.mla_init(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = rglru_mod.rglru_init(ks[0], cfg, dtype)
    if kind == "dec":
        p["norm_cross"] = _norm_init(cfg, dtype)
        p["cross"] = attn.cross_attn_init(ks[1], cfg, dtype)
    if kind in ("moe", "mla_moe"):
        p["ffn"] = moe_mod.moe_init(ks[2], cfg, dtype)
    elif kind in ("mla_dense",):
        p["ffn"] = nn.mlp_init(ks[2], cfg.d_model, cfg.dense_d_ff or cfg.d_ff,
                               cfg.activation, dtype)
    elif kind == "dense_first":
        p["attn"] = (mla_mod.mla_init(ks[0], cfg, dtype) if cfg.mla
                     else attn.attn_init(ks[0], cfg, dtype))
        p["ffn"] = nn.mlp_init(ks[2], cfg.d_model, cfg.dense_d_ff or cfg.d_ff,
                               cfg.activation, dtype)
    else:
        p["ffn"] = nn.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.activation, dtype)
    return p


def layer_apply(p, x, cfg, positions, kind: str, *, enc_out=None,
                attn_impl="chunked"):
    """Full-sequence layer (train / prefill compute).  Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        return x + ssm_mod.ssm_block_apply(p["mixer"], _norm_apply(cfg, p["norm1"], x), cfg), aux
    h = _norm_apply(cfg, p["norm1"], x)
    if kind == "rglru":
        x = x + rglru_mod.rglru_block_apply(p["mixer"], h, cfg)
    elif kind == "local":
        x = x + attn.attention_apply(p["attn"], h, cfg, positions, causal=True,
                                     window=cfg.local_window, impl=attn_impl)
    elif kind in ("mla_moe", "mla_dense", "mla_first"):
        x = x + mla_mod.mla_attention_apply(p["attn"], h, cfg, positions)
    elif kind == "enc":
        # full (non-chunked) encoder attention: measured better than the
        # chunked variant at 1500 frames (padding to 2048 + scan overhead
        # outweigh the avoided S^2 tensor; EXPERIMENTS.md §Perf, refuted)
        x = x + attn.attention_apply(p["attn"], h, cfg, positions, causal=False,
                                     impl="full", rope=not cfg.learned_pos_emb)
    else:
        x = x + attn.attention_apply(p["attn"], h, cfg, positions, causal=True,
                                     impl=attn_impl, rope=not cfg.learned_pos_emb)
    if kind == "dec":
        x = x + attn.cross_attention_apply(
            p["cross"], _norm_apply(cfg, p["norm_cross"], x), enc_out, cfg)
    h2 = _norm_apply(cfg, p["norm2"], x)
    if kind in ("moe", "mla_moe"):
        y, aux = moe_mod.moe_apply(p["ffn"], h2, cfg)
        x = x + y
    else:
        x = x + nn.mlp_apply(p["ffn"], h2, cfg.activation)
    return x, aux


# ------------------------------------------------------------ caches ----

def layer_init_cache(cfg, kind: str, batch: int, max_len: int, dtype):
    if kind == "ssm":
        return ssm_mod.ssm_init_cache(cfg, batch, dtype)
    if kind == "rglru":
        return rglru_mod.rglru_init_cache(cfg, batch, dtype)
    if kind in ("mla_moe", "mla_dense"):
        return {
            "latent": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
        }
    S = min(max_len, cfg.local_window) if kind == "local" else max_len
    c = {
        "k": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.head_dim), dtype),
    }
    if kind == "local":
        c["k_pos"] = jnp.full((batch, S), -1, jnp.int32)
    if kind == "dec":
        c["ck"] = jnp.zeros((batch, cfg.encoder_seq_len, cfg.num_kv_heads, cfg.head_dim), dtype)
        c["cv"] = jnp.zeros((batch, cfg.encoder_seq_len, cfg.num_kv_heads, cfg.head_dim), dtype)
    return c


def layer_prefill(p, x, cfg, positions, kind: str, max_len: int, *,
                  enc_out=None, attn_impl="chunked"):
    """Layer fwd that also emits its decode cache.  Returns (x, cache)."""
    b, s, _ = x.shape
    dtype = x.dtype
    if kind == "ssm":
        h = _norm_apply(cfg, p["norm1"], x)
        out, state = ssm_mod.ssm_block_apply(p["mixer"], h, cfg, return_state=True)
        cache = ssm_mod.ssm_init_cache(cfg, b, dtype)
        cache["state"] = state
        # conv tail: reconstruct last (width-1) pre-conv activations
        zx = h @ p["mixer"]["w_in"]
        d_in, _, _ = ssm_mod.ssm_dims(cfg)
        xbc = zx[..., d_in: 2 * d_in + 2 * cfg.ssm_state]
        cache["conv"] = xbc[:, -(cfg.ssm_conv_width - 1):, :]
        return x + out, cache
    if kind == "rglru":
        h = _norm_apply(cfg, p["norm1"], x)
        mixed, h_last = rglru_mod.rglru_block_apply(p["mixer"], h, cfg, return_state=True)
        rec = h @ p["mixer"]["w_x"]
        cache = {"h": h_last.astype(jnp.float32), "conv": rec[:, -3:, :]}
        out = x + mixed
        h2 = _norm_apply(cfg, p["norm2"], out)
        out = out + nn.mlp_apply(p["ffn"], h2, cfg.activation)
        return out, cache

    h = _norm_apply(cfg, p["norm1"], x)
    if kind in ("mla_moe", "mla_dense"):
        latent, k_rope = mla_mod.mla_prefill_latent(p["attn"], h, cfg, positions)
        cache = {"latent": _pad_to(latent, max_len, 1),
                 "k_rope": _pad_to(k_rope, max_len, 1)}
        x = x + mla_mod.mla_attention_apply(p["attn"], h, cfg, positions)
    else:
        q, k, v = attn.qkv_project(p["attn"], h, cfg, positions,
                                   rope=not cfg.learned_pos_emb)
        if kind == "local":
            o = attn.chunked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk,
                                       window=cfg.local_window)
            # ring buffer: token at absolute pos i lives in slot i % W, so
            # subsequent decode writes at (pos % W) stay consistent.
            W = min(max_len, cfg.local_window)
            t = min(s, W)
            slots = (jnp.arange(s - t, s) % W)              # static values
            kbuf = jnp.zeros((b, W) + k.shape[2:], k.dtype).at[:, slots].set(k[:, -t:])
            vbuf = jnp.zeros((b, W) + v.shape[2:], v.dtype).at[:, slots].set(v[:, -t:])
            pbuf = jnp.full((b, W), -1, jnp.int32).at[:, slots].set(
                jnp.broadcast_to(positions[-t:][None], (b, t)).astype(jnp.int32))
            cache = {"k": kbuf, "v": vbuf, "k_pos": pbuf}
        else:
            if s <= cfg.attn_chunk:
                o = attn.full_attention(q, k, v, causal=True)
            elif attn_impl == "triangular":
                o = attn.triangular_chunked_attention(q, k, v,
                                                      chunk=cfg.attn_chunk)
            else:
                o = attn.chunked_attention(q, k, v, causal=True,
                                           chunk=cfg.attn_chunk)
            cache = {"k": _pad_to(k, max_len, 1), "v": _pad_to(v, max_len, 1)}
        x = x + o.reshape(b, s, cfg.num_heads * cfg.head_dim) @ p["attn"]["wo"]
    if kind == "dec":
        ck = enc_out @ p["cross"]["wk"]
        cv = enc_out @ p["cross"]["wv"]
        cache["ck"] = ck.reshape(b, -1, cfg.num_kv_heads, cfg.head_dim)
        cache["cv"] = cv.reshape(b, -1, cfg.num_kv_heads, cfg.head_dim)
        x = x + attn.cross_attention_apply(
            p["cross"], _norm_apply(cfg, p["norm_cross"], x), enc_out, cfg)
    h2 = _norm_apply(cfg, p["norm2"], x)
    if kind in ("moe", "mla_moe"):
        y, _ = moe_mod.moe_apply(p["ffn"], h2, cfg)
        x = x + y
    else:
        x = x + nn.mlp_apply(p["ffn"], h2, cfg.activation)
    return x, cache


def layer_decode(p, x, cfg, cache, pos, kind: str):
    """One-token layer step.  x: (b,1,d); pos: scalar int32 (write index)."""
    aux = None
    if kind == "ssm":
        h = _norm_apply(cfg, p["norm1"], x)
        out, new_cache = ssm_mod.ssm_decode_step(p["mixer"], h, cache, cfg)
        return x + out, new_cache
    h = _norm_apply(cfg, p["norm1"], x)
    if kind == "rglru":
        mixed, new_cache = rglru_mod.rglru_decode_step(p["mixer"], h, cache, cfg)
        x = x + mixed
        h2 = _norm_apply(cfg, p["norm2"], x)
        x = x + nn.mlp_apply(p["ffn"], h2, cfg.activation)
        return x, new_cache
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    if kind in ("mla_moe", "mla_dense"):
        latent, k_rope = mla_mod.mla_prefill_latent(p["attn"], h, cfg, positions)
        lat_c = jax.lax.dynamic_update_slice(cache["latent"], latent.astype(cache["latent"].dtype), (0, pos, 0))
        kr_c = jax.lax.dynamic_update_slice(cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), (0, pos, 0))
        new_cache = {"latent": lat_c, "k_rope": kr_c}
        S = lat_c.shape[1]
        mask = jnp.arange(S)[None, :] <= pos
        x = x + mla_mod.mla_decode_attention(p["attn"], h, lat_c, kr_c, cfg,
                                             positions, mask)
    else:
        q, k, v = attn.qkv_project(p["attn"], h, cfg, positions,
                                   rope=not cfg.learned_pos_emb)
        if kind == "local":
            W = cache["k"].shape[1]
            slot = pos % W
            kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
            kp = jax.lax.dynamic_update_slice(
                cache["k_pos"], jnp.full((b, 1), pos, jnp.int32), (0, slot))
            new_cache = {"k": kc, "v": vc, "k_pos": kp}
            mask = (kp >= 0) & (kp > pos - cfg.local_window) & (kp <= pos)
        else:
            kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
            new_cache = dict(cache, k=kc, v=vc)
            S = kc.shape[1]
            mask = jnp.broadcast_to(jnp.arange(S)[None, :] <= pos, (b, S))
        o = attn.decode_attention(q, kc, vc, mask)
        x = x + o.reshape(b, 1, cfg.num_heads * cfg.head_dim) @ p["attn"]["wo"]
    if kind == "dec":
        x = x + attn.cross_attention_decode(
            p["cross"], _norm_apply(cfg, p["norm_cross"], x), cache["ck"], cache["cv"], cfg)
        new_cache["ck"], new_cache["cv"] = cache["ck"], cache["cv"]
    h2 = _norm_apply(cfg, p["norm2"], x)
    if kind in ("moe", "mla_moe"):
        y, _ = moe_mod.moe_apply(p["ffn"], h2, cfg)
        x = x + y
    else:
        x = x + nn.mlp_apply(p["ffn"], h2, cfg.activation)
    return x, new_cache


def _pad_to(x, target: int, axis: int):
    pad = target - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# =================================================================
# stacks
# =================================================================

def _stacked_init(key, cfg, dtype, kind: str, n: int):
    return jax.vmap(lambda k: layer_init(k, cfg, dtype, kind))(jax.random.split(key, n))


def _act_constraint(x, cfg, mesh):
    """Pin the residual-stream sharding between layers.

    Batch ALWAYS shards over (pod, data): without the constraint GSPMD
    happily propagates the embedding table's d-over-data spec into the
    activations and replicates batch — catastrophic for activation
    memory (observed: 40 GB/dev on tinyllama before this pin).

    With ``cfg.seq_shard_acts`` additionally shard the *sequence* dim
    over 'model' (Megatron sequence parallelism): bounds the remat-saved
    layer inputs to 1/TP; GSPMD inserts the all-gather at the attention
    boundary.  No-op when no mesh is threaded or dims don't divide."""
    if mesh is None or x.ndim != 3:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed import sharding as shrules
    ba = shrules.batch_axes(mesh)
    baxis = ba if len(ba) > 1 else ba[0]
    b, s, _ = x.shape
    seq = (shrules.maybe("model", s, mesh) if cfg.seq_shard_acts else None)
    spec = P(shrules.maybe(baxis, b, mesh), seq, None)
    phys = getattr(mesh, "base", mesh)     # MeshView -> physical mesh
    return jax.lax.with_sharding_constraint(x, NamedSharding(phys, spec))


def _scan_layers(params_stacked, x, cfg, positions, kind, *, enc_out=None,
                 attn_impl="chunked", mesh=None):
    def body(carry, lp):
        h, aux = carry
        h, a = layer_apply(lp, h, cfg, positions, kind, enc_out=enc_out,
                           attn_impl=attn_impl)
        h = _act_constraint(h, cfg, mesh)
        return (h, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x = _act_constraint(x, cfg, mesh)
    carry0 = (x, jnp.zeros((), jnp.float32))

    G = getattr(cfg, "remat_block", 0)
    L = jax.tree.leaves(params_stacked)[0].shape[0]
    if cfg.remat and G and L % G == 0 and L // G > 1:
        # two-level (sqrt-L) remat: outer scan over L/G blocks saves one
        # carry per *block*; the inner per-layer checkpoints are
        # re-materialized during the block's backward.  Saved residuals
        # drop from L x act to (L/G + G) x act — required to fit the
        # 126-layer llama3-405b (DESIGN.md §5.5).
        blocked = jax.tree.map(
            lambda p: p.reshape((L // G, G) + p.shape[1:]), params_stacked)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def block_body(carry, bp):
            out, _ = jax.lax.scan(body, carry, bp)
            return out, None

        (x, aux), _ = jax.lax.scan(block_body, carry0, blocked)
        return x, aux

    (x, aux), _ = jax.lax.scan(body, carry0, params_stacked)
    return x, aux


def _scan_prefill(params_stacked, x, cfg, positions, kind, max_len, *,
                  enc_out=None, attn_impl="chunked", mesh=None):
    def body(h, lp):
        h, cache = layer_prefill(lp, h, cfg, positions, kind, max_len,
                                 enc_out=enc_out, attn_impl=attn_impl)
        return _act_constraint(h, cfg, mesh), cache
    return jax.lax.scan(body, _act_constraint(x, cfg, mesh), params_stacked)


def _scan_decode(params_stacked, x, cfg, caches_stacked, pos, kind):
    """Decode layer scan with the stacked caches as the scan CARRY.

    As scan xs/ys the caches double-buffer (ys are fresh allocations —
    +8.6 GB/device on llama3 decode_32k); while-loop carries update in
    place, and jit-level donation of the cache argument reuses the input
    buffer for the carry, so the cache exists exactly once.
    """
    L = jax.tree.leaves(params_stacked)[0].shape[0]

    def body(carry, inp):
        h, caches = carry
        li, lp = inp
        c = jax.tree.map(
            lambda buf: jax.lax.dynamic_index_in_dim(buf, li, 0,
                                                     keepdims=False), caches)
        h, nc = layer_decode(lp, h, cfg, c, pos, kind)
        caches = jax.tree.map(
            lambda buf, n: jax.lax.dynamic_update_index_in_dim(
                buf, n.astype(buf.dtype), li, 0), caches, nc)
        return (h, caches), None

    (x, caches), _ = jax.lax.scan(
        body, (x, caches_stacked),
        (jnp.arange(L, dtype=jnp.int32), params_stacked))
    return x, caches


# =================================================================
# model builder
# =================================================================

@dataclasses.dataclass
class ModelFns:
    cfg: Any
    init: Any
    train_forward: Any
    prefill: Any
    decode_step: Any
    init_cache: Any


def _layer_plan(cfg):
    """Returns list of (kind, count) segments, in order."""
    if cfg.ssm:
        return [("ssm", cfg.num_layers)]
    if cfg.hybrid:
        return [("hybrid", cfg.num_layers)]          # handled specially
    if cfg.encdec:
        return [("dec", cfg.num_layers)]             # encoder handled separately
    if cfg.num_experts:
        kind = "mla_moe" if cfg.mla else "moe"
        segs = []
        if cfg.first_k_dense:
            segs.append(("mla_dense" if cfg.mla else "dense_first", cfg.first_k_dense))
        segs.append((kind, cfg.num_layers - cfg.first_k_dense))
        return segs
    if cfg.mla:
        return [("mla_dense", cfg.num_layers)]
    return [("dense", cfg.num_layers)]


def build_model(cfg, *, attn_impl: str = "chunked", mesh=None) -> ModelFns:
    dtype = cfg.param_dtype
    emb_scale = float(cfg.d_model) ** 0.5 if cfg.tie_embeddings else 1.0

    hybrid_pattern = cfg.block_pattern if cfg.hybrid else ()
    gs = len(hybrid_pattern) or 1
    n_groups = cfg.num_layers // gs if cfg.hybrid else 0
    tail = tuple(hybrid_pattern[: cfg.num_layers % gs]) if cfg.hybrid else ()

    # -------------------------------------------------------- init ----
    def init(key):
        ks = jax.random.split(key, 12)
        params: Dict[str, Any] = {
            # padded vocab rows so the vocab dim shards over "model" even
            # for indivisible tokenizer sizes; logits are masked/sliced
            # back to the true vocab everywhere they surface.
            "embed": nn.embedding_init(ks[0], cfg.padded_vocab, cfg.d_model,
                                       dtype),
            "final_norm": _norm_init(cfg, dtype),
        }
        if not cfg.tie_embeddings:
            params["head"] = nn.dense_init(ks[1], cfg.d_model,
                                           cfg.padded_vocab, dtype)
        if cfg.frontend == "vision_stub":
            params["vis_proj"] = nn.dense_init(ks[2], cfg.vision_dim, cfg.d_model, dtype)
        if cfg.encdec:
            params["enc_layers"] = _stacked_init(ks[3], cfg, dtype, "enc", cfg.encoder_layers)
            params["enc_norm"] = _norm_init(cfg, dtype)
            if cfg.learned_pos_emb:
                params["enc_pos"] = nn.embedding_init(ks[4], cfg.encoder_seq_len, cfg.d_model, dtype)
        if cfg.learned_pos_emb:
            params["dec_pos"] = nn.embedding_init(ks[5], _max_pos(cfg), cfg.d_model, dtype)
        if cfg.hybrid:
            group = {}
            for i, k in enumerate(hybrid_pattern):
                group[f"b{i}"] = _stacked_init(jax.random.fold_in(ks[6], i), cfg, dtype, k, n_groups)
            params["groups"] = group
            for i, k in enumerate(tail):
                params[f"tail{i}"] = layer_init(jax.random.fold_in(ks[7], i), cfg, dtype, k)
        else:
            for si, (kind, n) in enumerate(_layer_plan(cfg)):
                params[f"seg{si}"] = _stacked_init(
                    jax.random.fold_in(ks[8], si), cfg, dtype, kind, n)
        return params

    # ------------------------------------------------- embedding ----
    def _embed_tokens(params, tokens):
        x = params["embed"][tokens].astype(cfg.compute_dtype)
        return x * jnp.asarray(emb_scale, x.dtype)

    def _inputs_train(params, batch):
        tokens = batch["tokens"]
        x = _embed_tokens(params, tokens)
        loss_mask = jnp.ones(tokens.shape, bool)
        if cfg.frontend == "vision_stub":
            vis = batch["patch_emb"].astype(cfg.compute_dtype) @ params["vis_proj"]
            x = jnp.concatenate([vis, x], axis=1)
            loss_mask = jnp.concatenate(
                [jnp.zeros(vis.shape[:2], bool), loss_mask], axis=1)
        if cfg.learned_pos_emb:
            x = x + params["dec_pos"][: x.shape[1]][None].astype(x.dtype)
        positions = jnp.arange(x.shape[1])
        return x, positions, loss_mask

    def _encode(params, batch):
        a = batch["audio_emb"].astype(cfg.compute_dtype)
        if cfg.learned_pos_emb:
            a = a + params["enc_pos"][: a.shape[1]][None].astype(a.dtype)
        pos = jnp.arange(a.shape[1])
        h, _ = _scan_layers(params["enc_layers"], a, cfg, pos, "enc")
        return _norm_apply(cfg, params["enc_norm"], h)

    def _logits(params, x):
        x = _norm_apply(cfg, params["final_norm"], x)
        logits = (x @ params["embed"].T.astype(x.dtype)
                  if cfg.tie_embeddings else x @ params["head"])
        return logits[..., : cfg.vocab_size]

    def _backbone_train(params, x, positions):
        aux = jnp.zeros((), jnp.float32)
        enc_out = None
        if cfg.encdec:
            return None  # handled in train_forward
        if cfg.hybrid:
            x, aux = _hybrid_apply(params, x, positions)
            return x, aux
        for si, (kind, n) in enumerate(_layer_plan(cfg)):
            x, a = _scan_layers(params[f"seg{si}"], x, cfg, positions,
                                kind, attn_impl=attn_impl, mesh=mesh)
            aux = aux + a
        return x, aux

    def _hybrid_apply(params, x, positions):
        def group_body(carry, gp):
            h, aux = carry
            for i, k in enumerate(hybrid_pattern):
                h, a = layer_apply(gp[f"b{i}"], h, cfg, positions, k,
                                   attn_impl=attn_impl)
                aux = aux + a
            return (_act_constraint(h, cfg, mesh), aux), None
        if cfg.remat:
            group_body = jax.checkpoint(group_body, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(group_body, (x, jnp.zeros((), jnp.float32)),
                                   params["groups"])
        for i, k in enumerate(tail):
            x, a = layer_apply(params[f"tail{i}"], x, cfg, positions, k,
                               attn_impl=attn_impl)
            aux = aux + a
        return x, aux

    # ----------------------------------------------------- train ----
    def train_forward(params, batch):
        if cfg.encdec:
            enc_out = _encode(params, batch)
            x, positions, loss_mask = _inputs_train(params, batch)
            x, aux = _scan_layers(params["seg0"], x, cfg, positions, "dec",
                                  enc_out=enc_out, attn_impl=attn_impl,
                                  mesh=mesh)
        else:
            x, positions, loss_mask = _inputs_train(params, batch)
            x, aux = _backbone_train(params, x, positions)
        labels = batch["labels"]
        if cfg.frontend == "vision_stub":
            # loss over text positions only
            x = x[:, cfg.num_vision_tokens:, :]
        x = _norm_apply(cfg, params["final_norm"], x)
        if cfg.ce_chunk:
            w = (params["embed"].T.astype(x.dtype) if cfg.tie_embeddings
                 else params["head"])
            # next-token shift without slicing (keeps seq length chunkable
            # and the batch sharding untouched): mask the final position.
            s = labels.shape[1]
            labels_next = jnp.concatenate(
                [labels[:, 1:], jnp.zeros_like(labels[:, :1])], axis=1)
            pos_mask = jnp.broadcast_to(
                (jnp.arange(s) < s - 1)[None, :], labels.shape)
            loss = nn.chunked_cross_entropy_head(
                x, w, labels_next, pos_mask, chunk=cfg.ce_chunk,
                vocab_real=cfg.vocab_size)
        else:
            logits = (x @ (params["embed"].T.astype(x.dtype)
                           if cfg.tie_embeddings else params["head"]))
            logits = logits[..., : cfg.vocab_size]
            loss = nn.cross_entropy(logits[:, :-1], labels[:, 1:])
        return loss + aux, {"ce": loss, "aux": aux}

    # --------------------------------------------------- serving ----
    def init_cache(batch_size: int, max_len: int, dtype_=None):
        dt = dtype_ or cfg.compute_dtype
        caches: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
        if cfg.hybrid:
            g = {}
            for i, k in enumerate(hybrid_pattern):
                one = layer_init_cache(cfg, k, batch_size, max_len, dt)
                g[f"b{i}"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape).copy(), one)
            caches["groups"] = g
            for i, k in enumerate(tail):
                caches[f"tail{i}"] = layer_init_cache(cfg, k, batch_size, max_len, dt)
            return caches
        for si, (kind, n) in enumerate(_layer_plan(cfg)):
            one = layer_init_cache(cfg, kind, batch_size, max_len, dt)
            caches[f"seg{si}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), one)
        return caches

    def prefill(params, batch, max_len: int):
        if cfg.encdec:
            enc_out = _encode(params, batch)
        else:
            enc_out = None
        x, positions, _ = _inputs_train(params, batch)
        caches: Dict[str, Any] = {"pos": jnp.asarray(x.shape[1], jnp.int32)}
        if cfg.hybrid:
            g = {}

            def gbody(h, gp):
                out_caches = {}
                for i, k in enumerate(hybrid_pattern):
                    h, c = layer_prefill(gp[f"b{i}"], h, cfg, positions, k, max_len,
                                         attn_impl=attn_impl)
                    out_caches[f"b{i}"] = c
                return h, out_caches
            x, g = jax.lax.scan(gbody, x, params["groups"])
            caches["groups"] = g
            for i, k in enumerate(tail):
                x, c = layer_prefill(params[f"tail{i}"], x, cfg, positions, k, max_len,
                                     attn_impl=attn_impl)
                caches[f"tail{i}"] = c
        else:
            for si, (kind, n) in enumerate(_layer_plan(cfg)):
                x, c = _scan_prefill(params[f"seg{si}"], x, cfg, positions,
                                     kind, max_len, enc_out=enc_out,
                                     attn_impl=attn_impl, mesh=mesh)
                caches[f"seg{si}"] = c
        logits = _logits(params, x[:, -1:, :])
        return logits, caches

    def decode_step(params, tokens, caches):
        """tokens: (b,1) int32. Returns (logits (b,1,V), new caches)."""
        pos = caches["pos"]
        x = _embed_tokens(params, tokens)
        if cfg.learned_pos_emb:
            x = x + params["dec_pos"][pos][None, None].astype(x.dtype)
        new_caches: Dict[str, Any] = {"pos": pos + 1}
        if cfg.hybrid:
            g = {}

            def gbody(h, inp):
                gp, gc = inp
                ncs = {}
                for i, k in enumerate(hybrid_pattern):
                    h, nc = layer_decode(gp[f"b{i}"], h, cfg, gc[f"b{i}"], pos, k)
                    ncs[f"b{i}"] = nc
                return h, ncs
            x, g = jax.lax.scan(gbody, x, (params["groups"], caches["groups"]))
            new_caches["groups"] = g
            for i, k in enumerate(tail):
                x, nc = layer_decode(params[f"tail{i}"], x, cfg, caches[f"tail{i}"], pos, k)
                new_caches[f"tail{i}"] = nc
        else:
            for si, (kind, n) in enumerate(_layer_plan(cfg)):
                x, nc = _scan_decode(params[f"seg{si}"], x, cfg, caches[f"seg{si}"],
                                     pos, kind)
                new_caches[f"seg{si}"] = nc
        logits = _logits(params, x)
        return logits, new_caches

    return ModelFns(cfg=cfg, init=init, train_forward=train_forward,
                    prefill=prefill, decode_step=decode_step,
                    init_cache=init_cache)


def _max_pos(cfg):
    return 65536 if not cfg.encdec else 32768
