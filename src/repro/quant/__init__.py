"""ICQ x LM integration (DESIGN.md §4): the paper's two-step / composite
quantization machinery applied inside the LM serving and training stack.

  int8.py          blockwise int8 quantize/dequantize primitives
  kv_cache.py      ICQ-KV: interleaved-subspace quantized KV cache with
                   crude-first two-step attention at decode
  grad_compress.py cross-pod gradient compression with error feedback
"""
from repro.quant.int8 import quantize_int8, dequantize_int8
from repro.quant.kv_cache import (ICQKVConfig, build_icq_kv_cache,
                                  icq_kv_append, icq_kv_decode_attention,
                                  init_icq_kv_cache)
from repro.quant.grad_compress import (compress_state_init,
                                       compressed_cross_pod_mean,
                                       ef_quantize)

__all__ = [
    "quantize_int8", "dequantize_int8",
    "ICQKVConfig", "build_icq_kv_cache", "icq_kv_append",
    "icq_kv_decode_attention", "init_icq_kv_cache",
    "compress_state_init", "compressed_cross_pod_mean", "ef_quantize",
]
