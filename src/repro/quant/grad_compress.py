"""Cross-pod gradient compression with error feedback (DESIGN.md §4,
"ICQ-grad") — the distributed-optimization trick for 1000+ node scale.

Within a pod, gradients reduce over the 'data' axis in full precision
(GSPMD, ICI-bandwidth class).  *Across pods* the links are the scarce
resource (DCI), so the pod-axis combine runs compressed:

    1. error feedback:   e = g + residual;  q, s = int8(e);
                         residual' = e - dequant(q, s)
    2. all_gather(q, s) over the 'pod' axis   (1B/elem on the wire
                                               vs 4B/elem fp32 psum)
    3. local dequantize + mean over the gathered pod shards

The all_gather-then-sum form (instead of psum-of-int8) keeps the wire
format int8 without overflow while every pod still obtains the identical
full-precision mean, and the residual carries the quantization error
into the next step — the 1-bit-Adam/EF-SGD correctness argument.

These helpers are shard_map-ready: ``compressed_cross_pod_mean`` calls
``jax.lax.all_gather(axis_name='pod')`` and must run inside a region
that is *manual* over the pod axis (see launch.train_step's
``jax.shard_map(..., axis_names={'pod'})`` wrapper).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.int8 import dequantize_int8, quantize_int8


def compress_state_init(grads):
    """Error-feedback residual pytree (zeros_like grads, fp32)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def ef_quantize(g, residual):
    """Error-feedback int8 quantization of one tensor.

    Returns (q int8, scale, new_residual).  Scales are per leading row
    (axis=-1 slices) — small relative to the payload.
    """
    e = g.astype(jnp.float32) + residual
    q, s = quantize_int8(e, axis=-1)
    new_residual = e - dequantize_int8(q, s)
    return q, s, new_residual


def compressed_cross_pod_mean(grads, residuals, axis_name: str = "pod"):
    """Compressed mean of ``grads`` over the pod axis (call under
    shard_map manual on ``axis_name``).  Returns (mean_grads, residuals')."""

    def one(g, r):
        q, s, r_new = ef_quantize(g, r)
        qs = jax.lax.all_gather(q, axis_name)       # (npod, ...) int8 on wire
        ss = jax.lax.all_gather(s, axis_name)
        deq = dequantize_int8(qs, ss)
        return jnp.mean(deq, axis=0).astype(g.dtype), r_new

    out = jax.tree.map(one, grads, residuals)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return mean, res


def plain_cross_pod_mean(grads, axis_name: str = "pod"):
    """Uncompressed control: fp32 psum-mean over the pod axis."""
    return jax.tree.map(lambda g: jax.lax.pmean(g, axis_name), grads)
