"""Symmetric int8 quantization with per-slice scales.

Scales are computed over the trailing axis (one scale per row/token/head
slice) — the layout every consumer here uses, chosen so dequantize is a
broadcast multiply that fuses into the following matmul.
"""
from __future__ import annotations

import jax.numpy as jnp


def quantize_int8(x, axis: int = -1):
    """x -> (q int8, scale f32 with ``axis`` reduced to size 1)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)
