"""ICQ-KV: the paper's interleaved two-step machinery applied to the
decode-time KV cache (DESIGN.md §4).

Mapping of the paper's pieces onto attention:
  - psi (high-variance subspace)  ->  the d_fast key dimensions with the
    largest per-dimension key variance, found *per kv-head* from the
    prefill keys (the online-Welford estimate, eq. 9).  The dims are
    interleaved in head_dim; a per-head permutation gathers them to the
    front **once at cache-write time**, so the crude scorer reads a
    contiguous (S, d_fast) tile — the TPU-native equivalent of ICQ's
    interleaved supports (no scatter/gather at score time).
  - crude comparison (eq. 2)      ->  q_fast . k_fast over all S cached
    keys (bf16, d_fast of head_dim dims).
  - margin + refinement (eq. 1)   ->  static ``top_c`` survivors by crude
    score are gathered, dequantized (int8 full-width codes), and scored
    exactly; softmax + value mix run over the survivors only.  A static
    cap replaces the data-dependent threshold (TPU shapes must be
    static) — the same dial as core.search.two_step_search_compact.

Decode-time HBM traffic per kv-head drops from  S * dh * 2B (bf16 K) +
S * dh * 2B (V)  to  S * d_fast * 2B (crude)  +  c * 2 * dh * 1B
(survivor K+V int8):  ~6.4x at d_fast = dh/4, c = S/16 — the memory-
roofline win measured in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.quant.int8 import dequantize_int8, quantize_int8

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class ICQKVConfig:
    d_fast: int = 64             # |psi| dims per head used for crude scores
    top_c_frac: float = 1 / 16   # survivor fraction of the cache length
    min_top_c: int = 128


def _variance_perm(k):
    """Per-head permutation sorting head_dim by descending key variance.

    k: (b, s, kvh, dh) -> perm (kvh, dh) int32.  Variance pooled over
    (batch, positions) — the eq. 9 estimate at prefill time.
    """
    var = jnp.var(k.astype(jnp.float32), axis=(0, 1))        # (kvh, dh)
    return jnp.argsort(-var, axis=-1).astype(jnp.int32)


def _apply_perm(x, perm):
    """Gather head_dim by per-head perm.  x: (b,s,kvh,dh), perm: (kvh,dh)."""
    return jnp.take_along_axis(x, perm[None, None, :, :], axis=-1)


def init_icq_kv_cache(cfg_kv: ICQKVConfig, batch: int, max_len: int,
                      kvh: int, dh: int, dtype=jnp.bfloat16) -> Dict:
    return {
        "perm": jnp.tile(jnp.arange(dh, dtype=jnp.int32)[None], (kvh, 1)),
        "k_fast": jnp.zeros((batch, max_len, kvh, cfg_kv.d_fast), dtype),
        "kq": jnp.zeros((batch, max_len, kvh, dh), jnp.int8),
        "ks": jnp.zeros((batch, max_len, kvh, 1), jnp.float32),
        "vq": jnp.zeros((batch, max_len, kvh, dh), jnp.int8),
        "vs": jnp.zeros((batch, max_len, kvh, 1), jnp.float32),
        "len": jnp.zeros((), jnp.int32),
    }


def build_icq_kv_cache(cfg_kv: ICQKVConfig, k, v, max_len: int,
                       dtype=jnp.bfloat16) -> Dict:
    """Quantize prefill K/V into an ICQ-KV cache.  k/v: (b,s,kvh,dh)."""
    b, s, kvh, dh = k.shape
    perm = _variance_perm(k)
    k_rot = _apply_perm(k, perm)
    kq, ks = quantize_int8(k_rot)
    vq, vs = quantize_int8(v)
    k_fast = k_rot[..., : cfg_kv.d_fast].astype(dtype)

    def pad(x):
        return jnp.pad(x, [(0, 0), (0, max_len - s)] + [(0, 0)] * (x.ndim - 2))

    return {"perm": perm, "k_fast": pad(k_fast),
            "kq": pad(kq), "ks": pad(ks), "vq": pad(vq), "vs": pad(vs),
            "len": jnp.asarray(s, jnp.int32)}


def icq_kv_append(cache: Dict, cfg_kv: ICQKVConfig, k_new, v_new, pos) -> Dict:
    """Append one decode step's K/V.  k_new/v_new: (b,1,kvh,dh)."""
    k_rot = _apply_perm(k_new, cache["perm"])
    kq, ks = quantize_int8(k_rot)
    vq, vs = quantize_int8(v_new)
    upd = lambda buf, val: jax.lax.dynamic_update_slice(
        buf, val.astype(buf.dtype), (0, pos) + (0,) * (buf.ndim - 2))
    return dict(
        cache,
        k_fast=upd(cache["k_fast"], k_rot[..., : cfg_kv.d_fast]),
        kq=upd(cache["kq"], kq), ks=upd(cache["ks"], ks),
        vq=upd(cache["vq"], vq), vs=upd(cache["vs"], vs),
        len=jnp.maximum(cache["len"], pos + 1))


def icq_kv_decode_attention(q, cache: Dict, cfg_kv: ICQKVConfig, pos,
                            top_c: int):
    """Two-step decode attention.  q: (b, 1, H, dh) -> (b, 1, H, dh).

    Phase 1: crude scores over all S from the d_fast high-variance dims.
    Phase 2: exact scores + softmax over the static top_c survivors.
    """
    b, _, h, dh = q.shape
    S = cache["kq"].shape[1]
    kvh = cache["kq"].shape[2]
    g = h // kvh
    scale = dh ** -0.5
    valid = jnp.arange(S)[None, :] <= pos                    # (1,S)

    qg = q[:, 0].reshape(b, kvh, g, dh)                      # head h -> kv h//g
    q_rot = jnp.take_along_axis(qg, cache["perm"][None, :, None, :], axis=-1)
    q_fast = q_rot[..., : cfg_kv.d_fast]

    # ---- phase 1: crude scores (b,kvh,g,S) ----
    crude = jnp.einsum("bkgf,bskf->bkgs",
                       q_fast.astype(jnp.float32),
                       cache["k_fast"][:, :S].astype(jnp.float32)) * scale
    crude = jnp.where(valid[:, None, None, :], crude, NEG_INF)
    _, cand = jax.lax.top_k(crude, top_c)                    # (b,kvh,g,c)

    # ---- phase 2: gather survivors, dequantize, exact attention ----
    # gather along S:  kq (b,S,kvh,dh) -> (b,kvh,g,c,dh)
    def gather(buf):
        bf = buf.transpose(0, 2, 1, 3)                       # (b,kvh,S,·)
        bf = jnp.broadcast_to(bf[:, :, None], (b, kvh, g) + bf.shape[2:])
        return jnp.take_along_axis(
            bf, cand[..., None], axis=3)                     # (b,kvh,g,c,·)

    k_sel = dequantize_int8(gather(cache["kq"]), gather(cache["ks"]))
    v_sel = dequantize_int8(gather(cache["vq"]), gather(cache["vs"]))
    s = jnp.einsum("bkgd,bkgcd->bkgc", q_rot.astype(jnp.float32), k_sel) * scale
    cand_valid = jnp.take_along_axis(
        jnp.broadcast_to(valid[:, None, None, :], crude.shape), cand, axis=3)
    s = jnp.where(cand_valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bkgcd->bkgd", p, v_sel)           # (b,kvh,g,dh)
    return out.reshape(b, 1, h, dh).astype(q.dtype)


def reference_decode_attention(q, k, v, pos):
    """Oracle: exact attention over the raw (unquantized) cache."""
    b, _, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = dh ** -0.5
    S = k.shape[1]
    qg = q.reshape(b, kvh, g, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where((jnp.arange(S)[None, None, None, :] <= pos), s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, 1, h, dh).astype(q.dtype)


# ------------------------------------------------------- context-parallel --

def icq_kv_attention_partial(q, cache: Dict, cfg_kv: ICQKVConfig, pos,
                             top_c_local: int, *, shard_offset=0):
    """Shard-local two-step attention over a position-sharded cache slice.

    Each shard scores its own S_local positions crude-first, refines its
    local ``top_c_local`` survivors, and returns *unnormalized* softmax
    partials (m, l, o) — combined across shards by
    ``combine_attention_partials``.  This keeps the top-k and the
    survivor gather entirely shard-local: the only cross-shard traffic
    is the (b, kvh, g[, dh]) partial stats, vs the full-cache gathers
    GSPMD emits for the global formulation (llama3-405b decode_32k:
    57.6 s -> ~0 collective term; EXPERIMENTS.md §Perf Cell A).
    """
    b, _, h, dh = q.shape
    S_local = cache["kq"].shape[1]
    kvh = cache["kq"].shape[2]
    g = h // kvh
    scale = dh ** -0.5
    local_pos = shard_offset + jnp.arange(S_local)
    valid = local_pos[None, :] <= pos                        # (1,S_local)

    qg = q[:, 0].reshape(b, kvh, g, dh)
    q_rot = jnp.take_along_axis(qg, cache["perm"][None, :, None, :], axis=-1)
    q_fast = q_rot[..., : cfg_kv.d_fast]

    crude = jnp.einsum("bkgf,bskf->bkgs", q_fast.astype(jnp.float32),
                       cache["k_fast"].astype(jnp.float32)) * scale
    crude = jnp.where(valid[:, None, None, :], crude, NEG_INF)
    _, cand = jax.lax.top_k(crude, top_c_local)              # (b,kvh,g,c)

    def gather(buf):
        bf = buf.transpose(0, 2, 1, 3)
        bf = jnp.broadcast_to(bf[:, :, None], (b, kvh, g) + bf.shape[2:])
        return jnp.take_along_axis(bf, cand[..., None], axis=3)

    k_sel = dequantize_int8(gather(cache["kq"]), gather(cache["ks"]))
    v_sel = dequantize_int8(gather(cache["vq"]), gather(cache["vs"]))
    s = jnp.einsum("bkgd,bkgcd->bkgc", q_rot.astype(jnp.float32), k_sel) * scale
    cand_valid = jnp.take_along_axis(
        jnp.broadcast_to(valid[:, None, None, :], crude.shape), cand, axis=3)
    s = jnp.where(cand_valid, s, NEG_INF)
    m = jnp.max(s, axis=-1)                                  # (b,kvh,g)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgc,bkgcd->bkgd", p, v_sel)             # unnormalized
    return m, l, o


def combine_attention_partials(m, l, o, axis_name: str):
    """Merge per-shard (m, l, o) softmax partials across ``axis_name``."""
    m_g = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * corr, axis_name)
    o_g = jax.lax.psum(o * corr[..., None], axis_name)
    return o_g / jnp.maximum(l_g, 1e-30)[..., None]


def combine_partials_local(ms, ls, os_):
    """Host-side reference combine over stacked shard partials (tests)."""
    m_g = jnp.max(ms, axis=0)
    corr = jnp.exp(ms - m_g[None])
    l_g = jnp.sum(ls * corr, axis=0)
    o_g = jnp.sum(os_ * corr[..., None], axis=0)
    return o_g / jnp.maximum(l_g, 1e-30)[..., None]
