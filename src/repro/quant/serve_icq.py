"""ICQ serving hot paths: the batched ANN search engine entry point and
the ICQ-KV decode step for dense-attention LMs (§Perf hillclimb "decode
memory").

``build_ann_engine`` instantiates one of the unified index layer's
implementations (``repro.index``, DESIGN.md §7) — ``index="flat"``
(one-step ADC), ``"two-step"`` (exhaustive ICQ, the default), or
``"ivf"`` (coarse-partitioned; pass ``emb_db=`` and ``n_lists=``) —
and wraps it into a jitted query-batch server: codes stay resident
(packed uint8), each call takes an (nq, d) embedding batch and returns
a SearchResult.  With ``mesh=`` the index is sharded over the mesh's
``data`` axis (``Index.shard``): per-shard local top-k + global merge,
ids identical to single-device.  Used by ``launch/serve.py --ann`` and
``examples/serve_retrieval.py``.

A drop-in replacement for the baseline ``decode_step`` of dense-family
archs: each layer's KV cache is stored as the interleaved quantized form
(per-head variance-permuted d_fast bf16 crude slab + int8 full-width
codes, repro.quant.kv_cache) and attention runs crude-first over d_fast
dims, refining only the static ``top_c`` survivors.

Decode-time HBM traffic per layer drops from  S*(dh*2)*2B (bf16 K+V)
to  S*d_fast*2B + top_c*2*dh*1B  (~3.6x at d_fast=dh/4, top_c=S/16);
the dry-run memory/roofline deltas are recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.models.transformer import _norm_apply
from repro.quant.kv_cache import (ICQKVConfig, icq_kv_append,
                                  icq_kv_decode_attention,
                                  init_icq_kv_cache)


class AnnEngine:
    """A serving handle over one index: callable for query batches and
    growable via ``add`` (DESIGN.md §9).

    ``engine(queries)`` (or ``engine.search(queries)``) runs the jitted
    batched search — the historical ``build_ann_engine`` contract.
    ``engine.add(new_vectors)`` encodes the new embeddings through the
    tiled ICM engine, appends/routes them into the index *without
    retraining*, and refreshes the jitted search (re-sharding over the
    engine's mesh if one was given); the engine keeps the unsharded
    source index precisely so sharded serving stays growable.  Returns
    ``self`` so calls chain."""

    def __init__(self, index, mesh=None):
        self.index = index                   # the unsharded source index
        self.mesh = mesh
        self._refresh()

    def _refresh(self):
        if self.mesh is not None:
            self._serve = self.index.shard(self.mesh).search
        else:
            idx = self.index
            self._serve = jax.jit(lambda queries: idx.search(queries))

    def __call__(self, queries):
        return self._serve(queries)

    def search(self, queries):
        return self._serve(queries)

    @property
    def n(self) -> int:
        return self.index.codes.shape[0]

    def add(self, new_vectors, **encode_opts) -> "AnnEngine":
        self.index = self.index.add(new_vectors, **encode_opts)
        self._refresh()
        return self


def build_ann_engine(codes, C, structure, *, topk: int = 50,
                     backend: str = "auto", block_q=None, block_n=None,
                     query_chunk=None, index: str = "two-step", mesh=None,
                     emb_db=None, n_lists: int = 64, n_probe: int = 8,
                     refine_cap=None, key=None, lut_dtype: str = "f32"):
    """Batched ANN serving entry: returns an ``AnnEngine`` — call it
    with an (nq, d) query batch for a ``repro.index.SearchResult``,
    and grow it in place with ``engine.add(new_vectors)`` (incremental
    encode + append, no retraining).

    ``index`` selects the implementation ("flat" | "two-step" | "ivf");
    "ivf" additionally needs ``emb_db`` (the database embeddings the
    codes encode) and takes ``n_lists`` / ``n_probe`` / ``key``.
    ``mesh`` (optional, with a "data" axis) shards the index for
    data-parallel serving.  ``codes`` stay device-resident across calls
    (packed uint8; widened at the kernel boundary).  ``backend`` follows
    the unified dispatch: "pallas" fused kernels on TPU, vectorized jnp
    elsewhere.  ``lut_dtype`` ("f32" | "int8") selects the crude-pass
    LUT precision (DESIGN.md §8; honored by the sharded engines too).
    """
    from repro.index import make_index

    opts: Dict[str, Any] = dict(topk=topk, backend=backend,
                                query_chunk=query_chunk,
                                lut_dtype=lut_dtype)
    # None = keep the index class's own tile defaults (they differ
    # between the flat engines and the IVF slab kernels)
    if block_q is not None:
        opts["block_q"] = block_q
    if block_n is not None:
        opts["block_n"] = block_n
    if index != "flat":
        opts["refine_cap"] = refine_cap
    if index == "ivf":
        if emb_db is None:
            raise ValueError("index='ivf' needs emb_db= to fit the "
                             "coarse quantizer")
        opts.update(emb_db=emb_db, n_lists=n_lists, n_probe=n_probe,
                    key=key)
    idx = make_index(index, jax.device_put(codes), jax.device_put(C),
                     structure, **opts)
    return AnnEngine(idx, mesh=mesh)


def supports_icq_kv(cfg) -> bool:
    """Dense decoder-only GQA archs (uniform layer plan)."""
    return (not cfg.ssm and not cfg.hybrid and not cfg.encdec
            and not cfg.mla and cfg.num_experts == 0
            and cfg.frontend == "none")


def build_icq_decode(cfg, kv_cfg: ICQKVConfig, *, mesh=None):
    """Returns (decode_fn, init_cache_fn) mirroring ModelFns' signatures.

    decode_fn(params, tokens, caches) -> (logits, new_caches); caches are
    the stacked ICQ-KV pytree per layer + the scalar position.
    """
    emb_scale = float(cfg.d_model) ** 0.5 if cfg.tie_embeddings else 1.0

    def init_cache(batch: int, max_len: int, dtype=jnp.bfloat16) -> Dict:
        one = init_icq_kv_cache(kv_cfg, batch, max_len, cfg.num_kv_heads,
                                cfg.head_dim, dtype)
        L = cfg.num_layers
        return {
            "pos": jnp.zeros((), jnp.int32),
            "layers": jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(),
                one),
        }

    def layer_decode(lp, x, cache, pos, top_c):
        h = _norm_apply(cfg, lp["norm1"], x)
        b = x.shape[0]
        positions = jnp.full((b, 1), pos, jnp.int32)
        from repro.models.attention import qkv_project
        q, k, v = qkv_project(lp["attn"], h, cfg, positions)
        cache = icq_kv_append(cache, kv_cfg, k, v, pos)
        o = icq_kv_decode_attention(q, cache, kv_cfg, pos, top_c)
        x = x + o.reshape(b, 1, cfg.num_heads * cfg.head_dim) @ lp["attn"]["wo"]
        h2 = _norm_apply(cfg, lp["norm2"], x)
        x = x + nn.mlp_apply(lp["ffn"], h2, cfg.activation)
        return x, cache

    def decode_step(params, tokens, caches, *, top_c: int):
        pos = caches["pos"]
        x = params["embed"][tokens].astype(cfg.compute_dtype)
        x = x * jnp.asarray(emb_scale, x.dtype)
        L = cfg.num_layers

        def body(carry, inp):
            h, layer_caches = carry
            li, lp = inp
            c = jax.tree.map(
                lambda buf: jax.lax.dynamic_index_in_dim(
                    buf, li, 0, keepdims=False), layer_caches)
            h, nc = layer_decode(lp, h, c, pos, top_c)
            layer_caches = jax.tree.map(
                lambda buf, n: jax.lax.dynamic_update_index_in_dim(
                    buf, n.astype(buf.dtype), li, 0), layer_caches, nc)
            return (h, layer_caches), None

        (x, layers), _ = jax.lax.scan(
            body, (x, caches["layers"]),
            (jnp.arange(L, dtype=jnp.int32), params["seg0"]))
        x = _norm_apply(cfg, params["final_norm"], x)
        logits = (x @ params["embed"].T.astype(x.dtype)
                  if cfg.tie_embeddings else x @ params["head"])
        return logits[..., : cfg.vocab_size], dict(pos=pos + 1, layers=layers)

    return decode_step, init_cache


def icq_kv_cache_shardings(cache_sh, cfg, mesh):
    """Shard the quantized cache: batch over data; heads over model when
    they divide, else positions over model (mirrors the baseline rules)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed import sharding as shrules

    msize = shrules.axis_size(mesh, "model")
    heads_ok = cfg.num_kv_heads % max(msize, 1) == 0 and \
        cfg.num_kv_heads >= msize

    def one(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        nd = len(leaf.shape)
        last = name.rsplit("/", 1)[-1]
        if last == "pos" or nd <= 1:
            return NamedSharding(mesh, P())
        if last == "perm":                       # (L, kvh, dh)
            return NamedSharding(mesh, P(
                None, shrules.maybe("model", leaf.shape[1], mesh)
                if heads_ok else None, None))
        # (L, b, S, kvh, ...) buffers
        spec = [None] * nd
        spec[1] = shrules.maybe(("data",), leaf.shape[1], mesh)
        if heads_ok and nd >= 4:
            spec[3] = shrules.maybe("model", leaf.shape[3], mesh)
        elif nd >= 3:
            spec[2] = shrules.maybe("model", leaf.shape[2], mesh)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_sh)
