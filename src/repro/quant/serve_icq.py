"""ICQ serving hot paths: the batched ANN search engine entry point and
the ICQ-KV decode step for dense-attention LMs (§Perf hillclimb "decode
memory").

``AnnEngine`` / ``build_ann_engine`` moved to ``repro.api.serving`` as
part of the front-door API redesign (docs/api.md) and are re-exported
here unchanged for backward compatibility — ``build_ann_engine``'s
kwargs now fold into the api config tree (``IndexConfig`` +
``ServeConfig``) before reaching the unified index layer.  New code
should import from ``repro.api``.

The ICQ-KV side stays here: a drop-in replacement for the baseline
``decode_step`` of dense-family archs — each layer's KV cache is stored
as the interleaved quantized form (per-head variance-permuted d_fast
bf16 crude slab + int8 full-width codes, repro.quant.kv_cache) and
attention runs crude-first over d_fast dims, refining only the static
``top_c`` survivors.

Decode-time HBM traffic per layer drops from  S*(dh*2)*2B (bf16 K+V)
to  S*d_fast*2B + top_c*2*dh*1B  (~3.6x at d_fast=dh/4, top_c=S/16);
the dry-run memory/roofline deltas are recorded in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

# back-compat re-exports: the serving engine now lives in the api layer
from repro.api.serving import AnnEngine, build_ann_engine  # noqa: F401
from repro.models import nn
from repro.models.transformer import _norm_apply
from repro.quant.kv_cache import (ICQKVConfig, icq_kv_append,
                                  icq_kv_decode_attention,
                                  init_icq_kv_cache)


def supports_icq_kv(cfg) -> bool:
    """Dense decoder-only GQA archs (uniform layer plan)."""
    return (not cfg.ssm and not cfg.hybrid and not cfg.encdec
            and not cfg.mla and cfg.num_experts == 0
            and cfg.frontend == "none")


def build_icq_decode(cfg, kv_cfg: ICQKVConfig, *, mesh=None):
    """Returns (decode_fn, init_cache_fn) mirroring ModelFns' signatures.

    decode_fn(params, tokens, caches) -> (logits, new_caches); caches are
    the stacked ICQ-KV pytree per layer + the scalar position.
    """
    emb_scale = float(cfg.d_model) ** 0.5 if cfg.tie_embeddings else 1.0

    def init_cache(batch: int, max_len: int, dtype=jnp.bfloat16) -> Dict:
        one = init_icq_kv_cache(kv_cfg, batch, max_len, cfg.num_kv_heads,
                                cfg.head_dim, dtype)
        L = cfg.num_layers
        return {
            "pos": jnp.zeros((), jnp.int32),
            "layers": jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(),
                one),
        }

    def layer_decode(lp, x, cache, pos, top_c):
        h = _norm_apply(cfg, lp["norm1"], x)
        b = x.shape[0]
        positions = jnp.full((b, 1), pos, jnp.int32)
        from repro.models.attention import qkv_project
        q, k, v = qkv_project(lp["attn"], h, cfg, positions)
        cache = icq_kv_append(cache, kv_cfg, k, v, pos)
        o = icq_kv_decode_attention(q, cache, kv_cfg, pos, top_c)
        x = x + o.reshape(b, 1, cfg.num_heads * cfg.head_dim) @ lp["attn"]["wo"]
        h2 = _norm_apply(cfg, lp["norm2"], x)
        x = x + nn.mlp_apply(lp["ffn"], h2, cfg.activation)
        return x, cache

    def decode_step(params, tokens, caches, *, top_c: int):
        pos = caches["pos"]
        x = params["embed"][tokens].astype(cfg.compute_dtype)
        x = x * jnp.asarray(emb_scale, x.dtype)
        L = cfg.num_layers

        def body(carry, inp):
            h, layer_caches = carry
            li, lp = inp
            c = jax.tree.map(
                lambda buf: jax.lax.dynamic_index_in_dim(
                    buf, li, 0, keepdims=False), layer_caches)
            h, nc = layer_decode(lp, h, c, pos, top_c)
            layer_caches = jax.tree.map(
                lambda buf, n: jax.lax.dynamic_update_index_in_dim(
                    buf, n.astype(buf.dtype), li, 0), layer_caches, nc)
            return (h, layer_caches), None

        (x, layers), _ = jax.lax.scan(
            body, (x, caches["layers"]),
            (jnp.arange(L, dtype=jnp.int32), params["seg0"]))
        x = _norm_apply(cfg, params["final_norm"], x)
        logits = (x @ params["embed"].T.astype(x.dtype)
                  if cfg.tie_embeddings else x @ params["head"])
        return logits[..., : cfg.vocab_size], dict(pos=pos + 1, layers=layers)

    return decode_step, init_cache


def icq_kv_cache_shardings(cache_sh, cfg, mesh):
    """Shard the quantized cache: batch over data; heads over model when
    they divide, else positions over model (mirrors the baseline rules)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed import sharding as shrules

    msize = shrules.axis_size(mesh, "model")
    heads_ok = cfg.num_kv_heads % max(msize, 1) == 0 and \
        cfg.num_kv_heads >= msize

    def one(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        nd = len(leaf.shape)
        last = name.rsplit("/", 1)[-1]
        if last == "pos" or nd <= 1:
            return NamedSharding(mesh, P())
        if last == "perm":                       # (L, kvh, dh)
            return NamedSharding(mesh, P(
                None, shrules.maybe("model", leaf.shape[1], mesh)
                if heads_ok else None, None))
        # (L, b, S, kvh, ...) buffers
        spec = [None] * nd
        spec[1] = shrules.maybe(("data",), leaf.shape[1], mesh)
        if heads_ok and nd >= 4:
            spec[3] = shrules.maybe("model", leaf.shape[3], mesh)
        elif nd >= 3:
            spec[2] = shrules.maybe("model", leaf.shape[2], mesh)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_sh)
