"""``repro.resilience`` — the robustness substrate under the serving
and artifact stack (docs/robustness.md, DESIGN.md §11).

Three orthogonal pieces, deliberately free of engine imports so the
index / api layers can depend on them without cycles:

  ``budget``  — ``SearchBudget`` (deadline + stage caps) and
      ``ResultMeta`` (degraded level, stages run, wall time, coverage):
      the vocabulary of deadline-aware degraded search.  The ladder
      itself (full → capped refine → reduced probes → crude-only) is
      executed by ``repro.api.serving.AnnEngine``.
  ``retry``   — ``retry_with_backoff`` / ``BackoffPolicy``: bounded
      retries with exponential backoff, used by the engine's
      Pallas→jnp failover and anything else that faces transient
      faults.
  ``faults``  — ``FaultInjector``: a *seeded, deterministic* chaos
      harness that raises, delays, or corrupts bytes at configured
      probabilities.  Tests and the ``benchmarks/run.py --only faults``
      chaos target drive every failover path through it.
"""
from repro.resilience.budget import (DEGRADE_LEVELS, ResultMeta,
                                     SearchBudget)
from repro.resilience.faults import FaultInjector, FaultSpec, InjectedFault
from repro.resilience.retry import BackoffPolicy, RetriesExhausted, \
    retry_with_backoff

__all__ = [
    "SearchBudget", "ResultMeta", "DEGRADE_LEVELS",
    "BackoffPolicy", "retry_with_backoff", "RetriesExhausted",
    "FaultInjector", "FaultSpec", "InjectedFault",
]
