"""Search budgets and result metadata — the vocabulary of
deadline-aware degraded search (docs/robustness.md).

A ``SearchBudget`` says how much a caller is willing to pay for one
query batch; a ``ResultMeta`` rides on every ``SearchResult`` and says
what was actually paid: which rung of the degradation ladder ran, which
stages executed, the measured wall time, and the fraction of the
database that was reachable (``coverage`` < 1.0 under dead shards).

The ladder (executed by ``repro.api.serving.AnnEngine``):

    level 0  full      the index's configured search (eq. 1 refine)
    level 1  capped    refine capped at ``refine_cap`` best-crude
                       survivors (jnp engines; the fused kernels bound
                       phase-2 work in-kernel and skip this rung)
    level 2  probes    IVF only: reduced ``n_probe``
    level 3  crude     crude-only ranking (eq. 2's fast subset) —
                       bitwise-identical to the crude ranking the full
                       path computes internally

Level choice is *measured*, not guessed: the engine keeps a per-level
EMA of warm wall times and picks the least-degraded rung whose measured
(or inherited-upper-bound) time fits the deadline; the crude floor is
always eligible.  ``ResultMeta.degraded`` flags anything above level 0
or any coverage < 1.0, so callers can always distinguish exact results
from approximate-under-pressure ones.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

# ladder rungs, least → most degraded (docs/robustness.md)
DEGRADE_LEVELS = ("full", "capped", "probes", "crude")


class SearchBudget(NamedTuple):
    """What one query batch may cost.

    deadline_ms   target wall time for the batch; the engine picks the
                  least-degraded ladder level whose *measured* time
                  fits (None = no deadline: caps alone pick the level).
    allow_refine  False forces the crude-only floor outright (Quick-ADC
                  style cheap-pass-only serving).
    max_n_probe   IVF: clamp the probe count for this batch.
    refine_cap    override the capped level's survivor cap.
    force_level   pin a ladder level by name ("full" | "capped" |
                  "probes" | "crude"), bypassing timing choice.
    """
    deadline_ms: Optional[float] = None
    allow_refine: bool = True
    max_n_probe: Optional[int] = None
    refine_cap: Optional[int] = None
    force_level: Optional[str] = None


class ResultMeta(NamedTuple):
    """What one search actually did (attached to ``SearchResult.meta``
    *outside* jit — it carries host types).

    ``degraded`` is True iff the result is anything less than the full
    configured search over the full database: a ladder level above 0,
    or coverage < 1.0 (dead shards).

    ``queue_ms`` / ``batch_fill`` stay ``None`` on the offline
    ``AnnEngine`` paths; only the async serving loop
    (``repro.serve.ServingLoop``, docs/serving.md) populates them —
    time spent coalescing in the request queue before the batch was
    dispatched, and the fraction of the dispatched tile occupied by
    real (non-padding) query rows.  They ride through the degradation
    ladder unchanged: the loop stamps them onto whatever meta the
    ladder produced for the batch.
    """
    level: int = 0                       # ladder rung index
    level_name: str = "full"             # DEGRADE_LEVELS[level]
    degraded: bool = False
    stages: Tuple[str, ...] = ()         # e.g. ("probe", "crude", "refine")
    wall_ms: float = -1.0                # measured batch wall time
    deadline_ms: Optional[float] = None  # the budget's deadline, if any
    deadline_exceeded: bool = False      # wall_ms > deadline_ms
    coverage: float = 1.0                # reachable fraction of the db
    backend: str = ""                    # engine backend that served it
    queue_ms: Optional[float] = None     # serving loop: coalescing wait
    batch_fill: Optional[float] = None   # serving loop: real rows / tile


def validate_budget(budget: SearchBudget) -> SearchBudget:
    """Sanity-check a budget (raises ``ValueError`` naming the field)."""
    if budget.deadline_ms is not None and budget.deadline_ms <= 0:
        raise ValueError(
            f"SearchBudget.deadline_ms must be > 0, got {budget.deadline_ms}")
    if budget.max_n_probe is not None and budget.max_n_probe < 1:
        raise ValueError(
            f"SearchBudget.max_n_probe must be >= 1, got {budget.max_n_probe}")
    if budget.refine_cap is not None and budget.refine_cap < 1:
        raise ValueError(
            f"SearchBudget.refine_cap must be >= 1, got {budget.refine_cap}")
    if budget.force_level is not None \
            and budget.force_level not in DEGRADE_LEVELS:
        raise ValueError(
            f"SearchBudget.force_level={budget.force_level!r} is not one "
            f"of {list(DEGRADE_LEVELS)}")
    return budget
