"""Deterministic fault injection (docs/robustness.md).

``FaultInjector`` is a *seeded* chaos harness: every probabilistic
decision comes from one ``np.random.default_rng(seed)`` stream, so a
fixed seed + a fixed call sequence reproduces the exact same faults —
the property that lets the chaos tests assert specific failover paths
instead of flaking.

Stages are plain strings (``"kernels.batched_crude_topk"``,
``"engine.search"``, ``"artifacts.save"`` …).  A spec's ``targets``
tuple selects stages by prefix (empty = all).  Three fault modes, drawn
independently per ``check``:

  raise     raise ``InjectedFault`` (simulated kernel/node failure)
  delay     sleep ``delay_ms`` (simulated straggler / slow device)
  corrupt   arm byte corruption: the *next* ``corrupt_bytes`` /
            ``corrupt_array`` call flips deterministic bytes (simulated
            bit rot; artifact tests feed saved tensors through it)

Install points:

  - ``repro.kernels.ops`` calls the module hook at every public kernel
    entry — ``injector.install_kernels()`` / ``uninstall_kernels()``
    (or the ``installed()`` context manager) attach the injector there.
    Note kernels called under an outer ``jax.jit`` trace once; the
    serving engine therefore drops to eager dispatch whenever a fault
    injector is attached, so every batch re-enters the hook.
  - ``AnnEngine(fault_injector=...)`` checks ``engine.search`` per
    batch and routes kernel installs for you.
  - ``injector.wrap(stage, fn)`` wraps any callable.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, Tuple

import numpy as np


class InjectedFault(RuntimeError):
    """A fault raised by ``FaultInjector`` (never by real code paths)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Per-stage fault probabilities.  ``targets`` are stage-name
    prefixes (empty tuple = every stage)."""
    p_raise: float = 0.0
    p_delay: float = 0.0
    p_corrupt: float = 0.0
    delay_ms: float = 1.0
    targets: Tuple[str, ...] = ()

    def __post_init__(self):
        for name in ("p_raise", "p_delay", "p_corrupt"):
            p = getattr(self, name)
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"FaultSpec.{name}={p} outside [0, 1]")


class FaultInjector:
    """Seeded, deterministic fault source.  See the module docstring.

    ``counts`` tallies injected faults per ``"stage:mode"`` so tests
    and the chaos benchmark can report what actually fired."""

    def __init__(self, seed: int, spec: FaultSpec = FaultSpec(), *,
                 sleep=time.sleep):
        self.spec = spec
        self._rng = np.random.default_rng(seed)
        self._sleep = sleep
        self._corrupt_armed = False
        self.counts: Dict[str, int] = {}

    # ------------------------------------------------------------ check --
    def matches(self, stage: str) -> bool:
        t = self.spec.targets
        return not t or any(stage.startswith(p) for p in t)

    def check(self, stage: str) -> None:
        """Draw this stage's fate: maybe raise, maybe delay, maybe arm
        corruption.  Call at stage entry.  Deterministic in (seed, call
        sequence)."""
        if not self.matches(stage):
            return
        u_raise, u_delay, u_corrupt = self._rng.random(3)
        if self.spec.p_corrupt > 0.0 and u_corrupt < self.spec.p_corrupt:
            self._corrupt_armed = True
            self._count(stage, "corrupt")
        if self.spec.p_delay > 0.0 and u_delay < self.spec.p_delay:
            self._count(stage, "delay")
            self._sleep(self.spec.delay_ms / 1000.0)
        if self.spec.p_raise > 0.0 and u_raise < self.spec.p_raise:
            self._count(stage, "raise")
            raise InjectedFault(f"injected fault at stage {stage!r}")

    def _count(self, stage: str, mode: str) -> None:
        key = f"{stage}:{mode}"
        self.counts[key] = self.counts.get(key, 0) + 1

    @property
    def total_faults(self) -> int:
        return sum(self.counts.values())

    # -------------------------------------------------------- corruption --
    def corrupt_bytes(self, data: bytes, n_flips: int = 8) -> bytes:
        """Flip ``n_flips`` deterministic bytes of ``data`` (always
        corrupts — probability gating happens in ``check``)."""
        if not data:
            return data
        buf = bytearray(data)
        pos = self._rng.integers(0, len(buf), size=min(n_flips, len(buf)))
        for p in pos:
            buf[p] ^= 0xFF
        return bytes(buf)

    def corrupt_array(self, a: np.ndarray, n_flips: int = 8) -> np.ndarray:
        """A byte-flipped copy of ``a`` (same dtype/shape — the kind of
        corruption only checksums catch)."""
        a = np.ascontiguousarray(a)
        raw = self.corrupt_bytes(a.tobytes(), n_flips)
        return np.frombuffer(raw, dtype=a.dtype).reshape(a.shape).copy()

    def maybe_corrupt_array(self, a: np.ndarray) -> np.ndarray:
        """Corrupt ``a`` iff a prior ``check`` armed corruption (then
        disarm).  Lets wrapped stages corrupt their own outputs."""
        if not self._corrupt_armed:
            return a
        self._corrupt_armed = False
        return self.corrupt_array(a)

    # ------------------------------------------------------------- wraps --
    def wrap(self, stage: str, fn):
        """Wrap ``fn``: every call runs ``check(stage)`` first; ndarray
        returns pass through ``maybe_corrupt_array``."""
        def wrapped(*args, **kwargs):
            self.check(stage)
            out = fn(*args, **kwargs)
            if isinstance(out, np.ndarray):
                return self.maybe_corrupt_array(out)
            return out
        return wrapped

    def install_kernels(self):
        """Attach ``check`` to every ``repro.kernels.ops`` entry point.
        Returns the previously installed hook (restore it via
        ``uninstall_kernels(prev)``)."""
        from repro.kernels import ops
        return ops.set_fault_hook(self.check)

    @staticmethod
    def uninstall_kernels(prev=None):
        from repro.kernels import ops
        ops.set_fault_hook(prev)

    @contextlib.contextmanager
    def installed(self):
        """``with injector.installed():`` — kernel hook attached for the
        block, previous hook restored after."""
        prev = self.install_kernels()
        try:
            yield self
        finally:
            self.uninstall_kernels(prev)
