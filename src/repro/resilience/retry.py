"""Bounded retries with exponential backoff.

The one retry policy of the stack: the serving engine's Pallas→jnp
failover retries its fallback through this, and anything else that
faces transient faults (flaky storage, injected chaos) can reuse it.
``sleep`` is injectable so tests assert the exact backoff schedule
without waiting for it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Tuple, Type


@dataclasses.dataclass(frozen=True)
class BackoffPolicy:
    """Exponential backoff: attempt ``i`` (0-based) sleeps
    ``min(base_ms * multiplier**i, max_ms)`` before retrying; after
    ``max_retries`` failed retries the last error propagates
    (``max_retries=0`` = no retries: one attempt, fail fast)."""
    max_retries: int = 2
    base_ms: float = 10.0
    max_ms: float = 1000.0
    multiplier: float = 2.0

    def delay_ms(self, attempt: int) -> float:
        return min(self.base_ms * self.multiplier ** attempt, self.max_ms)


class RetriesExhausted(RuntimeError):
    """All retry attempts failed; ``__cause__`` is the last error."""


def retry_with_backoff(fn: Callable, *,
                       policy: BackoffPolicy = BackoffPolicy(),
                       retryable: Tuple[Type[BaseException], ...] = (Exception,),
                       sleep: Callable[[float], None] = time.sleep,
                       on_retry: Optional[Callable] = None):
    """Call ``fn()`` with up to ``policy.max_retries`` retries.

    Backoff sleeps run *between* attempts (seconds, from the policy's
    millisecond schedule).  ``on_retry(attempt, error, delay_ms)`` is
    invoked before each sleep — the engine uses it to log failovers.
    Raises ``RetriesExhausted`` (chaining the last error) once the
    budget is spent; non-``retryable`` errors propagate immediately.
    """
    attempt = 0
    while True:
        try:
            return fn()
        except retryable as e:
            if attempt >= policy.max_retries:
                raise RetriesExhausted(
                    f"{attempt + 1} attempt(s) failed; last error: "
                    f"{type(e).__name__}: {e}") from e
            delay = policy.delay_ms(attempt)
            if on_retry is not None:
                on_retry(attempt, e, delay)
            sleep(delay / 1000.0)
            attempt += 1
