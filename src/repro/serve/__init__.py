"""repro.serve — the async traffic serving engine (docs/serving.md,
DESIGN.md §14): query coalescing over fixed compiled tile shapes,
multi-tenant sessions behind one process, and a seeded Poisson load
harness.  Scheduling never changes math: coalesced responses are
bitwise-identical to direct ``Searcher``/``AnnEngine`` calls on the
same rows."""
from repro.serve.coalescer import (Coalescer, FlushBatch, FlushSlice,
                                   PendingRequest, ServeError)
from repro.serve.loadgen import (RequestSpec, make_workload,
                                 poisson_arrivals, run_closed_loop,
                                 run_open_loop, summarize)
from repro.serve.loop import ServingLoop
from repro.serve.tenants import (Tenant, load_tenants, parse_tenant_specs)

__all__ = [
    "Coalescer", "FlushBatch", "FlushSlice", "PendingRequest", "ServeError",
    "RequestSpec", "make_workload", "poisson_arrivals", "run_closed_loop",
    "run_open_loop", "summarize",
    "ServingLoop",
    "Tenant", "load_tenants", "parse_tenant_specs",
]
