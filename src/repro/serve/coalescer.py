"""Query coalescing: the pure state machine under the async serving
loop (DESIGN.md §14, docs/serving.md).

Arriving single/small-batch requests are queued FIFO *at row
granularity* and assembled into fixed-size flush tiles so one compiled
program shape serves every arrival size:

  - a flush fires the moment ``tile`` rows are pending (**full tile**),
    never waiting out the window;
  - otherwise the oldest pending row may wait at most ``window_s``
    before a **window-expiry** flush ships whatever is queued (padded
    up to the tile by the serving loop);
  - a request larger than the remaining tile capacity is **split**
    across consecutive flushes — each flush records the row spans it
    carries (``FlushSlice``) so the loop can route result rows back to
    the right caller and reassemble them in order.

The class is deliberately *pure*: it never reads a clock or touches a
thread — every method takes ``now`` (seconds, any monotonic origin)
explicitly.  ``ServingLoop`` owns the real clock and the condition
variable; the state-machine tests (tests/test_serve.py) drive a fake
clock through the exact same transitions.

Invariant: after any ``submit`` returns, fewer than ``tile`` rows
remain queued (full tiles are emitted eagerly), so ``poll`` emits at
most one partial flush per expiry and ``flush_all`` at most one batch.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Callable, List, Optional

import numpy as np


class ServeError(RuntimeError):
    """A serving-loop usage or capacity error (never a search failure —
    engine exceptions propagate through the request's future)."""


_rid_counter = itertools.count()


class PendingRequest:
    """One caller's in-flight request: its query rows, routing options,
    and the accumulator the loop fills as flush slices complete."""

    __slots__ = ("rid", "tenant", "queries", "topk", "budget", "t_submit",
                 "future", "t_done", "_rows_done", "_parts", "_fills")

    def __init__(self, tenant: str, queries: np.ndarray,
                 topk: Optional[int], budget, t_submit: float, future):
        self.rid = next(_rid_counter)
        self.tenant = tenant
        self.queries = queries              # (nq, d) float32, host-side
        self.topk = topk
        self.budget = budget
        self.t_submit = t_submit
        self.future = future
        self.t_done: Optional[float] = None
        self._rows_done = 0
        self._parts: List = []              # (req_start, ids, dists, res)
        self._fills: List = []              # (rows, batch_fill) per part

    @property
    def nq(self) -> int:
        return self.queries.shape[0]

    def deliver(self, req_start: int, ids: np.ndarray, dists: np.ndarray,
                result, fill: float) -> bool:
        """Accept one flush slice's result rows; True when the request
        is complete (all parts arrived)."""
        self._parts.append((req_start, ids, dists, result))
        self._fills.append((ids.shape[0], fill))
        self._rows_done += ids.shape[0]
        return self._rows_done >= self.nq

    def assemble(self):
        """(ids, dists, last_part_result, row-weighted mean fill) in
        request-row order — call only once complete."""
        parts = sorted(self._parts, key=lambda p: p[0])
        ids = np.concatenate([p[1] for p in parts], axis=0)
        dists = np.concatenate([p[2] for p in parts], axis=0)
        rows = sum(r for r, _ in self._fills)
        fill = sum(r * f for r, f in self._fills) / max(rows, 1)
        return ids, dists, parts[-1][3], fill


@dataclasses.dataclass(frozen=True)
class FlushSlice:
    """One request's contiguous span inside a flush tile."""
    request: PendingRequest
    req_start: int               # first row of the span in the request
    batch_start: int             # first row of the span in the tile
    rows: int


@dataclasses.dataclass(frozen=True)
class FlushBatch:
    """An assembled flush: the concatenated real query rows (<= tile)
    and the spans that map result rows back to their requests."""
    slices: tuple                # of FlushSlice
    rows: int                    # real rows (tile fill numerator)
    tile: int
    reason: str                  # "full" | "window" | "drain"

    @property
    def fill(self) -> float:
        return self.rows / self.tile

    def queries(self) -> np.ndarray:
        return np.concatenate(
            [s.request.queries[s.req_start:s.req_start + s.rows]
             for s in self.slices], axis=0)


class Coalescer:
    """The per-lane request queue (one lane = one tenant + one static
    (topk, budget) serving configuration; see ``ServingLoop``)."""

    def __init__(self, tile: int, window_s: float):
        if tile < 1:
            raise ServeError(f"coalescer tile must be >= 1, got {tile}")
        if window_s < 0:
            raise ServeError(
                f"coalescer window must be >= 0 s, got {window_s}")
        self.tile = int(tile)
        self.window_s = float(window_s)
        # FIFO of [request, rows_consumed_by_prior_flushes]
        self._queue: deque = deque()
        self._pending_rows = 0
        self._oldest_t: Optional[float] = None   # submit time of queue head

    # ------------------------------------------------------------- state --
    @property
    def pending_rows(self) -> int:
        return self._pending_rows

    @property
    def pending_requests(self) -> int:
        return len(self._queue)

    def next_deadline(self) -> Optional[float]:
        """Absolute time the oldest pending row must flush by (None =
        queue empty)."""
        if self._oldest_t is None:
            return None
        return self._oldest_t + self.window_s

    # ------------------------------------------------------- transitions --
    def submit(self, request: PendingRequest,
               now: float) -> List[FlushBatch]:
        """Enqueue a request; returns the full-tile flushes it
        triggered (possibly several for an oversize burst, possibly
        none)."""
        self._queue.append([request, 0])
        self._pending_rows += request.nq
        if self._oldest_t is None:
            self._oldest_t = now
        flushes = []
        while self._pending_rows >= self.tile:
            flushes.append(self._take(self.tile, "full"))
        return flushes

    def poll(self, now: float) -> List[FlushBatch]:
        """Window-expiry check: flush the (partial) queue if the oldest
        pending row has waited ``window_s``."""
        dl = self.next_deadline()
        if dl is None or now < dl:
            return []
        return [self._take(min(self._pending_rows, self.tile), "window")]

    def flush_all(self) -> List[FlushBatch]:
        """Drain everything pending (loop shutdown) regardless of the
        window."""
        flushes = []
        while self._pending_rows > 0:
            flushes.append(
                self._take(min(self._pending_rows, self.tile), "drain"))
        return flushes

    # ------------------------------------------------------------ packing --
    def _take(self, rows: int, reason: str) -> FlushBatch:
        """Pop ``rows`` queued rows FIFO into one flush, splitting the
        request at the boundary if it does not fit whole."""
        slices, taken = [], 0
        while taken < rows:
            entry = self._queue[0]
            req, consumed = entry
            span = min(req.nq - consumed, rows - taken)
            slices.append(FlushSlice(request=req, req_start=consumed,
                                     batch_start=taken, rows=span))
            taken += span
            entry[1] += span
            if entry[1] >= req.nq:
                self._queue.popleft()
        self._pending_rows -= rows
        # the window re-arms from the new head's submit time; a split
        # head keeps its original arrival time (its rows are oldest)
        self._oldest_t = (self._queue[0][0].t_submit if self._queue
                          else None)
        return FlushBatch(slices=tuple(slices), rows=rows, tile=self.tile,
                          reason=reason)
