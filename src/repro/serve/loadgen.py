"""Poisson load generation and latency measurement for the serving
loop (docs/benchmarks.md, ``benchmarks/run.py --only serve``).

Everything random is seeded: arrival gaps, tenant choice, request
sizes, and query-row picks all come from one ``numpy`` generator, so
the same seed replays the same request stream row-for-row (the
determinism contract tests/test_bench_determinism.py holds for the
serve bench).  Latency is wall-clock and never part of that contract —
``summarize`` keeps timing and content fields separate.

Two drivers:

  - ``run_open_loop``: arrivals fire on the Poisson schedule whether or
    not earlier requests finished (open-loop, the honest way to measure
    a queueing system — closed-loop drivers self-throttle and hide
    queueing delay);
  - ``run_closed_loop``: ``concurrency`` workers submit back-to-back,
    measuring saturated throughput rather than latency under a rate.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class RequestSpec:
    """One scheduled request of a generated workload."""
    t_arrival: float             # seconds from workload start
    tenant: str
    queries: np.ndarray          # (nq, d) float32


def poisson_arrivals(rate_hz: float, duration_s: float, *,
                     rng: np.random.Generator) -> np.ndarray:
    """Arrival times (seconds, sorted) of a Poisson process: i.i.d.
    exponential gaps at ``rate_hz``, truncated at ``duration_s``."""
    if rate_hz <= 0:
        raise ValueError(f"rate_hz must be > 0, got {rate_hz}")
    # draw in chunks until past the horizon; E[n] = rate * duration
    gaps: List[np.ndarray] = []
    total = 0.0
    while total < duration_s:
        chunk = rng.exponential(1.0 / rate_hz,
                                size=max(int(rate_hz * duration_s) + 1, 16))
        gaps.append(chunk)
        total += float(chunk.sum())
    times = np.cumsum(np.concatenate(gaps))
    return times[times < duration_s]


def make_workload(query_pools: Dict[str, np.ndarray], rate_hz: float,
                  duration_s: float, *, rng: np.random.Generator,
                  rows_choices: Sequence[int] = (1, 2, 4)) -> List[RequestSpec]:
    """A seeded Poisson request stream over ``query_pools``
    (tenant name -> (n, d) candidate query rows).  Tenants are drawn
    uniformly **in sorted-name order** so the stream is identical for
    the same seed regardless of dict insertion order."""
    names = sorted(query_pools)
    if not names:
        raise ValueError("make_workload needs at least one tenant pool")
    out: List[RequestSpec] = []
    for t in poisson_arrivals(rate_hz, duration_s, rng=rng):
        name = names[int(rng.integers(len(names)))]
        pool = query_pools[name]
        nq = int(rows_choices[int(rng.integers(len(rows_choices)))])
        rows = rng.integers(pool.shape[0], size=nq)
        out.append(RequestSpec(
            t_arrival=float(t), tenant=name,
            queries=np.asarray(pool[rows], dtype=np.float32)))
    return out


def _record(spec: RequestSpec, t_submit: float, t_done: float, result):
    meta = result.meta
    return {
        "tenant": spec.tenant,
        "nq": int(spec.queries.shape[0]),
        "latency_ms": (t_done - t_submit) * 1000.0,
        "queue_ms": None if meta is None else meta.queue_ms,
        "batch_fill": None if meta is None else meta.batch_fill,
        "degraded": bool(meta.degraded) if meta is not None else False,
        "level_name": meta.level_name if meta is not None else "",
        "ids": np.asarray(result.indices),
        "dists": np.asarray(result.distances),
    }


def run_open_loop(loop, workload: Sequence[RequestSpec], *,
                  clock=time.monotonic, sleep=time.sleep,
                  timeout_s: float = 120.0) -> List[dict]:
    """Fire the workload on its Poisson schedule against a *started*
    ``ServingLoop``; returns one record per request (workload order)
    with end-to-end latency and the delivered rows."""
    entries = []               # (spec, t_submit, future)
    t0 = clock()
    for spec in workload:
        delay = spec.t_arrival - (clock() - t0)
        if delay > 0:
            sleep(delay)
        t_submit = clock()
        done_times: List[float] = []
        fut = loop.submit(spec.queries, tenant=spec.tenant)
        fut.add_done_callback(
            lambda _f, _c=clock, _d=done_times: _d.append(_c()))
        entries.append((spec, t_submit, fut, done_times))
    records = []
    for spec, t_submit, fut, done_times in entries:
        res = fut.result(timeout=timeout_s)
        t_done = done_times[0] if done_times else clock()
        records.append(_record(spec, t_submit, t_done, res))
    return records


def run_closed_loop(loop, workload: Sequence[RequestSpec], *,
                    concurrency: int = 4, clock=time.monotonic,
                    timeout_s: float = 120.0) -> List[dict]:
    """Back-to-back driver: ``concurrency`` workers each keep one
    request in flight (arrival times ignored).  Records keep workload
    order."""
    records: List[Optional[dict]] = [None] * len(workload)
    next_i = [0]
    lock = threading.Lock()

    def worker():
        while True:
            with lock:
                i = next_i[0]
                if i >= len(workload):
                    return
                next_i[0] += 1
            spec = workload[i]
            t_submit = clock()
            res = loop.submit(spec.queries,
                              tenant=spec.tenant).result(timeout=timeout_s)
            records[i] = _record(spec, t_submit, clock(), res)

    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(max(1, int(concurrency)))]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    return [r for r in records if r is not None]


def summarize(records: Sequence[dict], *, wall_s: float) -> dict:
    """Latency/throughput digest of one run: p50/p99 end-to-end
    latency, rows/requests per second over ``wall_s``, degraded-response
    rate, and mean coalescing stats.  Content (ids) is NOT summarized
    here — the bitwise gate compares rows directly."""
    if not records:
        return {"requests": 0, "rows": 0, "p50_ms": None, "p99_ms": None,
                "qps": 0.0, "rows_per_s": 0.0, "degraded_rate": 0.0,
                "mean_queue_ms": None, "mean_batch_fill": None}
    lat = np.asarray([r["latency_ms"] for r in records], dtype=np.float64)
    rows = int(sum(r["nq"] for r in records))
    queue = [r["queue_ms"] for r in records if r["queue_ms"] is not None]
    fill = [r["batch_fill"] for r in records if r["batch_fill"] is not None]
    return {
        "requests": len(records),
        "rows": rows,
        "p50_ms": float(np.percentile(lat, 50)),
        "p99_ms": float(np.percentile(lat, 99)),
        "qps": len(records) / wall_s if wall_s > 0 else 0.0,
        "rows_per_s": rows / wall_s if wall_s > 0 else 0.0,
        "degraded_rate": float(np.mean([r["degraded"] for r in records])),
        "mean_queue_ms": float(np.mean(queue)) if queue else None,
        "mean_batch_fill": float(np.mean(fill)) if fill else None,
    }
