"""The async serving loop: coalesced, multi-tenant request serving over
``repro.api.AnnEngine`` (DESIGN.md §14, docs/serving.md).

``ServingLoop`` turns the synchronous batch engines into a request
path.  Callers ``submit`` single or small-batch queries and get a
future; a background worker coalesces arrivals per *lane* — one lane
per (tenant, topk, budget) static serving configuration — and flushes
them as fixed-shape tiles:

  - every flush is padded to the lane's ``tile`` rows, so **one**
    compiled program shape serves all arrival sizes (the warm cache
    ``warm``/``_warmed`` is keyed per lane exactly like
    ``index/pipelined.py``'s per-instance plan cache: the key names the
    static configuration, jit's own signature cache holds the trace);
  - a flush fires on a full tile or on window expiry, whichever comes
    first (``repro.serve.coalescer``); oversize bursts split across
    consecutive tiles and the loop routes result rows back to each
    caller FIFO.

Scheduling never changes math: each query row's result depends only on
its own row (the per-query independence the pipelined executor's
bitwise tests established, DESIGN.md §13), and padding rows are sliced
off before delivery — so a coalesced response is bitwise-identical
(ids AND distances) to calling the same ``Searcher``/``AnnEngine``
directly on that request's rows.  tests/test_serve.py holds this for
all three index kinds; the load harness re-asserts it under Poisson
traffic (``benchmarks/run.py --only serve``).

Each delivered ``SearchResult.meta`` is the engine's ``ResultMeta``
(degradation rung, wall time, backend — the PR 6 ladder runs per
*flush*, so deadline budgets degrade real traffic) extended with the
loop's own accounting: ``queue_ms`` (submit -> flush dispatch of the
request's last part) and ``batch_fill`` (row-weighted real-rows/tile
of the flushes that served it).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Dict, Optional, Tuple

import numpy as np

from repro.index.base import SearchResult
from repro.resilience.budget import SearchBudget
from repro.serve.coalescer import (Coalescer, FlushBatch, PendingRequest,
                                   ServeError)
from repro.serve.tenants import Tenant

_DEFAULT_TILE = 32
_DEFAULT_WINDOW_MS = 2.0
_DEFAULT_MAX_QUEUE = 4096


class _Lane:
    """One static serving configuration's queue: a coalescer plus the
    per-flush call options shared by every request in it."""

    __slots__ = ("tenant", "topk", "budget", "coal")

    def __init__(self, tenant: Tenant, topk: Optional[int],
                 budget: Optional[SearchBudget], tile: int,
                 window_s: float):
        self.tenant = tenant
        self.topk = topk
        self.budget = budget
        self.coal = Coalescer(tile, window_s)


class ServingLoop:
    """Coalescing multi-tenant serving front end (module docstring).

    ``tenants``    a ``Tenant``, an iterable of them, or a name->Tenant
                   mapping (``repro.serve.load_tenants`` output).
    ``window_ms``  override every tenant's coalescing window (None =
                   per-tenant ``Tenant.window_ms``, falling back to the
                   ``ServeConfig`` default of 2 ms).
    ``tile``       override every tenant's flush tile rows likewise.
    ``max_queue``  queued-row backpressure bound across all lanes;
                   ``submit`` beyond it raises ``ServeError`` instead
                   of growing the queue without bound.

    Use as a context manager (``with ServingLoop(...) as loop:``) or
    call ``start()``/``close()`` explicitly; ``close`` drains every
    lane (pending requests are served, then the worker exits).
    """

    def __init__(self, tenants, *, window_ms: Optional[float] = None,
                 tile: Optional[int] = None,
                 max_queue: Optional[int] = None,
                 clock=time.monotonic):
        self.tenants = self._as_tenant_map(tenants)
        self._window_ms = window_ms
        self._tile = tile
        # pin each engine's canonical compiled shape to the lane tile:
        # direct engine/Searcher calls now run the same (tile, d)
        # program as coalesced flushes (AnnEngine.query_tile), which is
        # what makes the bitwise coalesced-vs-direct invariant hold —
        # XLA's reduction order (and so last-ulp distances) varies with
        # the compiled batch size
        for t in self.tenants.values():
            t.engine.query_tile = self._tile_of(t)
        self._max_queue = (_DEFAULT_MAX_QUEUE if max_queue is None
                           else int(max_queue))
        self._clock = clock
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._lanes: Dict[Tuple, _Lane] = {}
        self._ready: deque = deque()         # FlushBatch FIFO
        self._warmed: Dict[Tuple, bool] = {}
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        self.stats: Dict[str, float] = {
            "requests": 0, "rows": 0, "batches": 0, "padded_rows": 0,
            "flush_full": 0, "flush_window": 0, "flush_drain": 0}

    # ------------------------------------------------------------- setup --
    @staticmethod
    def _as_tenant_map(tenants) -> Dict[str, Tenant]:
        if isinstance(tenants, Tenant):
            tenants = [tenants]
        if isinstance(tenants, dict):
            items = list(tenants.values())
        else:
            items = list(tenants)
        if not items:
            raise ServeError("ServingLoop needs at least one tenant")
        out: Dict[str, Tenant] = {}
        for t in items:
            if not isinstance(t, Tenant):
                raise ServeError(
                    f"tenants must be repro.serve.Tenant, got "
                    f"{type(t).__name__}; wrap engines with "
                    "Tenant(name=..., engine=...)")
            if t.name in out:
                raise ServeError(f"duplicate tenant name {t.name!r}")
            out[t.name] = t
        return out

    @classmethod
    def for_engine(cls, engine, *, name: str = "default",
                   budget: Optional[SearchBudget] = None,
                   **kwargs) -> "ServingLoop":
        """Single-tenant convenience over a bare ``AnnEngine``."""
        return cls(Tenant(name=name, engine=engine, budget=budget),
                   **kwargs)

    def _tile_of(self, tenant: Tenant) -> int:
        if self._tile is not None:
            return int(self._tile)
        return int(tenant.tile) if tenant.tile is not None else _DEFAULT_TILE

    def _window_s_of(self, tenant: Tenant) -> float:
        wm = self._window_ms
        if wm is None:
            wm = (tenant.window_ms if tenant.window_ms is not None
                  else _DEFAULT_WINDOW_MS)
        return float(wm) / 1000.0

    # --------------------------------------------------------- lifecycle --
    def start(self) -> "ServingLoop":
        if self._thread is not None:
            raise ServeError("ServingLoop already started")
        self._thread = threading.Thread(target=self._run,
                                        name="repro-serve-loop",
                                        daemon=True)
        self._thread.start()
        return self

    def close(self) -> None:
        """Drain every lane and stop the worker.  Safe on an already
        closed (or never started) loop; pending requests are served
        before the worker exits (clean-shutdown contract)."""
        if self._thread is None:
            with self._cond:
                self._drain_locked()
                self._stop = True
            # never started: execute the drained flushes inline
            while True:
                with self._cond:
                    if not self._ready:
                        break
                    batch = self._ready.popleft()
                self._execute(batch)
            return
        with self._cond:
            if not self._stop:
                self._drain_locked()
                self._stop = True
            self._cond.notify_all()
        self._thread.join()
        self._thread = None

    def _drain_locked(self):
        for lane in self._lanes.values():
            self._ready.extend(lane.coal.flush_all())

    def __enter__(self) -> "ServingLoop":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------ submit --
    def _resolve_tenant(self, tenant: Optional[str]) -> Tenant:
        if tenant is None:
            if len(self.tenants) == 1:
                return next(iter(self.tenants.values()))
            raise ServeError(
                f"this loop serves {sorted(self.tenants)}; pass "
                "submit(..., tenant=NAME)")
        t = self.tenants.get(tenant)
        if t is None:
            raise ServeError(f"unknown tenant {tenant!r}; loaded: "
                             f"{sorted(self.tenants)}")
        return t

    def submit(self, queries, *, tenant: Optional[str] = None,
               k: Optional[int] = None,
               budget: Optional[SearchBudget] = None) -> Future:
        """Enqueue one request ((nq, d) raw rows, or (d,) for a single
        query) and return a future resolving to its ``SearchResult``
        (rows in request order, ``meta.queue_ms``/``meta.batch_fill``
        populated).  ``budget`` falls back to the tenant's default."""
        t = self._resolve_tenant(tenant)
        q = np.asarray(queries, dtype=np.float32)
        if q.ndim == 1:
            q = q[None, :]
        if q.ndim != 2:
            raise ServeError(
                f"queries must be (nq, d) or (d,), got shape {q.shape}")
        # embed BEFORE coalescing: per-request, so batching never
        # changes the numbers a direct Searcher.search would produce
        q = np.asarray(t.embed(q), dtype=np.float32)
        if q.shape[1] != t.d:
            raise ServeError(
                f"tenant {t.name!r} serves d={t.d} queries, got "
                f"d={q.shape[1]}")
        budget = budget if budget is not None else t.budget
        fut: Future = Future()
        with self._cond:
            if self._stop:
                raise ServeError("ServingLoop is closed")
            pending = sum(l.coal.pending_rows for l in self._lanes.values())
            if pending + q.shape[0] > self._max_queue:
                raise ServeError(
                    f"serving queue full ({pending} rows pending, "
                    f"max_queue={self._max_queue}); retry later or raise "
                    "serve.max_queue")
            now = self._clock()
            req = PendingRequest(t.name, q, k, budget, now, fut)
            lane_key = (t.name, k, budget)
            lane = self._lanes.get(lane_key)
            if lane is None:
                lane = _Lane(t, k, budget, self._tile_of(t),
                             self._window_s_of(t))
                self._lanes[lane_key] = lane
            self._ready.extend(lane.coal.submit(req, now))
            self.stats["requests"] += 1
            self.stats["rows"] += q.shape[0]
            self._cond.notify()
        return fut

    def search(self, queries, *, tenant: Optional[str] = None,
               k: Optional[int] = None,
               budget: Optional[SearchBudget] = None,
               timeout: Optional[float] = None) -> SearchResult:
        """Synchronous convenience: ``submit`` + wait."""
        return self.submit(queries, tenant=tenant, k=k,
                           budget=budget).result(timeout=timeout)

    # -------------------------------------------------------------- warm --
    def warm(self, tenant: Optional[str] = None,
             k: Optional[int] = None,
             budget: Optional[SearchBudget] = None) -> "ServingLoop":
        """Precompile one lane's tile-shaped program so the first real
        request pays dispatch, not tracing.  Keyed per (tenant, tile,
        topk, budget) like the pipelined plan cache — warming twice is
        a no-op."""
        t = self._resolve_tenant(tenant)
        key = (t.name, self._tile_of(t), k, budget)
        if self._warmed.get(key):
            return self
        eff = budget if budget is not None else t.budget
        t.engine.warm(self._tile_of(t), k,
                      budget=eff if eff is not None else None)
        self._warmed[key] = True
        return self

    # ------------------------------------------------------------ worker --
    def _run(self):
        while True:
            batch = None
            with self._cond:
                while True:
                    now = self._clock()
                    for lane in self._lanes.values():
                        self._ready.extend(lane.coal.poll(now))
                    if self._ready:
                        batch = self._ready.popleft()
                        break
                    if self._stop:
                        return
                    deadlines = [lane.coal.next_deadline()
                                 for lane in self._lanes.values()]
                    deadlines = [d for d in deadlines if d is not None]
                    timeout = (max(min(deadlines) - now, 0.0)
                               if deadlines else None)
                    self._cond.wait(timeout=timeout)
            self._execute(batch)

    def _execute(self, batch: FlushBatch):
        """Serve one flush tile and route result rows back to each
        request; engine failures fail exactly the requests in the
        flush (the worker survives)."""
        lane_tenant = self.tenants[batch.slices[0].request.tenant]
        topk = batch.slices[0].request.topk
        budget = batch.slices[0].request.budget
        t_flush = self._clock()
        try:
            q = batch.queries()
            if batch.rows < batch.tile:         # pad to the compiled tile
                pad = np.zeros((batch.tile - batch.rows, q.shape[1]),
                               dtype=q.dtype)
                q = np.concatenate([q, pad], axis=0)
            res = lane_tenant.engine.search(q, topk, budget=budget)
            ids = np.asarray(res.indices)
            dists = np.asarray(res.distances)
        except Exception as e:                  # noqa: BLE001
            for s in batch.slices:
                if not s.request.future.done():
                    s.request.future.set_exception(e)
            return
        self.stats["batches"] += 1
        self.stats["padded_rows"] += batch.tile - batch.rows
        self.stats[f"flush_{batch.reason}"] += 1
        for s in batch.slices:
            req = s.request
            done = req.deliver(
                s.req_start,
                ids[s.batch_start:s.batch_start + s.rows],
                dists[s.batch_start:s.batch_start + s.rows],
                res, batch.fill)
            if not done:
                continue
            r_ids, r_dists, last, fill = req.assemble()
            meta = last.meta
            if meta is not None:
                meta = meta._replace(
                    queue_ms=(t_flush - req.t_submit) * 1000.0,
                    batch_fill=fill)
            req.t_done = self._clock()
            if not req.future.done():
                req.future.set_result(SearchResult(
                    indices=r_ids, distances=r_dists,
                    avg_ops=last.avg_ops, pass_rate=last.pass_rate,
                    meta=meta))
