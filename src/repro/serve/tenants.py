"""Multi-tenant sessions: several loaded indexes behind one serving
process (docs/serving.md).

A ``Tenant`` names one serving engine (an ``repro.api.AnnEngine``,
optionally with an embedding model in front of it) plus its per-tenant
serving defaults: the default ``SearchBudget`` applied to requests that
do not carry one, and the coalescing tile/window the loop uses for its
lanes (read from the artifact's embedded ``ServeConfig`` when the
tenant is loaded from disk).

``load_tenants`` is the multi-artifact front door behind
``launch/serve.py --serve-loop --tenant name=dir``: each spec runs
through ``repro.api.load_ann_engine`` (one shared mesh across all
tenants — shards share devices, never processes), and duplicate or
conflicting specs fail up front with a one-line actionable error
instead of silently double-loading the same Artifacts directory.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.resilience.budget import SearchBudget
from repro.serve.coalescer import ServeError


@dataclasses.dataclass
class Tenant:
    """One tenant's serving surface inside a ``ServingLoop``.

    ``engine``      the query server (``repro.api.AnnEngine``).
    ``model``       optional embedder: when set, raw request rows are
                    embedded at submit time (per request, before
                    coalescing — so batching never changes the math a
                    direct ``Searcher.search`` would run).
    ``budget``      default ``SearchBudget`` for requests without one
                    (on top of the engine's own ``ResilienceConfig``
                    deadline default).
    ``tile``        coalescing tile rows (None = the loop's default).
    ``window_ms``   coalescing window (None = the loop's default).
    """
    name: str
    engine: object
    model: Optional[object] = None
    budget: Optional[SearchBudget] = None
    tile: Optional[int] = None
    window_ms: Optional[float] = None

    def __post_init__(self):
        if not self.name or "=" in self.name:
            raise ServeError(
                f"tenant name {self.name!r} must be a non-empty string "
                "without '='")

    @property
    def d(self) -> int:
        """The engine-side (embedded) query dimension."""
        return int(self.engine.index.C.shape[-1])

    def embed(self, queries):
        """Raw request rows -> engine-space rows (identity without a
        model)."""
        if self.model is None:
            return queries
        return self.model.embed(queries)

    # ------------------------------------------------------ constructors --
    @classmethod
    def from_artifacts(cls, name: str, path: str, *, mesh=None,
                       overrides=None, budget: Optional[SearchBudget] = None,
                       fault_injector=None) -> "Tenant":
        """Open one saved artifact directory as a tenant: the engine via
        ``repro.api.load_ann_engine`` (inheriting the embedded
        ``ResilienceConfig``), the coalescing knobs from the embedded
        ``ServeConfig`` (``batch_tile`` / ``batch_window_ms``)."""
        from repro.api import Artifacts, load_ann_engine

        engine = load_ann_engine(path, mesh=mesh, overrides=overrides,
                                 fault_injector=fault_injector)
        cfg = Artifacts.load(path, overrides=overrides).config
        return cls(name=name, engine=engine, budget=budget,
                   tile=cfg.serve.batch_tile,
                   window_ms=cfg.serve.batch_window_ms)

    @classmethod
    def from_searcher(cls, name: str, searcher, *,
                      budget: Optional[SearchBudget] = None) -> "Tenant":
        """Wrap a live ``repro.api.Searcher`` (model + engine): the loop
        embeds raw rows exactly as ``searcher.search`` would."""
        cfg = searcher.config.serve
        return cls(name=name, engine=searcher.engine,
                   model=searcher.model, budget=budget,
                   tile=cfg.batch_tile, window_ms=cfg.batch_window_ms)


def parse_tenant_specs(specs: Sequence[str]) -> List[Tuple[str, str]]:
    """``["name=path", ...]`` -> ``[(name, path), ...]`` with the
    duplicate/conflict checks the CLI relies on (one-line errors):

      - malformed specs (no '=', empty halves) are rejected by name;
      - two specs with the same tenant name are rejected;
      - two specs whose paths resolve to the same directory are
        rejected — loading one Artifacts dir twice doubles device
        memory for bitwise-identical answers, so it is always a typo.
    """
    out: List[Tuple[str, str]] = []
    seen_names: Dict[str, str] = {}
    seen_paths: Dict[str, str] = {}
    for spec in specs:
        name, eq, path = str(spec).partition("=")
        if not eq or not name or not path:
            raise ServeError(
                f"tenant spec {spec!r} must be NAME=ARTIFACTS_DIR "
                "(e.g. --tenant prod=/models/prod)")
        if name in seen_names:
            raise ServeError(
                f"duplicate tenant name {name!r} ({seen_names[name]!r} "
                f"vs {path!r}); give each --tenant a unique name")
        real = os.path.realpath(path)
        if real in seen_paths:
            raise ServeError(
                f"tenants {seen_paths[real]!r} and {name!r} both point "
                f"at {path!r}; load each Artifacts dir once and route "
                "requests by tenant name instead")
        seen_names[name] = path
        seen_paths[real] = name
        out.append((name, path))
    return out


def load_tenants(specs: Sequence[str], *, mesh=None, overrides=None,
                 fault_injector=None) -> Dict[str, Tenant]:
    """Validate + load ``NAME=DIR`` specs into a tenant map sharing one
    mesh.  Raises ``ServeError`` before any loading when the specs
    conflict (``parse_tenant_specs``)."""
    tenants: Dict[str, Tenant] = {}
    for name, path in parse_tenant_specs(specs):
        tenants[name] = Tenant.from_artifacts(
            name, path, mesh=mesh, overrides=overrides,
            fault_injector=fault_injector)
    return tenants
