"""AdamW + Adafactor (no optax in this environment).

Moments can be stored in a reduced dtype (``moment_dtype='bfloat16'``) —
quantized optimizer state, required to fit llama3-405b training on a
v5e-256 pod and consistent with the paper's compression theme.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[Any], Any]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    # OPTIONAL: scan the elementwise update over the leading (stacked-
    # layers) axis of big leaves.  Hypothesis was that it bounds the fp32
    # m/v/delta temporaries; MEASURED REFUTED on llama3-405b (+10 GB):
    # XLA already fuses the elementwise chain into one loop with donated
    # in-place buffers, while scan ys cannot alias the donated inputs.
    # Kept as an opt-in for non-fusing backends (EXPERIMENTS.md §Perf).
    scan_update_ndim: int = 3
    scan_update_min_elems: int = 1 << 60

    def init(self, params):
        mk = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return {
            "m": jax.tree.map(mk, params),
            "v": jax.tree.map(mk, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        gnorm = global_norm(grads)
        # clip folded into the elementwise update (a standalone
        # clip_by_global_norm materializes a full fp32 copy of the grads
        # — 6.3 GB/device on llama3-405b)
        scale = (jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
                 if self.clip_norm else jnp.float32(1.0))
        b1, b2 = self.b1, self.b2
        mdt = jnp.dtype(self.moment_dtype)

        def upd_flat(g, m, v, p):
            g32 = g.astype(jnp.float32) * scale
            m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
            v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
            mhat = m32 / (1 - b1 ** step.astype(jnp.float32))
            vhat = v32 / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:   # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - self.lr(step) * delta
            return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

        def upd(g, m, v, p):
            if (p.ndim >= self.scan_update_ndim
                    and p.size >= self.scan_update_min_elems):
                def body(_, slc):
                    return None, upd_flat(*slc)
                _, (np_, nm, nv) = jax.lax.scan(body, None, (g, m, v, p))
                return np_, nm, nv
            return upd_flat(g, m, v, p)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm


@dataclasses.dataclass(frozen=True)
class Adafactor:
    """Factored second-moment optimizer — O(n) -> O(rows+cols) state for
    matrices; the memory-frugal alternative at extreme scale."""
    lr: Callable[[Any], Any]
    decay: float = 0.8
    eps: float = 1e-30
    clip_norm: float = 1.0

    def init(self, params):
        def mk(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(mk, params, is_leaf=lambda x: hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params):
        step = state["step"] + 1
        if self.clip_norm:
            grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        else:
            gnorm = global_norm(grads)
        beta = 1.0 - step.astype(jnp.float32) ** -self.decay

        def upd(g, f, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + self.eps
            if p.ndim >= 2:
                vr = f["vr"] * beta + g2.mean(-1) * (1 - beta)
                vc = f["vc"] * beta + g2.mean(-2) * (1 - beta)
                denom = (vr[..., None] / jnp.maximum(
                    vr.mean(-1, keepdims=True)[..., None], self.eps)) * vc[..., None, :]
                delta = g32 / jnp.sqrt(jnp.maximum(denom, self.eps))
                nf = {"vr": vr, "vc": vc}
            else:
                v = f["v"] * beta + g2 * (1 - beta)
                delta = g32 / jnp.sqrt(jnp.maximum(v, self.eps))
                nf = {"v": v}
            newp = p.astype(jnp.float32) - self.lr(step) * delta
            return newp.astype(p.dtype), nf

        is_f = lambda t: isinstance(t, dict) and ("vr" in t or "v" in t)
        out = jax.tree.map(upd, grads, state["f"], params, is_leaf=lambda x: hasattr(x, "shape"))
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_f = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"f": new_f, "step": step}, gnorm


def make_optimizer(cfg, total_steps: int = 10000, base_lr: float = 3e-4):
    return AdamW(lr=cosine_schedule(base_lr, warmup=min(2000, total_steps // 10 + 1),
                                    total=total_steps),
                 moment_dtype=cfg.optimizer_dtype)
