"""Unified trainer layer (DESIGN.md §9): one ``Quantizer`` protocol
(``init``/``step``/``finalize``), the joint ICQ trainer plus the
baseline quantizers behind it, the scan-compiled (optionally
mesh-sharded) epoch driver, and the tiled database encoder.

    from repro.trainer import fit, make_quantizer, encode_database
    model = fit(key, xs, ys, cfg, mode="icq", epochs=6)       # scan epochs
    q = make_quantizer("cq", cfg); st = q.init(key, xs)       # protocol
    codes = encode_database(emb_new, model.C)                 # engine

``core.train`` and ``core.baselines.*`` re-export everything here for
backward compatibility; new code should import from ``repro.trainer``.
The config-driven facade over this layer (``repro.api.icq_session``:
one ``ICQConfig`` drives fit → index → search → save, docs/api.md)
re-exports ``fit`` / ``make_quantizer`` / ``encode_database`` at the
package root.
"""
from repro.trainer.base import ICQModel, Quantizer, plain_structure
from repro.trainer.encode import encode_database
from repro.trainer.epoch import compile_epoch, epoch_batches, fit
from repro.trainer.joint import (finalize, init_train_state,
                                 make_train_step)
from repro.trainer.quantizers import (CQQuantizer, JointQuantizer,
                                      OPQQuantizer, PQQuantizer, fit_cq,
                                      fit_opq, fit_pq)

QUANTIZER_KINDS = {
    "icq": lambda cfg, **o: JointQuantizer(cfg, mode="icq", **o),
    "sq": lambda cfg, **o: JointQuantizer(cfg, mode="cq", **o),
    "pqn": lambda cfg, **o: JointQuantizer(cfg, mode="pq", **o),
    "pq": PQQuantizer,
    "opq": OPQQuantizer,
    "cq": CQQuantizer,
}


def make_quantizer(kind: str, icq_cfg, **opts) -> Quantizer:
    """Build a quantizer by name: the joint trainer modes ("icq", "sq",
    "pqn") or the unsupervised baselines ("pq", "opq", "cq")."""
    try:
        ctor = QUANTIZER_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown quantizer kind {kind!r}; expected one "
                         f"of {sorted(QUANTIZER_KINDS)}") from None
    return ctor(icq_cfg, **opts)


__all__ = [
    "ICQModel", "Quantizer", "QUANTIZER_KINDS", "JointQuantizer",
    "PQQuantizer", "OPQQuantizer", "CQQuantizer", "make_quantizer",
    "fit", "finalize", "init_train_state", "make_train_step",
    "compile_epoch", "epoch_batches", "encode_database",
    "plain_structure", "fit_pq", "fit_opq", "fit_cq",
]
