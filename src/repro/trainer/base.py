"""Trainer-layer foundations: the ``Quantizer`` protocol and the shared
``ICQModel`` fitted artifact (DESIGN.md §9).

The trainer layer is the producer-side twin of the index layer (§7):
where every index speaks ``build / search / shard``, every quantizer —
the joint ICQ trainer and the PQ / OPQ / CQ / SQ / PQN baselines —
speaks the same three-verb protocol:

    init(key, xs, ys)   -> state     seed codebooks / embedding / prior
    step(state, batch)  -> state     one optimization step or round
    finalize(state, xs) -> ICQModel  export: project, encode db, pack

so drivers (``trainer.epoch.fit``, ``launch/train.py --icq``,
benchmark harnesses) select a quantizer by name via
``trainer.make_quantizer`` and never touch trainer internals.  ``state``
is a plain dict; its array leaves form a pytree (jit/scan/donation
friendly) and non-array entries (jitted step fns, static config) ride
along untouched.

``finalize`` always exports through the tiled encoding engine
(``trainer.encode.encode_database``): fixed-shape padded chunks (one
compile), ICM for additive codebooks / independent assignment for PQ,
codes packed to the narrowest dtype that fits m.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Protocol, runtime_checkable

import jax.numpy as jnp


@dataclasses.dataclass
class ICQModel:
    """Fitted artifact: everything the search side needs."""
    icq_cfg: Any
    embed_params: Any
    embed_apply: Callable
    C: jnp.ndarray               # (K,m,d) — hard-projected for mode="icq"
    codes: jnp.ndarray           # (n,K) database codes (ICM-encoded, packed)
    structure: Any               # core.icq.ICQStructure
    lam: jnp.ndarray             # (d,) final variance estimate
    mode: str = "icq"

    def embed(self, x):
        return self.embed_apply(self.embed_params, x)


@runtime_checkable
class Quantizer(Protocol):
    """The unified quantizer protocol (DESIGN.md §9)."""

    def init(self, key, xs, ys=None) -> Dict:
        ...

    def step(self, state: Dict, batch) -> Dict:
        ...

    def finalize(self, state: Dict, xs) -> ICQModel:
        ...


def plain_structure(C, d: int):
    """The degenerate structure non-interleaved quantizers export: every
    dimension in psi, every codebook fast, zero margin — one-step ADC
    semantics through the shared search API.  Returns an
    ``core.icq.ICQStructure`` (imported lazily: this module is the
    trainer layer's import root and must stay core-free so
    ``repro.trainer`` and ``repro.core`` can import in either order)."""
    from repro.core import icq as icq_mod

    return icq_mod.ICQStructure(
        xi=jnp.ones((d,), bool),
        fast_mask=jnp.ones((C.shape[0],), bool),
        sigma=jnp.zeros(()))
