"""Database encoding through the tiled engine (DESIGN.md §9): embed +
encode in fixed-shape padded chunks, pack to the narrowest dtype.

The seed export loop embedded and encoded raw-size chunks, so the
ragged last chunk re-jitted the encode function (a full ICM trace +
compile for one partial batch).  ``encode_database`` compiles exactly
one (chunk, ...)-shaped embed+encode function, zero-pads the final
chunk up to that shape, and masks the pad rows out of the stored codes.
Per-point independence of both encoders (PQ argmin and the ICM residual
recurrence) means padding never changes a real row's codes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encode as enc


def encode_database(xs, C, *, embed_apply=None, embed_params=None,
                    mode: str = "icm", icm_iters: int = 3,
                    chunk: int = 8192, backend: str = "auto",
                    block_n: int = 1024, interpret=None,
                    pack: bool = True, code_bits: int = 8):
    """Encode a database against codebooks ``C`` -> (n, K) packed codes
    ((n, ceil(K/2)) nibble-packed under ``code_bits=4``).

    xs:           (n, ...) raw inputs (numpy or jnp); embedded per chunk
                  with ``embed_apply(embed_params, chunk)`` when given,
                  else taken as embeddings directly.
    C:            (K, m, d) codebooks.
    mode:         "icm" (additive codebooks — the tiled ICM engine,
                  PQ-warm-started) | "pq" (independent per-codebook
                  assignment; exact for orthogonal supports).
    chunk:        rows per jitted call; the last chunk is zero-padded up
                  to this size (one compile for the whole database).
    backend:      engine dispatch for the ICM sweeps
                  ("jnp" | "pallas" | "auto").
    block_n:      pallas point-tile size.
    pack:         pack to the narrowest dtype that fits m
                  (``encode.pack_codes``); False returns int32.
    code_bits:    8 (default) packs one code per byte/uint16; 4 packs
                  two codes per byte (``encode.pack_nibbles``, requires
                  m <= 16 and pack=True) — the fast-scan storage format
                  (DESIGN.md §12).
    """
    from repro.index.base import resolve_code_bits

    code_bits = resolve_code_bits(code_bits)
    n = xs.shape[0]
    m = C.shape[1]
    if code_bits == 4:
        if not pack:
            raise ValueError("code_bits=4 requires pack=True (nibble "
                             "packing is the 4-bit storage format)")
        if m > 16:
            raise ValueError(f"code_bits=4 requires codebook_size <= 16 "
                             f"codewords (4-bit codes), got m={m}")
    chunk = max(min(chunk, n), 1)

    @jax.jit
    def enc_chunk(xc):
        emb = (embed_apply(embed_params, xc) if embed_apply is not None
               else xc)
        if mode == "pq":
            return enc.encode_pq(emb, C)
        return enc.icm_encode(emb, C, icm_iters, backend=backend,
                              block_n=block_n, interpret=interpret)

    parts = []
    for s in range(0, n, chunk):
        xc = xs[s: s + chunk]
        if xc.shape[0] < chunk:                 # pad the ragged last chunk
            pad = [(0, chunk - xc.shape[0])] + [(0, 0)] * (xs.ndim - 1)
            xc = (np.pad(np.asarray(xc), pad) if isinstance(xc, np.ndarray)
                  else jnp.pad(xc, pad))
        parts.append(enc_chunk(jnp.asarray(xc)))
    codes = jnp.concatenate(parts, axis=0)[:n]  # mask pad rows out
    if code_bits == 4:
        return enc.pack_nibbles(codes, C.shape[0])
    return enc.pack_codes(codes, m) if pack else codes
