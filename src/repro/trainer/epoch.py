"""Compiled epoch driver (DESIGN.md §9): ``lax.scan`` over pre-permuted
device-resident batches with donated train state, optionally
data-parallel over a mesh's ``data`` axis.

The seed ``fit`` dispatched one jitted step per batch from the host —
per-batch dispatch overhead, host-side fancy indexing for every batch,
and a hardcoded shuffle seed.  This driver:

  1. threads the *caller's* key: one split for init, one chain for the
     per-epoch permutations, so runs are actually seeded;
  2. permutes on device and reshapes into an (nb, bs, ...) batch stack,
     then runs the whole epoch as ONE compiled ``lax.scan`` with the
     train state donated (``jit(..., donate_argnums)``) — no per-batch
     host round-trips, no buffer churn;
  3. with ``mesh`` given (must carry a ``data`` axis), wraps the epoch
     in ``shard_map``: the batch dimension of every scan step is
     sharded over ``data``, the step pmeans gradients and consumes
     global batch moments (``make_train_step(axis_name="data")``), and
     parameters / optimizer / variance state stay replicated — the
     ``distributed/sharding.py`` shims handle jax-version differences.

Fresh variance state per epoch (the seed semantics) is kept: Lambda
tracks the *current* embedding distribution, not a stale average.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import variance
from repro.distributed.sharding import axis_size, shard_map_compat
from repro.trainer import joint
from repro.trainer.base import ICQModel


def compile_epoch(step, d: int, *, mesh=None, donate: bool = True):
    """Compile ``step`` into an epoch function.

    step:  (params, opt_state, var_state, (x, y)) -> (params, opt_state,
           var_state, metrics) — from ``joint.make_train_step`` (built
           with ``axis_name="data"`` when ``mesh`` is given).
    d:     embedding dim (fresh variance state per epoch).

    Returns ``epoch_fn(params, opt_state, xb, yb)`` -> (params,
    opt_state, var_state, last_metrics) where xb (nb, bs, ...) /
    yb (nb, bs) are the epoch's pre-permuted batch stacks.  The input
    params/opt_state buffers are donated.
    """
    def epoch_body(params, opt_state, xb, yb):
        def body(carry, batch):
            p, o, v = carry
            p, o, v, mets = step(p, o, v, batch)
            return (p, o, v), mets

        carry0 = (params, opt_state, variance.init_state(d))
        (p, o, v), mets = jax.lax.scan(body, carry0, (xb, yb))
        return p, o, v, jax.tree.map(lambda a: a[-1], mets)

    fn = epoch_body
    if mesh is not None:
        if "data" not in mesh.axis_names:
            raise ValueError("epoch driver needs a mesh with a 'data' axis")
        fn = shard_map_compat(
            epoch_body, mesh,
            in_specs=(P(), P(), P(None, "data"), P(None, "data")),
            out_specs=(P(), P(), P(), P()))
    return jax.jit(fn, donate_argnums=(0, 1) if donate else ())


def epoch_batches(key, xs, ys, batch_size: int):
    """Device-side permute + reshape into the epoch's batch stacks.

    Returns (xb (nb, bs, ...), yb (nb, bs)) with nb = n // bs full
    batches (the permutation's tail rows beyond nb*bs are dropped for
    this epoch, as in the seed loop)."""
    n = xs.shape[0]
    bs = max(min(batch_size, n), 1)
    nb = n // bs
    perm = jax.random.permutation(key, n)[: nb * bs]
    xb = jnp.asarray(xs)[perm].reshape((nb, bs) + xs.shape[1:])
    yb = jnp.asarray(ys)[perm].reshape((nb, bs))
    return xb, yb


def fit(key, xs, ys, icq_cfg, *, embed_kind="linear", num_classes=10,
        img_hw=None, channels=None, mode="icq", epochs=5, batch_size=256,
        lr=1e-3, tau=1.0, verbose=False, mesh=None,
        encode_batch: int = 8192, encode_backend: str = "auto",
        donate: bool = True, ckpt_dir: Optional[str] = None,
        save_every: int = 1, max_restarts: int = 3, heartbeat=None,
        fault_hook=None) -> ICQModel:
    """Scan-compiled training over (xs, ys) arrays -> fitted ICQModel.

    The drop-in successor of the seed host loop: same losses, same
    state transitions, but the whole epoch runs as one compiled scan
    (donated state) and the shuffle stream is derived from ``key`` —
    two calls with different keys draw different permutations and
    different init, two calls with the same key are identical.

    mesh:  optional mesh with a ``data`` axis — data-parallel training
           via shard_map with pmean'd gradients; ``batch_size`` must
           divide by the axis size.  Results match single-device
           training up to float reassociation.

    ckpt_dir (docs/robustness.md): supervised training — the epoch
    loop runs under ``distributed.TrainSupervisor`` with per-epoch
    checkpoints every ``save_every`` epochs, NaN-epoch quarantine, and
    up to ``max_restarts`` restore-and-replay restarts.  A killed fit
    re-invoked with the *same key and data* resumes from the newest
    checkpoint and produces bitwise-identical final codebooks: the
    checkpointed state carries the post-epoch rng, so the replayed
    shuffle chain is exactly the uninterrupted one.  Donation is
    disabled (restart replay needs the pre-epoch buffers alive).
    ``heartbeat`` (a ``distributed.HeartbeatMonitor``) gets a
    ``beat(0, epoch_seconds)`` per epoch; ``fault_hook(epoch)`` may
    raise to inject node loss (the chaos tests drive it).
    """
    n = xs.shape[0]
    d_raw = xs.shape[-1] if xs.ndim == 2 else None
    k_init, k_shuffle = jax.random.split(key)
    state = joint.init_train_state(
        k_init, icq_cfg, embed_kind=embed_kind, d_raw=d_raw,
        num_classes=num_classes, img_hw=img_hw, channels=channels,
        mode=mode, lr=lr,
        sample_batch=(xs[:min(n, 4096)], ys[:min(n, 4096)]))
    axis = "data" if mesh is not None else None
    bs = max(min(batch_size, n), 1)
    if mesh is not None and bs % axis_size(mesh, "data") != 0:
        raise ValueError(
            f"batch_size={bs} must divide over the {axis_size(mesh, 'data')}"
            "-way 'data' axis for the sharded epoch driver")
    step = joint.make_train_step(icq_cfg, state["embed_apply"], state["opt"],
                                 mode, state["pq_mask"], tau, axis_name=axis)
    if ckpt_dir is not None:
        donate = False        # restart replay needs pre-epoch buffers
    epoch_fn = compile_epoch(step, icq_cfg.d, mesh=mesh, donate=donate)

    if ckpt_dir is not None:
        params, var_state = _supervised_loop(
            ckpt_dir, epoch_fn, state, k_shuffle, xs, ys, bs, epochs,
            save_every=save_every, max_restarts=max_restarts,
            heartbeat=heartbeat, fault_hook=fault_hook, verbose=verbose)
    else:
        params, opt_state = state["params"], state["opt_state"]
        var_state = state["var_state"]
        rng = k_shuffle
        for ep in range(epochs):
            rng, k = jax.random.split(rng)
            xb, yb = epoch_batches(k, xs, ys, bs)
            params, opt_state, var_state, mets = epoch_fn(params, opt_state,
                                                          xb, yb)
            if verbose:
                print(f"  epoch {ep}: " + " ".join(
                    f"{name}={float(v):.4f}" for name, v in mets.items()))
    return joint.finalize(params, state["embed_apply"], var_state, icq_cfg,
                          xs, mode=mode, encode_batch=encode_batch,
                          encode_backend=encode_backend)


def _supervised_loop(ckpt_dir, epoch_fn, state, k_shuffle, xs, ys, bs,
                     epochs, *, save_every, max_restarts, heartbeat,
                     fault_hook, verbose):
    """Run the epoch loop under ``TrainSupervisor`` (one supervisor
    step == one epoch).  Returns (params, var_state) after the final
    epoch — resumed or not, the state transitions are the ones the
    plain loop would have made."""
    import time

    from repro.distributed import CheckpointManager, TrainSupervisor

    sup = TrainSupervisor(CheckpointManager(ckpt_dir),
                          save_every=save_every,
                          max_restarts=max_restarts, async_save=False)

    def step_fn(s, ep):
        t0 = time.perf_counter()
        rng, k = jax.random.split(s["rng"])
        xb, yb = epoch_batches(k, xs, ys, bs)
        params, opt_state, var_state, mets = epoch_fn(
            s["params"], s["opt_state"], xb, yb)
        jax.block_until_ready(params)
        if heartbeat is not None:
            heartbeat.beat(0, time.perf_counter() - t0)
        if verbose:
            print(f"  epoch {ep}: " + " ".join(
                f"{name}={float(v):.4f}" for name, v in mets.items()))
        # the supervisor's NaN quarantine reads 'loss'; the joint
        # trainer calls its total 'total'
        metrics = dict(mets)
        metrics["loss"] = metrics.get("total", 0.0)
        return ({"params": params, "opt_state": opt_state,
                 "var_state": var_state, "rng": rng}, metrics)

    state0 = {"params": state["params"], "opt_state": state["opt_state"],
              "var_state": state["var_state"], "rng": k_shuffle}
    final, _report = sup.run(state0, step_fn, epochs,
                             fault_hook=fault_hook)
    return final["params"], final["var_state"]
