"""Joint embedding + quantizer training (paper §3.1-3.3) — the
trainer-layer home of what used to be ``core/train.py`` (now a thin
re-export, mirroring how PR 2 folded ``core/search.py`` into the index
layer).

One trainer covers ICQ and the ablation/baseline modes by switching the
active loss terms (paper eq. 3 augmented):

    mode="icq":  L^E + L^C + gamma1 L^P + gamma2 L^ICQ (+ CQ penalty)
    mode="cq":   L^E + L^C + CQ penalty          (SQ = linear embed + cq)
    mode="pq":   L^E + L^C with codebooks hard-projected onto contiguous
                 subspaces after every step (PQ/PQN-style)

Gradient flow notes:
- Lambda is the *online* variance estimate (eq. 9, core.variance); its
  value comes from the running state but its gradient flows through the
  current batch's sample variance (straight-through running stats), so
  L^P shapes the embedding W as intended.
- xi is hard for search but L^ICQ uses the prior's soft responsibilities
  (minor-mode posterior) so the interleaving penalty stays differentiable
  in Theta.
- L^C uses straight-through soft assignments (core.encode.st_decode);
  codebooks get dense gradients, embeddings see the hard reconstruction.

The step is pure JAX; drivers compile it either per-batch (host loop)
or as a whole epoch (``trainer.epoch`` — ``lax.scan`` over
device-resident batches with donated state, DESIGN.md §9).  With
``axis_name`` set the step is data-parallel-ready: gradients are
pmean'd over the mesh axis and the Lambda update consumes *global*
batch moments, so every shard applies the identical state transition.
Encode-side ICM re-encoding happens at export time (``finalize``)
through the tiled encoding engine.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import codebooks as cb
from repro.core import embed as embed_mod
from repro.core import icq as icq_mod
from repro.core import losses
from repro.core import prior as prior_mod
from repro.core import variance
from repro.trainer.base import ICQModel
from repro.train.optimizer import AdamW


def _pq_support_mask(K: int, d: int):
    """(K,d) 0/1 contiguous-subspace masks (PQ)."""
    assert d % K == 0
    sub = d // K
    m = jnp.zeros((K, d))
    for k in range(K):
        m = m.at[k, k * sub:(k + 1) * sub].set(1.0)
    return m


def init_train_state(key, icq_cfg, *, embed_kind: str = "linear",
                     d_raw: Optional[int] = None, num_classes: int = 10,
                     img_hw: Optional[int] = None, channels: Optional[int] = None,
                     mode: str = "icq", lr: float = 1e-3,
                     sample_batch=None) -> Dict:
    """Build params + optimizer + variance state.  ``sample_batch`` (x, y)
    seeds the codebooks from real embeddings (residual k-means)."""
    d, K, m = icq_cfg.d, icq_cfg.num_codebooks, icq_cfg.codebook_size
    k_embed, k_cb, k3 = jax.random.split(key, 3)
    embed_params, embed_apply = embed_mod.build_embedder(
        embed_kind, k_embed, d_raw=d_raw, d=d, num_classes=num_classes,
        img_hw=img_hw, channels=channels)

    theta0 = prior_mod.init_theta()
    if sample_batch is not None:
        emb0 = embed_apply(embed_params, sample_batch[0])
        if mode == "pq":
            C0 = cb.init_pq(k_cb, emb0, K, m)
        else:
            C0 = cb.init_residual(k_cb, emb0, K, m)
        theta0 = prior_mod.init_theta_from_data(jnp.var(emb0, axis=0))
    else:
        C0 = jax.random.normal(k_cb, (K, m, d), jnp.float32) * 0.1

    params = {"embed": embed_params, "C": C0, "theta": theta0}
    opt = AdamW(lr=lambda step: jnp.asarray(lr, jnp.float32),
                weight_decay=0.0, clip_norm=1.0)
    return {
        "params": params,
        "opt_state": opt.init(params),
        "var_state": variance.init_state(d),
        "opt": opt,
        "embed_apply": embed_apply,
        "mode": mode,
        "pq_mask": _pq_support_mask(K, d) if mode == "pq" else None,
    }


def _soft_xi(lam, theta, icq_cfg):
    """Minor-mode posterior responsibility — the differentiable xi."""
    log_major, log_minor = prior_mod.mode_log_components(
        lam, theta, pi1=icq_cfg.pi1, pi2=icq_cfg.pi2, alpha2=icq_cfg.alpha2)
    return jax.nn.sigmoid(log_minor - log_major)


def make_train_step(icq_cfg, embed_apply, opt: AdamW, mode: str,
                    pq_mask=None, tau: float = 1.0,
                    axis_name: Optional[str] = None):
    """Returns jit-able step(params, opt_state, var_state, batch) ->
    (params, opt_state, var_state, metrics).

    ``axis_name`` (optional): the mesh axis of a data-parallel region
    the step runs inside (``trainer.epoch`` shard_map driver).  Batch
    moments for the Lambda update become global (pmean of shard
    moments — exact for the driver's equal shards) and gradients are
    pmean'd, so parameters and variance state stay replicated without
    any extra synchronization."""

    def loss_fn(params, var_state, x, y):
        emb = embed_apply(params["embed"], x)
        # --- L^E ---
        logits = embed_mod.classify(params["embed"], emb)
        l_e = losses.classification_loss(logits, y)
        # --- online variance with straight-through running value ---
        m_b, lam_batch = variance.global_batch_moments(emb, axis_name)
        nb = emb.shape[0] if axis_name is None else (
            emb.shape[0] * jax.lax.psum(1, axis_name))
        new_var = variance.update_from_moments(var_state, m_b, lam_batch, nb)
        lam = (jax.lax.stop_gradient(variance.lambda_hat(new_var) - lam_batch)
               + lam_batch)
        # --- L^C ---
        l_c, codes = losses.quantization_loss(emb, params["C"], tau)
        total = l_e + l_c
        mets = {"l_e": l_e, "l_c": l_c}
        if mode in ("icq", "cq"):
            l_cq, _ = losses.cq_penalty(params["C"], codes)
            total = total + icq_cfg.gamma_cq * l_cq
            mets["l_cq"] = l_cq
        if mode == "icq":
            l_p = prior_mod.nll(lam, params["theta"], pi1=icq_cfg.pi1,
                                pi2=icq_cfg.pi2, alpha2=icq_cfg.alpha2)
            xi_soft = _soft_xi(jax.lax.stop_gradient(lam), params["theta"],
                               icq_cfg)
            l_icq = losses.icq_loss(params["C"], xi_soft)
            total = total + icq_cfg.gamma_p * l_p + icq_cfg.gamma_icq * l_icq
            mets.update(l_p=l_p, l_icq=l_icq, psi_size=jnp.sum(xi_soft > 0.5))
        mets["total"] = total
        return total, (new_var, mets)

    def step(params, opt_state, var_state, batch):
        x, y = batch
        grads, (new_var, mets) = jax.grad(loss_fn, has_aux=True)(
            params, var_state, x, y)
        if axis_name is not None:
            # data-parallel: mean-of-shard-grads == grad of the global
            # batch mean loss (equal shard sizes); metrics follow suit
            grads = jax.lax.pmean(grads, axis_name)
            mets = jax.lax.pmean(mets, axis_name)
        if mode == "icq":
            # Theta must track the (moving) variance distribution faster
            # than W reshapes it, or the mixture collapses to one mode
            # (§3.3); 3 scalars, so the boosted rate is cheap and safe.
            grads = dict(grads, theta=jax.tree.map(
                lambda g: g * 10.0, grads["theta"]))
        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        if mode == "pq":                      # hard support projection
            params = dict(params, C=params["C"] * pq_mask[:, None, :])
        mets["gnorm"] = gnorm
        return params, opt_state, new_var, mets

    return step


def finalize(params, embed_apply, var_state, icq_cfg, xs, *, mode="icq",
             encode_batch: int = 8192, encode_backend: str = "auto",
             interpret=None) -> ICQModel:
    """Export: hard-project codebooks (ICQ), ICM-encode the database
    through the tiled engine (DESIGN.md §9), build the search structure.

    ``encode_batch`` chunks the database through one fixed-shape jitted
    embed+encode function — the ragged last chunk is zero-padded up to
    the chunk size and the pad rows masked out of the stored codes, so
    the encode function compiles exactly once.  ``encode_backend``
    follows the engine dispatch ("jnp" | "pallas" | "auto")."""
    from repro.trainer.encode import encode_database

    lam = variance.lambda_hat(var_state)
    C = params["C"]
    if mode == "icq":
        structure = icq_mod.build_structure(C, lam, params["theta"], icq_cfg)
        C = icq_mod.project_codebooks(C, structure.xi, structure.fast_mask)
        # rebuild with projected C (fast set/energies unchanged by projection)
        structure = icq_mod.ICQStructure(
            xi=structure.xi, fast_mask=structure.fast_mask,
            sigma=structure.sigma)
    else:
        xi = prior_mod.psi_mask_topk(lam, max(1, icq_cfg.d // 2))
        structure = icq_mod.ICQStructure(
            xi=xi, fast_mask=jnp.ones((C.shape[0],), bool),
            sigma=jnp.zeros(()))

    codes = encode_database(
        xs, C, embed_apply=embed_apply, embed_params=params["embed"],
        mode="pq" if mode == "pq" else "icm", icm_iters=icq_cfg.icm_iters,
        chunk=encode_batch, backend=encode_backend, interpret=interpret)
    return ICQModel(icq_cfg=icq_cfg, embed_params=params["embed"],
                    embed_apply=embed_apply, C=C, codes=codes,
                    structure=structure, lam=lam, mode=mode)
