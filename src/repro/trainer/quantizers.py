"""Quantizer implementations behind the trainer protocol (DESIGN.md §9).

``JointQuantizer`` wraps the joint trainer (mode="icq" | "cq" | "pq" —
the ICQ system plus the SQ and PQN supervised baselines).  The
unsupervised baselines PQ / OPQ / CQ implement the same
init/step/finalize verbs: closed-form or round-based ``step``s, and a
``finalize`` that exports through the tiled encoding engine.  The
historical ``fit_*`` entry points (re-exported by ``core/baselines/*``)
are thin drivers over these classes — behavior and seeds unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import codebooks as cb
from repro.core import encode as enc
from repro.core import losses
from repro.trainer import joint
from repro.trainer.base import ICQModel, plain_structure
from repro.trainer.encode import encode_database
from repro.train.optimizer import AdamW


@dataclasses.dataclass
class JointQuantizer:
    """The joint embedding+codebook trainer as a protocol Quantizer.

    mode="icq" is the paper's system; mode="cq" with the linear embedder
    is SQ (Wang et al.); mode="pq" with the CNN embedder is PQN-style
    (Yu et al.).  ``step`` is one SGD step on a (x, y) minibatch — the
    epoch driver (``trainer.epoch``) compiles stacks of them into one
    scan."""
    icq_cfg: object
    mode: str = "icq"
    embed_kind: str = "linear"
    num_classes: int = 10
    img_hw: Optional[int] = None
    channels: Optional[int] = None
    lr: float = 1e-3
    tau: float = 1.0
    sample_size: int = 4096

    def init(self, key, xs, ys=None) -> Dict:
        n = xs.shape[0]
        st = joint.init_train_state(
            key, self.icq_cfg, embed_kind=self.embed_kind,
            d_raw=xs.shape[-1] if xs.ndim == 2 else None,
            num_classes=self.num_classes, img_hw=self.img_hw,
            channels=self.channels, mode=self.mode, lr=self.lr,
            sample_batch=(xs[:min(n, self.sample_size)],
                          ys[:min(n, self.sample_size)]))
        st["step_fn"] = jax.jit(joint.make_train_step(
            self.icq_cfg, st["embed_apply"], st["opt"], self.mode,
            st["pq_mask"], self.tau))
        return st

    def step(self, state: Dict, batch) -> Dict:
        p, o, v, mets = state["step_fn"](state["params"],
                                         state["opt_state"],
                                         state["var_state"], batch)
        return dict(state, params=p, opt_state=o, var_state=v,
                    last_metrics=mets)

    def finalize(self, state: Dict, xs) -> ICQModel:
        return joint.finalize(state["params"], state["embed_apply"],
                              state["var_state"], self.icq_cfg, xs,
                              mode=self.mode)


@dataclasses.dataclass
class PQQuantizer:
    """Product Quantization (Jegou, Douze, Schmid 2010).

    Unsupervised and closed-form: ``init`` fits k-means per contiguous
    subspace on the given sample; ``step`` is the identity (kept for
    protocol uniformity); ``finalize`` encodes independently per
    codebook through the engine."""
    icq_cfg: object
    kmeans_iters: int = 25
    embed_params: object = None
    embed_apply: object = None

    def _apply(self):
        return self.embed_apply or (lambda p, x: x)

    def init(self, key, xs, ys=None) -> Dict:
        emb = self._apply()(self.embed_params, xs)
        C = cb.init_pq(key, emb, self.icq_cfg.num_codebooks,
                       self.icq_cfg.codebook_size, self.kmeans_iters)
        return {"C": C}

    def step(self, state: Dict, batch) -> Dict:
        return state                          # closed-form at init

    def finalize(self, state: Dict, xs) -> ICQModel:
        apply_fn = self._apply()
        emb = apply_fn(self.embed_params, xs)
        C = state["C"]
        codes = encode_database(emb, C, mode="pq")
        return ICQModel(icq_cfg=self.icq_cfg, embed_params=self.embed_params,
                        embed_apply=apply_fn, C=C, codes=codes,
                        structure=plain_structure(C, emb.shape[-1]),
                        lam=jnp.var(emb, axis=0), mode="pq")


@dataclasses.dataclass
class OPQQuantizer:
    """Optimized Product Quantization (Ge et al. 2013) — non-parametric.

    ``step`` is one alternation round on its batch: (1) PQ in the
    rotated space R x; (2) rotation update by the orthogonal Procrustes
    solution R = U V^T from SVD(X^T Xbar).  ``finalize`` folds the
    learned R into the embedding apply so search-side code is shared
    with plain PQ."""
    icq_cfg: object
    kmeans_iters: int = 10
    embed_params: object = None
    embed_apply: object = None

    def _apply(self):
        return self.embed_apply or (lambda p, x: x)

    def init(self, key, xs, ys=None) -> Dict:
        emb = self._apply()(self.embed_params, xs).astype(jnp.float32)
        return {"R": jnp.eye(emb.shape[-1], dtype=jnp.float32), "C": None,
                "key": key, "round": 0}

    def step(self, state: Dict, batch) -> Dict:
        emb = batch[0] if isinstance(batch, tuple) else batch
        emb = self._apply()(self.embed_params, emb).astype(jnp.float32)
        xr = emb @ state["R"]
        C = cb.init_pq(jax.random.fold_in(state["key"], state["round"]), xr,
                       self.icq_cfg.num_codebooks,
                       self.icq_cfg.codebook_size, self.kmeans_iters)
        codes = enc.encode_pq(xr, C)
        xbar = cb.decode(C, codes)
        # Procrustes: maximize tr(R^T X^T Xbar)  ->  R = U V^T
        u, s, vt = jnp.linalg.svd(emb.T @ xbar, full_matrices=False)
        return dict(state, R=u @ vt, C=C, round=state["round"] + 1)

    def finalize(self, state: Dict, xs) -> ICQModel:
        base_apply = self._apply()
        emb = base_apply(self.embed_params, xs).astype(jnp.float32)
        xr = emb @ state["R"]
        C = state["C"]
        codes = encode_database(xr, C, mode="pq")
        ep = {"base": self.embed_params, "R": state["R"]}

        def apply_fn(p, x):
            return base_apply(p["base"], x) @ p["R"]

        return ICQModel(icq_cfg=self.icq_cfg, embed_params=ep,
                        embed_apply=apply_fn, C=C, codes=codes,
                        structure=plain_structure(C, emb.shape[-1]),
                        lam=jnp.var(xr, axis=0), mode="pq")


@dataclasses.dataclass
class CQQuantizer:
    """Composite Quantization (Zhang, Du, Wang 2014) — unsupervised.

    Additive codebooks with the constant-inner-product constraint;
    ``step`` is one round of ``grad_steps`` gradient updates on C
    followed by ICM re-encoding (warm-started from the previous codes,
    through the tiled engine)."""
    icq_cfg: object
    grad_steps: int = 50
    lr: float = 5e-3
    embed_params: object = None
    embed_apply: object = None

    def _apply(self):
        return self.embed_apply or (lambda p, x: x)

    def init(self, key, xs, ys=None) -> Dict:
        emb = self._apply()(self.embed_params, xs).astype(jnp.float32)
        C = cb.init_residual(key, emb, self.icq_cfg.num_codebooks,
                             self.icq_cfg.codebook_size, iters=10)
        codes = enc.icm_encode(emb, C, self.icq_cfg.icm_iters)
        opt = AdamW(lr=lambda s: jnp.asarray(self.lr), weight_decay=0.0,
                    clip_norm=0.0)
        gamma = self.icq_cfg.gamma_cq

        def loss_fn(C, codes, emb):
            rec = cb.decode(C, codes)
            l_rec = jnp.mean(jnp.sum(jnp.square(emb - rec), axis=-1))
            l_cq, _ = losses.cq_penalty(C, codes)
            return l_rec + gamma * l_cq

        @jax.jit
        def c_steps(C, codes, opt_state, emb):
            def body(carry, _):
                C, opt_state = carry
                g = jax.grad(loss_fn)(C, codes, emb)
                params, opt_state, _ = opt.update({"C": g}, opt_state,
                                                  {"C": C})
                return (params["C"], opt_state), None
            (C, opt_state), _ = jax.lax.scan(body, (C, opt_state), None,
                                             length=self.grad_steps)
            return C, opt_state

        encode_jit = jax.jit(lambda e, C, codes: enc.icm_encode(
            e, C, self.icq_cfg.icm_iters, init_codes=codes))
        return {"C": C, "codes": codes, "opt_state": opt.init({"C": C}),
                "c_steps": c_steps, "encode": encode_jit}

    def step(self, state: Dict, batch) -> Dict:
        emb = batch[0] if isinstance(batch, tuple) else batch
        emb = self._apply()(self.embed_params, emb).astype(jnp.float32)
        C, opt_state = state["c_steps"](state["C"], state["codes"],
                                        state["opt_state"], emb)
        codes = state["encode"](emb, C, state["codes"])
        return dict(state, C=C, codes=codes, opt_state=opt_state)

    def finalize(self, state: Dict, xs) -> ICQModel:
        apply_fn = self._apply()
        emb = apply_fn(self.embed_params, xs).astype(jnp.float32)
        C = state["C"]
        codes = enc.pack_codes(state["codes"], self.icq_cfg.codebook_size)
        return ICQModel(icq_cfg=self.icq_cfg, embed_params=self.embed_params,
                        embed_apply=apply_fn, C=C, codes=codes,
                        structure=plain_structure(C, emb.shape[-1]),
                        lam=jnp.var(emb, axis=0), mode="cq")


# ------------------------------------------------- historical fit_* entries

def fit_pq(key, xs, icq_cfg, *, kmeans_iters: int = 25,
           embed_params=None, embed_apply=None) -> ICQModel:
    """Fit PQ on raw vectors (or pre-embedded if embed_* given)."""
    q = PQQuantizer(icq_cfg, kmeans_iters=kmeans_iters,
                    embed_params=embed_params, embed_apply=embed_apply)
    return q.finalize(q.init(key, xs), xs)


def fit_opq(key, xs, icq_cfg, *, rounds: int = 8, kmeans_iters: int = 10,
            embed_params=None, embed_apply=None) -> ICQModel:
    """Fit OPQ: ``rounds`` alternation steps over the full data."""
    q = OPQQuantizer(icq_cfg, kmeans_iters=kmeans_iters,
                     embed_params=embed_params, embed_apply=embed_apply)
    state = q.init(key, xs)
    for _ in range(rounds):
        state = q.step(state, xs)
    return q.finalize(state, xs)


def fit_cq(key, xs, icq_cfg, *, rounds: int = 10, grad_steps: int = 50,
           lr: float = 5e-3, embed_params=None, embed_apply=None) -> ICQModel:
    """Fit CQ: ``rounds`` (C-gradient + ICM re-encode) rounds."""
    q = CQQuantizer(icq_cfg, grad_steps=grad_steps, lr=lr,
                    embed_params=embed_params, embed_apply=embed_apply)
    state = q.init(key, xs)
    for _ in range(rounds):
        state = q.step(state, xs)
    return q.finalize(state, xs)
