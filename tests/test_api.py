"""Front-door api layer (repro.api, docs/api.md): config round-trip +
validation, fit→save→load→search bitwise identity for every index type
/ code width / LUT dtype, and corruption/version rejection."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AnnEngine, ArtifactError, Artifacts, ConfigError,
                       EncodeConfig, ICQConfig, ICQSession, IndexConfig,
                       ServeConfig, TrainConfig, build_ann_engine,
                       icq_session, load_ann_engine)


# ---------------------------------------------------------------- config ----

def test_config_json_round_trip():
    cfg = ICQConfig(train=TrainConfig(codebook_size=64, epochs=7),
                    index=IndexConfig(kind="ivf", n_lists=32, n_probe=4),
                    serve=ServeConfig(lut_dtype="int8", query_chunk=16))
    cfg2 = ICQConfig.from_json(cfg.to_json())
    assert cfg2 == cfg
    assert cfg2.config_hash() == cfg.config_hash()


def test_config_file_round_trip(tmp_path):
    cfg = ICQConfig(train=TrainConfig(epochs=3))
    path = str(tmp_path / "cfg.json")
    cfg.save(path)
    assert ICQConfig.load(path) == cfg


def test_config_overrides():
    cfg = ICQConfig().with_overrides({"train.epochs": 9,
                                      "serve.lut_dtype": "int8"})
    assert cfg.train.epochs == 9 and cfg.serve.lut_dtype == "int8"
    # hash tracks content
    assert cfg.config_hash() != ICQConfig().config_hash()
    with pytest.raises(ConfigError, match="unknown override field"):
        ICQConfig().with_overrides({"train.epochz": 9})
    with pytest.raises(ConfigError, match="section.field"):
        ICQConfig().with_overrides({"epochs": 9})


@pytest.mark.parametrize("bad,match", [
    ({"schema_version": 99}, "schema_version=99"),
    ({}, "missing 'schema_version'"),
    ({"schema_version": 1, "trian": {}}, "unknown config section"),
    ({"schema_version": 1, "train": {"epochz": 1}}, "unknown field"),
    ({"schema_version": 1, "train": {"epochs": "six"}}, "must be int"),
    ({"schema_version": 1, "serve": {"lut_dtype": "int4"}}, "not one of"),
    ({"schema_version": 1, "index": {"kind": "hnsw"}}, "not one of"),
    ({"schema_version": 1,
      "train": {"num_fast": 8, "num_codebooks": 8}}, "num_fast"),
    ({"schema_version": 1,
      "index": {"n_probe": 99, "n_lists": 4}}, "n_probe"),
    ({"schema_version": 1, "train": {"epochs": 0}}, "positive int"),
    ({"schema_version": 1, "train": {"lr": -0.001}}, "must be > 0"),
    ({"schema_version": 1, "train": {"pi1": -0.1}}, "must be >= 0"),
])
def test_config_rejections(bad, match):
    with pytest.raises(ConfigError, match=match):
        ICQConfig.from_dict(bad)


def test_config_not_json():
    with pytest.raises(ConfigError, match="not valid JSON"):
        ICQConfig.from_json("{nope")


# ------------------------------------------------------------- artifacts ----

def _synthetic(n=2000, d=16, K=8, m=64, seed=0):
    from repro.data.synthetic import make_synthetic_index
    key = jax.random.PRNGKey(seed)
    codes, C, structure = make_synthetic_index(key, n, d=d, K=K, m=m)
    from repro.core import codebooks as cb
    return codes, C, structure, cb.decode(C, codes)


def _cfg_for(kind, lut_dtype="f32", topk=20):
    return ICQConfig(index=IndexConfig(kind=kind, n_lists=16, n_probe=4),
                     serve=ServeConfig(topk=topk, backend="jnp",
                                       lut_dtype=lut_dtype))


@pytest.mark.parametrize("kind", ["flat", "two-step", "ivf"])
@pytest.mark.parametrize("lut_dtype", ["f32", "int8"])
def test_artifacts_index_bitwise_round_trip(tmp_path, kind, lut_dtype):
    """save→load serves bitwise-identical ids AND distances for every
    index type and LUT dtype (the api layer's headline guarantee)."""
    codes, C, structure, emb_db = _synthetic()
    engine = build_ann_engine(codes, C, structure, topk=20, backend="jnp",
                              index=kind, emb_db=emb_db, n_lists=16,
                              n_probe=4, lut_dtype=lut_dtype,
                              key=jax.random.PRNGKey(1))
    q = jax.random.normal(jax.random.PRNGKey(2), (8, 16))
    r0 = engine(q)
    path = str(tmp_path / f"art_{kind}_{lut_dtype}")
    Artifacts(config=_cfg_for(kind, lut_dtype),
              index=engine.index).save(path)
    r1 = load_ann_engine(path)(q)
    assert np.array_equal(np.asarray(r0.indices), np.asarray(r1.indices))
    assert np.array_equal(np.asarray(r0.distances),
                          np.asarray(r1.distances))


@pytest.mark.parametrize("m,dtype", [(64, np.uint8), (300, np.uint16)])
def test_artifacts_preserve_code_width(tmp_path, m, dtype):
    """uint8 and uint16 packed codes survive the round trip in their
    stored dtype (no silent widening) and serve identically."""
    codes, C, structure, _ = _synthetic(n=500, m=m)
    assert np.asarray(codes).dtype == dtype
    engine = build_ann_engine(codes, C, structure, topk=10, backend="jnp")
    q = jax.random.normal(jax.random.PRNGKey(2), (4, 16))
    r0 = engine(q)
    path = str(tmp_path / f"art_m{m}")
    Artifacts(config=_cfg_for("two-step", topk=10),
              index=engine.index).save(path)
    loaded = load_ann_engine(path)
    assert np.asarray(loaded.index.codes).dtype == dtype
    r1 = loaded(q)
    assert np.array_equal(np.asarray(r0.indices), np.asarray(r1.indices))
    assert np.array_equal(np.asarray(r0.distances),
                          np.asarray(r1.distances))


def test_session_fit_save_load_search_identity(tmp_path):
    """The full lifecycle: fit → index → search, save, reload in a
    'fresh process' (new objects from disk only) → bitwise-identical
    ids and distances, embed params included."""
    from repro.data import make_table1_dataset
    xtr, ytr, xte, _ = make_table1_dataset("dataset2")
    xtr, ytr = xtr[:600], ytr[:600]
    cfg = ICQConfig(train=TrainConfig(codebook_size=32, epochs=1),
                    index=IndexConfig(kind="ivf", n_lists=8, n_probe=4),
                    serve=ServeConfig(topk=10, backend="jnp"))
    session = icq_session(cfg)
    session.fit(xtr, ytr, key=jax.random.PRNGKey(0))
    searcher = session.index()
    r0 = searcher.search(xte[:8])
    path = str(tmp_path / "sess")
    searcher.save(path)

    engine = load_ann_engine(path)
    session2 = ICQSession.from_artifacts(path)
    emb_q = session2.model.embed(jnp.asarray(xte[:8]))
    r1 = engine(emb_q)
    assert np.array_equal(np.asarray(r0.indices), np.asarray(r1.indices))
    assert np.array_equal(np.asarray(r0.distances),
                          np.asarray(r1.distances))
    # the reloaded model embeds identically (params round-tripped)
    assert np.array_equal(
        np.asarray(searcher.model.embed(jnp.asarray(xte[:8]))),
        np.asarray(emb_q))


def test_artifacts_reject_missing_and_corrupt(tmp_path):
    codes, C, structure, _ = _synthetic(n=300)
    engine = build_ann_engine(codes, C, structure, topk=10, backend="jnp")
    path = str(tmp_path / "art")
    Artifacts(config=_cfg_for("two-step"), index=engine.index).save(path)

    # not an artifacts dir
    with pytest.raises(ArtifactError, match="not an artifacts directory"):
        Artifacts.load(str(tmp_path / "nowhere"))

    # unsupported / old format version
    manifest_path = os.path.join(path, "manifest.json")
    with open(manifest_path) as f:
        manifest = json.load(f)
    old = dict(manifest, format_version=0)
    with open(manifest_path, "w") as f:
        json.dump(old, f)
    with pytest.raises(ArtifactError, match="format_version=0"):
        Artifacts.load(path)

    # corrupt manifest json
    with open(manifest_path, "w") as f:
        f.write("{truncated")
    with pytest.raises(ArtifactError, match="corrupt manifest.json"):
        Artifacts.load(path)

    # inventory mismatch (tampered dtype)
    bad = json.loads(json.dumps(manifest))
    bad["arrays"]["index/codes"]["dtype"] = "float64"
    with open(manifest_path, "w") as f:
        json.dump(bad, f)
    with pytest.raises(ArtifactError, match="corrupt or tampered"):
        Artifacts.load(path)

    # missing arrays file
    with open(manifest_path, "w") as f:
        json.dump(manifest, f)
    os.remove(os.path.join(path, "arrays.npz"))
    with pytest.raises(ArtifactError, match="missing arrays.npz"):
        Artifacts.load(path)


def test_artifacts_manifest_contents(tmp_path):
    codes, C, structure, _ = _synthetic(n=300)
    cfg = _cfg_for("two-step")
    engine = build_ann_engine(codes, C, structure, topk=10, backend="jnp")
    path = str(tmp_path / "art")
    Artifacts(config=cfg, index=engine.index).save(path)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format_version"] == 1
    assert manifest["config_hash"] == cfg.config_hash()
    assert ICQConfig.from_dict(manifest["config"]) == cfg
    inv = manifest["arrays"]
    assert inv["index/codes"]["dtype"] == "uint8"
    assert inv["index/codes"]["shape"] == [300, 8]


def test_load_ann_engine_overrides_and_errors(tmp_path):
    codes, C, structure, _ = _synthetic(n=300)
    engine = build_ann_engine(codes, C, structure, topk=10, backend="jnp")
    path = str(tmp_path / "art")
    Artifacts(config=_cfg_for("two-step"), index=engine.index).save(path)
    eng = load_ann_engine(path, overrides={"serve.topk": 5})
    q = jax.random.normal(jax.random.PRNGKey(2), (4, 16))
    assert eng(q).indices.shape == (4, 5)
    # the stored layout cannot be overridden away
    with pytest.raises(ArtifactError, match="index.kind cannot be"):
        load_ann_engine(path, overrides={"index.kind": "flat"})
    # model-only artifacts refuse to serve
    with pytest.raises(ArtifactError, match="nothing to save"):
        Artifacts(config=_cfg_for("two-step")).save(str(tmp_path / "e"))


def test_load_ann_engine_ivf_n_probe_override(tmp_path):
    """index.n_probe overrides actually change the probe count of a
    reloaded IVF index (and an inconsistent save is rejected)."""
    codes, C, structure, emb_db = _synthetic(n=600)
    engine = build_ann_engine(codes, C, structure, topk=10, backend="jnp",
                              index="ivf", emb_db=emb_db, n_lists=16,
                              n_probe=4, key=jax.random.PRNGKey(1))
    path = str(tmp_path / "art")
    Artifacts(config=_cfg_for("ivf", topk=10), index=engine.index).save(path)
    eng = load_ann_engine(path, overrides={"index.n_probe": 16})
    assert eng.index.n_probe == 16
    q = jax.random.normal(jax.random.PRNGKey(2), (4, 16))
    r_all = eng(q)                       # probes all 16 lists
    loaded_plain = load_ann_engine(path)
    assert loaded_plain.index.n_probe == 4      # plain reload unchanged
    assert r_all.indices.shape == loaded_plain(q).indices.shape
    # save refuses a config that misdescribes the index
    with pytest.raises(ArtifactError, match="n_probe"):
        Artifacts(config=_cfg_for("ivf", topk=10).with_overrides(
            {"index.n_probe": 8}),
            index=engine.index).save(str(tmp_path / "bad"))


def test_searcher_add_encode_opts():
    """Searcher.add's encode_opts override the config (no kwarg
    collision with the config-derived defaults)."""
    from repro.data import make_table1_dataset
    xtr, ytr, _, _ = make_table1_dataset("dataset2")
    cfg = ICQConfig(train=TrainConfig(codebook_size=32, epochs=1),
                    serve=ServeConfig(topk=10, backend="jnp"))
    session = icq_session(cfg)
    session.fit(xtr[:400], ytr[:400], key=jax.random.PRNGKey(0))
    searcher = session.index()
    n0 = searcher.n
    searcher.add(xtr[400:432], icm_iters=1)
    assert searcher.n == n0 + 32


def test_session_guards():
    session = icq_session(ICQConfig())
    with pytest.raises(ConfigError, match="before session.fit"):
        session.index()
    with pytest.raises(ConfigError, match="needs an api ICQConfig"):
        icq_session({"train": {}})
