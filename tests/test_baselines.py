"""Baseline quantizers: structural invariants + end-to-end MAP sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ICQConfig
from repro.core import adc_search, mean_average_precision
from repro.core import codebooks as cb
from repro.core import encode as enc
from repro.core.baselines import fit_cq, fit_opq, fit_pq, fit_pqn, fit_sq
from repro.data import make_table1_dataset

CFG = ICQConfig(d=16, num_codebooks=4, codebook_size=16, num_fast=2)


@pytest.fixture(scope="module")
def ds():
    xtr, ytr, xte, yte = make_table1_dataset("dataset3")
    return xtr[:1500], ytr[:1500], xte[:80], yte[:80]


def test_pq_supports_disjoint(key, ds):
    xtr, *_ = ds
    m = fit_pq(key, np.asarray(xtr[:, :16]), CFG)
    sup = np.asarray(jnp.any(jnp.abs(m.C) > 0, axis=1))   # (K, d)
    assert (sup.sum(0) <= 1).all()


def test_opq_rotation_orthogonal(key, ds):
    xtr, *_ = ds
    m = fit_opq(key, np.asarray(xtr[:, :16]), CFG, rounds=3)
    R = np.asarray(m.embed_params["R"])
    np.testing.assert_allclose(R @ R.T, np.eye(16), atol=1e-4)


def test_opq_not_worse_than_pq(key, ds):
    xtr, *_ = ds
    x = np.asarray(xtr[:, :16])
    mp = fit_pq(key, x, CFG)
    mo = fit_opq(key, x, CFG, rounds=5)
    ep = float(cb.quantization_mse(jnp.asarray(x), mp.C, mp.codes))
    xr = mo.embed(jnp.asarray(x))
    eo = float(cb.quantization_mse(xr, mo.C, mo.codes))
    assert eo <= ep * 1.05


def test_cq_reduces_cq_penalty(key, ds):
    from repro.core import losses
    xtr, *_ = ds
    x = np.asarray(xtr[:500, :16])
    m = fit_cq(key, x, CFG, rounds=3, grad_steps=25)
    pen, _ = losses.cq_penalty(m.C, m.codes)
    C0 = cb.init_residual(key, jnp.asarray(x), 4, 16, iters=5)
    codes0 = enc.icm_encode(jnp.asarray(x), C0, 2)
    pen0, _ = losses.cq_penalty(C0, codes0)
    assert float(pen) < float(pen0)


def test_sq_and_pqn_reach_usable_map(key, ds):
    xtr, ytr, xte, yte = ds
    for fit_fn in (fit_sq, fit_pqn):
        m = (fit_fn(key, xtr, ytr, CFG, epochs=3)
             if fit_fn is fit_sq else
             fit_fn(key, xtr, ytr, CFG, epochs=3))
        r = adc_search(m.embed(xte), m.codes, m.C, 20)
        mapv = float(mean_average_precision(r.indices, ytr, yte))
        assert mapv > 0.5, fit_fn.__name__
