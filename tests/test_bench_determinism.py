"""Seed determinism for the benchmark layer (docs/benchmarks.md): every
``benchmarks/run.py --only`` target takes ``--seed`` and threads it into
data generation, so two same-seed runs must report identical recall.
Exercised end-to-end on the pareto sweep (the target with the most
moving parts: pseudo-real data, skewed queries, ground truth, training,
grid measurement) with a tiny grid — timing fields (qps, search_us) are
wall-clock and excluded from the comparison.
"""
import numpy as np

from benchmarks import sweep
from repro.data.pseudo_real import pseudo_sift, skewed_queries

_TINY_GRID = [
    dict(kind="ivf", n_probe=4, num_fast=2, refine_cap=None,
         lut_dtype="f32", code_bits=8),
    dict(kind="two_step", n_probe=None, num_fast=2, refine_cap=None,
         lut_dtype="f32", code_bits=8),
]

_DATA_FIELDS = ("kind", "n_probe", "num_fast", "refine_cap", "lut_dtype",
                "code_bits", "recall", "avg_ops", "pass_rate")


def _tiny_sweep(tmp_path, tag, seed):
    return sweep.run(out_path=str(tmp_path / f"pareto_{tag}.json"),
                     n=1500, nq=16, d=16, n_clusters=8, K=4, m=8, k=5,
                     n_lists=8, icm_iters=1, repeats=1, grid=_TINY_GRID,
                     cache_dir=None, seed=seed)


def test_same_seed_sweep_runs_report_identical_recall(tmp_path):
    a = _tiny_sweep(tmp_path, "a", seed=3)
    b = _tiny_sweep(tmp_path, "b", seed=3)
    assert [{f: r[f] for f in _DATA_FIELDS} for r in a["rows"]] \
        == [{f: r[f] for f in _DATA_FIELDS} for r in b["rows"]]
    assert [{f: r[f] for f in _DATA_FIELDS} for r in a["frontier"]] \
        == [{f: r[f] for f in _DATA_FIELDS} for r in b["frontier"]]
    assert a["frontier_monotone"] == b["frontier_monotone"]
    assert a["seed"] == b["seed"] == 3


def test_same_seed_serve_runs_report_identical_rows(tmp_path):
    """--only serve: the seed threads through the Poisson arrival
    stream and every tenant's query pool, and the no-deadline sweep
    always serves the full ladder level — so two same-seed runs deliver
    identical result content (the per-window ids hashes) and identical
    workload shapes.  Latency/QPS fields are wall-clock and excluded."""
    from benchmarks import serve_load

    def tiny(tag):
        return serve_load.run(
            out_path=str(tmp_path / f"serve_{tag}.json"), n=2000,
            windows_ms=(0.5, 2.0), rate_hz=40.0, duration_s=0.4,
            pool_q=16, seed=5)

    a, b = tiny("a"), tiny("b")
    assert a["ids_sha256_per_window"] == b["ids_sha256_per_window"]
    # coalescing canonicalizes the compiled shape, so the content hash
    # is also window-invariant (scheduling never changes math)
    assert len(set(a["ids_sha256_per_window"].values())) == 1
    shape_fields = ("window_ms", "tenant", "requests", "rows")
    assert [{f: r[f] for f in shape_fields} for r in a["rows"]] \
        == [{f: r[f] for f in shape_fields} for r in b["rows"]]
    assert a["bitwise_coalesced_vs_direct"] \
        and b["bitwise_coalesced_vs_direct"]
    assert a["tenants"] == b["tenants"] == ["flat", "ivf"]


def test_seed_threads_into_data_generation():
    # the seed actually reaches the workload: same seed is bitwise
    # reproducible, a different seed changes db, queries, and skew
    db0, q0, cid0 = pseudo_sift(400, 8, d=16, n_clusters=8, seed=0)
    db0b, q0b, cid0b = pseudo_sift(400, 8, d=16, n_clusters=8, seed=0)
    np.testing.assert_array_equal(db0, db0b)
    np.testing.assert_array_equal(q0, q0b)
    np.testing.assert_array_equal(cid0, cid0b)
    db1, _, _ = pseudo_sift(400, 8, d=16, n_clusters=8, seed=1)
    assert not np.array_equal(db0, db1)
    sq0, _ = skewed_queries(db0, cid0, 8, seed=0)
    sq0b, _ = skewed_queries(db0, cid0, 8, seed=0)
    sq1, _ = skewed_queries(db0, cid0, 8, seed=1)
    np.testing.assert_array_equal(sq0, sq0b)
    assert not np.array_equal(sq0, sq1)
