"""Unit + property tests for the variance prior (paper §3.1, §3.3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; CI installs it
from hypothesis import given, settings, strategies as st

from repro.core import prior as P

PI1, PI2, A2 = 0.9, 0.1, -10.0


def test_skewnormal_integrates_to_one():
    xs = np.linspace(-30, 30, 200001)
    pdf = np.exp(np.asarray(P.skewnormal_logpdf(jnp.asarray(xs), 1.0, 0.7, A2)))
    area = np.trapezoid(pdf, xs)
    assert abs(area - 1.0) < 1e-3


def test_skewnormal_negative_alpha_mass_below_mu():
    xs = np.linspace(-20, 20, 100001)
    pdf = np.exp(np.asarray(P.skewnormal_logpdf(jnp.asarray(xs), 2.0, 1.0, A2)))
    below = np.trapezoid(pdf[xs <= 2.0], xs[xs <= 2.0])
    assert below > 0.95      # alpha<0 skews mass below the location


def test_logcdf_matches_naive_in_bulk():
    x = jnp.linspace(-5, 5, 101)
    naive = jnp.log(0.5 * jax.lax.erfc(-x / jnp.sqrt(2.0)))
    assert jnp.max(jnp.abs(P.normal_logcdf(x) - naive)) < 1e-5


def test_nll_gradients_finite_in_tails():
    """The erfc-based logcdf NaNs here — regression for the fix."""
    theta = P.init_theta(sigma1=0.1, sigma2=0.5, mu2=1.0)
    lam = jnp.asarray([0.0, 1e-3, 5.0, 50.0, 500.0])
    g = jax.grad(lambda th: P.nll(lam, th, pi1=PI1, pi2=PI2, alpha2=A2))(theta)
    assert all(bool(jnp.isfinite(v)) for v in jax.tree.leaves(g))
    glam = jax.grad(lambda l: P.nll(l, theta, pi1=PI1, pi2=PI2, alpha2=A2))(lam)
    assert bool(jnp.all(jnp.isfinite(glam)))


@settings(deadline=None, max_examples=30)
@given(st.floats(0.05, 5.0), st.floats(0.05, 5.0), st.floats(0.1, 20.0),
       st.integers(0, 1000))
def test_psi_mask_elementwise_equivariant(s1, s2, mu2, seed):
    """Property: the psi decision is per-dimension (equal lambdas get equal
    membership; permuting lambda permutes xi)."""
    theta = P.init_theta(sigma1=s1, sigma2=s2, mu2=mu2)
    rng = np.random.default_rng(seed)
    lam = jnp.asarray(rng.uniform(0, 2 * mu2, 16))
    xi = np.asarray(P.psi_mask(lam, theta, pi1=PI1, pi2=PI2, alpha2=A2))
    perm = rng.permutation(16)
    xi_p = np.asarray(P.psi_mask(lam[perm], theta, pi1=PI1, pi2=PI2,
                                 alpha2=A2))
    np.testing.assert_array_equal(xi[perm], xi_p)


def test_psi_mask_upper_set_when_modes_separated():
    """In the post-training regime (narrow major mode at 0, minor mode far
    out) membership is an upper set: higher variance => in psi.  (With
    overlapping modes the minor-mode window is an interval, not a ray —
    that regime is handled by the top-k fallback in icq.compute_xi.)"""
    theta = P.init_theta(sigma1=0.2, sigma2=1.5, mu2=6.0)
    lam = jnp.linspace(0.0, 7.0, 64)
    xi = np.asarray(P.psi_mask(lam, theta, pi1=PI1, pi2=PI2, alpha2=A2))
    assert xi.any() and (~xi).any()
    first = int(np.argmax(xi))
    assert xi[first:].all() and not xi[:first].any()


def test_psi_topk_fallback():
    lam = jnp.asarray([0.1, 5.0, 0.2, 3.0])
    xi = np.asarray(P.psi_mask_topk(lam, 2))
    assert list(xi) == [False, True, False, True]


def test_robustness_term_keeps_minor_mode(key):
    """Eq. 10: without the -log P(SN) term, emptying the minor mode is a
    feasible minimum; with it the NLL blows up as all lam leave the mode."""
    theta = P.init_theta(sigma1=1.0, sigma2=0.5, mu2=8.0)
    lam_far = jnp.full((16,), 0.5)     # all in major mode
    lam_near = lam_far.at[0].set(8.0)  # one dim in the minor mode
    assert float(P.nll(lam_near, theta, pi1=PI1, pi2=PI2, alpha2=A2)) < \
        float(P.nll(lam_far, theta, pi1=PI1, pi2=PI2, alpha2=A2))
