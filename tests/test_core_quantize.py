"""Codebooks, encoding (ICM), and the ICQ structural invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; CI installs it
from hypothesis import given, settings, strategies as st

from repro.configs.base import ICQConfig
from repro.core import codebooks as cb
from repro.core import encode as enc
from repro.core import icq as icq_mod
from repro.core import losses


@pytest.fixture(scope="module")
def data(key):
    x = jax.random.normal(key, (512, 16)) * jnp.linspace(0.2, 3.0, 16)
    return x


def test_kmeans_reduces_distortion(key, data):
    cent, ids = cb.kmeans(key, data, 16, iters=1)
    d1 = float(jnp.mean(jnp.sum(jnp.square(data - cent[ids]), -1)))
    cent, ids = cb.kmeans(key, data, 16, iters=20)
    d2 = float(jnp.mean(jnp.sum(jnp.square(data - cent[ids]), -1)))
    assert d2 <= d1 + 1e-6


def test_kmeans_no_empty_clusters(key, data):
    cent, ids = cb.kmeans(key, data, 32, iters=15)
    counts = np.bincount(np.asarray(ids), minlength=32)
    assert (counts > 0).all()


def test_pq_init_orthogonal_supports(key, data):
    C = cb.init_pq(key, data, 4, 8)
    for k in range(4):
        sup = np.asarray(jnp.any(jnp.abs(C[k]) > 0, axis=0))
        assert sup[k * 4: (k + 1) * 4].all() and sup.sum() == 4


def test_pq_encode_matches_bruteforce(key, data):
    C = cb.init_pq(key, data, 4, 8)
    codes = enc.encode_pq(data, C)
    # brute force over all codewords per codebook
    for k in range(4):
        d = jnp.sum(jnp.square(data[:, None, :] - C[k][None]), -1)
        np.testing.assert_array_equal(np.asarray(codes[:, k]),
                                      np.asarray(jnp.argmin(d, -1)))


def test_icm_never_increases_reconstruction_error(key, data):
    C = cb.init_residual(key, data, 4, 16, iters=5)
    codes0 = enc.encode_pq(data, C)               # independent warm start
    e0 = float(cb.quantization_mse(data, C, codes0))
    codes1 = enc.icm_encode(data, C, iters=1, init_codes=codes0)
    e1 = float(cb.quantization_mse(data, C, codes1))
    codes3 = enc.icm_encode(data, C, iters=3, init_codes=codes0)
    e3 = float(cb.quantization_mse(data, C, codes3))
    assert e1 <= e0 + 1e-5 and e3 <= e1 + 1e-5


def test_residual_init_beats_random(key, data):
    Cr = cb.init_residual(key, data, 4, 16, iters=10)
    Crand = jax.random.normal(key, Cr.shape) * 0.5
    er = float(cb.quantization_mse(data, Cr, enc.icm_encode(data, Cr, 2)))
    ern = float(cb.quantization_mse(data, Crand, enc.icm_encode(data, Crand, 2)))
    assert er < ern


def test_st_decode_gradients_flow(key, data):
    C = cb.init_residual(key, data, 4, 8, iters=3)

    def loss(C, x):
        xbar, _ = enc.st_decode(x, C)
        return jnp.mean(jnp.sum(jnp.square(x - xbar), -1))

    gC = jax.grad(loss)(C, data)
    gx = jax.grad(loss, argnums=1)(C, data)
    assert float(jnp.abs(gC).max()) > 0 and float(jnp.abs(gx).max()) > 0
    assert bool(jnp.all(jnp.isfinite(gC)))


def test_pack_codes_roundtrip(key):
    codes = jax.random.randint(key, (64, 8), 0, 256)
    packed = enc.pack_codes(codes, 256)
    assert packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(enc.unpack_codes(packed)),
                                  np.asarray(codes))


# ----------------------------------------------------------- ICQ invariants

def test_projection_enforces_exact_orthogonality(key, data):
    cfg = ICQConfig(d=16, num_codebooks=4, codebook_size=8, num_fast=2)
    C = cb.init_residual(key, data, 4, 8, iters=3)
    xi = jnp.asarray([1] * 5 + [0] * 11, bool)
    fast = jnp.asarray([True, True, False, False])
    Cp = icq_mod.project_codebooks(C, xi, fast)
    # eq. 6 exactly zero after projection
    assert float(losses.icq_loss(Cp, xi)) < 1e-4  # eps floor inside sqrt
    # fast codewords live in psi, slow in the complement
    in_e, out_e = icq_mod.codebook_energies(Cp, xi)
    assert float(out_e[:2].max()) == 0.0 and float(in_e[2:].max()) == 0.0


def test_fast_set_selection_eq8(key):
    xi = jnp.asarray([1, 1, 0, 0], bool)
    C = jnp.zeros((2, 3, 4))
    C = C.at[0, :, :2].set(1.0)        # codebook 0 inside psi
    C = C.at[1, :, 2:].set(1.0)        # codebook 1 outside
    mask = np.asarray(icq_mod.fast_set(C, xi))
    assert list(mask) == [True, False]


@settings(deadline=None, max_examples=20)
@given(st.integers(1, 15))
def test_margin_sigma_monotone_in_psi(psi_size):
    """Property: growing psi can only shrink the margin (eq. 11)."""
    lam = jnp.asarray(np.random.default_rng(0).uniform(0.1, 2.0, 16))
    order = jnp.argsort(-lam)
    xi_small = jnp.zeros(16, bool).at[order[:psi_size]].set(True)
    xi_big = jnp.zeros(16, bool).at[order[: psi_size + 1]].set(True)
    assert float(icq_mod.margin_sigma(lam, xi_big)) <= \
        float(icq_mod.margin_sigma(lam, xi_small)) + 1e-6


def test_cq_penalty_zero_for_orthogonal_codebooks(key, data):
    C = cb.init_pq(key, data, 4, 8)    # disjoint supports -> cross terms 0
    codes = enc.encode_pq(data, C)
    pen, mean = losses.cq_penalty(C, codes)
    assert abs(float(mean)) < 1e-4 and float(pen) < 1e-6
