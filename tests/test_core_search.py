"""Two-step search (paper §3.4): correctness vs one-step ADC, pruning
accounting, and the end-to-end joint-training invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ICQConfig
from repro.core import (adc_search, exact_search, fit,
                        mean_average_precision, recall_at, two_step_search,
                        two_step_search_compact)
from repro.core import codebooks as cb
from repro.core import encode as enc
from repro.core import icq as icq_mod
from repro.core import search as srch
from repro.data import make_table1_dataset


@pytest.fixture(scope="module")
def model():
    xtr, ytr, xte, yte = make_table1_dataset("dataset3")
    xtr, ytr, xte, yte = xtr[:2000], ytr[:2000], xte[:100], yte[:100]
    cfg = ICQConfig(d=16, num_codebooks=8, codebook_size=32, num_fast=2)
    m = fit(jax.random.PRNGKey(0), xtr, ytr, cfg, mode="icq", epochs=4,
            batch_size=256)
    return m, xtr, ytr, xte, yte


def test_lut_sum_equals_decode_distance(key):
    """ADC identity: ||q-xbar||^2 = ||q||^2 + LUT-sum + cross-terms; for
    orthogonal (PQ) codebooks the cross terms vanish exactly."""
    x = jax.random.normal(key, (128, 16))
    C = cb.init_pq(key, x, 4, 8)
    codes = enc.encode_pq(x, C)
    q = jax.random.normal(jax.random.fold_in(key, 1), (16,))
    lut = srch.build_lut(q, C)
    lhs = srch.lut_sum(lut, codes) + jnp.sum(jnp.square(q))
    xbar = cb.decode(C, codes)
    rhs = jnp.sum(jnp.square(q[None] - xbar), -1)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4)


def test_exact_search_is_exact(key):
    x = jax.random.normal(key, (200, 8))
    q = jax.random.normal(jax.random.fold_in(key, 1), (5, 8))
    idx, dist = exact_search(q, x, 10)
    d2 = np.sum((np.asarray(q)[:, None] - np.asarray(x)[None]) ** 2, -1)
    np.testing.assert_array_equal(np.sort(np.asarray(idx), -1),
                                  np.sort(np.argsort(d2, -1)[:, :10], -1))


def test_two_step_never_worse_map_than_its_pruning(model):
    m, xtr, ytr, xte, yte = model
    emb_te, emb_tr = m.embed(xte), m.embed(xtr)
    r2 = two_step_search(emb_te, m.codes, m.C, m.structure, topk=20)
    r1 = adc_search(emb_te, m.codes, m.C, topk=20)
    map2 = float(mean_average_precision(r2.indices, ytr, yte))
    map1 = float(mean_average_precision(r1.indices, ytr, yte))
    assert map2 >= map1 - 0.02          # pruning may cost at most epsilon
    assert float(r2.avg_ops) < float(r1.avg_ops)   # and must be faster


def test_two_step_ops_accounting(model):
    m, xtr, ytr, xte, yte = model
    r2 = two_step_search(m.embed(xte), m.codes, m.C, m.structure, topk=20)
    K = m.C.shape[0]
    kf = float(jnp.sum(m.structure.fast_mask))
    expected = kf + float(r2.pass_rate) * (K - kf)
    assert abs(float(r2.avg_ops) - expected) < 1e-5
    assert 0.0 <= float(r2.pass_rate) <= 1.0


def test_infinite_margin_recovers_adc(model):
    """sigma -> inf disables pruning: two-step == one-step ADC exactly."""
    m, xtr, ytr, xte, yte = model
    s = icq_mod.ICQStructure(xi=m.structure.xi,
                             fast_mask=m.structure.fast_mask,
                             sigma=jnp.asarray(1e30))
    emb = m.embed(xte)
    r2 = two_step_search(emb, m.codes, m.C, s, topk=20)
    r1 = adc_search(emb, m.codes, m.C, topk=20)
    np.testing.assert_array_equal(np.asarray(r2.indices),
                                  np.asarray(r1.indices))
    assert float(r2.pass_rate) == 1.0


def test_compact_matches_dense_when_cap_sufficient(model):
    m, xtr, ytr, xte, yte = model
    emb = m.embed(xte)
    r_dense = two_step_search(emb, m.codes, m.C, m.structure, topk=10)
    r_comp = two_step_search_compact(emb, m.codes, m.C, m.structure,
                                     topk=10, refine_cap=m.codes.shape[0])
    np.testing.assert_array_equal(np.asarray(r_dense.indices),
                                  np.asarray(r_comp.indices))


def test_lut_sum_vectorized_matches_loop(key):
    """The take_along_axis formulation == the per-codebook gather loop,
    for both the plain and the masked (fast subset) case."""
    K, m, n = 6, 16, 200
    lut = jax.random.normal(key, (K, m))
    codes = jax.random.randint(jax.random.fold_in(key, 1), (n, K), 0, m)
    mask = jnp.zeros((K,), bool).at[:2].set(True)
    for cb_mask in (None, mask):
        want = jnp.stack([lut[k][codes[:, k]] for k in range(K)], axis=1)
        if cb_mask is not None:
            want = want * cb_mask[None, :].astype(want.dtype)
        want = jnp.sum(want, axis=1)
        got = srch.lut_sum(lut, codes, cb_mask)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6)
    # batched luts against shared codes, and per-query candidate codes
    nq = 4
    luts = jax.random.normal(jax.random.fold_in(key, 2), (nq, K, m))
    got_b = srch.lut_sum(luts, codes)
    want_b = jnp.stack([srch.lut_sum(luts[i], codes) for i in range(nq)])
    np.testing.assert_allclose(np.asarray(got_b), np.asarray(want_b),
                               rtol=1e-5, atol=1e-5)
    cand = jax.random.randint(jax.random.fold_in(key, 3), (nq, 9, K), 0, m)
    got_c = srch.lut_sum(luts, cand)
    want_c = jnp.stack([srch.lut_sum(luts[i], cand[i]) for i in range(nq)])
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c),
                               rtol=1e-6)


def _random_problem(key, n, nq, K, m, kf, d=16, sigma=1.0):
    C = jax.random.normal(key, (K, m, d)) * 0.3
    codes = jax.random.randint(jax.random.fold_in(key, 1), (n, K), 0,
                               m).astype(jnp.uint8)
    fast = jnp.zeros((K,), bool).at[:kf].set(True)
    st = icq_mod.ICQStructure(xi=jnp.ones((d,), bool), fast_mask=fast,
                              sigma=jnp.asarray(sigma))
    q = jax.random.normal(jax.random.fold_in(key, 2), (nq, d))
    return q, codes, C, st


@pytest.mark.parametrize("n,nq,K,m,kf", [
    (257, 5, 4, 16, 1),      # non-divisible n/nq, |K_fast| = 1
    (530, 7, 8, 32, 7),      # |K_fast| = K - 1
    (1024, 16, 8, 32, 2),    # divisible shapes
])
def test_batched_backends_parity(key, n, nq, K, m, kf):
    """jnp-vectorized == lax.map oracle == pallas fused kernels: exact
    index parity, 1e-4 distance parity, identical pass accounting."""
    from repro.kernels.ref import two_step_search_looped
    q, codes, C, st = _random_problem(jax.random.fold_in(key, n), n, nq,
                                      K, m, kf)
    topk = 17
    r_loop = two_step_search_looped(q, codes, C, st, topk)
    r_jnp = two_step_search(q, codes, C, st, topk, backend="jnp")
    r_pal = two_step_search(q, codes, C, st, topk, backend="pallas",
                            interpret=True, block_q=3, block_n=200)
    np.testing.assert_array_equal(np.asarray(r_jnp.indices),
                                  np.asarray(r_loop.indices))
    np.testing.assert_allclose(np.asarray(r_jnp.distances),
                               np.asarray(r_loop.distances), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(r_pal.indices),
                                  np.asarray(r_jnp.indices))
    np.testing.assert_allclose(np.asarray(r_pal.distances),
                               np.asarray(r_jnp.distances), atol=1e-4)
    assert float(r_pal.pass_rate) == pytest.approx(float(r_jnp.pass_rate),
                                                   abs=1e-5)
    assert float(r_pal.avg_ops) == pytest.approx(float(r_jnp.avg_ops),
                                                 abs=1e-4)


def test_query_chunking_is_invariant(key):
    q, codes, C, st = _random_problem(key, 400, 11, 4, 16, 2)
    r_full = two_step_search(q, codes, C, st, 9, backend="jnp")
    r_chunk = two_step_search(q, codes, C, st, 9, backend="jnp",
                              query_chunk=3)
    np.testing.assert_array_equal(np.asarray(r_full.indices),
                                  np.asarray(r_chunk.indices))
    np.testing.assert_allclose(np.asarray(r_full.distances),
                               np.asarray(r_chunk.distances), rtol=1e-6)
    assert float(r_full.pass_rate) == pytest.approx(
        float(r_chunk.pass_rate), abs=1e-6)


def test_adc_backend_parity(key):
    q, codes, C, st = _random_problem(key, 300, 6, 4, 16, 2)
    r_j = adc_search(q, codes, C, 12, backend="jnp")
    r_p = adc_search(q, codes, C, 12, backend="pallas", interpret=True,
                     block_q=4, block_n=128)
    np.testing.assert_array_equal(np.asarray(r_j.indices),
                                  np.asarray(r_p.indices))
    np.testing.assert_allclose(np.asarray(r_j.distances),
                               np.asarray(r_p.distances), atol=1e-4)


def test_pallas_backend_matches_jnp_on_seed_model(model):
    """Acceptance: on the seed config the fused-kernel backend matches
    the jnp reference on indices exactly (hence recall) and on the ops
    accounting (avg_ops / pass_rate)."""
    m, xtr, ytr, xte, yte = model
    emb = m.embed(xte)
    r_j = two_step_search(emb, m.codes, m.C, m.structure, 20, backend="jnp")
    r_p = two_step_search(emb, m.codes, m.C, m.structure, 20,
                          backend="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(r_j.indices),
                                  np.asarray(r_p.indices))
    np.testing.assert_allclose(np.asarray(r_j.distances),
                               np.asarray(r_p.distances), atol=1e-4)
    assert float(r_p.avg_ops) == pytest.approx(float(r_j.avg_ops), abs=1e-4)
    assert float(r_p.pass_rate) == pytest.approx(float(r_j.pass_rate),
                                                 abs=1e-5)
    map_j = float(mean_average_precision(r_j.indices, ytr, yte))
    map_p = float(mean_average_precision(r_p.indices, ytr, yte))
    assert map_p == pytest.approx(map_j, abs=1e-9)


def test_codes_stored_packed_and_width_invariant(model):
    """The fitted model stores uint8 codes (m <= 256); searching packed
    vs pre-widened int32 codes is bit-identical."""
    m, xtr, ytr, xte, yte = model
    assert m.codes.dtype == jnp.uint8
    emb = m.embed(xte)
    r_u8 = two_step_search(emb, m.codes, m.C, m.structure, 15, backend="jnp")
    r_i32 = two_step_search(emb, m.codes.astype(jnp.int32), m.C,
                            m.structure, 15, backend="jnp")
    np.testing.assert_array_equal(np.asarray(r_u8.indices),
                                  np.asarray(r_i32.indices))
    np.testing.assert_array_equal(np.asarray(r_u8.distances),
                                  np.asarray(r_i32.distances))


def test_map_metric_sane():
    ids = jnp.asarray([[0, 1, 2]])
    db = jnp.asarray([5, 5, 7])
    q = jnp.asarray([5])
    m = float(mean_average_precision(ids, db, q))
    assert abs(m - 1.0) < 1e-6          # both relevant docs ranked first
    q2 = jnp.asarray([7])
    m2 = float(mean_average_precision(ids, db, q2))
    assert m2 == pytest.approx(1 / 3)


def test_fitted_structure_invariants(model):
    m, *_ = model
    assert int(m.structure.xi.sum()) >= 1
    assert int(m.structure.fast_mask.sum()) == m.icq_cfg.num_fast
    assert float(m.structure.sigma) >= 0
    # projection happened: eq. 6 is exactly satisfied on the exported C
    from repro.core import losses
    assert float(losses.icq_loss(m.C, m.structure.xi)) < 1e-4


def test_ivf_icq_composition(model):
    """Beyond-paper: IVF coarse partitioning composed with the two-step —
    ops must drop further at no MAP loss vs plain ICQ (the production
    ANN deployment shape)."""
    from repro.core.ivf import build_ivf, ivf_two_step_search
    m, xtr, ytr, xte, yte = model
    emb_db, emb_q = m.embed(xtr), m.embed(xte)
    ivf = build_ivf(jax.random.PRNGKey(1), emb_db, n_lists=32)
    assert ivf.imbalance < 10.0
    r_icq = two_step_search(emb_q, m.codes, m.C, m.structure, 20)
    r_ivf = ivf_two_step_search(emb_q, m.codes, m.C, m.structure, ivf,
                                20, n_probe=8)
    map_icq = float(mean_average_precision(r_icq.indices, ytr, yte))
    map_ivf = float(mean_average_precision(r_ivf.indices, ytr, yte))
    assert map_ivf >= map_icq - 0.03
    assert float(r_ivf.avg_ops) < float(r_icq.avg_ops)
