"""Online variance (paper eq. 9) against numpy, + merge properties."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; CI installs it
from hypothesis import given, settings, strategies as st

from repro.core import variance as V


def _run_batches(x, bs):
    st_ = V.init_state(x.shape[1])
    for i in range(0, len(x), bs):
        st_ = V.update(st_, jnp.asarray(x[i: i + bs]))
    return st_


def test_equal_batches_match_numpy():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((1024, 8)) * rng.uniform(0.5, 3, 8)
    state = _run_batches(x, 128)
    np.testing.assert_allclose(np.asarray(V.lambda_hat(state)),
                               x.var(axis=0), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(V.lambda_exact(state)),
                               x.var(axis=0), rtol=1e-5)


@settings(deadline=None, max_examples=20)
@given(st.integers(1, 7), st.integers(10, 200))
def test_exact_estimator_batchsize_invariant(nb, n):
    """Property: the (n, m2) accumulators give the pooled variance exactly
    regardless of batch partitioning (paper's estimator is exact only for
    equal batches — the exact merge covers ragged tails)."""
    rng = np.random.default_rng(nb * 1000 + n)
    x = rng.standard_normal((n, 4)) * 2 + 1
    bs = max(n // nb, 1)
    state = _run_batches(x, bs)
    np.testing.assert_allclose(np.asarray(V.lambda_exact(state)),
                               x.var(axis=0), rtol=1e-4, atol=1e-7)


def test_welford_merge_cross_host():
    rng = np.random.default_rng(3)
    a = rng.standard_normal((100, 4))
    b = rng.standard_normal((37, 4)) * 3 + 2
    sa = _run_batches(a, 25)
    sb = _run_batches(b, 10)
    merged = V.welford_merge(sa, sb)
    np.testing.assert_allclose(np.asarray(V.lambda_exact(merged)),
                               np.concatenate([a, b]).var(axis=0), rtol=1e-4)
