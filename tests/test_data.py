"""Data generators + pipelines."""
import numpy as np
import pytest

from repro.data import (ArrayPipeline, TokenPipeline, guyon_dataset,
                        make_table1_dataset, pseudo_cifar, pseudo_mnist)


def test_table1_specs():
    for name, n_inf in [("dataset1", 32), ("dataset2", 16), ("dataset3", 8)]:
        xtr, ytr, xte, yte = make_table1_dataset(name)
        assert xtr.shape == (10000, 64) and xte.shape == (1000, 64)
        assert ytr.shape == (10000,) and set(np.unique(ytr)) <= set(range(10))


def test_guyon_informative_dims_carry_signal():
    X, y = guyon_dataset(4000, 32, 8, n_classes=4, seed=0,
                         shuffle_features=False)
    # between-class variance concentrated in informative dims
    overall = X.var(axis=0)
    within = np.mean([X[y == c].var(axis=0) for c in range(4)], axis=0)
    between = overall - within
    assert between[:8].mean() > 5 * max(between[24:].mean(), 1e-6)


def test_pseudo_datasets_separable():
    for gen, d in [(pseudo_mnist, 784), (pseudo_cifar, 3072)]:
        xtr, ytr, xte, yte = gen(n_train=1000, n_test=200, seed=0)
        assert xtr.shape == (1000, d)
        assert xtr.min() >= 0 and xtr.max() <= 1
        # nearest-centroid accuracy far above chance -> class structure
        cents = np.stack([xtr[ytr == c].mean(0) for c in range(10)])
        pred = np.argmin(
            ((xte[:, None] - cents[None]) ** 2).sum(-1), axis=1)
        assert (pred == yte).mean() > 0.4


def test_token_pipeline_determinism_and_sharding():
    p0 = TokenPipeline(vocab_size=100, seq_len=16, global_batch=8,
                       num_hosts=2, host_id=0, seed=1)
    p0b = TokenPipeline(vocab_size=100, seq_len=16, global_batch=8,
                        num_hosts=2, host_id=0, seed=1)
    p1 = TokenPipeline(vocab_size=100, seq_len=16, global_batch=8,
                       num_hosts=2, host_id=1, seed=1)
    b0 = p0.batch(5)
    assert b0["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b0["tokens"], p0b.batch(5)["tokens"])
    assert not np.array_equal(b0["tokens"], p1.batch(5)["tokens"])


def test_array_pipeline_epoch_cover():
    x = np.arange(100)[:, None].astype(np.float32)
    y = np.arange(100).astype(np.int32)
    pipe = ArrayPipeline(x, y, batch_size=10)
    seen = []
    for xb, yb in pipe.epoch(0):
        assert xb.shape == (10, 1)
        seen.extend(yb.tolist())
    assert sorted(seen) == list(range(100))
    # different epoch -> different order
    order1 = [yb[0] for _, yb in pipe.epoch(1)]
    order0 = [yb[0] for _, yb in pipe.epoch(0)]
    assert order0 != order1
