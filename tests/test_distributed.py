"""Checkpoint manager, fault-tolerant supervisor, heartbeat monitor,
elastic re-mesh planning, and the sharding rule tables."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import (CheckpointManager, HeartbeatMonitor,
                               TrainSupervisor, plan_mesh_shape)
from repro.distributed import sharding as shrules


# ------------------------------------------------------------ checkpoint --

def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.zeros((4,))},
            "step": jnp.asarray(int(v), jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state(3.0)
    mgr.save(10, s)
    step, restored = mgr.restore_latest(_state())
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.full((4, 4), 3.0))


def test_checkpoint_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for i in range(5):
        mgr.save(i, _state(float(i)))
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_keep_period(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=1, keep_period=2)
    for i in range(5):
        mgr.save(i, _state(float(i)))
    assert set(mgr.all_steps()) == {0, 2, 4}


def test_corrupt_latest_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _state(1.0))
    mgr.save(2, _state(2.0))
    # corrupt the newest arrays file
    with open(os.path.join(str(tmp_path), "step_00000002", "arrays.npz"),
              "wb") as f:
        f.write(b"garbage")
    step, restored = mgr.restore_latest(_state())
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.full((4, 4), 1.0))


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(7, _state(7.0))
    mgr.wait()
    assert mgr.all_steps() == [7]


# ------------------------------------------------------------- supervisor --

def test_supervisor_restart_after_fault(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    sup = TrainSupervisor(mgr, save_every=2, async_save=False)
    crashed = {"done": False}

    def fault_hook(step):
        if step == 5 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("simulated node loss")

    def step_fn(state, idx):
        return ({"params": {"w": state["params"]["w"] + 1.0},
                 "step": jnp.asarray(idx)}, {"loss": 1.0})

    state, rep = sup.run({"params": {"w": jnp.zeros(())},
                          "step": jnp.asarray(0)}, step_fn, 8,
                         fault_hook=fault_hook)
    assert rep.restarts == 1
    assert rep.final_step == 7
    # replayed steps 5.. from the step-4 checkpoint: total = 8 increments
    assert float(state["params"]["w"]) == 8.0


def test_supervisor_nan_quarantine(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    sup = TrainSupervisor(mgr, save_every=100, async_save=False)

    def step_fn(state, idx):
        loss = float("nan") if idx == 3 else 0.5
        return ({"params": {"w": state["params"]["w"] + 1.0},
                 "step": jnp.asarray(idx)}, {"loss": loss})

    state, rep = sup.run({"params": {"w": jnp.zeros(())},
                          "step": jnp.asarray(0)}, step_fn, 6)
    assert rep.nan_skips == 1
    assert float(state["params"]["w"]) == 5.0     # one update dropped


def test_supervisor_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, {"params": {"w": jnp.asarray(42.0)}, "step": jnp.asarray(3)})
    sup = TrainSupervisor(mgr, save_every=100, async_save=False)

    def step_fn(state, idx):
        return ({"params": {"w": state["params"]["w"] + 1.0},
                 "step": jnp.asarray(idx)}, {"loss": 0.1})

    state, rep = sup.run({"params": {"w": jnp.zeros(())},
                          "step": jnp.asarray(0)}, step_fn, 6)
    assert rep.resumed_from == 3
    assert float(state["params"]["w"]) == 44.0    # steps 4,5 applied


def test_supervisor_restart_budget_exhausted(tmp_path):
    """A permanent fault propagates once ``max_restarts`` is spent —
    the supervisor never spins forever on a dead fleet."""
    mgr = CheckpointManager(str(tmp_path))
    sup = TrainSupervisor(mgr, save_every=1, max_restarts=2,
                          async_save=False)
    attempts = {"n": 0}

    def fault_hook(step):
        attempts["n"] += 1
        raise RuntimeError("permanent node loss")

    def step_fn(state, idx):
        return state, {"loss": 0.1}

    with pytest.raises(RuntimeError, match="permanent node loss"):
        sup.run({"params": {"w": jnp.zeros(())}}, step_fn, 4,
                fault_hook=fault_hook)
    assert attempts["n"] == 3          # initial try + max_restarts


def test_supervisor_always_writes_final_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    sup = TrainSupervisor(mgr, save_every=100, async_save=False)

    def step_fn(state, idx):
        return ({"params": {"w": state["params"]["w"] + 1.0}},
                {"loss": 0.1})

    sup.run({"params": {"w": jnp.zeros(())}}, step_fn, 3)
    # save_every never fired, but the final state is still durable
    assert 2 in mgr.all_steps()


# -------------------------------------------------------------- heartbeat --

def test_heartbeat_straggler_detection():
    mon = HeartbeatMonitor(num_hosts=4, straggler_factor=3.0)
    for step in range(8):
        for h in range(4):
            mon.beat(h, 1.0 if h != 2 else 5.0)
    assert mon.stragglers() == [2]


def test_heartbeat_window_trims_history():
    mon = HeartbeatMonitor(num_hosts=1, window=8)
    for i in range(50):
        mon.beat(0, float(i))
    assert len(mon._latency[0]) == 8
    assert mon._latency[0][-1] == 49.0


def test_heartbeat_no_beats_no_stragglers():
    # median of an empty fleet must not divide by zero or flag anyone
    mon = HeartbeatMonitor(num_hosts=3)
    assert mon.stragglers() == [] and mon.fleet_median() == 0.0


def test_heartbeat_dead_host():
    mon = HeartbeatMonitor(num_hosts=2, dead_after=10.0)
    now = 1000.0
    mon.beat(0, 1.0, now=now)
    mon.beat(1, 1.0, now=now - 60.0)
    mon._last_seen[1] = now - 60.0
    assert mon.dead(now=now) == [1]


# ---------------------------------------------------------------- elastic --

@pytest.mark.parametrize("n,divisors,expect", [
    (256, (16, 128), (16, 16)),
    (255, (16, 128), (8, 16)),     # lost a chip: pow2 floor 128 -> 8x16
    (8, (4,), (2, 4)),
    (8, (3,), (8, 1)),             # model must divide heads: falls to 1
])
def test_plan_mesh_shape(n, divisors, expect):
    assert plan_mesh_shape(n, model_divisors=divisors) == expect


# ------------------------------------------------------------- shardings --

def test_param_pspec_tables(key):
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import abstract_mesh
    mesh = abstract_mesh((1, 1), ("data", "model"))

    class Leaf:
        def __init__(self, shape):
            self.shape = shape

    # embed (V, d) -> (model, data); divisibility guard passes at size 1
    spec = shrules.param_pspec(
        (jax.tree_util.DictKey("embed"),), Leaf((100, 64)), mesh)
    assert spec == P(None, None)   # axis size 1 -> replicated by guard

    mesh2 = abstract_mesh((2, 2), ("data", "model"))
    spec2 = shrules.param_pspec(
        (jax.tree_util.DictKey("embed"),), Leaf((100, 64)), mesh2)
    assert spec2 == P("model", "data")
    # odd vocab falls back to replicated on that dim
    spec3 = shrules.param_pspec(
        (jax.tree_util.DictKey("embed"),), Leaf((101, 64)), mesh2)
    assert spec3 == P(None, "data")


def test_every_smoke_param_gets_a_spec():
    """The rule table must cover every parameter of every architecture
    (falling back to replication is fine; crashing is not)."""
    from repro.configs import list_archs, smoke_config
    from repro.models import build_model
    from repro.distributed.sharding import abstract_mesh
    mesh = abstract_mesh((2, 2), ("data", "model"))
    for arch in list_archs():
        cfg = smoke_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        shardings = shrules.param_shardings(shapes, mesh)
        assert (jax.tree_util.tree_structure(shardings)
                == jax.tree_util.tree_structure(shapes)), arch
