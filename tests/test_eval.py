"""Oracle tests for the evaluation core (``repro.eval``): recall@k
against hand-computed answers (ties, -1 padding, k > n, vacuous truth),
the brute-force ground-truth helper vs a naive numpy oracle (filtered
and unfiltered), the content-keyed ground-truth cache, and the Pareto
frontier / operating-point selection used by ``--only pareto`` and
``ICQSession.tune``."""
import numpy as np
import pytest

from repro import eval as ev


# ------------------------------------------------------------ recall ----

def test_recall_at_k_hand_computed():
    # q0: 2/3 recovered; q1: all 3 -> mean 5/6
    retrieved = np.array([[1, 2, 9], [4, 5, 6]])
    truth = np.array([[1, 2, 3], [6, 5, 4]])
    assert ev.recall_at_k(retrieved, truth) == pytest.approx(5 / 6)


def test_recall_at_k_order_independent():
    # set overlap, not position match
    assert ev.recall_at_k(np.array([[3, 2, 1]]),
                          np.array([[1, 2, 3]])) == 1.0


def test_recall_at_k_truncates_to_k():
    retrieved = np.array([[1, 9, 2]])
    truth = np.array([[1, 2, 9]])
    assert ev.recall_at_k(retrieved, truth, 2) == pytest.approx(0.5)


def test_recall_at_k_negative_ids_are_padding():
    # -1 in retrieved never matches; -1 in truth shrinks the denominator
    assert ev.recall_at_k(np.array([[1, -1, -1]]),
                          np.array([[1, 2, -1]])) == pytest.approx(0.5)
    # a -1 in retrieved must not "hit" a -1 in truth
    assert ev.recall_at_k(np.array([[-1]]), np.array([[-1]])) == 1.0


def test_recall_at_k_k_larger_than_n():
    # truth for a 2-row database padded to k=4: recall measured against
    # the 2 neighbors that exist
    retrieved = np.array([[0, 1, -1, -1]])
    truth = np.array([[1, 0, -1, -1]])
    assert ev.recall_at_k(retrieved, truth, 4) == 1.0


def test_recall_at_k_vacuous_truth_is_one():
    assert ev.recall_at_k(np.array([[0, 1]]),
                          np.array([[-1, -1]])) == 1.0


def test_recall_at_k_rejects_bad_shapes():
    with pytest.raises(ValueError, match="recall_at_k"):
        ev.recall_at_k(np.array([1, 2]), np.array([[1, 2]]))
    with pytest.raises(ValueError, match="k must be positive"):
        ev.recall_at_k(np.array([[1]]), np.array([[1]]), 0)


def test_tie_aware_recall_accepts_either_tied_row():
    # db rows 1 and 2 are identical -> both tie at the k=2 boundary;
    # an engine may return either without penalty
    db = np.array([[0.0], [1.0], [1.0], [5.0]])
    q = np.array([[0.0]])
    for pick in (1, 2):
        assert ev.tie_aware_recall_at_k(np.array([[0, pick]]), q, db,
                                        2) == 1.0
    # but a genuinely wrong id is still a miss
    assert ev.tie_aware_recall_at_k(np.array([[0, 3]]), q, db,
                                    2) == pytest.approx(0.5)


def test_tie_aware_recall_filtered_denominator():
    # filter passes one row -> denominator is 1, retrieving it = recall 1
    db = np.array([[0.0], [1.0], [2.0]])
    pred = np.array([False, True, False])
    assert ev.tie_aware_recall_at_k(np.array([[1, -1]]), np.array([[0.0]]),
                                    db, 2, filter=pred) == 1.0


# ------------------------------------------------------ ground truth ----

def _naive_gt(db, q, k, pred=None):
    d2 = ((q[:, None, :] - db[None, :, :]) ** 2).sum(-1)
    if pred is not None:
        d2 = np.where(pred[None, :], d2, np.inf)
    ids = np.argsort(d2, axis=1, kind="stable")[:, :k]
    out = np.where(np.take_along_axis(d2, ids, 1) < np.inf, ids, -1)
    return out


def test_ground_truth_matches_naive(rng):
    db = rng.standard_normal((40, 6)).astype(np.float32)
    q = rng.standard_normal((7, 6)).astype(np.float32)
    ids, dist = ev.ground_truth(db, q, 5, query_chunk=3)
    np.testing.assert_array_equal(ids, _naive_gt(db, q, 5))
    assert dist.shape == (7, 5) and np.all(np.diff(dist, axis=1) >= 0)


def test_ground_truth_filtered_matches_naive(rng):
    db = rng.standard_normal((30, 4)).astype(np.float32)
    q = rng.standard_normal((5, 4)).astype(np.float32)
    pred = rng.random(30) < 0.4
    ids, dist = ev.ground_truth(db, q, 6, filter=pred)
    np.testing.assert_array_equal(ids, _naive_gt(db, q, 6, pred))
    # every returned id passes the predicate
    assert all(pred[i] for i in ids.ravel() if i >= 0)


def test_ground_truth_pads_when_short(rng):
    db = rng.standard_normal((3, 4)).astype(np.float32)
    q = rng.standard_normal((2, 4)).astype(np.float32)
    ids, dist = ev.ground_truth(db, q, 5)
    assert ids.shape == (2, 5)
    np.testing.assert_array_equal(ids[:, 3:], -1)
    assert np.all(np.isinf(dist[:, 3:]))
    # filter passing < k rows pads the same way
    pred = np.zeros(3, bool)
    pred[1] = True
    ids_f, _ = ev.ground_truth(db, q, 5, filter=pred)
    np.testing.assert_array_equal(ids_f[:, 0], 1)
    np.testing.assert_array_equal(ids_f[:, 1:], -1)


def test_cached_ground_truth_content_keyed(rng, tmp_path):
    db = rng.standard_normal((20, 4)).astype(np.float32)
    q = rng.standard_normal((4, 4)).astype(np.float32)
    cd = str(tmp_path)
    ids1, d1, hit1 = ev.cached_ground_truth(db, q, 3, cache_dir=cd)
    ids2, d2, hit2 = ev.cached_ground_truth(db, q, 3, cache_dir=cd)
    assert (hit1, hit2) == (False, True)
    np.testing.assert_array_equal(ids1, ids2)
    np.testing.assert_array_equal(d1, d2)
    # perturbing one db value must miss the cache (content keying)
    db2 = db.copy()
    db2[0, 0] += 1.0
    _, _, hit3 = ev.cached_ground_truth(db2, q, 3, cache_dir=cd)
    assert hit3 is False
    # a different filter is a different key too
    pred = np.ones(20, bool)
    pred[0] = False
    _, _, hit4 = ev.cached_ground_truth(db, q, 3, cache_dir=cd,
                                        filter=pred)
    assert hit4 is False
    # cache_dir=None computes without touching disk
    _, _, hit5 = ev.cached_ground_truth(db, q, 3, cache_dir=None)
    assert hit5 is False


# ----------------------------------------------------- pareto / tune ----

def test_pareto_frontier_hand_computed():
    pts = [dict(qps=100, recall=0.5), dict(qps=50, recall=0.9),
           dict(qps=80, recall=0.4),          # dominated by the first
           dict(qps=50, recall=0.7),          # dominated by the second
           dict(qps=10, recall=0.95)]
    assert ev.pareto_frontier(pts) == [0, 1, 4]
    frontier = [pts[i] for i in ev.pareto_frontier(pts)]
    assert ev.is_monotone_frontier(frontier)
    assert not ev.is_monotone_frontier([pts[0], pts[2], pts[4]])


def test_pareto_frontier_drops_duplicates():
    pts = [dict(qps=10, recall=0.5), dict(qps=10, recall=0.5)]
    assert len(ev.pareto_frontier(pts)) == 1


def test_select_operating_point():
    pts = [dict(qps=100, recall=0.5), dict(qps=50, recall=0.85),
           dict(qps=20, recall=0.95)]
    # fastest point meeting the target
    assert ev.select_operating_point(pts, 0.8) == (1, True)
    assert ev.select_operating_point(pts, 0.5) == (0, True)
    # unreachable target falls back to max recall
    assert ev.select_operating_point(pts, 0.99) == (2, False)
    with pytest.raises(ValueError, match="empty sweep"):
        ev.select_operating_point([], 0.5)
